//! Bench E5 (paper Fig. 5): SQNN/FQNN transistor ratios across the six
//! network sizes and K = 1..5.
use nvnmd::benchkit::Bench;
use nvnmd::hw::synth::{mlp_netlist, WeightDatapath, FQNN_BITS, Q13_BITS};

fn main() {
    let mut b = Bench::new("fig5_hw_overhead");
    b.measure("synthesize_silicon_sqnn_k3", || {
        mlp_netlist(&[64, 64, 64, 3], Q13_BITS, WeightDatapath::Shift { k: 3 }).transistors()
    });
    b.measure("synthesize_silicon_fqnn", || {
        mlp_netlist(&[64, 64, 64, 3], FQNN_BITS, WeightDatapath::Multiplier).transistors()
    });
    match nvnmd::exp::fig5::run() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig5 failed: {e:#}"),
    }
    b.finish();
}
