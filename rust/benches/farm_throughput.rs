//! Farm serving-path throughput: molecule-steps/second of the batched,
//! sharded [`WaterFarm`] — the measured counterpart of the §VI A₂
//! (intra-ASIC parallelization) projection. Emits host throughput for
//! inline vs threaded shard backends and the modelled lane-model
//! throughput sweep into the benchkit JSON, so `BENCH_*.json` tracks a
//! throughput trajectory PR over PR.

use nvnmd::benchkit::Bench;
use nvnmd::coordinator::farm::{random_water_systems, FarmConfig, WaterFarm};
use nvnmd::coordinator::ParallelMode;
use nvnmd::exp::water_model_or_fallback as model;
use nvnmd::hw::timing::CLOCK_HZ;
use nvnmd::util::json::{self, Value};

fn main() {
    let mut b = Bench::new("farm_throughput");
    let quick = nvnmd::benchkit::quick_mode();
    let m = model();
    let n_mols = 64usize;
    let ticks = if quick { 200 } else { 2_000 };
    let systems = random_water_systems(n_mols, 300.0, 2024);

    let mut rows: Vec<Value> = Vec::new();
    let cases = [
        ("inline_1shard", ParallelMode::Inline, 1usize),
        ("inline_4shard", ParallelMode::Inline, 4),
        ("threaded_2shard", ParallelMode::Threaded, 2),
        ("threaded_8shard", ParallelMode::Threaded, 8),
    ];
    for (label, mode, shards) in cases {
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards, mode, ..FarmConfig::default() },
        )
        .expect("farm construction");
        b.measure_once(&format!("farm_{n_mols}mol_{label}_x{ticks}"), || {
            farm.run(ticks).expect("farm run");
        });
        let ledger = farm.finish().expect("farm finish");
        // Same definition as exp::scaling's host_steps_per_s (the
        // ledger's accumulated per-tick wall), so the two reports agree.
        let steps_per_sec = ledger.host_steps_per_second();
        b.note(
            &format!("{label}_molecule_steps_per_sec"),
            format!("{steps_per_sec:.0}"),
        );
        rows.push(json::obj(vec![
            ("label", json::s(label)),
            ("n_molecules", json::num(n_mols as f64)),
            ("shards", json::num(shards as f64)),
            ("ticks", json::num(ticks as f64)),
            ("molecule_steps_per_sec", json::num(steps_per_sec)),
            (
                "modelled_steps_per_sec",
                json::num(ledger.modelled_steps_per_second(CLOCK_HZ)),
            ),
        ]));
    }

    // Modelled lane-model sweep (the A₂ story in numbers): same farm,
    // chip lane count rising with transistor density — throughput on the
    // modelled hardware, independent of host speed.
    let mut lane_rows: Vec<Value> = Vec::new();
    for lanes in [1usize, 4, 16, 64] {
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 4, lanes, ..FarmConfig::default() },
        )
        .expect("farm construction");
        farm.run(if quick { 20 } else { 100 }).expect("farm run");
        let ledger = farm.finish().expect("farm finish");
        let modelled = ledger.modelled_steps_per_second(CLOCK_HZ);
        b.note(
            &format!("modelled_steps_per_sec_lanes{lanes}"),
            format!("{modelled:.0}"),
        );
        lane_rows.push(json::obj(vec![
            ("lanes", json::num(lanes as f64)),
            ("modelled_steps_per_sec", json::num(modelled)),
            (
                "s_per_step_atom",
                json::num(ledger.s_per_step_atom(CLOCK_HZ)),
            ),
        ]));
    }

    b.attach("farm", Value::Arr(rows));
    b.attach("lane_sweep", Value::Arr(lane_rows));
    b.finish();
}
