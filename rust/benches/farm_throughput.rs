//! Farm serving-path throughput: molecule-steps/second of the batched,
//! sharded [`WaterFarm`] — the measured counterpart of the §VI A₂
//! (intra-ASIC parallelization) projection. Every shard's MLP stage
//! runs the SWAR shift-program batch kernel (`nn::sqnn`), so these
//! numbers track the end-to-end serving effect of the kernel work that
//! `hotpath_micro`'s `batch_sweep` isolates — plus the mixed-species
//! [`MoleculeFarm`] (water + ethanol-class molecules, each shard
//! programmed with its own species model) reporting molecule-steps/s
//! **per species**, and the serving `Gateway`'s saturation sweep
//! (offered load × deadline window: p99 latency, reject-rate,
//! steps/s). Emits host throughput for inline vs threaded shard
//! backends and the modelled lane-model throughput sweep into the
//! benchkit JSON, so `BENCH_*.json` tracks a throughput trajectory PR
//! over PR.

use nvnmd::benchkit::Bench;
use nvnmd::coordinator::farm::{random_water_systems, FarmConfig, MoleculeFarm, WaterFarm};
use nvnmd::coordinator::ParallelMode;
use nvnmd::exp::scaling::{measure_gateway_saturation, mixed_farm_groups};
use nvnmd::exp::water_model_or_fallback as model;
use nvnmd::hw::timing::CLOCK_HZ;
use nvnmd::util::json::{self, Value};

fn main() {
    let mut b = Bench::new("farm_throughput");
    let quick = nvnmd::benchkit::quick_mode();
    let m = model();
    let n_mols = 64usize;
    let ticks = if quick { 200 } else { 2_000 };
    let systems = random_water_systems(n_mols, 300.0, 2024);

    let mut rows: Vec<Value> = Vec::new();
    let cases = [
        ("inline_1shard", ParallelMode::Inline, 1usize),
        ("inline_4shard", ParallelMode::Inline, 4),
        ("threaded_2shard", ParallelMode::Threaded, 2),
        ("threaded_8shard", ParallelMode::Threaded, 8),
    ];
    for (label, mode, shards) in cases {
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards, mode, ..FarmConfig::default() },
        )
        .expect("farm construction");
        b.measure_once(&format!("farm_{n_mols}mol_{label}_x{ticks}"), || {
            farm.run(ticks).expect("farm run");
        });
        let ledger = farm.finish().expect("farm finish");
        // Same definition as exp::scaling's host_steps_per_s (the
        // ledger's accumulated per-tick wall), so the two reports agree.
        let steps_per_sec = ledger.host_steps_per_second();
        b.note(
            &format!("{label}_molecule_steps_per_sec"),
            format!("{steps_per_sec:.0}"),
        );
        rows.push(json::obj(vec![
            ("label", json::s(label)),
            ("n_molecules", json::num(n_mols as f64)),
            ("shards", json::num(shards as f64)),
            ("ticks", json::num(ticks as f64)),
            ("molecule_steps_per_sec", json::num(steps_per_sec)),
            (
                "modelled_steps_per_sec",
                json::num(ledger.modelled_steps_per_second(CLOCK_HZ)),
            ),
        ]));
    }

    // Modelled lane-model sweep (the A₂ story in numbers): same farm,
    // chip lane count rising with transistor density — throughput on the
    // modelled hardware, independent of host speed.
    let mut lane_rows: Vec<Value> = Vec::new();
    for lanes in [1usize, 4, 16, 64] {
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 4, lanes, ..FarmConfig::default() },
        )
        .expect("farm construction");
        farm.run(if quick { 20 } else { 100 }).expect("farm run");
        let ledger = farm.finish().expect("farm finish");
        let modelled = ledger.modelled_steps_per_second(CLOCK_HZ);
        b.note(
            &format!("modelled_steps_per_sec_lanes{lanes}"),
            format!("{modelled:.0}"),
        );
        lane_rows.push(json::obj(vec![
            ("lanes", json::num(lanes as f64)),
            ("modelled_steps_per_sec", json::num(modelled)),
            (
                "s_per_step_atom",
                json::num(ledger.s_per_step_atom(CLOCK_HZ)),
            ),
        ]));
    }

    // Mixed-species serving tier: two species with distinct per-shard
    // models (water 3→…→2, ethanol 32→…→3) in one farm — host
    // molecule-steps/s per species, inline and threaded. The farm shape
    // is the shared `exp::scaling::mixed_farm_groups` definition, so
    // this bench and the scaling report measure the same tier.
    let mixed_ticks = if quick { 50 } else { 500 };
    let mut mixed_rows: Vec<Value> = Vec::new();
    for (label, mode) in [("inline", ParallelMode::Inline), ("threaded", ParallelMode::Threaded)] {
        let groups = mixed_farm_groups(48, 16, 2024, 4048).expect("mixed groups");
        let mut farm = MoleculeFarm::new(groups, 1, mode).expect("farm construction");
        b.measure_once(&format!("mixed_farm_{label}_x{mixed_ticks}"), || {
            farm.run(mixed_ticks).expect("farm run");
        });
        let ledger = farm.finish().expect("farm finish");
        let farm_elapsed = ledger.host_wall.as_secs_f64();
        for sp in &ledger.species {
            // Two rates per species: achieved rate over the farm's
            // elapsed wall (species share the run), and the backend-
            // independent per-shard-second serving cost.
            let elapsed_rate =
                if farm_elapsed > 0.0 { sp.molecule_steps as f64 / farm_elapsed } else { 0.0 };
            let shard_rate = sp.steps_per_shard_second();
            b.note(
                &format!("mixed_{label}_{}_molecule_steps_per_sec", sp.name),
                format!("{elapsed_rate:.0}"),
            );
            mixed_rows.push(json::obj(vec![
                ("backend", json::s(label)),
                ("species", json::s(&sp.name)),
                ("n_molecules", json::num(sp.n_molecules as f64)),
                ("n_atoms", json::num(sp.n_atoms as f64)),
                ("ticks", json::num(mixed_ticks as f64)),
                ("molecule_steps_per_sec", json::num(elapsed_rate)),
                ("molecule_steps_per_shard_sec", json::num(shard_rate)),
                ("chip_inferences", json::num(sp.chip_inferences as f64)),
            ]));
        }
    }

    // Epoch-batched driver sweep (the perf tentpole): the same
    // mixed-species farm driven per-tick (epoch 1) and with one shard
    // job per `epoch` ticks — amortizing the threaded backend's
    // per-tick submit/recv round-trip + barrier and overlapping the
    // host's ledger folding with shard execution. Speedups are vs the
    // epoch-1 run of the same backend.
    let epoch_ticks = if quick { 128 } else { 1024 };
    let mut epoch_rows: Vec<Value> = Vec::new();
    for (label, mode) in [("inline", ParallelMode::Inline), ("threaded", ParallelMode::Threaded)] {
        let mut tick_secs = 0.0f64;
        for epoch in [1usize, 4, 16, 64] {
            let groups = mixed_farm_groups(48, 16, 2024, 4048).expect("mixed groups");
            let mut farm = MoleculeFarm::new(groups, 1, mode).expect("farm construction");
            let (_, dt) = b.measure_once(
                &format!("epoch_sweep_{label}_e{epoch}_x{epoch_ticks}"),
                || farm.run_epoched(epoch_ticks, epoch).expect("farm run"),
            );
            let ledger = farm.finish().expect("farm finish");
            let secs = dt.as_secs_f64();
            if epoch == 1 {
                tick_secs = secs;
            }
            let speedup = if secs > 0.0 { tick_secs / secs } else { 0.0 };
            b.note(
                &format!("epoch_speedup_vs_tick_{label}_e{epoch}"),
                format!("{speedup:.2}"),
            );
            epoch_rows.push(json::obj(vec![
                ("backend", json::s(label)),
                ("epoch", json::num(epoch as f64)),
                ("ticks", json::num(epoch_ticks as f64)),
                (
                    "molecule_steps_per_sec",
                    json::num(ledger.host_steps_per_second()),
                ),
                ("epoch_speedup_vs_tick", json::num(speedup)),
            ]));
        }
    }

    // Serving gateway saturation (the request front door over the
    // epoch farm): deterministic arrival plans at two offered-load
    // levels × deadline-window lengths, per backend. The arrival plans
    // are fixed by seed, so inline and threaded rows measure identical
    // request streams — p99 latency (virtual-clock ticks), door
    // reject-rate, and host molecule-steps/s per point.
    let mut gw_rows: Vec<Value> = Vec::new();
    for (label, mode) in [("inline", ParallelMode::Inline), ("threaded", ParallelMode::Threaded)] {
        let sweep = measure_gateway_saturation(mode, quick).expect("gateway sweep");
        for g in &sweep {
            b.note(
                &format!("gateway_{label}_w{}_gap{}_p99_ticks", g.window_ticks, g.mean_gap),
                format!("{}", g.p99_ticks),
            );
            b.note(
                &format!("gateway_{label}_w{}_gap{}_reject_rate", g.window_ticks, g.mean_gap),
                format!("{:.3}", g.reject_rate()),
            );
            b.note(
                &format!("gateway_{label}_w{}_gap{}_steps_per_sec", g.window_ticks, g.mean_gap),
                format!("{:.0}", g.host_steps_per_s),
            );
            gw_rows.push(g.json_row(label));
        }
    }

    b.attach("farm", Value::Arr(rows));
    b.attach("lane_sweep", Value::Arr(lane_rows));
    b.attach("mixed_species", Value::Arr(mixed_rows));
    b.attach("epoch_sweep", Value::Arr(epoch_rows));
    b.attach("gateway_saturation", Value::Arr(gw_rows));
    b.finish();
}
