//! §Perf micro-benches: the request-path hot spots of every layer —
//! Q13 arithmetic, SQNN forward, chip inference, FPGA feature/integrate,
//! the worker-pool submit/recv round-trip, full coordinator step
//! (inline and threaded), and the PJRT dispatch.
//! This is the harness the EXPERIMENTS.md §Perf iteration log is
//! measured with.

use nvnmd::asic::{ChipConfig, MlpChip};
use nvnmd::benchkit::Bench;
use nvnmd::coordinator::{ParallelMode, WaterSystem};
use nvnmd::exp::water_model_or_fallback as model;
use nvnmd::fixedpoint::{q13, Q13};
use nvnmd::fpga::WaterFpga;
use nvnmd::md::{initialize_velocities, System};
use nvnmd::nn::Sqnn;
use nvnmd::potentials::WaterPes;
use nvnmd::runtime::{Runtime, Tensor};
use nvnmd::util::json::{self, Value};
use nvnmd::util::rng::Pcg;

fn initial() -> System {
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 300.0, 6, &mut Pcg::new(3));
    sys
}

fn main() {
    let mut b = Bench::new("hotpath_micro");
    let m = model();

    // L0: fixed-point primitive ops.
    let mut rng = Pcg::new(5);
    let qa: Vec<Q13> = (0..256).map(|_| Q13::from_f64(rng.range(-2.0, 2.0))).collect();
    let qb: Vec<Q13> = (0..256).map(|_| Q13::from_f64(rng.range(-2.0, 2.0))).collect();
    b.measure("q13_mul_x256", || {
        qa.iter().zip(&qb).map(|(x, y)| x.mul(*y).0 as i64).sum::<i64>()
    });
    b.measure("q13_dot_wide_256", || q13::dot_wide(&qa, &qb).0);

    // L3a: SQNN forward (the chip datapath without accounting) — the
    // allocating convenience form (the historical §Perf series) and the
    // allocation-free `_into` the coordinator actually drives, so the
    // batch-speedup notes below can separate the batching gain from the
    // scalar wrapper's per-call Vec.
    let net = Sqnn::from_mlp(&m, 3);
    let x = [Q13::from_f64(1.03), Q13::from_f64(0.65), Q13::from_f64(1.03)];
    let scalar = b.measure("sqnn_forward_q13", || net.forward_q13(&x)[0].0);
    let mut y = [Q13::ZERO; 2];
    let scalar_into = b.measure("sqnn_forward_q13_into", || {
        net.forward_q13_into(&x, &mut y);
        y[0].0
    });

    // L3a': weight-stationary batched SQNN forward (the molecule-farm
    // serving kernel), measured with caller-owned scratch exactly as the
    // chip drives it. Each measurement runs a whole SoA batch, so
    // ns/inference = median / batch — recorded as notes for the §Perf
    // iteration log.
    //
    // Old-vs-new sweep: `sqnn_forward_batch{B}` is the serving path
    // (the SWAR shift-program kernel — same JSON key as the historical
    // §Perf series), `sqnn_reference_batch{B}` the pre-program kernel
    // kept as the reference datapath. The per-batch rows land in the
    // `batch_sweep` section of the JSON artifact so the ≥2× batch-64
    // claim is a recorded number, not prose.
    let mut batch_stats = Vec::new();
    let mut sweep_rows: Vec<Value> = Vec::new();
    let mut scratch = nvnmd::nn::sqnn::BatchScratch::default();
    let mut ref_scratch = nvnmd::nn::sqnn::BatchScratch::default();
    for batch in [1usize, 8, 16, 64] {
        let mut xs = vec![Q13::ZERO; net.in_dim() * batch];
        for (i, slot) in xs.iter_mut().enumerate() {
            *slot = Q13::from_f64(0.55 + 0.01 * (i % 23) as f64);
        }
        let mut out = vec![Q13::ZERO; net.out_dim() * batch];
        let st = b.measure(&format!("sqnn_forward_batch{batch}"), || {
            net.forward_q13_batch_with(&xs, batch, &mut out, &mut scratch);
            out[0].0
        });
        let rf = b.measure(&format!("sqnn_reference_batch{batch}"), || {
            net.forward_q13_batch_reference(&xs, batch, &mut out, &mut ref_scratch);
            out[0].0
        });
        let swar_per_inf = st.median_ns / batch as f64;
        let ref_per_inf = rf.median_ns / batch as f64;
        sweep_rows.push(json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("swar_ns_per_inference", json::num(swar_per_inf)),
            ("reference_ns_per_inference", json::num(ref_per_inf)),
            ("speedup_vs_reference", json::num(ref_per_inf / swar_per_inf)),
        ]));
        batch_stats.push((batch, st, rf));
    }
    b.attach(
        "batch_sweep",
        Value::Arr(sweep_rows),
    );
    b.note("sqnn_scalar_ns_per_inference", format!("{:.1}", scalar.median_ns));
    b.note("sqnn_scalar_into_ns_per_inference", format!("{:.1}", scalar_into.median_ns));
    for (batch, st, rf) in &batch_stats {
        b.note(
            &format!("sqnn_batch{batch}_ns_per_inference"),
            format!("{:.1}", st.median_ns / *batch as f64),
        );
        b.note(
            &format!("sqnn_batch{batch}_speedup_vs_reference"),
            format!("{:.2}x", rf.median_ns / st.median_ns),
        );
    }
    if let Some((batch, st, _)) = batch_stats.last() {
        let per_inf = st.median_ns / *batch as f64;
        let vs_scalar = scalar.median_ns / per_inf;
        let vs_into = scalar_into.median_ns / per_inf;
        b.note(
            "sqnn_batch_speedup_vs_scalar",
            format!("batch{batch}: {vs_scalar:.2}x faster than the scalar path, per inference"),
        );
        // vs the allocation-free scalar: the batching gain proper, with
        // the scalar wrapper's per-call Vec factored out.
        b.note(
            "sqnn_batch_speedup_vs_scalar_into",
            format!("batch{batch}: {vs_into:.2}x faster than the alloc-free scalar path"),
        );
    }

    // The same sweep on a wide ethanol-class model (32→16→16→3): the
    // water MLP is only 3 wide, so this is where the 8-lane tiles and
    // the fused single-term instructions have room to show up.
    {
        let wide = nvnmd::exp::molecule_model_or_fallback("ethanol");
        let wnet = Sqnn::from_mlp(&wide, 3);
        let mut wide_rows: Vec<Value> = Vec::new();
        for batch in [8usize, 64] {
            let mut xs = vec![Q13::ZERO; wnet.in_dim() * batch];
            for (i, slot) in xs.iter_mut().enumerate() {
                *slot = Q13::from_f64(0.3 + 0.007 * (i % 41) as f64);
            }
            let mut out = vec![Q13::ZERO; wnet.out_dim() * batch];
            let st = b.measure(&format!("sqnn_wide_forward_batch{batch}"), || {
                wnet.forward_q13_batch_with(&xs, batch, &mut out, &mut scratch);
                out[0].0
            });
            let rf = b.measure(&format!("sqnn_wide_reference_batch{batch}"), || {
                wnet.forward_q13_batch_reference(&xs, batch, &mut out, &mut ref_scratch);
                out[0].0
            });
            wide_rows.push(json::obj(vec![
                ("batch", json::num(batch as f64)),
                ("swar_ns_per_inference", json::num(st.median_ns / batch as f64)),
                ("reference_ns_per_inference", json::num(rf.median_ns / batch as f64)),
                ("speedup_vs_reference", json::num(rf.median_ns / st.median_ns)),
            ]));
        }
        b.attach("batch_sweep_wide", Value::Arr(wide_rows));
    }

    // L3b: chip inference with cycle/energy accounting.
    let mut chip = MlpChip::new(0, ChipConfig::default());
    chip.program(&m, 3);
    b.measure("chip_infer_accounted", || chip.infer(&x).unwrap()[0].0);

    // L3c: FPGA feature extraction + integration.
    let sys = initial();
    let mut fpga = WaterFpga::new(&sys, 0.25);
    b.measure("fpga_extract_features", || fpga.extract_features()[0].d[0].0);
    let frames = fpga.extract_features();
    b.measure("fpga_integrate", || {
        fpga.integrate(&frames, [[Q13(12), Q13(-9)]; 2]);
        fpga.steps
    });

    // L3c': generic-molecule serving path — SoA descriptor extraction +
    // conditioning and the per-step fixed-point integration for an
    // ethanol-class molecule (9 atoms, 4·n_nb = 32 features/lane).
    {
        use nvnmd::fpga::{FeatureConditioner, MoleculeFpga};
        let mol = nvnmd::potentials::ff::ethanol();
        let n_nb = 8usize;
        let gsys = nvnmd::md::System::new(mol.coords.clone(), mol.masses());
        let nb: Vec<Vec<usize>> = (0..gsys.len())
            .map(|i| nvnmd::features::reference_neighbors(&mol.coords, i, n_nb))
            .collect();
        let cond = FeatureConditioner::new(4 * n_nb, &[], &[]).unwrap();
        let mut gfpga = MoleculeFpga::new(&gsys, nb, cond, 0.25).unwrap();
        let lanes = gfpga.n_atoms();
        let mut gfeats = vec![Q13::ZERO; 4 * n_nb * lanes];
        b.measure("molecule_fpga_extract_soa_9atom", || {
            gfpga.extract_features_soa(&mut gfeats, lanes, 0);
            gfeats[0].0
        });
        let gc = vec![Q13(7); 3 * lanes];
        b.measure("molecule_fpga_integrate_soa_9atom", || {
            gfpga.integrate_soa(&gc, lanes, 0);
            gfpga.steps
        });
    }

    // L2: the supervisor↔shard transport itself — one submit/recv
    // round-trip through the worker pool. This is the per-tick sync
    // cost the epoch-batched farm driver (`MoleculeFarm::run_epoch`)
    // amortizes down to one round-trip per epoch; `farm_throughput`'s
    // `epoch_sweep` measures the end-to-end effect.
    {
        use nvnmd::coordinator::WorkerPool;
        let pool = WorkerPool::spawn("bench-counter", vec![0u64; 4]).unwrap();
        b.measure("pool_submit_recv_roundtrip", || {
            pool.submit(0, |_, c: &mut u64| {
                *c += 1;
                *c
            })
            .unwrap()
            .recv()
            .unwrap()
        });
        // Fan-out + barrier across all four workers: the full per-tick
        // transport bill of a 4-shard threaded farm before batching.
        b.measure("pool_submit_recv_barrier_4", || {
            let replies: Vec<_> = (0..4)
                .map(|i| {
                    pool.submit(i, |_, c: &mut u64| {
                        *c += 1;
                        *c
                    })
                    .unwrap()
                })
                .collect();
            replies.into_iter().map(|r| r.recv().unwrap()).sum::<u64>()
        });
        drop(pool.into_items());
    }

    // L3d: full coordinator step, inline vs threaded.
    let mut inline = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Inline).unwrap();
    b.measure("coordinator_step_inline", || {
        inline.step().unwrap();
        inline.ledger.md_steps
    });
    let mut threaded = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Threaded).unwrap();
    b.measure("coordinator_step_threaded", || {
        threaded.step().unwrap();
        threaded.ledger.md_steps
    });

    // Runtime: PJRT dispatch cost (vN path), when artifacts exist.
    let hlo = nvnmd::artifact_path("water_mlp.hlo.txt");
    if hlo.exists() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&hlo).unwrap();
        let input = Tensor::new(vec![1.03, 0.65, 1.03, 1.02, 0.66, 1.04], &[2, 3]).unwrap();
        b.measure("pjrt_water_mlp_batch2", || {
            exe.run(std::slice::from_ref(&input)).unwrap()[0].data[0]
        });
        let md = nvnmd::artifact_path("water_md_step.hlo.txt");
        if md.exists() {
            let exe2 = rt.load_hlo_text(&md).unwrap();
            let pos = Tensor::new(
                vec![0.0, 0.0, 0.0, 0.766, 0.593, 0.0, -0.766, 0.593, 0.0],
                &[3, 3],
            )
            .unwrap();
            let vel = Tensor::new(vec![0.0; 9], &[3, 3]).unwrap();
            b.measure("pjrt_water_md_step", || {
                exe2.run(&[pos.clone(), vel.clone()]).unwrap()[0].data[0]
            });
        }
    } else {
        println!("  (PJRT benches skipped: run `make artifacts`)");
    }

    // Simulation throughput summary for §Perf.
    let mut sim = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Inline).unwrap();
    let t0 = std::time::Instant::now();
    let n = 200_000;
    for _ in 0..n {
        sim.step().unwrap();
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    b.note("inline_sim_steps_per_sec", format!("{rate:.0}"));
    b.note(
        "sim_vs_modelled_hw",
        format!(
            "simulator runs {:.1}x the modelled 25 MHz hardware rate ({:.0} steps/s)",
            rate / nvnmd::hw::timing::SystemTiming::water_nominal().steps_per_second(),
            nvnmd::hw::timing::SystemTiming::water_nominal().steps_per_second()
        ),
    );
    b.finish();
}
