//! §Perf micro-benches: the request-path hot spots of every layer —
//! Q13 arithmetic, SQNN forward, chip inference, FPGA feature/integrate,
//! full coordinator step (inline and threaded), and the PJRT dispatch.
//! This is the harness the EXPERIMENTS.md §Perf iteration log is
//! measured with.

use nvnmd::asic::{ChipConfig, MlpChip};
use nvnmd::benchkit::Bench;
use nvnmd::coordinator::{ParallelMode, WaterSystem};
use nvnmd::fixedpoint::{q13, Q13};
use nvnmd::fpga::WaterFpga;
use nvnmd::md::{initialize_velocities, System};
use nvnmd::nn::{Activation, Mlp, Sqnn};
use nvnmd::potentials::WaterPes;
use nvnmd::runtime::{Runtime, Tensor};
use nvnmd::util::rng::Pcg;

fn model() -> Mlp {
    Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json")).unwrap_or_else(|_| {
        let mut rng = Pcg::new(7);
        let mut m = Mlp::init_random("fallback", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.4;
            }
        }
        m
    })
}

fn initial() -> System {
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 300.0, 6, &mut Pcg::new(3));
    sys
}

fn main() {
    let mut b = Bench::new("hotpath_micro");
    let m = model();

    // L0: fixed-point primitive ops.
    let mut rng = Pcg::new(5);
    let qa: Vec<Q13> = (0..256).map(|_| Q13::from_f64(rng.range(-2.0, 2.0))).collect();
    let qb: Vec<Q13> = (0..256).map(|_| Q13::from_f64(rng.range(-2.0, 2.0))).collect();
    b.measure("q13_mul_x256", || {
        qa.iter().zip(&qb).map(|(x, y)| x.mul(*y).0 as i64).sum::<i64>()
    });
    b.measure("q13_dot_wide_256", || q13::dot_wide(&qa, &qb).0);

    // L3a: SQNN forward (the chip datapath without accounting).
    let net = Sqnn::from_mlp(&m, 3);
    let x = [Q13::from_f64(1.03), Q13::from_f64(0.65), Q13::from_f64(1.03)];
    b.measure("sqnn_forward_q13", || net.forward_q13(&x)[0].0);

    // L3b: chip inference with cycle/energy accounting.
    let mut chip = MlpChip::new(0, ChipConfig::default());
    chip.program(&m, 3);
    b.measure("chip_infer_accounted", || chip.infer(&x).unwrap()[0].0);

    // L3c: FPGA feature extraction + integration.
    let sys = initial();
    let mut fpga = WaterFpga::new(&sys, 0.25);
    b.measure("fpga_extract_features", || fpga.extract_features()[0].d[0].0);
    let frames = fpga.extract_features();
    b.measure("fpga_integrate", || {
        fpga.integrate(&frames, [[Q13(12), Q13(-9)]; 2]);
        fpga.steps
    });

    // L3d: full coordinator step, inline vs threaded.
    let mut inline = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Inline).unwrap();
    b.measure("coordinator_step_inline", || {
        inline.step().unwrap();
        inline.ledger.md_steps
    });
    let mut threaded = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Threaded).unwrap();
    b.measure("coordinator_step_threaded", || {
        threaded.step().unwrap();
        threaded.ledger.md_steps
    });

    // Runtime: PJRT dispatch cost (vN path), when artifacts exist.
    let hlo = nvnmd::artifact_path("water_mlp.hlo.txt");
    if hlo.exists() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&hlo).unwrap();
        let input = Tensor::new(vec![1.03, 0.65, 1.03, 1.02, 0.66, 1.04], &[2, 3]).unwrap();
        b.measure("pjrt_water_mlp_batch2", || {
            exe.run(std::slice::from_ref(&input)).unwrap()[0].data[0]
        });
        let md = nvnmd::artifact_path("water_md_step.hlo.txt");
        if md.exists() {
            let exe2 = rt.load_hlo_text(&md).unwrap();
            let pos = Tensor::new(
                vec![0.0, 0.0, 0.0, 0.766, 0.593, 0.0, -0.766, 0.593, 0.0],
                &[3, 3],
            )
            .unwrap();
            let vel = Tensor::new(vec![0.0; 9], &[3, 3]).unwrap();
            b.measure("pjrt_water_md_step", || {
                exe2.run(&[pos.clone(), vel.clone()]).unwrap()[0].data[0]
            });
        }
    } else {
        println!("  (PJRT benches skipped: run `make artifacts`)");
    }

    // Simulation throughput summary for §Perf.
    let mut sim = WaterSystem::new(&m, 3, &initial(), 0.25, ParallelMode::Inline).unwrap();
    let t0 = std::time::Instant::now();
    let n = 200_000;
    for _ in 0..n {
        sim.step().unwrap();
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    b.note("inline_sim_steps_per_sec", format!("{rate:.0}"));
    b.note(
        "sim_vs_modelled_hw",
        format!(
            "simulator runs {:.1}x the modelled 25 MHz hardware rate ({:.0} steps/s)",
            rate / nvnmd::hw::timing::SystemTiming::water_nominal().steps_per_second(),
            nvnmd::hw::timing::SystemTiming::water_nominal().steps_per_second()
        ),
    );
    b.finish();
}
