//! Bench E7 (paper Table II): the four-method property comparison.
//! Honours NVNMD_BENCH_QUICK=1 for a reduced run.
use nvnmd::benchkit::Bench;
use nvnmd::exp::table2;

fn main() {
    let mut b = Bench::new("table2_properties");
    let quick = nvnmd::benchkit::quick_mode();
    let cfg = table2::Config::with_quick(quick);
    let (res, wall) = b.measure_once("table2_four_methods", || table2::run(cfg));
    match res {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("table2 unavailable (run `make artifacts`): {e:#}"),
    }
    b.note("steps per method", cfg.steps);
    b.note("total wall", format!("{wall:?}"));
    b.finish();
}
