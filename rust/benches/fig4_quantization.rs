//! Bench E4 (paper Fig. 4): CNN-vs-QNN accuracy sweep through the Q13
//! chip datapath, plus quantizer/datapath micro-benches.
use nvnmd::benchkit::Bench;
use nvnmd::quant::quantize_weight;
use nvnmd::util::rng::Pcg;

fn main() {
    let mut b = Bench::new("fig4_quantization");
    let mut rng = Pcg::new(1);
    let ws: Vec<f64> = (0..4096).map(|_| rng.range(-2.0, 2.0)).collect();
    for k in [1usize, 3, 5] {
        b.measure(&format!("quantize_weight_k{k}_x4096"), || {
            ws.iter().map(|&w| quantize_weight(w, k).terms()).sum::<usize>()
        });
    }
    match nvnmd::exp::fig4::run() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig4 unavailable (run `make artifacts`): {e:#}"),
    }
    b.finish();
}
