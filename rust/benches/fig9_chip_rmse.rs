//! Bench E6 (paper Fig. 9): chip-vs-DFT force RMSE plus the chip
//! inference hot path.
use nvnmd::benchkit::Bench;
use nvnmd::asic::{ChipConfig, MlpChip};
use nvnmd::fixedpoint::Q13;
use nvnmd::nn::Mlp;

fn main() {
    let mut b = Bench::new("fig9_chip_rmse");
    if let Ok(model) = Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json")) {
        let mut chip = MlpChip::new(0, ChipConfig::default());
        chip.program(&model, model.quant_k.max(3));
        let x = [Q13::from_f64(1.03), Q13::from_f64(0.65), Q13::from_f64(1.03)];
        b.measure("chip_infer_water", || chip.infer(&x).unwrap()[0].0);
        b.note("chip latency (modelled cycles)", chip.latency_cycles());
    }
    match nvnmd::exp::fig9::run() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig9 unavailable (run `make artifacts`): {e:#}"),
    }
    b.finish();
}
