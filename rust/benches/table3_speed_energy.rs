//! Bench E9 (paper Table III): measured S/P/eta for all five methods.
use nvnmd::benchkit::Bench;

fn main() {
    let mut b = Bench::new("table3_speed_energy");
    let quick = nvnmd::benchkit::quick_mode();
    let (res, wall) = b.measure_once("table3_all_methods", || nvnmd::exp::table3::run(quick));
    match res {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("table3 unavailable (run `make artifacts`): {e:#}"),
    }
    b.note("total wall", format!("{wall:?}"));
    b.finish();
}
