//! Bench E2 (paper Fig. 3b): synthesis-model cost of the two activation
//! circuits, plus the netlist-builder throughput.
use nvnmd::benchkit::Bench;
use nvnmd::hw::synth;

fn main() {
    let mut b = Bench::new("fig3_transistors");
    b.measure("synthesize_phi_unit", || synth::phi_unit(13).transistors());
    b.measure("synthesize_tanh_cordic", || {
        synth::tanh_cordic_unit(synth::CORDIC_BITS, synth::CORDIC_ITERS).transistors()
    });
    b.measure("synthesize_water_mlp_sqnn", || {
        synth::mlp_netlist(&[3, 3, 3, 2], 13, synth::WeightDatapath::Shift { k: 3 }).transistors()
    });
    match nvnmd::exp::fig3::run_transistors() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig3b failed: {e:#}"),
    }
    b.finish();
}
