//! Bench E1+E3 (paper Fig. 3a + Table I): activation evaluation cost and
//! the tanh-vs-phi accuracy table.
use nvnmd::benchkit::Bench;
use nvnmd::nn::activation::{phi, phi_q13, tanh_cordic};
use nvnmd::fixedpoint::Q13;

fn main() {
    let mut b = Bench::new("table1_activation");
    let xs: Vec<f64> = (0..1024).map(|i| -4.0 + i as f64 * 8.0 / 1024.0).collect();
    let qs: Vec<Q13> = xs.iter().map(|&x| Q13::from_f64(x)).collect();
    b.measure("tanh_f64_x1024", || xs.iter().map(|x| x.tanh()).sum::<f64>());
    b.measure("phi_f64_x1024", || xs.iter().map(|&x| phi(x)).sum::<f64>());
    b.measure("phi_q13_x1024", || qs.iter().map(|&q| phi_q13(q).0 as i64).sum::<i64>());
    b.measure("tanh_cordic14_x1024", || {
        xs.iter().map(|&x| tanh_cordic(x.clamp(-1.1, 1.1), 14, 16)).sum::<f64>()
    });

    match nvnmd::exp::fig3::run_curves() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig3a unavailable: {e:#}"),
    }
    match nvnmd::exp::table1::run() {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("table1 unavailable (run `make artifacts`): {e:#}"),
    }
    b.finish();
}
