//! Bench E10 (paper §VI): process-node projection.
use nvnmd::benchkit::Bench;

fn main() {
    let mut b = Bench::new("scaling_projection");
    let quick = nvnmd::benchkit::quick_mode();
    match nvnmd::exp::scaling::run(quick) {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("scaling failed: {e:#}"),
    }
    b.measure("projection_compute", || nvnmd::exp::scaling::compute().len());
    b.finish();
}
