//! Bench E8 (paper Fig. 10): the DOS spectra pipeline (trajectories +
//! VACF + FFT) and the FFT substrate hot path.
use nvnmd::benchkit::Bench;
use nvnmd::util::fft::{self, Cplx};

fn main() {
    let mut b = Bench::new("fig10_spectra");
    let n = 1 << 14;
    let signal: Vec<f64> = (0..n).map(|i| (0.37 * i as f64).sin()).collect();
    b.measure("fft_16k", || {
        let mut buf: Vec<Cplx> = signal.iter().map(|&x| Cplx::new(x, 0.0)).collect();
        fft::fft(&mut buf, false);
        buf[1].re
    });
    b.measure("autocorrelation_4k_lags", || {
        fft::autocorrelation(&signal[..8192], 4096).len()
    });
    let quick = nvnmd::benchkit::quick_mode();
    let (res, _) = b.measure_once("fig10_full_pipeline", || nvnmd::exp::fig10::run(quick));
    match res {
        Ok(r) => println!("{}", r.render()),
        Err(e) => println!("fig10 unavailable (run `make artifacts`): {e:#}"),
    }
    b.finish();
}
