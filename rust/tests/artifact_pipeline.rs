//! Integration tests over the build artifacts (`make artifacts`):
//! model/HLO contracts, cross-language quantizer parity, chip-vs-PJRT
//! numerics, and the AOT round trip. Every test skips (with a notice)
//! when the artifacts have not been built, so `cargo test` works on a
//! fresh checkout.

use nvnmd::features;
use nvnmd::nn::{ConditionedSqnn, Mlp, Sqnn};
use nvnmd::quant;
use nvnmd::runtime::{HloForceModel, Runtime, Tensor};
use nvnmd::coordinator::vn::HForceModel;

fn have_artifacts() -> bool {
    nvnmd::artifact_path("models/water_qnn_k3.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (`make artifacts`)");
            return;
        }
    };
}

#[test]
fn qnn_export_weights_are_exact_pow2_sums() {
    require_artifacts!();
    for k in 1..=5usize {
        let m = Mlp::load(&nvnmd::artifact_path(&format!("models/water_qnn_k{k}.json"))).unwrap();
        assert_eq!(m.quant_k, k);
        for l in &m.layers {
            for &w in &l.w {
                let q = quant::quantize_weight(w, k);
                assert_eq!(
                    q.value(),
                    w,
                    "k={k}: exported weight {w} is not an exact ≤{k}-term sum"
                );
            }
        }
        // therefore the rust SQNN is a lossless view of the export
        let s = Sqnn::from_mlp(&m, k);
        let deq = s.dequantized_mlp().unwrap();
        for (a, b) in m.layers.iter().zip(&deq.layers) {
            assert_eq!(a.w, b.w);
        }
    }
}

#[test]
fn model_contracts() {
    require_artifacts!();
    for stem in ["water_cnn_phi", "water_cnn_tanh", "water_qnn_k3", "water_deepmd_like"] {
        let m = Mlp::load(&nvnmd::artifact_path(&format!("models/{stem}.json"))).unwrap();
        assert_eq!(m.in_dim(), 3, "{stem}");
        assert_eq!(m.out_dim(), 2, "{stem}");
        assert!(m.output_scale > 0.0);
        // sane outputs on a representative feature vector
        let y = m.forward_physical(&[1.03, 0.65, 1.03]);
        assert!(y.iter().all(|v| v.is_finite() && v.abs() < 32.0), "{stem}: {y:?}");
    }
}

#[test]
fn pjrt_mlp_matches_rust_float_forward() {
    require_artifacts!();
    let m = Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut hlo = HloForceModel::load(&rt, &nvnmd::artifact_path("water_mlp.hlo.txt")).unwrap();
    let feats = [[1.03f64, 0.65, 1.03], [0.98, 0.70, 1.01]];
    let got = hlo.eval(&feats).unwrap();
    let want0 = m.forward_physical(&feats[0]);
    let want1 = m.forward_physical(&feats[1]);
    for (g, w) in got[0].iter().zip(&want0).chain(got[1].iter().zip(&want1)) {
        assert!((g - w).abs() < 1e-4, "pjrt {g} vs rust {w}");
    }
}

#[test]
fn pjrt_shift_kernel_artifact_known_runtime_defect() {
    // The dense and shift-reconstruction artifacts are bit-equivalent at
    // the JAX level (pytest asserts this), but the crate's xla_extension
    // 0.5.1 mis-executes the shift artifact's lowered graph (row mixing
    // in the exp2/reduce region). This test documents the defect: it
    // passes if the artifact either matches (a future xla_extension) or
    // mismatches in the known way — and fails if loading itself breaks.
    require_artifacts!();
    let shift_path = nvnmd::artifact_path("water_mlp_shiftkernel.hlo.txt");
    if !shift_path.exists() {
        eprintln!("skipping: shift-kernel artifact missing");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let dense = rt.load_hlo_text(&nvnmd::artifact_path("water_mlp.hlo.txt")).unwrap();
    let shift = rt.load_hlo_text(&shift_path).unwrap();
    let x = Tensor::new(vec![1.03, 0.65, 1.03, 0.98, 0.70, 1.01], &[2, 3]).unwrap();
    let a = dense.run(std::slice::from_ref(&x)).unwrap();
    let b = shift.run(std::slice::from_ref(&x)).unwrap();
    assert_eq!(a[0].dims, b[0].dims);
    let agree = a[0]
        .data
        .iter()
        .zip(&b[0].data)
        .all(|(u, v)| (u - v).abs() < 1e-4);
    if !agree {
        eprintln!(
            "known xla_extension 0.5.1 defect: shift-kernel artifact \
             mis-executes on PJRT ({:?} vs {:?}); the JAX-level \
             equivalence is asserted by python/tests instead",
            &a[0].data, &b[0].data
        );
    }
}

#[test]
fn pjrt_md_step_matches_rust_float_euler() {
    require_artifacts!();
    let md_path = nvnmd::artifact_path("water_md_step.hlo.txt");
    if !md_path.exists() {
        eprintln!("skipping: md-step artifact missing");
        return;
    }
    let m = Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&md_path).unwrap();

    // Rust float reference of the same step.
    let pes = nvnmd::potentials::WaterPes::dft_surrogate();
    let pos0 = pes.equilibrium();
    let mut sys = nvnmd::md::System::new(pos0.clone(), nvnmd::potentials::WaterPes::masses());
    sys.vel[1] = nvnmd::util::Vec3::new(0.003, -0.002, 0.001);
    let mut driver = nvnmd::coordinator::vn::VnMlmd::new(
        sys.clone(),
        nvnmd::coordinator::vn::MlpForceModel { model: m },
        0.25,
    );
    driver.step().unwrap();

    let flat = |vs: &[nvnmd::util::Vec3]| -> Vec<f32> {
        vs.iter().flat_map(|v| v.to_array().map(|x| x as f32)).collect()
    };
    let out = exe
        .run(&[
            Tensor::new(flat(&sys.pos), &[3, 3]).unwrap(),
            Tensor::new(flat(&sys.vel), &[3, 3]).unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 2);
    let pos_hlo = &out[0].data;
    let pos_rust = flat(&driver.sys.pos);
    for (a, b) in pos_hlo.iter().zip(&pos_rust) {
        assert!((a - b).abs() < 1e-5, "hlo {a} vs rust {b}");
    }
}

#[test]
fn chip_rmse_within_paper_band() {
    require_artifacts!();
    // The Fig. 9 headline: chip-level force error small compared to the
    // thermal force scale. We accept up to ~8× the paper's 7.56 meV/Å on
    // this surrogate setup and assert the relative error < 5%.
    let eval = nvnmd::exp::fig9::compute(200).unwrap();
    assert!(
        eval.rmse_mev < 60.0,
        "chip RMSE {:.1} meV/Å too large",
        eval.rmse_mev
    );
    let spread = {
        let xs: Vec<f64> = eval.scatter.iter().map(|p| p.0).collect();
        nvnmd::analysis::mean_std(&xs).1
    };
    assert!(
        eval.rmse_mev / 1000.0 < 0.05 * spread,
        "relative error {:.1}% too large",
        100.0 * eval.rmse_mev / 1000.0 / spread
    );
}

#[test]
fn quant_vectors_artifact_is_self_consistent() {
    let path = nvnmd::artifact_path("quant_vectors.json");
    if !path.exists() {
        eprintln!("skipping: quant_vectors.json not built");
        return;
    }
    let doc = nvnmd::util::json::read_file(&path).unwrap();
    let vectors = doc.get("vectors").unwrap().as_arr().unwrap();
    assert!(vectors.len() >= 100);
    for v in vectors {
        let w = v.get("w").unwrap().as_f64().unwrap();
        let k = v.get("k").unwrap().as_usize().unwrap();
        let q = quant::quantize_weight(w, k);
        assert_eq!(q.sign as f64, v.get("sign").unwrap().as_f64().unwrap());
        assert_eq!(
            q.exps,
            v.get("exps").unwrap().as_i32_vec().unwrap(),
            "w={w} k={k}"
        );
    }
}

#[test]
fn chip_and_float_agree_on_equilibrium_features() {
    require_artifacts!();
    let m = Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json")).unwrap();
    let s = ConditionedSqnn::from_mlp(&m, m.quant_k.max(3));
    let pes = nvnmd::potentials::WaterPes::dft_surrogate();
    let pos = pes.equilibrium();
    for h in [1usize, 2] {
        let feats = features::water_features(&pos, h);
        // ConditionedSqnn::forward applies the same conditioning stage as the FPGA
        let chip_out = s.forward(&feats);
        let float_out = m.forward(&feats);
        for (c, f) in chip_out.iter().zip(&float_out) {
            assert!((c - f).abs() < 0.05, "chip {c} vs float {f}");
        }
    }
}
