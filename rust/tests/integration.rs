//! Cross-module integration tests that do NOT require build artifacts:
//! the full pipeline is exercised on in-crate trained stand-ins.

use nvnmd::analysis::WaterSeries;
use nvnmd::asic::{ChipConfig, MlpChip};
use nvnmd::coordinator::pool::ChipPool;
use nvnmd::coordinator::{ParallelMode, WaterSystem};
use nvnmd::datasets;
use nvnmd::features;
use nvnmd::fixedpoint::Q13;
use nvnmd::md::{initialize_velocities, ForceField, System};
use nvnmd::nn::{Activation, ConditionedSqnn, Mlp};
use nvnmd::potentials::WaterPes;
use nvnmd::testkit;
use nvnmd::util::rng::Pcg;
use nvnmd::util::Vec3;

/// Train a small water model in-process (gradient descent on the float
/// MLP) — a miniature of the python pipeline, enough for integration
/// checks without artifacts.
fn train_tiny_water_model(rows: usize, epochs: usize) -> (Mlp, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let mut sp = datasets::spec("water").unwrap();
    sp.n_configs = rows;
    let ds = datasets::water_dataset(&sp);
    let scale = 4.0;
    let mut rng = Pcg::new(99);
    let mut m = Mlp::init_random("tiny-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
    m.output_scale = scale;
    // feature conditioning, exactly like the python trainer (without it
    // the near-constant inverse-distance features are untrainable)
    let dim = 3;
    let mut center = vec![0.0; dim];
    for row in &ds.train_x {
        for (c, v) in center.iter_mut().zip(row) {
            *c += v / ds.train_x.len() as f64;
        }
    }
    let mut gains = vec![1.0; dim];
    for d in 0..dim {
        let dev = ds
            .train_x
            .iter()
            .map(|r| (r[d] - center[d]).abs())
            .fold(1e-6, f64::max);
        let m_exp = (2.0 / dev).log2().floor().clamp(0.0, 12.0);
        gains[d] = (2f64).powi(m_exp as i32);
    }
    m.feature_center = center;
    m.feature_scale = gains;

    // plain full-batch gradient descent with numerically safe steps
    let lr = 0.05;
    for _ in 0..epochs {
        // accumulate gradients by finite differences over params — slow
        // but dependency-free; the tiny net keeps it fast enough.
        let loss = |m: &Mlp| -> f64 {
            let mut s = 0.0;
            for (x, y) in ds.train_x.iter().zip(&ds.train_y) {
                let p = m.forward(x);
                for (pi, yi) in p.iter().zip(y) {
                    let d = pi - yi / scale;
                    s += d * d;
                }
            }
            s / ds.train_x.len() as f64
        };
        let base = loss(&m);
        let mut grads: Vec<(usize, usize, f64, bool)> = Vec::new();
        for li in 0..m.layers.len() {
            for wi in 0..m.layers[li].w.len() {
                let h = 1e-4;
                m.layers[li].w[wi] += h;
                let g = (loss(&m) - base) / h;
                m.layers[li].w[wi] -= h;
                grads.push((li, wi, g, true));
            }
            for bi in 0..m.layers[li].b.len() {
                let h = 1e-4;
                m.layers[li].b[bi] += h;
                let g = (loss(&m) - base) / h;
                m.layers[li].b[bi] -= h;
                grads.push((li, bi, g, false));
            }
        }
        for (li, i, g, is_w) in grads {
            if is_w {
                m.layers[li].w[i] -= lr * g;
            } else {
                m.layers[li].b[i] -= lr * g;
            }
        }
    }
    (m, ds.test_x, ds.test_y)
}

#[test]
fn end_to_end_tiny_pipeline_data_train_chip_md() {
    // data → train (in-process) → quantize → chip → MD on the
    // heterogeneous system: positions must stay bounded and finite, and
    // chip accuracy must beat the untrained baseline.
    let (m, test_x, test_y) = train_tiny_water_model(120, 60);

    // quantized chip accuracy vs float
    let s = ConditionedSqnn::from_mlp(&m, 3);
    let mut err_q = 0.0;
    let mut err_zero = 0.0;
    let mut n = 0;
    for (x, y) in test_x.iter().zip(&test_y) {
        let p = s.forward(x);
        for (pi, yi) in p.iter().zip(y) {
            err_q += (pi * m.output_scale - yi).powi(2);
            err_zero += yi * yi;
            n += 1;
        }
    }
    let rmse_q = (err_q / n as f64).sqrt();
    let rmse_zero = (err_zero / n as f64).sqrt();
    assert!(
        rmse_q < 0.8 * rmse_zero,
        "chip model ({rmse_q:.3}) should beat predict-zero ({rmse_zero:.3})"
    );

    // MD through the full heterogeneous system (plumbing check — a
    // 60-epoch toy model is not a stable force field, so assert state
    // sanity + accounting, not physical geometry; the physically
    // accurate run is the artifact-gated table2 path)
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 100.0, 6, &mut Pcg::new(5));
    let mut hw = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Threaded).unwrap();
    hw.thermostat = Some((100.0, 0.25 / 500.0));
    let mut series = WaterSeries::default();
    hw.run(3_000, 5, |p| series.push(p)).unwrap();
    assert_eq!(series.len(), 600);
    for p in hw.positions() {
        assert!(p.norm().is_finite());
        assert!(p.norm() <= 32.0 * 1.8, "state escaped saturation: {p:?}");
    }
    let ledger = hw.finish().unwrap();
    assert_eq!(ledger.md_steps, 3_000);
    assert_eq!(ledger.chip_inferences, 6_000);
}

#[test]
fn chip_pool_scales_and_is_deterministic() {
    let mut rng = Pcg::new(2);
    let mut m = Mlp::init_random("p", &[3, 4, 4, 2], Activation::Phi, &mut rng);
    for l in &mut m.layers {
        for w in &mut l.w {
            *w *= 0.5;
        }
    }
    let rows: Vec<Vec<Q13>> = (0..200)
        .map(|i| (0..3).map(|j| Q13::from_f64(0.3 + 0.001 * (i * 3 + j) as f64)).collect())
        .collect();
    let mut reference: Option<Vec<Vec<Q13>>> = None;
    for n_chips in [1usize, 2, 5] {
        let chips = (0..n_chips)
            .map(|id| {
                let mut c = MlpChip::new(id, ChipConfig::default());
                c.program(&m, 3);
                c
            })
            .collect();
        let mut pool = ChipPool::spawn(chips).unwrap();
        let out = pool.infer_batch(&rows).unwrap();
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(*r, out, "{n_chips} chips disagree with 1 chip"),
        }
    }
}

#[test]
fn property_forces_reconstruct_for_random_geometries() {
    // features → local frame → reconstruction is lossless for PES forces
    // on randomized (non-degenerate) geometries.
    let cfg = testkit::Config { cases: 150, ..Default::default() };
    let pes = WaterPes::dft_surrogate();
    testkit::forall_f64_vec(&cfg, 9, 9, -0.12, 0.12, |d| {
        let mut pos = pes.equilibrium();
        for i in 0..3 {
            pos[i] += Vec3::new(d[3 * i], d[3 * i + 1], d[3 * i + 2]);
        }
        let (r1, r2, th) = WaterPes::internal(&pos);
        if r1 < 0.5 || r2 < 0.5 || th < 0.3 || th > 2.9 {
            return Ok(()); // skip degenerate frames
        }
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        for h in [1usize, 2] {
            let c = features::water_force_to_local(&pos, h, f[h]);
            let back = features::water_force_from_local(&pos, h, c);
            testkit::close((back - f[h]).norm(), 0.0, 1e-8, 0.0)?;
        }
        Ok(())
    });
}

#[test]
fn nvn_trajectory_is_reproducible_bitwise() {
    let mut rng = Pcg::new(1);
    let mut m = Mlp::init_random("r", &[3, 3, 3, 2], Activation::Phi, &mut rng);
    for l in &mut m.layers {
        for w in &mut l.w {
            *w *= 0.3;
        }
    }
    m.output_scale = 4.0;
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 200.0, 6, &mut Pcg::new(11));

    let run = || {
        let mut hw = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
        for _ in 0..500 {
            hw.step().unwrap();
        }
        hw.positions()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "fixed-point MD must be bit-deterministic");
}
