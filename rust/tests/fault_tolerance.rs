//! End-to-end graceful degradation of the supervised farm (ISSUE 8
//! acceptance): with one shard panicked mid-run and one molecule forced
//! into rail saturation, the farm completes its run, every unaffected
//! molecule's trajectory is bit-identical to a fault-free run, and the
//! ledger reports exactly the injected faults — identically for the
//! inline and threaded backends.
//!
//! Requires the library's fault-injection hooks:
//! `cargo test --features faults --test fault_tolerance`.

use nvnmd::coordinator::farm::{random_molecule_systems, random_water_systems, WaterFarm};
use nvnmd::coordinator::gateway::{Gateway, GatewayConfig, GatewaySpecies, Outcome, Submission};
use nvnmd::coordinator::{FarmConfig, ParallelMode, QuarantineReason};
use nvnmd::md::System;
use nvnmd::nn::{Activation, Mlp};
use nvnmd::potentials::ff;
use nvnmd::testkit::faults::FaultPlan;
use nvnmd::util::rng::Pcg;

fn toy_model() -> Mlp {
    let mut rng = Pcg::new(77);
    let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
    for l in &mut m.layers {
        for w in &mut l.w {
            *w *= 0.3;
        }
    }
    m
}

fn build(systems: &[System], mode: ParallelMode, faults: Option<FaultPlan>) -> WaterFarm {
    WaterFarm::new(
        &toy_model(),
        systems,
        &FarmConfig { shards: 3, mode, faults, ..FarmConfig::default() },
    )
    .unwrap()
}

#[test]
fn farm_degrades_gracefully_and_identically_on_both_backends() {
    // 12 molecules over 3 shards (4 each; shard 2 = molecules 8..=11).
    // Injected faults: shard 2 panics at tick 10; molecule 1 is pinned
    // onto the 26-bit rail at tick 4 (quarantined that same tick).
    let systems = random_water_systems(12, 150.0, 0xACCE);
    let ticks = 100u64;
    let plan = FaultPlan::new().panic_shard(2, 10).saturate_molecule(1, 4);

    let mut clean = build(&systems, ParallelMode::Inline, None);
    clean.run(ticks as usize).unwrap();
    let clean_pos = clean.positions().unwrap();
    let clean_ledger = clean.finish().unwrap();
    assert_eq!(clean_ledger.molecule_steps, 12 * ticks);
    assert_eq!(clean_ledger.degraded_ticks, 0);
    assert_eq!(clean_ledger.saturation_events, 0);

    let mut results = Vec::new();
    for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
        let mut farm = build(&systems, mode, Some(plan));
        // The farm must complete the full run despite both faults.
        farm.run(ticks as usize).unwrap();
        let pos = farm.positions().unwrap();

        // Unaffected molecules (not the quarantined one, not on the dead
        // shard) are bit-identical to the fault-free run — including
        // molecules 0, 2, 3, which shared batch lanes with the
        // quarantined molecule before its lanes were removed.
        for mol in [0usize, 2, 3, 4, 5, 6, 7] {
            assert_eq!(pos[mol], clean_pos[mol], "unaffected molecule {mol} diverged");
        }
        // The faulted ones are not (frozen early / pinned on the rail).
        assert_ne!(pos[1], clean_pos[1]);
        for mol in 8..12 {
            assert_ne!(pos[mol], clean_pos[mol], "dead-shard molecule {mol} should be frozen");
        }

        let l = farm.finish().unwrap();
        // Ledger reports exactly the injected faults.
        assert_eq!(l.panics_recovered, 1);
        assert_eq!(l.replies_lost, 0);
        assert_eq!(l.molecules_quarantined, 1);
        assert_eq!(l.quarantined.len(), 1);
        let q = l.quarantined[0];
        assert_eq!((q.molecule, q.tick), (1, 4));
        assert_eq!(q.reason, QuarantineReason::SaturationEvents);
        assert_eq!(l.shards_lost.len(), 1);
        assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (2, 10));
        // Degraded from the quarantine tick onward: ticks 4..=99.
        assert_eq!(l.degraded_ticks, 96);
        // Steps: 7 healthy × 100, molecule 1 integrated 5 (ticks 0..=4),
        // the dead shard's 4 molecules integrated 10 each (ticks 0..=9).
        assert_eq!(l.molecule_steps, 7 * 100 + 5 + 4 * 10);
        assert!(l.saturation_events >= 3);
        results.push((pos, l));
    }

    // Backend identity: same trajectories, same fault accounting.
    let ((pa, la), (pb, lb)) = (&results[0], &results[1]);
    assert_eq!(pa, pb, "backends disagree under faults");
    assert_eq!(la.molecule_steps, lb.molecule_steps);
    assert_eq!(la.panics_recovered, lb.panics_recovered);
    assert_eq!(la.molecules_quarantined, lb.molecules_quarantined);
    assert_eq!(la.saturation_events, lb.saturation_events);
    assert_eq!(la.degraded_ticks, lb.degraded_ticks);
    assert_eq!(la.quarantined, lb.quarantined);
    assert_eq!(
        (la.shards_lost[0].shard, &la.shards_lost[0].detail),
        (lb.shards_lost[0].shard, &lb.shards_lost[0].detail),
    );
}

#[test]
fn epoch_batched_runs_degrade_identically_to_per_tick_runs() {
    // The epoch driver's acceptance: the exact scenario above — shard 2
    // panics at tick 10, molecule 1 saturates at tick 4 — driven in
    // epochs of 8 (both faults mid-epoch) and 7 (ragged tail) must
    // reproduce the per-tick run bit for bit: trajectories, quarantine
    // records, loss ticks, degraded-tick count, step ledger.
    let systems = random_water_systems(12, 150.0, 0xACCE);
    let plan = FaultPlan::new().panic_shard(2, 10).saturate_molecule(1, 4);

    let mut per_tick = build(&systems, ParallelMode::Inline, Some(plan));
    per_tick.run(100).unwrap();
    let ref_pos = per_tick.positions().unwrap();
    let rl = per_tick.finish().unwrap();
    assert_eq!(rl.degraded_ticks, 96);

    for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
        for epoch in [8usize, 7] {
            let mut farm = build(&systems, mode, Some(plan));
            farm.run_epoched(100, epoch).unwrap();
            assert_eq!(farm.ticks(), 100);
            let pos = farm.positions().unwrap();
            assert_eq!(pos, ref_pos, "mode {mode:?} epoch {epoch} trajectories diverged");
            let l = farm.finish().unwrap();
            assert_eq!(l.ticks, 100);
            assert_eq!(l.molecule_steps, rl.molecule_steps);
            assert_eq!(l.panics_recovered, 1);
            assert_eq!(l.replies_lost, 0);
            assert_eq!(l.quarantined, rl.quarantined);
            assert_eq!(l.degraded_ticks, 96, "mode {mode:?} epoch {epoch}");
            assert_eq!(l.shards_lost.len(), 1);
            assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (2, 10));
            assert_eq!(l.saturation_events, rl.saturation_events);
            assert_eq!(l.chip_inferences, rl.chip_inferences);
        }
    }
}

#[test]
fn reply_drop_lands_mid_epoch_with_exact_tick_attribution() {
    // Transport fault crossing an epoch boundary: shard 0's reply is
    // scheduled to drop at tick 5, inside the second epoch of a
    // 4-tick-epoch run. The supervisor must attribute the loss to tick
    // 5 exactly, count the drop tick as executed, and serve positions
    // in degraded mode — all identical to the per-tick driver.
    let systems = random_water_systems(6, 140.0, 0xD20B);
    let plan = FaultPlan::new().drop_reply(0, 5);
    let mut per_tick = build(&systems, ParallelMode::Threaded, Some(plan));
    per_tick.run(12).unwrap();
    let ref_pos = per_tick.positions().unwrap();
    let rl = per_tick.finish().unwrap();
    assert_eq!(rl.replies_lost, 1);
    assert_eq!((rl.shards_lost[0].shard, rl.shards_lost[0].tick), (0, 5));

    let mut farm = build(&systems, ParallelMode::Threaded, Some(plan));
    farm.run_epoched(12, 4).unwrap();
    assert_eq!(farm.positions().unwrap(), ref_pos);
    let l = farm.finish().unwrap();
    assert_eq!(l.replies_lost, 1);
    assert_eq!(l.panics_recovered, 0);
    assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (0, 5));
    assert_eq!(l.degraded_ticks, rl.degraded_ticks);
    // Shard 0's two molecules executed through the drop tick (6 ticks),
    // the other two shards' four molecules all 12.
    assert_eq!(l.molecule_steps, rl.molecule_steps);
    assert_eq!(l.molecule_steps, 2 * 6 + 4 * 12);
}

#[test]
fn seeded_chaos_plans_reproduce_bit_identical_degraded_runs() {
    // Two farms driven by the same seeded FaultPlan::random must agree
    // bit for bit — fault injection is part of the deterministic state
    // machine, not a source of nondeterminism.
    let systems = random_water_systems(9, 130.0, 0xC1A0);
    let plan = FaultPlan::random(0xD1CE, 3, 9, 50);
    let run = |mode: ParallelMode| {
        let mut farm = build(&systems, mode, Some(plan));
        farm.run(50).unwrap();
        let pos = farm.positions().unwrap();
        (pos, farm.finish().unwrap())
    };
    let (pa, la) = run(ParallelMode::Inline);
    let (pb, lb) = run(ParallelMode::Threaded);
    assert_eq!(pa, pb);
    assert_eq!(la.panics_recovered, lb.panics_recovered);
    assert_eq!(la.molecules_quarantined, lb.molecules_quarantined);
    assert_eq!(la.degraded_ticks, lb.degraded_ticks);
    assert_eq!(la.molecule_steps, lb.molecule_steps);
    // The plan injects one panic and one saturation; whether or not both
    // bite (the saturated molecule may sit on the already-dead shard),
    // the farm must have recorded the panic and completed the run.
    assert_eq!(la.panics_recovered, 1);
    assert_eq!(la.ticks, 50);

    // And the epoch driver reproduces the same chaos run bit for bit,
    // wherever the random faults landed relative to epoch boundaries.
    let run_epoched = |mode: ParallelMode| {
        let mut farm = build(&systems, mode, Some(plan));
        farm.run_epoched(50, 16).unwrap();
        let pos = farm.positions().unwrap();
        (pos, farm.finish().unwrap())
    };
    let (pc, lc) = run_epoched(ParallelMode::Inline);
    let (pd, ld) = run_epoched(ParallelMode::Threaded);
    assert_eq!(pa, pc, "inline epoch run diverged from per-tick");
    assert_eq!(pa, pd, "threaded epoch run diverged from per-tick");
    assert_eq!(la.degraded_ticks, lc.degraded_ticks);
    assert_eq!(la.degraded_ticks, ld.degraded_ticks);
    assert_eq!(la.molecule_steps, lc.molecule_steps);
    assert_eq!(la.molecule_steps, ld.molecule_steps);
    assert_eq!(la.quarantined, lc.quarantined);
    assert_eq!(la.quarantined, ld.quarantined);
}

fn toy_generic_model(n_nb: usize) -> Mlp {
    let mut rng = Pcg::new(55);
    let mut m = Mlp::init_random("toy-generic", &[4 * n_nb, 8, 8, 3], Activation::Phi, &mut rng);
    for l in &mut m.layers {
        for w in &mut l.w {
            *w *= 0.2;
        }
    }
    m
}

/// Two-species gateway: water on shards 0–1, ethanol on shards 2–3.
fn two_species_gateway(cfg: GatewayConfig) -> Gateway {
    let eth = ff::ethanol();
    Gateway::new(
        vec![
            GatewaySpecies::water(&toy_model(), 3, 2, 0.25).unwrap(),
            GatewaySpecies::generic("ethanol", &toy_generic_model(4), &eth.coords, 4, 3, 2, 0.25)
                .unwrap(),
        ],
        cfg,
    )
    .unwrap()
}

#[test]
fn gateway_degrades_one_species_while_the_other_keeps_meeting_deadlines() {
    // ISSUE 10 acceptance: shard 1 (water's second shard) panics at
    // tick 6 — mid-window under a 4-tick window. The requests resident
    // there fail as ShardLost; water's other shard and the whole
    // ethanol species keep serving and meeting deadlines. Decisions,
    // per-request results (positions included), and SLO ledgers must be
    // bit-identical inline vs threaded.
    let water_sys = random_water_systems(4, 140.0, 0x6A7E);
    let eth = ff::ethanol();
    let eth_sys = random_molecule_systems(&eth.coords, &eth.masses(), 4, 100.0, 0x47E);
    let plan = FaultPlan::new().panic_shard(1, 6);
    let run = |mode: ParallelMode| {
        let cfg = GatewayConfig {
            window_ticks: 4,
            mode,
            faults: Some(plan),
            ..GatewayConfig::default()
        };
        let mut gw = two_species_gateway(cfg);
        // Water ids 0..=3 (alternating shards 0/1), ethanol ids 4..=7
        // (alternating shards 2/3) — placement is least-resident with
        // lowest-index tie-break, so ids 1 and 3 land on shard 1.
        for sys in &water_sys {
            assert!(matches!(gw.submit(0, sys, 8, 40).unwrap(), Submission::Accepted(_)));
        }
        for sys in &eth_sys {
            assert!(matches!(gw.submit(1, sys, 8, 40).unwrap(), Submission::Accepted(_)));
        }
        gw.run_windows(3).unwrap();
        let results = gw.take_results();
        let (slo, ledger) = gw.finish().unwrap();
        (results, slo, ledger)
    };
    let (ri, li, gi) = run(ParallelMode::Inline);
    let (rt, lt, gt) = run(ParallelMode::Threaded);
    assert_eq!(ri, rt, "per-request results diverged across backends under faults");
    assert_eq!(li, lt, "SLO ledgers diverged across backends under faults");
    assert_eq!(gi.molecule_steps, gt.molecule_steps);
    assert_eq!(gi.panics_recovered, 1);

    let water = &li.species[0];
    let ethanol = &li.species[1];
    assert_eq!(water.failed_shard_lost, 2, "shard 1 held two water requests");
    assert_eq!(water.completed, 2, "shard 0's water requests still finish");
    assert_eq!(water.deadline_missed, 0);
    assert_eq!(ethanol.completed, 4, "ethanol is untouched by water's loss");
    assert_eq!(ethanol.deadline_met, 4);
    assert_eq!(ethanol.failed_shard_lost + ethanol.failed_quarantined, 0);
    // The failed requests carry the loss tick; no positions come back
    // off a dead shard.
    let lost: Vec<_> = ri
        .iter()
        .filter(|r| matches!(r.outcome, Outcome::ShardLost { tick: 6 }))
        .collect();
    assert_eq!(lost.len(), 2);
    for r in lost {
        assert_eq!(r.ticks_run, 6, "ran until the shard froze at tick 6");
        assert!(!r.deadline_met);
    }
}

#[test]
fn gateway_quarantine_settles_the_request_and_ledgers_match_across_backends() {
    // Molecule id 1 (second admitted request — shard 1 by placement) is
    // pinned onto the 26-bit rail at tick 2: the divergence monitor
    // quarantines it that tick, the gateway retires it with its frozen
    // state, and the neighbor request is bit-identical to a fault-free
    // run on both backends.
    let systems = random_water_systems(2, 130.0, 0x0A12);
    let plan = FaultPlan::new().saturate_molecule(1, 2);
    let run = |mode: ParallelMode| {
        let cfg = GatewayConfig {
            window_ticks: 4,
            mode,
            faults: Some(plan),
            ..GatewayConfig::default()
        };
        let mut gw = Gateway::new(
            vec![GatewaySpecies::water(&toy_model(), 3, 2, 0.25).unwrap()],
            cfg,
        )
        .unwrap();
        for sys in &systems {
            assert!(matches!(gw.submit(0, sys, 8, 40).unwrap(), Submission::Accepted(_)));
        }
        gw.run_windows(2).unwrap();
        let results = gw.take_results();
        let (slo, _) = gw.finish().unwrap();
        (results, slo)
    };
    let (ri, li) = run(ParallelMode::Inline);
    let (rt, lt) = run(ParallelMode::Threaded);
    assert_eq!(ri, rt);
    assert_eq!(li, lt);
    assert_eq!(li.species[0].failed_quarantined, 1);
    assert_eq!(li.species[0].completed, 1);
    let q = ri.iter().find(|r| r.id.0 == 1).unwrap();
    let Outcome::Quarantined { reason, tick, positions } = &q.outcome else {
        panic!("expected quarantine, got {:?}", q.outcome)
    };
    assert_eq!(*reason, QuarantineReason::SaturationEvents);
    assert_eq!(*tick, 2);
    assert!(!positions.is_empty(), "frozen state comes back with the verdict");
    assert_eq!(q.ticks_run, 3, "integrated ticks 0..=2 before the verdict");
}

#[test]
fn telemetry_undercounts_on_lost_replies_but_finish_books_are_complete() {
    // The documented source-of-truth relation (ISSUE 10 satellite):
    // `Gateway::telemetry()` delegates to the farm's running view,
    // which misses the steps of an epoch whose reply was dropped — the
    // epoch executed, but nobody reported it. `finish()` reads shard
    // state directly (workers survive reply drops), so its FarmLedger
    // counts every step. Telemetry is for dashboards; bill from the
    // ledger.
    let systems = random_water_systems(2, 140.0, 0x105F);
    let plan = FaultPlan::new().drop_reply(1, 5);
    let cfg = GatewayConfig {
        window_ticks: 4,
        mode: ParallelMode::Threaded,
        faults: Some(plan),
        ..GatewayConfig::default()
    };
    let mut gw = Gateway::new(
        vec![GatewaySpecies::water(&toy_model(), 3, 2, 0.25).unwrap()],
        cfg,
    )
    .unwrap();
    for sys in &systems {
        assert!(matches!(gw.submit(0, sys, 8, 40).unwrap(), Submission::Accepted(_)));
    }
    gw.run_windows(2).unwrap();
    let telemetry = gw.telemetry();
    let results = gw.take_results();
    let (slo, ledger) = gw.finish().unwrap();
    assert_eq!(ledger.replies_lost, 1);
    // Shard 0's request ran 8 ticks and completed; shard 1's executed
    // ticks 4 and 5 of its second window before the reply vanished —
    // 6 steps on the frozen shard. The running view saw only the 4
    // reported first-window steps of that molecule.
    assert_eq!(ledger.molecule_steps, 8 + 6, "finish() reads shards directly");
    assert_eq!(telemetry.molecule_steps, 8 + 4, "the dropped epoch's steps go unreported");
    assert!(telemetry.molecule_steps < ledger.molecule_steps);
    // The SLO ledger settles off supervisor records, not the lost
    // reply: one completion, one shard-lost failure.
    assert_eq!(slo.species[0].completed, 1);
    assert_eq!(slo.species[0].failed_shard_lost, 1);
    let lost = results.iter().find(|r| r.id.0 == 1).unwrap();
    assert!(matches!(lost.outcome, Outcome::ShardLost { tick: 5 }));
}
