//! Typed errors of the **core** (embedded) layer.
//!
//! The core profile (`--no-default-features`) has no `anyhow`, so every
//! fallible core API returns this small enum instead. The host layer
//! converts transparently: under `std` the enum implements
//! [`std::error::Error`], so `?` lifts a [`CoreError`] into
//! `anyhow::Result` at the seam with no glue code.

use core::fmt;

use alloc::string::String;

/// Error type of the float-free integer datapath.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoreError {
    /// `Activation::from_name` saw a name outside the activation table.
    UnknownActivation(String),
    /// An SQNN layer exceeds the packed fast path's stack scratch
    /// ([`crate::nn::sqnn::MAX_WIDTH`]).
    LayerTooWide { width: usize, max: usize },
    /// An SQNN was constructed with no layers.
    EmptyNetwork,
    /// Adjacent SQNN layers disagree on their shared dimension, or a
    /// layer's weight/bias vectors do not match its declared shape.
    LayerShapeMismatch { layer: usize },
}

impl fmt::Display for CoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoreError::UnknownActivation(name) => {
                write!(f, "unknown activation {name:?}")
            }
            CoreError::LayerTooWide { width, max } => {
                write!(f, "layer width {width} exceeds the packed fast path ({max})")
            }
            CoreError::EmptyNetwork => write!(f, "SQNN needs at least one layer"),
            CoreError::LayerShapeMismatch { layer } => {
                write!(f, "SQNN layer {layer}: dimension/shape mismatch")
            }
        }
    }
}

#[cfg(feature = "std")]
impl std::error::Error for CoreError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = CoreError::UnknownActivation("relu".into());
        assert!(e.to_string().contains("relu"));
        let e = CoreError::LayerTooWide { width: 200, max: 128 };
        assert!(e.to_string().contains("200") && e.to_string().contains("128"));
        assert!(CoreError::EmptyNetwork.to_string().contains("layer"));
        assert!(CoreError::LayerShapeMismatch { layer: 2 }.to_string().contains('2'));
    }

    #[test]
    fn lifts_into_anyhow_at_the_seam() {
        fn host() -> anyhow::Result<()> {
            Err(CoreError::EmptyNetwork)?
        }
        let err = host().unwrap_err();
        assert!(err.to_string().contains("SQNN"));
    }
}
