//! PJRT runtime — loads the HLO-text artifacts produced at build time by
//! `python/compile/aot.py` (the L2 JAX graph, with the L1 Pallas kernel
//! lowered inline) and executes them on the request path. This is the
//! only place Python output touches the runtime, and it is data (HLO
//! text), never code.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::vn::HForceModel;

/// A PJRT CPU session.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled computation. Convention (enforced by `aot.py`): inputs are
/// f32 arrays, output is a tuple of f32 arrays (`return_tuple=True`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// A host-side f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "tensor shape {:?} != data len {}", dims, data.len());
        Ok(Tensor { data, dims: dims.to_vec() })
    }
    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], dims: vec![] }
    }
    pub fn vec1(v: &[f32]) -> Tensor {
        Tensor { data: v.to_vec(), dims: vec![v.len()] }
    }
    pub fn mat(rows: &[Vec<f32>]) -> Tensor {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Tensor { data, dims: vec![r, c] }
    }
}

impl Executable {
    /// Execute with f32 tensor inputs; returns the tuple elements as f32
    /// tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(&t.data);
                if t.dims.is_empty() {
                    // scalar: reshape to rank-0
                    Ok(lit.reshape(&[])?)
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let elems = out.to_tuple().context("decomposing result tuple")?;
        elems
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("result shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                // convert (jax may emit f32 already; convert is cheap/noop)
                let lit = lit.convert(xla::PrimitiveType::F32)?;
                let data = lit.to_vec::<f32>().context("result to_vec")?;
                Tensor::new(data, &dims)
            })
            .collect()
    }
}

/// Water force model backed by an AOT-compiled MLP graph: the measured
/// vN-MLMD path of Table III. The artifact contract (see `aot.py`):
/// input `f32[2,3]` (feature rows for both hydrogens), output tuple of
/// one `f32[2,2]` (local-frame coefficients).
pub struct HloForceModel {
    pub exe: Executable,
    pub calls: u64,
}

impl HloForceModel {
    pub fn load(rt: &Runtime, path: &Path) -> Result<Self> {
        Ok(HloForceModel { exe: rt.load_hlo_text(path)?, calls: 0 })
    }
}

impl HForceModel for HloForceModel {
    fn eval(&mut self, feats: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]> {
        let flat: Vec<f32> = feats.iter().flatten().map(|&x| x as f32).collect();
        let out = self.exe.run(&[Tensor::new(flat, &[2, 3])?])?;
        anyhow::ensure!(out.len() == 1 && out[0].dims == vec![2, 2], "bad output shape");
        let d = &out[0].data;
        self.calls += 1;
        Ok([[d[0] as f64, d[1] as f64], [d[2] as f64, d[3] as f64]])
    }
    fn name(&self) -> String {
        format!("pjrt:{}", self.exe.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a computation in-process with XlaBuilder (no python needed):
    /// f(x, w) = tuple(x·w + 1) over f32[2,3]·f32[3,2].
    fn make_matmul_exe(rt: &Runtime) -> Executable {
        let b = xla::XlaBuilder::new("test_matmul");
        let x = b
            .parameter(0, xla::ElementType::F32, &[2, 3], "x")
            .unwrap();
        let w = b
            .parameter(1, xla::ElementType::F32, &[3, 2], "w")
            .unwrap();
        let y = x.matmul(&w).unwrap();
        let one = b.c0(1.0f32).unwrap();
        let y = (y + one).unwrap();
        let comp = b.build(&b.tuple(&[y]).unwrap()).unwrap();
        let exe = rt.client.compile(&comp).unwrap();
        Executable { exe, name: "test_matmul".into() }
    }

    #[test]
    fn pjrt_cpu_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
        let exe = make_matmul_exe(&rt);
        let x = Tensor::mat(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let w = Tensor::mat(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]]);
        let out = exe.run(&[x, w]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![2, 2]);
        assert_eq!(out[0].data, vec![5.0, 6.0, 11.0, 12.0]);
    }

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![1.0; 5], &[2, 3]).is_err());
        assert!(Tensor::new(vec![1.0; 6], &[2, 3]).is_ok());
        assert_eq!(Tensor::scalar(2.0).dims.len(), 0);
    }

    #[test]
    fn hlo_text_artifact_roundtrip_if_present() {
        // Full AOT path (python → HLO text → PJRT) — exercised when the
        // artifacts exist; `make artifacts` builds them.
        let path = crate::artifact_path("water_mlp.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut model = HloForceModel::load(&rt, &path).unwrap();
        let out = model
            .eval(&[[1.03, 0.65, 1.03], [1.02, 0.66, 1.04]])
            .unwrap();
        for row in out {
            for v in row {
                assert!(v.is_finite());
            }
        }
        assert_eq!(model.calls, 1);
    }
}
