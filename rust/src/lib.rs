//! # NvN-MLMD
//!
//! Reproduction of *"A Heterogeneous Parallel Non-von Neumann Architecture
//! System for Accurate and Efficient Machine Learning Molecular Dynamics"*
//! (IEEE TCSI 2023, DOI 10.1109/TCSI.2023.3255199).
//!
//! The crate is the Layer-3 (run-time) half of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1/L2** live in `python/compile/` and run only at build time: the
//!   Pallas shift-quantized MLP kernel, the JAX MLMD compute graph, the
//!   quantization-aware training pipeline, and the AOT lowering to HLO text.
//! * **L3** (this crate) owns everything on the request path: the
//!   heterogeneous coordinator that mirrors the paper's CPU + FPGA + 2×ASIC
//!   topology, bit/cycle-accurate device simulators, the MD engine, the
//!   physics oracles used as the DFT surrogate, the analysis stack, and the
//!   PJRT runtime that executes the AOT artifacts as the von-Neumann
//!   baseline.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! (E1–E10 map to the paper's Figs. 3–10 and Tables I–III).

pub mod util;
pub mod linalg;
pub mod fixedpoint;
pub mod quant;
pub mod nn;
pub mod hw;
pub mod asic;
pub mod fpga;
pub mod md;
pub mod potentials;
pub mod features;
pub mod datasets;
pub mod analysis;
pub mod dft;
pub mod coordinator;
pub mod runtime;
pub mod benchkit;
pub mod testkit;
pub mod exp;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Canonical location of build artifacts (AOT HLO, trained models,
/// generated datasets) relative to the repository root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve a path under the artifacts directory, honouring the
/// `NVNMD_ARTIFACTS` environment variable so tests and benches work from
/// any working directory.
pub fn artifact_path(rel: &str) -> std::path::PathBuf {
    let base = std::env::var("NVNMD_ARTIFACTS")
        .unwrap_or_else(|_| ARTIFACTS_DIR.to_string());
    let p = std::path::Path::new(&base).join(rel);
    if p.exists() {
        return p;
    }
    // Fall back to the repo root (benches may run from target/..).
    for up in ["..", "../..", "../../.."] {
        let q = std::path::Path::new(up).join(&base).join(rel);
        if q.exists() {
            return q;
        }
    }
    p
}
