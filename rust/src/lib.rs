//! # NvN-MLMD
//!
//! Reproduction of *"A Heterogeneous Parallel Non-von Neumann Architecture
//! System for Accurate and Efficient Machine Learning Molecular Dynamics"*
//! (IEEE TCSI 2023, DOI 10.1109/TCSI.2023.3255199).
//!
//! The crate is the Layer-3 (run-time) half of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1/L2** live in `python/compile/` and run only at build time: the
//!   Pallas shift-quantized MLP kernel, the JAX MLMD compute graph, the
//!   quantization-aware training pipeline, and the AOT lowering to HLO text.
//! * **L3** (this crate) owns everything on the request path: the
//!   heterogeneous coordinator that mirrors the paper's CPU + FPGA + 2×ASIC
//!   topology, bit/cycle-accurate device simulators, the MD engine, the
//!   physics oracles used as the DFT surrogate, the analysis stack, and the
//!   PJRT runtime that executes the AOT artifacts as the von-Neumann
//!   baseline.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! (E1–E10 map to the paper's Figs. 3–10 and Tables I–III).
//!
//! ## Core/host seam (feature flags)
//!
//! The paper's thesis is that the exact integer SQNN datapath runs on
//! low-end hardware. The crate is therefore split into a **core layer**
//! that compiles for embedded targets and a **host layer** that needs an
//! operating system:
//!
//! | profile | cargo flags | contents | guarantees |
//! |---|---|---|---|
//! | host (default) | `--features std` (default) | everything below plus float conditioning, model loading/JSON, device simulators, farm/coordinator, MD engine, experiments, benches | full crate |
//! | core | `--no-default-features` | [`fixedpoint`] (Q13 + `shift_raw`), [`quant`]'s integer shift-apply, [`nn::activation`] (`phi_q13`, `tanh_q13`), [`nn::sqnn`] scalar + weight-stationary batch kernels, [`fpga::rsqrt`], [`fpga::qint`] (26-bit integrator arithmetic), [`error::CoreError`] | `no_std` + `alloc`; float-free (no f64 in any kernel); `anyhow`-free (typed [`error::CoreError`]); no `std`-only sync primitives (const tables instead of `OnceLock`) |
//!
//! The split is behavior-preserving by construction: the core kernels are
//! the *same code* in both profiles (only float convenience wrappers and
//! host glue are gated), and `rust/tests/core_golden.rs` pins the kernels
//! to shared golden vectors so the two profiles can never diverge by a
//! single bit.
//!
//! Always-compiled (core) modules: [`error`], [`fixedpoint`], [`quant`],
//! [`nn`] (integer subset), [`fpga`] (`rsqrt`/`qint` subset).
//! Host-only modules: [`util`], [`linalg`], [`hw`], [`asic`], [`md`],
//! [`potentials`], [`features`], [`datasets`], [`analysis`], [`dft`],
//! [`coordinator`], [`runtime`], [`benchkit`], [`testkit`], [`exp`].

#![cfg_attr(not(feature = "std"), no_std)]

// The core profile is alloc-only (Vec/String for network storage); under
// `std` this is the same allocator the rest of the crate uses.
extern crate alloc;

pub mod error;
pub mod fixedpoint;
pub mod quant;
pub mod nn;
pub mod fpga;

#[cfg(feature = "std")]
pub mod util;
#[cfg(feature = "std")]
pub mod linalg;
#[cfg(feature = "std")]
pub mod hw;
#[cfg(feature = "std")]
pub mod asic;
#[cfg(feature = "std")]
pub mod md;
#[cfg(feature = "std")]
pub mod potentials;
#[cfg(feature = "std")]
pub mod features;
#[cfg(feature = "std")]
pub mod datasets;
#[cfg(feature = "std")]
pub mod analysis;
#[cfg(feature = "std")]
pub mod dft;
#[cfg(feature = "std")]
pub mod coordinator;
#[cfg(feature = "std")]
pub mod runtime;
#[cfg(feature = "std")]
pub mod benchkit;
#[cfg(feature = "std")]
pub mod testkit;
#[cfg(feature = "std")]
pub mod exp;

/// Crate-wide result type (host layer). Core APIs return
/// `Result<T, error::CoreError>` instead.
#[cfg(feature = "std")]
pub type Result<T> = anyhow::Result<T>;

/// Canonical location of build artifacts (AOT HLO, trained models,
/// generated datasets) relative to the repository root.
#[cfg(feature = "std")]
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve a path under the artifacts directory, honouring the
/// `NVNMD_ARTIFACTS` environment variable so tests and benches work from
/// any working directory.
#[cfg(feature = "std")]
pub fn artifact_path(rel: &str) -> std::path::PathBuf {
    let base = std::env::var("NVNMD_ARTIFACTS")
        .unwrap_or_else(|_| ARTIFACTS_DIR.to_string());
    let p = std::path::Path::new(&base).join(rel);
    if p.exists() {
        return p;
    }
    // Fall back to the repo root (benches may run from target/..).
    for up in ["..", "../..", "../../.."] {
        let q = std::path::Path::new(up).join(&base).join(rel);
        if q.exists() {
            return q;
        }
    }
    p
}
