//! Cycle-level simulator of the MLP ASIC (Fig. 7 / §IV-B) — the paper's
//! taped-out SilTerra 180 nm chip.
//!
//! Non-von-Neumann organization: the quantized shift parameters
//! (s, n₁..n_K) and biases live in distributed near-compute storage,
//! loaded **once** at initialization (`MlpChip::program`) and never
//! re-fetched; layer results flow register-to-register without any
//! off-chip traffic. `infer` is bit-accurate (it *is* the `nn::Sqnn`
//! datapath) and additionally accounts cycles and operation energies per
//! inference.

use anyhow::Result;

use crate::fixedpoint::Q13;
use crate::hw::power::{EnergyModel, OpCounts, ProcessNode, CHIP_POWER_W};
use crate::nn::sqnn::BatchScratch;
use crate::nn::{Mlp, Sqnn};

/// Static configuration of the chip.
#[derive(Debug, Clone, Copy)]
pub struct ChipConfig {
    /// Core clock (paper: 25 MHz).
    pub clock_hz: f64,
    /// Fabrication node (paper: SilTerra 180 nm).
    pub node: ProcessNode,
    /// Die area (paper: 1.73 mm²) — reported, not derived.
    pub die_mm2: f64,
    /// Parallel MLP lanes on the die — the §VI A₂ knob: transistor
    /// density at advanced nodes buys replicated shift–accumulate
    /// datapaths, so a batch of B inferences takes ⌈B/lanes⌉ sequential
    /// waves instead of B. The taped-out 180 nm chip has one lane.
    pub lanes: usize,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            clock_hz: crate::hw::timing::CLOCK_HZ,
            node: ProcessNode::N180,
            die_mm2: 1.73,
            lanes: 1,
        }
    }
}

/// One MLP chip instance.
#[derive(Debug, Clone)]
pub struct MlpChip {
    pub cfg: ChipConfig,
    pub id: usize,
    net: Option<Sqnn>,
    /// Lifetime counters.
    pub inferences: u64,
    pub total_cycles: u64,
    pub ops: OpCounts,
    /// §Perf: per-inference ops/latency derived once at program time
    /// (the network is static after initialization — NvN).
    per_inf_ops: OpCounts,
    per_inf_cycles: u64,
    /// Chip-owned batch-kernel scratch: steady-state
    /// [`Self::infer_batch_into`] allocates nothing.
    scratch: BatchScratch,
}

impl MlpChip {
    pub fn new(id: usize, cfg: ChipConfig) -> Self {
        MlpChip {
            cfg,
            id,
            net: None,
            inferences: 0,
            total_cycles: 0,
            ops: OpCounts::default(),
            per_inf_ops: OpCounts::default(),
            per_inf_cycles: 0,
            scratch: BatchScratch::default(),
        }
    }

    /// Program the distributed weight memory (the one-time
    /// initialization the CPU performs, §IV-A: "w and b are only
    /// initialized once before MLP inference").
    pub fn program(&mut self, model: &Mlp, k: usize) {
        self.program_sqnn(Sqnn::from_mlp(model, k));
    }

    pub fn program_sqnn(&mut self, net: Sqnn) {
        self.net = Some(net);
        self.per_inf_cycles = self.latency_cycles();
        self.per_inf_ops = self.derive_per_inference_ops();
    }

    /// Static per-inference op counts of the programmed network.
    fn derive_per_inference_ops(&self) -> OpCounts {
        let net = self.net.as_ref().expect("chip not programmed");
        let mut ops = OpCounts::default();
        for (li, l) in net.layers.iter().enumerate() {
            let weights = l.w.len() as u64;
            let terms: u64 = l.w.iter().map(|w| w.terms() as u64).sum();
            ops.shifts += terms; // active SU shifters
            ops.adds += terms.saturating_sub(weights) + weights; // SU sums + tree
            ops.adds += l.out_dim as u64; // bias adds
            // NB: no sram_reads — the NvN point: weights/biases are
            // statically wired into the SUs (distributed storage is part
            // of the datapath), nothing is fetched per inference.
            ops.reg_writes_bits += (l.out_dim as u64) * 13;
            let is_hidden = li + 1 < net.layers.len();
            if is_hidden || net.output_activation {
                // AU: one squarer-multiply, one subtract per neuron
                ops.mults += l.out_dim as u64;
                ops.adds += l.out_dim as u64;
            }
        }
        ops
    }

    pub fn is_programmed(&self) -> bool {
        self.net.is_some()
    }

    pub fn network(&self) -> Option<&Sqnn> {
        self.net.as_ref()
    }

    /// Pipeline latency in cycles for one inference: per layer, one
    /// cycle for the parallel SU shift–accumulate, ⌈log₂(fan_in)⌉ for
    /// the MU adder tree, one for bias+saturation, one for the AU
    /// (hidden layers). Plus input/output register stages.
    pub fn latency_cycles(&self) -> u64 {
        let net = self.net.as_ref().expect("chip not programmed");
        let mut cycles = 2; // input latch + output latch
        let n_layers = net.layers.len();
        for (li, l) in net.layers.iter().enumerate() {
            let tree = (l.in_dim.max(2) as f64).log2().ceil() as u64;
            cycles += 1 + tree + 1;
            if li + 1 < n_layers || net.output_activation {
                cycles += 1; // AU
            }
        }
        cycles
    }

    /// Run one inference. Returns the Q13 outputs; updates cycle and
    /// energy counters.
    pub fn infer(&mut self, features: &[Q13]) -> Result<Vec<Q13>> {
        let net = self
            .net
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("chip {} not programmed", self.id))?;
        anyhow::ensure!(
            features.len() == net.in_dim(),
            "chip {}: feature width {} != {}",
            self.id,
            features.len(),
            net.in_dim()
        );
        let out = net.forward_q13(features);

        // Account cycles and ops (precomputed at program time — the
        // network is static, §Perf).
        self.total_cycles += self.per_inf_cycles;
        self.inferences += 1;
        let per_inf = self.per_inf_ops;
        self.ops.merge(&per_inf);
        Ok(out)
    }

    /// Allocation-free inference into a caller buffer (§Perf hot path
    /// used by the coordinator step).
    pub fn infer_into(&mut self, features: &[Q13], out: &mut [Q13]) -> Result<()> {
        let net = self
            .net
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("chip {} not programmed", self.id))?;
        anyhow::ensure!(
            features.len() == net.in_dim() && out.len() == net.out_dim(),
            "chip {}: io width mismatch",
            self.id
        );
        net.forward_q13_into(features, out);
        self.total_cycles += self.per_inf_cycles;
        self.inferences += 1;
        let per_inf = self.per_inf_ops;
        self.ops.merge(&per_inf);
        Ok(())
    }

    /// Modelled latency of a batch of `batch` inferences under the lane
    /// model: the lanes run in lock-step, so the batch drains in
    /// ⌈batch/lanes⌉ sequential pipeline waves.
    pub fn batch_latency_cycles(&self, batch: usize) -> u64 {
        let lanes = self.cfg.lanes.max(1);
        (batch.div_ceil(lanes)) as u64 * self.per_inf_cycles
    }

    /// Batched inference on an SoA batch (feature `i` of lane `b` at
    /// `xs[i*batch + b]`, output `o` of lane `b` at `out[o*batch + b]`):
    /// the weight-stationary **SWAR shift-program kernel**
    /// (`Sqnn::forward_q13_batch_with` — 8-lane accumulator tiles
    /// streaming each layer's precompiled instruction stream,
    /// bit-identical per lane to the scalar datapath) run against the
    /// chip-owned scratch (allocation-free in steady state), plus the
    /// lane-model cycle accounting and per-inference op/energy
    /// accounting. The SWAR tile is the software analogue of the lane
    /// model's replicated shift–add array: `cfg.lanes` models silicon
    /// parallelism in cycles, the tile delivers the same parallelism in
    /// host SIMD.
    pub fn infer_batch_into(&mut self, xs: &[Q13], batch: usize, out: &mut [Q13]) -> Result<()> {
        let net = self
            .net
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("chip {} not programmed", self.id))?;
        anyhow::ensure!(
            xs.len() == net.in_dim() * batch && out.len() == net.out_dim() * batch,
            "chip {}: batch io width mismatch (batch {batch})",
            self.id
        );
        net.forward_q13_batch_with(xs, batch, out, &mut self.scratch);
        self.total_cycles += self.batch_latency_cycles(batch);
        self.inferences += batch as u64;
        self.ops.merge(&self.per_inf_ops.scale(batch as u64));
        Ok(())
    }

    /// Float convenience wrapper.
    pub fn infer_f64(&mut self, features: &[f64]) -> Result<Vec<f64>> {
        let q: Vec<Q13> = features.iter().map(|&x| Q13::from_f64(x)).collect();
        Ok(self.infer(&q)?.into_iter().map(|v| v.to_f64()).collect())
    }

    /// Modelled *dynamic* energy consumed so far (pJ).
    pub fn dynamic_energy_pj(&self) -> f64 {
        self.ops.energy_pj(&EnergyModel::at(self.cfg.node))
    }

    /// Modelled chip power at full utilization: the calibrated measured
    /// power (static-dominated at 25 MHz; see `hw::power`).
    pub fn power_w(&self) -> f64 {
        CHIP_POWER_W
    }

    /// Simulated wall-clock time spent inferring (s of chip time).
    pub fn busy_seconds(&self) -> f64 {
        self.total_cycles as f64 / self.cfg.clock_hz
    }

    pub fn reset_counters(&mut self) {
        self.inferences = 0;
        self.total_cycles = 0;
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::util::rng::Pcg;

    fn water_like_chip() -> MlpChip {
        let mut rng = Pcg::new(3);
        let mut m = Mlp::init_random("w", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.7;
            }
        }
        let mut chip = MlpChip::new(0, ChipConfig::default());
        chip.program(&m, 3);
        chip
    }

    #[test]
    fn unprogrammed_chip_refuses() {
        let mut chip = MlpChip::new(0, ChipConfig::default());
        assert!(!chip.is_programmed());
        assert!(chip.infer(&[Q13::ZERO; 3]).is_err());
    }

    #[test]
    fn wrong_width_rejected() {
        let mut chip = water_like_chip();
        assert!(chip.infer(&[Q13::ZERO; 2]).is_err());
        assert!(chip.infer(&[Q13::ZERO; 3]).is_ok());
    }

    #[test]
    fn infer_matches_sqnn_bit_exactly() {
        let mut chip = water_like_chip();
        let net = chip.network().unwrap().clone();
        let mut rng = Pcg::new(5);
        for _ in 0..500 {
            let x: Vec<Q13> = (0..3).map(|_| Q13::from_f64(rng.range(-1.5, 1.5))).collect();
            let a = chip.infer(&x).unwrap();
            let b = net.forward_q13(&x);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn batch_inference_matches_scalar_bit_exactly() {
        let mut chip = water_like_chip();
        let net = chip.network().unwrap().clone();
        let mut rng = Pcg::new(17);
        // 13 and 67 straddle the SWAR tile width (full tiles + ragged
        // tails); 1 and 5 are tail-only; 32 is tile-only.
        for batch in [1usize, 5, 13, 32, 67] {
            let lanes: Vec<Vec<Q13>> = (0..batch)
                .map(|_| (0..3).map(|_| Q13::from_f64(rng.range(-2.0, 2.0))).collect())
                .collect();
            let mut xs = vec![Q13::ZERO; 3 * batch];
            for (b, lane) in lanes.iter().enumerate() {
                for (i, &v) in lane.iter().enumerate() {
                    xs[i * batch + b] = v;
                }
            }
            let mut out = vec![Q13::ZERO; 2 * batch];
            chip.infer_batch_into(&xs, batch, &mut out).unwrap();
            for (b, lane) in lanes.iter().enumerate() {
                let want = net.forward_q13(lane);
                assert_eq!(out[b], want[0]);
                assert_eq!(out[batch + b], want[1]);
            }
        }
    }

    #[test]
    fn batch_accounting_matches_scalar_with_one_lane() {
        // lanes = 1: a batch of B must cost exactly B scalar inferences
        // in cycles, op counts, and inference count.
        let mut a = water_like_chip();
        let mut b = water_like_chip();
        let x = [Q13::from_f64(0.9), Q13::from_f64(0.5), Q13::from_f64(1.1)];
        let batch = 16usize;
        let mut xs = vec![Q13::ZERO; 3 * batch];
        for lane in 0..batch {
            for i in 0..3 {
                xs[i * batch + lane] = x[i];
            }
        }
        let mut out = vec![Q13::ZERO; 2 * batch];
        a.infer_batch_into(&xs, batch, &mut out).unwrap();
        for _ in 0..batch {
            b.infer(&x).unwrap();
        }
        assert_eq!(a.inferences, b.inferences);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.ops, b.ops);
    }

    #[test]
    fn batch_zero_is_a_noop() {
        // Farm edge case: an empty shard tick must be accepted and must
        // not move any counter (no inferences, no cycles, no ops, no
        // scratch garbage on later calls).
        let mut chip = water_like_chip();
        let mut out: Vec<Q13> = Vec::new();
        chip.infer_batch_into(&[], 0, &mut out).unwrap();
        assert!(out.is_empty());
        assert_eq!(chip.inferences, 0);
        assert_eq!(chip.total_cycles, 0);
        assert_eq!(chip.ops, crate::hw::power::OpCounts::default());
        // and a real batch afterwards still works
        let net = chip.network().unwrap().clone();
        let mut xs = vec![Q13::ZERO; 3];
        xs[0] = Q13::from_f64(0.8);
        let mut y = vec![Q13::ZERO; 2];
        chip.infer_batch_into(&xs, 1, &mut y).unwrap();
        let want = net.forward_q13(&[xs[0], xs[1], xs[2]]);
        assert_eq!(y, want);
        assert_eq!(chip.inferences, 1);
    }

    #[test]
    fn lane_model_compresses_batch_latency() {
        let mut rng = Pcg::new(3);
        let mut m = Mlp::init_random("w", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.7;
            }
        }
        let mut chip = MlpChip::new(0, ChipConfig { lanes: 4, ..ChipConfig::default() });
        chip.program(&m, 3);
        let per = chip.latency_cycles();
        // 10 inferences on 4 lanes: ceil(10/4) = 3 waves.
        assert_eq!(chip.batch_latency_cycles(10), 3 * per);
        assert_eq!(chip.batch_latency_cycles(1), per);
        assert_eq!(chip.batch_latency_cycles(0), 0);
        // one lane degenerates to the sequential model
        let mut seq = MlpChip::new(1, ChipConfig::default());
        seq.program(&m, 3);
        assert_eq!(seq.batch_latency_cycles(10), 10 * per);
    }

    #[test]
    fn latency_matches_architecture() {
        let chip = water_like_chip();
        // layers 3→3, 3→3, 3→2: per hidden layer 1+⌈log2 3⌉+1+1 = 5,
        // output layer 1+2+1 = 4, +2 IO = 16.
        assert_eq!(chip.latency_cycles(), 2 + 5 + 5 + 4);
    }

    #[test]
    fn counters_accumulate_linearly() {
        let mut chip = water_like_chip();
        let x = [Q13::from_f64(1.0), Q13::from_f64(0.6), Q13::from_f64(1.0)];
        chip.infer(&x).unwrap();
        let ops1 = chip.ops;
        let cyc1 = chip.total_cycles;
        for _ in 0..9 {
            chip.infer(&x).unwrap();
        }
        assert_eq!(chip.inferences, 10);
        assert_eq!(chip.total_cycles, 10 * cyc1);
        assert_eq!(chip.ops, ops1.scale(10));
        chip.reset_counters();
        assert_eq!(chip.inferences, 0);
        assert_eq!(chip.total_cycles, 0);
    }

    #[test]
    fn energy_accounting_is_static_dominated_at_25mhz() {
        // Run the chip "for one second" of simulated time and check the
        // dynamic energy is a small fraction of the 8.7 mW measured
        // budget — the paper's point that the NvN datapath is cheap.
        let mut chip = water_like_chip();
        let x = [Q13::from_f64(1.0), Q13::from_f64(0.6), Q13::from_f64(1.0)];
        let lat = chip.latency_cycles();
        let inf_per_s = (chip.cfg.clock_hz / lat as f64) as u64;
        // scale down 100× and extrapolate to keep the test fast
        let n = (inf_per_s / 100).max(1);
        for _ in 0..n {
            chip.infer(&x).unwrap();
        }
        let dyn_w = chip.dynamic_energy_pj() * 1e-12 * 100.0 / 1.0;
        assert!(dyn_w < 0.2 * chip.power_w(), "dynamic {dyn_w} W vs {}", chip.power_w());
        assert!(dyn_w > 0.0);
    }

    #[test]
    fn busy_time_tracks_cycles() {
        let mut chip = water_like_chip();
        let x = [Q13::ZERO; 3];
        for _ in 0..1000 {
            chip.infer(&x).unwrap();
        }
        let t = chip.busy_seconds();
        let expect = 1000.0 * chip.latency_cycles() as f64 / chip.cfg.clock_hz;
        assert!((t - expect).abs() < 1e-12);
    }
}
