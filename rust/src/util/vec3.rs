//! 3-vector used throughout the MD stack.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Vec3 { x, y, z }
    }
    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }
    pub fn norm_sq(self) -> f64 {
        self.dot(self)
    }
    pub fn norm(self) -> f64 {
        self.norm_sq().sqrt()
    }
    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        debug_assert!(n > 0.0, "normalizing zero vector");
        self / n
    }
    /// Angle between two vectors in radians.
    pub fn angle_between(self, o: Vec3) -> f64 {
        let c = (self.dot(o) / (self.norm() * o.norm())).clamp(-1.0, 1.0);
        c.acos()
    }
    pub fn to_array(self) -> [f64; 3] {
        [self.x, self.y, self.z]
    }
    pub fn from_array(a: [f64; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }
    /// Component-wise minimum-image wrap into a cubic box of side `l`.
    pub fn min_image(self, l: f64) -> Vec3 {
        let wrap = |v: f64| v - l * (v / l).round();
        Vec3::new(wrap(self.x), wrap(self.y), wrap(self.z))
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}
impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}
impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}
impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}
impl Mul<Vec3> for f64 {
    type Output = Vec3;
    fn mul(self, v: Vec3) -> Vec3 {
        v * self
    }
}
impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}
impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}
impl SubAssign for Vec3 {
    fn sub_assign(&mut self, o: Vec3) {
        *self = *self - o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_algebra() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-1.0, 0.5, 2.0);
        assert_eq!(a + b, Vec3::new(0.0, 2.5, 5.0));
        assert_eq!(a - b, Vec3::new(2.0, 1.5, 1.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert!((a.dot(b) - 6.0).abs() < 1e-12);
        assert_eq!(a.cross(b), Vec3::new(2.5, -5.0, 2.5));
        assert!((a.cross(b).dot(a)).abs() < 1e-12);
    }

    #[test]
    fn angles() {
        let x = Vec3::new(1.0, 0.0, 0.0);
        let y = Vec3::new(0.0, 2.0, 0.0);
        assert!((x.angle_between(y) - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
        assert!((x.angle_between(x * 3.0)).abs() < 1e-7);
        assert!((x.angle_between(-x) - std::f64::consts::PI).abs() < 1e-7);
    }

    #[test]
    fn min_image_wraps() {
        let v = Vec3::new(5.4, -5.4, 0.1).min_image(10.0);
        assert!((v.x - (-4.6)).abs() < 1e-12);
        assert!((v.y - 4.6).abs() < 1e-12);
        assert!((v.z - 0.1).abs() < 1e-12);
    }
}
