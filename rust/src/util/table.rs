//! Plain-text table rendering for CLI/bench reports that mirror the
//! paper's tables.

/// Render rows as an aligned ASCII table with a header rule.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.chars().count()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row width mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push(' ');
            line.push_str(c);
            for _ in c.chars().count()..*w {
                line.push(' ');
            }
            line.push_str(" |");
        }
        line.push('\n');
        line
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    let mut rule = String::from("|");
    for w in &widths {
        for _ in 0..w + 2 {
            rule.push('-');
        }
        rule.push('|');
    }
    rule.push('\n');
    out.push_str(&rule);
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Format a float in scientific notation like the paper ("1.6×10⁻⁶" → "1.6e-6").
pub fn sci(x: f64, digits: usize) -> String {
    if x == 0.0 {
        return "0".into();
    }
    format!("{:.*e}", digits, x)
}

/// Format with fixed decimals.
pub fn fix(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

/// Percent with given decimals.
pub fn pct(x: f64, digits: usize) -> String {
    format!("{:.*}%", digits, 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(
            &["Method", "S (s/step/atom)"],
            &[
                vec!["DFT".into(), "1.9".into()],
                vec!["NvN-MLMD".into(), "1.6e-6".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
        assert!(t.contains("NvN-MLMD"));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        render(&["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(sci(1.6e-6, 1), "1.6e-6");
        assert_eq!(fix(104.876, 2), "104.88");
        assert_eq!(pct(0.0106, 2), "1.06%");
    }
}
