//! Unit system and physical constants.
//!
//! Internal MD units: length Å, time fs, energy eV, mass amu. In this
//! system accelerations need one conversion factor because
//! 1 eV/(Å·amu) = [`ACC_CONV`] Å/fs².

/// Boltzmann constant, eV/K.
pub const KB: f64 = 8.617333262e-5;

/// 1 eV/(Å·amu) expressed in Å/fs² — the force→acceleration conversion.
/// (1.602176634e-19 J / (1e-10 m · 1.66053906660e-27 kg) = 9.648533e13
/// m/s² = 9.648533e-3 Å/fs².)
pub const ACC_CONV: f64 = 9.648533212331e-3;

/// Speed of light in cm/fs (for wavenumber conversion).
pub const C_CM_PER_FS: f64 = 2.99792458e-5;

/// Convert an angular-frequency-squared eigenvalue λ (in eV/(Å²·amu),
/// i.e. mass-weighted Hessian units) to a wavenumber in cm⁻¹.
/// ω [rad/fs] = sqrt(λ·ACC_CONV); ν̃ = ω/(2πc).
pub fn hessian_eig_to_wavenumber(lambda: f64) -> f64 {
    if lambda <= 0.0 {
        return 0.0;
    }
    let omega = (lambda * ACC_CONV).sqrt(); // rad/fs
    omega / (2.0 * std::f64::consts::PI * C_CM_PER_FS)
}

/// Convert a cyclic frequency in 1/fs to a wavenumber in cm⁻¹.
pub fn freq_fs_to_wavenumber(f: f64) -> f64 {
    f / C_CM_PER_FS
}

/// Convert a wavenumber in cm⁻¹ to a cyclic frequency in 1/fs.
pub fn wavenumber_to_freq_fs(nu: f64) -> f64 {
    nu * C_CM_PER_FS
}

/// Atomic masses in amu.
pub mod mass {
    pub const H: f64 = 1.00794;
    pub const C: f64 = 12.011;
    pub const O: f64 = 15.9994;
    pub const SI: f64 = 28.0855;
}

/// eV per hartree, bohr per Å (used by the toy SCF engine).
pub const HARTREE_EV: f64 = 27.211386245988;
pub const BOHR_A: f64 = 0.529177210903;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oh_stretch_wavenumber_sanity() {
        // Diatomic OH with k = 50 eV/Å²: ν̃ = sqrt(k/μ)·conv ≈ 3700–3800 cm⁻¹.
        let mu = mass::O * mass::H / (mass::O + mass::H);
        let k = 50.0;
        let nu = hessian_eig_to_wavenumber(k / mu);
        assert!((3600.0..3900.0).contains(&nu), "nu={nu}");
    }

    #[test]
    fn wavenumber_roundtrip() {
        let nu = 1603.0;
        let f = wavenumber_to_freq_fs(nu);
        assert!((freq_fs_to_wavenumber(f) - nu).abs() < 1e-9);
        // 1603 cm⁻¹ → period ≈ 20.8 fs.
        assert!(((1.0 / f) - 20.8).abs() < 0.1, "period={}", 1.0 / f);
    }

    #[test]
    fn kt_room_temperature() {
        assert!((KB * 300.0 - 0.02585).abs() < 1e-4);
    }
}
