//! General-purpose substrates.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (serde, rand,
//! rustfft, …) are unavailable. The equivalents needed by the rest of the
//! system are implemented here as small, tested modules.

pub mod json;
pub mod rng;
pub mod fft;
pub mod units;
pub mod vec3;
pub mod table;

pub use vec3::Vec3;
