//! Deterministic pseudo-random numbers (the `rand` crate is unavailable
//! offline). PCG-XSH-RR 64/32 core with convenience distributions.
//!
//! Every stochastic component of the system (dataset sampling, velocity
//! initialization, property-test case generation) takes an explicit `Pcg`
//! so runs are reproducible from a seed recorded in the artifact metadata.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with an arbitrary 64-bit value; stream constant fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream (must be odd after shifting; handled).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut m = (self.next_u32() as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let threshold = n.wrapping_neg() % n;
            while lo < threshold {
                m = (self.next_u32() as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(11);
        let n = 200_000;
        let (mut sum, mut sq, mut cube) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
            cube += x * x * x;
        }
        let mean = sum / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((sq / n as f64 - 1.0).abs() < 0.02);
        assert!((cube / n as f64).abs() < 0.05, "skew");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Pcg::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg::new(9);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut uniq = idx.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 30);
    }
}
