//! Radix-2 FFT and spectral helpers (rustfft is unavailable offline).
//!
//! Used by the analysis stack to turn velocity/mode autocorrelation
//! functions into vibrational densities of states (paper Fig. 10).

use std::f64::consts::PI;

/// Complex number (no external num-complex to keep the dependency set to
/// the vendored closure).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cplx {
    pub re: f64,
    pub im: f64,
}

impl Cplx {
    pub const ZERO: Cplx = Cplx { re: 0.0, im: 0.0 };
    pub fn new(re: f64, im: f64) -> Self {
        Cplx { re, im }
    }
    pub fn conj(self) -> Self {
        Cplx::new(self.re, -self.im)
    }
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }
    fn mul(self, o: Cplx) -> Cplx {
        Cplx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
    fn add(self, o: Cplx) -> Cplx {
        Cplx::new(self.re + o.re, self.im + o.im)
    }
    fn sub(self, o: Cplx) -> Cplx {
        Cplx::new(self.re - o.re, self.im - o.im)
    }
}

/// In-place iterative radix-2 Cooley–Tukey FFT. `data.len()` must be a
/// power of two. `inverse` applies the conjugate transform *without* the
/// 1/N normalization (caller normalizes).
pub fn fft(data: &mut [Cplx], inverse: bool) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            data.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = Cplx::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = Cplx::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = data[i + k + len / 2].mul(w);
                data[i + k] = u.add(v);
                data[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Hann window coefficients of length n.
pub fn hann(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| 0.5 * (1.0 - (2.0 * PI * i as f64 / (n.max(2) - 1) as f64).cos()))
        .collect()
}

/// One-sided power spectrum of a real signal, zero-padded to a power of
/// two (≥ `min_len` if given). Returns (bin frequencies in cycles per
/// sample, power). Applies a Hann window when `window` is true.
pub fn power_spectrum(signal: &[f64], window: bool, min_len: Option<usize>) -> (Vec<f64>, Vec<f64>) {
    let n = signal.len();
    assert!(n > 1, "need at least 2 samples");
    let padded = next_pow2(n.max(min_len.unwrap_or(0)));
    let w = if window { hann(n) } else { vec![1.0; n] };
    let mut buf: Vec<Cplx> = (0..padded)
        .map(|i| {
            if i < n {
                Cplx::new(signal[i] * w[i], 0.0)
            } else {
                Cplx::ZERO
            }
        })
        .collect();
    fft(&mut buf, false);
    let half = padded / 2;
    let freqs = (0..half).map(|k| k as f64 / padded as f64).collect();
    let power = buf[..half].iter().map(|c| c.norm_sq() / n as f64).collect();
    (freqs, power)
}

/// Normalized autocorrelation of a real signal up to `max_lag` (inclusive
/// upper bound `max_lag-1`), computed directly (O(N·L) — our signals are
/// short enough, and the direct form avoids circular-correlation edge
/// effects).
pub fn autocorrelation(signal: &[f64], max_lag: usize) -> Vec<f64> {
    let n = signal.len();
    let max_lag = max_lag.min(n);
    let mean = signal.iter().sum::<f64>() / n as f64;
    let xs: Vec<f64> = signal.iter().map(|x| x - mean).collect();
    let mut acf = Vec::with_capacity(max_lag);
    let denom: f64 = xs.iter().map(|x| x * x).sum::<f64>().max(1e-300);
    for lag in 0..max_lag {
        let mut s = 0.0;
        for i in 0..n - lag {
            s += xs[i] * xs[i + lag];
        }
        acf.push(s / denom);
    }
    acf
}

/// Find the index of the maximum value; returns (index, value).
pub fn argmax(xs: &[f64]) -> (usize, f64) {
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.1 {
            best = (i, x);
        }
    }
    best
}

/// Refine a spectral peak location with a parabolic fit through the
/// three bins around `i` (standard quadratic interpolation). Returns the
/// sub-bin peak position.
pub fn parabolic_peak(power: &[f64], i: usize) -> f64 {
    if i == 0 || i + 1 >= power.len() {
        return i as f64;
    }
    let (a, b, c) = (power[i - 1], power[i], power[i + 1]);
    let denom = a - 2.0 * b + c;
    if denom.abs() < 1e-300 {
        return i as f64;
    }
    i as f64 + 0.5 * (a - c) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_matches_dft_small() {
        let n = 16;
        let mut rngish = 1u64;
        let mut next = || {
            rngish = rngish.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((rngish >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let signal: Vec<Cplx> = (0..n).map(|_| Cplx::new(next(), next())).collect();
        let mut fast = signal.clone();
        fft(&mut fast, false);
        // Naive DFT reference.
        for k in 0..n {
            let mut acc = Cplx::ZERO;
            for (t, s) in signal.iter().enumerate() {
                let ang = -2.0 * PI * (k * t) as f64 / n as f64;
                acc = acc.add(s.mul(Cplx::new(ang.cos(), ang.sin())));
            }
            assert!((acc.re - fast[k].re).abs() < 1e-9);
            assert!((acc.im - fast[k].im).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_inverse_roundtrip() {
        let n = 128;
        let orig: Vec<Cplx> = (0..n).map(|i| Cplx::new((i as f64).sin(), 0.25 * i as f64)).collect();
        let mut buf = orig.clone();
        fft(&mut buf, false);
        fft(&mut buf, true);
        for (a, b) in orig.iter().zip(&buf) {
            assert!((a.re - b.re / n as f64).abs() < 1e-9);
            assert!((a.im - b.im / n as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn spectrum_finds_tone() {
        // 90.5 cycles over 4096 samples, detect to sub-bin accuracy.
        let n = 4096;
        let f0 = 90.5 / n as f64;
        let signal: Vec<f64> = (0..n).map(|i| (2.0 * PI * f0 * i as f64).sin()).collect();
        let (freqs, power) = power_spectrum(&signal, true, Some(4 * n));
        let (i, _) = argmax(&power);
        let peak = parabolic_peak(&power, i);
        let df = freqs[1] - freqs[0];
        let f_est = peak * df;
        assert!((f_est - f0).abs() < 0.05 * f0, "f_est={f_est} f0={f0}");
    }

    #[test]
    fn autocorrelation_of_cosine_oscillates() {
        let n = 2000;
        let period = 50.0;
        let signal: Vec<f64> = (0..n).map(|i| (2.0 * PI * i as f64 / period).cos()).collect();
        let acf = autocorrelation(&signal, 200);
        assert!((acf[0] - 1.0).abs() < 1e-12);
        assert!((acf[50] - 1.0).abs() < 0.05, "acf[period]={}", acf[50]);
        assert!(acf[25] < -0.9, "acf[period/2]={}", acf[25]);
    }

    #[test]
    #[should_panic]
    fn fft_rejects_non_pow2() {
        let mut v = vec![Cplx::ZERO; 12];
        fft(&mut v, false);
    }

    #[test]
    fn hann_endpoints_zero() {
        let w = hann(64);
        assert!(w[0].abs() < 1e-12 && w[63].abs() < 1e-12);
        assert!((w[32] - 1.0).abs() < 1e-2);
    }
}
