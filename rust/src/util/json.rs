//! Minimal JSON parser / emitter (serde is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are parsed as `f64` (adequate
//! for our interchange: model weights, datasets, metric reports). Object
//! key order is preserved, which keeps emitted artifacts diff-friendly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {}", other.kind())),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }
    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {}", other.kind())),
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {}", other.kind())),
        }
    }
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            other => Err(anyhow!("expected array, got {}", other.kind())),
        }
    }
    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            other => Err(anyhow!("expected object, got {}", other.kind())),
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(o) => o
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            other => Err(anyhow!("expected object for key {key:?}, got {}", other.kind())),
        }
    }
    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Array of numbers → `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
    /// Array of numbers → `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }
    /// Array of integers → `Vec<i32>`.
    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect()
    }
    /// 2-D array of numbers → row-major `Vec<Vec<f64>>`.
    pub fn as_f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|row| row.as_f64_vec()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        emit(self, &mut s, None, 0);
        s
    }
    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        emit(self, &mut s, Some(2), 0);
        s.push('\n');
        s
    }
}

/// Convenience builders.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}
pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}
pub fn arr_i32(xs: &[i32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}
pub fn mat_f64(rows: &[Vec<f64>]) -> Value {
    Value::Arr(rows.iter().map(|r| arr_f64(r)).collect())
}
pub fn num(x: f64) -> Value {
    Value::Num(x)
}
pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}

fn emit(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => emit_num(*n, out),
        Value::Str(s) => emit_str(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                emit(item, out, indent, depth + 1);
            }
            newline(out, indent, depth);
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                emit_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                emit(item, out, indent, depth + 1);
            }
            newline(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn emit_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encode as null (parse side tolerates).
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Shortest round-trippable representation rust provides.
        let _ = write!(out, "{n}");
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

/// Read and parse a JSON file.
pub fn read_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Serialize (pretty) and write a JSON file, creating parent directories.
pub fn write_file(path: &std::path::Path, v: &Value) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, v.to_pretty())
        .with_context(|| format!("writing {}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(c) if c == b => Ok(()),
            Some(c) => bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, c as char),
            None => bail!("expected {:?}, got end of input", b as char),
        }
    }
    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(b'N') => self.lit("NaN", Value::Num(f64::NAN)), // tolerated extension
            Some(b'I') => self.lit("Infinity", Value::Num(f64::INFINITY)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected {:?} at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => bail!("expected ',' or '}}' at byte {}", self.pos - 1),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => bail!("expected ',' or ']' at byte {}", self.pos - 1),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                bail!("lone high surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?);
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                    }
                    _ => bail!("invalid escape at byte {}", self.pos - 1),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow!("invalid UTF-8 at byte {start}"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| anyhow!("truncated \\u escape"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("invalid hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            // Tolerated extension (emitted by some tools):
            if self.peek() == Some(b'I') {
                self.lit("Infinity", Value::Null)?;
                return Ok(Value::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number {text:?} at byte {start}"))?;
        Ok(Value::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Map from string keys — convenience for config-style objects.
pub fn to_map(v: &Value) -> Result<BTreeMap<String, Value>> {
    Ok(v.as_obj()?.iter().cloned().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = parse(text).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = obj(vec![
            ("name", s("water")),
            ("arch", arr_i32(&[3, 3, 3, 2])),
            ("w", mat_f64(&[vec![1.0, -0.5], vec![0.25, 2.0]])),
            ("ok", Value::Bool(true)),
            ("none", Value::Null),
        ]);
        let text = v.to_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "water");
        assert_eq!(back.get("arch").unwrap().as_i32_vec().unwrap(), vec![3, 3, 3, 2]);
        assert_eq!(back.get("w").unwrap().as_f64_mat().unwrap()[1][1], 2.0);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
        // surrogate pair (😀)
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "[1 2]", "1.2.3", ""] {
            assert!(parse(text).is_err(), "{text:?} should fail");
        }
        assert!(parse("[1,2] junk").is_err());
    }

    #[test]
    fn numbers_precise() {
        let xs = [0.1, -2.5e-7, 1234567.875, f64::MIN_POSITIVE, 1e300];
        let text = arr_f64(&xs).to_string();
        let back = parse(&text).unwrap().as_f64_vec().unwrap();
        assert_eq!(back, xs.to_vec());
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(num(f64::NAN).to_string(), "null");
        assert_eq!(num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("{\"å\": \"分子動力学\"}").unwrap();
        assert_eq!(v.get("å").unwrap().as_str().unwrap(), "分子動力学");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn accessors_report_errors() {
        let v = parse(r#"{"a": [1, "x"]}"#).unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_f64_vec().is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }
}
