//! SQNN — the paper's multiplication-less quantized network: weights as
//! sums of ≤K powers of two, evaluated by a shift–accumulate datapath in
//! Q(1,2,10). This is the bit-accurate software model of the ASIC MLP
//! chip (Fig. 7); `asic::MlpChip` wraps it with the cycle/energy model.
//!
//! ## Shift programs (pack-time compilation)
//!
//! The chip's weight memory is distributed and static: every weight is a
//! sign plus ≤K shift exponents, wired into its shift unit once at
//! programming time. The software model mirrors that at pack time by
//! **compiling each layer into a shift program** — a linear instruction
//! stream of [`ShiftOp`]s (`{src, sh, neg}`: read input row `src`, shift
//! by `sh`, add or subtract into the accumulator), with per-neuron
//! extents (`op_ends`). The compilation folds away all the per-weight
//! indirection the hot loop used to pay:
//!
//! * zero weights (`sign == 0`) emit **no** instruction at all;
//! * a single-term weight — the dominant case in trained Q13 models —
//!   is exactly **one** fused instruction (no term-count load, no
//!   exponent-slice bounds check);
//! * multi-term weights unroll into consecutive instructions sharing
//!   `src`/`neg`, so the kernel never walks a nested `exps` slice.
//!
//! ## The SWAR batch kernel
//!
//! [`Sqnn::forward_q13_batch_with`] executes the shift program over the
//! SoA lane planes in fixed-width tiles of [`SWAR_LANES`] lanes: a
//! `[i64; SWAR_LANES]` register-resident accumulator tile per output
//! neuron, one instruction applied to the whole tile before the next is
//! decoded. The tile loops are plain indexed loops over fixed-size
//! arrays so LLVM autovectorizes them (no `std::simd`, no intrinsics —
//! the kernel compiles unchanged in the `no_std` core profile); the
//! ragged tail (`batch % SWAR_LANES`) runs the same code monomorphized
//! at tile width 1. The tile is the software picture of the ASIC's
//! replicated parallel shift–add array (§VI A₂): lanes advance in
//! lock-step through one statically-programmed instruction stream.
//!
//! **Bit-identity contract:** the lane accumulators are exact `i64` and
//! nothing saturates mid-sum, so neither the tiling nor the per-term
//! (instead of per-weight) accumulation order can change a single output
//! bit. The pre-program kernel is kept as
//! [`Sqnn::forward_q13_batch_reference`] and the property tests +
//! `tests/core_golden.rs` (both build profiles) pin the equivalence.
//!
//! Core/host seam: [`Sqnn`] itself is core — pure integer storage
//! (quantized weights, raw Q13 biases) plus the scalar and
//! weight-stationary batch kernels, constructible on-device from
//! pre-quantized layers via [`Sqnn::from_layers`]. The float glue lives
//! host-side: [`Sqnn::from_mlp`] (quantizing a trained float model),
//! [`Sqnn::dequantized_mlp`], and [`ConditionedSqnn`] — the
//! feature-conditioning wrapper that models the FPGA stage in float.

use alloc::string::String;
use alloc::vec;
use alloc::vec::Vec;

use crate::error::CoreError;
use crate::fixedpoint::{q13, Q13};
use crate::nn::activation::{phi_q13, tanh_q13};
use crate::quant::ShiftWeight;
use super::Activation;
#[cfg(feature = "std")]
use super::Mlp;
#[cfg(feature = "std")]
use crate::quant::quantize_matrix;

/// One SQNN layer: quantized weights (row-major out×in) and Q13 biases.
#[derive(Debug, Clone)]
pub struct SqnnLayer {
    pub out_dim: usize,
    pub in_dim: usize,
    pub w: Vec<ShiftWeight>,
    pub b: Vec<Q13>,
}

/// One compiled shift-program instruction: apply `±(x[src] << sh)` (or
/// an arithmetic right shift for negative `sh`) to the accumulator tile.
/// A single-term weight is exactly one of these; a K-term weight is K
/// consecutive ones sharing `src`/`neg`; a zero weight is none.
#[derive(Debug, Clone, Copy)]
struct ShiftOp {
    /// Source input row of the SoA plane.
    src: u32,
    /// Shift exponent: ≥ 0 left shift, < 0 truncating arithmetic right
    /// shift (the RTL's `P(x, n)`, Eq. 11).
    sh: i8,
    /// Subtract instead of add (the weight's sign selector).
    neg: bool,
}

/// Hot-path layer layout: the per-layer **shift program** (see the
/// module doc) plus the legacy dense shift-parameter arrays that the
/// reference batch datapath still walks. §Perf: the original packing
/// took the water-MLP forward from ~156 ns to well under 100 ns; the
/// shift program removes the remaining per-weight decode entirely.
#[derive(Debug, Clone)]
struct PackedLayer {
    out_dim: usize,
    in_dim: usize,
    /// Compiled shift program, all neurons concatenated.
    ops: Vec<ShiftOp>,
    /// Per output neuron: exclusive end index into `ops` (neuron `j`
    /// runs `ops[op_ends[j-1]..op_ends[j]]`, starting at 0).
    op_ends: Vec<u32>,
    /// Reference datapath only — per weight (row-major out×in): −1/0/+1.
    sign: Vec<i8>,
    /// Reference datapath only — per weight: number of active terms.
    n_terms: Vec<u8>,
    /// Reference datapath only — active exponents, in weight order.
    exps: Vec<i8>,
    /// Q13 bias raws.
    bias: Vec<i32>,
    activation: bool,
}

/// Maximum layer width of the packed fast path (stack scratch size).
pub const MAX_WIDTH: usize = 128;

/// SWAR tile width of the batch kernel: lanes are processed in chunks of
/// this many `i64` accumulators (two AVX2 / one AVX-512 register's
/// worth), the ragged tail at tile width 1.
pub const SWAR_LANES: usize = 8;

/// Aggregate shape of a network's compiled shift programs — exposed so
/// the golden-vector suite can pin the compiler itself, not just the
/// kernel outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShiftProgramStats {
    /// Total weights across all layers (incl. zero weights).
    pub weights: usize,
    /// Weights with `sign == 0` — compiled to nothing.
    pub zero_weights: usize,
    /// Nonzero single-term weights — the fused one-instruction case.
    pub single_term_weights: usize,
    /// Total instructions (= active shift terms).
    pub ops: usize,
}

/// Reusable scratch of the batch kernels: the two ping-pong activation
/// planes, plus the lane-accumulator vector only the reference kernel
/// still uses (the SWAR kernel's accumulator tiles live in registers).
/// Own one per serving shard/chip and pass it to
/// [`Sqnn::forward_q13_batch_with`] so steady-state batched inference
/// allocates nothing (buffers grow to the high-water
/// `max_layer_width × batch` and are reused).
#[derive(Debug, Clone, Default)]
pub struct BatchScratch {
    plane_a: Vec<i32>,
    plane_b: Vec<i32>,
    acc: Vec<i64>,
}

/// The shift-based quantized MLP.
#[derive(Debug, Clone)]
pub struct Sqnn {
    pub name: String,
    pub layers: Vec<SqnnLayer>,
    pub activation: Activation,
    pub output_activation: bool,
    /// K used for quantization.
    pub k: usize,
    /// Flattened hot-path layout (kept in sync with `layers`).
    packed: Vec<PackedLayer>,
}

impl Sqnn {
    /// Core constructor: assemble a network from pre-quantized layers
    /// (what an embedded target would be programmed with — the shift
    /// parameters arrive from the host, never computed on-device).
    /// Validates the layer chain and the packed-fast-path width bound
    /// with typed errors.
    pub fn from_layers(
        name: &str,
        layers: Vec<SqnnLayer>,
        activation: Activation,
        output_activation: bool,
        k: usize,
    ) -> Result<Self, CoreError> {
        if layers.is_empty() {
            return Err(CoreError::EmptyNetwork);
        }
        for (li, l) in layers.iter().enumerate() {
            if l.w.len() != l.out_dim * l.in_dim || l.b.len() != l.out_dim {
                return Err(CoreError::LayerShapeMismatch { layer: li });
            }
            if li + 1 < layers.len() && l.out_dim != layers[li + 1].in_dim {
                return Err(CoreError::LayerShapeMismatch { layer: li + 1 });
            }
            let width = l.in_dim.max(l.out_dim);
            if width > MAX_WIDTH {
                return Err(CoreError::LayerTooWide { width, max: MAX_WIDTH });
            }
        }
        let mut s = Sqnn {
            name: String::from(name),
            layers,
            activation,
            output_activation,
            k,
            packed: Vec::new(),
        };
        s.pack();
        Ok(s)
    }

    /// Quantize a trained float model with K shift terms per weight —
    /// the host initialization path. (When the float model came from QAT
    /// its weights are already exact sums of ≤K powers of two and this is
    /// lossless.) Feature conditioning is NOT carried here — wrap the
    /// result in a [`ConditionedSqnn`] for the float serving convenience.
    #[cfg(feature = "std")]
    pub fn from_mlp(m: &Mlp, k: usize) -> Self {
        let layers: Vec<SqnnLayer> = m
            .layers
            .iter()
            .map(|l| SqnnLayer {
                out_dim: l.out_dim,
                in_dim: l.in_dim,
                w: quantize_matrix(&l.w, k),
                b: l.b.iter().map(|&x| Q13::from_f64(x)).collect(),
            })
            .collect();
        Sqnn::from_layers(&m.name, layers, m.activation, m.output_activation, k)
            .expect("float model shape already validated by Mlp")
    }

    /// Build the flattened hot-path layout from `layers` (widths already
    /// validated by the constructors): compile each layer's shift
    /// program and keep the dense shift-parameter arrays for the
    /// reference datapath.
    fn pack(&mut self) {
        let n_layers = self.layers.len();
        let output_activation = self.output_activation;
        self.packed = self
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                let mut sign = Vec::with_capacity(l.w.len());
                let mut n_terms = Vec::with_capacity(l.w.len());
                let mut exps = Vec::new();
                let mut ops = Vec::new();
                let mut op_ends = Vec::with_capacity(l.out_dim);
                for j in 0..l.out_dim {
                    for i in 0..l.in_dim {
                        let w = &l.w[j * l.in_dim + i];
                        sign.push(w.sign);
                        n_terms.push(w.terms() as u8);
                        exps.extend(w.exps.iter().map(|&e| e as i8));
                        if w.sign == 0 {
                            continue;
                        }
                        let neg = w.sign < 0;
                        ops.extend(w.exps.iter().map(|&e| ShiftOp {
                            src: i as u32,
                            sh: e as i8,
                            neg,
                        }));
                    }
                    op_ends.push(ops.len() as u32);
                }
                PackedLayer {
                    out_dim: l.out_dim,
                    in_dim: l.in_dim,
                    ops,
                    op_ends,
                    sign,
                    n_terms,
                    exps,
                    bias: l.b.iter().map(|b| b.0).collect(),
                    activation: li + 1 < n_layers || output_activation,
                }
            })
            .collect();
    }

    /// Shape of the compiled shift programs, aggregated over all layers.
    pub fn shift_program_stats(&self) -> ShiftProgramStats {
        let mut s = ShiftProgramStats {
            weights: 0,
            zero_weights: 0,
            single_term_weights: 0,
            ops: 0,
        };
        for l in &self.layers {
            for w in &l.w {
                s.weights += 1;
                if w.sign == 0 {
                    s.zero_weights += 1;
                } else {
                    if w.terms() == 1 {
                        s.single_term_weights += 1;
                    }
                    s.ops += w.terms();
                }
            }
        }
        debug_assert_eq!(s.ops, self.packed.iter().map(|l| l.ops.len()).sum::<usize>());
        s
    }

    pub fn arch(&self) -> Vec<usize> {
        let mut a = vec![self.layers[0].in_dim];
        a.extend(self.layers.iter().map(|l| l.out_dim));
        a
    }

    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim
    }
    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// The AU datapath: φ for the taped-out chip, the baked Q13 tanh
    /// table for the software-ablation tanh SQNN. Both are exact integer
    /// paths — no float anywhere in the kernel (the tanh arm used to
    /// round-trip through `f64::tanh`; see `nn::tanh_table`).
    #[inline(always)]
    fn activate(&self, v: Q13) -> Q13 {
        match self.activation {
            Activation::Phi => phi_q13(v),
            Activation::Tanh => tanh_q13(v),
        }
    }

    /// Bit-accurate forward pass on Q13 inputs.
    ///
    /// Per output neuron: shift–accumulate all inputs in a wide
    /// accumulator (the MU adder tree keeps full width), add bias,
    /// truncate+saturate to Q13, then the AU (φ) — except a linear output
    /// layer unless `output_activation`. Runs on the packed flat layout
    /// with stack scratch (no allocation on the hot path).
    pub fn forward_q13(&self, x: &[Q13]) -> Vec<Q13> {
        let mut out = vec![Q13::ZERO; self.out_dim()];
        self.forward_q13_into(x, &mut out);
        out
    }

    /// Allocation-free forward: writes the outputs into `out` (must be
    /// exactly `out_dim()` long). Same bit-exact datapath as
    /// [`Self::forward_q13`] — runs the compiled shift program at tile
    /// width 1 over stack scratch.
    pub fn forward_q13_into(&self, x: &[Q13], out: &mut [Q13]) {
        let mut buf_a = [0i32; MAX_WIDTH];
        let mut buf_b = [0i32; MAX_WIDTH];
        debug_assert_eq!(x.len(), self.in_dim());
        debug_assert_eq!(out.len(), self.out_dim());
        for (slot, v) in buf_a.iter_mut().zip(x) {
            *slot = v.0;
        }
        let mut cur_is_a = true;
        let mut out_dim = x.len();
        for layer in &self.packed {
            let (cur, next) = if cur_is_a {
                (&buf_a[..], &mut buf_b[..])
            } else {
                (&buf_b[..], &mut buf_a[..])
            };
            self.run_program_tile::<1>(layer, cur, next, 1, 0);
            out_dim = layer.out_dim;
            cur_is_a = !cur_is_a;
        }
        let res = if cur_is_a { &buf_a[..out_dim] } else { &buf_b[..out_dim] };
        for (slot, &r) in out.iter_mut().zip(res) {
            *slot = Q13(r);
        }
    }

    /// Execute one layer's shift program on a tile of `L` consecutive
    /// lanes starting at lane `base` of an SoA plane of width `batch`.
    ///
    /// The accumulator tile is a fixed-size `[i64; L]` so it stays in
    /// registers across the whole instruction stream of a neuron, and
    /// every per-instruction loop runs over fixed-size array views —
    /// the shape LLVM autovectorizes without `std::simd` or intrinsics
    /// (shift amount and direction are loop-invariant per instruction,
    /// so each compiles to a splat shift + add/sub over the tile).
    #[inline]
    fn run_program_tile<const L: usize>(
        &self,
        layer: &PackedLayer,
        cur: &[i32],
        next: &mut [i32],
        batch: usize,
        base: usize,
    ) {
        let mut start = 0usize;
        for (j, &end) in layer.op_ends.iter().enumerate() {
            let end = end as usize;
            let mut acc = [layer.bias[j] as i64; L];
            for op in &layer.ops[start..end] {
                let at = op.src as usize * batch + base;
                let row: &[i32; L] = cur[at..at + L].try_into().unwrap();
                if op.sh >= 0 {
                    let s = op.sh as u32;
                    if op.neg {
                        for l in 0..L {
                            acc[l] -= (row[l] as i64) << s;
                        }
                    } else {
                        for l in 0..L {
                            acc[l] += (row[l] as i64) << s;
                        }
                    }
                } else {
                    let s = (-(op.sh as i32)) as u32;
                    if op.neg {
                        for l in 0..L {
                            acc[l] -= (row[l] as i64) >> s;
                        }
                    } else {
                        for l in 0..L {
                            acc[l] += (row[l] as i64) >> s;
                        }
                    }
                }
            }
            start = end;
            let at = j * batch + base;
            let dst: &mut [i32; L] = (&mut next[at..at + L]).try_into().unwrap();
            for l in 0..L {
                let mut v = Q13(acc[l].clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32);
                if layer.activation {
                    v = self.activate(v);
                }
                dst[l] = v.0;
            }
        }
    }

    /// Weight-stationary batched forward on an SoA batch (the
    /// molecule-farm serving kernel).
    ///
    /// Layout: feature `i` of lane `b` lives at `xs[i * batch + b]`, and
    /// output `o` of lane `b` at `out[o * batch + b]`. The layer's
    /// precompiled shift program is streamed **once** per
    /// [`SWAR_LANES`]-wide lane tile, each instruction applied to the
    /// whole register-resident accumulator tile before the next is
    /// decoded (§Perf: the A₂ intra-ASIC-parallelism story needs many
    /// inferences per cycle to be cheap on the simulator too).
    ///
    /// Bit-identical per lane to [`Self::forward_q13_reference`] and to
    /// [`Self::forward_q13_batch_reference`]: the lane accumulators are
    /// exact i64 (no mid-sum saturation), so neither the tiling nor the
    /// reassociated accumulation order can change any bit.
    ///
    /// This convenience form allocates a fresh [`BatchScratch`] per
    /// call; the serving hot path (`asic::MlpChip`, and through it the
    /// molecule farm) holds its own scratch and calls
    /// [`Self::forward_q13_batch_with`] so a steady-state tick allocates
    /// nothing.
    pub fn forward_q13_batch_into(&self, xs: &[Q13], batch: usize, out: &mut [Q13]) {
        let mut scratch = BatchScratch::default();
        self.forward_q13_batch_with(xs, batch, out, &mut scratch);
    }

    /// The batch kernel proper: same datapath as
    /// [`Self::forward_q13_batch_into`], with caller-owned scratch.
    ///
    /// This is the SWAR shift-program kernel (see the module doc): the
    /// batch is walked in [`SWAR_LANES`]-wide tiles whose `[i64; 8]`
    /// accumulators stay in registers while the layer's compiled
    /// instruction stream runs; the ragged tail (`batch % SWAR_LANES`)
    /// runs the same code at tile width 1. Bit-identical per lane to
    /// [`Self::forward_q13_batch_reference`] and to the scalar
    /// [`Self::forward_q13_reference`].
    pub fn forward_q13_batch_with(
        &self,
        xs: &[Q13],
        batch: usize,
        out: &mut [Q13],
        scratch: &mut BatchScratch,
    ) {
        assert_eq!(xs.len(), self.in_dim() * batch, "SoA input length");
        assert_eq!(out.len(), self.out_dim() * batch, "SoA output length");
        if batch == 0 {
            return;
        }
        let maxw = self
            .packed
            .iter()
            .map(|l| l.out_dim.max(l.in_dim))
            .max()
            .unwrap_or(0);
        let BatchScratch { plane_a, plane_b, .. } = scratch;
        plane_a.resize(maxw * batch, 0);
        plane_b.resize(maxw * batch, 0);
        for (slot, v) in plane_a.iter_mut().zip(xs) {
            *slot = v.0;
        }
        let mut cur_is_a = true;
        let mut width = self.in_dim();
        for layer in &self.packed {
            let (cur, next) = if cur_is_a {
                (&plane_a[..], &mut plane_b[..])
            } else {
                (&plane_b[..], &mut plane_a[..])
            };
            let mut base = 0usize;
            while base + SWAR_LANES <= batch {
                self.run_program_tile::<SWAR_LANES>(layer, cur, next, batch, base);
                base += SWAR_LANES;
            }
            while base < batch {
                self.run_program_tile::<1>(layer, cur, next, batch, base);
                base += 1;
            }
            width = layer.out_dim;
            cur_is_a = !cur_is_a;
        }
        let res = if cur_is_a { &plane_a[..] } else { &plane_b[..] };
        for (slot, &r) in out.iter_mut().zip(&res[..width * batch]) {
            *slot = Q13(r);
        }
    }

    /// The pre-shift-program batch kernel, kept verbatim as the
    /// **reference datapath** for the SWAR kernel's bit-identity
    /// property tests: it re-decodes every packed weight
    /// (sign / n_terms / exps slice) per output neuron and accumulates
    /// each weight's shift-sum before applying the sign — an
    /// independently-structured evaluation of the same exact integer
    /// math. Not on any serving path.
    pub fn forward_q13_batch_reference(
        &self,
        xs: &[Q13],
        batch: usize,
        out: &mut [Q13],
        scratch: &mut BatchScratch,
    ) {
        assert_eq!(xs.len(), self.in_dim() * batch, "SoA input length");
        assert_eq!(out.len(), self.out_dim() * batch, "SoA output length");
        if batch == 0 {
            return;
        }
        let maxw = self
            .packed
            .iter()
            .map(|l| l.out_dim.max(l.in_dim))
            .max()
            .unwrap_or(0);
        let BatchScratch { plane_a, plane_b, acc } = scratch;
        plane_a.resize(maxw * batch, 0);
        plane_b.resize(maxw * batch, 0);
        acc.resize(batch, 0);
        let (buf_a, buf_b) = (plane_a, plane_b);
        for (slot, v) in buf_a.iter_mut().zip(xs) {
            *slot = v.0;
        }
        let mut cur_is_a = true;
        let mut width = self.in_dim();
        for layer in &self.packed {
            let (cur, next) = if cur_is_a {
                (&buf_a[..], &mut buf_b[..])
            } else {
                (&buf_b[..], &mut buf_a[..])
            };
            let mut term_idx = 0usize;
            let mut w_idx = 0usize;
            for j in 0..layer.out_dim {
                let bias = layer.bias[j] as i64;
                for a in acc.iter_mut() {
                    *a = bias;
                }
                for i in 0..layer.in_dim {
                    let sign = layer.sign[w_idx];
                    let nt = layer.n_terms[w_idx] as usize;
                    w_idx += 1;
                    if sign == 0 {
                        debug_assert_eq!(nt, 0);
                        continue;
                    }
                    let exps = &layer.exps[term_idx..term_idx + nt];
                    term_idx += nt;
                    let row = &cur[i * batch..(i + 1) * batch];
                    if sign < 0 {
                        for (a, &xr) in acc.iter_mut().zip(row) {
                            let xv = xr as i64;
                            let mut wsum: i64 = 0;
                            for &e in exps {
                                wsum += if e >= 0 { xv << e } else { xv >> (-e) };
                            }
                            *a -= wsum;
                        }
                    } else {
                        for (a, &xr) in acc.iter_mut().zip(row) {
                            let xv = xr as i64;
                            let mut wsum: i64 = 0;
                            for &e in exps {
                                wsum += if e >= 0 { xv << e } else { xv >> (-e) };
                            }
                            *a += wsum;
                        }
                    }
                }
                let dst = &mut next[j * batch..(j + 1) * batch];
                for (slot, &a) in dst.iter_mut().zip(acc.iter()) {
                    let mut v = Q13(a.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32);
                    if layer.activation {
                        v = self.activate(v);
                    }
                    *slot = v.0;
                }
            }
            width = layer.out_dim;
            cur_is_a = !cur_is_a;
        }
        let res = if cur_is_a { &buf_a[..] } else { &buf_b[..] };
        for (slot, &r) in out.iter_mut().zip(&res[..width * batch]) {
            *slot = Q13(r);
        }
    }

    /// Allocating convenience wrapper around
    /// [`Self::forward_q13_batch_into`] (same SoA layout).
    pub fn forward_q13_batch(&self, xs: &[Q13], batch: usize) -> Vec<Q13> {
        let mut out = vec![Q13::ZERO; self.out_dim() * batch];
        self.forward_q13_batch_into(xs, batch, &mut out);
        out
    }

    /// Reference (unpacked) forward — used by tests to pin the packed
    /// fast path to the straightforward datapath semantics.
    pub fn forward_q13_reference(&self, x: &[Q13]) -> Vec<Q13> {
        let mut cur: Vec<Q13> = x.to_vec();
        let last = self.layers.len() - 1;
        for (li, layer) in self.layers.iter().enumerate() {
            debug_assert_eq!(cur.len(), layer.in_dim);
            let mut next = Vec::with_capacity(layer.out_dim);
            for j in 0..layer.out_dim {
                let row = &layer.w[j * layer.in_dim..(j + 1) * layer.in_dim];
                let mut acc: i64 = 0;
                for (wq, xv) in row.iter().zip(&cur) {
                    acc += wq.apply_raw(xv.0 as i64);
                }
                acc += layer.b[j].0 as i64;
                let mut v = Q13(acc.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32);
                if li < last || self.output_activation {
                    v = self.activate(v);
                }
                next.push(v);
            }
            cur = next;
        }
        cur
    }

    /// Total number of active shift terms (hardware SUs actually used).
    pub fn total_shift_terms(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.iter().map(|w| w.terms()).sum::<usize>())
            .sum()
    }

    /// The dequantized float weights (what the L2 JAX kernel multiplies
    /// by) — used to cross-check the Python/Rust pipelines. Conditioning
    /// constants are not part of the core network; use
    /// [`ConditionedSqnn::dequantized_mlp`] to carry them over.
    #[cfg(feature = "std")]
    pub fn dequantized_mlp(&self) -> crate::Result<Mlp> {
        let layers = self
            .layers
            .iter()
            .map(|l| crate::nn::mlp::Dense {
                out_dim: l.out_dim,
                in_dim: l.in_dim,
                w: l.w.iter().map(|w| w.value()).collect(),
                b: l.b.iter().map(|b| b.to_f64()).collect(),
            })
            .collect();
        Mlp::from_layers(&self.name, layers, self.activation, self.output_activation)
    }
}

/// Host-side serving wrapper: a core [`Sqnn`] plus the float feature
/// conditioning of the FPGA stage (center/scale as trained/exported by
/// the model). This is the float glue that used to live on `Sqnn`
/// itself — moved across the seam so the core network stays float-free.
#[cfg(feature = "std")]
#[derive(Debug, Clone)]
pub struct ConditionedSqnn {
    pub net: Sqnn,
    /// Feature conditioning constants (the FPGA stage; see `nn::Mlp`).
    pub feature_center: Vec<f64>,
    pub feature_scale: Vec<f64>,
}

#[cfg(feature = "std")]
impl ConditionedSqnn {
    /// Quantize a trained float model and carry its conditioning
    /// constants (the old `Sqnn::from_mlp` semantics).
    pub fn from_mlp(m: &Mlp, k: usize) -> Self {
        ConditionedSqnn {
            net: Sqnn::from_mlp(m, k),
            feature_center: m.feature_center.clone(),
            feature_scale: m.feature_scale.clone(),
        }
    }

    /// Float-in/float-out convenience wrapper on *raw* features: applies
    /// the feature conditioning (modelling the FPGA stage in float, its
    /// own fixed-point error being negligible post-gain), then quantizes
    /// to Q13 for the chip datapath.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let gain = |i: usize| -> f64 {
            match self.feature_scale.len() {
                0 => 1.0,
                1 => self.feature_scale[0],
                _ => self.feature_scale[i],
            }
        };
        let cond: Vec<f64> = if self.feature_center.is_empty() {
            x.to_vec()
        } else {
            x.iter()
                .zip(&self.feature_center)
                .enumerate()
                .map(|(i, (v, c))| (v - c) * gain(i))
                .collect()
        };
        let q: Vec<Q13> = cond.iter().map(|&v| Q13::from_f64(v)).collect();
        self.net.forward_q13(&q).into_iter().map(|v| v.to_f64()).collect()
    }

    /// Dequantized float view including the conditioning constants.
    pub fn dequantized_mlp(&self) -> crate::Result<Mlp> {
        let mut m = self.net.dequantized_mlp()?;
        m.feature_center = self.feature_center.clone();
        m.feature_scale = self.feature_scale.clone();
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn trained_like_model() -> Mlp {
        let mut rng = Pcg::new(9);
        let mut m = Mlp::init_random("sq", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.8;
            }
        }
        m
    }

    #[test]
    fn matches_dequantized_float_within_datapath_error() {
        let m = trained_like_model();
        let s = ConditionedSqnn::from_mlp(&m, 3);
        let deq = s.dequantized_mlp().unwrap();
        let mut rng = Pcg::new(4);
        for _ in 0..2_000 {
            let x: Vec<f64> = (0..3).map(|_| rng.range(-1.0, 1.0)).collect();
            let qs = s.forward(&x);
            // the float reference must itself see the quantized input
            let xq: Vec<f64> = x.iter().map(|&v| Q13::from_f64(v).to_f64()).collect();
            let fd = deq.forward(&xq);
            for (a, b) in qs.iter().zip(&fd) {
                // datapath truncation: a few LSB through 3 layers
                assert!((a - b).abs() < 8.0 * q13::LSB, "x={x:?} q={a} f={b}");
            }
        }
    }

    #[test]
    fn higher_k_shrinks_weight_error_monotonically() {
        // The guaranteed Fig.-4 ingredient is in *weight space*: each
        // extra shift term can only reduce |w − w_q| (Eq. 7 is a greedy
        // residual expansion). Output-space convergence additionally needs
        // the paper's post-quantization retraining, which is exercised by
        // the E4 pipeline (python QAT + fig4 bench), not here.
        let m = trained_like_model();
        let mut prev = f64::INFINITY;
        for k in 1..=5 {
            let s = Sqnn::from_mlp(&m, k);
            let deq = s.dequantized_mlp().unwrap();
            let mut err = 0.0;
            for (l0, l1) in m.layers.iter().zip(&deq.layers) {
                for (a, b) in l0.w.iter().zip(&l1.w) {
                    err += (a - b).abs();
                }
            }
            assert!(err <= prev + 1e-12, "k={k}: weight error grew ({err} > {prev})");
            assert!(err.is_finite());
            prev = err;
        }
        // And K=3 is substantially better than K=1 on aggregate.
        let e1 = {
            let deq = Sqnn::from_mlp(&m, 1).dequantized_mlp().unwrap();
            m.layers
                .iter()
                .zip(&deq.layers)
                .flat_map(|(a, b)| a.w.iter().zip(&b.w))
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
        };
        assert!(e1 > 1.3 * prev, "K=1 err {e1} vs K=5 err {prev}");
    }

    #[test]
    fn shift_terms_bounded_by_k_times_weights() {
        let m = trained_like_model();
        for k in 1..=5 {
            let s = Sqnn::from_mlp(&m, k);
            let nweights: usize = m.layers.iter().map(|l| l.w.len()).sum();
            assert!(s.total_shift_terms() <= k * nweights);
            assert!(s.total_shift_terms() > 0);
        }
    }

    #[test]
    fn core_constructor_validates_with_typed_errors() {
        use crate::error::CoreError;
        let layer = |out_dim: usize, in_dim: usize| SqnnLayer {
            out_dim,
            in_dim,
            w: vec![ShiftWeight::zero(); out_dim * in_dim],
            b: vec![Q13::ZERO; out_dim],
        };
        assert_eq!(
            Sqnn::from_layers("e", vec![], Activation::Phi, false, 3).unwrap_err(),
            CoreError::EmptyNetwork
        );
        // chain mismatch: 3→2 then 3→1
        assert_eq!(
            Sqnn::from_layers(
                "c",
                vec![layer(2, 3), layer(1, 3)],
                Activation::Phi,
                false,
                3
            )
            .unwrap_err(),
            CoreError::LayerShapeMismatch { layer: 1 }
        );
        // over-wide layer
        assert_eq!(
            Sqnn::from_layers("w", vec![layer(MAX_WIDTH + 1, 3)], Activation::Phi, false, 3)
                .unwrap_err(),
            CoreError::LayerTooWide { width: MAX_WIDTH + 1, max: MAX_WIDTH }
        );
        // malformed weight vector
        let mut bad = layer(2, 3);
        bad.w.pop();
        assert_eq!(
            Sqnn::from_layers("s", vec![bad], Activation::Phi, false, 3).unwrap_err(),
            CoreError::LayerShapeMismatch { layer: 0 }
        );
        // and a good one round-trips through the same path as from_mlp
        let ok = Sqnn::from_layers("ok", vec![layer(2, 3)], Activation::Phi, false, 3).unwrap();
        assert_eq!(ok.arch(), vec![3, 2]);
        assert_eq!(ok.name, "ok");
    }

    #[test]
    fn tanh_network_runs_the_integer_table_path() {
        // A tanh SQNN (software ablation) must produce the same bits as
        // the float-tanh round-trip it replaced, on scalar, packed, and
        // batch kernels alike.
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("t", &[3, 4, 2], Activation::Tanh, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.7;
            }
        }
        let s = Sqnn::from_mlp(&m, 3);
        for _ in 0..500 {
            let x: Vec<Q13> = (0..3).map(|_| Q13::from_f64(rng.range(-4.0, 4.0))).collect();
            let got = s.forward_q13(&x);
            let want = s.forward_q13_reference(&x);
            assert_eq!(got, want);
            // float-tanh round-trip reference for the first layer's AU
            for v in &got {
                assert!(v.0.abs() <= 1023, "tanh output must stay in (−1, 1)");
            }
        }
    }

    #[test]
    fn packed_fast_path_is_bit_identical_to_reference() {
        // §Perf invariant: the packed flat layout must reproduce the
        // straightforward datapath bit for bit, including extremes.
        let mut rng = Pcg::new(123);
        for arch in [&[3usize, 3, 3, 2][..], &[8, 16, 16, 3], &[64, 64, 64, 3]] {
            let mut m = Mlp::init_random("p", arch, Activation::Phi, &mut rng);
            for l in &mut m.layers {
                for w in &mut l.w {
                    *w *= 0.6;
                }
            }
            for k in [1usize, 3, 5] {
                let s = Sqnn::from_mlp(&m, k);
                for _ in 0..200 {
                    let x: Vec<Q13> = (0..arch[0])
                        .map(|_| Q13::from_f64(rng.range(-4.0, 4.0)))
                        .collect();
                    assert_eq!(s.forward_q13(&x), s.forward_q13_reference(&x));
                }
            }
        }
    }

    #[test]
    fn batch_kernel_is_bit_identical_to_reference_per_lane() {
        // The farm-serving invariant: the weight-stationary batch kernel
        // must reproduce the reference datapath bit for bit on every
        // lane, across architectures, K, and batch sizes — including
        // saturating inputs (lane 0 of every batch is forced to the Q13
        // rails).
        let mut rng = Pcg::new(2024);
        for arch in [&[3usize, 3, 3, 2][..], &[8, 16, 16, 3], &[64, 64, 64, 3]] {
            let mut m = Mlp::init_random("b", arch, Activation::Phi, &mut rng);
            for l in &mut m.layers {
                for w in &mut l.w {
                    *w *= 0.6;
                }
            }
            for k in [1usize, 3, 5] {
                let s = Sqnn::from_mlp(&m, k);
                for batch in [1usize, 7, 8, 64] {
                    // AoS lanes, then transpose to the kernel's SoA.
                    let lanes: Vec<Vec<Q13>> = (0..batch)
                        .map(|b| {
                            (0..arch[0])
                                .map(|_| {
                                    if b == 0 {
                                        if rng.below(2) == 0 { Q13::MAX } else { Q13::MIN }
                                    } else {
                                        Q13::from_f64(rng.range(-6.0, 6.0))
                                    }
                                })
                                .collect()
                        })
                        .collect();
                    let mut xs = vec![Q13::ZERO; arch[0] * batch];
                    for (b, lane) in lanes.iter().enumerate() {
                        for (i, &v) in lane.iter().enumerate() {
                            xs[i * batch + b] = v;
                        }
                    }
                    let out = s.forward_q13_batch(&xs, batch);
                    for (b, lane) in lanes.iter().enumerate() {
                        let want = s.forward_q13_reference(lane);
                        for (o, &w) in want.iter().enumerate() {
                            assert_eq!(
                                out[o * batch + b], w,
                                "arch={arch:?} k={k} batch={batch} lane={b} out={o}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn batch_kernel_handles_empty_batch() {
        let s = Sqnn::from_mlp(&trained_like_model(), 3);
        let mut out: Vec<Q13> = Vec::new();
        s.forward_q13_batch_into(&[], 0, &mut out);
        assert!(out.is_empty());
        let mut scratch = BatchScratch::default();
        s.forward_q13_batch_reference(&[], 0, &mut out, &mut scratch);
        assert!(out.is_empty());
    }

    /// Run one SoA batch through both batch kernels and the scalar
    /// reference and assert all three agree bit for bit on every lane.
    fn assert_kernels_agree(s: &Sqnn, xs: &[Q13], batch: usize, ctx: &str) {
        let mut swar = vec![Q13::ZERO; s.out_dim() * batch];
        let mut refr = vec![Q13::ZERO; s.out_dim() * batch];
        let mut scratch_a = BatchScratch::default();
        let mut scratch_b = BatchScratch::default();
        s.forward_q13_batch_with(xs, batch, &mut swar, &mut scratch_a);
        s.forward_q13_batch_reference(xs, batch, &mut refr, &mut scratch_b);
        assert_eq!(swar, refr, "{ctx}: SWAR vs reference batch kernel");
        for b in 0..batch {
            let lane: Vec<Q13> = (0..s.in_dim()).map(|i| xs[i * batch + b]).collect();
            let want = s.forward_q13_reference(&lane);
            for (o, &w) in want.iter().enumerate() {
                assert_eq!(swar[o * batch + b], w, "{ctx}: lane {b} out {o} vs scalar");
            }
        }
    }

    #[test]
    fn swar_kernel_bit_identical_across_all_batch_sizes() {
        // The tentpole invariant, fuzzed over every batch size 0..=67:
        // full 8-lane tiles, every ragged tail 1..=7, and the
        // one-past-a-tile sizes (9, 17, 65...) all reproduce both
        // reference datapaths bit for bit. Lane 0 is forced to the Q13
        // rails so saturation is always exercised.
        let mut rng = Pcg::new(31337);
        for arch in [&[3usize, 3, 3, 2][..], &[8, 16, 16, 3]] {
            let mut m = Mlp::init_random("sw", arch, Activation::Phi, &mut rng);
            for l in &mut m.layers {
                for w in &mut l.w {
                    *w *= 0.6;
                }
            }
            let s = Sqnn::from_mlp(&m, 3);
            for batch in 0..=67usize {
                let mut xs = vec![Q13::ZERO; arch[0] * batch];
                for i in 0..arch[0] {
                    for b in 0..batch {
                        xs[i * batch + b] = if b == 0 {
                            if rng.below(2) == 0 { Q13::MAX } else { Q13::MIN }
                        } else {
                            Q13::from_f64(rng.range(-6.0, 6.0))
                        };
                    }
                }
                assert_kernels_agree(&s, &xs, batch, &format!("arch={arch:?} batch={batch}"));
            }
        }
    }

    #[test]
    fn swar_kernel_handles_zero_rows_and_negative_exponent_layers() {
        // Compiler edge cases: an output neuron whose weights are all
        // zero (its shift program is empty — bias only), a weight that
        // is nonzero but term-free, and a layer whose every exponent is
        // negative (pure truncating right shifts). Batches straddle the
        // tile width.
        let w = |sign: i8, exps: &[i32]| ShiftWeight { sign, exps: exps.to_vec() };
        let layers = vec![
            SqnnLayer {
                out_dim: 4,
                in_dim: 3,
                w: vec![
                    w(1, &[0]), w(-1, &[-2, -5]), w(0, &[]),
                    w(0, &[]), w(0, &[]), w(0, &[]), // all-zero row
                    w(1, &[2]), w(1, &[-1]), w(-1, &[0, -3, -7]),
                    w(-1, &[-4]), w(1, &[1, 0]), w(1, &[]), // term-free nonzero
                ],
                b: vec![Q13(33), Q13(700), Q13(-1200), Q13(5)],
            },
            SqnnLayer {
                out_dim: 2,
                in_dim: 4,
                // every exponent negative
                w: vec![
                    w(1, &[-1, -3]), w(-1, &[-2]), w(1, &[-5]), w(-1, &[-1]),
                    w(-1, &[-6]), w(1, &[-1]), w(1, &[-2, -4]), w(1, &[-8]),
                ],
                b: vec![Q13(-77), Q13(256)],
            },
        ];
        let s = Sqnn::from_layers("edge", layers, Activation::Phi, false, 3).unwrap();
        let stats = s.shift_program_stats();
        assert_eq!(stats.weights, 20);
        assert_eq!(stats.zero_weights, 4);
        // nonzero single-exponent weights: 1 in row 0, 2 in row 2,
        // 1 in row 3, 6 in layer 2 (the term-free w(1, []) is not one)
        assert_eq!(stats.single_term_weights, 10);
        assert_eq!(stats.ops, 21);
        let mut rng = Pcg::new(99);
        for batch in [1usize, 5, 7, 8, 9, 13, 16, 63, 64, 67] {
            let mut xs = vec![Q13::ZERO; 3 * batch];
            for slot in xs.iter_mut() {
                *slot = Q13::from_f64(rng.range(-6.0, 6.0));
            }
            assert_kernels_agree(&s, &xs, batch, &format!("edge net batch={batch}"));
        }
        // The all-zero row really is bias-only: observe the first layer
        // alone (activated output) — neuron 1 must be phi(bias)
        // regardless of input.
        let one = Sqnn::from_layers(
            "edge-l1",
            vec![s.layers[0].clone()],
            Activation::Phi,
            true,
            3,
        )
        .unwrap();
        let y = one.forward_q13(&[Q13::MAX, Q13::MIN, Q13::MAX]);
        assert_eq!(y[1], phi_q13(Q13(700)));
    }

    #[test]
    fn saturating_behaviour_on_extreme_inputs() {
        let m = trained_like_model();
        let s = ConditionedSqnn::from_mlp(&m, 3);
        let y = s.forward(&[1000.0, -1000.0, 1000.0]);
        for v in y {
            assert!(v.abs() <= 4.0);
        }
    }

    #[test]
    fn arch_preserved() {
        let m = trained_like_model();
        let s = Sqnn::from_mlp(&m, 3);
        assert_eq!(s.arch(), vec![3, 3, 3, 2]);
        assert_eq!(s.in_dim(), 3);
        assert_eq!(s.out_dim(), 2);
    }
}
