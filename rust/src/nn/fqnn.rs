//! FQNN — the fixed-point *multiplier* baseline of Fig. 5: the CNN with
//! weights, activations, biases and layer inputs quantized to a fixed-
//! point format (16-bit in the paper) and evaluated with a conventional
//! MAC datapath (wide accumulator, truncate, saturate).

use crate::fixedpoint::{Fix, FxFormat};
use super::{Activation, Mlp};
use crate::nn::activation::phi;

/// A fixed-point-quantized view of an [`Mlp`], multiplier datapath.
#[derive(Debug, Clone)]
pub struct Fqnn {
    pub fmt: FxFormat,
    pub activation: Activation,
    pub output_activation: bool,
    /// Per layer: (out_dim, in_dim, w_raw row-major, b_raw).
    layers: Vec<(usize, usize, Vec<i64>, Vec<i64>)>,
}

impl Fqnn {
    /// Quantize a float model into `fmt`.
    pub fn from_mlp(m: &Mlp, fmt: FxFormat) -> Self {
        let layers = m
            .layers
            .iter()
            .map(|l| {
                let w = l.w.iter().map(|&x| fmt.encode(x)).collect();
                let b = l.b.iter().map(|&x| fmt.encode(x)).collect();
                (l.out_dim, l.in_dim, w, b)
            })
            .collect();
        Fqnn {
            fmt,
            activation: m.activation,
            output_activation: m.output_activation,
            layers,
        }
    }

    /// Forward pass: inputs are quantized on entry; each dot product uses
    /// a wide accumulator then one truncate+saturate; activations are
    /// computed in the datapath format.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let fmt = self.fmt;
        let mut cur: Vec<i64> = x.iter().map(|&v| fmt.encode(v)).collect();
        let last = self.layers.len() - 1;
        for (li, (out_dim, in_dim, w, b)) in self.layers.iter().enumerate() {
            debug_assert_eq!(cur.len(), *in_dim);
            let mut next = Vec::with_capacity(*out_dim);
            for j in 0..*out_dim {
                let row = &w[j * in_dim..(j + 1) * in_dim];
                let mut acc: i128 = 0;
                for (wv, xv) in row.iter().zip(&cur) {
                    acc += (*wv as i128) * (*xv as i128);
                }
                let mut v = fmt.saturate((acc >> fmt.frac_bits) as i64);
                v = fmt.saturate(v + b[j]);
                if li < last || self.output_activation {
                    v = self.activate_raw(v);
                }
                next.push(v);
            }
            cur = next;
        }
        cur.into_iter().map(|r| fmt.decode(r)).collect()
    }

    /// Activation evaluated in the datapath format. φ uses the AU circuit
    /// ops (mul, >>2, sub); tanh models the CORDIC output by quantizing
    /// the float tanh to the format (the CORDIC's intrinsic error is below
    /// 1 LSB at these widths, see `activation::tanh_cordic` tests).
    fn activate_raw(&self, raw: i64) -> i64 {
        let fmt = self.fmt;
        match self.activation {
            Activation::Phi => {
                let x = Fix { raw, fmt };
                let two = Fix::from_f64(2.0, fmt);
                if x.raw >= two.raw {
                    Fix::from_f64(1.0, fmt).raw
                } else if x.raw <= -two.raw {
                    Fix::from_f64(-1.0, fmt).raw
                } else {
                    let ax = if x.raw < 0 { x.neg() } else { x };
                    x.sub(x.mul(ax).shift(-2)).raw
                }
            }
            Activation::Tanh => fmt.encode(fmt.decode(raw).tanh()),
        }
    }

    /// RMSE of the fixed-point forward pass against targets.
    pub fn rmse(&self, xs: &[Vec<f64>], ys: &[Vec<f64>]) -> f64 {
        crate::analysis::rmse_vecs(&xs.iter().map(|x| self.forward(x)).collect::<Vec<_>>(), ys)
    }
}

/// Float model evaluated with φ — convenience used in tests comparing
/// float vs fixed datapaths.
pub fn phi_float_forward(m: &Mlp, x: &[f64]) -> Vec<f64> {
    debug_assert_eq!(m.activation, Activation::Phi);
    let _ = phi(0.0);
    m.forward(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn small_model(act: Activation) -> Mlp {
        let mut rng = Pcg::new(42);
        let mut m = Mlp::init_random("t", &[4, 8, 8, 2], act, &mut rng);
        // keep pre-activations within format range
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.5;
            }
        }
        m
    }

    #[test]
    fn q16_close_to_float() {
        let m = small_model(Activation::Phi);
        let q = Fqnn::from_mlp(&m, FxFormat::Q16);
        let mut rng = Pcg::new(1);
        let mut max_err: f64 = 0.0;
        for _ in 0..500 {
            let x: Vec<f64> = (0..4).map(|_| rng.range(-1.0, 1.0)).collect();
            let yf = m.forward(&x);
            let yq = q.forward(&x);
            for (a, b) in yf.iter().zip(&yq) {
                max_err = max_err.max((a - b).abs());
            }
        }
        // 10-bit fraction ⇒ errors of order a few LSB through 3 layers
        assert!(max_err < 0.02, "max_err={max_err}");
    }

    #[test]
    fn wider_format_is_more_accurate() {
        let m = small_model(Activation::Phi);
        let mut rng = Pcg::new(2);
        let xs: Vec<Vec<f64>> = (0..300)
            .map(|_| (0..4).map(|_| rng.range(-1.0, 1.0)).collect())
            .collect();
        let ys: Vec<Vec<f64>> = xs.iter().map(|x| m.forward(x)).collect();
        let coarse = Fqnn::from_mlp(&m, FxFormat::new(10, 7)).rmse(&xs, &ys);
        let fine = Fqnn::from_mlp(&m, FxFormat::new(20, 14)).rmse(&xs, &ys);
        assert!(fine < coarse, "fine={fine} coarse={coarse}");
        assert!(fine < 1e-3);
    }

    #[test]
    fn tanh_variant_works() {
        let m = small_model(Activation::Tanh);
        let q = Fqnn::from_mlp(&m, FxFormat::Q16);
        let y = q.forward(&[0.1, -0.2, 0.3, 0.0]);
        let yf = m.forward(&[0.1, -0.2, 0.3, 0.0]);
        for (a, b) in y.iter().zip(&yf) {
            assert!((a - b).abs() < 0.02);
        }
    }

    #[test]
    fn saturation_does_not_wrap() {
        // Huge inputs must clamp, not overflow.
        let m = small_model(Activation::Phi);
        let q = Fqnn::from_mlp(&m, FxFormat::Q1_2_10);
        let y = q.forward(&[100.0, -100.0, 100.0, -100.0]);
        for v in y {
            assert!(v.abs() <= FxFormat::Q1_2_10.max_value() + 1e-9);
        }
    }
}
