//! Activation functions: tanh(x) and the paper's hardware-friendly
//! φ(x) (Eq. 4):
//!
//! ```text
//!        ⎧  1              x ≥ 2
//! φ(x) = ⎨  x − x·|x|/4    −2 < x < 2
//!        ⎩ −1              x ≤ −2
//! ```
//!
//! φ is C¹ (the quadratic meets the clamps with zero slope at ±2), needs
//! one multiply and one shift-by-2, and tracks tanh closely enough that
//! swapping it in costs no measurable accuracy (paper Table I; our E3).
//!
//! Core/host seam: the activation table ([`Activation`], `from_name`) and
//! the exact Q13 datapaths ([`phi_q13`], [`tanh_q13`]) are core — pure
//! integer logic with typed [`CoreError`]s. The float references
//! (`apply`, `phi`, the CORDIC model) are host-only (`std`).

use alloc::string::ToString;

use crate::error::CoreError;
use crate::fixedpoint::Q13;
use crate::nn::tanh_table::TANH_Q13;

/// Which nonlinearity an MLP uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Tanh,
    Phi,
}

impl Activation {
    #[cfg(feature = "std")]
    pub fn apply(self, x: f64) -> f64 {
        match self {
            Activation::Tanh => x.tanh(),
            Activation::Phi => phi(x),
        }
    }
    /// Derivative (for reference-training gradients in tests).
    #[cfg(feature = "std")]
    pub fn grad(self, x: f64) -> f64 {
        match self {
            Activation::Tanh => {
                let t = x.tanh();
                1.0 - t * t
            }
            Activation::Phi => phi_grad(x),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Activation::Tanh => "tanh",
            Activation::Phi => "phi",
        }
    }
    /// Inverse of [`Self::name`] — pure table logic, so it returns the
    /// core's typed error (the host's `anyhow` contexts lift it via `?`).
    pub fn from_name(name: &str) -> Result<Self, CoreError> {
        match name {
            "tanh" => Ok(Activation::Tanh),
            "phi" => Ok(Activation::Phi),
            other => Err(CoreError::UnknownActivation(other.to_string())),
        }
    }
}

/// The paper's φ(x), float version (Eq. 4).
#[cfg(feature = "std")]
pub fn phi(x: f64) -> f64 {
    if x >= 2.0 {
        1.0
    } else if x <= -2.0 {
        -1.0
    } else {
        x - x * x.abs() / 4.0
    }
}

/// dφ/dx = 1 − |x|/2 inside (−2, 2), 0 outside.
#[cfg(feature = "std")]
pub fn phi_grad(x: f64) -> f64 {
    if x.abs() >= 2.0 {
        0.0
    } else {
        1.0 - x.abs() / 2.0
    }
}

/// φ's clamp threshold 2.0 on the Q13 grid.
const TWO_Q13: Q13 = Q13(2 << 10);

/// Bit-accurate AU (activation unit) datapath of Fig. 7: two range
/// comparators/selectors, one multiplier, one shift-right-by-2, one
/// subtractor — all in Q(1,2,10).
pub fn phi_q13(x: Q13) -> Q13 {
    let one = Q13::ONE;
    if x >= TWO_Q13 {
        one
    } else if x <= TWO_Q13.neg() {
        one.neg()
    } else {
        // x − (x·|x|)>>2
        let sq = x.mul(x.abs());
        x.sub(sq.shift(-2))
    }
}

/// Bit-accurate Q13 tanh via the baked [`TANH_Q13`] table and odd
/// symmetry — the core-profile datapath of a tanh SQNN (used only in
/// software ablations; the taped-out AU is φ).
///
/// Bit-compatible with the float round-trip it replaced
/// (`Q13::from_f64(x.to_f64().tanh())`) for **every** raw input,
/// including `Q13::MIN`: tanh(−4.0) and tanh(−3.999) both round to
/// −1023/1024, so clamping |MIN| to MAX before the lookup is exact.
pub fn tanh_q13(x: Q13) -> Q13 {
    let mag = x.0.unsigned_abs().min(crate::fixedpoint::q13::MAX_RAW as u32) as usize;
    let t = TANH_Q13[mag] as i32;
    Q13(if x.0 < 0 { -t } else { t })
}

/// Fixed-point CORDIC hyperbolic tanh, the circuit the paper compares φ
/// against (Fig. 3b). Iteratively rotates (x, y) with the hyperbolic
/// CORDIC recurrence and returns y/x via a final division — modelled here
/// at the arithmetic level to (a) validate that a 13-bit CORDIC matches
/// tanh and (b) anchor the transistor model's iteration count.
///
/// Valid for |z| ≲ 1.12 (the native hyperbolic CORDIC convergence range);
/// the driver extends range with the identity
/// tanh(z) = (tanh(z−a) + t) / (1 + t·tanh(z−a)) only in the float
/// reference — the hardware comparison uses the native range, as the
/// paper's transistor count (50 418) corresponds to the plain iterative
/// core.
#[cfg(feature = "std")]
pub fn tanh_cordic(z: f64, iters: u32, frac_bits: u32) -> f64 {
    // Work in integer fixed point with `frac_bits` fraction bits.
    let one = 1i64 << frac_bits;
    let to_fix = |v: f64| (v * one as f64).round() as i64;
    let from_fix = |v: i64| v as f64 / one as f64;

    let mut x = to_fix(1.0);
    let mut y = 0i64;
    let mut z_acc = to_fix(z.clamp(-1.1, 1.1));

    // Hyperbolic CORDIC repeats iterations 4, 13, 40… for convergence.
    let mut i = 1u32;
    let mut next_repeat = 4u32;
    let mut done = 0u32;
    while done < iters {
        let atanh_i = to_fix(((2f64).powi(-(i as i32))).atanh());
        let d = if z_acc >= 0 { 1 } else { -1 };
        let x_new = x + d * (y >> i);
        let y_new = y + d * (x >> i);
        z_acc -= d * atanh_i;
        x = x_new;
        y = y_new;
        done += 1;
        if i == next_repeat && done < iters {
            // repeat this i once
            next_repeat = next_repeat * 3 + 1;
        } else {
            i += 1;
        }
    }
    // tanh = y/x
    from_fix(((y as i128 * one as i128) / (x as i128).max(1)) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::q13;
    use crate::util::rng::Pcg;

    #[test]
    fn phi_matches_paper_definition() {
        assert_eq!(phi(2.0), 1.0);
        assert_eq!(phi(5.0), 1.0);
        assert_eq!(phi(-2.0), -1.0);
        assert_eq!(phi(-5.0), -1.0);
        assert_eq!(phi(0.0), 0.0);
        assert!((phi(1.0) - 0.75).abs() < 1e-15); // 1 − 1/4
        assert!((phi(-1.0) + 0.75).abs() < 1e-15); // odd
    }

    #[test]
    fn phi_is_continuous_and_monotone() {
        let mut prev = phi(-3.0);
        let mut x = -3.0;
        while x < 3.0 {
            let y = phi(x);
            assert!(y >= prev - 1e-12, "monotone at x={x}");
            assert!((y - prev).abs() < 2e-3, "continuous at x={x}");
            prev = y;
            x += 1e-3;
        }
    }

    #[test]
    fn phi_close_to_tanh() {
        // Fig. 3(a): the two curves are close; max deviation on [−4, 4]
        // is modest (≈0.12) and tiny near the origin.
        let mut max_dev: f64 = 0.0;
        let mut x = -4.0;
        while x <= 4.0 {
            max_dev = max_dev.max((phi(x) - x.tanh()).abs());
            x += 0.01;
        }
        assert!(max_dev < 0.13, "max deviation {max_dev}");
        assert!((phi(0.25) - (0.25f64).tanh()).abs() < 0.02);
    }

    #[test]
    fn phi_grad_is_derivative() {
        let mut x = -2.5;
        while x < 2.5 {
            let h = 1e-6;
            let num = (phi(x + h) - phi(x - h)) / (2.0 * h);
            assert!((num - phi_grad(x)).abs() < 1e-5, "x={x}");
            x += 0.0173;
        }
    }

    #[test]
    fn from_name_roundtrips_and_rejects() {
        for a in [Activation::Tanh, Activation::Phi] {
            assert_eq!(Activation::from_name(a.name()).unwrap(), a);
        }
        let err = Activation::from_name("relu").unwrap_err();
        assert_eq!(err, CoreError::UnknownActivation("relu".into()));
        assert!(err.to_string().contains("relu"));
    }

    #[test]
    fn phi_q13_matches_float_within_2_lsb() {
        let mut rng = Pcg::new(3);
        for _ in 0..20_000 {
            let x = rng.range(-4.0, 4.0);
            let q = Q13::from_f64(x);
            let got = phi_q13(q).to_f64();
            let want = phi(q.to_f64());
            assert!(
                (got - want).abs() <= 2.0 * crate::fixedpoint::q13::LSB,
                "x={x} got={got} want={want}"
            );
        }
    }

    #[test]
    fn phi_q13_saturates_exactly() {
        assert_eq!(phi_q13(Q13::from_f64(3.0)), Q13::ONE);
        assert_eq!(phi_q13(Q13::from_f64(-3.0)), Q13::ONE.neg());
        assert_eq!(phi_q13(Q13::from_f64(2.0)), Q13::ONE);
    }

    #[test]
    fn tanh_table_matches_float_roundtrip_exactly() {
        // The baked table must equal the float expression it replaced on
        // EVERY raw Q13 input — this is what makes the const-table swap a
        // no-op bit-wise. (gen_tables.py asserts every entry is far from
        // a rounding tie, so this holds for any faithfully-rounded libm.)
        for raw in q13::MIN_RAW..=q13::MAX_RAW {
            let q = Q13(raw);
            let want = Q13::from_f64(q.to_f64().tanh());
            assert_eq!(tanh_q13(q), want, "raw={raw}");
        }
    }

    #[test]
    fn tanh_q13_is_odd_monotone_and_bounded() {
        let mut prev = i32::MIN;
        for raw in q13::MIN_RAW..=q13::MAX_RAW {
            let t = tanh_q13(Q13(raw));
            assert!(t.0.abs() <= 1023, "output must stay inside (−1, 1)");
            assert!(t.0 >= prev, "monotone at raw={raw}");
            prev = t.0;
            if raw >= 0 {
                assert_eq!(tanh_q13(Q13(-raw)).0, -t.0, "odd symmetry at {raw}");
            }
        }
        assert_eq!(tanh_q13(Q13::ZERO), Q13::ZERO);
        assert_eq!(tanh_q13(Q13::MIN), tanh_q13(Q13::MAX).neg());
    }

    #[test]
    fn cordic_tanh_converges() {
        for &z in &[-1.0, -0.5, -0.1, 0.0, 0.3, 0.8, 1.05] {
            let approx = tanh_cordic(z, 14, 16);
            assert!((approx - z.tanh()).abs() < 3e-3, "z={z} approx={approx}");
        }
        // more iterations → better
        let coarse = (tanh_cordic(0.7, 8, 16) - (0.7f64).tanh()).abs();
        let fine = (tanh_cordic(0.7, 15, 16) - (0.7f64).tanh()).abs();
        assert!(fine <= coarse + 1e-9);
    }
}
