//! Neural-network models: the float reference (CNN), the 16-bit
//! fixed-point multiplier baseline (FQNN), and the paper's shift-based
//! quantized network (SQNN).
//!
//! Terminology follows §III of the paper:
//!
//! * **CNN** — "continuous NN": float32/float64 MLP, the accuracy
//!   baseline (not a convolutional network).
//! * **FQNN** — CNN quantized to 16-bit fixed point, multiplier datapath;
//!   the hardware baseline of Fig. 5.
//! * **SQNN** — weights quantized as sums of ≤K powers of two, shift–add
//!   datapath; the network the ASIC implements.

pub mod activation;
pub mod mlp;
pub mod fqnn;
pub mod sqnn;

pub use activation::Activation;
pub use mlp::Mlp;
pub use fqnn::Fqnn;
pub use sqnn::Sqnn;
