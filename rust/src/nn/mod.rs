//! Neural-network models: the float reference (CNN), the 16-bit
//! fixed-point multiplier baseline (FQNN), and the paper's shift-based
//! quantized network (SQNN).
//!
//! Terminology follows §III of the paper:
//!
//! * **CNN** — "continuous NN": float32/float64 MLP, the accuracy
//!   baseline (not a convolutional network).
//! * **FQNN** — CNN quantized to 16-bit fixed point, multiplier datapath;
//!   the hardware baseline of Fig. 5.
//! * **SQNN** — weights quantized as sums of ≤K powers of two, shift–add
//!   datapath; the network the ASIC implements.
//!
//! Core/host seam: [`activation`] (integer subset) and [`sqnn`]'s Q13
//! kernels are core; [`mlp`] (float training/JSON) and [`fqnn`] are
//! host-only, as is the float glue around `Sqnn`
//! ([`sqnn::ConditionedSqnn`], `Sqnn::from_mlp`).

pub mod activation;
pub mod tanh_table;
pub mod sqnn;
#[cfg(feature = "std")]
pub mod mlp;
#[cfg(feature = "std")]
pub mod fqnn;

pub use activation::Activation;
pub use sqnn::Sqnn;
#[cfg(feature = "std")]
pub use mlp::Mlp;
#[cfg(feature = "std")]
pub use fqnn::Fqnn;
#[cfg(feature = "std")]
pub use sqnn::ConditionedSqnn;
