//! Float MLP — the "continuous NN" (CNN) reference model, plus JSON
//! (de)serialization of the interchange format produced by
//! `python/compile/train.py`.
//!
//! Model JSON schema (shared with the Python trainer):
//!
//! ```json
//! {
//!   "name": "water_cnn_phi",
//!   "arch": [3, 3, 3, 2],
//!   "activation": "phi",
//!   "output_activation": false,
//!   "layers": [{"w": [[...out×in...]], "b": [...]}, ...],
//!   "quant_k": 3,            // present on QNN exports
//!   "metrics": {...}          // training metadata (free-form)
//! }
//! ```

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};
use super::Activation;

/// One dense layer: `w` is row-major `(out × in)`, `b` has length `out`.
#[derive(Debug, Clone)]
pub struct Dense {
    pub out_dim: usize,
    pub in_dim: usize,
    pub w: Vec<f64>,
    pub b: Vec<f64>,
}

impl Dense {
    pub fn forward(&self, x: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(x.len(), self.in_dim);
        out.clear();
        for j in 0..self.out_dim {
            let row = &self.w[j * self.in_dim..(j + 1) * self.in_dim];
            let mut acc = self.b[j];
            for (wv, xv) in row.iter().zip(x) {
                acc += wv * xv;
            }
            out.push(acc);
        }
    }
}

/// A float multilayer perceptron (Eq. 1).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub name: String,
    pub layers: Vec<Dense>,
    pub activation: Activation,
    /// Whether φ/tanh is applied to the output layer too. The paper's
    /// Eq. (1) ranges l = 1..L+1; we default to a linear output for the
    /// regression head (documented choice, see DESIGN.md §Numerics).
    pub output_activation: bool,
    /// K of the quantization this model was trained for (0 = CNN).
    pub quant_k: usize,
    /// Physical force per unit of network output (eV/Å). The trainer
    /// scales labels by 1/output_scale so the Q(1,2,10) output range
    /// [−4, 4) covers the force distribution; the hardware applies the
    /// inverse as a free power-of-two shift at force reconstruction.
    pub output_scale: f64,
    /// Feature conditioning (the FPGA's constant-subtract + pow2 gain
    /// stage): network inputs are `(raw − center) · scale`. Empty center
    /// = no conditioning.
    pub feature_center: Vec<f64>,
    /// Per-feature power-of-two gains (len = in_dim, or len 1 to
    /// broadcast; empty = 1.0).
    pub feature_scale: Vec<f64>,
}

impl Mlp {
    /// Layer widths including input and output: `[in, h1, …, out]`.
    pub fn arch(&self) -> Vec<usize> {
        let mut a = vec![self.layers[0].in_dim];
        a.extend(self.layers.iter().map(|l| l.out_dim));
        a
    }

    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim
    }
    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Gain of feature dimension `i` (broadcasting a scalar gain).
    pub fn gain(&self, i: usize) -> f64 {
        match self.feature_scale.len() {
            0 => 1.0,
            1 => self.feature_scale[0],
            _ => self.feature_scale[i],
        }
    }

    /// The power-of-two force rescale the hardware undoes at force
    /// reconstruction: the model predicts `F / output_scale`, and the
    /// shift datapath can only apply a 2^m gain. Validates
    /// `output_scale` and returns `m = log2(output_scale)`. Shared by
    /// every fixed-point serving path (water and generic molecules), so
    /// they can never diverge on the protocol.
    pub fn force_shift(&self) -> Result<i32> {
        anyhow::ensure!(
            self.output_scale > 0.0 && self.output_scale.log2().fract() == 0.0,
            "output_scale {} must be a power of two for the shift datapath",
            self.output_scale
        );
        Ok(self.output_scale.log2() as i32)
    }

    /// Apply the feature-conditioning stage to raw features.
    pub fn condition(&self, x: &[f64]) -> Vec<f64> {
        if self.feature_center.is_empty() {
            return x.to_vec();
        }
        debug_assert_eq!(x.len(), self.feature_center.len());
        x.iter()
            .zip(&self.feature_center)
            .enumerate()
            .map(|(i, (v, c))| (v - c) * self.gain(i))
            .collect()
    }

    /// Forward pass for one *raw* (physical) input vector: feature
    /// conditioning is applied on entry.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut cur = self.condition(x);
        let mut next = Vec::new();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            layer.forward(&cur, &mut next);
            if i < last || self.output_activation {
                for v in next.iter_mut() {
                    *v = self.activation.apply(*v);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur
    }

    /// Forward pass for a batch of rows; returns row-major outputs.
    pub fn forward_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        xs.iter().map(|x| self.forward(x)).collect()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Construct from explicit layer data.
    pub fn from_layers(
        name: &str,
        layers: Vec<Dense>,
        activation: Activation,
        output_activation: bool,
    ) -> Result<Self> {
        if layers.is_empty() {
            bail!("MLP needs at least one layer");
        }
        for w in layers.windows(2) {
            if w[0].out_dim != w[1].in_dim {
                bail!("layer dim mismatch: {} -> {}", w[0].out_dim, w[1].in_dim);
            }
        }
        for l in &layers {
            if l.w.len() != l.out_dim * l.in_dim || l.b.len() != l.out_dim {
                bail!("layer shape mismatch");
            }
        }
        Ok(Mlp {
            name: name.to_string(),
            layers,
            activation,
            output_activation,
            quant_k: 0,
            output_scale: 1.0,
            feature_center: Vec::new(),
            feature_scale: Vec::new(),
        })
    }

    /// Random small-weight initialization (for tests and in-crate
    /// reference training).
    pub fn init_random(
        name: &str,
        arch: &[usize],
        activation: Activation,
        rng: &mut crate::util::rng::Pcg,
    ) -> Self {
        let mut layers = Vec::new();
        for pair in arch.windows(2) {
            let (nin, nout) = (pair[0], pair[1]);
            let scale = (1.0 / nin as f64).sqrt();
            let w = (0..nin * nout).map(|_| rng.normal() * scale).collect();
            let b = vec![0.0; nout];
            layers.push(Dense { out_dim: nout, in_dim: nin, w, b });
        }
        Mlp {
            name: name.to_string(),
            layers,
            activation,
            output_activation: false,
            quant_k: 0,
            output_scale: 1.0,
            feature_center: Vec::new(),
            feature_scale: Vec::new(),
        }
    }

    // ---- JSON interchange ----

    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let rows: Vec<Vec<f64>> = (0..l.out_dim)
                    .map(|j| l.w[j * l.in_dim..(j + 1) * l.in_dim].to_vec())
                    .collect();
                json::obj(vec![("w", json::mat_f64(&rows)), ("b", json::arr_f64(&l.b))])
            })
            .collect();
        json::obj(vec![
            ("name", json::s(&self.name)),
            (
                "arch",
                json::arr_i32(&self.arch().iter().map(|&x| x as i32).collect::<Vec<_>>()),
            ),
            ("activation", json::s(self.activation.name())),
            ("output_activation", Value::Bool(self.output_activation)),
            ("quant_k", Value::Num(self.quant_k as f64)),
            ("output_scale", Value::Num(self.output_scale)),
            ("feature_center", json::arr_f64(&self.feature_center)),
            ("feature_scale", json::arr_f64(&self.feature_scale)),
            ("layers", Value::Arr(layers)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let name = v.get("name")?.as_str()?.to_string();
        let activation = Activation::from_name(v.get("activation")?.as_str()?)?;
        let output_activation = match v.opt("output_activation") {
            Some(b) => b.as_bool()?,
            None => false,
        };
        let quant_k = match v.opt("quant_k") {
            Some(k) => k.as_usize()?,
            None => 0,
        };
        let output_scale = match v.opt("output_scale") {
            Some(s) => s.as_f64()?,
            None => 1.0,
        };
        let feature_center = match v.opt("feature_center") {
            Some(c) => c.as_f64_vec()?,
            None => Vec::new(),
        };
        let feature_scale = match v.opt("feature_scale") {
            Some(Value::Num(n)) => vec![*n],
            Some(arr) => arr.as_f64_vec()?,
            None => Vec::new(),
        };
        let mut layers = Vec::new();
        for lv in v.get("layers")?.as_arr()? {
            let rows = lv.get("w")?.as_f64_mat()?;
            let b = lv.get("b")?.as_f64_vec()?;
            let out_dim = rows.len();
            let in_dim = rows.first().map_or(0, |r| r.len());
            let mut w = Vec::with_capacity(out_dim * in_dim);
            for r in &rows {
                if r.len() != in_dim {
                    bail!("ragged weight matrix in {name}");
                }
                w.extend_from_slice(r);
            }
            if b.len() != out_dim {
                bail!("bias length mismatch in {name}");
            }
            layers.push(Dense { out_dim, in_dim, w, b });
        }
        let mut m = Mlp::from_layers(&name, layers, activation, output_activation)
            .with_context(|| format!("loading model {name}"))?;
        m.quant_k = quant_k;
        m.output_scale = output_scale;
        if !feature_center.is_empty() && feature_center.len() != m.in_dim() {
            bail!("feature_center length {} != input dim {}", feature_center.len(), m.in_dim());
        }
        if feature_scale.len() > 1 && feature_scale.len() != m.in_dim() {
            bail!("feature_scale length {} != input dim {}", feature_scale.len(), m.in_dim());
        }
        m.feature_center = feature_center;
        m.feature_scale = feature_scale;
        Ok(m)
    }

    /// Forward pass scaled to physical units (output × output_scale).
    pub fn forward_physical(&self, x: &[f64]) -> Vec<f64> {
        let mut y = self.forward(x);
        if self.output_scale != 1.0 {
            for v in y.iter_mut() {
                *v *= self.output_scale;
            }
        }
        y
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&json::read_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        json::write_file(path, &self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn toy() -> Mlp {
        // 2 → 2 → 1, known weights.
        Mlp::from_layers(
            "toy",
            vec![
                Dense {
                    out_dim: 2,
                    in_dim: 2,
                    w: vec![1.0, -1.0, 0.5, 0.5],
                    b: vec![0.0, 0.1],
                },
                Dense { out_dim: 1, in_dim: 2, w: vec![2.0, -2.0], b: vec![0.25] },
            ],
            Activation::Phi,
            false,
        )
        .unwrap()
    }

    #[test]
    fn forward_by_hand() {
        let m = toy();
        let y = m.forward(&[1.0, 0.5]);
        // layer1 pre: [0.5, 0.85] → φ: [0.4375, 0.669375]
        // layer2: 2·0.4375 − 2·0.669375 + 0.25 = −0.21375
        assert!((y[0] - (-0.21375)).abs() < 1e-12, "{y:?}");
    }

    #[test]
    fn json_roundtrip() {
        let m = toy();
        let v = m.to_json();
        let back = Mlp::from_json(&v).unwrap();
        assert_eq!(back.arch(), m.arch());
        let x = [0.3, -0.7];
        assert_eq!(back.forward(&x), m.forward(&x));
    }

    #[test]
    fn arch_and_params() {
        let m = toy();
        assert_eq!(m.arch(), vec![2, 2, 1]);
        assert_eq!(m.num_params(), 4 + 2 + 2 + 1);
    }

    #[test]
    fn rejects_mismatched_layers() {
        let bad = Mlp::from_layers(
            "bad",
            vec![
                Dense { out_dim: 2, in_dim: 2, w: vec![0.0; 4], b: vec![0.0; 2] },
                Dense { out_dim: 1, in_dim: 3, w: vec![0.0; 3], b: vec![0.0; 1] },
            ],
            Activation::Tanh,
            false,
        );
        assert!(bad.is_err());
    }

    #[test]
    fn random_init_bounded_outputs() {
        let mut rng = Pcg::new(1);
        let m = Mlp::init_random("r", &[8, 16, 16, 3], Activation::Tanh, &mut rng);
        let x: Vec<f64> = (0..8).map(|_| rng.range(-1.0, 1.0)).collect();
        let y = m.forward(&x);
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn output_activation_flag() {
        let mut m = toy();
        let lin = m.forward(&[1.0, 0.5])[0];
        m.output_activation = true;
        let act = m.forward(&[1.0, 0.5])[0];
        assert!((act - crate::nn::activation::phi(lin)).abs() < 1e-12);
    }
}
