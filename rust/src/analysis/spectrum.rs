//! Vibrational density of states from mode-coordinate time series
//! (paper Fig. 10): autocorrelate the (mean-removed) internal coordinate,
//! window, FFT, normalize, locate the peak.

use crate::util::fft;
use crate::util::units;

/// A one-sided normalized spectrum.
#[derive(Debug, Clone)]
pub struct Dos {
    /// Wavenumbers (cm⁻¹) per bin.
    pub wavenumber: Vec<f64>,
    /// Normalized power (peak = 1).
    pub power: Vec<f64>,
}

impl Dos {
    /// Wavenumber of the global peak, refined by parabolic interpolation.
    pub fn peak(&self) -> f64 {
        let (i, _) = fft::argmax(&self.power);
        let frac = fft::parabolic_peak(&self.power, i);
        let dnu = self.wavenumber[1] - self.wavenumber[0];
        frac * dnu
    }

    /// Restrict to a wavenumber window (used to isolate a mode's band).
    pub fn window(&self, lo: f64, hi: f64) -> Dos {
        let mut w = Vec::new();
        let mut p = Vec::new();
        for (nu, pw) in self.wavenumber.iter().zip(&self.power) {
            if (lo..=hi).contains(nu) {
                w.push(*nu);
                p.push(*pw);
            }
        }
        Dos { wavenumber: w, power: p }
    }
}

/// Compute the normalized DOS of a mode-coordinate signal sampled every
/// `dt_fs` femtoseconds. Uses the autocorrelation route of Fig. 10
/// (ACF → Hann window → zero-padded FFT).
pub fn mode_spectrum(signal: &[f64], dt_fs: f64) -> Dos {
    assert!(signal.len() >= 64, "signal too short for a spectrum");
    let max_lag = (signal.len() / 2).min(1 << 15);
    let acf = fft::autocorrelation(signal, max_lag);
    let (freqs, mut power) = fft::power_spectrum(&acf, true, Some(8 * acf.len()));
    // bins: cycles/sample → cm⁻¹
    let wavenumber: Vec<f64> = freqs
        .iter()
        .map(|f| units::freq_fs_to_wavenumber(f / dt_fs))
        .collect();
    let maxp = power.iter().cloned().fold(f64::MIN, f64::max).max(1e-300);
    for p in power.iter_mut() {
        *p /= maxp;
    }
    Dos { wavenumber, power }
}

/// Peak wavenumber of a signal, restricted to a physically sensible band
/// (cuts the zero-frequency/drift bin).
pub fn peak_wavenumber(signal: &[f64], dt_fs: f64, band: (f64, f64)) -> f64 {
    mode_spectrum(signal, dt_fs).window(band.0, band.1).peak_with_offset(band.0)
}

impl Dos {
    fn peak_with_offset(&self, _lo: f64) -> f64 {
        let (i, _) = fft::argmax(&self.power);
        let frac = fft::parabolic_peak(&self.power, i);
        if self.wavenumber.len() < 2 {
            return *self.wavenumber.first().unwrap_or(&0.0);
        }
        let dnu = self.wavenumber[1] - self.wavenumber[0];
        self.wavenumber[0] + frac * dnu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tone(nu_cm: f64, dt_fs: f64, n: usize) -> Vec<f64> {
        let f = crate::util::units::wavenumber_to_freq_fs(nu_cm); // 1/fs
        (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * f * i as f64 * dt_fs).sin())
            .collect()
    }

    #[test]
    fn recovers_single_mode_frequency() {
        // 1603 cm⁻¹ bend-like tone, 0.25 fs sampling, 40k frames.
        let dt = 0.25;
        let signal = tone(1603.0, dt, 40_000);
        let peak = peak_wavenumber(&signal, dt, (200.0, 3000.0));
        assert!((peak - 1603.0).abs() < 15.0, "peak={peak}");
    }

    #[test]
    fn recovers_stretch_frequency() {
        let dt = 0.25;
        let signal = tone(4241.0, dt, 40_000);
        let peak = peak_wavenumber(&signal, dt, (3000.0, 5000.0));
        assert!((peak - 4241.0).abs() < 20.0, "peak={peak}");
    }

    #[test]
    fn separates_two_modes_by_band() {
        let dt = 0.25;
        let n = 40_000;
        let a = tone(1600.0, dt, n);
        let b = tone(4000.0, dt, n);
        let mixed: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + 0.5 * y).collect();
        let low = peak_wavenumber(&mixed, dt, (500.0, 2500.0));
        let high = peak_wavenumber(&mixed, dt, (3000.0, 5000.0));
        assert!((low - 1600.0).abs() < 20.0, "low={low}");
        assert!((high - 4000.0).abs() < 25.0, "high={high}");
    }

    #[test]
    fn dos_normalized() {
        let dt = 0.25;
        let d = mode_spectrum(&tone(2000.0, dt, 8192), dt);
        let maxp = d.power.iter().cloned().fold(f64::MIN, f64::max);
        assert!((maxp - 1.0).abs() < 1e-12);
        assert_eq!(d.wavenumber.len(), d.power.len());
        assert!(d.wavenumber.windows(2).all(|w| w[1] > w[0]));
    }
}
