//! Harmonic normal-mode analysis: finite-difference Hessian of a force
//! field, mass weighting, Jacobi diagonalization → vibrational
//! wavenumbers. Used to calibrate the water PES against the paper's DFT
//! column of Table II and as an independent check on the MD/VACF route.

use crate::linalg::{eigh, Mat};
use crate::md::ForceField;
use crate::util::{units, Vec3};

/// Finite-difference Hessian H[3i+a, 3j+b] = ∂²V/∂x_{ia}∂x_{jb}
/// (eV/Å²), from central differences of analytic forces.
pub fn hessian<F: ForceField + ?Sized>(ff: &F, pos: &[Vec3], h: f64) -> Mat {
    let n = pos.len();
    let dim = 3 * n;
    let mut hess = Mat::zeros(dim, dim);
    let mut fp = vec![Vec3::ZERO; n];
    let mut fm = vec![Vec3::ZERO; n];
    let mut p = pos.to_vec();
    for i in 0..n {
        for a in 0..3 {
            let orig = p[i];
            let mut displaced = orig.to_array();
            displaced[a] += h;
            p[i] = Vec3::from_array(displaced);
            ff.compute(&p, &mut fp);
            displaced[a] -= 2.0 * h;
            p[i] = Vec3::from_array(displaced);
            ff.compute(&p, &mut fm);
            p[i] = orig;
            for j in 0..n {
                let dfp = fp[j].to_array();
                let dfm = fm[j].to_array();
                for b in 0..3 {
                    // H = −∂F/∂x
                    hess[(3 * i + a, 3 * j + b)] = -(dfp[b] - dfm[b]) / (2.0 * h);
                }
            }
        }
    }
    hess.symmetrize();
    hess
}

/// Vibrational wavenumbers (cm⁻¹) of all 3N modes, ascending, from the
/// mass-weighted Hessian. Near-zero modes (translations/rotations) come
/// out ≈ 0.
pub fn normal_mode_wavenumbers<F: ForceField + ?Sized>(
    ff: &F,
    pos: &[Vec3],
    masses: &[f64],
) -> Vec<f64> {
    assert_eq!(pos.len(), masses.len());
    let hess = hessian(ff, pos, 1e-4);
    let dim = 3 * pos.len();
    let mut mw = Mat::zeros(dim, dim);
    for i in 0..dim {
        for j in 0..dim {
            let mi = masses[i / 3];
            let mj = masses[j / 3];
            mw[(i, j)] = hess[(i, j)] / (mi * mj).sqrt();
        }
    }
    let (vals, _) = eigh(&mw);
    vals.into_iter()
        .map(units::hessian_eig_to_wavenumber)
        .collect()
}

/// The vibrational (non-zero) modes: drops the 3N−M smallest
/// |λ| entries where `m_vib` is the expected vibration count
/// (3N−6 for a nonlinear molecule).
pub fn vibrational_modes<F: ForceField + ?Sized>(
    ff: &F,
    pos: &[Vec3],
    masses: &[f64],
    m_vib: usize,
) -> Vec<f64> {
    let all = normal_mode_wavenumbers(ff, pos, masses);
    let n = all.len();
    assert!(m_vib <= n);
    all[n - m_vib..].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::ForceField;

    /// Two unit masses on a spring, k = 50 eV/Å², r0 = 1 Å.
    struct Spring;
    impl ForceField for Spring {
        fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
            let d = pos[1] - pos[0];
            let r = d.norm();
            let k = 50.0;
            let f = k * (r - 1.0);
            let u = d / r;
            forces[0] = u * f;
            forces[1] = u * (-f);
            0.5 * k * (r - 1.0) * (r - 1.0)
        }
    }

    #[test]
    fn diatomic_frequency_analytic() {
        let pos = [Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0)];
        let masses = [1.0, 1.0];
        let modes = normal_mode_wavenumbers(&Spring, &pos, &masses);
        // 6 modes: 5 ≈ 0 (3 trans + 2 rot), 1 vibration at sqrt(k/μ), μ=0.5.
        let expect = units::hessian_eig_to_wavenumber(50.0 / 0.5);
        let vib = modes.last().unwrap();
        assert!((vib - expect).abs() < 0.5, "vib={vib} expect={expect}");
        for z in &modes[..5] {
            assert!(z.abs() < 5.0, "soft mode {z}");
        }
    }

    #[test]
    fn hessian_is_symmetric_and_translation_invariant() {
        let pos = [Vec3::new(0.1, 0.2, -0.1), Vec3::new(1.05, -0.1, 0.2)];
        let h = hessian(&Spring, &pos, 1e-4);
        // symmetry
        for i in 0..6 {
            for j in 0..6 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-6);
            }
        }
        // row sums over the partner atom blocks vanish (force invariance
        // under rigid translation): H_ii = −H_ij for a pair system.
        for a in 0..3 {
            for b in 0..3 {
                assert!((h[(a, b)] + h[(a, 3 + b)]).abs() < 1e-4);
            }
        }
    }
}
