//! Trajectory and model analysis: RMSE metrics, structural statistics
//! (bond lengths, angles), vibrational spectra via autocorrelation + FFT
//! (paper Fig. 10), and normal-mode analysis used to calibrate/verify the
//! DFT-surrogate PES (paper Table II).

pub mod spectrum;
pub mod normal_modes;

pub use spectrum::{mode_spectrum, peak_wavenumber, Dos};
pub use normal_modes::{hessian, normal_mode_wavenumbers};

use crate::util::Vec3;

/// Root-mean-square error between flat prediction/target slices.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(target)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    (s / pred.len() as f64).sqrt()
}

/// RMSE over rows of vectors (flattened).
pub fn rmse_vecs(pred: &[Vec<f64>], target: &[Vec<f64>]) -> f64 {
    assert_eq!(pred.len(), target.len());
    let p: Vec<f64> = pred.iter().flatten().copied().collect();
    let t: Vec<f64> = target.iter().flatten().copied().collect();
    rmse(&p, &t)
}

/// Mean and standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Relative error |a − b| / |b| (the paper's Error¹/²/³ definition).
pub fn relative_error(measured: f64, reference: f64) -> f64 {
    (measured - reference).abs() / reference.abs()
}

/// Structural time series extracted from a water-molecule trajectory.
#[derive(Debug, Clone, Default)]
pub struct WaterSeries {
    /// O–H1 and O–H2 bond lengths per frame (Å).
    pub r1: Vec<f64>,
    pub r2: Vec<f64>,
    /// H–O–H angle per frame (degrees).
    pub angle: Vec<f64>,
}

impl WaterSeries {
    /// Record one frame given positions ordered [O, H1, H2].
    pub fn push(&mut self, pos: &[Vec3]) {
        let (o, h1, h2) = (pos[0], pos[1], pos[2]);
        let b1 = h1 - o;
        let b2 = h2 - o;
        self.r1.push(b1.norm());
        self.r2.push(b2.norm());
        self.angle.push(b1.angle_between(b2).to_degrees());
    }

    pub fn len(&self) -> usize {
        self.r1.len()
    }
    pub fn is_empty(&self) -> bool {
        self.r1.is_empty()
    }

    /// Mean bond length over both bonds (Å) — Table II "Bond length".
    pub fn mean_bond_length(&self) -> f64 {
        let (m1, _) = mean_std(&self.r1);
        let (m2, _) = mean_std(&self.r2);
        0.5 * (m1 + m2)
    }

    /// Mean H–O–H angle (degrees) — Table II "H-O-H angle".
    pub fn mean_angle(&self) -> f64 {
        mean_std(&self.angle).0
    }

    /// Internal-coordinate mode signals for the three vibration modes:
    /// symmetric stretch (r1+r2)/√2, asymmetric stretch (r1−r2)/√2,
    /// bend (angle). Mean-removed.
    pub fn mode_signals(&self) -> [Vec<f64>; 3] {
        let n = self.len();
        let mut sym = Vec::with_capacity(n);
        let mut asym = Vec::with_capacity(n);
        for i in 0..n {
            sym.push((self.r1[i] + self.r2[i]) * std::f64::consts::FRAC_1_SQRT_2);
            asym.push((self.r1[i] - self.r2[i]) * std::f64::consts::FRAC_1_SQRT_2);
        }
        [sym, asym, self.angle.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[1.0, 2.0], &[2.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((rmse(&[0.0, 0.0, 0.0, 4.0], &[0.0; 4]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn relative_error_matches_paper_formula() {
        // Error¹ for bond length: |0.968 − 0.969| / 0.969 ≈ 0.10%.
        let e = relative_error(0.968, 0.969);
        assert!((e * 100.0 - 0.103).abs() < 0.01, "{e}");
    }

    #[test]
    fn water_series_geometry() {
        let mut ws = WaterSeries::default();
        // O at origin, H at 0.97 along x, H in xy-plane at 104.5°.
        let th = 104.5f64.to_radians();
        ws.push(&[
            Vec3::ZERO,
            Vec3::new(0.97, 0.0, 0.0),
            Vec3::new(0.97 * th.cos(), 0.97 * th.sin(), 0.0),
        ]);
        assert!((ws.mean_bond_length() - 0.97).abs() < 1e-12);
        assert!((ws.mean_angle() - 104.5).abs() < 1e-9);
        let [sym, asym, _] = ws.mode_signals();
        assert!((sym[0] - 0.97 * 2.0 / 2f64.sqrt()).abs() < 1e-12);
        assert!(asym[0].abs() < 1e-12);
    }
}
