//! Feature extraction — module (i) of the paper's MLMD pipeline (Fig. 2):
//! convert atomic coordinates into symmetry-invariant descriptors.
//!
//! ## Water (the taped-out system)
//!
//! Per hydrogen atom `a` (the chip predicts H forces; O follows from
//! Newton's third law, §IV-C) the features are inverse distances
//!
//! `D_a = (1/r_aO, 1/r_ab, 1/r_bO)`
//!
//! where `b` is the other hydrogen — a complete, translation/rotation/
//! permutation-invariant coordinate set for a 3-atom molecule (paper
//! §II-B; the paper's input layer width is 3).
//!
//! The MLP output is 2-dimensional (paper §IV-B): the force on `a`
//! expressed in the **local bond frame**, `F_a = c₁·û_aO + c₂·û_ab`.
//! This is exact — the physical force on a hydrogen lies in the molecular
//! plane spanned by those two directions — and makes the 3→…→2 network
//! rotationally equivariant by construction.
//!
//! ## Generic molecules (datasets for Table I / Figs. 4–5)
//!
//! Per atom: `(1/r_j, x_j/r_j², y_j/r_j², z_j/r_j²)` for each of the
//! `n_nb` nearest reference-topology neighbors — a DeePMD-style local
//! descriptor evaluated in the canonical molecule frame (datasets are
//! orientation-fixed; see DESIGN.md §Substitutions).

use crate::util::Vec3;

/// Water feature vector for one hydrogen: (1/r_aO, 1/r_ab, 1/r_bO).
/// `which_h` is 1 or 2, with positions ordered [O, H1, H2].
pub fn water_features(pos: &[Vec3], which_h: usize) -> [f64; 3] {
    debug_assert!(which_h == 1 || which_h == 2);
    let o = pos[0];
    let a = pos[which_h];
    let b = pos[3 - which_h];
    [
        1.0 / (a - o).norm(),
        1.0 / (a - b).norm(),
        1.0 / (b - o).norm(),
    ]
}

/// Local bond frame of hydrogen `which_h`: (û_aO, û_ab).
pub fn water_frame(pos: &[Vec3], which_h: usize) -> (Vec3, Vec3) {
    let o = pos[0];
    let a = pos[which_h];
    let b = pos[3 - which_h];
    ((o - a).normalized(), (b - a).normalized())
}

/// Project a hydrogen's Cartesian force onto the local frame:
/// solve F = c₁·û₁ + c₂·û₂ in the span (exact for planar forces; any
/// out-of-plane residual — zero for a 3-atom PES — is dropped).
pub fn water_force_to_local(pos: &[Vec3], which_h: usize, f: Vec3) -> [f64; 2] {
    let (u1, u2) = water_frame(pos, which_h);
    // Solve the 2×2 Gram system [1, g; g, 1]·c = [f·u1, f·u2].
    let g = u1.dot(u2);
    let det = 1.0 - g * g;
    debug_assert!(det.abs() > 1e-9, "degenerate bond frame");
    let b1 = f.dot(u1);
    let b2 = f.dot(u2);
    [(b1 - g * b2) / det, (b2 - g * b1) / det]
}

/// Reconstruct the Cartesian force from local coefficients.
pub fn water_force_from_local(pos: &[Vec3], which_h: usize, c: [f64; 2]) -> Vec3 {
    let (u1, u2) = water_frame(pos, which_h);
    u1 * c[0] + u2 * c[1]
}

/// Generic per-atom descriptor: 4 features per neighbor, neighbors fixed
/// by the reference-topology ordering (`nb_idx`).
pub fn local_descriptor(pos: &[Vec3], atom: usize, nb_idx: &[usize]) -> Vec<f64> {
    let mut out = vec![0.0; 4 * nb_idx.len()];
    local_descriptor_into(pos, atom, nb_idx, &mut out);
    out
}

/// Allocation-free form of [`local_descriptor`]: writes the 4·n_nb
/// features into `out` (the serving hot path re-extracts every step, so
/// the farm's generic-molecule FPGA owns this scratch).
pub fn local_descriptor_into(pos: &[Vec3], atom: usize, nb_idx: &[usize], out: &mut [f64]) {
    debug_assert_eq!(out.len(), 4 * nb_idx.len());
    let ri = pos[atom];
    for (k, &j) in nb_idx.iter().enumerate() {
        let d = pos[j] - ri;
        let r2 = d.norm_sq();
        let r = r2.sqrt();
        out[4 * k] = 1.0 / r;
        out[4 * k + 1] = d.x / r2;
        out[4 * k + 2] = d.y / r2;
        out[4 * k + 3] = d.z / r2;
    }
}

/// Keep the `n_nb` nearest candidate indices by `dist` (ties broken by
/// index, the documented ordering). Each distance is evaluated exactly
/// once up front; an O(N) `select_nth_unstable_by` partition then keeps
/// only the winners and a final sort orders just that prefix — the full
/// O(N log N) sort (with per-comparison distance recomputation) the
/// previous implementation paid is gone for bulk systems where
/// `n_nb ≪ N`.
fn nearest_by(
    candidates: impl Iterator<Item = usize>,
    n_nb: usize,
    dist: impl Fn(usize) -> f64,
) -> Vec<usize> {
    if n_nb == 0 {
        return Vec::new();
    }
    let mut keyed: Vec<(f64, usize)> = candidates.map(|j| (dist(j), j)).collect();
    let cmp = |a: &(f64, usize), b: &(f64, usize)| {
        a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
    };
    if keyed.len() > n_nb {
        // total order (index tie-break), so the first n_nb slots are
        // exactly the n_nb smallest after selecting the (n_nb−1)-th
        keyed.select_nth_unstable_by(n_nb - 1, cmp);
        keyed.truncate(n_nb);
    }
    keyed.sort_by(cmp);
    keyed.into_iter().map(|(_, j)| j).collect()
}

/// Neighbor ordering for an atom: indices of the `n_nb` nearest other
/// atoms in the reference geometry (stable across configurations).
pub fn reference_neighbors(ref_coords: &[Vec3], atom: usize, n_nb: usize) -> Vec<usize> {
    nearest_by((0..ref_coords.len()).filter(|&j| j != atom), n_nb, |j| {
        (ref_coords[j] - ref_coords[atom]).norm()
    })
}

/// Periodic variant for bulk systems: minimum-image distances in a cubic
/// box; also returns the same fixed neighbor list semantics.
pub fn reference_neighbors_pbc(
    ref_coords: &[Vec3],
    atom: usize,
    n_nb: usize,
    box_l: f64,
) -> Vec<usize> {
    nearest_by((0..ref_coords.len()).filter(|&j| j != atom), n_nb, |j| {
        (ref_coords[j] - ref_coords[atom]).min_image(box_l).norm()
    })
}

/// Periodic descriptor (minimum-image displacements).
pub fn local_descriptor_pbc(pos: &[Vec3], atom: usize, nb_idx: &[usize], box_l: f64) -> Vec<f64> {
    let mut out = vec![0.0; 4 * nb_idx.len()];
    local_descriptor_pbc_into(pos, atom, nb_idx, box_l, &mut out);
    out
}

/// Allocation-free form of [`local_descriptor_pbc`] — the periodic
/// counterpart of [`local_descriptor_into`], used by the generic
/// molecule FPGA's serving hot path for bulk (PBC) systems.
pub fn local_descriptor_pbc_into(
    pos: &[Vec3],
    atom: usize,
    nb_idx: &[usize],
    box_l: f64,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), 4 * nb_idx.len());
    let ri = pos[atom];
    for (k, &j) in nb_idx.iter().enumerate() {
        let d = (pos[j] - ri).min_image(box_l);
        let r2 = d.norm_sq();
        let r = r2.sqrt();
        out[4 * k] = 1.0 / r;
        out[4 * k + 1] = d.x / r2;
        out[4 * k + 2] = d.y / r2;
        out[4 * k + 3] = d.z / r2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potentials::WaterPes;
    use crate::md::ForceField;
    use crate::util::rng::Pcg;

    fn random_rotation(rng: &mut Pcg) -> [[f64; 3]; 3] {
        // Rodrigues from random axis-angle.
        let axis = Vec3::new(rng.normal(), rng.normal(), rng.normal()).normalized();
        let th = rng.range(0.0, std::f64::consts::TAU);
        let (s, c) = th.sin_cos();
        let (x, y, z) = (axis.x, axis.y, axis.z);
        [
            [c + x * x * (1.0 - c), x * y * (1.0 - c) - z * s, x * z * (1.0 - c) + y * s],
            [y * x * (1.0 - c) + z * s, c + y * y * (1.0 - c), y * z * (1.0 - c) - x * s],
            [z * x * (1.0 - c) - y * s, z * y * (1.0 - c) + x * s, c + z * z * (1.0 - c)],
        ]
    }

    fn rot(m: &[[f64; 3]; 3], v: Vec3) -> Vec3 {
        Vec3::new(
            m[0][0] * v.x + m[0][1] * v.y + m[0][2] * v.z,
            m[1][0] * v.x + m[1][1] * v.y + m[1][2] * v.z,
            m[2][0] * v.x + m[2][1] * v.y + m[2][2] * v.z,
        )
    }

    #[test]
    fn water_features_invariant_under_rigid_motion() {
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.02, -0.03, 0.05);
        let f0 = water_features(&pos, 1);
        let mut rng = Pcg::new(21);
        for _ in 0..20 {
            let m = random_rotation(&mut rng);
            let t = Vec3::new(rng.normal(), rng.normal(), rng.normal());
            let moved: Vec<Vec3> = pos.iter().map(|p| rot(&m, *p) + t).collect();
            let f1 = water_features(&moved, 1);
            for (a, b) in f0.iter().zip(&f1) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn water_features_swap_symmetry() {
        // Swapping H labels swaps which_h semantics consistently:
        // D(H1 in [O,H1,H2]) == D(H2 in [O,H2,H1]).
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.03, 0.0, -0.02);
        let swapped = vec![pos[0], pos[2], pos[1]];
        let a = water_features(&pos, 1);
        let b = water_features(&swapped, 2);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn local_force_roundtrip_is_exact_for_pes_forces() {
        // The PES force on H is in span(û_aO, û_ab): projection +
        // reconstruction must be lossless.
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.04, -0.02, 0.01);
        pos[2] += Vec3::new(-0.03, 0.02, -0.02);
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        for h in [1usize, 2] {
            let c = water_force_to_local(&pos, h, f[h]);
            let back = water_force_from_local(&pos, h, c);
            assert!((back - f[h]).norm() < 1e-9, "h={h}: {back:?} vs {:?}", f[h]);
        }
    }

    #[test]
    fn local_force_equivariance() {
        // Rotate the configuration: coefficients stay fixed, Cartesian
        // reconstruction co-rotates.
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.05, 0.01, -0.03);
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        let c0 = water_force_to_local(&pos, 1, f[1]);
        let mut rng = Pcg::new(5);
        let m = random_rotation(&mut rng);
        let moved: Vec<Vec3> = pos.iter().map(|p| rot(&m, *p)).collect();
        let mut fm = vec![Vec3::ZERO; 3];
        pes.compute(&moved, &mut fm);
        let c1 = water_force_to_local(&moved, 1, fm[1]);
        assert!((c0[0] - c1[0]).abs() < 1e-8 && (c0[1] - c1[1]).abs() < 1e-8);
        let rec = water_force_from_local(&moved, 1, c0);
        assert!((rec - rot(&m, f[1])).norm() < 1e-8);
    }

    #[test]
    fn reference_neighbors_sorted_and_stable() {
        let coords = vec![
            Vec3::ZERO,
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 2.0, 0.0),
            Vec3::new(0.0, 0.0, 1.5),
        ];
        let nb = reference_neighbors(&coords, 0, 3);
        assert_eq!(nb, vec![1, 3, 2]);
        let nb2 = reference_neighbors(&coords, 0, 2);
        assert_eq!(nb2, vec![1, 3]);
    }

    #[test]
    fn selection_matches_full_sort_including_ties() {
        // The O(N) selection path must reproduce the old full-sort
        // semantics exactly: distance order, ties broken by index. A
        // lattice gives many exactly-equal distances.
        let mut coords = Vec::new();
        for x in 0..4 {
            for y in 0..4 {
                for z in 0..4 {
                    coords.push(Vec3::new(x as f64, y as f64, z as f64));
                }
            }
        }
        let mut rng = Pcg::new(31);
        for _ in 0..20 {
            let atom = rng.below(coords.len() as u32) as usize;
            for n_nb in [0usize, 1, 5, 12, 63, 100] {
                let got = reference_neighbors(&coords, atom, n_nb);
                // reference: the previous full-sort implementation
                let mut want: Vec<usize> = (0..coords.len()).filter(|&j| j != atom).collect();
                want.sort_by(|&a, &b| {
                    let da = (coords[a] - coords[atom]).norm();
                    let db = (coords[b] - coords[atom]).norm();
                    da.partial_cmp(&db).unwrap().then(a.cmp(&b))
                });
                want.truncate(n_nb);
                assert_eq!(got, want, "atom {atom} n_nb {n_nb}");
                let got_pbc = reference_neighbors_pbc(&coords, atom, n_nb, 4.0);
                let mut want_pbc: Vec<usize> =
                    (0..coords.len()).filter(|&j| j != atom).collect();
                want_pbc.sort_by(|&a, &b| {
                    let da = (coords[a] - coords[atom]).min_image(4.0).norm();
                    let db = (coords[b] - coords[atom]).min_image(4.0).norm();
                    da.partial_cmp(&db).unwrap().then(a.cmp(&b))
                });
                want_pbc.truncate(n_nb);
                assert_eq!(got_pbc, want_pbc, "pbc atom {atom} n_nb {n_nb}");
            }
        }
    }

    #[test]
    fn descriptor_shape_and_values() {
        let coords = vec![Vec3::ZERO, Vec3::new(2.0, 0.0, 0.0), Vec3::new(0.0, 1.0, 0.0)];
        let nb = reference_neighbors(&coords, 0, 2);
        let d = local_descriptor(&coords, 0, &nb);
        assert_eq!(d.len(), 8);
        // nearest neighbor is atom 2 at distance 1
        assert!((d[0] - 1.0).abs() < 1e-12); // 1/r
        assert!((d[2] - 1.0).abs() < 1e-12); // y/r²
        // second neighbor atom 1 at distance 2
        assert!((d[4] - 0.5).abs() < 1e-12);
        assert!((d[5] - 0.5).abs() < 1e-12); // x/r² = 2/4
    }

    #[test]
    fn descriptor_into_matches_allocating_form() {
        let mut rng = Pcg::new(77);
        let coords: Vec<Vec3> = (0..12)
            .map(|_| Vec3::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-2.0, 2.0)))
            .collect();
        for atom in 0..coords.len() {
            let nb = reference_neighbors(&coords, atom, 5);
            let want = local_descriptor(&coords, atom, &nb);
            let mut got = vec![0.0; 20];
            local_descriptor_into(&coords, atom, &nb, &mut got);
            assert_eq!(got, want, "atom {atom}");
        }
    }

    #[test]
    fn pbc_descriptor_uses_minimum_image() {
        let coords = vec![Vec3::ZERO, Vec3::new(9.5, 0.0, 0.0)];
        let nb = reference_neighbors_pbc(&coords, 0, 1, 10.0);
        let d = local_descriptor_pbc(&coords, 0, &nb, 10.0);
        // image distance 0.5, direction −x
        assert!((d[0] - 2.0).abs() < 1e-12);
        assert!((d[1] + 2.0).abs() < 1e-12);
    }
}
