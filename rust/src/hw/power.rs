//! Energy/power model — the basis of the paper's Table III energy rows.
//!
//! Per-operation energies start from the widely used 45 nm/0.9 V numbers
//! (Horowitz, ISSCC 2014: "Computing's energy problem") and scale with
//! process node as E ∝ C·V² (capacitance ≈ linear in feature size). The
//! chip/system power constants are then *calibrated* to the paper's two
//! measurements — 8.7 mW per MLP chip and 1.9 W system total — with the
//! calibration residual absorbed into the static (leakage + clock tree +
//! I/O) terms, exactly the terms a dynamic op-count model cannot predict.
//! The GPU/CPU rows of Table III use the paper's published device powers
//! (they cannot be measured on this testbed); see EXPERIMENTS.md.

/// A fabrication process node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProcessNode {
    pub nm: f64,
    pub vdd: f64,
}

impl ProcessNode {
    /// Horowitz reference node.
    pub const N45: ProcessNode = ProcessNode { nm: 45.0, vdd: 0.9 };
    /// The paper's ASIC (SilTerra 180 nm, 1.8 V core).
    pub const N180: ProcessNode = ProcessNode { nm: 180.0, vdd: 1.8 };
    /// The projection node of §VI.
    pub const N14: ProcessNode = ProcessNode { nm: 14.0, vdd: 0.8 };

    /// Energy scale factor relative to the 45 nm reference:
    /// E ∝ C·V² with C ∝ feature size.
    pub fn energy_scale(&self) -> f64 {
        (self.nm / Self::N45.nm) * (self.vdd / Self::N45.vdd).powi(2)
    }

    /// Achievable clock frequency scale (§VI: advanced nodes reach GHz;
    /// delay ∝ CV/I roughly ∝ feature size at constant field).
    pub fn freq_scale(&self) -> f64 {
        Self::N45.nm / self.nm
    }

    /// Transistor-density scale relative to this node (for the §VI
    /// intra-ASIC parallelization argument): density ∝ 1/feature².
    pub fn density_vs(&self, other: ProcessNode) -> f64 {
        (self.nm / other.nm).powi(2)
    }
}

/// Per-op energies in picojoules at a given node.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub node: ProcessNode,
    /// 13-bit add (scaled from 0.03 pJ @8b/45nm ≈ linear in width).
    pub add13_pj: f64,
    /// 16-bit add.
    pub add16_pj: f64,
    /// Barrel shift, 13-bit.
    pub shift13_pj: f64,
    /// 13×13 multiply (≈ quadratic in width from 0.2 pJ @8b).
    pub mult13_pj: f64,
    /// 16×16 multiply.
    pub mult16_pj: f64,
    /// 32-bit float multiply-add (CPU/GPU comparisons).
    pub fp32_fma_pj: f64,
    /// Local (distributed, near-compute) SRAM read per 16-bit word.
    pub sram_local_pj: f64,
    /// Off-chip DRAM access per 16-bit word — the "memory wall" cost the
    /// NvN design avoids.
    pub dram_pj: f64,
    /// Register write per bit.
    pub reg_bit_pj: f64,
}

impl EnergyModel {
    pub fn at(node: ProcessNode) -> Self {
        let s = node.energy_scale();
        // 45 nm baselines (Horowitz): add8 0.03, add32 0.1, mult8 0.2,
        // mult32 3.1, 8K-SRAM read 10 (per 64b → 2.5/16b), DRAM 1.3–2.6 nJ
        // per 64b → ~325 pJ/16b.
        let add8 = 0.03;
        let mult8 = 0.2;
        EnergyModel {
            node,
            add13_pj: s * add8 * 13.0 / 8.0,
            add16_pj: s * add8 * 16.0 / 8.0,
            shift13_pj: s * 0.01 * 13.0 / 8.0,
            mult13_pj: s * mult8 * (13.0f64 / 8.0).powi(2),
            mult16_pj: s * mult8 * (16.0f64 / 8.0).powi(2),
            fp32_fma_pj: s * (3.1 + 0.9),
            sram_local_pj: s * 2.5,
            dram_pj: s * 325.0,
            reg_bit_pj: s * 0.01,
        }
    }
}

/// Operation counts of one unit of work (e.g. one MLP inference or one
/// MD step) — filled by the device simulators.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    pub shifts: u64,
    pub adds: u64,
    pub mults: u64,
    pub sram_reads: u64,
    pub reg_writes_bits: u64,
    pub dram_accesses: u64,
}

impl OpCounts {
    pub fn energy_pj(&self, e: &EnergyModel) -> f64 {
        self.shifts as f64 * e.shift13_pj
            + self.adds as f64 * e.add13_pj
            + self.mults as f64 * e.mult13_pj
            + self.sram_reads as f64 * e.sram_local_pj
            + self.reg_writes_bits as f64 * e.reg_bit_pj
            + self.dram_accesses as f64 * e.dram_pj
    }
    pub fn merge(&mut self, o: &OpCounts) {
        self.shifts += o.shifts;
        self.adds += o.adds;
        self.mults += o.mults;
        self.sram_reads += o.sram_reads;
        self.reg_writes_bits += o.reg_writes_bits;
        self.dram_accesses += o.dram_accesses;
    }
    pub fn scale(&self, n: u64) -> OpCounts {
        OpCounts {
            shifts: self.shifts * n,
            adds: self.adds * n,
            mults: self.mults * n,
            sram_reads: self.sram_reads * n,
            reg_writes_bits: self.reg_writes_bits * n,
            dram_accesses: self.dram_accesses * n,
        }
    }
}

// ------------------------------------------------------------------
// Calibrated device power constants (paper measurements).
// ------------------------------------------------------------------

/// Measured power of one MLP chip (paper §V-C): 8.7 mW. The dynamic part
/// predicted by the op model at 25 MHz is tens of µW for the water MLP;
/// the remainder is static (leakage, clock tree, I/O pads) and is carried
/// as this calibrated constant.
pub const CHIP_POWER_W: f64 = 8.7e-3;

/// Measured total system power (paper §V-C): 1.9 W (FPGA + 2 chips).
pub const SYSTEM_POWER_W: f64 = 1.9;

/// FPGA share of the system power (system minus two chips).
pub fn fpga_power_w() -> f64 {
    SYSTEM_POWER_W - 2.0 * CHIP_POWER_W
}

/// Published device powers used for the rows of Table III that cannot be
/// measured on this testbed (values as reported in the paper).
pub mod published {
    /// DFT on CPU (paper row 1).
    pub const DFT_CPU_W: f64 = 230.0;
    /// vN-MLMD on CPU (paper row 2, Xeon E5-2696 v2).
    pub const VN_MLMD_CPU_W: f64 = 45.0;
    /// DeePMD on CPU (paper row 3).
    pub const DEEPMD_CPU_W: f64 = 152.0;
    /// DeePMD on CPU + V100 GPU (paper row 4).
    pub const DEEPMD_GPU_W: f64 = 250.0;
    /// Paper-reported speeds (s/step/atom) for external baselines that
    /// involve hardware we do not have.
    pub const DEEPMD_GPU_S: f64 = 2.6e-6;
    pub const DFT_CPU_S: f64 = 1.9;
}

/// FPGA vs ASIC energy/area overhead at the same node (Kuon & Rose,
/// TCAD 2007: FPGAs cost ~12–40× area and ~9–12× dynamic power). Used
/// when modelling what the FPGA modules would cost as ASIC and in the
/// §VI discussion.
pub const FPGA_VS_ASIC_ENERGY: f64 = 10.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_scaling_monotone() {
        let e180 = ProcessNode::N180.energy_scale();
        let e45 = ProcessNode::N45.energy_scale();
        let e14 = ProcessNode::N14.energy_scale();
        assert!((e45 - 1.0).abs() < 1e-12);
        assert!(e180 > 10.0 && e180 < 20.0, "180nm scale {e180}"); // 4×4 = 16
        assert!(e14 < 0.3, "14nm scale {e14}");
    }

    #[test]
    fn density_projection_matches_paper_section_vi() {
        // §VI: 14 nm has ~2 orders of magnitude higher integration than
        // 180 nm.
        let d = ProcessNode::N180.density_vs(ProcessNode::N14);
        assert!((50.0..500.0).contains(&d), "density ratio {d}");
    }

    #[test]
    fn energy_table_sane_at_180nm() {
        let e = EnergyModel::at(ProcessNode::N180);
        // multiply ≫ add ≫ shift; DRAM ≫ everything (the memory wall).
        assert!(e.mult13_pj > 5.0 * e.add13_pj);
        assert!(e.add13_pj > e.shift13_pj);
        assert!(e.dram_pj > 50.0 * e.mult13_pj);
        // a 13-bit add at 180 nm is still well under a nanojoule
        assert!(e.add13_pj < 10.0);
    }

    #[test]
    fn op_counts_energy_accumulates() {
        let e = EnergyModel::at(ProcessNode::N180);
        let a = OpCounts { shifts: 27, adds: 40, ..Default::default() };
        let b = OpCounts { mults: 3, sram_reads: 16, ..Default::default() };
        let mut c = a;
        c.merge(&b);
        let total = c.energy_pj(&e);
        assert!((total - (a.energy_pj(&e) + b.energy_pj(&e))).abs() < 1e-12);
        assert!(total > 0.0);
        assert_eq!(a.scale(2).adds, 80);
    }

    #[test]
    fn chip_dynamic_well_below_measured_power() {
        // The water MLP's dynamic op energy at 25 MHz must come out far
        // below 8.7 mW — the model attributes the rest to static power,
        // matching the calibration note.
        let e = EnergyModel::at(ProcessNode::N180);
        // rough water-MLP per-inference ops (see asic::tests for exact);
        // no per-inference SRAM traffic — weights are statically wired
        // (the NvN architecture).
        let per_inf = OpCounts { shifts: 72, adds: 60, mults: 6, reg_writes_bits: 200, ..Default::default() };
        let inf_per_s = 25.0e6 / 15.0;
        let dyn_w = per_inf.energy_pj(&e) * 1e-12 * inf_per_s;
        assert!(dyn_w < 0.1 * CHIP_POWER_W, "dynamic {dyn_w} W");
    }

    #[test]
    fn system_power_budget() {
        assert!(fpga_power_w() > 1.8 && fpga_power_w() < 1.9);
    }
}
