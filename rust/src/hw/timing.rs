//! Cycle/latency model of the heterogeneous system — the basis of the
//! Table III speed row (S = 1.6×10⁻⁶ s/step/atom at 25 MHz for the
//! 3-atom water system ⇒ 120 clock cycles per MD step).
//!
//! The per-stage budgets below follow the module designs in `fpga/` and
//! `asic/` (each constant is justified next to the stage it models); the
//! test at the bottom checks that the budget reproduces the paper's
//! headline S within tolerance, and `coordinator::Ledger` accounts real
//! simulated runs against these budgets.

/// System clock (paper §V-B).
pub const CLOCK_HZ: f64 = 25.0e6;

/// Cycle budget for one MD step of the water system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepCycles {
    /// FPGA feature extraction for both hydrogens: 3 pairwise distances
    /// (diff, square, accumulate = 4 cycles each) + 3 reciprocal-sqrt
    /// pipelines (LUT + 2 Newton stages = 6 cycles each) + packing.
    pub feature: u64,
    /// FPGA→ASIC feature transfer: 3 features × 13 bit over a 16-bit
    /// parallel link + handshake, per chip but the two chips load in
    /// parallel ⇒ one window.
    pub to_chip: u64,
    /// ASIC MLP latency: layer pipeline (see `asic::MlpChip::latency`).
    pub mlp: u64,
    /// ASIC→FPGA force transfer (2 outputs + handshake).
    pub from_chip: u64,
    /// FPGA: Newton's-third-law oxygen force + integration (Eqs. 2–3)
    /// for 3 atoms × 3 axes (MAC + state update, 2 cycles each) + frame
    /// bookkeeping.
    pub integrate: u64,
    /// Host/control overhead per step (sequencer state machine).
    pub control: u64,
}

impl StepCycles {
    /// The calibrated water-system budget.
    pub fn water() -> StepCycles {
        StepCycles {
            feature: 30,
            to_chip: 8,
            mlp: 12,
            from_chip: 6,
            integrate: 54,
            control: 10,
        }
    }

    pub fn total(&self) -> u64 {
        self.feature + self.to_chip + self.mlp + self.from_chip + self.integrate + self.control
    }

    /// Seconds per MD step at `clock_hz`.
    pub fn seconds_per_step(&self, clock_hz: f64) -> f64 {
        self.total() as f64 / clock_hz
    }

    /// The paper's S metric: s/step/atom.
    pub fn s_per_step_atom(&self, clock_hz: f64, n_atoms: usize) -> f64 {
        self.seconds_per_step(clock_hz) / n_atoms as f64
    }
}

/// End-to-end timing summary for reports.
#[derive(Debug, Clone, Copy)]
pub struct SystemTiming {
    pub clock_hz: f64,
    pub cycles_per_step: u64,
    pub n_atoms: usize,
}

impl SystemTiming {
    pub fn water_nominal() -> Self {
        SystemTiming {
            clock_hz: CLOCK_HZ,
            cycles_per_step: StepCycles::water().total(),
            n_atoms: 3,
        }
    }
    pub fn s_per_step_atom(&self) -> f64 {
        self.cycles_per_step as f64 / self.clock_hz / self.n_atoms as f64
    }
    /// Steps per wall-clock second of the modelled hardware.
    pub fn steps_per_second(&self) -> f64 {
        self.clock_hz / self.cycles_per_step as f64
    }
}

/// Paper's measured S for the NvN system (Table III row 5).
pub const PAPER_NVN_S: f64 = 1.6e-6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn water_budget_reproduces_paper_s() {
        let t = SystemTiming::water_nominal();
        let s = t.s_per_step_atom();
        let ratio = s / PAPER_NVN_S;
        assert!(
            (0.95..=1.05).contains(&ratio),
            "S = {s:.3e} vs paper {PAPER_NVN_S:.1e} (ratio {ratio:.3})"
        );
    }

    #[test]
    fn budget_components_positive_and_sum() {
        let c = StepCycles::water();
        assert_eq!(
            c.total(),
            c.feature + c.to_chip + c.mlp + c.from_chip + c.integrate + c.control
        );
        assert_eq!(c.total(), 120);
    }

    #[test]
    fn mlp_latency_not_dominant() {
        // The paper's point: once the MLP is on the NvN ASIC, it is a
        // small slice of the step; features+integration on the FPGA
        // dominate.
        let c = StepCycles::water();
        assert!(c.mlp * 4 < c.total());
    }

    #[test]
    fn steps_per_second_consistency() {
        let t = SystemTiming::water_nominal();
        let sps = t.steps_per_second();
        assert!((sps * t.cycles_per_step as f64 - t.clock_hz).abs() < 1e-6);
        // ~208k steps/s at 25 MHz / 120 cycles
        assert!((sps - 208_333.0).abs() < 1.0);
    }
}
