//! Gate-level transistor-count model — the reproduction's stand-in for
//! the paper's Synopsys Design Compiler reports (Fig. 3b: tanh 50 418 T
//! vs φ 4 098 T; Fig. 5: SQNN/FQNN ratios).
//!
//! Circuits are described as netlists of standard static-CMOS primitives
//! with textbook transistor counts; composite blocks (adders, barrel
//! shifters, array multipliers/squarers, CORDIC stages) are assembled
//! from them exactly as the RTL of §III–IV describes. The model is *not*
//! fitted to the paper's numbers — the two anchors are reproduced from
//! the architecture (unrolled 14-stage hyperbolic CORDIC + array divider
//! for tanh; conditional-negate + unsigned squarer + subtractor for φ)
//! and the tests assert agreement within a stated band, with the exact
//! measured values reported by `cargo bench --bench fig3_transistors`.

use std::collections::BTreeMap;

/// Static-CMOS primitive gates and their transistor counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Prim {
    Not,
    Nand2,
    Nor2,
    And2,
    Or2,
    Xor2,
    Mux2,
    FullAdder,
    HalfAdder,
    Dff,
    SramBit,
    RomBit,
}

impl Prim {
    pub fn transistors(self) -> u64 {
        match self {
            Prim::Not => 2,
            Prim::Nand2 | Prim::Nor2 => 4,
            Prim::And2 | Prim::Or2 => 6,
            Prim::Xor2 => 8,
            Prim::Mux2 => 6,       // transmission-gate mux + inverter
            Prim::FullAdder => 28, // standard static mirror adder
            Prim::HalfAdder => 14, // XOR + AND2
            Prim::Dff => 24,       // TG master–slave
            Prim::SramBit => 6,
            Prim::RomBit => 1,
        }
    }
}

/// A named bag of primitives.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub name: String,
    counts: BTreeMap<Prim, u64>,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Netlist { name: name.to_string(), counts: BTreeMap::new() }
    }
    pub fn add(&mut self, p: Prim, n: u64) -> &mut Self {
        *self.counts.entry(p).or_insert(0) += n;
        self
    }
    pub fn merge(&mut self, other: &Netlist) -> &mut Self {
        for (p, n) in &other.counts {
            *self.counts.entry(*p).or_insert(0) += n;
        }
        self
    }
    /// Merge `other` scaled by a multiplicity.
    pub fn merge_n(&mut self, other: &Netlist, times: u64) -> &mut Self {
        for (p, n) in &other.counts {
            *self.counts.entry(*p).or_insert(0) += n * times;
        }
        self
    }
    pub fn transistors(&self) -> u64 {
        self.counts.iter().map(|(p, n)| p.transistors() * n).sum()
    }
    pub fn count(&self, p: Prim) -> u64 {
        self.counts.get(&p).copied().unwrap_or(0)
    }
    pub fn breakdown(&self) -> Vec<(Prim, u64, u64)> {
        self.counts
            .iter()
            .map(|(p, n)| (*p, *n, p.transistors() * *n))
            .collect()
    }
}

// ------------------------------------------------------------------
// Composite arithmetic blocks.
// ------------------------------------------------------------------
pub mod blocks {
    use super::{Netlist, Prim};

    /// n-bit ripple-carry adder.
    pub fn adder(bits: u64) -> Netlist {
        let mut n = Netlist::new("adder");
        n.add(Prim::FullAdder, bits);
        n
    }

    /// n-bit adder/subtractor (adder + XOR row for operand inversion).
    pub fn add_sub(bits: u64) -> Netlist {
        let mut n = adder(bits);
        n.name = "add_sub".into();
        n.add(Prim::Xor2, bits);
        n
    }

    /// Two's-complement negate: XOR row + increment (half-adder chain).
    pub fn negate(bits: u64) -> Netlist {
        let mut n = Netlist::new("negate");
        n.add(Prim::Xor2, bits).add(Prim::HalfAdder, bits);
        n
    }

    /// Conditional negate (the sign/symbol selector of Fig. 7): negate +
    /// output mux.
    pub fn sign_select(bits: u64) -> Netlist {
        let mut n = negate(bits);
        n.name = "sign_select".into();
        n.add(Prim::Mux2, bits);
        n
    }

    /// Barrel shifter: `stages` mux levels across the datapath width.
    pub fn barrel_shifter(bits: u64, stages: u64) -> Netlist {
        let mut n = Netlist::new("barrel_shifter");
        n.add(Prim::Mux2, bits * stages);
        n
    }

    /// Unsigned n×m array multiplier: n·m AND partial products +
    /// (n−1)·m full-adder reduction rows.
    pub fn array_multiplier(n_bits: u64, m_bits: u64) -> Netlist {
        let mut n = Netlist::new("array_multiplier");
        n.add(Prim::And2, n_bits * m_bits);
        n.add(Prim::FullAdder, (n_bits.saturating_sub(1)) * m_bits);
        n
    }

    /// Signed (Baugh–Wooley) n×m multiplier: array + sign-correction row.
    pub fn signed_multiplier(n_bits: u64, m_bits: u64) -> Netlist {
        let mut n = array_multiplier(n_bits, m_bits);
        n.name = "signed_multiplier".into();
        n.add(Prim::Not, n_bits + m_bits);
        n.add(Prim::FullAdder, m_bits);
        n
    }

    /// Unsigned squarer: folding the partial-product array over its
    /// diagonal symmetry removes ≈ half the array (classic optimization).
    pub fn squarer(bits: u64) -> Netlist {
        let mut n = Netlist::new("squarer");
        n.add(Prim::And2, bits * (bits + 1) / 2);
        n.add(Prim::FullAdder, bits.saturating_sub(1) * bits / 2);
        n
    }

    /// Magnitude comparator against a constant: ~4T/bit of gating.
    pub fn comparator_const(bits: u64) -> Netlist {
        let mut n = Netlist::new("comparator_const");
        n.add(Prim::And2, bits / 2).add(Prim::Or2, bits / 2).add(Prim::Not, bits % 2);
        n
    }

    /// n-bit register.
    pub fn register(bits: u64) -> Netlist {
        let mut n = Netlist::new("register");
        n.add(Prim::Dff, bits);
        n
    }

    /// Non-restoring array divider (n-bit quotient): n rows of
    /// (add/sub + quotient mux).
    pub fn array_divider(bits: u64) -> Netlist {
        let mut n = Netlist::new("array_divider");
        for _ in 0..bits {
            n.merge(&add_sub(bits));
            n.add(Prim::Mux2, bits);
        }
        n
    }

    /// Distributed weight storage (the NvN "memory near compute"): SRAM
    /// bits co-located with the MACs.
    pub fn weight_sram(bits: u64) -> Netlist {
        let mut n = Netlist::new("weight_sram");
        n.add(Prim::SramBit, bits);
        n
    }
}

// ------------------------------------------------------------------
// Paper circuits.
// ------------------------------------------------------------------

/// Datapath width of the system (1 + 2 + 10, §IV-C).
pub const Q13_BITS: u64 = 13;
/// FQNN baseline width (Fig. 5).
pub const FQNN_BITS: u64 = 16;
/// CORDIC tanh implementation width/iterations (16-bit fixed point,
/// 14 hyperbolic iterations — the standard choice for ~1e-4 accuracy,
/// cf. `nn::activation::tanh_cordic` tests).
pub const CORDIC_BITS: u64 = 16;
pub const CORDIC_ITERS: u64 = 14;
/// SU shift-exponent field width (two's complement; exponents in
/// [−16, 15], see `quant::EXP_MIN/MAX`) ⇒ 5-stage barrel shifters.
pub const SU_SHIFT_STAGES: u64 = 5;
pub const SU_EXP_BITS: u64 = 5;

/// The φ(x) activation unit of Fig. 7: two range selectors
/// (comparator + saturation mux), conditional negate producing |x|, an
/// unsigned squarer computing x·|x| = sign·|x|² (11 significant bits in
/// (−2,2) with 10 fraction bits), a hardwired >>2 (free), and a
/// subtractor.
pub fn phi_unit(bits: u64) -> Netlist {
    let mag_bits = bits - 2; // |x| < 2 ⇒ drop sign and top integer bit
    let mut n = Netlist::new("phi_unit");
    n.merge(&blocks::comparator_const(bits)); // x ≥ 2
    n.merge(&blocks::comparator_const(bits)); // x ≤ −2
    n.add(Prim::Mux2, 2 * bits); // two saturation selectors
    n.merge(&blocks::negate(bits)); // |x|
    n.merge(&blocks::squarer(mag_bits)); // |x|²
    n.merge(&blocks::sign_select(bits)); // sign·|x|² (x·|x|)
    // >>2 is wiring (0 T)
    n.merge(&blocks::add_sub(bits)); // x − (x·|x|)>>2
    n
}

/// The CORDIC tanh unit the paper synthesized for comparison (Fig. 3b):
/// an unrolled pipeline of `iters` hyperbolic rotation stages (3
/// add/subs + 3 pipeline registers per stage; shifts hardwired in an
/// unrolled design; atanh constants folded into the z-path adders as ROM
/// bits), plus the final y/x division (tanh = sinh/cosh) on an array
/// divider, plus range-reduction compare/select.
pub fn tanh_cordic_unit(bits: u64, iters: u64) -> Netlist {
    let mut n = Netlist::new("tanh_cordic_unit");
    for _ in 0..iters {
        n.merge(&blocks::add_sub(bits)); // x-path
        n.merge(&blocks::add_sub(bits)); // y-path
        n.merge(&blocks::add_sub(bits)); // z-path
        n.merge(&blocks::register(bits)); // pipeline regs ×3
        n.merge(&blocks::register(bits));
        n.merge(&blocks::register(bits));
        n.add(Prim::RomBit, bits); // atanh constant
    }
    n.merge(&blocks::array_divider(bits)); // y/x
    n.merge(&blocks::comparator_const(bits)); // range check
    n.add(Prim::Mux2, bits);
    n
}

/// One shift unit (SU, Fig. 7): K barrel shifters, a (K−1)-adder
/// reduction, and the symbol selector; plus the distributed storage of
/// the quantized weight (1 sign bit + K exponent fields).
pub fn shift_unit(bits: u64, k: u64) -> Netlist {
    let mut n = Netlist::new("shift_unit");
    for _ in 0..k {
        n.merge(&blocks::barrel_shifter(bits, SU_SHIFT_STAGES));
    }
    for _ in 0..k.saturating_sub(1) {
        n.merge(&blocks::adder(bits));
    }
    n.merge(&blocks::sign_select(bits));
    n.merge(&blocks::weight_sram(1 + k * SU_EXP_BITS));
    n
}

/// FQNN's per-weight datapath: a signed multiplier + weight storage.
pub fn mult_unit(bits: u64) -> Netlist {
    let mut n = Netlist::new("mult_unit");
    n.merge(&blocks::signed_multiplier(bits, bits));
    n.merge(&blocks::weight_sram(bits));
    n
}

/// A matrix unit (MU, Fig. 7): `fan_in` per-weight datapaths, the
/// adder-tree reduction, the bias add (+ bias storage), and the output
/// register.
fn matrix_unit(per_weight: &Netlist, bits: u64, fan_in: u64) -> Netlist {
    let mut n = Netlist::new("matrix_unit");
    n.merge_n(per_weight, fan_in);
    for _ in 0..fan_in.saturating_sub(1) {
        n.merge(&blocks::adder(bits)); // reduction tree
    }
    n.merge(&blocks::adder(bits)); // bias
    n.merge(&blocks::weight_sram(bits)); // bias storage
    n.merge(&blocks::register(bits)); // output register
    n
}

/// Which per-weight datapath an MLP synthesis uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightDatapath {
    /// Shift–add with K terms (SQNN).
    Shift { k: u64 },
    /// Fixed-point multiplier (FQNN).
    Multiplier,
}

/// Synthesize a full MLP (Fig. 7 replicated per layer): for every layer,
/// `out_dim` MUs + `out_dim` activation units (the output layer is
/// linear, no AU). `arch` = [in, h1, …, out].
pub fn mlp_netlist(arch: &[usize], bits: u64, dp: WeightDatapath) -> Netlist {
    assert!(arch.len() >= 2);
    let per_weight = match dp {
        WeightDatapath::Shift { k } => shift_unit(bits, k),
        WeightDatapath::Multiplier => mult_unit(bits),
    };
    let phi = phi_unit(bits);
    let mut n = Netlist::new("mlp");
    for (li, pair) in arch.windows(2).enumerate() {
        let (fan_in, out_dim) = (pair[0] as u64, pair[1] as u64);
        let mu = matrix_unit(&per_weight, bits, fan_in);
        n.merge_n(&mu, out_dim);
        let is_output = li == arch.len() - 2;
        if !is_output {
            n.merge_n(&phi, out_dim);
        }
    }
    // input registers
    n.merge(&blocks::register(bits * arch[0] as u64));
    n
}

/// Paper anchors (Fig. 3b).
pub const PAPER_TANH_T: u64 = 50_418;
pub const PAPER_PHI_T: u64 = 4_098;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_counts_are_textbook() {
        assert_eq!(Prim::FullAdder.transistors(), 28);
        assert_eq!(blocks::adder(13).transistors(), 13 * 28);
        assert_eq!(blocks::register(16).transistors(), 16 * 24);
        let m = blocks::array_multiplier(8, 8);
        assert_eq!(m.transistors(), 64 * 6 + 7 * 8 * 28);
    }

    #[test]
    fn phi_anchor_within_band() {
        let t = phi_unit(Q13_BITS).transistors();
        let ratio = t as f64 / PAPER_PHI_T as f64;
        assert!(
            (0.65..=1.45).contains(&ratio),
            "φ unit = {t} T vs paper {PAPER_PHI_T} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn tanh_anchor_within_band() {
        let t = tanh_cordic_unit(CORDIC_BITS, CORDIC_ITERS).transistors();
        let ratio = t as f64 / PAPER_TANH_T as f64;
        assert!(
            (0.65..=1.45).contains(&ratio),
            "tanh unit = {t} T vs paper {PAPER_TANH_T} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn phi_is_an_order_of_magnitude_cheaper_than_tanh() {
        // Paper: φ costs 8% of tanh. Assert the qualitative claim broadly.
        let phi = phi_unit(Q13_BITS).transistors() as f64;
        let tanh = tanh_cordic_unit(CORDIC_BITS, CORDIC_ITERS).transistors() as f64;
        let frac = phi / tanh;
        assert!(frac < 0.15, "φ/tanh = {frac:.3}");
    }

    #[test]
    fn su_cheaper_than_multiplier_at_k3() {
        let su = shift_unit(Q13_BITS, 3).transistors();
        let mu = mult_unit(FQNN_BITS).transistors();
        let ratio = su as f64 / mu as f64;
        assert!(ratio < 0.55, "SU/mult = {ratio:.2}");
        assert!(ratio > 0.10, "SU/mult = {ratio:.2} suspiciously low");
    }

    #[test]
    fn sqnn_saves_50_to_70_percent_at_k3_on_larger_nets() {
        // Fig. 5 headline: at K=3, SQNN saves ~50–70% vs FQNN, more for
        // complex systems.
        for arch in [&[32usize, 16, 16, 3][..], &[56, 48, 48, 3], &[64, 64, 64, 3]] {
            let s = mlp_netlist(arch, Q13_BITS, WeightDatapath::Shift { k: 3 }).transistors();
            let f = mlp_netlist(arch, FQNN_BITS, WeightDatapath::Multiplier).transistors();
            let ratio = s as f64 / f as f64;
            assert!(
                (0.25..=0.55).contains(&ratio),
                "arch {arch:?}: ratio {ratio:.2} ({s} vs {f})"
            );
        }
    }

    #[test]
    fn ratio_decreases_with_complexity_and_increases_with_k() {
        let archs: Vec<Vec<usize>> = vec![
            vec![3, 3, 3, 2],
            vec![32, 16, 16, 3],
            vec![40, 24, 24, 3],
            vec![48, 32, 32, 3],
            vec![56, 48, 48, 3],
            vec![64, 64, 64, 3],
        ];
        let mut prev = f64::INFINITY;
        for arch in &archs {
            let s = mlp_netlist(arch, Q13_BITS, WeightDatapath::Shift { k: 3 }).transistors();
            let f = mlp_netlist(arch, FQNN_BITS, WeightDatapath::Multiplier).transistors();
            let ratio = s as f64 / f as f64;
            assert!(ratio < prev + 0.02, "ratio should fall with complexity");
            prev = ratio;
        }
        // K sweep on one arch: ratio grows with K
        let f = mlp_netlist(&[48, 32, 32, 3], FQNN_BITS, WeightDatapath::Multiplier).transistors();
        let mut last = 0.0;
        for k in 1..=5 {
            let s = mlp_netlist(&[48, 32, 32, 3], Q13_BITS, WeightDatapath::Shift { k }).transistors();
            let r = s as f64 / f as f64;
            assert!(r > last, "k={k}");
            last = r;
        }
    }

    #[test]
    fn netlist_merge_bookkeeping() {
        let mut a = Netlist::new("a");
        a.add(Prim::FullAdder, 2);
        let mut b = Netlist::new("b");
        b.add(Prim::FullAdder, 3).add(Prim::Not, 1);
        a.merge_n(&b, 2);
        assert_eq!(a.count(Prim::FullAdder), 8);
        assert_eq!(a.count(Prim::Not), 2);
        assert_eq!(a.transistors(), 8 * 28 + 2 * 2);
        let bd = a.breakdown();
        assert_eq!(bd.len(), 2);
    }
}
