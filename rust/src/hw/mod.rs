//! Hardware models: gate-level transistor-count synthesis (the stand-in
//! for the paper's Synopsys DC reports, Figs. 3b/5), the per-operation
//! energy model (Table III power rows), and the cycle/latency model of
//! the heterogeneous system (Table III speed row).

pub mod synth;
pub mod power;
pub mod timing;

pub use synth::Netlist;
pub use power::{EnergyModel, ProcessNode};
pub use timing::SystemTiming;
