//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Provides seeded generators, a `forall` runner with failure-case
//! shrinking for numeric inputs, and helpers used by the coordinator and
//! kernel-equivalence invariants. Deliberately small: generators are
//! closures over [`Pcg`], shrinking bisects floats toward zero and
//! vectors toward shorter lengths.

use crate::util::rng::Pcg;

pub mod arrivals;
#[cfg(any(test, feature = "faults"))]
pub mod faults;

/// Configuration for a property run.
#[derive(Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x5eed, max_shrink_steps: 200 }
    }
}

/// Outcome of a property check on one case.
pub type Check = Result<(), String>;

/// Run `prop` on `cases` generated inputs; on failure, shrink with
/// `shrink` (which yields candidate simplifications) and panic with the
/// minimal failing case.
pub fn forall<T, G, P, S>(cfg: &Config, mut gen: G, mut shrink: S, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> Check,
    S: FnMut(&T) -> Vec<T>,
{
    let mut rng = Pcg::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink
            let mut best = (input.clone(), msg.clone());
            let mut steps = 0;
            'outer: loop {
                if steps >= cfg.max_shrink_steps {
                    break;
                }
                for cand in shrink(&best.0) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.seed, best.0, best.1
            );
        }
    }
}

/// Convenience: property over a `Vec<f64>` with length in [min_len,
/// max_len] and elements in [lo, hi]. Shrinks by halving elements and
/// dropping halves of the vector.
pub fn forall_f64_vec<P>(cfg: &Config, min_len: usize, max_len: usize, lo: f64, hi: f64, prop: P)
where
    P: FnMut(&Vec<f64>) -> Check,
{
    let gen = move |rng: &mut Pcg| {
        let n = min_len + rng.below((max_len - min_len + 1) as u32) as usize;
        (0..n).map(|_| rng.range(lo, hi)).collect::<Vec<f64>>()
    };
    let shrink = move |v: &Vec<f64>| {
        let mut out = Vec::new();
        if v.len() > min_len {
            out.push(v[..v.len() / 2.max(min_len)].to_vec());
            out.push(v[v.len() / 2..].to_vec());
        }
        // halve magnitudes
        if v.iter().any(|x| x.abs() > 1e-12) {
            out.push(v.iter().map(|x| x / 2.0).collect());
        }
        // zero one element at a time (first few)
        for i in 0..v.len().min(4) {
            if v[i] != 0.0 {
                let mut w = v.clone();
                w[i] = 0.0;
                out.push(w);
            }
        }
        out.retain(|w: &Vec<f64>| w.len() >= min_len);
        out
    };
    forall(cfg, gen, shrink, prop);
}

/// Assert two floats are close (absolute + relative tolerance), as a
/// `Check` for use inside properties.
pub fn close(a: f64, b: f64, atol: f64, rtol: f64) -> Check {
    let tol = atol + rtol * b.abs().max(a.abs());
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assert slices are element-wise close.
pub fn all_close(a: &[f64], b: &[f64], atol: f64, rtol: f64) -> Check {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        close(*x, *y, atol, rtol).map_err(|e| format!("at {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall_f64_vec(&Config { cases: 50, ..Default::default() }, 1, 8, -1.0, 1.0, |v| {
            count += 1;
            if v.iter().all(|x| x.abs() <= 1.0) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_shrinks() {
        // Property: sum < 3. Failing cases get shrunk; the panic message
        // should contain a small counterexample.
        let result = std::panic::catch_unwind(|| {
            forall_f64_vec(&Config { cases: 200, seed: 1, ..Default::default() }, 1, 10, 0.0, 1.0, |v| {
                if v.iter().sum::<f64>() < 3.0 {
                    Ok(())
                } else {
                    Err(format!("sum = {}", v.iter().sum::<f64>()))
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("property failed"), "{msg}");
    }

    #[test]
    fn close_helpers() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-9, 0.0).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
