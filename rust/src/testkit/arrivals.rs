//! Deterministic arrival-process generator for gateway load tests and
//! benches.
//!
//! Serving-tier tests need an open-loop request stream (bursty
//! inter-arrival times, mixed species, scattered deadlines) that replays
//! **bit-identically** on every platform and backend — so results,
//! accept/reject decisions, and SLO ledgers can be compared exactly
//! between runs. The generator therefore uses only the crate's own
//! integer [`Pcg`] stream: inter-arrival gaps are geometric (the
//! discrete analogue of Poisson exponential gaps) sampled by integer
//! rejection — `P(gap = g) ∝ (1 - 1/mean_gap)^g`, truncated at
//! `max_gap` — with no floating-point `ln` anywhere, so there is no
//! libm to disagree across targets. The same plan drives both the test
//! suite and `benches/farm_throughput.rs`.

use crate::util::rng::Pcg;

/// One request in an arrival plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// Virtual-clock tick the request arrives at (non-decreasing along
    /// the plan).
    pub at_tick: u64,
    /// Species index to submit against.
    pub species: usize,
    /// MD ticks of simulation requested.
    pub ticks: u64,
    /// Absolute virtual-clock deadline (`at_tick + ticks + slack`).
    pub deadline: u64,
}

/// Parameters of a deterministic arrival plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalSpec {
    /// RNG seed; same seed + same spec ⇒ bit-identical plan.
    pub seed: u64,
    /// Number of arrivals to generate.
    pub n: usize,
    /// Mean inter-arrival gap in ticks (geometric distribution; `0` is
    /// treated as `1`). Smaller = heavier offered load.
    pub mean_gap: u32,
    /// Hard cap on a single inter-arrival gap (keeps plans bounded).
    pub max_gap: u64,
    /// Relative weights of each species in the mix (length = species
    /// count; zero-weight species never arrive).
    pub species_weights: Vec<u32>,
    /// Inclusive range of requested MD ticks per arrival.
    pub ticks_range: (u64, u64),
    /// Inclusive range of deadline slack beyond the requested ticks.
    pub slack_range: (u64, u64),
}

impl ArrivalSpec {
    /// A reasonable default mix: uniform weights over `n_species`,
    /// short requests, moderate slack.
    pub fn new(seed: u64, n: usize, n_species: usize) -> ArrivalSpec {
        ArrivalSpec {
            seed,
            n,
            mean_gap: 4,
            max_gap: 64,
            species_weights: vec![1; n_species.max(1)],
            ticks_range: (4, 24),
            slack_range: (8, 40),
        }
    }
}

/// Generate the arrival plan for `spec`: a vector of [`Arrival`]s with
/// non-decreasing `at_tick`, pure in `spec` (same spec ⇒ same plan, on
/// every platform).
pub fn plan(spec: &ArrivalSpec) -> Vec<Arrival> {
    assert!(
        spec.species_weights.iter().any(|&w| w > 0),
        "arrival spec needs at least one species with nonzero weight"
    );
    assert!(spec.ticks_range.0 <= spec.ticks_range.1, "empty ticks range");
    assert!(spec.slack_range.0 <= spec.slack_range.1, "empty slack range");
    let total_w: u32 = spec.species_weights.iter().sum();
    let mean = spec.mean_gap.max(1);
    let mut rng = Pcg::with_stream(spec.seed, 0xa5517a15);
    let mut out = Vec::with_capacity(spec.n);
    let mut t = 0u64;
    for _ in 0..spec.n {
        // Geometric gap with success probability 1/mean: count failures
        // of a `below(mean) == 0` trial, truncated at max_gap. Integer
        // only — replays bit-identically everywhere.
        let mut gap = 0u64;
        while gap < spec.max_gap && rng.below(mean) != 0 {
            gap += 1;
        }
        t += gap;
        // Weighted species pick.
        let mut pick = rng.below(total_w);
        let mut species = 0usize;
        for (si, &w) in spec.species_weights.iter().enumerate() {
            if pick < w {
                species = si;
                break;
            }
            pick -= w;
        }
        let (tl, th) = spec.ticks_range;
        let ticks = tl + u64::from(rng.below((th - tl + 1).min(u64::from(u32::MAX)) as u32));
        let (sl, sh) = spec.slack_range;
        let slack = sl + u64::from(rng.below((sh - sl + 1).min(u64::from(u32::MAX)) as u32));
        out.push(Arrival { at_tick: t, species, ticks, deadline: t + ticks + slack });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic() {
        let spec = ArrivalSpec::new(42, 64, 3);
        let a = plan(&spec);
        let b = plan(&spec);
        assert_eq!(a, b);
        assert_eq!(a.len(), 64);
    }

    #[test]
    fn arrivals_are_ordered_and_in_range() {
        let spec = ArrivalSpec {
            seed: 7,
            n: 200,
            mean_gap: 3,
            max_gap: 16,
            species_weights: vec![2, 1],
            ticks_range: (5, 9),
            slack_range: (10, 20),
        };
        let p = plan(&spec);
        let mut prev = 0u64;
        for a in &p {
            assert!(a.at_tick >= prev, "at_tick must be non-decreasing");
            prev = a.at_tick;
            assert!(a.species < 2);
            assert!((5..=9).contains(&a.ticks));
            let slack = a.deadline - a.at_tick - a.ticks;
            assert!((10..=20).contains(&slack));
        }
    }

    #[test]
    fn all_weighted_species_appear() {
        let spec = ArrivalSpec::new(99, 300, 4);
        let p = plan(&spec);
        for s in 0..4 {
            assert!(p.iter().any(|a| a.species == s), "species {s} never arrived");
        }
    }

    #[test]
    fn zero_weight_species_never_arrive() {
        let mut spec = ArrivalSpec::new(11, 200, 3);
        spec.species_weights = vec![1, 0, 1];
        assert!(plan(&spec).iter().all(|a| a.species != 1));
    }

    #[test]
    fn different_seeds_differ() {
        let a = plan(&ArrivalSpec::new(1, 64, 2));
        let b = plan(&ArrivalSpec::new(2, 64, 2));
        assert_ne!(a, b);
    }
}
