//! Deterministic fault injection for the supervised farm.
//!
//! A [`FaultPlan`] is a small, `Copy`, exactly-reproducible schedule of
//! faults — *panic shard i at tick t*, *force molecule j into rail
//! saturation at tick t*, *drop shard i's reply channel at tick t* —
//! that the farm and pool consult at fixed points of their tick. There
//! is no timing or randomness at injection time: the same plan against
//! the same workload produces the same fault, the same recovery, and
//! the same ledger on every run and on both backends (the whole point —
//! the tier-1 suite asserts inline/threaded ledger identity *under*
//! faults).
//!
//! Compiled only under `cfg(any(test, feature = "faults"))`; production
//! builds carry no injection branches.

use crate::util::rng::Pcg;

/// Max scheduled faults per kind. Fixed arrays keep the plan `Copy`, so
/// it can ride inside the `Copy` farm/config structs.
pub const MAX_FAULTS: usize = 4;

/// A deterministic fault schedule. Coordinates are farm-level: shards
/// by farm shard index, molecules by farm-wide construction-order
/// index, ticks by farm tick (0-based).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// (shard, tick): panic the shard's job at the top of that tick,
    /// before it mutates any state.
    panics: [Option<(usize, u64)>; MAX_FAULTS],
    /// (molecule, tick): pin the molecule's integrator state to the
    /// 26-bit rail at the top of that tick.
    sats: [Option<(usize, u64)>; MAX_FAULTS],
    /// (shard, tick): drop the reply channel of that tick's job
    /// (threaded backend; ignored inline, where there is no transport).
    reply_drops: [Option<(usize, u64)>; MAX_FAULTS],
}

fn push(slots: &mut [Option<(usize, u64)>; MAX_FAULTS], entry: (usize, u64)) {
    for s in slots.iter_mut() {
        if s.is_none() {
            *s = Some(entry);
            return;
        }
    }
    panic!("FaultPlan holds at most {MAX_FAULTS} faults per kind");
}

fn hit(slots: &[Option<(usize, u64)>; MAX_FAULTS], idx: usize, tick: u64) -> bool {
    slots.iter().flatten().any(|&(i, t)| i == idx && t == tick)
}

fn first_in(
    slots: &[Option<(usize, u64)>; MAX_FAULTS],
    idx: usize,
    t0: u64,
    t1: u64,
) -> Option<u64> {
    slots
        .iter()
        .flatten()
        .filter(|&&(i, t)| i == idx && t0 <= t && t < t1)
        .map(|&(_, t)| t)
        .min()
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Schedule a panic in shard `shard`'s job at tick `tick`.
    pub fn panic_shard(mut self, shard: usize, tick: u64) -> Self {
        push(&mut self.panics, (shard, tick));
        self
    }

    /// Schedule rail saturation of molecule `molecule` at tick `tick`.
    pub fn saturate_molecule(mut self, molecule: usize, tick: u64) -> Self {
        push(&mut self.sats, (molecule, tick));
        self
    }

    /// Schedule the loss of shard `shard`'s reply channel at tick
    /// `tick` (threaded backend only).
    pub fn drop_reply(mut self, shard: usize, tick: u64) -> Self {
        push(&mut self.reply_drops, (shard, tick));
        self
    }

    /// Does the plan panic `shard` at `tick`?
    pub fn panics_at(&self, shard: usize, tick: u64) -> bool {
        hit(&self.panics, shard, tick)
    }

    /// Does the plan saturate `molecule` at `tick`?
    pub fn saturates_at(&self, molecule: usize, tick: u64) -> bool {
        hit(&self.sats, molecule, tick)
    }

    /// Does the plan drop `shard`'s reply at `tick`?
    pub fn drops_reply_at(&self, shard: usize, tick: u64) -> bool {
        hit(&self.reply_drops, shard, tick)
    }

    /// Earliest scheduled panic of `shard` in the tick window
    /// `[t0, t1)` — the epoch driver's view of the schedule: one shard
    /// job now covers a whole window of ticks, and the supervisor needs
    /// to know which fault fires *first* inside it.
    pub fn first_panic_in(&self, shard: usize, t0: u64, t1: u64) -> Option<u64> {
        first_in(&self.panics, shard, t0, t1)
    }

    /// Earliest scheduled reply drop of `shard` in the tick window
    /// `[t0, t1)`.
    pub fn first_reply_drop_in(&self, shard: usize, t0: u64, t1: u64) -> Option<u64> {
        first_in(&self.reply_drops, shard, t0, t1)
    }

    /// Seeded chaos plan: one shard panic and one molecule saturation at
    /// pseudorandom (but fully seed-determined) coordinates within the
    /// given farm shape. Two calls with the same arguments build the
    /// same plan.
    pub fn random(seed: u64, n_shards: usize, n_molecules: usize, ticks: u64) -> FaultPlan {
        assert!(n_shards > 0 && n_molecules > 0 && ticks > 0);
        let mut rng = Pcg::new(seed);
        let shard = rng.below(n_shards as u32) as usize;
        let panic_tick = rng.below(ticks as u32) as u64;
        let molecule = rng.below(n_molecules as u32) as usize;
        let sat_tick = rng.below(ticks as u32) as u64;
        FaultPlan::new()
            .panic_shard(shard, panic_tick)
            .saturate_molecule(molecule, sat_tick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_schedules_and_queries_faults() {
        let plan = FaultPlan::new()
            .panic_shard(2, 10)
            .saturate_molecule(5, 4)
            .drop_reply(1, 7);
        assert!(plan.panics_at(2, 10));
        assert!(!plan.panics_at(2, 11));
        assert!(!plan.panics_at(1, 10));
        assert!(plan.saturates_at(5, 4));
        assert!(!plan.saturates_at(4, 5));
        assert!(plan.drops_reply_at(1, 7));
        assert!(!plan.drops_reply_at(7, 1));
        // An empty plan injects nothing anywhere.
        let none = FaultPlan::default();
        assert!(!none.panics_at(0, 0) && !none.saturates_at(0, 0) && !none.drops_reply_at(0, 0));
    }

    #[test]
    fn random_plans_are_seed_deterministic_and_in_range() {
        let a = FaultPlan::random(0xFA11, 5, 40, 100);
        let b = FaultPlan::random(0xFA11, 5, 40, 100);
        assert_eq!(a, b);
        let hits: Vec<_> = (0..5)
            .flat_map(|s| (0..100).map(move |t| (s, t)))
            .filter(|&(s, t)| a.panics_at(s, t))
            .collect();
        assert_eq!(hits.len(), 1);
        assert_ne!(a, FaultPlan::random(0xFA12, 5, 40, 100));
    }

    #[test]
    fn window_queries_find_the_first_fault_in_range() {
        let plan = FaultPlan::new()
            .panic_shard(1, 12)
            .panic_shard(1, 5)
            .panic_shard(2, 3)
            .drop_reply(1, 9);
        // Earliest in-window hit wins, bounds are [t0, t1).
        assert_eq!(plan.first_panic_in(1, 0, 64), Some(5));
        assert_eq!(plan.first_panic_in(1, 6, 64), Some(12));
        assert_eq!(plan.first_panic_in(1, 6, 12), None);
        assert_eq!(plan.first_panic_in(1, 5, 6), Some(5));
        assert_eq!(plan.first_panic_in(0, 0, 64), None);
        assert_eq!(plan.first_reply_drop_in(1, 0, 64), Some(9));
        assert_eq!(plan.first_reply_drop_in(1, 10, 64), None);
        assert_eq!(plan.first_reply_drop_in(2, 0, 64), None);
    }

    #[test]
    #[should_panic(expected = "at most")]
    fn plan_overflow_panics() {
        let mut plan = FaultPlan::new();
        for i in 0..=MAX_FAULTS {
            plan = plan.panic_shard(i, 0);
        }
    }
}
