//! Power-of-two weight quantization — the paper's multiplication-less NN
//! core (§III-C, Eqs. (5)–(11)).
//!
//! A float weight `w` is represented as `w_q = s · Σ_{k=1..K} 2^{n_k}`
//! (Eq. 9): a sign plus at most `K` integer powers of two, chosen by the
//! greedy residual recursion of Eq. (7) over the basis function
//! `Q(w) = 2^⌈log₂(|w|/1.5)⌉` (Eq. 8). Multiplication by such a weight is
//! then a base-2 **shift–sum** (Eq. 10) using the shift function `P(x,n)`
//! (Eq. 11) — no multiplier in the datapath.
//!
//! Each greedy step lands within ±33% of its residual (the 1.5 divisor
//! centers the ceiling), so after `m` *active* terms the error is at most
//! `|w|·3⁻ᵐ` — when a step overshoots, Eq. (7)'s `max(·, 0)` clips the
//! residual and the recursion stops early with that step's error. A
//! property test asserts `|w − w_q| ≤ |w|·3^{−terms}` and monotone
//! non-increasing error in K.
//!
//! Core/host seam: [`ShiftWeight`] and its integer shift-apply are core
//! (the stored format and the datapath); the float→shift quantizer and
//! the dequantized float views are host-only (`std`) — quantization is
//! host initialization work, never on-device.

use alloc::vec::Vec;

use crate::fixedpoint::shift_raw;

/// Hardware range of stored shift exponents. The SU barrel shifter width
/// in `hw::synth` is derived from this (5-bit two's-complement exponent
/// field → shifts in [−16, 15]).
pub const EXP_MIN: i32 = -16;
pub const EXP_MAX: i32 = 15;

/// A weight quantized as a sign and up to K powers of two (Eq. 9).
///
/// `exps` holds the active exponents `n_k`, largest first; terms whose
/// greedy residual reached exactly zero are absent (the corresponding SU
/// is disabled in hardware, its output gated to 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShiftWeight {
    /// −1, 0, +1 (Eq. 6); 0 only for w = 0.
    pub sign: i8,
    /// Active exponents, at most K of them.
    pub exps: Vec<i32>,
}

impl ShiftWeight {
    pub fn zero() -> Self {
        ShiftWeight { sign: 0, exps: Vec::new() }
    }

    /// Reconstructed float value `s·Σ 2^{n_k}` (host side).
    #[cfg(feature = "std")]
    pub fn value(&self) -> f64 {
        let mag: f64 = self.exps.iter().map(|&n| (2f64).powi(n)).sum();
        self.sign as f64 * mag
    }

    /// Apply to a raw fixed-point input: `w_q · x` as shift-accumulate
    /// (Eq. 10). Shifts truncate like the RTL (`P` of Eq. 11); the sum is
    /// in a wide accumulator, sign applied last (the MU's symbol
    /// selector).
    pub fn apply_raw(&self, x_raw: i64) -> i64 {
        if self.sign == 0 {
            return 0;
        }
        let mut acc: i64 = 0;
        for &n in &self.exps {
            acc += shift_raw(x_raw, n);
        }
        if self.sign < 0 {
            -acc
        } else {
            acc
        }
    }

    /// Number of hardware shift terms in use.
    pub fn terms(&self) -> usize {
        self.exps.len()
    }
}

/// The basis function Q(w) of Eq. (8): the power of two with exponent
/// ⌈log₂(|w|/1.5)⌉, returned as that exponent. `w` must be > 0.
#[cfg(feature = "std")]
pub fn basis_exponent(w: f64) -> i32 {
    debug_assert!(w > 0.0);
    let y = w / 1.5;
    let mut n = y.log2().ceil() as i32;
    // Guard against f64 log rounding at exact powers of two.
    while (2f64).powi(n - 1) >= y {
        n -= 1;
    }
    while (2f64).powi(n) < y {
        n += 1;
    }
    n
}

/// Quantize a float weight with at most `k` power-of-two terms
/// (Eqs. 5–8). Exponents are clamped to the hardware range
/// [`EXP_MIN`, `EXP_MAX`]; residuals below 2^EXP_MIN are dropped.
#[cfg(feature = "std")]
pub fn quantize_weight(w: f64, k: usize) -> ShiftWeight {
    if w == 0.0 || !w.is_finite() {
        return ShiftWeight::zero();
    }
    let sign: i8 = if w > 0.0 { 1 } else { -1 };
    let mut residual = w.abs();
    let mut exps = Vec::with_capacity(k);
    for _ in 0..k {
        if residual <= (2f64).powi(EXP_MIN - 1) {
            break; // below hardware resolution
        }
        let n = basis_exponent(residual).clamp(EXP_MIN, EXP_MAX);
        exps.push(n);
        let q = (2f64).powi(n);
        residual = (residual - q).max(0.0); // Eq. 7's max(·, 0)
        if residual == 0.0 {
            break;
        }
    }
    ShiftWeight { sign, exps }
}

/// Quantize a full weight matrix (row-major `rows × cols`).
#[cfg(feature = "std")]
pub fn quantize_matrix(w: &[f64], k: usize) -> Vec<ShiftWeight> {
    w.iter().map(|&x| quantize_weight(x, k)).collect()
}

/// Dequantized float view of a quantized matrix (for QAT equivalence and
/// the L2 kernel, which reconstructs `w_q` rather than shifting).
#[cfg(feature = "std")]
pub fn dequantize(ws: &[ShiftWeight]) -> Vec<f64> {
    ws.iter().map(|w| w.value()).collect()
}

/// Worst-case relative quantization error bound after `m` *active*
/// terms: 3⁻ᵐ. (Overshoot at step m clips the residual to zero with an
/// error ≤ residual/3 ≤ |w|·3⁻ᵐ; undershoot continues with residual
/// ≤ |w|·3⁻ᵐ.)
#[cfg(feature = "std")]
pub fn error_bound(m: usize) -> f64 {
    (3f64).powi(-(m as i32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn basis_exponent_examples() {
        // Eq. 8: Q(1.0) = 2^⌈log2(1/1.5)⌉ = 2^0 = 1.
        assert_eq!(basis_exponent(1.0), 0);
        // Q(1.5) = 2^⌈log2(1)⌉ = 1 → exponent 0.
        assert_eq!(basis_exponent(1.5), 0);
        // Q(1.6): log2(1.0667) ≈ 0.093 → ceil 1 → exponent 1 (value 2).
        assert_eq!(basis_exponent(1.6), 1);
        // Q(0.75): log2(0.5) = −1 exactly → exponent −1 (value 0.5).
        assert_eq!(basis_exponent(0.75), -1);
        // exact powers of two: Q(2^m) = 2^m (since 2^m/1.5 → ceil lands on m)
        for m in -10..10 {
            let w = (2f64).powi(m);
            assert_eq!(basis_exponent(w), m, "w=2^{m}");
        }
    }

    #[test]
    fn basis_within_33_percent() {
        // Q(w) ∈ [w/1.5, 2w/1.5): the residual |w − Q(w)| ≤ w/3.
        let mut rng = Pcg::new(5);
        for _ in 0..10_000 {
            let w = rng.range(1e-4, 4.0);
            let q = (2f64).powi(basis_exponent(w));
            assert!(q >= w / 1.5 - 1e-12 && q < 2.0 * w / 1.5 + 1e-12, "w={w} q={q}");
            assert!((w - q).abs() <= w / 3.0 + 1e-12, "w={w} q={q}");
        }
    }

    #[test]
    fn error_shrinks_geometrically_with_k() {
        let mut rng = Pcg::new(17);
        for _ in 0..2_000 {
            let w = rng.range(-2.0, 2.0);
            if w.abs() < 1e-3 {
                continue;
            }
            let mut prev = f64::INFINITY;
            for k in 1..=5 {
                let q = quantize_weight(w, k);
                let err = (q.value() - w).abs();
                assert!(
                    err <= w.abs() * error_bound(q.terms()) + 1e-12,
                    "w={w} k={k} terms={} err={err}",
                    q.terms()
                );
                assert!(err <= prev + 1e-15, "error must be monotone in K");
                prev = err;
            }
        }
    }

    #[test]
    fn sign_function_eq6() {
        assert_eq!(quantize_weight(0.7, 3).sign, 1);
        assert_eq!(quantize_weight(-0.7, 3).sign, -1);
        assert_eq!(quantize_weight(0.0, 3).sign, 0);
        assert_eq!(quantize_weight(0.0, 3).value(), 0.0);
    }

    #[test]
    fn apply_raw_equals_value_times_input_when_no_truncation() {
        // With non-negative exponents, shifts are exact.
        let w = ShiftWeight { sign: -1, exps: vec![2, 0] }; // −5
        assert_eq!(w.value(), -5.0);
        assert_eq!(w.apply_raw(7), -35);
    }

    #[test]
    fn apply_raw_truncation_matches_p_function() {
        // exponent −2 on raw 7 → 7>>2 = 1 (truncated), then sign.
        let w = ShiftWeight { sign: 1, exps: vec![-2] };
        assert_eq!(w.apply_raw(7), 1);
        let wn = ShiftWeight { sign: -1, exps: vec![-2] };
        assert_eq!(wn.apply_raw(7), -1);
        // negative input: arithmetic shift −7>>2 = −2.
        assert_eq!(w.apply_raw(-7), -2);
    }

    #[test]
    fn shift_apply_close_to_float_product() {
        let mut rng = Pcg::new(31);
        let frac = 10u32;
        for _ in 0..5_000 {
            let wv = rng.range(-2.0, 2.0);
            let xv = rng.range(-3.9, 3.9);
            let q = quantize_weight(wv, 3);
            let x_raw = (xv * (1 << frac) as f64).round() as i64;
            let got = q.apply_raw(x_raw) as f64 / (1 << frac) as f64;
            let ideal = q.value() * (x_raw as f64 / (1 << frac) as f64);
            // truncation loses at most 1 LSB per active term
            let tol = q.terms() as f64 / (1 << frac) as f64 + 1e-12;
            assert!((got - ideal).abs() <= tol, "w={wv} x={xv} got={got} ideal={ideal}");
        }
    }

    #[test]
    fn at_most_k_terms_and_descending() {
        let mut rng = Pcg::new(77);
        for _ in 0..2_000 {
            let w = rng.range(-4.0, 4.0);
            for k in 1..=5 {
                let q = quantize_weight(w, k);
                assert!(q.terms() <= k);
                for pair in q.exps.windows(2) {
                    assert!(pair[0] >= pair[1], "exponents should be non-increasing: {:?}", q.exps);
                }
                for &e in &q.exps {
                    assert!((EXP_MIN..=EXP_MAX).contains(&e));
                }
            }
        }
    }

    #[test]
    fn tiny_weights_flush_to_zero() {
        let q = quantize_weight(1e-9, 3);
        assert_eq!(q.value(), 0.0);
        assert_eq!(q.apply_raw(1000), 0);
    }

    #[test]
    fn matrix_quantize_roundtrip() {
        let w = vec![0.5, -1.25, 0.0, 0.3];
        let q = quantize_matrix(&w, 3);
        let d = dequantize(&q);
        for ((orig, deq), qw) in w.iter().zip(&d).zip(&q) {
            assert!((orig - deq).abs() <= orig.abs() * error_bound(qw.terms()) + 1e-12);
        }
        assert_eq!(d[2], 0.0);
        // 0.5 and −1.25 are exact sums of ≤3 powers of two
        assert_eq!(d[0], 0.5);
        assert_eq!(d[1], -1.25);
    }
}
