//! `nvnmd gen-data` — the build-time dataset generator (consumed by the
//! Python trainer) plus the cross-language quantizer parity vectors.

use anyhow::Result;

use crate::datasets;
use crate::quant;
use crate::util::json::{self, Value};

pub fn run(out_dir: &str, quick: bool) -> Result<()> {
    let out = std::path::Path::new(out_dir);
    std::fs::create_dir_all(out)?;
    for mut spec in datasets::all_specs() {
        if quick {
            spec.n_configs = (spec.n_configs / 10).max(8);
        }
        let t0 = std::time::Instant::now();
        let ds = match spec.name {
            "water" => datasets::water_dataset(&spec),
            "silicon" => datasets::silicon_dataset(&spec),
            name => {
                let mol = match name {
                    "ethanol" => crate::potentials::ff::ethanol(),
                    "toluene" => crate::potentials::ff::toluene(),
                    "naphthalene" => crate::potentials::ff::naphthalene(),
                    "aspirin" => crate::potentials::ff::aspirin(),
                    other => anyhow::bail!("unknown system {other}"),
                };
                datasets::molecule_dataset(&spec, mol)
            }
        };
        let path = out.join(format!("{}.json", spec.name));
        ds.save(&path)?;
        println!(
            "  {}: {} train / {} test rows ({} features) in {:.1}s → {}",
            spec.name,
            ds.n_train(),
            ds.n_test(),
            ds.feature_dim,
            t0.elapsed().as_secs_f64(),
            path.display()
        );
    }
    write_quant_vectors(out.parent().unwrap_or(out))?;
    Ok(())
}

/// Deterministic quantizer test vectors for the Python parity test.
fn write_quant_vectors(dir: &std::path::Path) -> Result<()> {
    let mut vectors = Vec::new();
    let mut w = 1e-4f64;
    while w < 4.0 {
        for k in 1..=5usize {
            for sign in [1.0, -1.0] {
                let q = quant::quantize_weight(sign * w, k);
                vectors.push(json::obj(vec![
                    ("w", json::num(sign * w)),
                    ("k", json::num(k as f64)),
                    ("sign", json::num(q.sign as f64)),
                    ("exps", json::arr_i32(&q.exps)),
                    ("value", json::num(q.value())),
                ]));
            }
        }
        w *= 1.37;
    }
    let doc = json::obj(vec![
        ("note", json::s("rust quant::quantize_weight outputs; python must match exactly")),
        ("vectors", Value::Arr(vectors)),
    ]);
    let path = dir.join("quant_vectors.json");
    json::write_file(&path, &doc)?;
    println!("  quant vectors → {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_generation_writes_all_artifacts() {
        let dir = std::env::temp_dir().join("nvnmd_gen_data_test");
        let _ = std::fs::remove_dir_all(&dir);
        run(dir.join("datasets").to_str().unwrap(), true).unwrap();
        for name in ["water", "ethanol", "toluene", "naphthalene", "aspirin", "silicon"] {
            let p = dir.join("datasets").join(format!("{name}.json"));
            assert!(p.exists(), "{p:?}");
        }
        assert!(dir.join("quant_vectors.json").exists());
        // parse one back
        let ds = crate::datasets::Dataset::load(&dir.join("datasets/ethanol.json")).unwrap();
        assert_eq!(ds.feature_dim, 32);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
