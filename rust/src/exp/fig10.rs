//! E8 — paper Fig. 10: normalized vibrational DOS of the three water
//! modes (symmetric stretch, asymmetric stretch, bend) for all four
//! methods, written as CSV series (wavenumber, power per method).

use anyhow::Result;

use crate::analysis::spectrum::{mode_spectrum, Dos};
use crate::util::json::{self, Value};

use super::water_md;
use super::{load_model, Report};

const MODES: [&str; 3] = ["symmetric_stretch", "asymmetric_stretch", "bending"];

fn spectra(series: &crate::analysis::WaterSeries, dt: f64) -> [Dos; 3] {
    let [sym, asym, bend] = series.mode_signals();
    [
        mode_spectrum(&sym, dt),
        mode_spectrum(&asym, dt),
        mode_spectrum(&bend, dt),
    ]
}

pub fn run(quick: bool) -> Result<Report> {
    let mut report = Report::new("Fig. 10 — vibrational DOS, three modes × four methods");
    let steps = if quick { 8_000 } else { 48_000 };
    let dt = 0.25;
    let seed = 42;

    let (s_dft, p_dft) = water_md::run_dft(steps, dt, seed);
    let (vn_model, _) = water_md::vn_model("water_mlp.hlo.txt", "water_qnn_k3")?;
    let (s_vn, p_vn) = water_md::run_vn(vn_model, steps, dt, seed)?;
    let model = load_model("water_qnn_k3")?;
    let (s_nvn, p_nvn, _) = water_md::run_nvn(&model, model.quant_k.max(3), steps, dt, seed, false)?;
    let (dp_model, _) = water_md::vn_model("water_deepmd.hlo.txt", "water_deepmd_like")?;
    let (s_dp, p_dp) = water_md::run_vn(dp_model, steps, dt, seed)?;

    let all = [
        ("dft", spectra(&s_dft, dt)),
        ("vn_mlmd", spectra(&s_vn, dt)),
        ("nvn_mlmd", spectra(&s_nvn, dt)),
        ("deepmd_like", spectra(&s_dp, dt)),
    ];

    // One CSV per mode: wavenumber, then a power column per method,
    // restricted to the mode's band.
    for (mi, mode) in MODES.iter().enumerate() {
        let band = if mi == 2 { water_md::BEND_BAND } else { water_md::STRETCH_BAND };
        let windows: Vec<Dos> = all.iter().map(|(_n, sp)| sp[mi].window(band.0, band.1)).collect();
        let n = windows.iter().map(|d| d.wavenumber.len()).min().unwrap_or(0);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let mut row = vec![windows[0].wavenumber[i]];
                row.extend(windows.iter().map(|d| d.power[i]));
                row
            })
            .collect();
        report.save_csv(
            &format!("fig10_{mode}"),
            "wavenumber_cm1,dft,vn_mlmd,nvn_mlmd,deepmd_like",
            &rows,
        )?;
    }

    // Peak table like the visual peaks of Fig. 10.
    let peak_rows: Vec<Vec<String>> = [
        ("DFT", p_dft),
        ("vN-MLMD", p_vn),
        ("NvN-MLMD", p_nvn),
        ("DeePMD-like", p_dp),
    ]
    .iter()
    .map(|(n, p)| {
        vec![
            n.to_string(),
            format!("{:.0}", p.nu_sym),
            format!("{:.0}", p.nu_asym),
            format!("{:.0}", p.nu_bend),
        ]
    })
    .collect();
    report.table(
        "DOS peak locations (cm⁻¹)",
        &["method", "sym", "asym", "bend"],
        &peak_rows,
    );
    report.attach(
        "peaks",
        Value::Arr(
            [("dft", p_dft), ("vn", p_vn), ("nvn", p_nvn), ("deepmd", p_dp)]
                .iter()
                .map(|(n, p)| {
                    json::obj(vec![
                        ("method", json::s(n)),
                        ("sym", json::num(p.nu_sym)),
                        ("asym", json::num(p.nu_asym)),
                        ("bend", json::num(p.nu_bend)),
                    ])
                })
                .collect(),
        ),
    );
    report.save("fig10")?;
    Ok(report)
}
