//! Shared water-MD property machinery for Table II / Fig. 10: run each
//! method's trajectory from identical initial conditions, extract the
//! structural series, and measure bond length, angle, and the three
//! vibration peaks.

use anyhow::Result;

use crate::analysis::{self, WaterSeries};
use crate::coordinator::vn::{HForceModel, MlpForceModel, VnMlmd};
use crate::coordinator::{ParallelMode, WaterSystem};
use crate::md::{initialize_velocities, Engine, System};
use crate::potentials::WaterPes;
use crate::runtime::{HloForceModel, Runtime};
use crate::util::rng::Pcg;

/// Frequency bands (cm⁻¹) used to isolate each mode's peak.
pub const BEND_BAND: (f64, f64) = (800.0, 2800.0);
pub const STRETCH_BAND: (f64, f64) = (3000.0, 5200.0);

/// Measurement-protocol thermostat: direct-force MLPs are not exactly
/// conservative (the paper's architecture predicts F, not −∇E), so long
/// property runs heat from model/quantization noise. All four methods
/// use the *same* weak Berendsen coupling (τ = 1 ps at dt = 0.25 fs —
/// far above every vibration period, so spectra are unaffected).
pub const PROTOCOL_T: f64 = 300.0;
pub const PROTOCOL_DT_OVER_TAU: f64 = 0.25 / 1000.0;

/// Measured properties of one method's trajectory.
#[derive(Debug, Clone, Copy)]
pub struct WaterProperties {
    pub bond_length: f64,
    pub angle_deg: f64,
    pub nu_sym: f64,
    pub nu_asym: f64,
    pub nu_bend: f64,
}

impl WaterProperties {
    pub fn from_series(series: &WaterSeries, dt_fs: f64) -> Self {
        let [sym, asym, bend] = series.mode_signals();
        WaterProperties {
            bond_length: series.mean_bond_length(),
            angle_deg: series.mean_angle(),
            nu_sym: analysis::spectrum::peak_wavenumber(&sym, dt_fs, STRETCH_BAND),
            nu_asym: analysis::spectrum::peak_wavenumber(&asym, dt_fs, STRETCH_BAND),
            nu_bend: analysis::spectrum::peak_wavenumber(&bend, dt_fs, BEND_BAND),
        }
    }

    /// Max relative error vs a reference (the paper's Error formula,
    /// applied per property).
    pub fn errors_vs(&self, r: &WaterProperties) -> [f64; 5] {
        [
            analysis::relative_error(self.bond_length, r.bond_length),
            analysis::relative_error(self.angle_deg, r.angle_deg),
            analysis::relative_error(self.nu_sym, r.nu_sym),
            analysis::relative_error(self.nu_asym, r.nu_asym),
            analysis::relative_error(self.nu_bend, r.nu_bend),
        ]
    }
}

/// The standard initial condition shared by every method: equilibrium
/// geometry, Maxwell–Boltzmann velocities at 300 K, fixed seed.
pub fn initial_condition(seed: u64) -> System {
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    let mut rng = Pcg::new(seed);
    initialize_velocities(&mut sys, 300.0, 6, &mut rng);
    sys
}

/// Reference ("DFT") trajectory: velocity Verlet on the surrogate PES,
/// same weak-coupling protocol as the MLMD methods.
pub fn run_dft(steps: usize, dt: f64, seed: u64) -> (WaterSeries, WaterProperties) {
    let pes = WaterPes::dft_surrogate();
    let sys = initial_condition(seed);
    let mut eng = Engine::new(sys, pes, dt);
    let mut series = WaterSeries::default();
    for _ in 0..steps {
        eng.step_verlet();
        crate::md::berendsen_rescale(&mut eng.sys, PROTOCOL_T, 6, PROTOCOL_DT_OVER_TAU);
        series.push(&eng.sys.pos);
    }
    let props = WaterProperties::from_series(&series, dt);
    (series, props)
}

/// vN-MLMD trajectory through any [`HForceModel`].
pub fn run_vn<M: HForceModel>(
    model: M,
    steps: usize,
    dt: f64,
    seed: u64,
) -> Result<(WaterSeries, WaterProperties)> {
    let sys = initial_condition(seed);
    let mut driver = VnMlmd::new(sys, model, dt);
    let mut series = WaterSeries::default();
    for _ in 0..steps {
        driver.step()?;
        crate::md::berendsen_rescale(&mut driver.sys, PROTOCOL_T, 6, PROTOCOL_DT_OVER_TAU);
        series.push(&driver.sys.pos);
    }
    let props = WaterProperties::from_series(&series, dt);
    Ok((series, props))
}

/// NvN-MLMD trajectory through the heterogeneous system (control-plane
/// thermostat, same coupling as the other methods).
pub fn run_nvn(
    model: &crate::nn::Mlp,
    k: usize,
    steps: usize,
    dt: f64,
    seed: u64,
    strict13: bool,
) -> Result<(WaterSeries, WaterProperties, crate::coordinator::Ledger)> {
    let sys = initial_condition(seed);
    let mut ws = WaterSystem::new(model, k, &sys, dt, ParallelMode::Inline)?;
    ws.fpga.strict13 = strict13;
    ws.thermostat = Some((PROTOCOL_T, PROTOCOL_DT_OVER_TAU));
    let mut series = WaterSeries::default();
    for _ in 0..steps {
        ws.step()?;
        series.push(&ws.positions());
    }
    let props = WaterProperties::from_series(&series, dt);
    let ledger = ws.finish()?;
    Ok((series, props, ledger))
}

/// Build the vN force model for a given model stem: prefer the AOT/PJRT
/// artifact (`<stem>.hlo.txt` name passed in), fall back to the
/// in-process float model with a notice.
///
/// The PJRT path is **validated before use**: the artifact's outputs are
/// compared against the in-process float model on reference inputs, and
/// the runtime falls back when they disagree. (Known defect: the crate's
/// xla_extension 0.5.1 mis-executes some lowered graphs — observed on
/// the tanh/60-wide DeePMD artifact and the exp2-reconstruction shift
/// artifact — while the production water_mlp/md_step artifacts verify
/// clean. See EXPERIMENTS.md §Runtime-notes.)
pub fn vn_model(hlo_name: &str, model_stem: &str) -> Result<(Box<dyn HForceModel>, bool)> {
    let float_model = super::load_model(model_stem)?;
    let hlo = crate::artifact_path(hlo_name);
    if hlo.exists() {
        if let Ok(rt) = Runtime::cpu() {
            if let Ok(mut m) = HloForceModel::load(&rt, &hlo) {
                // cross-validate on reference inputs
                let probes = [
                    [[1.03f64, 0.65, 1.03], [0.98, 0.70, 1.01]],
                    [[1.01, 0.66, 1.05], [1.04, 0.63, 1.00]],
                ];
                let mut ok = true;
                for p in &probes {
                    let got = m.eval(p)?;
                    let want = [
                        float_model.forward_physical(&p[0]),
                        float_model.forward_physical(&p[1]),
                    ];
                    for (g, w) in got.iter().flatten().zip(want.iter().flatten()) {
                        if (g - w).abs() > 1e-3 * (1.0 + w.abs()) {
                            ok = false;
                        }
                    }
                }
                if ok {
                    return Ok((Box::new(m), true));
                }
                eprintln!(
                    "warning: {hlo_name} fails cross-validation against the float \
                     model (xla_extension 0.5.1 defect) — using in-process path"
                );
            }
        }
    }
    Ok((Box::new(MlpForceModel { model: float_model }), false))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dft_reference_reproduces_paper_column() {
        // Moderate run: the VACF peaks must land near the calibrated
        // normal-mode targets (finite-T anharmonicity shifts them only
        // slightly at 300 K).
        let (_s, p) = run_dft(24_000, 0.25, 42);
        assert!((p.bond_length - 0.969).abs() < 0.01, "bond {}", p.bond_length);
        assert!((p.angle_deg - 104.88).abs() < 2.0, "angle {}", p.angle_deg);
        assert!((p.nu_bend - 1603.0).abs() < 80.0, "bend {}", p.nu_bend);
        assert!((p.nu_sym - 4007.0).abs() < 120.0, "sym {}", p.nu_sym);
        assert!((p.nu_asym - 4241.0).abs() < 120.0, "asym {}", p.nu_asym);
        // mode ordering preserved
        assert!(p.nu_bend < p.nu_sym && p.nu_sym < p.nu_asym);
    }

    #[test]
    fn properties_error_helper() {
        let a = WaterProperties { bond_length: 0.968, angle_deg: 104.90, nu_sym: 4040.0, nu_asym: 4291.0, nu_bend: 1619.0 };
        let d = WaterProperties { bond_length: 0.969, angle_deg: 104.88, nu_sym: 4007.0, nu_asym: 4241.0, nu_bend: 1603.0 };
        let e = a.errors_vs(&d);
        // paper Error¹ row: 0.10%, 0.02%, 0.82%, 1.18%, 1.00%
        assert!((e[0] * 100.0 - 0.10).abs() < 0.02);
        assert!((e[1] * 100.0 - 0.02).abs() < 0.01);
        assert!((e[2] * 100.0 - 0.82).abs() < 0.02);
        assert!((e[3] * 100.0 - 1.18).abs() < 0.02);
        assert!((e[4] * 100.0 - 1.00).abs() < 0.02);
    }

    #[test]
    fn same_seed_same_initial_condition() {
        let a = initial_condition(7);
        let b = initial_condition(7);
        assert_eq!(a.vel[1], b.vel[1]);
        let c = initial_condition(8);
        assert_ne!(a.vel[1], c.vel[1]);
    }
}
