//! `nvnmd info` — artifact inventory and environment check.

use anyhow::Result;

use super::Report;

pub fn run() -> Result<Report> {
    let mut report = Report::new("environment & artifact inventory");

    match crate::runtime::Runtime::cpu() {
        Ok(rt) => report.note(format!("PJRT: ok (platform {})", rt.platform())),
        Err(e) => report.note(format!("PJRT: UNAVAILABLE — {e}")),
    };

    let mut rows = Vec::new();
    for (kind, rel) in [
        ("dataset", "datasets/water.json"),
        ("dataset", "datasets/ethanol.json"),
        ("dataset", "datasets/toluene.json"),
        ("dataset", "datasets/naphthalene.json"),
        ("dataset", "datasets/aspirin.json"),
        ("dataset", "datasets/silicon.json"),
        ("quant vectors", "quant_vectors.json"),
        ("model", "models/water_cnn_phi.json"),
        ("model", "models/water_cnn_tanh.json"),
        ("model", "models/water_qnn_k3.json"),
        ("model", "models/water_deepmd_like.json"),
        ("model metrics", "models/metrics.json"),
        ("HLO", "water_mlp.hlo.txt"),
        ("HLO", "water_mlp_cnn.hlo.txt"),
        ("HLO", "water_md_step.hlo.txt"),
        ("HLO", "water_deepmd.hlo.txt"),
        ("HLO", "water_mlp_shiftkernel.hlo.txt"),
    ] {
        let p = crate::artifact_path(rel);
        let status = if p.exists() {
            let bytes = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            format!("ok ({bytes} B)")
        } else {
            "MISSING (run `make artifacts`)".into()
        };
        rows.push(vec![kind.to_string(), rel.to_string(), status]);
    }
    report.table("artifacts", &["kind", "path", "status"], &rows);
    Ok(report)
}
