//! Experiment implementations (E1–E10 of DESIGN.md) shared by the CLI
//! (`nvnmd <cmd>`) and the bench targets (`cargo bench`). Each module
//! returns a [`Report`] — rendered tables/notes plus a JSON artifact
//! under `artifacts/report/`.

pub mod gen_data;
pub mod fig3;
pub mod table1;
pub mod fig4;
pub mod fig5;
pub mod fig9;
pub mod table2;
pub mod fig10;
pub mod table3;
pub mod scaling;
pub mod run_md;
pub mod info;
pub mod water_md;

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::nn::Mlp;
use crate::util::json::{self, Value};
use crate::util::table;

/// A rendered experiment result.
pub struct Report {
    pub title: String,
    body: String,
    data: Vec<(String, Value)>,
    pub saved_to: Option<PathBuf>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), body: String::new(), data: Vec::new(), saved_to: None }
    }

    pub fn table(&mut self, caption: &str, headers: &[&str], rows: &[Vec<String>]) -> &mut Self {
        self.body.push_str(&format!("\n{caption}\n"));
        self.body.push_str(&table::render(headers, rows));
        self
    }

    pub fn note(&mut self, text: impl std::fmt::Display) -> &mut Self {
        self.body.push_str(&format!("  • {text}\n"));
        self
    }

    pub fn attach(&mut self, key: &str, v: Value) -> &mut Self {
        self.data.push((key.to_string(), v));
        self
    }

    /// Persist the JSON artifact under `artifacts/report/<slug>.json`.
    pub fn save(&mut self, slug: &str) -> Result<()> {
        let mut fields = vec![("title".to_string(), json::s(&self.title))];
        fields.extend(self.data.iter().cloned());
        let path = crate::artifact_path("report").join(format!("{slug}.json"));
        json::write_file(&path, &Value::Obj(fields))?;
        self.saved_to = Some(path);
        Ok(())
    }

    /// Also write a CSV next to the JSON (for figures).
    pub fn save_csv(&mut self, slug: &str, header: &str, rows: &[Vec<f64>]) -> Result<()> {
        let path = crate::artifact_path("report").join(format!("{slug}.csv"));
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut text = String::from(header);
        text.push('\n');
        for row in rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            text.push_str(&cells.join(","));
            text.push('\n');
        }
        std::fs::write(&path, text)?;
        self.note(format!("CSV: {}", path.display()));
        Ok(())
    }

    pub fn render(&self) -> String {
        format!("== {} ==\n{}", self.title, self.body)
    }
}

/// Load a trained model artifact by stem (e.g. `water_qnn_k3`).
pub fn load_model(stem: &str) -> Result<Mlp> {
    let path = crate::artifact_path(&format!("models/{stem}.json"));
    Mlp::load(&path).with_context(|| {
        format!(
            "loading model artifact {} — run `make artifacts` first",
            path.display()
        )
    })
}

/// The water model of the §Perf benches: the trained artifact when
/// present, else a deterministic random fallback. Shared by
/// `hotpath_micro` and `farm_throughput` so their scalar-vs-farm
/// numbers always measure the same network.
pub fn water_model_or_fallback() -> Mlp {
    load_model("water_qnn_k3").unwrap_or_else(|_| {
        let mut rng = crate::util::rng::Pcg::new(7);
        let mut m = Mlp::init_random(
            "fallback",
            &[3, 3, 3, 2],
            crate::nn::Activation::Phi,
            &mut rng,
        );
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.4;
            }
        }
        m
    })
}

/// The serving model of a non-water Table-I molecule for the §Perf
/// benches and the mixed-species farm: the trained `<name>_qnn_k3`
/// artifact when present *and* compatible with the fixed-point serving
/// path (4·n_nb→…→3 shape, power-of-two output scale), else a
/// deterministic random fallback at the spec's architecture. Shared by
/// `farm_throughput` and `exp::scaling` so both measure the same
/// network.
pub fn molecule_model_or_fallback(name: &str) -> Mlp {
    let spec = crate::datasets::spec(name).expect("known Table-I system");
    if let Ok(m) = load_model(&format!("{name}_qnn_k3")) {
        if m.in_dim() == 4 * spec.n_nb && m.out_dim() == 3 && m.force_shift().is_ok() {
            return m;
        }
    }
    let mut rng = crate::util::rng::Pcg::new(40 + spec.seed);
    let mut m = Mlp::init_random(
        &format!("{name}-fallback"),
        &spec.arch,
        crate::nn::Activation::Phi,
        &mut rng,
    );
    for l in &mut m.layers {
        for w in &mut l.w {
            *w *= 0.2;
        }
    }
    m
}

/// Load a dataset artifact by name.
pub fn load_dataset(name: &str) -> Result<crate::datasets::Dataset> {
    let path = crate::artifact_path(&format!("datasets/{name}.json"));
    crate::datasets::Dataset::load(&path).with_context(|| {
        format!(
            "loading dataset artifact {} — run `make artifacts` first",
            path.display()
        )
    })
}

/// All experiments for `nvnmd all`.
#[allow(clippy::type_complexity)]
pub fn all_experiments(quick: bool) -> Vec<(&'static str, Box<dyn FnOnce() -> Result<Report>>)> {
    vec![
        ("fig3a", Box::new(fig3::run_curves) as Box<dyn FnOnce() -> Result<Report>>),
        ("fig3b", Box::new(fig3::run_transistors)),
        ("table1", Box::new(table1::run)),
        ("fig4", Box::new(fig4::run)),
        ("fig5", Box::new(fig5::run)),
        ("fig9", Box::new(fig9::run)),
        ("table2", Box::new(move || table2::run(table2::Config::with_quick(quick)))),
        ("fig10", Box::new(move || fig10::run(quick))),
        ("table3", Box::new(move || table3::run(quick))),
        ("scaling", Box::new(move || scaling::run(quick))),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_tables_and_notes() {
        let mut r = Report::new("demo");
        r.note("hello");
        r.table("cap", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        let text = r.render();
        assert!(text.contains("demo") && text.contains("hello") && text.contains("cap"));
        assert!(text.contains("| 1 | 2 |"));
    }

    #[test]
    fn report_saves_json() {
        let dir = std::env::temp_dir().join("nvnmd_test_report");
        std::env::set_var("NVNMD_ARTIFACTS", &dir);
        let mut r = Report::new("t");
        r.attach("x", json::num(1.5));
        r.save("unit_test_report").unwrap();
        let path = r.saved_to.clone().unwrap();
        let v = json::read_file(&path).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64().unwrap(), 1.5);
        std::env::remove_var("NVNMD_ARTIFACTS");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
