//! `nvnmd run` — drive the water system interactively and print the
//! measured properties + hardware ledger.

use anyhow::{bail, Result};

use crate::hw::timing::CLOCK_HZ;
use crate::util::json::{self, Value};
use crate::util::table::{fix, sci};

use super::water_md::{self, WaterProperties};
use super::{load_model, Report};

pub fn run(mode: &str, steps: usize, dt: f64, strict13: bool) -> Result<Report> {
    let mut report = Report::new(&format!("MD run — mode={mode}, {steps} steps × {dt} fs"));
    let seed = 42;
    let props: WaterProperties;
    match mode {
        "nvn" => {
            let model = load_model("water_qnn_k3")?;
            let t0 = std::time::Instant::now();
            let (_s, p, ledger) =
                water_md::run_nvn(&model, model.quant_k.max(3), steps, dt, seed, strict13)?;
            props = p;
            report.note(format!(
                "host simulation wall: {:.2}s; modelled hardware: {:.2}s @ 25 MHz",
                t0.elapsed().as_secs_f64(),
                ledger.hw_seconds(CLOCK_HZ)
            ));
            report.note(format!(
                "S = {} s/step/atom; chip inferences = {}; strict13 = {strict13}",
                sci(ledger.s_per_step_atom(CLOCK_HZ), 2),
                ledger.chip_inferences
            ));
        }
        "vn" => {
            let (m, used_pjrt) = water_md::vn_model("water_mlp.hlo.txt", "water_qnn_k3")?;
            let t0 = std::time::Instant::now();
            let (_s, p) = water_md::run_vn(m, steps, dt, seed)?;
            props = p;
            report.note(format!(
                "wall: {:.2}s ({} force path)",
                t0.elapsed().as_secs_f64(),
                if used_pjrt { "PJRT" } else { "in-process" }
            ));
        }
        "dft" | "oracle" => {
            let (_s, p) = water_md::run_dft(steps, dt, seed);
            props = p;
        }
        "chip-vs-oracle" => {
            let eval = super::fig9::compute(steps.min(2_000) / 2)?;
            report.note(format!("chip force RMSE = {:.2} meV/Å", eval.rmse_mev));
            report.save("run_chip_vs_oracle")?;
            return Ok(report);
        }
        other => bail!("unknown mode {other:?} (nvn|vn|dft|chip-vs-oracle)"),
    }
    report.table(
        "measured properties",
        &["bond (Å)", "∠HOH (°)", "ν_sym", "ν_asym", "ν_bend"],
        &[vec![
            fix(props.bond_length, 3),
            fix(props.angle_deg, 2),
            fix(props.nu_sym, 0),
            fix(props.nu_asym, 0),
            fix(props.nu_bend, 0),
        ]],
    );
    report.attach(
        "properties",
        json::obj(vec![
            ("bond_A", json::num(props.bond_length)),
            ("angle_deg", json::num(props.angle_deg)),
            ("nu_sym", json::num(props.nu_sym)),
            ("nu_asym", json::num(props.nu_asym)),
            ("nu_bend", json::num(props.nu_bend)),
        ]),
    );
    report.attach("mode", Value::Str(mode.to_string()));
    report.save(&format!("run_{mode}"))?;
    Ok(report)
}
