//! E1/E2 — paper Fig. 3: (a) tanh vs φ curves, (b) transistor counts of
//! the two activation circuits.

use anyhow::Result;

use crate::hw::synth;
use crate::nn::activation::{phi, tanh_cordic};
use crate::util::json::{self, Value};

use super::Report;

/// Fig. 3(a): sampled curves (CSV artifact) + deviation summary.
pub fn run_curves() -> Result<Report> {
    let mut report = Report::new("Fig. 3(a) — tanh(x) vs φ(x)");
    let mut rows = Vec::new();
    let mut max_dev: f64 = 0.0;
    let mut x = -4.0f64;
    while x <= 4.0 + 1e-9 {
        let t = x.tanh();
        let p = phi(x);
        let c = tanh_cordic(x.clamp(-1.1, 1.1), 14, 16);
        rows.push(vec![x, t, p, c]);
        max_dev = max_dev.max((t - p).abs());
        x += 0.02;
    }
    report.save_csv("fig3a_curves", "x,tanh,phi,cordic_tanh_native_range", &rows)?;
    report.note(format!("max |tanh − φ| on [−4,4]: {max_dev:.4} (curves nearly coincide near 0)"));
    report.note("paper: \"tanh(x) and φ(x) are similar at the numerical value\"");
    report.attach("max_deviation", json::num(max_dev));
    report.save("fig3a")?;
    Ok(report)
}

/// Fig. 3(b): transistor counts from the synthesis model.
pub fn run_transistors() -> Result<Report> {
    let mut report = Report::new("Fig. 3(b) — transistor cost of the activation circuits");
    let tanh_net = synth::tanh_cordic_unit(synth::CORDIC_BITS, synth::CORDIC_ITERS);
    let phi_net = synth::phi_unit(synth::Q13_BITS);
    let t_tanh = tanh_net.transistors();
    let t_phi = phi_net.transistors();

    let rows = vec![
        vec![
            "tanh (CORDIC, 16-bit × 14 iter)".to_string(),
            t_tanh.to_string(),
            synth::PAPER_TANH_T.to_string(),
            format!("{:.2}", t_tanh as f64 / synth::PAPER_TANH_T as f64),
        ],
        vec![
            "φ(x) unit (13-bit, Fig. 7 AU)".to_string(),
            t_phi.to_string(),
            synth::PAPER_PHI_T.to_string(),
            format!("{:.2}", t_phi as f64 / synth::PAPER_PHI_T as f64),
        ],
    ];
    report.table(
        "Transistors (measured model vs paper DC report)",
        &["circuit", "measured", "paper", "ratio"],
        &rows,
    );
    report.note(format!(
        "φ/tanh = {:.1}% (paper: 8%)",
        100.0 * t_phi as f64 / t_tanh as f64
    ));
    for (prim, n, t) in phi_net.breakdown() {
        report.note(format!("φ breakdown: {prim:?} ×{n} = {t} T"));
    }
    report.attach(
        "measured",
        json::obj(vec![
            ("tanh", Value::Num(t_tanh as f64)),
            ("phi", Value::Num(t_phi as f64)),
        ]),
    );
    report.save("fig3b")?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_reports_build() {
        let dir = std::env::temp_dir().join("nvnmd_fig3_test");
        std::env::set_var("NVNMD_ARTIFACTS", &dir);
        let a = run_curves().unwrap();
        assert!(a.render().contains("tanh"));
        let b = run_transistors().unwrap();
        assert!(b.render().contains("transistor") || b.render().contains("Transistors"));
        std::env::remove_var("NVNMD_ARTIFACTS");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
