//! E5 — paper Fig. 5: hardware overhead of shift-based SQNN relative to
//! multiplier-based FQNN (16-bit) for K = 1..5 across the six network
//! sizes. Pure synthesis-model experiment (no trained artifacts needed).

use anyhow::Result;

use crate::datasets::all_specs;
use crate::hw::synth::{mlp_netlist, WeightDatapath, FQNN_BITS, Q13_BITS};
use crate::util::json::{self, Value};

use super::Report;

pub struct Row {
    pub system: String,
    pub arch: Vec<usize>,
    pub fqnn_t: u64,
    /// SQNN transistors for K = 1..5.
    pub sqnn_t: [u64; 5],
}

impl Row {
    /// N^s_K / N^m × 100% (the paper's y-axis).
    pub fn ratio_pct(&self) -> [f64; 5] {
        self.sqnn_t.map(|s| 100.0 * s as f64 / self.fqnn_t as f64)
    }
}

pub fn compute() -> Vec<Row> {
    all_specs()
        .iter()
        .map(|spec| {
            let fqnn = mlp_netlist(&spec.arch, FQNN_BITS, WeightDatapath::Multiplier).transistors();
            let mut sqnn = [0u64; 5];
            for k in 1..=5u64 {
                sqnn[(k - 1) as usize] =
                    mlp_netlist(&spec.arch, Q13_BITS, WeightDatapath::Shift { k }).transistors();
            }
            Row { system: spec.name.to_string(), arch: spec.arch.clone(), fqnn_t: fqnn, sqnn_t: sqnn }
        })
        .collect()
}

pub fn run() -> Result<Report> {
    let mut report = Report::new("Fig. 5 — SQNN/FQNN transistor ratio (N^s_K / N^m)");
    let rows = compute();
    let mut table = Vec::new();
    let mut data = Vec::new();
    for r in &rows {
        let pct = r.ratio_pct();
        table.push(vec![
            format!("{} {:?}", r.system, r.arch),
            r.fqnn_t.to_string(),
            format!("{:.0}%", pct[0]),
            format!("{:.0}%", pct[1]),
            format!("{:.0}%", pct[2]),
            format!("{:.0}%", pct[3]),
            format!("{:.0}%", pct[4]),
        ]);
        data.push(json::obj(vec![
            ("system", json::s(&r.system)),
            ("fqnn_t", json::num(r.fqnn_t as f64)),
            (
                "sqnn_t",
                json::arr_f64(&r.sqnn_t.iter().map(|&x| x as f64).collect::<Vec<_>>()),
            ),
        ]));
    }
    report.table(
        "Transistor ratio vs K (FQNN = 16-bit multiplier datapath)",
        &["system (arch)", "FQNN T", "K=1", "K=2", "K=3", "K=4", "K=5"],
        &table,
    );
    // Paper claims: at K=3, 50–70% savings; bigger systems save more;
    // K=4/5 add ~10–20% cost over K=3.
    let k3: Vec<f64> = rows.iter().map(|r| r.ratio_pct()[2]).collect();
    report.note(format!(
        "K=3 ratios: {:?} (paper: ~30–50%, i.e. 50–70% saving)",
        k3.iter().map(|x| format!("{x:.0}%")).collect::<Vec<_>>()
    ));
    let k5_over_k3: Vec<f64> = rows
        .iter()
        .map(|r| 100.0 * (r.sqnn_t[4] as f64 / r.sqnn_t[2] as f64 - 1.0))
        .collect();
    report.note(format!(
        "K=5 over K=3 extra cost: {:?} (paper: ~10–20%)",
        k5_over_k3.iter().map(|x| format!("{x:.0}%")).collect::<Vec<_>>()
    ));
    report.attach("rows", Value::Arr(data));
    let csv: Vec<Vec<f64>> = rows
        .iter()
        .enumerate()
        .flat_map(|(i, r)| {
            let pct = r.ratio_pct();
            (1..=5).map(move |k| vec![i as f64, k as f64, pct[k - 1]])
        })
        .collect();
    report.save_csv("fig5_ratio", "system_index,k,ratio_pct", &csv)?;
    report.save("fig5")?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_claims() {
        let rows = compute();
        assert_eq!(rows.len(), 6);
        // ratio falls with system complexity at K=3
        let k3: Vec<f64> = rows.iter().map(|r| r.ratio_pct()[2]).collect();
        for w in k3.windows(2) {
            assert!(w[1] <= w[0] + 2.0, "ratios {k3:?}");
        }
        // ratio grows with K for every system
        for r in &rows {
            let p = r.ratio_pct();
            assert!(p.windows(2).all(|w| w[1] > w[0]), "{p:?}");
        }
        // headline band at K=3 for the non-trivial systems
        for r in &rows[1..] {
            let p3 = r.ratio_pct()[2];
            assert!((25.0..=55.0).contains(&p3), "{}: {p3}", r.system);
        }
    }
}
