//! E4 — paper Fig. 4: CNN vs QNN accuracy for K = 1..5 on the six
//! datasets. QNN RMSEs are evaluated through the Rust **Q13 shift–add
//! datapath** (`nn::Sqnn`) — the same bit-accurate arithmetic the ASIC
//! simulator runs — so this is the chip-level accuracy, not a float
//! proxy.

use anyhow::Result;

use crate::analysis::rmse_vecs;
use crate::nn::ConditionedSqnn;
use crate::util::json::{self, Value};

use super::{load_dataset, load_model, Report};
use super::table1::SYSTEMS;

pub struct SystemSweep {
    pub system: String,
    pub cnn_mev: f64,
    /// QNN RMSE (meV/Å) for K = 1..5.
    pub qnn_mev: [f64; 5],
}

impl SystemSweep {
    /// RMSE ratio CNN/QNN per K (the paper's secondary axis).
    pub fn ratio(&self) -> [f64; 5] {
        self.qnn_mev.map(|q| self.cnn_mev / q)
    }
}

pub fn compute() -> Result<Vec<SystemSweep>> {
    let mut out = Vec::new();
    for name in SYSTEMS {
        let ds = load_dataset(name)?;
        let cnn = load_model(&format!("{name}_cnn_phi"))?;
        let cnn_preds: Vec<Vec<f64>> = ds.test_x.iter().map(|x| cnn.forward_physical(x)).collect();
        let cnn_rmse = 1000.0 * rmse_vecs(&cnn_preds, &ds.test_y);
        let mut qnn = [0.0; 5];
        for k in 1..=5usize {
            let m = load_model(&format!("{name}_qnn_k{k}"))?;
            // chip-level evaluation: Q13 features, shift-add MACs; the
            // output rescale is the FPGA's free power-of-two shift
            let s = ConditionedSqnn::from_mlp(&m, k);
            let scale = m.output_scale;
            let preds: Vec<Vec<f64>> = ds
                .test_x
                .iter()
                .map(|x| s.forward(x).into_iter().map(|v| v * scale).collect())
                .collect();
            qnn[k - 1] = 1000.0 * rmse_vecs(&preds, &ds.test_y);
        }
        out.push(SystemSweep { system: name.to_string(), cnn_mev: cnn_rmse, qnn_mev: qnn });
    }
    Ok(out)
}

pub fn run() -> Result<Report> {
    let mut report = Report::new("Fig. 4 — CNN vs QNN (Q13 chip datapath) across K");
    let sweeps = compute()?;
    let mut table = Vec::new();
    let mut data = Vec::new();
    for s in &sweeps {
        table.push(vec![
            s.system.clone(),
            format!("{:.2}", s.cnn_mev),
            format!("{:.2}", s.qnn_mev[0]),
            format!("{:.2}", s.qnn_mev[1]),
            format!("{:.2}", s.qnn_mev[2]),
            format!("{:.2}", s.qnn_mev[3]),
            format!("{:.2}", s.qnn_mev[4]),
        ]);
        data.push(json::obj(vec![
            ("system", json::s(&s.system)),
            ("cnn_mev", json::num(s.cnn_mev)),
            ("qnn_mev", json::arr_f64(&s.qnn_mev)),
        ]));
    }
    report.table(
        "Force RMSE (meV/Å); QNN through the bit-accurate shift datapath",
        &["system", "CNN", "K=1", "K=2", "K=3", "K=4", "K=5"],
        &table,
    );
    // Shape claims of the paper.
    let mut k1_worse = 0;
    let mut k3_converged = 0;
    for s in &sweeps {
        if s.qnn_mev[0] > 1.3 * s.qnn_mev[2] {
            k1_worse += 1;
        }
        if s.qnn_mev[4] > 0.75 * s.qnn_mev[2] {
            k3_converged += 1;
        }
        report.note(format!(
            "{}: K=3 loss vs CNN = {:+.1}% (paper band: 6.5–12%)",
            s.system,
            100.0 * (s.qnn_mev[2] - s.cnn_mev) / s.cnn_mev
        ));
    }
    report.note(format!(
        "K=1 clearly worse than K=3 on {k1_worse}/6 systems; K≥3 plateau on {k3_converged}/6"
    ));
    report.attach("systems", Value::Arr(data));
    let csv: Vec<Vec<f64>> = sweeps
        .iter()
        .enumerate()
        .flat_map(|(i, s)| {
            (1..=5).map(move |k| vec![i as f64, k as f64, s.qnn_mev[k - 1], s.cnn_mev])
        })
        .collect();
    report.save_csv("fig4_sweep", "system_index,k,qnn_mev,cnn_mev", &csv)?;
    report.save("fig4")?;
    Ok(report)
}
