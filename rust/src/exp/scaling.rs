//! E10 — paper §VI: the process-node projection. A₁ ≈ 10² from clock
//! frequency (25 MHz → GHz-class), A₂ ≈ 10² from transistor-density-
//! driven intra-ASIC parallelization (180 nm → 14 nm), so S falls from
//! ~10⁻⁶ to ~10⁻¹⁰ s/step/atom.
//!
//! Next to the analytical table this report now *measures* the A₂
//! mechanism on the simulator: a [`WaterFarm`] with the chip lane model
//! at each node's density factor, reporting modelled hardware
//! throughput (molecule-steps/s) and the host simulation rate.

use anyhow::Result;

use crate::coordinator::farm::{random_water_systems, FarmConfig, WaterFarm};
use crate::hw::power::ProcessNode;
use crate::hw::timing::{SystemTiming, CLOCK_HZ, PAPER_NVN_S};
use crate::util::json::{self, Value};
use crate::util::table::sci;

use super::Report;

pub struct Projection {
    pub node: ProcessNode,
    pub clock_hz: f64,
    pub a1: f64,
    pub a2: f64,
    pub s_projected: f64,
}

pub fn compute() -> Vec<Projection> {
    let base = SystemTiming::water_nominal();
    let s0 = base.s_per_step_atom();
    [
        (ProcessNode::N180, 25.0e6),
        (ProcessNode { nm: 65.0, vdd: 1.2 }, 600.0e6),
        (ProcessNode { nm: 28.0, vdd: 1.0 }, 1.5e9),
        (ProcessNode::N14, 2.5e9),
    ]
    .iter()
    .map(|&(node, clock)| {
        let a1 = clock / base.clock_hz;
        let a2 = ProcessNode::N180.density_vs(node);
        Projection { node, clock_hz: clock, a1, a2, s_projected: s0 / (a1 * a2) }
    })
    .collect()
}

/// One measured farm point of the lane sweep.
pub struct FarmMeasurement {
    pub lanes: usize,
    pub host_steps_per_s: f64,
    pub modelled_steps_per_s: f64,
    pub s_per_step_atom: f64,
}

/// Measure farm throughput for a sweep of chip lane counts: the same
/// water model as the `farm_throughput` bench (trained artifact or the
/// shared deterministic fallback), `n_mols` molecules, `ticks` steps
/// each — the measured side of the A₂ (density-driven parallelization)
/// argument.
pub fn measure_farm(
    n_mols: usize,
    ticks: usize,
    lanes_sweep: &[usize],
) -> Result<Vec<FarmMeasurement>> {
    let m = super::water_model_or_fallback();
    let systems = random_water_systems(n_mols, 300.0, 17);
    lanes_sweep
        .iter()
        .map(|&lanes| {
            let mut farm = WaterFarm::new(
                &m,
                &systems,
                &FarmConfig { shards: 4, lanes, ..FarmConfig::default() },
            )?;
            farm.run(ticks)?;
            let ledger = farm.finish()?;
            Ok(FarmMeasurement {
                lanes,
                host_steps_per_s: ledger.host_steps_per_second(),
                modelled_steps_per_s: ledger.modelled_steps_per_second(CLOCK_HZ),
                s_per_step_atom: ledger.s_per_step_atom(CLOCK_HZ),
            })
        })
        .collect()
}

pub fn run(quick: bool) -> Result<Report> {
    let mut report = Report::new("§VI projection — NvN-MLMD at advanced process nodes");
    let rows = compute();
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|p| {
            vec![
                format!("{:.0} nm", p.node.nm),
                format!("{:.2e} Hz", p.clock_hz),
                format!("{:.0}×", p.a1),
                format!("{:.0}×", p.a2),
                sci(p.s_projected, 1),
            ]
        })
        .collect();
    report.table(
        "A₁ = clock scaling, A₂ = density-driven parallelization",
        &["node", "clock", "A₁", "A₂", "projected S (s/step/atom)"],
        &table,
    );
    let last = rows.last().unwrap();
    report.note(format!(
        "14 nm projection: A₁×A₂ = {:.0} ≈ 10⁴ (paper) ⇒ S ≈ {} s/step/atom (paper: ~10⁻¹⁰)",
        last.a1 * last.a2,
        sci(last.s_projected, 1)
    ));
    report.note(format!("baseline measured S at 180 nm / 25 MHz: {}", sci(PAPER_NVN_S, 1)));

    // Measured A₂: the same farm at 1, 8, and 32 chip lanes — a
    // geometric sweep toward the advanced nodes' density headroom. The
    // MLP stage drains in ⌈(2·N/shards)/lanes⌉ waves, so once lanes
    // reach the per-shard lane demand (32 at the full 64-molecule /
    // 4-shard size) the sweep saturates and further lanes buy nothing.
    let (n_mols, ticks) = if quick { (16, 30) } else { (64, 200) };
    let farm_rows = measure_farm(n_mols, ticks, &[1, 8, 32])?;
    let farm_table: Vec<Vec<String>> = farm_rows
        .iter()
        .map(|f| {
            vec![
                format!("{}", f.lanes),
                format!("{:.0}", f.modelled_steps_per_s),
                sci(f.s_per_step_atom, 1),
                format!("{:.0}", f.host_steps_per_s),
            ]
        })
        .collect();
    report.table(
        "Measured farm throughput (4 shards) under the intra-ASIC lane model",
        &["chip lanes", "modelled steps/s", "measured S (s/step/atom)", "host sim steps/s"],
        &farm_table,
    );
    report.attach(
        "farm_throughput",
        Value::Arr(
            farm_rows
                .iter()
                .map(|f| {
                    json::obj(vec![
                        ("lanes", json::num(f.lanes as f64)),
                        ("modelled_steps_per_s", json::num(f.modelled_steps_per_s)),
                        ("host_steps_per_s", json::num(f.host_steps_per_s)),
                        ("s_per_step_atom", json::num(f.s_per_step_atom)),
                    ])
                })
                .collect(),
        ),
    );
    report.attach(
        "projections",
        Value::Arr(
            rows.iter()
                .map(|p| {
                    json::obj(vec![
                        ("node_nm", json::num(p.node.nm)),
                        ("clock_hz", json::num(p.clock_hz)),
                        ("a1", json::num(p.a1)),
                        ("a2", json::num(p.a2)),
                        ("s", json::num(p.s_projected)),
                    ])
                })
                .collect(),
        ),
    );
    report.save("scaling")?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_reaches_paper_magnitude() {
        let rows = compute();
        let last = rows.last().unwrap();
        let a = last.a1 * last.a2;
        assert!((3e3..3e5).contains(&a), "A1×A2 = {a}");
        assert!(last.s_projected < 1e-9, "S = {}", last.s_projected);
        // baseline row is identity
        assert!((rows[0].a1 - 1.0).abs() < 1e-12);
        assert!((rows[0].a2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn measured_farm_throughput_scales_with_lanes() {
        // The measured side of A₂: more chip lanes ⇒ strictly higher
        // modelled hardware throughput and lower S, same physics.
        let rows = measure_farm(8, 30, &[1, 8]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].host_steps_per_s > 0.0);
        assert!(
            rows[1].modelled_steps_per_s > rows[0].modelled_steps_per_s,
            "lanes=8 {} !> lanes=1 {}",
            rows[1].modelled_steps_per_s,
            rows[0].modelled_steps_per_s
        );
        assert!(rows[1].s_per_step_atom < rows[0].s_per_step_atom);
    }
}
