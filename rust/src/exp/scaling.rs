//! E10 — paper §VI: the process-node projection. A₁ ≈ 10² from clock
//! frequency (25 MHz → GHz-class), A₂ ≈ 10² from transistor-density-
//! driven intra-ASIC parallelization (180 nm → 14 nm), so S falls from
//! ~10⁻⁶ to ~10⁻¹⁰ s/step/atom.
//!
//! Next to the analytical table this report now *measures* the A₂
//! mechanism on the simulator: a [`WaterFarm`] with the chip lane model
//! at each node's density factor, reporting modelled hardware
//! throughput (molecule-steps/s) and the host simulation rate.

use anyhow::Result;

use crate::coordinator::farm::{
    generic_group, random_molecule_systems, random_water_systems, water_group, FarmConfig,
    FarmLedger, MoleculeFarm, SpeciesGroup, WaterFarm,
};
use crate::coordinator::gateway::{Gateway, GatewayConfig, GatewaySpecies};
use crate::coordinator::ParallelMode;
use crate::hw::power::ProcessNode;
use crate::hw::timing::{SystemTiming, CLOCK_HZ, PAPER_NVN_S};
use crate::testkit::arrivals::{self, ArrivalSpec};
use crate::util::json::{self, Value};
use crate::util::table::sci;

use super::Report;

pub struct Projection {
    pub node: ProcessNode,
    pub clock_hz: f64,
    pub a1: f64,
    pub a2: f64,
    pub s_projected: f64,
}

pub fn compute() -> Vec<Projection> {
    let base = SystemTiming::water_nominal();
    let s0 = base.s_per_step_atom();
    [
        (ProcessNode::N180, 25.0e6),
        (ProcessNode { nm: 65.0, vdd: 1.2 }, 600.0e6),
        (ProcessNode { nm: 28.0, vdd: 1.0 }, 1.5e9),
        (ProcessNode::N14, 2.5e9),
    ]
    .iter()
    .map(|&(node, clock)| {
        let a1 = clock / base.clock_hz;
        let a2 = ProcessNode::N180.density_vs(node);
        Projection { node, clock_hz: clock, a1, a2, s_projected: s0 / (a1 * a2) }
    })
    .collect()
}

/// One measured farm point of the lane sweep.
pub struct FarmMeasurement {
    pub lanes: usize,
    pub host_steps_per_s: f64,
    pub modelled_steps_per_s: f64,
    pub s_per_step_atom: f64,
}

/// Measure farm throughput for a sweep of chip lane counts: the same
/// water model as the `farm_throughput` bench (trained artifact or the
/// shared deterministic fallback), `n_mols` molecules, `ticks` steps
/// each — the measured side of the A₂ (density-driven parallelization)
/// argument.
pub fn measure_farm(
    n_mols: usize,
    ticks: usize,
    lanes_sweep: &[usize],
) -> Result<Vec<FarmMeasurement>> {
    let m = super::water_model_or_fallback();
    let systems = random_water_systems(n_mols, 300.0, 17);
    lanes_sweep
        .iter()
        .map(|&lanes| {
            let mut farm = WaterFarm::new(
                &m,
                &systems,
                &FarmConfig { shards: 4, lanes, ..FarmConfig::default() },
            )?;
            farm.run(ticks)?;
            let ledger = farm.finish()?;
            Ok(FarmMeasurement {
                lanes,
                host_steps_per_s: ledger.host_steps_per_second(),
                modelled_steps_per_s: ledger.modelled_steps_per_second(CLOCK_HZ),
                s_per_step_atom: ledger.s_per_step_atom(CLOCK_HZ),
            })
        })
        .collect()
}

/// Measure the heterogeneous serving tier: one [`MoleculeFarm`] holding
/// water and the ethanol-class generic species (distinct descriptor
/// widths, every shard programmed with its **own** species model),
/// reporting the per-species ledger — the mixed-traffic counterpart of
/// [`measure_farm`]'s single-species lane sweep.
/// Build the water + ethanol-class species groups of the mixed-traffic
/// measurement — one definition shared by this report and the
/// `farm_throughput` bench, so both always measure the same farm shape
/// (models, shard counts, dt, conditioning; only counts/seeds vary).
pub fn mixed_farm_groups(
    n_water: usize,
    n_ethanol: usize,
    water_seed: u64,
    ethanol_seed: u64,
) -> Result<Vec<SpeciesGroup>> {
    let wm = super::water_model_or_fallback();
    let em = super::molecule_model_or_fallback("ethanol");
    let eth = crate::potentials::ff::ethanol();
    let spec = crate::datasets::spec("ethanol")?;
    let water_systems = random_water_systems(n_water, 300.0, water_seed);
    let eth_systems =
        random_molecule_systems(&eth.coords, &eth.masses(), n_ethanol, 300.0, ethanol_seed);
    Ok(vec![
        water_group(&wm, &water_systems, 3, 2, 0.25)?,
        generic_group("ethanol", &em, &eth.coords, &eth_systems, spec.n_nb, 3, 2, 0.25)?,
    ])
}

pub fn measure_mixed_farm(
    n_water: usize,
    n_ethanol: usize,
    ticks: usize,
    mode: ParallelMode,
) -> Result<FarmLedger> {
    let mut farm = MoleculeFarm::new(mixed_farm_groups(n_water, n_ethanol, 17, 23)?, 1, mode)?;
    farm.run(ticks)?;
    farm.finish()
}

/// One measured point of the epoch-batched driver sweep.
pub struct EpochMeasurement {
    /// Ticks per shard job (1 = classic per-tick driving).
    pub epoch: usize,
    pub host_steps_per_s: f64,
    pub elapsed_s: f64,
    /// Wall-clock speedup over the sweep's first (per-tick baseline)
    /// point.
    pub speedup_vs_tick: f64,
}

/// Measure the epoch-batched farm driver on the mixed-species workload:
/// the same run driven in epochs of each given length (pass `1` first —
/// it is the per-tick baseline the speedups are against). The epoch
/// driver amortizes the per-tick submit/recv round-trip and barrier of
/// the threaded backend and overlaps the host's ledger folding with
/// shard execution, so the speedup grows with epoch length until the
/// per-epoch transport cost vanishes against the MD work.
pub fn measure_epoch_sweep(
    n_water: usize,
    n_ethanol: usize,
    ticks: usize,
    mode: ParallelMode,
    epochs: &[usize],
) -> Result<Vec<EpochMeasurement>> {
    let mut baseline: Option<f64> = None;
    let mut out = Vec::with_capacity(epochs.len());
    for &epoch in epochs {
        let mut farm =
            MoleculeFarm::new(mixed_farm_groups(n_water, n_ethanol, 17, 23)?, 1, mode)?;
        let t0 = std::time::Instant::now();
        farm.run_epoched(ticks, epoch)?;
        let elapsed = t0.elapsed().as_secs_f64();
        let ledger = farm.finish()?;
        let base = *baseline.get_or_insert(elapsed);
        out.push(EpochMeasurement {
            epoch,
            host_steps_per_s: ledger.host_steps_per_second(),
            elapsed_s: elapsed,
            speedup_vs_tick: if elapsed > 0.0 { base / elapsed } else { 0.0 },
        });
    }
    Ok(out)
}

/// One measured point of the gateway saturation sweep: a fixed
/// deterministic arrival plan (offered load set by `mean_gap`) replayed
/// through the serving gateway at one deadline-window length.
pub struct GatewayMeasurement {
    /// Deadline window (ticks per `run_epoch` quantum).
    pub window_ticks: u64,
    /// Mean inter-arrival gap of the plan (smaller = heavier load).
    pub mean_gap: u32,
    /// Requests in the plan.
    pub offered: u64,
    pub accepted: u64,
    /// Door rejections (queue full + species down + impossible
    /// deadline).
    pub rejected: u64,
    /// Accepted then shed from the queue once unmeetable.
    pub shed_queued: u64,
    pub completed: u64,
    pub deadline_met: u64,
    pub deadline_missed: u64,
    pub p50_ticks: u64,
    pub p99_ticks: u64,
    pub queue_high_water: u64,
    pub molecule_steps: u64,
    pub host_steps_per_s: f64,
    pub elapsed_s: f64,
}

impl GatewayMeasurement {
    /// Door reject fraction of the offered load.
    pub fn reject_rate(&self) -> f64 {
        if self.offered > 0 { self.rejected as f64 / self.offered as f64 } else { 0.0 }
    }

    /// The bench-json row (shared by the scaling report and
    /// `farm_throughput` so artifacts stay schema-identical).
    pub fn json_row(&self, backend: &str) -> Value {
        json::obj(vec![
            ("backend", json::s(backend)),
            ("window_ticks", json::num(self.window_ticks as f64)),
            ("mean_gap", json::num(f64::from(self.mean_gap))),
            ("offered", json::num(self.offered as f64)),
            ("accepted", json::num(self.accepted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("shed_queued", json::num(self.shed_queued as f64)),
            ("completed", json::num(self.completed as f64)),
            ("deadline_met", json::num(self.deadline_met as f64)),
            ("deadline_missed", json::num(self.deadline_missed as f64)),
            ("p50_ticks", json::num(self.p50_ticks as f64)),
            ("p99_ticks", json::num(self.p99_ticks as f64)),
            ("queue_high_water", json::num(self.queue_high_water as f64)),
            ("reject_rate", json::num(self.reject_rate())),
            ("molecule_steps_per_sec", json::num(self.host_steps_per_s)),
        ])
    }
}

/// Sweep the serving gateway across offered load × deadline window: a
/// water-only gateway on 2 shards (capacity 4 residents/shard, queue
/// bound 16) replaying deterministic arrival plans — the same plans for
/// every backend, so inline and threaded sweeps are comparable
/// point-for-point. Heavy-load points (`mean_gap` 1) drive the door
/// into admission control (nonzero rejects, bounded queue); light
/// points measure the latency floor of window quantization.
pub fn measure_gateway_saturation(
    mode: ParallelMode,
    quick: bool,
) -> Result<Vec<GatewayMeasurement>> {
    let m = super::water_model_or_fallback();
    let points: &[(u64, u32)] =
        if quick { &[(4, 1), (8, 6)] } else { &[(4, 1), (4, 6), (8, 1), (8, 6)] };
    let n_req = if quick { 24 } else { 96 };
    let systems = random_water_systems(n_req, 300.0, 99);
    let mut out = Vec::with_capacity(points.len());
    for &(window_ticks, mean_gap) in points {
        let mut gw = Gateway::new(
            vec![GatewaySpecies::water(&m, 3, 2, 0.25)?],
            GatewayConfig {
                window_ticks,
                queue_limit: 16,
                shard_capacity: 4,
                mode,
                ..GatewayConfig::default()
            },
        )?;
        let plan = arrivals::plan(&ArrivalSpec {
            seed: 0x6a7e,
            n: n_req,
            mean_gap,
            max_gap: 32,
            species_weights: vec![1],
            ticks_range: (4, 16),
            slack_range: (4, 24),
        });
        let t0 = std::time::Instant::now();
        gw.play(&plan, |i, _| systems[i].clone())?;
        let elapsed = t0.elapsed().as_secs_f64();
        let (slo, ledger) = gw.finish()?;
        let sp = &slo.species[0];
        out.push(GatewayMeasurement {
            window_ticks,
            mean_gap,
            offered: n_req as u64,
            accepted: sp.accepted,
            rejected: sp.rejected(),
            shed_queued: sp.shed_queued,
            completed: sp.completed,
            deadline_met: sp.deadline_met,
            deadline_missed: sp.deadline_missed,
            p50_ticks: sp.latency.p50(),
            p99_ticks: sp.latency.p99(),
            queue_high_water: sp.queue_depth_high_water,
            molecule_steps: ledger.molecule_steps,
            host_steps_per_s: if elapsed > 0.0 {
                ledger.molecule_steps as f64 / elapsed
            } else {
                0.0
            },
            elapsed_s: elapsed,
        });
    }
    Ok(out)
}

pub fn run(quick: bool) -> Result<Report> {
    let mut report = Report::new("§VI projection — NvN-MLMD at advanced process nodes");
    let rows = compute();
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|p| {
            vec![
                format!("{:.0} nm", p.node.nm),
                format!("{:.2e} Hz", p.clock_hz),
                format!("{:.0}×", p.a1),
                format!("{:.0}×", p.a2),
                sci(p.s_projected, 1),
            ]
        })
        .collect();
    report.table(
        "A₁ = clock scaling, A₂ = density-driven parallelization",
        &["node", "clock", "A₁", "A₂", "projected S (s/step/atom)"],
        &table,
    );
    let last = rows.last().unwrap();
    report.note(format!(
        "14 nm projection: A₁×A₂ = {:.0} ≈ 10⁴ (paper) ⇒ S ≈ {} s/step/atom (paper: ~10⁻¹⁰)",
        last.a1 * last.a2,
        sci(last.s_projected, 1)
    ));
    report.note(format!("baseline measured S at 180 nm / 25 MHz: {}", sci(PAPER_NVN_S, 1)));

    // Measured A₂: the same farm at 1, 8, and 32 chip lanes — a
    // geometric sweep toward the advanced nodes' density headroom. The
    // MLP stage drains in ⌈(2·N/shards)/lanes⌉ waves, so once lanes
    // reach the per-shard lane demand (32 at the full 64-molecule /
    // 4-shard size) the sweep saturates and further lanes buy nothing.
    let (n_mols, ticks) = if quick { (16, 30) } else { (64, 200) };
    let farm_rows = measure_farm(n_mols, ticks, &[1, 8, 32])?;
    let farm_table: Vec<Vec<String>> = farm_rows
        .iter()
        .map(|f| {
            vec![
                format!("{}", f.lanes),
                format!("{:.0}", f.modelled_steps_per_s),
                sci(f.s_per_step_atom, 1),
                format!("{:.0}", f.host_steps_per_s),
            ]
        })
        .collect();
    report.table(
        "Measured farm throughput (4 shards) under the intra-ASIC lane model",
        &["chip lanes", "modelled steps/s", "measured S (s/step/atom)", "host sim steps/s"],
        &farm_table,
    );
    report.attach(
        "farm_throughput",
        Value::Arr(
            farm_rows
                .iter()
                .map(|f| {
                    json::obj(vec![
                        ("lanes", json::num(f.lanes as f64)),
                        ("modelled_steps_per_s", json::num(f.modelled_steps_per_s)),
                        ("host_steps_per_s", json::num(f.host_steps_per_s)),
                        ("s_per_step_atom", json::num(f.s_per_step_atom)),
                    ])
                })
                .collect(),
        ),
    );
    // Mixed-species serving: the same farm machinery holding two
    // species with their own per-shard models (water 3→…→2, ethanol
    // 4·n_nb→…→3) — the heterogeneous-traffic point of the serving
    // tier, with per-species molecule-steps/s.
    let (n_water, n_eth, mixed_ticks) = if quick { (8, 4, 30) } else { (32, 16, 200) };
    let mixed = measure_mixed_farm(n_water, n_eth, mixed_ticks, ParallelMode::Inline)?;
    let farm_elapsed = mixed.host_wall.as_secs_f64();
    let elapsed_rate = |steps: u64| if farm_elapsed > 0.0 { steps as f64 / farm_elapsed } else { 0.0 };
    let mixed_table: Vec<Vec<String>> = mixed
        .species
        .iter()
        .map(|sp| {
            vec![
                sp.name.clone(),
                format!("{}", sp.n_molecules),
                format!("{}", sp.n_atoms),
                format!("{}", sp.molecule_steps),
                format!("{:.0}", sp.steps_per_shard_second()),
                format!("{:.0}", elapsed_rate(sp.molecule_steps)),
            ]
        })
        .collect();
    report.table(
        "Mixed-species farm (per-shard models; host rates per species)",
        &["species", "molecules", "atoms", "molecule-steps", "steps/shard-s", "steps/s elapsed"],
        &mixed_table,
    );
    report.attach(
        "mixed_farm",
        Value::Arr(
            mixed
                .species
                .iter()
                .map(|sp| {
                    json::obj(vec![
                        ("species", json::s(&sp.name)),
                        ("n_molecules", json::num(sp.n_molecules as f64)),
                        ("n_atoms", json::num(sp.n_atoms as f64)),
                        ("molecule_steps", json::num(sp.molecule_steps as f64)),
                        ("steps_per_shard_s", json::num(sp.steps_per_shard_second())),
                        ("steps_per_s_elapsed", json::num(elapsed_rate(sp.molecule_steps))),
                        ("chip_inferences", json::num(sp.chip_inferences as f64)),
                    ])
                })
                .collect(),
        ),
    );
    // Epoch-batched driver: one shard job per epoch instead of per
    // tick — the measured amortization of the per-tick round-trip +
    // barrier (and of the per-tick host-side supervision fold).
    let (epoch_ticks, epoch_lens): (usize, Vec<usize>) =
        if quick { (64, vec![1, 16]) } else { (512, vec![1, 4, 16, 64]) };
    for (label, mode) in [("inline", ParallelMode::Inline), ("threaded", ParallelMode::Threaded)] {
        let sweep = measure_epoch_sweep(n_water, n_eth, epoch_ticks, mode, &epoch_lens)?;
        let epoch_table: Vec<Vec<String>> = sweep
            .iter()
            .map(|e| {
                vec![
                    format!("{}", e.epoch),
                    format!("{:.0}", e.host_steps_per_s),
                    format!("{:.2}×", e.speedup_vs_tick),
                ]
            })
            .collect();
        report.table(
            &format!("Epoch-batched farm driver ({label} backend, {epoch_ticks} ticks)"),
            &["epoch (ticks/job)", "host steps/s", "speedup vs per-tick"],
            &epoch_table,
        );
        report.attach(
            &format!("epoch_sweep_{label}"),
            Value::Arr(
                sweep
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("epoch", json::num(e.epoch as f64)),
                            ("host_steps_per_s", json::num(e.host_steps_per_s)),
                            ("elapsed_s", json::num(e.elapsed_s)),
                            ("epoch_speedup_vs_tick", json::num(e.speedup_vs_tick)),
                        ])
                    })
                    .collect(),
            ),
        );
    }
    // Serving gateway saturation: offered load × deadline window over
    // the request front door — admission control visibly shedding at
    // heavy load while accepted requests keep their deadlines.
    let gw_rows = measure_gateway_saturation(ParallelMode::Inline, quick)?;
    let gw_table: Vec<Vec<String>> = gw_rows
        .iter()
        .map(|g| {
            vec![
                format!("{}", g.window_ticks),
                format!("{}", g.mean_gap),
                format!("{}", g.offered),
                format!("{}", g.accepted),
                format!("{:.0}%", 100.0 * g.reject_rate()),
                format!("{}", g.shed_queued),
                format!("{}/{}", g.deadline_met, g.completed),
                format!("{}", g.p50_ticks),
                format!("{}", g.p99_ticks),
                format!("{:.0}", g.host_steps_per_s),
            ]
        })
        .collect();
    report.table(
        "Serving gateway saturation (inline; water on 2 shards, queue bound 16)",
        &[
            "window",
            "mean gap",
            "offered",
            "accepted",
            "reject%",
            "shed",
            "met/done",
            "p50",
            "p99",
            "steps/s",
        ],
        &gw_table,
    );
    report.attach(
        "gateway_saturation",
        Value::Arr(gw_rows.iter().map(|g| g.json_row("inline")).collect()),
    );
    report.attach(
        "projections",
        Value::Arr(
            rows.iter()
                .map(|p| {
                    json::obj(vec![
                        ("node_nm", json::num(p.node.nm)),
                        ("clock_hz", json::num(p.clock_hz)),
                        ("a1", json::num(p.a1)),
                        ("a2", json::num(p.a2)),
                        ("s", json::num(p.s_projected)),
                    ])
                })
                .collect(),
        ),
    );
    report.save("scaling")?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_reaches_paper_magnitude() {
        let rows = compute();
        let last = rows.last().unwrap();
        let a = last.a1 * last.a2;
        assert!((3e3..3e5).contains(&a), "A1×A2 = {a}");
        assert!(last.s_projected < 1e-9, "S = {}", last.s_projected);
        // baseline row is identity
        assert!((rows[0].a1 - 1.0).abs() < 1e-12);
        assert!((rows[0].a2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_farm_serves_both_species() {
        let l = measure_mixed_farm(4, 2, 20, ParallelMode::Inline).unwrap();
        assert_eq!(l.species.len(), 2);
        assert_eq!(l.species[0].name, "water");
        assert_eq!(l.species[1].name, "ethanol");
        assert_eq!(l.species[0].molecule_steps, 80);
        assert_eq!(l.species[1].molecule_steps, 40);
        assert_eq!(l.molecule_steps, 120);
        // distinct per-shard models: water chips serve 2 lanes/molecule,
        // ethanol chips one lane per atom (9)
        assert_eq!(l.species[0].chip_inferences, 80 * 2);
        assert_eq!(l.species[1].chip_inferences, 40 * 9);
        for sp in &l.species {
            assert!(sp.steps_per_shard_second() > 0.0, "{} rate", sp.name);
        }
    }

    #[test]
    fn epoch_sweep_reports_all_points_with_tick_baseline() {
        let rows = measure_epoch_sweep(4, 2, 12, ParallelMode::Inline, &[1, 4]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].epoch, rows[1].epoch), (1, 4));
        // The first point is its own baseline by definition.
        assert!((rows[0].speedup_vs_tick - 1.0).abs() < 1e-12);
        for r in &rows {
            assert!(r.host_steps_per_s > 0.0);
            assert!(r.elapsed_s > 0.0);
            assert!(r.speedup_vs_tick > 0.0);
        }
    }

    #[test]
    fn gateway_saturation_sweep_is_sane() {
        let rows = measure_gateway_saturation(ParallelMode::Inline, true).unwrap();
        assert_eq!(rows.len(), 2);
        // The heavy point (mean gap 1) must drive the door into
        // admission control; the light point should serve nearly all.
        assert!(rows[0].rejected + rows[0].shed_queued > 0, "heavy point never shed");
        for g in &rows {
            assert!(g.completed > 0, "w={} gap={} completed nothing", g.window_ticks, g.mean_gap);
            assert_eq!(g.offered, g.accepted + g.rejected, "door accounting identity");
            assert!(g.p99_ticks >= g.p50_ticks);
            assert!(g.queue_high_water <= 16, "queue bound violated");
            assert!(g.molecule_steps > 0);
            assert!((0.0..=1.0).contains(&g.reject_rate()));
        }
    }

    #[test]
    fn measured_farm_throughput_scales_with_lanes() {
        // The measured side of A₂: more chip lanes ⇒ strictly higher
        // modelled hardware throughput and lower S, same physics.
        let rows = measure_farm(8, 30, &[1, 8]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].host_steps_per_s > 0.0);
        assert!(
            rows[1].modelled_steps_per_s > rows[0].modelled_steps_per_s,
            "lanes=8 {} !> lanes=1 {}",
            rows[1].modelled_steps_per_s,
            rows[0].modelled_steps_per_s
        );
        assert!(rows[1].s_per_step_atom < rows[0].s_per_step_atom);
    }
}
