//! E10 — paper §VI: the process-node projection. A₁ ≈ 10² from clock
//! frequency (25 MHz → GHz-class), A₂ ≈ 10² from transistor-density-
//! driven intra-ASIC parallelization (180 nm → 14 nm), so S falls from
//! ~10⁻⁶ to ~10⁻¹⁰ s/step/atom.

use anyhow::Result;

use crate::hw::power::ProcessNode;
use crate::hw::timing::{SystemTiming, PAPER_NVN_S};
use crate::util::json::{self, Value};
use crate::util::table::sci;

use super::Report;

pub struct Projection {
    pub node: ProcessNode,
    pub clock_hz: f64,
    pub a1: f64,
    pub a2: f64,
    pub s_projected: f64,
}

pub fn compute() -> Vec<Projection> {
    let base = SystemTiming::water_nominal();
    let s0 = base.s_per_step_atom();
    [
        (ProcessNode::N180, 25.0e6),
        (ProcessNode { nm: 65.0, vdd: 1.2 }, 600.0e6),
        (ProcessNode { nm: 28.0, vdd: 1.0 }, 1.5e9),
        (ProcessNode::N14, 2.5e9),
    ]
    .iter()
    .map(|&(node, clock)| {
        let a1 = clock / base.clock_hz;
        let a2 = ProcessNode::N180.density_vs(node);
        Projection { node, clock_hz: clock, a1, a2, s_projected: s0 / (a1 * a2) }
    })
    .collect()
}

pub fn run() -> Result<Report> {
    let mut report = Report::new("§VI projection — NvN-MLMD at advanced process nodes");
    let rows = compute();
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|p| {
            vec![
                format!("{:.0} nm", p.node.nm),
                format!("{:.2e} Hz", p.clock_hz),
                format!("{:.0}×", p.a1),
                format!("{:.0}×", p.a2),
                sci(p.s_projected, 1),
            ]
        })
        .collect();
    report.table(
        "A₁ = clock scaling, A₂ = density-driven parallelization",
        &["node", "clock", "A₁", "A₂", "projected S (s/step/atom)"],
        &table,
    );
    let last = rows.last().unwrap();
    report.note(format!(
        "14 nm projection: A₁×A₂ = {:.0} ≈ 10⁴ (paper) ⇒ S ≈ {} s/step/atom (paper: ~10⁻¹⁰)",
        last.a1 * last.a2,
        sci(last.s_projected, 1)
    ));
    report.note(format!("baseline measured S at 180 nm / 25 MHz: {}", sci(PAPER_NVN_S, 1)));
    report.attach(
        "projections",
        Value::Arr(
            rows.iter()
                .map(|p| {
                    json::obj(vec![
                        ("node_nm", json::num(p.node.nm)),
                        ("clock_hz", json::num(p.clock_hz)),
                        ("a1", json::num(p.a1)),
                        ("a2", json::num(p.a2)),
                        ("s", json::num(p.s_projected)),
                    ])
                })
                .collect(),
        ),
    );
    report.save("scaling")?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_reaches_paper_magnitude() {
        let rows = compute();
        let last = rows.last().unwrap();
        let a = last.a1 * last.a2;
        assert!((3e3..3e5).contains(&a), "A1×A2 = {a}");
        assert!(last.s_projected < 1e-9, "S = {}", last.s_projected);
        // baseline row is identity
        assert!((rows[0].a1 - 1.0).abs() < 1e-12);
        assert!((rows[0].a2 - 1.0).abs() < 1e-12);
    }
}
