//! E3 — paper Table I: force RMSE of tanh-MLP vs φ-MLP on the six
//! datasets. Models come from the Python trainer; the RMSEs here are
//! recomputed in Rust (float forward pass on the held-out test split).

use anyhow::Result;

use crate::analysis::rmse_vecs;
use crate::util::json::{self, Value};

use super::{load_dataset, load_model, Report};

pub const SYSTEMS: [&str; 6] = ["water", "ethanol", "toluene", "naphthalene", "aspirin", "silicon"];

/// Paper Table I values (meV/Å) for side-by-side reporting.
pub const PAPER: [(&str, f64, f64); 6] = [
    ("water", 25.04, 24.83),
    ("ethanol", 29.33, 29.84),
    ("toluene", 53.15, 52.70),
    ("naphthalene", 46.45, 46.63),
    ("aspirin", 74.85, 75.20),
    ("silicon", 67.10, 67.28),
];

pub struct Row {
    pub system: String,
    pub tanh_mev: f64,
    pub phi_mev: f64,
}

pub fn compute() -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in SYSTEMS {
        let ds = load_dataset(name)?;
        let tanh = load_model(&format!("{name}_cnn_tanh"))?;
        let phi = load_model(&format!("{name}_cnn_phi"))?;
        let pred_t: Vec<Vec<f64>> = ds.test_x.iter().map(|x| tanh.forward_physical(x)).collect();
        let pred_p: Vec<Vec<f64>> = ds.test_x.iter().map(|x| phi.forward_physical(x)).collect();
        rows.push(Row {
            system: name.to_string(),
            tanh_mev: 1000.0 * rmse_vecs(&pred_t, &ds.test_y),
            phi_mev: 1000.0 * rmse_vecs(&pred_p, &ds.test_y),
        });
    }
    Ok(rows)
}

pub fn run() -> Result<Report> {
    let mut report = Report::new("Table I — force RMSE (meV/Å): tanh-MLP vs φ-MLP");
    let rows = compute()?;
    let mut table = Vec::new();
    let mut data = Vec::new();
    for r in &rows {
        let paper = PAPER.iter().find(|(n, _, _)| *n == r.system).unwrap();
        table.push(vec![
            r.system.clone(),
            format!("{:.2}", r.tanh_mev),
            format!("{:.2}", r.phi_mev),
            format!("{:+.2}", r.tanh_mev - r.phi_mev),
            format!("{:.2} / {:.2}", paper.1, paper.2),
        ]);
        data.push(json::obj(vec![
            ("system", json::s(&r.system)),
            ("tanh_mev", json::num(r.tanh_mev)),
            ("phi_mev", json::num(r.phi_mev)),
        ]));
        // the headline claim: swapping tanh→φ costs ~nothing
        let rel = (r.tanh_mev - r.phi_mev).abs() / r.tanh_mev.max(1e-9);
        if rel > 0.15 {
            report.note(format!(
                "NOTE: {}: tanh/φ differ by {:.0}% — larger than the paper's ≤2%",
                r.system,
                rel * 100.0
            ));
        }
    }
    report.table(
        "Measured (this repo, synthetic datasets) vs paper (MD17/DFT datasets)",
        &["system", "tanh", "φ", "difference", "paper tanh/φ"],
        &table,
    );
    report.note("shape claim: replacing tanh with φ brings no material accuracy loss");
    report.attach("rows", Value::Arr(data));
    report.save("table1")?;
    Ok(report)
}
