//! E9 — paper Table III: computational time cost S (s/step/atom), power
//! P (W), and energy η = S×P (J/step/atom) for five methods.
//!
//! Measurement policy (EXPERIMENTS.md): rows that run on this testbed
//! are **measured** (DFT surrogate SCF, vN-MLMD via PJRT, DeePMD-like
//! via PJRT); their CPU powers use the paper's published device powers
//! (we cannot meter the host). The DeePMD-GPU row is taken from the
//! paper (no GPU here). The NvN row's S comes from the cycle-accurate
//! ledger at 25 MHz and P from the calibrated power model.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::vn::VnMlmd;
use crate::dft::{ScfConfig, ToyDft};
use crate::hw::power::{published, SYSTEM_POWER_W};
use crate::hw::timing::CLOCK_HZ;
use crate::util::json::{self, Value};
use crate::util::table::sci;
use crate::util::Vec3;

use super::water_md;
use super::{load_model, Report};

pub struct MethodRow {
    pub method: String,
    pub hardware: String,
    pub s: f64,
    pub p: f64,
    pub measured: bool,
    pub note: String,
}

impl MethodRow {
    pub fn eta(&self) -> f64 {
        self.s * self.p
    }
}

pub fn compute(quick: bool) -> Result<Vec<MethodRow>> {
    let mut rows = Vec::new();
    let dt = 0.25;

    // --- DFT (toy SCF workload; forces from oracle) ---
    let dft_steps = if quick { 3 } else { 10 };
    let mut dft = ToyDft::new(ScfConfig::default());
    let mut sys = water_md::initial_condition(1);
    let mut forces = vec![Vec3::ZERO; 3];
    let t0 = Instant::now();
    for _ in 0..dft_steps {
        dft.aimd_force_step(&sys.pos, &mut forces);
        crate::md::euler_step(&mut sys, crate::potentials::WaterPes::dft_surrogate(), dt, &mut forces);
    }
    let s_dft = t0.elapsed().as_secs_f64() / dft_steps as f64 / 3.0;
    rows.push(MethodRow {
        method: "DFT".into(),
        hardware: "CPU (toy SCF surrogate)".into(),
        s: s_dft,
        p: published::DFT_CPU_W,
        measured: true,
        note: format!(
            "measured on toy SCF ({} basis, {} iters/step); paper: {} s/step/atom on SIESTA",
            dft.n_basis(),
            dft.last.iterations,
            sci(published::DFT_CPU_S, 1)
        ),
    });

    // --- vN-MLMD (PJRT if available) ---
    let steps = if quick { 2_000 } else { 20_000 };
    let (vn_model, vn_pjrt) = water_md::vn_model("water_mlp.hlo.txt", "water_qnn_k3")?;
    let mut driver = VnMlmd::new(water_md::initial_condition(1), vn_model, dt);
    let t0 = Instant::now();
    driver.run(steps, 0, |_| {})?;
    let s_vn = t0.elapsed().as_secs_f64() / steps as f64 / 3.0;
    rows.push(MethodRow {
        method: "vN-MLMD".into(),
        hardware: if vn_pjrt { "CPU (PJRT, AOT HLO)".into() } else { "CPU (in-process float)".into() },
        s: s_vn,
        p: published::VN_MLMD_CPU_W,
        measured: true,
        note: "same MLMD algorithm, von-Neumann execution".into(),
    });

    // --- DeePMD-like (PJRT if available) ---
    let (dp_model, dp_pjrt) = water_md::vn_model("water_deepmd.hlo.txt", "water_deepmd_like")?;
    let mut driver = VnMlmd::new(water_md::initial_condition(1), dp_model, dt);
    let t0 = Instant::now();
    driver.run(steps, 0, |_| {})?;
    let s_dp = t0.elapsed().as_secs_f64() / steps as f64 / 3.0;
    rows.push(MethodRow {
        method: "DeePMD-like".into(),
        hardware: if dp_pjrt { "CPU (PJRT, AOT HLO)".into() } else { "CPU (in-process float)".into() },
        s: s_dp,
        p: published::DEEPMD_CPU_W,
        measured: true,
        note: "larger float network, same driver".into(),
    });

    // --- DeePMD on GPU: paper-published (no GPU on this testbed) ---
    rows.push(MethodRow {
        method: "DeePMD (paper)".into(),
        hardware: "CPU + V100 GPU".into(),
        s: published::DEEPMD_GPU_S,
        p: published::DEEPMD_GPU_W,
        measured: false,
        note: "paper-published values (no GPU on this testbed)".into(),
    });

    // --- NvN-MLMD: cycle-accurate ledger at 25 MHz ---
    let model = load_model("water_qnn_k3")?;
    let nvn_steps = if quick { 2_000 } else { 20_000 };
    let (_s, _p, ledger) = water_md::run_nvn(&model, model.quant_k.max(3), nvn_steps, dt, 1, false)?;
    rows.push(MethodRow {
        method: "NvN-MLMD".into(),
        hardware: "ASIC (180 nm) + FPGA @ 25 MHz".into(),
        s: ledger.s_per_step_atom(CLOCK_HZ),
        p: SYSTEM_POWER_W,
        measured: true,
        note: format!(
            "cycle-accurate ledger: {} cycles / step (budget in hw::timing)",
            ledger.modelled_cycles / ledger.md_steps
        ),
    });

    Ok(rows)
}

pub fn run(quick: bool) -> Result<Report> {
    let mut report = Report::new("Table III — computational time cost and energy consumption");
    let rows = compute(quick)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                r.hardware.clone(),
                sci(r.s, 2),
                format!("{:.1}", r.p),
                sci(r.eta(), 2),
                if r.measured { "measured".into() } else { "paper".into() },
            ]
        })
        .collect();
    report.table(
        "S = s/step/atom; η = S×P (paper: DFT 4.4e2, vN 2.3e-2, DeePMD-CPU 1.3e-2, DeePMD-GPU 6.5e-4, NvN 3.0e-6 J/step/atom)",
        &["method", "hardware", "S (s/step/atom)", "P (W)", "η (J/step/atom)", "origin"],
        &table,
    );
    for r in &rows {
        report.note(format!("{}: {}", r.method, r.note));
    }
    // Headline ratios.
    let nvn = rows.last().unwrap();
    let gpu = &rows[3];
    report.note(format!(
        "NvN vs DeePMD-GPU: speed ×{:.1} (paper: 1.6), energy ×{:.0} (paper: 10²–10³)",
        gpu.s / nvn.s,
        gpu.eta() / nvn.eta()
    ));
    let dft = &rows[0];
    report.note(format!(
        "NvN vs DFT-surrogate speedup: {:.1e} (paper: ~10⁶ vs SIESTA; our SCF surrogate is smaller than DZP SIESTA)",
        dft.s / nvn.s
    ));
    report.attach(
        "rows",
        Value::Arr(
            rows.iter()
                .map(|r| {
                    json::obj(vec![
                        ("method", json::s(&r.method)),
                        ("s", json::num(r.s)),
                        ("p_w", json::num(r.p)),
                        ("eta", json::num(r.eta())),
                        ("measured", Value::Bool(r.measured)),
                    ])
                })
                .collect(),
        ),
    );
    report.save("table3")?;
    Ok(report)
}
