//! E7 — paper Table II: bond length, H–O–H angle, and the three
//! vibration frequencies computed by four methods — DFT (surrogate PES,
//! velocity Verlet), vN-MLMD (same MLMD algorithm in float via PJRT),
//! NvN-MLMD (the heterogeneous fixed-point system), and the DeePMD-style
//! baseline — plus the paper's Error¹/²/³ rows.

use anyhow::Result;

use crate::util::json::{self, Value};
use crate::util::table::fix;

use super::water_md::{self, WaterProperties};
use super::{load_model, Report};

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub steps: usize,
    pub dt: f64,
    pub seed: u64,
    pub strict13: bool,
}

impl Config {
    pub fn with_quick(quick: bool) -> Self {
        Config { steps: if quick { 8_000 } else { 48_000 }, dt: 0.25, seed: 42, strict13: false }
    }
}

pub struct Table2 {
    pub dft: WaterProperties,
    pub vn: WaterProperties,
    pub nvn: WaterProperties,
    pub deepmd: WaterProperties,
    pub vn_used_pjrt: bool,
    pub deepmd_used_pjrt: bool,
    pub nvn_ledger: crate::coordinator::Ledger,
}

pub fn compute(cfg: Config) -> Result<Table2> {
    // DFT reference.
    let (_s, dft) = water_md::run_dft(cfg.steps, cfg.dt, cfg.seed);

    // vN-MLMD: the QNN model in float through PJRT (fallback in-process).
    let (vn_model, vn_used_pjrt) = water_md::vn_model("water_mlp.hlo.txt", "water_qnn_k3")?;
    let (_s, vn) = water_md::run_vn(vn_model, cfg.steps, cfg.dt, cfg.seed)?;

    // NvN-MLMD: the heterogeneous fixed-point system.
    let model = load_model("water_qnn_k3")?;
    let (_s, nvn, ledger) =
        water_md::run_nvn(&model, model.quant_k.max(3), cfg.steps, cfg.dt, cfg.seed, cfg.strict13)?;

    // DeePMD-style baseline.
    let (dp_model, deepmd_used_pjrt) =
        water_md::vn_model("water_deepmd.hlo.txt", "water_deepmd_like")?;
    let (_s, deepmd) = water_md::run_vn(dp_model, cfg.steps, cfg.dt, cfg.seed)?;

    Ok(Table2 { dft, vn, nvn, deepmd, vn_used_pjrt, deepmd_used_pjrt, nvn_ledger: ledger })
}

fn prop_row(name: &str, p: &WaterProperties) -> Vec<String> {
    vec![
        name.to_string(),
        fix(p.bond_length, 3),
        fix(p.angle_deg, 2),
        fix(p.nu_sym, 0),
        fix(p.nu_asym, 0),
        fix(p.nu_bend, 0),
    ]
}

fn err_row(name: &str, e: &[f64; 5]) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.2}%", e[0] * 100.0),
        format!("{:.2}%", e[1] * 100.0),
        format!("{:.2}%", e[2] * 100.0),
        format!("{:.2}%", e[3] * 100.0),
        format!("{:.2}%", e[4] * 100.0),
    ]
}

fn prop_json(p: &WaterProperties) -> Value {
    json::obj(vec![
        ("bond_A", json::num(p.bond_length)),
        ("angle_deg", json::num(p.angle_deg)),
        ("nu_sym", json::num(p.nu_sym)),
        ("nu_asym", json::num(p.nu_asym)),
        ("nu_bend", json::num(p.nu_bend)),
    ])
}

pub fn run(cfg: Config) -> Result<Report> {
    let mut report = Report::new("Table II — structural & dynamic properties, four methods");
    let t = compute(cfg)?;

    let headers = ["method", "bond (Å)", "∠HOH (°)", "ν_sym", "ν_asym", "ν_bend"];
    let rows = vec![
        prop_row("DFT", &t.dft),
        prop_row("vN-MLMD", &t.vn),
        prop_row("NvN-MLMD", &t.nvn),
        prop_row("DeePMD-like", &t.deepmd),
        err_row("Error¹ (vN vs DFT)", &t.vn.errors_vs(&t.dft)),
        err_row("Error² (NvN vs DFT)", &t.nvn.errors_vs(&t.dft)),
        err_row("Error³ (DeePMD vs DFT)", &t.deepmd.errors_vs(&t.dft)),
    ];
    report.table(
        &format!("{} steps × {} fs (paper DFT row: 0.969 Å, 104.88°, 4007/4241/1603 cm⁻¹)", cfg.steps, cfg.dt),
        &headers,
        &rows,
    );
    let e2_max = t.nvn.errors_vs(&t.dft).iter().cloned().fold(0.0, f64::max);
    report.note(format!(
        "max Error² = {:.2}% (paper: ≤1.06%) — the fixed-point NvN system does not sacrifice MLMD accuracy",
        e2_max * 100.0
    ));
    report.note(format!(
        "vN force path: {}; DeePMD path: {}",
        if t.vn_used_pjrt { "PJRT (AOT artifact)" } else { "in-process float (artifact missing)" },
        if t.deepmd_used_pjrt { "PJRT (AOT artifact)" } else { "in-process float (artifact missing)" },
    ));
    report.note(format!(
        "NvN modelled hardware time: {:.3} s for {} steps (S = {:.2e} s/step/atom)",
        t.nvn_ledger.hw_seconds(crate::hw::timing::CLOCK_HZ),
        t.nvn_ledger.md_steps,
        t.nvn_ledger.s_per_step_atom(crate::hw::timing::CLOCK_HZ),
    ));
    report.attach("dft", prop_json(&t.dft));
    report.attach("vn", prop_json(&t.vn));
    report.attach("nvn", prop_json(&t.nvn));
    report.attach("deepmd", prop_json(&t.deepmd));
    report.save("table2")?;
    Ok(report)
}
