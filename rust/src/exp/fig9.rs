//! E6 — paper Fig. 9: atomic forces predicted by the MLP chip vs the DFT
//! reference, on fresh configurations. The chip path is the full
//! pipeline — Q13 feature quantization → shift–add MLP (via the threaded
//! `ChipPool`) → local-frame reconstruction — compared against the
//! surrogate-PES forces in Cartesian space (meV/Å RMSE, like the paper).

use anyhow::Result;

use crate::analysis;
use crate::asic::{ChipConfig, MlpChip};
use crate::coordinator::pool::ChipPool;
use crate::features;
use crate::fixedpoint::Q13;
use crate::md::{initialize_velocities, Engine, ForceField, System};
use crate::potentials::WaterPes;
use crate::util::json::{self, Value};
use crate::util::rng::Pcg;
use crate::util::Vec3;

use super::{load_model, Report};

/// Paper's measured chip RMSE (meV/Å).
pub const PAPER_RMSE: f64 = 7.56;

pub struct ChipEval {
    /// (DFT force component, chip force component) pairs — the scatter.
    pub scatter: Vec<(f64, f64)>,
    pub rmse_mev: f64,
}

/// Sample `n_frames` fresh configurations (400 K MD, unseen seed) and
/// push them through the chip pool.
pub fn compute(n_frames: usize) -> Result<ChipEval> {
    let model = load_model("water_qnn_k3")?;
    let k = model.quant_k.max(3);
    let chips: Vec<MlpChip> = (0..2)
        .map(|id| {
            let mut c = MlpChip::new(id, ChipConfig::default());
            c.program(&model, k);
            c
        })
        .collect();
    let mut pool = ChipPool::spawn(chips)?;

    // Fresh configurations from re-initialized NVE bursts (same protocol
    // as the training sampler, held-out seed — see datasets::water_dataset
    // for why not a thermostatted trajectory).
    let pes = WaterPes::dft_surrogate();
    let mut rng = Pcg::new(0xF19); // held-out seed
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 2.0 * 350.0, 6, &mut rng);
    let mut eng = Engine::new(sys, pes, 0.25);
    for _ in 0..400 {
        eng.step_verlet();
    }

    let mut scatter = Vec::new();
    let mut se = 0.0;
    let mut n = 0usize;
    for frame in 0..n_frames {
        if frame % 40 == 39 {
            // re-draw velocities: new NVE burst
            initialize_velocities(&mut eng.sys, 2.0 * 350.0, 6, &mut rng);
            for _ in 0..400 {
                eng.step_verlet();
            }
        }
        for _ in 0..8 {
            eng.step_verlet();
        }
        let pos = eng.sys.pos.clone();
        let mut f_ref = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f_ref);

        // chip path: FPGA feature conditioning (constant-subtract + pow2
        // gain) then the Q13 bus
        let rows: Vec<Vec<Q13>> = [1usize, 2]
            .iter()
            .map(|&h| {
                model
                    .condition(&features::water_features(&pos, h))
                    .iter()
                    .map(|&x| Q13::from_f64(x))
                    .collect()
            })
            .collect();
        let outs = pool.infer_batch(&rows)?;
        for (hi, h) in [1usize, 2].iter().enumerate() {
            // the FPGA's power-of-two output rescale
            let c = [
                outs[hi][0].to_f64() * model.output_scale,
                outs[hi][1].to_f64() * model.output_scale,
            ];
            let f_chip = features::water_force_from_local(&pos, *h, c);
            let f_true = f_ref[*h];
            for (a, b) in f_chip.to_array().iter().zip(f_true.to_array()) {
                scatter.push((b, *a));
                se += (a - b) * (a - b);
                n += 1;
            }
        }
    }
    Ok(ChipEval { scatter, rmse_mev: 1000.0 * (se / n as f64).sqrt() })
}

pub fn run() -> Result<Report> {
    let mut report = Report::new("Fig. 9 — MLP-chip forces vs DFT surrogate");
    let eval = compute(600)?;
    report.note(format!(
        "chip force RMSE = {:.2} meV/Å over {} components (paper: {PAPER_RMSE} meV/Å)",
        eval.rmse_mev,
        eval.scatter.len()
    ));
    let spread = analysis::mean_std(&eval.scatter.iter().map(|p| p.0).collect::<Vec<_>>()).1;
    report.note(format!(
        "force spread σ = {:.3} eV/Å ⇒ relative error {:.1}%",
        spread,
        0.1 * eval.rmse_mev / spread
    ));
    let csv: Vec<Vec<f64>> = eval.scatter.iter().map(|&(d, c)| vec![d, c]).collect();
    report.save_csv("fig9_scatter", "dft_force_evA,chip_force_evA", &csv)?;
    report.attach("rmse_mev", json::num(eval.rmse_mev));
    report.attach("n_points", Value::Num(eval.scatter.len() as f64));
    report.save("fig9")?;
    Ok(report)
}
