//! Toy self-consistent-field "DFT" engine — the compute-cost surrogate
//! for the paper's SIESTA AIMD row of Table III.
//!
//! Per MD step it performs the structural workload of a small
//! LCAO DFT code: build a distance-dependent Hamiltonian over a basis of
//! `n_basis` orbitals, then iterate (diagonalize → occupy → mix density →
//! rebuild H) to self-consistency — O(n³) dense eigensolves × SCF
//! iterations, the cost profile the Table III DFT row measures. The
//! *forces* it returns are delegated to the calibrated PES oracle
//! (`potentials::WaterPes`), which is also what the training pipeline
//! treats as the DFT ground truth; the SCF machinery provides honest
//! compute cost (and a converged toy band energy), not new physics. See
//! DESIGN.md §Substitutions.

use crate::linalg::{eigh, Mat};
use crate::md::ForceField;
use crate::potentials::WaterPes;
use crate::util::Vec3;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct ScfConfig {
    /// Orbitals per atom (water: O gets 2×, H 1× this base) — total
    /// basis ≈ `4 × base` for H₂O.
    pub orbitals_per_atom: usize,
    /// Maximum SCF iterations per step.
    pub max_iter: usize,
    /// Density-mixing factor.
    pub mixing: f64,
    /// Convergence threshold on the density change (Frobenius).
    pub tol: f64,
}

impl Default for ScfConfig {
    fn default() -> Self {
        // Basis sized so one SCF step costs ~O(10⁶–10⁷) flops and a step
        // needs O(10) iterations — a "minimal DZP-flavoured" workload.
        ScfConfig { orbitals_per_atom: 16, max_iter: 60, mixing: 0.5, tol: 1e-6 }
    }
}

/// Diagnostics of the last step.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScfStats {
    pub iterations: usize,
    pub converged: bool,
    pub band_energy: f64,
}

/// The toy SCF engine for the water molecule.
pub struct ToyDft {
    pub cfg: ScfConfig,
    pub last: ScfStats,
    n_basis: usize,
    /// orbital → atom assignment
    orb_atom: Vec<usize>,
    /// persistent density matrix (warm start across MD steps)
    density: Mat,
}

impl ToyDft {
    pub fn new(cfg: ScfConfig) -> Self {
        // [O, H1, H2]: O carries 2× the base orbitals.
        let per = [2 * cfg.orbitals_per_atom, cfg.orbitals_per_atom, cfg.orbitals_per_atom];
        let n_basis: usize = per.iter().sum();
        let mut orb_atom = Vec::with_capacity(n_basis);
        for (atom, &count) in per.iter().enumerate() {
            orb_atom.extend(std::iter::repeat(atom).take(count));
        }
        ToyDft {
            cfg,
            last: ScfStats::default(),
            n_basis,
            orb_atom,
            density: Mat::eye(n_basis),
        }
    }

    pub fn n_basis(&self) -> usize {
        self.n_basis
    }

    /// Build the distance-dependent one-electron Hamiltonian: on-site
    /// energies by element, hoppings decaying exponentially with
    /// interatomic distance, intra-atomic level spacing, plus a Hartree-
    /// like diagonal shift from the current density.
    fn hamiltonian(&self, pos: &[Vec3], density: &Mat) -> Mat {
        let n = self.n_basis;
        let mut h = Mat::zeros(n, n);
        for i in 0..n {
            let ai = self.orb_atom[i];
            let onsite = if ai == 0 { -1.2 } else { -0.6 };
            // level spacing within an atom's block
            h[(i, i)] = onsite + 0.05 * (i % 7) as f64 + 0.3 * density[(i, i)];
            for j in i + 1..n {
                let aj = self.orb_atom[j];
                let t = if ai == aj {
                    0.08 // intra-atom coupling
                } else {
                    let r = (pos[ai] - pos[aj]).norm();
                    0.9 * (-1.7 * r).exp()
                };
                h[(i, j)] = t;
                h[(j, i)] = t;
            }
        }
        h
    }

    /// One self-consistency loop for the given geometry; returns the
    /// converged band energy.
    pub fn scf(&mut self, pos: &[Vec3]) -> f64 {
        let n = self.n_basis;
        let n_occ = n / 2;
        let mut density = self.density.clone();
        let mut stats = ScfStats::default();
        for it in 0..self.cfg.max_iter {
            let h = self.hamiltonian(pos, &density);
            let (vals, vecs) = eigh(&h);
            // occupy the lowest n_occ orbitals
            let mut new_density = Mat::zeros(n, n);
            for k in 0..n_occ {
                for i in 0..n {
                    let vik = vecs[(i, k)];
                    if vik == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        new_density[(i, j)] += vik * vecs[(j, k)];
                    }
                }
            }
            // mix
            let mut delta = 0.0;
            for idx in 0..n * n {
                let d = new_density.data[idx] - density.data[idx];
                delta += d * d;
                density.data[idx] += self.cfg.mixing * d;
            }
            stats.iterations = it + 1;
            stats.band_energy = vals[..n_occ].iter().sum();
            if delta.sqrt() < self.cfg.tol {
                stats.converged = true;
                break;
            }
        }
        self.density = density;
        self.last = stats;
        stats.band_energy
    }
}

impl ForceField for ToyDft {
    /// The "AIMD" force call: run the SCF workload, return the oracle
    /// forces (Hellmann–Feynman stand-in; see module docs).
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        // interior mutability dance: SCF needs &mut for the density warm
        // start; ForceField::compute takes &self. Clone a worker.
        let mut worker = ToyDft {
            cfg: self.cfg,
            last: self.last,
            n_basis: self.n_basis,
            orb_atom: self.orb_atom.clone(),
            density: self.density.clone(),
        };
        let band = worker.scf(pos);
        let pes_e = WaterPes::dft_surrogate().compute(pos, forces);
        // report the PES energy (the physically calibrated one); band
        // energy available via stats for diagnostics
        let _ = band;
        pes_e
    }

    fn name(&self) -> &'static str {
        "toy-scf-dft"
    }
}

impl ToyDft {
    /// The stateful step used by the Table III timing run (keeps the
    /// density warm start, which is how real AIMD amortizes SCF).
    pub fn aimd_force_step(&mut self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        self.scf(pos);
        WaterPes::dft_surrogate().compute(pos, forces)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Vec<Vec3> {
        WaterPes::dft_surrogate().equilibrium()
    }

    #[test]
    fn scf_converges_at_equilibrium() {
        let mut dft = ToyDft::new(ScfConfig::default());
        let e = dft.scf(&geom());
        assert!(dft.last.converged, "SCF did not converge: {:?}", dft.last);
        assert!(e < 0.0, "band energy should be negative: {e}");
        assert!(dft.last.iterations >= 3, "suspiciously fast: {:?}", dft.last);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let mut dft = ToyDft::new(ScfConfig::default());
        let mut pos = geom();
        dft.scf(&pos);
        let cold = dft.last.iterations;
        // tiny geometry change → warm density should reconverge faster
        pos[1] += Vec3::new(0.002, 0.0, 0.0);
        dft.scf(&pos);
        let warm = dft.last.iterations;
        assert!(warm <= cold, "warm {warm} vs cold {cold}");
    }

    #[test]
    fn band_energy_responds_to_geometry() {
        let mut dft = ToyDft::new(ScfConfig::default());
        let e0 = dft.scf(&geom());
        let mut stretched = geom();
        stretched[1] = stretched[1] * 1.3;
        let e1 = dft.scf(&stretched);
        assert!((e0 - e1).abs() > 1e-6, "band energy insensitive to geometry");
    }

    #[test]
    fn forces_are_the_calibrated_oracle() {
        let dft = ToyDft::new(ScfConfig { orbitals_per_atom: 4, max_iter: 8, ..Default::default() });
        let mut f_dft = vec![Vec3::ZERO; 3];
        let mut f_pes = vec![Vec3::ZERO; 3];
        let mut pos = geom();
        pos[2] += Vec3::new(0.02, -0.03, 0.01);
        let e_dft = dft.compute(&pos, &mut f_dft);
        let e_pes = WaterPes::dft_surrogate().compute(&pos, &mut f_pes);
        assert_eq!(e_dft, e_pes);
        for (a, b) in f_dft.iter().zip(&f_pes) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn cost_scales_with_basis() {
        use std::time::Instant;
        let mut small = ToyDft::new(ScfConfig { orbitals_per_atom: 4, max_iter: 10, ..Default::default() });
        let mut big = ToyDft::new(ScfConfig { orbitals_per_atom: 12, max_iter: 10, ..Default::default() });
        let pos = geom();
        let t0 = Instant::now();
        small.scf(&pos);
        let ts = t0.elapsed();
        let t1 = Instant::now();
        big.scf(&pos);
        let tb = t1.elapsed();
        assert!(tb > ts, "bigger basis must cost more ({ts:?} vs {tb:?})");
    }
}
