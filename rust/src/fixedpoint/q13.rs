//! `Q13` — the paper's signed 13-bit Q(1,2,10) datapath value, optimized
//! for the simulator hot path.
//!
//! Stored sign-extended in an `i32`; all operations reproduce the RTL
//! conventions of the generic [`super::Fix`] implementation (saturating,
//! truncating) and a property test in this module asserts agreement.

use super::{FxFormat, shift_raw};

/// Number of fractional bits (binary point position).
pub const FRAC: u32 = 10;
/// Total bits including sign.
pub const BITS: u32 = 13;
/// Max raw value (+3.999…).
pub const MAX_RAW: i32 = (1 << (BITS - 1)) - 1; // 4095
/// Min raw value (−4.0).
pub const MIN_RAW: i32 = -(1 << (BITS - 1)); // -4096
/// Value of one LSB.
pub const LSB: f64 = 1.0 / (1 << FRAC) as f64;

/// A Q(1,2,10) fixed-point value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Q13(pub i32);

#[inline(always)]
fn sat(x: i32) -> i32 {
    x.clamp(MIN_RAW, MAX_RAW)
}

impl Q13 {
    pub const ZERO: Q13 = Q13(0);
    pub const ONE: Q13 = Q13(1 << FRAC);
    pub const MAX: Q13 = Q13(MAX_RAW);
    pub const MIN: Q13 = Q13(MIN_RAW);

    /// Round-to-nearest, saturating conversion from f64 (host side; the
    /// core profile works on raw Q13 only).
    #[cfg(feature = "std")]
    #[inline]
    pub fn from_f64(x: f64) -> Q13 {
        if x.is_nan() {
            return Q13(0);
        }
        let r = (x * (1 << FRAC) as f64).round();
        if r >= MAX_RAW as f64 {
            Q13(MAX_RAW)
        } else if r <= MIN_RAW as f64 {
            Q13(MIN_RAW)
        } else {
            Q13(r as i32)
        }
    }

    #[cfg(feature = "std")]
    #[inline(always)]
    pub fn to_f64(self) -> f64 {
        self.0 as f64 * LSB
    }

    /// Saturating add.
    #[inline(always)]
    pub fn add(self, o: Q13) -> Q13 {
        Q13(sat(self.0 + o.0))
    }

    /// Saturating subtract.
    #[inline(always)]
    pub fn sub(self, o: Q13) -> Q13 {
        Q13(sat(self.0 - o.0))
    }

    /// Saturating negate.
    #[inline(always)]
    pub fn neg(self) -> Q13 {
        Q13(sat(-self.0))
    }

    /// Hardware multiply: full 26-bit product, truncate (arithmetic right
    /// shift) the 10 extra fraction bits, saturate.
    #[inline(always)]
    pub fn mul(self, o: Q13) -> Q13 {
        let wide = (self.0 as i64) * (o.0 as i64);
        Q13(sat((wide >> FRAC) as i32))
    }

    /// The paper's shift P(x, n) (Eq. 11), saturating.
    #[inline(always)]
    pub fn shift(self, n: i32) -> Q13 {
        Q13(sat(shift_raw(self.0 as i64, n).clamp(i32::MIN as i64, i32::MAX as i64) as i32))
    }

    /// |x| with saturation (|MIN| would overflow 13 bits).
    #[inline(always)]
    pub fn abs(self) -> Q13 {
        if self.0 < 0 {
            self.neg()
        } else {
            self
        }
    }

    pub fn raw(self) -> i32 {
        self.0
    }
}

/// Multiply-accumulate over slices in a *wide* (i64) accumulator, then a
/// single truncate+saturate at the end. This models an RTL dot-product
/// unit with a full-width accumulator — used by the FQNN reference
/// datapath.
pub fn dot_wide(a: &[Q13], b: &[Q13]) -> Q13 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc: i64 = 0;
    for (x, y) in a.iter().zip(b) {
        acc += (x.0 as i64) * (y.0 as i64);
    }
    Q13(sat((acc >> FRAC) as i32))
}

/// The format descriptor corresponding to `Q13`.
pub fn format() -> FxFormat {
    FxFormat::Q1_2_10
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Fix;
    use crate::util::rng::Pcg;

    #[test]
    fn constants() {
        assert_eq!(Q13::ONE.to_f64(), 1.0);
        assert_eq!(Q13::MAX.to_f64(), 4.0 - LSB);
        assert_eq!(Q13::MIN.to_f64(), -4.0);
    }

    #[test]
    fn agrees_with_generic_fix() {
        // Property: Q13 ops == generic Fix ops in the Q(1,2,10) format for
        // random operands (including extremes).
        let fmt = format();
        let mut rng = Pcg::new(2024);
        for _ in 0..20_000 {
            let xa = rng.range(-5.0, 5.0);
            let xb = rng.range(-5.0, 5.0);
            let (a, b) = (Q13::from_f64(xa), Q13::from_f64(xb));
            let (fa, fb) = (Fix::from_f64(xa, fmt), Fix::from_f64(xb, fmt));
            assert_eq!(a.0 as i64, fa.raw, "encode {xa}");
            assert_eq!(a.add(b).0 as i64, fa.add(fb).raw, "add {xa} {xb}");
            assert_eq!(a.sub(b).0 as i64, fa.sub(fb).raw, "sub {xa} {xb}");
            assert_eq!(a.mul(b).0 as i64, fa.mul(fb).raw, "mul {xa} {xb}");
            let n = (rng.below(9) as i32) - 4;
            assert_eq!(a.shift(n).0 as i64, fa.shift(n).raw, "shift {xa} by {n}");
        }
    }

    #[test]
    fn saturation_edges() {
        assert_eq!(Q13::MAX.add(Q13::ONE), Q13::MAX);
        assert_eq!(Q13::MIN.sub(Q13::ONE), Q13::MIN);
        assert_eq!(Q13::MIN.neg(), Q13::MAX); // |−4096| saturates to 4095
        assert_eq!(Q13::MAX.mul(Q13::MAX), Q13::MAX);
        assert_eq!(Q13::MAX.mul(Q13::MIN), Q13::MIN);
        assert_eq!(Q13::from_f64(2.0).shift(1), Q13::MAX);
        assert_eq!(Q13::from_f64(-2.5).shift(1), Q13::MIN);
    }

    #[test]
    fn mul_truncation_sign() {
        // 3·2⁻¹⁰ × 0.5 = 1.5·2⁻¹⁰ → 1 (trunc toward −∞); negative → −2.
        assert_eq!(Q13(3).mul(Q13::from_f64(0.5)).0, 1);
        assert_eq!(Q13(-3).mul(Q13::from_f64(0.5)).0, -2);
    }

    #[test]
    fn dot_wide_matches_float_within_lsb() {
        let mut rng = Pcg::new(7);
        for _ in 0..200 {
            let n = 1 + rng.below(16) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.range(-0.4, 0.4)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.range(-0.4, 0.4)).collect();
            let qa: Vec<Q13> = a.iter().map(|&x| Q13::from_f64(x)).collect();
            let qb: Vec<Q13> = b.iter().map(|&x| Q13::from_f64(x)).collect();
            let exact: f64 = qa.iter().zip(&qb).map(|(x, y)| x.to_f64() * y.to_f64()).sum();
            let got = dot_wide(&qa, &qb).to_f64();
            assert!((got - exact).abs() <= LSB, "n={n} got={got} exact={exact}");
        }
    }

    #[test]
    fn roundtrip_is_lossless_on_grid() {
        for raw in [MIN_RAW, -1, 0, 1, 512, MAX_RAW] {
            let q = Q13(raw);
            assert_eq!(Q13::from_f64(q.to_f64()), q);
        }
    }
}
