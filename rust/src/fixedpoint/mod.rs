//! Signed fixed-point arithmetic — the numeric substrate of the paper's
//! datapath.
//!
//! The paper's three modules all use **signed 13-bit fixed point: 1 sign
//! bit, 2 integer bits, 10 fractional bits** (§IV-C), i.e. range [−4, 4)
//! with LSB 2⁻¹⁰. The FQNN comparison baseline (Fig. 5) uses 16-bit fixed
//! point. Two implementations are provided:
//!
//! * [`Q13`] — the hot-path type: a 13-bit value sign-extended in an
//!   `i32`, with saturating hardware-style ops (truncating multiply,
//!   arithmetic shifts). This is what the ASIC/FPGA simulators compute
//!   with, bit for bit.
//! * [`Fix`] + [`FxFormat`] — a general runtime-parametrized format used
//!   by the FQNN baseline and by format-exploration benches.
//!
//! Rounding conventions (documented because they are part of the modelled
//! RTL): float→fixed conversion rounds to nearest (ties away from zero),
//! datapath multiplies/shifts truncate toward −∞ (Verilog `>>>`), and all
//! datapath results saturate symmetrically at the format limits.
//!
//! Core/host seam: the integer datapath (raw add/sub/mul/shift, `Q13`,
//! [`shift_raw`]) compiles in the embedded core profile; the float
//! encode/decode conveniences are host-only (`std`), keeping the core
//! float-free.

pub mod q13;
pub use q13::Q13;

/// A signed fixed-point format: `total_bits` including sign, of which
/// `frac_bits` are fractional.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FxFormat {
    pub total_bits: u32,
    pub frac_bits: u32,
}

impl FxFormat {
    /// The paper's system format: 1 sign + 2 integer + 10 fraction.
    pub const Q1_2_10: FxFormat = FxFormat { total_bits: 13, frac_bits: 10 };
    /// The FQNN baseline format of Fig. 5 (16-bit fixed point; we keep the
    /// same 10-bit binary point so both formats share signal scaling).
    pub const Q16: FxFormat = FxFormat { total_bits: 16, frac_bits: 10 };

    pub fn new(total_bits: u32, frac_bits: u32) -> Self {
        assert!(total_bits >= 2 && total_bits <= 63);
        assert!(frac_bits < total_bits);
        FxFormat { total_bits, frac_bits }
    }
    /// Largest representable raw value: 2^(total-1) − 1.
    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.total_bits - 1)) - 1
    }
    /// Smallest representable raw value: −2^(total-1).
    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.total_bits - 1))
    }
    /// Value of one least-significant bit (host-side float view).
    #[cfg(feature = "std")]
    pub fn lsb(&self) -> f64 {
        (2f64).powi(-(self.frac_bits as i32))
    }
    /// Largest representable value.
    #[cfg(feature = "std")]
    pub fn max_value(&self) -> f64 {
        self.max_raw() as f64 * self.lsb()
    }
    /// Smallest representable value.
    #[cfg(feature = "std")]
    pub fn min_value(&self) -> f64 {
        self.min_raw() as f64 * self.lsb()
    }
    /// Encode a float: round to nearest, saturate.
    #[cfg(feature = "std")]
    pub fn encode(&self, x: f64) -> i64 {
        if x.is_nan() {
            return 0;
        }
        let scaled = x * (1i64 << self.frac_bits) as f64;
        let r = scaled.round() as i64;
        r.clamp(self.min_raw(), self.max_raw())
    }
    /// Decode a raw value to float.
    #[cfg(feature = "std")]
    pub fn decode(&self, raw: i64) -> f64 {
        raw as f64 * self.lsb()
    }
    /// Quantize a float through this format (encode∘decode).
    #[cfg(feature = "std")]
    pub fn quantize(&self, x: f64) -> f64 {
        self.decode(self.encode(x))
    }
    /// Saturate an (already scaled) raw value into range.
    pub fn saturate(&self, raw: i64) -> i64 {
        raw.clamp(self.min_raw(), self.max_raw())
    }
}

/// A value in a runtime-chosen fixed-point format.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fix {
    pub raw: i64,
    pub fmt: FxFormat,
}

impl Fix {
    #[cfg(feature = "std")]
    pub fn from_f64(x: f64, fmt: FxFormat) -> Self {
        Fix { raw: fmt.encode(x), fmt }
    }
    #[cfg(feature = "std")]
    pub fn to_f64(self) -> f64 {
        self.fmt.decode(self.raw)
    }
    pub fn zero(fmt: FxFormat) -> Self {
        Fix { raw: 0, fmt }
    }
    /// Saturating add (same format required).
    pub fn add(self, o: Fix) -> Fix {
        assert_eq!(self.fmt, o.fmt);
        Fix { raw: self.fmt.saturate(self.raw + o.raw), fmt: self.fmt }
    }
    pub fn sub(self, o: Fix) -> Fix {
        assert_eq!(self.fmt, o.fmt);
        Fix { raw: self.fmt.saturate(self.raw - o.raw), fmt: self.fmt }
    }
    /// Saturating multiply with truncation toward −∞ of the extra
    /// fractional bits (hardware `>>>`).
    pub fn mul(self, o: Fix) -> Fix {
        assert_eq!(self.fmt, o.fmt);
        let wide = (self.raw as i128) * (o.raw as i128);
        let shifted = wide >> self.fmt.frac_bits;
        Fix { raw: self.fmt.saturate(shifted as i64), fmt: self.fmt }
    }
    /// Arithmetic shift by `n` (+left/−right), saturating.
    pub fn shift(self, n: i32) -> Fix {
        let raw = shift_raw(self.raw, n);
        Fix { raw: self.fmt.saturate(raw), fmt: self.fmt }
    }
    pub fn neg(self) -> Fix {
        Fix { raw: self.fmt.saturate(-self.raw), fmt: self.fmt }
    }
}

/// The paper's shift function P(x, n) (Eq. 11) on raw integers:
/// left shift for n>0, arithmetic right shift for n<0, identity for n=0.
pub fn shift_raw(x: i64, n: i32) -> i64 {
    if n > 0 {
        if n >= 63 {
            return if x >= 0 { i64::MAX } else { i64::MIN };
        }
        // detect overflow of the left shift
        let shifted = x << n;
        if (shifted >> n) != x {
            if x >= 0 {
                i64::MAX
            } else {
                i64::MIN
            }
        } else {
            shifted
        }
    } else if n < 0 {
        let k = (-n).min(63);
        x >> k
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q1_2_10_range_and_lsb() {
        let f = FxFormat::Q1_2_10;
        assert_eq!(f.max_raw(), 4095);
        assert_eq!(f.min_raw(), -4096);
        assert!((f.lsb() - 0.0009765625).abs() < 1e-15);
        assert!((f.max_value() - 3.9990234375).abs() < 1e-12);
        assert_eq!(f.min_value(), -4.0);
    }

    #[test]
    fn encode_rounds_to_nearest() {
        let f = FxFormat::Q1_2_10;
        assert_eq!(f.encode(0.0), 0);
        assert_eq!(f.encode(1.0), 1024);
        assert_eq!(f.encode(f.lsb() * 0.49), 0);
        assert_eq!(f.encode(f.lsb() * 0.51), 1);
        assert_eq!(f.encode(-f.lsb() * 0.51), -1);
        // saturation
        assert_eq!(f.encode(100.0), 4095);
        assert_eq!(f.encode(-100.0), -4096);
        assert_eq!(f.encode(f64::NAN), 0);
    }

    #[test]
    fn quantization_error_bounded_by_half_lsb() {
        let f = FxFormat::Q1_2_10;
        let mut x = -3.9;
        while x < 3.9 {
            let q = f.quantize(x);
            assert!((q - x).abs() <= f.lsb() / 2.0 + 1e-15, "x={x} q={q}");
            x += 0.00137;
        }
    }

    #[test]
    fn fix_arithmetic() {
        let f = FxFormat::Q1_2_10;
        let a = Fix::from_f64(1.5, f);
        let b = Fix::from_f64(-0.75, f);
        assert_eq!(a.add(b).to_f64(), 0.75);
        assert_eq!(a.sub(b).to_f64(), 2.25);
        assert_eq!(a.mul(b).to_f64(), -1.125);
        // saturating add
        let big = Fix::from_f64(3.9, f);
        assert_eq!(big.add(big).raw, f.max_raw());
        let nbig = Fix::from_f64(-4.0, f);
        assert_eq!(nbig.add(nbig).raw, f.min_raw());
    }

    #[test]
    fn mul_truncates_toward_neg_inf() {
        let f = FxFormat::Q1_2_10;
        // 3 LSB * 0.5 = 1.5 LSB → truncates to 1 LSB; negative → −2 LSB.
        let three = Fix { raw: 3, fmt: f };
        let half = Fix::from_f64(0.5, f);
        assert_eq!(three.mul(half).raw, 1);
        let nthree = Fix { raw: -3, fmt: f };
        assert_eq!(nthree.mul(half).raw, -2);
    }

    #[test]
    fn shift_raw_matches_eq11() {
        assert_eq!(shift_raw(5, 2), 20);
        assert_eq!(shift_raw(5, -1), 2);
        assert_eq!(shift_raw(-5, -1), -3); // arithmetic shift, toward −∞
        assert_eq!(shift_raw(7, 0), 7);
        assert_eq!(shift_raw(1, 100), i64::MAX);
        assert_eq!(shift_raw(-1, 100), i64::MIN);
        assert_eq!(shift_raw(-1, -100), -1);
        assert_eq!(shift_raw(1, -100), 0);
    }

    #[test]
    fn q16_wider_than_q13() {
        let a = FxFormat::Q1_2_10;
        let b = FxFormat::Q16;
        assert!(b.max_value() > a.max_value());
        assert_eq!(a.lsb(), b.lsb()); // same binary point by design
    }
}
