//! Temperature control and velocity initialization for sampling runs
//! (training-data generation uses a thermostatted trajectory; property
//! measurements run NVE like the paper).

use super::System;
use crate::util::rng::Pcg;
use crate::util::units::{ACC_CONV, KB};

/// Instantaneous temperature from kinetic energy, using `dof` degrees of
/// freedom (3N − constraints). T = 2·KE / (dof·k_B).
pub fn instantaneous_temperature(sys: &System, dof: usize) -> f64 {
    2.0 * sys.kinetic_energy() / (dof as f64 * KB)
}

/// Draw Maxwell–Boltzmann velocities at temperature `t_k`, remove the
/// center-of-mass drift, and rescale to hit `t_k` exactly.
pub fn initialize_velocities(sys: &mut System, t_k: f64, dof: usize, rng: &mut Pcg) {
    for (v, &m) in sys.vel.iter_mut().zip(&sys.masses) {
        // σ_v = sqrt(kB·T/m) in Å/fs (converted via ACC_CONV).
        let sigma = (KB * t_k * ACC_CONV / m).sqrt();
        v.x = rng.normal() * sigma;
        v.y = rng.normal() * sigma;
        v.z = rng.normal() * sigma;
    }
    sys.zero_momentum();
    let t_now = instantaneous_temperature(sys, dof);
    if t_now > 0.0 {
        let s = (t_k / t_now).sqrt();
        for v in &mut sys.vel {
            *v = *v * s;
        }
    }
}

/// Berendsen weak-coupling rescale toward `t_target` with coupling ratio
/// dt/τ. Call once per step during equilibration.
pub fn berendsen_rescale(sys: &mut System, t_target: f64, dof: usize, dt_over_tau: f64) {
    let t_now = instantaneous_temperature(sys, dof);
    if t_now <= 0.0 {
        return;
    }
    let lambda = (1.0 + dt_over_tau * (t_target / t_now - 1.0)).max(0.0).sqrt();
    for v in &mut sys.vel {
        *v = *v * lambda;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Vec3;

    fn water_like() -> System {
        System::new(
            vec![Vec3::ZERO, Vec3::new(0.97, 0.0, 0.0), Vec3::new(-0.24, 0.94, 0.0)],
            vec![15.999, 1.008, 1.008],
        )
    }

    #[test]
    fn init_hits_target_temperature() {
        let mut sys = water_like();
        let mut rng = Pcg::new(8);
        initialize_velocities(&mut sys, 300.0, 6, &mut rng);
        let t = instantaneous_temperature(&sys, 6);
        assert!((t - 300.0).abs() < 1e-9, "t={t}");
        assert!(sys.momentum().norm() < 1e-12);
    }

    #[test]
    fn berendsen_moves_toward_target() {
        let mut sys = water_like();
        let mut rng = Pcg::new(9);
        initialize_velocities(&mut sys, 600.0, 6, &mut rng);
        for _ in 0..200 {
            berendsen_rescale(&mut sys, 300.0, 6, 0.05);
        }
        let t = instantaneous_temperature(&sys, 6);
        assert!((t - 300.0).abs() < 5.0, "t={t}");
    }

    #[test]
    fn hydrogen_speeds_physical() {
        // Maxwell–Boltzmann at 300 K: hydrogen RMS speed ≈ 0.0272 Å/fs.
        // Use a large all-H system so the COM-removal correction is O(1/N).
        let n = 64;
        let mut sys = System::new(vec![Vec3::ZERO; n], vec![1.008; n]);
        let dof = 3 * n - 3;
        let mut rng = Pcg::new(10);
        let mut ms = 0.0;
        let trials = 400;
        for _ in 0..trials {
            initialize_velocities(&mut sys, 300.0, dof, &mut rng);
            ms += sys.vel.iter().map(|v| v.norm_sq()).sum::<f64>() / n as f64;
        }
        let h_rms = (ms / trials as f64).sqrt();
        let expect = (3.0 * KB * 300.0 * ACC_CONV / 1.008).sqrt();
        assert!((h_rms - expect).abs() < 0.02 * expect, "h_rms={h_rms} expect={expect}");
    }
}
