//! Molecular-dynamics engine: force-field abstraction, integrators
//! (velocity Verlet for reference runs; the paper's semi-implicit Euler,
//! Eqs. (2)–(3), as used by the FPGA integration module), thermostats,
//! and trajectory sampling.

pub mod integrator;
pub mod thermostat;

pub use integrator::{Integrator, euler_step, verlet_step};
pub use thermostat::{berendsen_rescale, initialize_velocities, instantaneous_temperature};

use crate::util::Vec3;

/// A conservative force field: fills `forces` and returns the potential
/// energy (eV). `forces.len()` must equal `pos.len()`.
pub trait ForceField {
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64;

    /// Optional human-readable name for reports.
    fn name(&self) -> &'static str {
        "forcefield"
    }
}

impl<T: ForceField + ?Sized> ForceField for &T {
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        (**self).compute(pos, forces)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Mutable state of an MD system.
#[derive(Debug, Clone)]
pub struct System {
    pub pos: Vec<Vec3>,
    pub vel: Vec<Vec3>,
    pub masses: Vec<f64>,
}

impl System {
    pub fn new(pos: Vec<Vec3>, masses: Vec<f64>) -> Self {
        let n = pos.len();
        assert_eq!(masses.len(), n);
        System { pos, vel: vec![Vec3::ZERO; n], masses }
    }

    pub fn len(&self) -> usize {
        self.pos.len()
    }
    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }

    /// Kinetic energy in eV: ½ Σ m v² / ACC_CONV (because v is Å/fs and
    /// m·v² is amu·Å²/fs² = (1/ACC_CONV) eV).
    pub fn kinetic_energy(&self) -> f64 {
        let s: f64 = self
            .vel
            .iter()
            .zip(&self.masses)
            .map(|(v, m)| 0.5 * m * v.norm_sq())
            .sum();
        s / crate::util::units::ACC_CONV
    }

    /// Total linear momentum (amu·Å/fs).
    pub fn momentum(&self) -> Vec3 {
        self.vel
            .iter()
            .zip(&self.masses)
            .fold(Vec3::ZERO, |acc, (v, m)| acc + *v * *m)
    }

    /// Remove center-of-mass velocity.
    pub fn zero_momentum(&mut self) {
        let total_m: f64 = self.masses.iter().sum();
        let p = self.momentum();
        let v_cm = p / total_m;
        for v in &mut self.vel {
            *v -= v_cm;
        }
    }

    /// Shift positions so the center of mass sits at the origin.
    pub fn center(&mut self) {
        let total_m: f64 = self.masses.iter().sum();
        let com = self
            .pos
            .iter()
            .zip(&self.masses)
            .fold(Vec3::ZERO, |acc, (r, m)| acc + *r * *m)
            / total_m;
        for r in &mut self.pos {
            *r -= com;
        }
    }
}

/// An MD driver owning a system, a force field, and scratch buffers.
pub struct Engine<'a, F: ForceField + ?Sized> {
    pub sys: System,
    pub ff: &'a F,
    pub dt: f64,
    forces: Vec<Vec3>,
    pub potential_energy: f64,
    pub steps_done: u64,
}

impl<'a, F: ForceField + ?Sized> Engine<'a, F> {
    pub fn new(sys: System, ff: &'a F, dt: f64) -> Self {
        let n = sys.len();
        let mut e = Engine {
            sys,
            ff,
            dt,
            forces: vec![Vec3::ZERO; n],
            potential_energy: 0.0,
            steps_done: 0,
        };
        e.potential_energy = e.ff.compute(&e.sys.pos, &mut e.forces);
        e
    }

    pub fn forces(&self) -> &[Vec3] {
        &self.forces
    }

    /// One velocity-Verlet step (reference/high-accuracy path).
    pub fn step_verlet(&mut self) {
        self.potential_energy =
            verlet_step(&mut self.sys, self.ff, self.dt, &mut self.forces);
        self.steps_done += 1;
    }

    /// One semi-implicit-Euler step, the paper's Eqs. (2)–(3):
    /// v(t) = v(t−dt) + F(t)/m·dt, then r(t+dt) = r(t) + v(t)·dt.
    pub fn step_euler(&mut self) {
        self.potential_energy =
            euler_step(&mut self.sys, self.ff, self.dt, &mut self.forces);
        self.steps_done += 1;
    }

    /// Total energy (eV).
    pub fn total_energy(&self) -> f64 {
        self.potential_energy + self.sys.kinetic_energy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units;

    struct Harmonic3d {
        k: f64,
    }
    impl ForceField for Harmonic3d {
        fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
            let mut e = 0.0;
            for (p, f) in pos.iter().zip(forces.iter_mut()) {
                *f = *p * (-self.k);
                e += 0.5 * self.k * p.norm_sq();
            }
            e
        }
    }

    #[test]
    fn verlet_conserves_energy_harmonic() {
        let ff = Harmonic3d { k: 10.0 };
        let sys = System::new(vec![Vec3::new(0.3, 0.0, 0.0)], vec![1.0]);
        let period = 2.0 * std::f64::consts::PI / (10.0f64 * units::ACC_CONV).sqrt();
        let dt = period / 100.0;
        let mut e = Engine::new(sys, &ff, dt);
        let e0 = e.total_energy();
        for _ in 0..10_000 {
            e.step_verlet();
        }
        let drift = (e.total_energy() - e0).abs() / e0;
        assert!(drift < 1e-4, "drift={drift}");
    }

    #[test]
    fn euler_tracks_verlet_for_small_dt() {
        let ff = Harmonic3d { k: 10.0 };
        let mut sys = System::new(vec![Vec3::new(0.2, 0.1, 0.0)], vec![1.0]);
        sys.vel[0] = Vec3::new(0.0, 0.01, 0.0);
        let dt = 0.01;
        let mut a = Engine::new(sys.clone(), &ff, dt);
        let mut b = Engine::new(sys, &ff, dt);
        for _ in 0..200 {
            a.step_verlet();
            b.step_euler();
        }
        let d = (a.sys.pos[0] - b.sys.pos[0]).norm();
        assert!(d < 5e-3, "divergence {d}");
    }

    #[test]
    fn euler_oscillator_stays_bounded() {
        // Semi-implicit Euler is symplectic: energy oscillates but stays
        // bounded over long runs.
        let ff = Harmonic3d { k: 30.0 };
        let sys = System::new(vec![Vec3::new(0.3, 0.0, 0.0)], vec![1.0]);
        let mut e = Engine::new(sys, &ff, 0.05);
        let e0 = e.total_energy();
        let mut max_e: f64 = 0.0;
        for _ in 0..50_000 {
            e.step_euler();
            max_e = max_e.max(e.total_energy());
        }
        assert!(max_e < 1.5 * e0, "max={max_e} e0={e0}");
    }

    #[test]
    fn momentum_tools() {
        let mut sys = System::new(
            vec![Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0)],
            vec![2.0, 1.0],
        );
        sys.vel = vec![Vec3::new(1.0, 0.0, 0.0), Vec3::new(-1.0, 1.0, 0.0)];
        assert_eq!(sys.momentum(), Vec3::new(1.0, 1.0, 0.0));
        sys.zero_momentum();
        assert!(sys.momentum().norm() < 1e-12);
        sys.center();
        let com = sys.pos[0] * 2.0 + sys.pos[1];
        assert!(com.norm() < 1e-12);
    }
}
