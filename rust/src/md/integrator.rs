//! Integration schemes.
//!
//! * [`verlet_step`] — velocity Verlet, the reference integrator for
//!   oracle (AIMD-surrogate) trajectories.
//! * [`euler_step`] — the paper's semi-implicit Euler (Eqs. (2)–(3)),
//!   which is what the FPGA integration module implements:
//!   `v(t) = v(t−dt) + F(t)/m·dt`, then `r(t+dt) = r(t) + v(t)·dt`.
//!
//! These are the *float references*. The fixed-point integrator the
//! devices actually run — the 26-bit MAC with round-to-nearest
//! renormalization — is `fpga::qint::mac_step` in the float-free core
//! profile; the `fpga` tests hold the two within drift tolerances.

use super::{ForceField, System};
use crate::util::units::ACC_CONV;
use crate::util::Vec3;

/// Which integrator a driver uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Integrator {
    VelocityVerlet,
    /// The paper's Eq. (2)–(3) scheme.
    SemiImplicitEuler,
}

/// One velocity-Verlet step. `forces` must hold F(r(t)) on entry and
/// holds F(r(t+dt)) on exit. Returns the new potential energy.
pub fn verlet_step<F: ForceField + ?Sized>(
    sys: &mut System,
    ff: &F,
    dt: f64,
    forces: &mut Vec<Vec3>,
) -> f64 {
    let n = sys.len();
    debug_assert_eq!(forces.len(), n);
    // half kick + drift
    for i in 0..n {
        let a = forces[i] * (ACC_CONV / sys.masses[i]);
        sys.vel[i] += a * (0.5 * dt);
        sys.pos[i] += sys.vel[i] * dt;
    }
    // new forces
    let pe = ff.compute(&sys.pos, forces);
    // half kick
    for i in 0..n {
        let a = forces[i] * (ACC_CONV / sys.masses[i]);
        sys.vel[i] += a * (0.5 * dt);
    }
    pe
}

/// One semi-implicit Euler step (paper Eqs. (2)–(3)). `forces` must hold
/// F(r(t)) on entry; on exit holds F(r(t+dt)). Returns the new potential
/// energy.
pub fn euler_step<F: ForceField + ?Sized>(
    sys: &mut System,
    ff: &F,
    dt: f64,
    forces: &mut Vec<Vec3>,
) -> f64 {
    let n = sys.len();
    debug_assert_eq!(forces.len(), n);
    for i in 0..n {
        // Eq. (3): v(t) = v(t−dt) + F(t)/m·dt
        let a = forces[i] * (ACC_CONV / sys.masses[i]);
        sys.vel[i] += a * dt;
        // Eq. (2): r(t+dt) = r(t) + v(t)·dt
        sys.pos[i] += sys.vel[i] * dt;
    }
    ff.compute(&sys.pos, forces)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1-D constant force field: F = (c, 0, 0) per atom.
    struct Constant {
        c: f64,
    }
    impl ForceField for Constant {
        fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
            for f in forces.iter_mut() {
                *f = Vec3::new(self.c, 0.0, 0.0);
            }
            -self.c * pos[0].x
        }
    }

    #[test]
    fn constant_force_kinematics() {
        // Under constant acceleration both schemes must reproduce
        // v = a·t exactly; positions agree with the discrete-scheme sums.
        let ff = Constant { c: 2.0 };
        let m = 4.0;
        let dt = 0.1;
        let a = 2.0 * ACC_CONV / m;
        let steps = 100;

        let sys0 = System::new(vec![Vec3::ZERO], vec![m]);

        let mut fbuf = vec![Vec3::ZERO; 1];
        ff.compute(&sys0.pos, &mut fbuf);
        let mut s_e = sys0.clone();
        let mut f_e = fbuf.clone();
        for _ in 0..steps {
            euler_step(&mut s_e, &ff, dt, &mut f_e);
        }
        let t = steps as f64 * dt;
        assert!((s_e.vel[0].x - a * t).abs() < 1e-12);
        // semi-implicit Euler: x = Σ_{k=1..N} a·k·dt·dt = a·dt²·N(N+1)/2
        let x_expect = a * dt * dt * (steps * (steps + 1)) as f64 / 2.0;
        assert!((s_e.pos[0].x - x_expect).abs() < 1e-12);

        let mut s_v = sys0;
        let mut f_v = fbuf;
        for _ in 0..steps {
            verlet_step(&mut s_v, &ff, dt, &mut f_v);
        }
        assert!((s_v.vel[0].x - a * t).abs() < 1e-12);
        // Verlet: x = ½·a·t² exactly for constant a
        assert!((s_v.pos[0].x - 0.5 * a * t * t).abs() < 1e-10);
    }

    #[test]
    fn both_schemes_preserve_zero_state() {
        struct Null;
        impl ForceField for Null {
            fn compute(&self, _pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
                for f in forces.iter_mut() {
                    *f = Vec3::ZERO;
                }
                0.0
            }
        }
        let mut sys = System::new(vec![Vec3::new(1.0, 2.0, 3.0)], vec![1.0]);
        let mut f = vec![Vec3::ZERO; 1];
        euler_step(&mut sys, &Null, 0.5, &mut f);
        verlet_step(&mut sys, &Null, 0.5, &mut f);
        assert_eq!(sys.pos[0], Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(sys.vel[0], Vec3::ZERO);
    }
}
