//! Benchmark harness (criterion is unavailable offline).
//!
//! Each file in `rust/benches/` is a `harness = false` target whose
//! `main` builds a [`Bench`] and registers measurements and report
//! sections. Reports print the paper's table/figure alongside measured
//! values, and are additionally written to `artifacts/bench/<name>.json`
//! so EXPERIMENTS.md numbers are regenerable.

use std::time::{Duration, Instant};

use crate::util::json::{self, Value};

/// Statistics of a timed measurement.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn per_iter(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

/// A benchmark session.
pub struct Bench {
    pub name: String,
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
    results: Vec<Stats>,
    report: Vec<(String, String)>,
    extra: Vec<(String, Value)>,
}

/// Quick mode for CI-style smoke runs (`NVNMD_BENCH_QUICK=1`): the one
/// place the protocol is parsed — bench bodies that scale their own
/// workloads (tick counts, molecule counts) must use this too, so they
/// can never drift from the warmup/measure windows.
pub fn quick_mode() -> bool {
    std::env::var("NVNMD_BENCH_QUICK").ok().as_deref() == Some("1")
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let quick = quick_mode();
        Bench {
            name: name.to_string(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(150) },
            measure: if quick { Duration::from_millis(80) } else { Duration::from_millis(600) },
            min_samples: 10,
            results: Vec::new(),
            report: Vec::new(),
            extra: Vec::new(),
        }
    }

    /// Time `f`, which performs exactly one operation per call. A
    /// `black_box`-style sink prevents the optimizer from deleting work:
    /// return a value and it is consumed via `std::hint::black_box`.
    pub fn measure<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup & calibration: find iterations per sample ≈ 1ms.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters_per_sample = ((1_000_000.0 / per).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure || samples.len() < self.min_samples {
            let s = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            samples.push(s.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let median = samples[n / 2];
        let min = samples[0];
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let st = Stats {
            name: name.to_string(),
            iters: iters_per_sample * n as u64,
            mean_ns: mean,
            median_ns: median,
            min_ns: min,
            stddev_ns: var.sqrt(),
        };
        println!(
            "  {:<44} {:>12}/iter  (min {}, n={})",
            name,
            fmt_ns(st.median_ns),
            fmt_ns(st.min_ns),
            n
        );
        self.results.push(st.clone());
        st
    }

    /// Record a one-shot wall-clock measurement of `f` (for end-to-end
    /// runs too long to repeat).
    pub fn measure_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> (T, Duration) {
        let s = Instant::now();
        let out = std::hint::black_box(f());
        let el = s.elapsed();
        println!("  {:<44} {:>12} (single run)", name, fmt_ns(el.as_nanos() as f64));
        self.results.push(Stats {
            name: name.to_string(),
            iters: 1,
            mean_ns: el.as_nanos() as f64,
            median_ns: el.as_nanos() as f64,
            min_ns: el.as_nanos() as f64,
            stddev_ns: 0.0,
        });
        (out, el)
    }

    /// Add a line to the human report (paper-vs-measured commentary).
    pub fn note(&mut self, key: &str, value: impl std::fmt::Display) {
        self.report.push((key.to_string(), value.to_string()));
    }

    /// Attach arbitrary structured data to the JSON report.
    pub fn attach(&mut self, key: &str, value: Value) {
        self.extra.push((key.to_string(), value));
    }

    /// Print the report block and write the JSON artifact.
    pub fn finish(self) {
        println!("\n== {} ==", self.name);
        for (k, v) in &self.report {
            println!("  {k}: {v}");
        }
        let results: Vec<Value> = self
            .results
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("median_ns", json::num(r.median_ns)),
                    ("mean_ns", json::num(r.mean_ns)),
                    ("min_ns", json::num(r.min_ns)),
                    ("stddev_ns", json::num(r.stddev_ns)),
                    ("iters", json::num(r.iters as f64)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("bench", json::s(&self.name)),
            ("results", Value::Arr(results)),
            (
                "notes",
                Value::Obj(
                    self.report
                        .iter()
                        .map(|(k, v)| (k.clone(), json::s(v)))
                        .collect(),
                ),
            ),
        ];
        for (k, v) in &self.extra {
            fields.push((k.as_str(), v.clone()));
        }
        let out = json::obj(fields);
        let path = crate::artifact_path("bench").join(format!("{}.json", self.name));
        if let Err(e) = json::write_file(&path, &out) {
            eprintln!("warning: could not write bench artifact: {e}");
        } else {
            println!("  [report: {}]", path.display());
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        std::env::set_var("NVNMD_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let st = b.measure("sum_1000", || (0..1000u64).sum::<u64>());
        assert!(st.median_ns > 0.0);
        assert!(st.iters > 0);
        // A 1000-element sum should be well under 100µs.
        assert!(st.median_ns < 1e5, "median {}", st.median_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
