//! Dense linear algebra: row-major matrices, Jacobi symmetric
//! eigensolver, and small helpers. Sized for the needs of this system
//! (normal-mode Hessians up to ~100×100 and the toy SCF engine's
//! Hamiltonians up to a few hundred).

pub mod jacobi;

pub use jacobi::eigh;

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }
    pub fn matmul(&self, o: &Mat) -> Mat {
        assert_eq!(self.cols, o.rows, "matmul dims {}x{} · {}x{}", self.rows, self.cols, o.rows, o.cols);
        let mut out = Mat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = o.row(k);
                let out_row = &mut out.data[i * o.cols..(i + 1) * o.cols];
                for (j, &b) in orow.iter().enumerate() {
                    out_row[j] += a * b;
                }
            }
        }
        out
    }
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }
    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, o: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        self.data
            .iter()
            .zip(&o.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
    /// Symmetrize in place: a ← (a + aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in i + 1..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}
impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Solve a small linear system A·x = b by Gaussian elimination with
/// partial pivoting. Panics on exactly singular input.
pub fn solve(a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(a.rows, b.len());
    let n = a.rows;
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // pivot
        let (mut piv, mut best) = (col, m[(col, col)].abs());
        for r in col + 1..n {
            if m[(r, col)].abs() > best {
                piv = r;
                best = m[(r, col)].abs();
            }
        }
        assert!(best > 1e-300, "singular matrix in solve()");
        if piv != col {
            for j in 0..n {
                let tmp = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = tmp;
            }
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        for r in col + 1..n {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                let v = m[(col, j)];
                m[(r, j)] -= f * v;
            }
            x[r] -= f * x[col];
        }
    }
    // back substitution
    for col in (0..n).rev() {
        let mut s = x[col];
        for j in col + 1..n {
            s -= m[(col, j)] * x[j];
        }
        x[col] = s / m[(col, col)];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(&[vec![7.0, 8.0], vec![9.0, 10.0], vec![11.0, 12.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn solve_roundtrip() {
        let a = Mat::from_rows(&[
            vec![4.0, 1.0, 0.0],
            vec![1.0, 3.0, -1.0],
            vec![0.0, -1.0, 2.0],
        ]);
        let x_true = vec![1.0, -2.0, 0.5];
        let b = a.matvec(&x_true);
        let x = solve(&a, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn solve_singular_panics() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        solve(&a, &[1.0, 2.0]);
    }
}
