//! Cyclic Jacobi eigensolver for real symmetric matrices.
//!
//! Used for normal-mode analysis (mass-weighted Hessians) and the toy SCF
//! engine's Hamiltonian diagonalizations. Quadratic convergence; for our
//! sizes (n ≤ a few hundred) this is plenty and avoids any LAPACK
//! dependency.

use super::Mat;

/// Eigendecomposition of a symmetric matrix. Returns `(eigenvalues,
/// eigenvectors)` with eigenvalues ascending and eigenvectors as matrix
/// columns (`vecs[(i, k)]` = component i of eigenvector k), satisfying
/// `A·v_k = λ_k·v_k`.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    // Enforce exact symmetry (tiny asymmetries from FD Hessians).
    m.symmetrize();
    let mut v = Mat::eye(n);

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s
    };

    let scale = m.fro_norm().max(1e-300);
    let tol = (1e-14 * scale).powi(2);
    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tangent of rotation angle
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Apply rotation G(p,q,θ): m ← Gᵀ m G.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| evals[i].partial_cmp(&evals[j]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, n);
    for (newk, &oldk) in order.iter().enumerate() {
        for i in 0..n {
            sorted_vecs[(i, newk)] = v[(i, oldk)];
        }
    }
    (sorted_vals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_symmetric(n: usize, rng: &mut Pcg) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    #[test]
    fn diagonal_is_fixed_point() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 0.5;
        let (vals, _) = eigh(&a);
        assert!((vals[0] + 1.0).abs() < 1e-12);
        assert!((vals[1] - 0.5).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1, 3.
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        // eigenvector of λ=3 is (1,1)/√2 up to sign
        let v = (vecs[(0, 1)], vecs[(1, 1)]);
        assert!((v.0.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v.0 - v.1).abs() < 1e-10);
    }

    #[test]
    fn reconstructs_random_matrices() {
        let mut rng = Pcg::new(1234);
        for &n in &[1usize, 2, 5, 20, 60] {
            let a = random_symmetric(n, &mut rng);
            let (vals, vecs) = eigh(&a);
            // A·V = V·diag(λ)
            let mut lam = Mat::zeros(n, n);
            for i in 0..n {
                lam[(i, i)] = vals[i];
            }
            let lhs = a.matmul(&vecs);
            let rhs = vecs.matmul(&lam);
            assert!(lhs.max_abs_diff(&rhs) < 1e-9 * (1.0 + a.fro_norm()), "n={n}");
            // orthonormality
            let vtv = vecs.transpose().matmul(&vecs);
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-10, "n={n}");
            // ascending order
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
        }
    }

    #[test]
    fn trace_and_frobenius_preserved() {
        let mut rng = Pcg::new(99);
        let a = random_symmetric(15, &mut rng);
        let (vals, _) = eigh(&a);
        let tr: f64 = (0..15).map(|i| a[(i, i)]).sum();
        assert!((vals.iter().sum::<f64>() - tr).abs() < 1e-9);
        let fro2: f64 = a.data.iter().map(|x| x * x).sum();
        assert!((vals.iter().map(|x| x * x).sum::<f64>() - fro2).abs() < 1e-8);
    }
}
