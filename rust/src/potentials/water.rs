//! The water-molecule PES — this reproduction's stand-in for the paper's
//! SIESTA DFT oracle.
//!
//! Functional form (anharmonic, intramolecular):
//!
//! ```text
//! V = Σᵢ D·(1 − e^{−a(rᵢ−r₀)})²          Morse O–H stretches
//!   + ½·k_θ·(θ − θ₀)²                     harmonic bend
//!   + k_rr·(r₁−r₀)(r₂−r₀)                 stretch–stretch coupling
//! ```
//!
//! The equilibrium geometry is (r₀, θ₀) by construction; the three force
//! constants (k_r = 2Da², k_θ, k_rr) are **calibrated at first use** by a
//! Newton iteration on the analytic-Hessian normal modes so the harmonic
//! wavenumbers match the paper's DFT column of Table II:
//! bend 1603, symmetric stretch 4007, asymmetric stretch 4241 cm⁻¹.
//! (Gas-phase DFT of a single molecule — hence stretches above the
//! liquid-phase values.) Calibration is deterministic, takes ~1 ms, and
//! is verified by tests against the targets.

use std::sync::OnceLock;

use crate::md::ForceField;
use crate::util::units::{mass, ACC_CONV, C_CM_PER_FS};
use crate::util::Vec3;

/// Paper Table II, DFT row — the calibration targets.
pub const TARGET_R0: f64 = 0.969; // Å
pub const TARGET_THETA0_DEG: f64 = 104.88;
pub const TARGET_NU_BEND: f64 = 1603.0; // cm⁻¹
pub const TARGET_NU_SYM: f64 = 4007.0;
pub const TARGET_NU_ASYM: f64 = 4241.0;

/// Morse well depth (eV). Fixed (typical O–H bond energy); the width `a`
/// carries the stretch force constant.
pub const MORSE_D: f64 = 5.0;

/// Calibrated parameter set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WaterParams {
    pub r0: f64,
    pub theta0: f64, // radians
    pub d: f64,      // Morse depth, eV
    pub a: f64,      // Morse width, 1/Å
    pub k_theta: f64, // eV/rad²
    pub k_rr: f64,   // eV/Å²
}

/// The PES. Atom order is **[O, H1, H2]**.
#[derive(Debug, Clone, Copy)]
pub struct WaterPes {
    pub p: WaterParams,
}

impl WaterPes {
    /// The calibrated oracle (cached process-wide).
    pub fn dft_surrogate() -> &'static WaterPes {
        static CAL: OnceLock<WaterPes> = OnceLock::new();
        CAL.get_or_init(|| WaterPes { p: calibrate() })
    }

    pub fn with_params(p: WaterParams) -> Self {
        WaterPes { p }
    }

    /// Equilibrium geometry [O, H1, H2], centered with H's symmetric
    /// about the y-axis in the xy-plane (molecule frame).
    pub fn equilibrium(&self) -> Vec<Vec3> {
        equilibrium_geometry(self.p.r0, self.p.theta0)
    }

    /// Masses [O, H, H].
    pub fn masses() -> Vec<f64> {
        vec![mass::O, mass::H, mass::H]
    }

    /// Internal coordinates (r1, r2, θ) of a configuration.
    pub fn internal(pos: &[Vec3]) -> (f64, f64, f64) {
        let u = pos[1] - pos[0];
        let v = pos[2] - pos[0];
        (u.norm(), v.norm(), u.angle_between(v))
    }
}

/// Build the equilibrium geometry for given r0/θ0 (O at origin before
/// mass-centering; the caller may re-center).
pub fn equilibrium_geometry(r0: f64, theta0: f64) -> Vec<Vec3> {
    let half = theta0 / 2.0;
    vec![
        Vec3::ZERO,
        Vec3::new(r0 * half.sin(), r0 * half.cos(), 0.0),
        Vec3::new(-r0 * half.sin(), r0 * half.cos(), 0.0),
    ]
}

impl ForceField for WaterPes {
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        debug_assert_eq!(pos.len(), 3);
        let p = &self.p;
        let (o, h1, h2) = (pos[0], pos[1], pos[2]);
        let u = h1 - o;
        let v = h2 - o;
        let r1 = u.norm();
        let r2 = v.norm();
        let uh = u / r1;
        let vh = v / r2;
        let dr1 = r1 - p.r0;
        let dr2 = r2 - p.r0;

        // Morse stretches.
        let e1 = (-p.a * dr1).exp();
        let e2 = (-p.a * dr2).exp();
        let v_morse = p.d * ((1.0 - e1) * (1.0 - e1) + (1.0 - e2) * (1.0 - e2));
        // dV/dr for Morse.
        let dv_dr1_m = 2.0 * p.d * p.a * (1.0 - e1) * e1;
        let dv_dr2_m = 2.0 * p.d * p.a * (1.0 - e2) * e2;

        // Bend.
        let cos_t = uh.dot(vh).clamp(-1.0, 1.0);
        let theta = cos_t.acos();
        let dth = theta - p.theta0;
        let v_bend = 0.5 * p.k_theta * dth * dth;
        let dv_dtheta = p.k_theta * dth;

        // Stretch–stretch coupling.
        let v_rr = p.k_rr * dr1 * dr2;
        let dv_dr1 = dv_dr1_m + p.k_rr * dr2;
        let dv_dr2 = dv_dr2_m + p.k_rr * dr1;

        // Gradients of internal coordinates.
        // ∂θ/∂(H1) = (cosθ·û − v̂) / (r1·sinθ), ∂θ/∂(H2) symmetric.
        let sin_t = theta.sin().max(1e-9);
        let dth_dh1 = (uh * cos_t - vh) / (r1 * sin_t);
        let dth_dh2 = (vh * cos_t - uh) / (r2 * sin_t);

        let f_h1 = -(uh * dv_dr1 + dth_dh1 * dv_dtheta);
        let f_h2 = -(vh * dv_dr2 + dth_dh2 * dv_dtheta);
        forces[1] = f_h1;
        forces[2] = f_h2;
        forces[0] = -(f_h1 + f_h2); // translation invariance

        v_morse + v_bend + v_rr
    }

    fn name(&self) -> &'static str {
        "water-pes (DFT surrogate)"
    }
}

/// Harmonic wavenumbers (bend, sym, asym) for a parameter set, from the
/// mass-weighted finite-difference Hessian.
pub fn harmonic_wavenumbers(p: WaterParams) -> [f64; 3] {
    let pes = WaterPes { p };
    let pos = pes.equilibrium();
    let masses = WaterPes::masses();
    let modes = crate::analysis::normal_modes::vibrational_modes(&pes, &pos, &masses, 3);
    [modes[0], modes[1], modes[2]] // ascending: bend, sym, asym
}

/// Newton calibration of (k_r, k_θ, k_rr) against the Table II DFT
/// wavenumbers. k_r enters through the Morse width a = sqrt(k_r/(2D)).
fn calibrate() -> WaterParams {
    let theta0 = TARGET_THETA0_DEG.to_radians();
    // Initial guesses from diatomic/G-matrix estimates.
    let mu_oh = mass::O * mass::H / (mass::O + mass::H);
    let nu_avg = 0.5 * (TARGET_NU_SYM + TARGET_NU_ASYM);
    let omega = 2.0 * std::f64::consts::PI * C_CM_PER_FS * nu_avg; // rad/fs
    let k_r0 = mu_oh * omega * omega / ACC_CONV; // eV/Å²
    let mut x = [k_r0, 4.8, 0.0]; // (k_r, k_θ, k_rr)

    let targets = [TARGET_NU_BEND, TARGET_NU_SYM, TARGET_NU_ASYM];
    let params_of = |x: &[f64; 3]| WaterParams {
        r0: TARGET_R0,
        theta0,
        d: MORSE_D,
        a: (x[0] / (2.0 * MORSE_D)).sqrt(),
        k_theta: x[1],
        k_rr: x[2],
    };
    let residual = |x: &[f64; 3]| -> [f64; 3] {
        let nu = harmonic_wavenumbers(params_of(x));
        [nu[0] - targets[0], nu[1] - targets[1], nu[2] - targets[2]]
    };

    for _iter in 0..20 {
        let f = residual(&x);
        let err = f.iter().map(|v| v.abs()).fold(0.0, f64::max);
        if err < 1e-6 {
            break;
        }
        // FD Jacobian.
        let mut jac = crate::linalg::Mat::zeros(3, 3);
        for j in 0..3 {
            let h = (x[j].abs() * 1e-4).max(1e-5);
            let mut xp = x;
            xp[j] += h;
            let fp = residual(&xp);
            for i in 0..3 {
                jac[(i, j)] = (fp[i] - f[i]) / h;
            }
        }
        let dx = crate::linalg::solve(&jac, &[-f[0], -f[1], -f[2]]);
        for j in 0..3 {
            x[j] += dx[j];
        }
        // keep physical
        x[0] = x[0].max(1.0);
        x[1] = x[1].max(0.1);
    }
    params_of(&x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::{Engine, System};

    #[test]
    fn calibrated_frequencies_match_paper_dft() {
        let pes = WaterPes::dft_surrogate();
        let nu = harmonic_wavenumbers(pes.p);
        assert!((nu[0] - TARGET_NU_BEND).abs() < 1.0, "bend={}", nu[0]);
        assert!((nu[1] - TARGET_NU_SYM).abs() < 1.0, "sym={}", nu[1]);
        assert!((nu[2] - TARGET_NU_ASYM).abs() < 1.0, "asym={}", nu[2]);
    }

    #[test]
    fn equilibrium_geometry_matches_targets() {
        let pes = WaterPes::dft_surrogate();
        let pos = pes.equilibrium();
        let (r1, r2, th) = WaterPes::internal(&pos);
        assert!((r1 - TARGET_R0).abs() < 1e-12);
        assert!((r2 - TARGET_R0).abs() < 1e-12);
        assert!((th.to_degrees() - TARGET_THETA0_DEG).abs() < 1e-9);
        // forces vanish at equilibrium
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        for fi in &f {
            assert!(fi.norm() < 1e-9, "{f:?}");
        }
    }

    #[test]
    fn forces_are_gradient_of_energy() {
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.03, -0.02, 0.04);
        pos[2] += Vec3::new(-0.01, 0.05, -0.02);
        pos[0] += Vec3::new(0.02, 0.01, -0.01);
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        let h = 1e-6;
        for i in 0..3 {
            for a in 0..3 {
                let mut pp = pos.clone();
                let mut arr = pp[i].to_array();
                arr[a] += h;
                pp[i] = Vec3::from_array(arr);
                let mut scratch = vec![Vec3::ZERO; 3];
                let ep = pes.compute(&pp, &mut scratch);
                arr[a] -= 2.0 * h;
                pp[i] = Vec3::from_array(arr);
                let em = pes.compute(&pp, &mut scratch);
                let f_num = -(ep - em) / (2.0 * h);
                let f_ana = f[i].to_array()[a];
                assert!(
                    (f_num - f_ana).abs() < 1e-5,
                    "atom {i} axis {a}: num {f_num} ana {f_ana}"
                );
            }
        }
    }

    #[test]
    fn forces_sum_to_zero_and_torque_free() {
        let pes = WaterPes::dft_surrogate();
        let mut pos = pes.equilibrium();
        pos[1] += Vec3::new(0.05, 0.02, -0.03);
        let mut f = vec![Vec3::ZERO; 3];
        pes.compute(&pos, &mut f);
        let net = f[0] + f[1] + f[2];
        assert!(net.norm() < 1e-10, "net force {net:?}");
        let torque = pos[0].cross(f[0]) + pos[1].cross(f[1]) + pos[2].cross(f[2]);
        assert!(torque.norm() < 1e-9, "net torque {torque:?}");
    }

    #[test]
    fn energy_rises_away_from_equilibrium() {
        let pes = WaterPes::dft_surrogate();
        let pos0 = pes.equilibrium();
        let mut scratch = vec![Vec3::ZERO; 3];
        let e0 = pes.compute(&pos0, &mut scratch);
        for (i, delta) in [
            (1usize, Vec3::new(0.1, 0.0, 0.0)),
            (2, Vec3::new(0.0, 0.1, 0.0)),
            (1, Vec3::new(0.0, 0.0, 0.1)),
        ] {
            let mut p = pos0.clone();
            p[i] += delta;
            let e = pes.compute(&p, &mut scratch);
            assert!(e > e0 + 1e-6, "displacement {i} {delta:?}");
        }
    }

    #[test]
    fn nve_md_conserves_energy() {
        let pes = WaterPes::dft_surrogate();
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        // kick an H slightly
        sys.vel[1] = Vec3::new(0.01, -0.005, 0.003);
        sys.zero_momentum();
        let mut eng = Engine::new(sys, pes, 0.1);
        let e0 = eng.total_energy();
        for _ in 0..20_000 {
            eng.step_verlet();
        }
        let drift = (eng.total_energy() - e0).abs();
        assert!(drift < 2e-4, "energy drift {drift} eV over 2 ps");
    }

    #[test]
    fn anharmonicity_present() {
        // Morse: stretching +0.2 Å costs less than 0.5·k·dr² of the
        // harmonic expansion would suggest at large dr (softening).
        let pes = WaterPes::dft_surrogate();
        let k_r = 2.0 * pes.p.d * pes.p.a * pes.p.a;
        let pos0 = pes.equilibrium();
        let mut scratch = vec![Vec3::ZERO; 3];
        let e0 = pes.compute(&pos0, &mut scratch);
        let mut p = pos0.clone();
        let dir = (p[1] - p[0]).normalized();
        p[1] += dir * 0.3;
        let e = pes.compute(&p, &mut scratch);
        let harmonic = 0.5 * k_r * 0.3 * 0.3;
        assert!(e - e0 < harmonic * 0.95, "e−e0={} harmonic={harmonic}", e - e0);
    }
}
