//! Bonded force fields over real molecule topologies — the synthetic
//! stand-in for the MD17 DFT datasets (ethanol, toluene, naphthalene,
//! aspirin). See DESIGN.md §Substitutions: Table I / Figs. 4–5 test
//! *relative* model accuracy and hardware cost across datasets of
//! increasing complexity, which these preserve.
//!
//! Energy terms (reference values r₀/θ₀ taken from the molecule's
//! reference geometry so every topology is exactly at equilibrium there):
//!
//! ```text
//! V = Σ_bonds  k_b·Δr²·(1 − α·Δr)      anharmonic stretch (α = 1 Å⁻¹)
//!   + Σ_angles ½·k_θ·Δθ²               harmonic bend
//! ```

use crate::md::ForceField;
use crate::util::units::mass;
use crate::util::Vec3;

/// Cubic anharmonicity coefficient (Å⁻¹) of the bond term.
pub const ANH_ALPHA: f64 = 1.0;

/// Chemical element of an atom (for masses and bond constants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Element {
    H,
    C,
    O,
    Si,
}

impl Element {
    pub fn mass(self) -> f64 {
        match self {
            Element::H => mass::H,
            Element::C => mass::C,
            Element::O => mass::O,
            Element::Si => mass::SI,
        }
    }
    pub fn symbol(self) -> &'static str {
        match self {
            Element::H => "H",
            Element::C => "C",
            Element::O => "O",
            Element::Si => "Si",
        }
    }
}

/// A harmonic bond between atoms `i`–`j`.
#[derive(Debug, Clone, Copy)]
pub struct Bond {
    pub i: usize,
    pub j: usize,
    pub k: f64,  // eV/Å²
    pub r0: f64, // Å
}

/// A harmonic angle i–j–k with vertex `j`.
#[derive(Debug, Clone, Copy)]
pub struct Angle {
    pub i: usize,
    pub j: usize,
    pub k: usize,
    pub kt: f64,     // eV/rad²
    pub theta0: f64, // rad
}

/// A molecule: elements, reference geometry, bonded terms.
#[derive(Debug, Clone)]
pub struct Molecule {
    pub name: String,
    pub elements: Vec<Element>,
    pub coords: Vec<Vec3>,
    pub bonds: Vec<Bond>,
    pub angles: Vec<Angle>,
}

impl Molecule {
    pub fn n_atoms(&self) -> usize {
        self.elements.len()
    }
    pub fn masses(&self) -> Vec<f64> {
        self.elements.iter().map(|e| e.mass()).collect()
    }
}

/// Force-constant lookup by bonded pair (symmetric), eV/Å².
fn bond_k(a: Element, b: Element) -> f64 {
    use Element::*;
    match (a, b) {
        (H, H) => 25.0,
        (C, H) | (H, C) => 32.0,
        (O, H) | (H, O) => 50.0,
        (C, C) => 28.0,
        (C, O) | (O, C) => 35.0,
        (O, O) => 30.0,
        _ => 20.0,
    }
}

/// Angle force constant by vertex element, eV/rad².
fn angle_k(vertex: Element) -> f64 {
    use Element::*;
    match vertex {
        C => 4.5,
        O => 4.0,
        H => 2.5,
        Si => 3.5,
    }
}

/// Finish a molecule: derive r₀/θ₀ from the reference geometry and build
/// angle terms for every bonded triple.
pub fn finalize(name: &str, elements: Vec<Element>, coords: Vec<Vec3>, bond_pairs: &[(usize, usize)]) -> Molecule {
    let n = elements.len();
    assert_eq!(coords.len(), n);
    let mut bonds = Vec::new();
    let mut adjacency = vec![Vec::new(); n];
    for &(i, j) in bond_pairs {
        assert!(i < n && j < n && i != j, "bad bond ({i},{j}) in {name}");
        let r0 = (coords[i] - coords[j]).norm();
        assert!(
            (0.5..2.6).contains(&r0),
            "suspicious bond length {r0} for ({i},{j}) in {name}"
        );
        bonds.push(Bond { i, j, k: bond_k(elements[i], elements[j]), r0 });
        adjacency[i].push(j);
        adjacency[j].push(i);
    }
    let mut angles = Vec::new();
    for j in 0..n {
        let nb = &adjacency[j];
        for x in 0..nb.len() {
            for y in x + 1..nb.len() {
                let (i, k) = (nb[x], nb[y]);
                let theta0 = (coords[i] - coords[j]).angle_between(coords[k] - coords[j]);
                angles.push(Angle { i, j, k, kt: angle_k(elements[j]), theta0 });
            }
        }
    }
    Molecule { name: name.to_string(), elements, coords, bonds, angles }
}

/// The force field evaluating a molecule's bonded terms.
#[derive(Debug, Clone)]
pub struct MoleculeFF {
    pub mol: Molecule,
}

impl ForceField for MoleculeFF {
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        debug_assert_eq!(pos.len(), self.mol.n_atoms());
        for f in forces.iter_mut() {
            *f = Vec3::ZERO;
        }
        let mut e = 0.0;

        for b in &self.mol.bonds {
            let d = pos[b.i] - pos[b.j];
            let r = d.norm();
            let u = d / r;
            let dr = r - b.r0;
            // V = k·dr²·(1 − α·dr);  dV/dr = k·dr·(2 − 3α·dr)
            e += b.k * dr * dr * (1.0 - ANH_ALPHA * dr);
            let dv = b.k * dr * (2.0 - 3.0 * ANH_ALPHA * dr);
            forces[b.i] -= u * dv;
            forces[b.j] += u * dv;
        }

        for a in &self.mol.angles {
            let u = pos[a.i] - pos[a.j];
            let v = pos[a.k] - pos[a.j];
            let (ru, rv) = (u.norm(), v.norm());
            let (uh, vh) = (u / ru, v / rv);
            let cos_t = uh.dot(vh).clamp(-1.0, 1.0);
            let theta = cos_t.acos();
            let dth = theta - a.theta0;
            e += 0.5 * a.kt * dth * dth;
            let dv_dtheta = a.kt * dth;
            let sin_t = theta.sin().max(1e-9);
            let dth_di = (uh * cos_t - vh) / (ru * sin_t);
            let dth_dk = (vh * cos_t - uh) / (rv * sin_t);
            let fi = -(dth_di * dv_dtheta);
            let fk = -(dth_dk * dv_dtheta);
            forces[a.i] += fi;
            forces[a.k] += fk;
            forces[a.j] -= fi + fk;
        }
        e
    }

    fn name(&self) -> &'static str {
        "molecule-ff"
    }
}

// ---------------------------------------------------------------------
// Molecule builders. Geometries are assembled from standard bond
// lengths/angles; they are *reference* geometries for a synthetic FF,
// not experimental structures.
// ---------------------------------------------------------------------

const CC: f64 = 1.54; // single C–C
const CC_AR: f64 = 1.39; // aromatic C–C
const CH: f64 = 1.09;
const CO: f64 = 1.43; // single C–O
const CO_D: f64 = 1.21; // C=O
const OH: f64 = 0.96;

/// Tetrahedral direction set (unit vectors).
fn tetra() -> [Vec3; 4] {
    let s = 1.0 / (3f64).sqrt();
    [
        Vec3::new(s, s, s),
        Vec3::new(s, -s, -s),
        Vec3::new(-s, s, -s),
        Vec3::new(-s, -s, s),
    ]
}

/// Planar hexagon of aromatic carbons in the xy-plane, centered at
/// `center`, first vertex toward +x.
fn hexagon(center: Vec3, r: f64) -> Vec<Vec3> {
    (0..6)
        .map(|i| {
            let a = std::f64::consts::PI / 3.0 * i as f64;
            center + Vec3::new(r * a.cos(), r * a.sin(), 0.0)
        })
        .collect()
}

/// Ethanol CH₃–CH₂–OH (9 atoms: C0 C1 O2 H3..H8).
pub fn ethanol() -> Molecule {
    let t = tetra();
    let c0 = Vec3::ZERO;
    let c1 = c0 + t[0] * CC;
    let o2 = c1 + (t[1] * -1.0) * -CO; // continue roughly along chain
    let mut coords = vec![c0, c1, o2];
    let mut elements = vec![Element::C, Element::C, Element::O];
    let mut bonds = vec![(0usize, 1usize), (1, 2)];
    // 3 H on C0 (directions away from C1)
    for k in 1..4 {
        coords.push(c0 + t[k] * CH);
        elements.push(Element::H);
        bonds.push((0, coords.len() - 1));
    }
    // 2 H on C1 (avoid t[0] toward C0 and direction toward O)
    coords.push(c1 + t[2] * CH);
    elements.push(Element::H);
    bonds.push((1, coords.len() - 1));
    coords.push(c1 + t[3] * CH);
    elements.push(Element::H);
    bonds.push((1, coords.len() - 1));
    // H on O
    coords.push(o2 + Vec3::new(0.2, 0.4, 0.9).normalized() * OH);
    elements.push(Element::H);
    bonds.push((2, coords.len() - 1));
    finalize("ethanol", elements, coords, &bonds)
}

/// Toluene C₆H₅–CH₃ (15 atoms).
pub fn toluene() -> Molecule {
    let ring = hexagon(Vec3::ZERO, CC_AR);
    let mut coords = ring.clone();
    let mut elements = vec![Element::C; 6];
    let mut bonds: Vec<(usize, usize)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
    // methyl C on ring atom 0, outward
    let out0 = ring[0].normalized();
    let cm = ring[0] + out0 * CC;
    coords.push(cm);
    elements.push(Element::C);
    bonds.push((0, 6));
    // ring H on atoms 1..5
    for i in 1..6 {
        let out = ring[i].normalized();
        coords.push(ring[i] + out * CH);
        elements.push(Element::H);
        bonds.push((i, coords.len() - 1));
    }
    // 3 methyl H
    let t = tetra();
    for k in 1..4 {
        // orient roughly away from ring
        let dir = (out0 + t[k] * 0.9).normalized();
        coords.push(cm + dir * CH);
        elements.push(Element::H);
        bonds.push((6, coords.len() - 1));
    }
    finalize("toluene", elements, coords, &bonds)
}

/// Naphthalene C₁₀H₈ (18 atoms): two fused rings.
pub fn naphthalene() -> Molecule {
    // Fused bicyclic: ring A vertices 0..5; ring B shares edge (0,1).
    let a = hexagon(Vec3::ZERO, CC_AR);
    // Ring B center: reflected across the shared edge midpoint.
    let shared_mid = (a[0] + a[1]) * 0.5;
    let center_b = shared_mid * 2.0;
    let b = hexagon(center_b, CC_AR);
    // pick the 4 vertices of b farthest from origin (not duplicating 0,1)
    let mut bsel: Vec<Vec3> = b
        .iter()
        .cloned()
        .filter(|p| (*p - a[0]).norm() > 0.3 && (*p - a[1]).norm() > 0.3)
        .collect();
    bsel.sort_by(|p, q| p.norm().partial_cmp(&q.norm()).unwrap());
    bsel.truncate(4);
    let mut coords = a.clone();
    coords.extend(bsel.iter().cloned());
    let mut elements = vec![Element::C; coords.len()];
    // bonds: ring A cycle + connect B chain between a[0] and a[1]
    let mut bonds: Vec<(usize, usize)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
    // order B vertices along the arc from a[0] to a[1] by angle around center_b
    let mut order: Vec<usize> = (6..coords.len()).collect();
    let ang = |p: Vec3| (p - center_b).y.atan2((p - center_b).x);
    let a0ang = ang(a[0]);
    order.sort_by(|&p, &q| {
        let ap = (ang(coords[p]) - a0ang).rem_euclid(std::f64::consts::TAU);
        let aq = (ang(coords[q]) - a0ang).rem_euclid(std::f64::consts::TAU);
        ap.partial_cmp(&aq).unwrap()
    });
    let mut prev = 0usize; // a[0]
    for &idx in &order {
        bonds.push((prev, idx));
        prev = idx;
    }
    bonds.push((prev, 1)); // close into a[1]
    // hydrogens on all C with fewer than 3 bonds
    let mut deg = vec![0usize; coords.len()];
    for &(i, j) in &bonds {
        deg[i] += 1;
        deg[j] += 1;
    }
    let nc = coords.len();
    let centroid = coords.iter().fold(Vec3::ZERO, |s, p| s + *p) / nc as f64;
    for i in 0..nc {
        if deg[i] < 3 {
            let out = (coords[i] - centroid).normalized();
            coords.push(coords[i] + out * CH);
            elements.push(Element::H);
            bonds.push((i, coords.len() - 1));
        }
    }
    finalize("naphthalene", elements, coords, &bonds)
}

/// Aspirin C₉H₈O₄ (21 atoms): benzene ring + carboxyl + acetyl ester.
pub fn aspirin() -> Molecule {
    let ring = hexagon(Vec3::ZERO, CC_AR);
    let mut coords = ring.clone();
    let mut elements = vec![Element::C; 6];
    let mut bonds: Vec<(usize, usize)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();

    let out = |i: usize, ring: &Vec<Vec3>| ring[i].normalized();

    // Carboxyl on ring atom 0: C6(=O7)(O8–H).
    let c6 = ring[0] + out(0, &ring) * CC;
    coords.push(c6); // 6
    elements.push(Element::C);
    bonds.push((0, 6));
    let o7 = c6 + (out(0, &ring) + Vec3::new(0.0, 0.0, 1.0)).normalized() * CO_D;
    coords.push(o7); // 7
    elements.push(Element::O);
    bonds.push((6, 7));
    let o8 = c6 + (out(0, &ring) + Vec3::new(0.0, 0.0, -1.0)).normalized() * CO;
    coords.push(o8); // 8
    elements.push(Element::O);
    bonds.push((6, 8));

    // Ester on ring atom 1: O9–C10(=O11)–C12(H3).
    let o9 = ring[1] + out(1, &ring) * CO;
    coords.push(o9); // 9
    elements.push(Element::O);
    bonds.push((1, 9));
    let c10 = o9 + (out(1, &ring) + Vec3::new(0.0, 0.0, 0.8)).normalized() * CO;
    coords.push(c10); // 10
    elements.push(Element::C);
    bonds.push((9, 10));
    let o11 = c10 + Vec3::new(0.0, 0.0, 1.0) * CO_D;
    coords.push(o11); // 11
    elements.push(Element::O);
    bonds.push((10, 11));
    let c12 = c10 + (out(1, &ring) * 0.7 + Vec3::new(0.4, 0.0, -0.6)).normalized() * CC;
    coords.push(c12); // 12
    elements.push(Element::C);
    bonds.push((10, 12));

    // 4 ring H on atoms 2..5.
    for i in 2..6 {
        coords.push(ring[i] + out(i, &ring) * CH);
        elements.push(Element::H);
        bonds.push((i, coords.len() - 1));
    }
    // H on carboxyl O8.
    coords.push(coords[8] + Vec3::new(0.3, 0.2, -0.9).normalized() * OH);
    elements.push(Element::H);
    bonds.push((8, coords.len() - 1));
    // 3 methyl H on C12.
    let t = tetra();
    for k in 0..3 {
        let dir = (Vec3::new(0.4, 0.0, -0.6).normalized() + t[k] * 0.9).normalized();
        coords.push(coords[12] + dir * CH);
        elements.push(Element::H);
        bonds.push((12, coords.len() - 1));
    }
    finalize("aspirin", elements, coords, &bonds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_molecule(m: &Molecule, n_expected: usize, formula: &[(Element, usize)]) {
        assert_eq!(m.n_atoms(), n_expected, "{}", m.name);
        for &(el, count) in formula {
            let got = m.elements.iter().filter(|&&e| e == el).count();
            assert_eq!(got, count, "{} count of {:?}", m.name, el);
        }
        // every atom bonded
        let mut deg = vec![0usize; m.n_atoms()];
        for b in &m.bonds {
            deg[b.i] += 1;
            deg[b.j] += 1;
        }
        assert!(deg.iter().all(|&d| d >= 1), "{} has unbonded atom", m.name);
        // no overlapping atoms
        for i in 0..m.n_atoms() {
            for j in i + 1..m.n_atoms() {
                let d = (m.coords[i] - m.coords[j]).norm();
                assert!(d > 0.6, "{}: atoms {i},{j} overlap (d={d})", m.name);
            }
        }
    }

    #[test]
    fn formulas_match() {
        check_molecule(&ethanol(), 9, &[(Element::C, 2), (Element::O, 1), (Element::H, 6)]);
        check_molecule(&toluene(), 15, &[(Element::C, 7), (Element::H, 8)]);
        check_molecule(&naphthalene(), 18, &[(Element::C, 10), (Element::H, 8)]);
        check_molecule(&aspirin(), 21, &[(Element::C, 9), (Element::O, 4), (Element::H, 8)]);
    }

    #[test]
    fn reference_geometry_is_equilibrium() {
        for m in [ethanol(), toluene(), naphthalene(), aspirin()] {
            let ff = MoleculeFF { mol: m };
            let mut f = vec![Vec3::ZERO; ff.mol.n_atoms()];
            let e = ff.compute(&ff.mol.coords, &mut f);
            assert!(e.abs() < 1e-10, "{}: E₀={e}", ff.mol.name);
            for (i, fi) in f.iter().enumerate() {
                assert!(fi.norm() < 1e-8, "{}: residual force on {i}: {fi:?}", ff.mol.name);
            }
        }
    }

    #[test]
    fn forces_match_fd_gradient() {
        let ff = MoleculeFF { mol: ethanol() };
        let mut pos = ff.mol.coords.clone();
        // random-ish displacement
        for (i, p) in pos.iter_mut().enumerate() {
            let s = 0.02 * ((i * 7 % 5) as f64 - 2.0);
            *p += Vec3::new(s, -0.5 * s, 0.3 * s);
        }
        let n = pos.len();
        let mut f = vec![Vec3::ZERO; n];
        ff.compute(&pos, &mut f);
        let h = 1e-6;
        let mut scratch = vec![Vec3::ZERO; n];
        for i in 0..n {
            for a in 0..3 {
                let mut arr = pos[i].to_array();
                let orig = pos[i];
                arr[a] += h;
                pos[i] = Vec3::from_array(arr);
                let ep = ff.compute(&pos, &mut scratch);
                arr[a] -= 2.0 * h;
                pos[i] = Vec3::from_array(arr);
                let em = ff.compute(&pos, &mut scratch);
                pos[i] = orig;
                let fnum = -(ep - em) / (2.0 * h);
                assert!(
                    (fnum - f[i].to_array()[a]).abs() < 1e-5,
                    "atom {i} axis {a}: fd {fnum} vs {}",
                    f[i].to_array()[a]
                );
            }
        }
    }

    #[test]
    fn net_force_and_torque_vanish() {
        for m in [ethanol(), toluene(), naphthalene(), aspirin()] {
            let ff = MoleculeFF { mol: m };
            let mut pos = ff.mol.coords.clone();
            for (i, p) in pos.iter_mut().enumerate() {
                let s = 0.03 * (((i * 13) % 7) as f64 - 3.0) / 3.0;
                *p += Vec3::new(s, s * 0.4, -s * 0.7);
            }
            let mut f = vec![Vec3::ZERO; pos.len()];
            ff.compute(&pos, &mut f);
            let net = f.iter().fold(Vec3::ZERO, |s, x| s + *x);
            assert!(net.norm() < 1e-9, "{}: net {net:?}", ff.mol.name);
            let torque = pos
                .iter()
                .zip(&f)
                .fold(Vec3::ZERO, |s, (r, fi)| s + r.cross(*fi));
            assert!(torque.norm() < 1e-8, "{}: torque {torque:?}", ff.mol.name);
        }
    }

    #[test]
    fn complexity_ordering_by_atom_count() {
        // The paper orders water < ethanol < toluene < naphthalene <
        // aspirin (< silicon) by complexity; our substitution keeps that.
        let ns = [ethanol().n_atoms(), toluene().n_atoms(), naphthalene().n_atoms(), aspirin().n_atoms()];
        assert!(ns.windows(2).all(|w| w[0] < w[1]), "{ns:?}");
    }
}
