//! Physics oracles (the "DFT" of this reproduction).
//!
//! The paper trains on SIESTA DFT data; offline we substitute calibrated
//! analytic oracles (see DESIGN.md §Substitutions):
//!
//! * [`water::WaterPes`] — anharmonic intramolecular water PES whose
//!   equilibrium geometry and harmonic frequencies are *calibrated in
//!   code* to the paper's DFT column of Table II.
//! * [`ff::MoleculeFF`] — per-molecule bonded force fields over real
//!   topologies for the MD17-like datasets (ethanol, toluene,
//!   naphthalene, aspirin).
//! * [`silicon::StillingerWeber`] — bulk silicon.

pub mod water;
pub mod ff;
pub mod silicon;

pub use water::WaterPes;
pub use ff::MoleculeFF;
pub use silicon::StillingerWeber;
