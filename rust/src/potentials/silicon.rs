//! Stillinger–Weber silicon (bulk, periodic) — the paper's sixth and most
//! complex dataset. Full two-body + three-body SW with analytic forces,
//! validated against finite differences.
//!
//! Parameters: F. H. Stillinger & T. A. Weber, PRB 31, 5262 (1985).

use crate::md::ForceField;
use crate::util::Vec3;

/// SW parameters for Si.
#[derive(Debug, Clone, Copy)]
pub struct SwParams {
    pub epsilon: f64, // eV
    pub sigma: f64,   // Å
    pub a: f64,       // cutoff multiplier (r_c = a·σ)
    pub big_a: f64,
    pub big_b: f64,
    pub p: i32,
    pub q: i32,
    pub lambda: f64,
    pub gamma: f64,
}

impl Default for SwParams {
    fn default() -> Self {
        SwParams {
            epsilon: 2.1683,
            sigma: 2.0951,
            a: 1.80,
            big_a: 7.049556277,
            big_b: 0.6022245584,
            p: 4,
            q: 0,
            lambda: 21.0,
            gamma: 1.20,
        }
    }
}

/// Periodic SW silicon in a cubic box.
#[derive(Debug, Clone)]
pub struct StillingerWeber {
    pub params: SwParams,
    /// Cubic box side (Å).
    pub box_l: f64,
}

/// Conventional diamond-cubic lattice constant of Si (Å).
pub const SI_A0: f64 = 5.431;

impl StillingerWeber {
    /// Diamond-cubic supercell of `nc³` conventional cells (8 atoms per
    /// cell). Returns (potential, positions).
    pub fn diamond_supercell(nc: usize) -> (StillingerWeber, Vec<Vec3>) {
        let basis = [
            [0.0, 0.0, 0.0],
            [0.0, 0.5, 0.5],
            [0.5, 0.0, 0.5],
            [0.5, 0.5, 0.0],
            [0.25, 0.25, 0.25],
            [0.25, 0.75, 0.75],
            [0.75, 0.25, 0.75],
            [0.75, 0.75, 0.25],
        ];
        let mut pos = Vec::with_capacity(8 * nc * nc * nc);
        for ix in 0..nc {
            for iy in 0..nc {
                for iz in 0..nc {
                    for b in &basis {
                        pos.push(Vec3::new(
                            (ix as f64 + b[0]) * SI_A0,
                            (iy as f64 + b[1]) * SI_A0,
                            (iz as f64 + b[2]) * SI_A0,
                        ));
                    }
                }
            }
        }
        (
            StillingerWeber { params: SwParams::default(), box_l: nc as f64 * SI_A0 },
            pos,
        )
    }

    fn cutoff(&self) -> f64 {
        self.params.a * self.params.sigma
    }

    /// Minimum-image displacement j→i.
    fn disp(&self, ri: Vec3, rj: Vec3) -> Vec3 {
        (ri - rj).min_image(self.box_l)
    }

    /// Two-body term value and dφ/dr.
    fn pair(&self, r: f64) -> (f64, f64) {
        let p = &self.params;
        let rc = self.cutoff();
        if r >= rc {
            return (0.0, 0.0);
        }
        let sr = p.sigma / r;
        let srp = sr.powi(p.p);
        let srq = if p.q == 0 { 1.0 } else { sr.powi(p.q) };
        let expo = (p.sigma / (r - rc)).exp();
        let v = p.epsilon * p.big_a * (p.big_b * srp - srq) * expo;
        // dv/dr = εA·[d(B·srp − srq)/dr]·expo + εA(B·srp−srq)·expo·(−σ/(r−rc)²)
        let d_poly = p.epsilon
            * p.big_a
            * (-(p.p as f64) * p.big_b * srp / r + (p.q as f64) * srq / r)
            * expo;
        let d_exp = v * (-p.sigma / ((r - rc) * (r - rc)));
        (v, d_poly + d_exp)
    }

    /// Three-body radial factor g(r) = exp(γσ/(r − r_c)) and g'(r).
    fn gfun(&self, r: f64) -> (f64, f64) {
        let p = &self.params;
        let rc = self.cutoff();
        if r >= rc {
            return (0.0, 0.0);
        }
        let g = (p.gamma * p.sigma / (r - rc)).exp();
        let dg = g * (-p.gamma * p.sigma / ((r - rc) * (r - rc)));
        (g, dg)
    }

    /// Neighbor list within cutoff (O(N²); cells are small here).
    fn neighbors(&self, pos: &[Vec3]) -> Vec<Vec<(usize, Vec3, f64)>> {
        let rc = self.cutoff();
        let n = pos.len();
        let mut out = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let d = self.disp(pos[j], pos[i]); // i→j
                let r = d.norm();
                if r < rc {
                    out[i].push((j, d, r));
                }
            }
        }
        out
    }
}

impl ForceField for StillingerWeber {
    fn compute(&self, pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
        let p = self.params;
        for f in forces.iter_mut() {
            *f = Vec3::ZERO;
        }
        let nb = self.neighbors(pos);
        let mut energy = 0.0;

        // Two-body.
        for i in 0..pos.len() {
            for &(j, d, r) in &nb[i] {
                if j < i {
                    continue; // count each pair once
                }
                let (v, dv) = self.pair(r);
                energy += v;
                let u = d / r; // i→j unit
                // F_i = +dv·u (force pulls i toward j when dv<0 ... sign:
                // V(r), F_i = −∂V/∂r_i = −dv·(∂r/∂r_i) = +dv·u)
                forces[i] += u * dv;
                forces[j] -= u * dv;
            }
        }

        // Three-body: Σ_i Σ_{j<k ∈ nb(i)} h(r_ij, r_ik, θ_jik).
        for i in 0..pos.len() {
            let nbi = &nb[i];
            for x in 0..nbi.len() {
                let (j, dij, rij) = nbi[x];
                let (gj, dgj) = self.gfun(rij);
                if gj == 0.0 {
                    continue;
                }
                let uij = dij / rij;
                for y in x + 1..nbi.len() {
                    let (k, dik, rik) = nbi[y];
                    let (gk, dgk) = self.gfun(rik);
                    if gk == 0.0 {
                        continue;
                    }
                    let uik = dik / rik;
                    let cos_t = uij.dot(uik);
                    let c = cos_t + 1.0 / 3.0;
                    let pref = p.epsilon * p.lambda;
                    let h = pref * gj * gk * c * c;
                    energy += h;

                    // ∂h/∂cosθ
                    let dh_dcos = pref * gj * gk * 2.0 * c;
                    // ∂cosθ/∂r_j = (u_ik − cosθ·u_ij)/r_ij (r_j enters via d_ij)
                    let dcos_drj = (uik - uij * cos_t) / rij;
                    let dcos_drk = (uij - uik * cos_t) / rik;
                    // ∂h/∂r_ij radial part
                    let dh_drij = pref * dgj * gk * c * c;
                    let dh_drik = pref * gj * dgk * c * c;

                    // gradient wrt atom j position: ∂r_ij/∂r_j = u_ij
                    let grad_j = uij * dh_drij + dcos_drj * dh_dcos;
                    let grad_k = uik * dh_drik + dcos_drk * dh_dcos;
                    forces[j] -= grad_j;
                    forces[k] -= grad_k;
                    forces[i] += grad_j + grad_k; // Newton's third law
                }
            }
        }
        energy
    }

    fn name(&self) -> &'static str {
        "stillinger-weber-si"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_lattice_cohesive_energy() {
        // SW was fitted so the diamond lattice at a₀ gives E/atom ≈ −4.336 eV.
        let (sw, pos) = StillingerWeber::diamond_supercell(2);
        let mut f = vec![Vec3::ZERO; pos.len()];
        let e = sw.compute(&pos, &mut f);
        let e_per_atom = e / pos.len() as f64;
        assert!(
            (e_per_atom + 4.336).abs() < 0.02,
            "E/atom = {e_per_atom} (expect ≈ −4.336)"
        );
    }

    #[test]
    fn perfect_lattice_has_zero_forces() {
        let (sw, pos) = StillingerWeber::diamond_supercell(2);
        let mut f = vec![Vec3::ZERO; pos.len()];
        sw.compute(&pos, &mut f);
        for (i, fi) in f.iter().enumerate() {
            assert!(fi.norm() < 1e-8, "atom {i}: {fi:?}");
        }
    }

    #[test]
    fn forces_match_fd() {
        let (sw, mut pos) = StillingerWeber::diamond_supercell(1);
        // displace a few atoms
        pos[0] += Vec3::new(0.12, -0.08, 0.05);
        pos[3] += Vec3::new(-0.06, 0.1, 0.02);
        pos[5] += Vec3::new(0.03, 0.04, -0.09);
        let n = pos.len();
        let mut f = vec![Vec3::ZERO; n];
        sw.compute(&pos, &mut f);
        let h = 1e-6;
        let mut scratch = vec![Vec3::ZERO; n];
        for i in [0usize, 3, 5, 7] {
            for a in 0..3 {
                let orig = pos[i];
                let mut arr = orig.to_array();
                arr[a] += h;
                pos[i] = Vec3::from_array(arr);
                let ep = sw.compute(&pos, &mut scratch);
                arr[a] -= 2.0 * h;
                pos[i] = Vec3::from_array(arr);
                let em = sw.compute(&pos, &mut scratch);
                pos[i] = orig;
                let fnum = -(ep - em) / (2.0 * h);
                let fana = f[i].to_array()[a];
                assert!(
                    (fnum - fana).abs() < 1e-4 * (1.0 + fana.abs()),
                    "atom {i} axis {a}: fd {fnum} vs analytic {fana}"
                );
            }
        }
    }

    #[test]
    fn forces_sum_to_zero() {
        let (sw, mut pos) = StillingerWeber::diamond_supercell(2);
        for (i, p) in pos.iter_mut().enumerate() {
            let s = 0.05 * (((i * 31) % 11) as f64 - 5.0) / 5.0;
            *p += Vec3::new(s, -0.4 * s, 0.8 * s);
        }
        let mut f = vec![Vec3::ZERO; pos.len()];
        sw.compute(&pos, &mut f);
        let net = f.iter().fold(Vec3::ZERO, |s, x| s + *x);
        assert!(net.norm() < 1e-8, "net {net:?}");
    }

    #[test]
    fn energy_rises_under_compression() {
        let (sw, pos) = StillingerWeber::diamond_supercell(1);
        let mut scratch = vec![Vec3::ZERO; pos.len()];
        let e0 = sw.compute(&pos, &mut scratch);
        let squeezed: Vec<Vec3> = pos.iter().map(|p| *p * 0.97).collect();
        let sw2 = StillingerWeber { box_l: sw.box_l * 0.97, ..sw.clone() };
        let e1 = sw2.compute(&squeezed, &mut scratch);
        assert!(e1 > e0, "e1={e1} e0={e0}");
    }
}
