//! `nvnmd` — leader entrypoint and CLI of the NvN-MLMD reproduction.
//!
//! Subcommands map 1:1 onto the experiment index of DESIGN.md (E1–E10)
//! plus the build-time data generator and an interactive MD runner.
//! Argument parsing is hand-rolled (clap is unavailable offline).

use anyhow::{bail, Result};

use nvnmd::exp;

const USAGE: &str = "\
nvnmd — heterogeneous parallel non-von-Neumann MLMD (TCSI 2023 reproduction)

USAGE: nvnmd <COMMAND> [OPTIONS]

Build-time:
  gen-data [--out DIR] [--quick]    generate the six datasets + quant vectors
                                    (consumed by python/compile/train.py)

Experiments (paper artifact → command):
  fig3a        tanh vs φ curves (CSV)
  fig3b        transistor counts: CORDIC-tanh vs φ unit
  table1       force RMSE, tanh-MLP vs φ-MLP, six datasets
  fig4         CNN vs QNN accuracy for K = 1..5, six datasets
  fig5         SQNN/FQNN transistor ratio for K = 1..5, six datasets
  fig9         MLP-chip force scatter vs DFT surrogate (RMSE)
  table2       bond length / angle / vibration freqs, four methods
  fig10        vibrational DOS spectra (CSV per mode/method)
  table3       computational time & energy, five methods
  scaling      §VI process-node projection (A1×A2)
  all          every experiment in sequence

Runtime:
  run [--steps N] [--mode nvn|vn|chip-vs-oracle] [--dt FS] [--strict13]
               drive the water system and print measured properties
  info         artifact inventory and environment check

Common options:
  --quick      reduced step counts / sweep sizes (CI smoke)
  --out DIR    output directory (default: artifacts or artifacts/report)
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny option scanner: `flag("--quick")`, `opt("--out")`.
struct Opts<'a> {
    rest: &'a [String],
}

impl<'a> Opts<'a> {
    fn flag(&self, name: &str) -> bool {
        self.rest.iter().any(|a| a == name)
    }
    fn opt(&self, name: &str) -> Option<&str> {
        self.rest
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.rest.get(i + 1))
            .map(|s| s.as_str())
    }
    fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value for {name}: {v:?}")),
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let o = Opts { rest: &args[1..] };
    let quick = o.flag("--quick");
    match cmd.as_str() {
        "gen-data" => {
            let out = o.opt("--out").unwrap_or("artifacts/datasets").to_string();
            exp::gen_data::run(&out, quick)
        }
        "fig3a" => exp::fig3::run_curves().map(print_report),
        "fig3b" => exp::fig3::run_transistors().map(print_report),
        "table1" => exp::table1::run().map(print_report),
        "fig4" => exp::fig4::run().map(print_report),
        "fig5" => exp::fig5::run().map(print_report),
        "fig9" => exp::fig9::run().map(print_report),
        "table2" => exp::table2::run(exp::table2::Config::with_quick(quick)).map(print_report),
        "fig10" => exp::fig10::run(quick).map(print_report),
        "table3" => exp::table3::run(quick).map(print_report),
        "scaling" => exp::scaling::run(quick).map(print_report),
        "all" => {
            for (name, f) in exp::all_experiments(quick) {
                println!("\n########## {name} ##########");
                print_report(f()?);
            }
            Ok(())
        }
        "run" => {
            let steps = o.opt_parse("--steps", if quick { 5_000usize } else { 50_000 })?;
            let dt = o.opt_parse("--dt", 0.25f64)?;
            let mode = o.opt("--mode").unwrap_or("nvn").to_string();
            let strict13 = o.flag("--strict13");
            exp::run_md::run(&mode, steps, dt, strict13).map(print_report)
        }
        "info" => exp::info::run().map(print_report),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `nvnmd help`)"),
    }
}

fn print_report(r: exp::Report) {
    println!("{}", r.render());
    if let Some(path) = &r.saved_to {
        println!("[saved: {}]", path.display());
    }
}
