//! The serving front door: a deadline-batched request gateway over the
//! epoch farm.
//!
//! The farm answers "drive N fixed molecules for T ticks"; a serving
//! tier answers a request *stream*: clients submit molecule-step
//! requests (`species`, initial [`System`], ticks wanted, absolute
//! deadline) and poll for results. The gateway turns the stream back
//! into the farm's shape:
//!
//! ```text
//!  submit(species, system, ticks, deadline) ──► per-species queues
//!                                                    │  EDF batch former
//!                                                    ▼  (admission control)
//!                                         MoleculeFarm::admit / retire
//!                                                    │
//!                run_epoch(window_ticks)  ◄──────────┘  one shard
//!                 one epoch per window                   round-trip
//!                                                    │   per window
//!            settle: losses → quarantines → due ◄────┘
//!                                                    │
//!                         SLO ledger + RequestResult ▼  take_result(id)
//! ```
//!
//! **Execution quantum = the deadline window.** The gateway drives the
//! farm exclusively through [`MoleculeFarm::run_epoch`]`(window_ticks)`
//! — one shard round-trip per window, riding the epoch driver's
//! `EpochFold` double-buffer (host-side settling of epoch *t* overlaps
//! the shards executing *t + 1*). Per-tick sync never comes back.
//! Requested ticks are quantized **up** to whole windows: a request for
//! 10 ticks under an 8-tick window runs 16 steps and completes at the
//! second window boundary. Arrivals between boundaries are picked up at
//! the next one.
//!
//! **Virtual clock.** Gateway time is `now: u64`, in farm ticks,
//! advanced by exactly `window_ticks` per window — no `Instant` anywhere
//! in the SLO path. Latency percentiles are therefore pure functions of
//! the arrival plan, so inline and threaded ledgers are *exactly*
//! comparable and percentile tests are deterministic.
//!
//! **Admission control** sheds or defers load off the farm's existing
//! health signals — no new health plumbing:
//! - a species with zero [`MoleculeFarm::live_shards`] rejects
//!   ([`Rejection::SpeciesDown`]); shard losses shrink capacity,
//! - per-species capacity is `live_shards × shard_capacity` minus a
//!   quarantine **backoff penalty** (+1 each window the species reports
//!   new quarantine/loss records, −1 each clean window),
//! - a bounded per-species queue rejects ([`Rejection::QueueFull`]),
//! - requests whose deadline can no longer be met are rejected at
//!   submit ([`Rejection::DeadlineImpossible`]) and shed from the queue
//!   ([`Outcome::Shed`]) rather than burning shard capacity on a
//!   guaranteed miss.
//!
//! The batch former is earliest-deadline-first with request-id
//! tie-break — a pure function of gateway state, so accept/reject/
//! placement decisions are bit-identical across backends and replays.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::md::System;
use crate::nn::Mlp;
use crate::testkit::arrivals::Arrival;
use crate::util::Vec3;

#[cfg(any(test, feature = "faults"))]
use crate::testkit::faults::FaultPlan;

use super::farm::{
    generic_group, water_group, FarmLedger, FarmSupervision, FarmTelemetry, HealthPolicy,
    MoleculeFarm, QuarantineReason, ServedMolecule, SpeciesGroup,
};
use super::ParallelMode;

/// Builds one served molecule from a client's initial [`System`] —
/// how a species turns a request payload into a farm resident.
pub type MoleculeBuilder = Box<dyn Fn(&System) -> Result<Box<dyn ServedMolecule>>>;

/// One species the gateway serves: an **empty** [`SpeciesGroup`] (its
/// shards are built up front, chips programmed, zero batch lanes —
/// molecules arrive as requests) plus the builder that instantiates a
/// request's molecule.
pub struct GatewaySpecies {
    group: SpeciesGroup,
    build: MoleculeBuilder,
}

impl GatewaySpecies {
    /// Wrap an empty group and a builder (the custom/PBC hook; the
    /// common cases have [`GatewaySpecies::water`] and
    /// [`GatewaySpecies::generic`]).
    pub fn new(group: SpeciesGroup, build: MoleculeBuilder) -> Result<GatewaySpecies> {
        anyhow::ensure!(
            group.n_molecules() == 0,
            "gateway species {:?} must start empty — molecules arrive as requests",
            group.name()
        );
        Ok(GatewaySpecies { group, build })
    }

    /// The water species on `shards` shards.
    pub fn water(model: &Mlp, k: usize, shards: usize, dt_fs: f64) -> Result<GatewaySpecies> {
        let group = water_group(model, &[], k, shards, dt_fs)?;
        let m = model.clone();
        GatewaySpecies::new(
            group,
            Box::new(move |sys| {
                Ok(water_group(&m, std::slice::from_ref(sys), k, 1, dt_fs)?
                    .into_molecules()
                    .pop()
                    .expect("one system in, one molecule out"))
            }),
        )
    }

    /// A generic Table-I species (4·n_nb descriptor path) on `shards`
    /// shards.
    #[allow(clippy::too_many_arguments)] // mirrors generic_group's flat init API
    pub fn generic(
        name: &str,
        model: &Mlp,
        ref_coords: &[Vec3],
        n_nb: usize,
        k: usize,
        shards: usize,
        dt_fs: f64,
    ) -> Result<GatewaySpecies> {
        let group = generic_group(name, model, ref_coords, &[], n_nb, k, shards, dt_fs)?;
        let m = model.clone();
        let rc = ref_coords.to_vec();
        let name = name.to_string();
        GatewaySpecies::new(
            group,
            Box::new(move |sys| {
                Ok(generic_group(&name, &m, &rc, std::slice::from_ref(sys), n_nb, k, 1, dt_fs)?
                    .into_molecules()
                    .pop()
                    .expect("one system in, one molecule out"))
            }),
        )
    }
}

/// Gateway construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Deadline window in farm ticks — the execution quantum: one
    /// `run_epoch(window_ticks)` per window, requests quantized up to
    /// whole windows.
    pub window_ticks: u64,
    /// Bounded per-species queue: submissions beyond this are rejected
    /// ([`Rejection::QueueFull`]).
    pub queue_limit: usize,
    /// Resident molecules a single live shard is allowed to carry —
    /// per-species admission capacity is `live_shards × shard_capacity`
    /// minus the quarantine backoff penalty.
    pub shard_capacity: usize,
    /// Parallel MLP lanes per shard chip.
    pub lanes: usize,
    /// Shard execution backend.
    pub mode: ParallelMode,
    /// Divergence-monitor thresholds (passed through to the farm).
    pub health: HealthPolicy,
    /// Virtual-clock origin (gateway `now` starts here; farm ticks
    /// start at 0 regardless).
    pub start_tick: u64,
    /// Deterministic fault plan (test/fault builds only).
    #[cfg(any(test, feature = "faults"))]
    pub faults: Option<FaultPlan>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            window_ticks: 8,
            queue_limit: 64,
            shard_capacity: 8,
            lanes: 1,
            mode: ParallelMode::Inline,
            health: HealthPolicy::default(),
            start_tick: 0,
            #[cfg(any(test, feature = "faults"))]
            faults: None,
        }
    }
}

/// Handle of an accepted request (dense, in submission order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

/// What `submit` decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    Accepted(RequestId),
    Rejected(Rejection),
}

/// Why a submission was turned away at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// No such species index.
    UnknownSpecies,
    /// The species' bounded queue is full — back off and retry.
    QueueFull,
    /// Every shard of the species is dead.
    SpeciesDown,
    /// Even if admitted at the next window boundary, the rounded-up
    /// window count lands past the deadline.
    DeadlineImpossible,
}

/// How a request ended.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Ran its full (window-quantized) tick count.
    Done { positions: Vec<Vec3>, steps: u64 },
    /// The divergence monitor pulled the molecule mid-run; `tick` is
    /// the **farm** tick of the verdict (virtual-clock time is
    /// `start_tick + tick`), `positions` the frozen state.
    Quarantined { reason: QuarantineReason, tick: u64, positions: Vec<Vec3> },
    /// The molecule's shard died mid-run (farm tick `tick`); its state
    /// stays frozen on the dead shard, so no positions come back.
    ShardLost { tick: u64 },
    /// Shed from the queue: the deadline became unmeetable before the
    /// request could be admitted.
    Shed,
}

/// The settled record of one request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestResult {
    pub id: RequestId,
    pub species: usize,
    /// Virtual-clock tick of submission.
    pub submitted_at: u64,
    /// Virtual-clock tick of settlement (a window boundary, except for
    /// sheds which settle at the boundary they were examined at).
    pub completed_at: u64,
    pub deadline: u64,
    pub ticks_requested: u64,
    /// MD ticks actually integrated (the window-quantized count when
    /// `Done`; partial progress on quarantine/loss; 0 when shed).
    pub ticks_run: u64,
    /// `completed_at - submitted_at` (queueing + quantized service).
    pub latency_ticks: u64,
    /// `Done` on or before the deadline. Failures and sheds never meet.
    pub deadline_met: bool,
    pub outcome: Outcome,
}

/// Where a request currently is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestStatus {
    /// Waiting in its species queue for a window with capacity.
    Queued,
    /// Resident in the farm, integrating.
    Running,
    /// Settled; the result waits in [`Gateway::take_result`].
    Finished,
    /// Never accepted, or its result was already taken.
    Unknown,
}

/// Buckets of the latency histogram (plus the implicit overflow tail in
/// the last bucket).
const HIST_BUCKETS: usize = 64;

/// Fixed-bucket latency histogram over virtual-clock ticks: bucket `i`
/// holds latencies in `[i·bucket_ticks, (i+1)·bucket_ticks)`; the last
/// bucket absorbs the overflow tail and quantiles landing there report
/// the recorded maximum. Integer counts over virtual time — quantiles
/// are exact functions of the arrival plan, identical across backends.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    bucket_ticks: u64,
    counts: Vec<u64>,
    total: u64,
    max: u64,
}

impl LatencyHistogram {
    fn new(bucket_ticks: u64) -> LatencyHistogram {
        LatencyHistogram {
            bucket_ticks: bucket_ticks.max(1),
            counts: vec![0; HIST_BUCKETS],
            total: 0,
            max: 0,
        }
    }

    fn record(&mut self, latency: u64) {
        let b = ((latency / self.bucket_ticks) as usize).min(HIST_BUCKETS - 1);
        self.counts[b] += 1;
        self.total += 1;
        self.max = self.max.max(latency);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// The smallest bucket upper bound covering quantile `q` of the
    /// recorded latencies (conservative: a quantile is never
    /// under-reported). 0 when empty; the overflow bucket reports the
    /// recorded maximum.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i == HIST_BUCKETS - 1 {
                    self.max
                } else {
                    (i as u64 + 1) * self.bucket_ticks
                };
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

/// Per-species SLO book. Accounting identities, checked by tests:
/// `submitted = accepted + rejected()` (unknown-species submissions are
/// counted by no species) and
/// `accepted = completed + shed_queued + failed_quarantined +
/// failed_shard_lost + still-queued + still-resident`.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeciesSlo {
    pub name: String,
    pub submitted: u64,
    pub accepted: u64,
    pub rejected_queue_full: u64,
    pub rejected_species_down: u64,
    pub rejected_deadline: u64,
    /// Accepted, then shed from the queue when the deadline became
    /// unmeetable before capacity opened up.
    pub shed_queued: u64,
    pub completed: u64,
    pub deadline_met: u64,
    pub deadline_missed: u64,
    pub failed_quarantined: u64,
    pub failed_shard_lost: u64,
    /// Deepest the species queue ever got (post-submit).
    pub queue_depth_high_water: u64,
    /// Most molecules ever resident in the farm at once.
    pub resident_high_water: u64,
    /// Latency of completed requests, in virtual-clock ticks.
    pub latency: LatencyHistogram,
}

impl SpeciesSlo {
    fn new(name: String, bucket_ticks: u64) -> SpeciesSlo {
        SpeciesSlo {
            name,
            submitted: 0,
            accepted: 0,
            rejected_queue_full: 0,
            rejected_species_down: 0,
            rejected_deadline: 0,
            shed_queued: 0,
            completed: 0,
            deadline_met: 0,
            deadline_missed: 0,
            failed_quarantined: 0,
            failed_shard_lost: 0,
            queue_depth_high_water: 0,
            resident_high_water: 0,
            latency: LatencyHistogram::new(bucket_ticks),
        }
    }

    /// All rejections at the door.
    pub fn rejected(&self) -> u64 {
        self.rejected_queue_full + self.rejected_species_down + self.rejected_deadline
    }
}

/// The gateway's SLO ledger: per-species request books over the virtual
/// clock. `PartialEq` so inline and threaded ledgers can be asserted
/// exactly equal.
#[derive(Debug, Clone, PartialEq)]
pub struct SloLedger {
    /// Deadline windows executed.
    pub windows: u64,
    pub window_ticks: u64,
    /// Virtual-clock origin.
    pub start_tick: u64,
    pub species: Vec<SpeciesSlo>,
}

/// A request waiting in its species queue (molecule already built —
/// construction cost is paid at submit, off the window loop).
struct Pending {
    id: RequestId,
    mol: Box<dyn ServedMolecule>,
    submitted_at: u64,
    ticks: u64,
    deadline: u64,
}

/// A request resident in the farm.
struct Resident {
    species: usize,
    mol_id: usize,
    shard: usize,
    submitted_at: u64,
    /// Farm tick (not virtual-clock tick) at admission.
    admitted_farm_tick: u64,
    /// Virtual-clock tick the request's quantized run completes at.
    due: u64,
    deadline: u64,
    ticks: u64,
}

/// Windows a request needs, rounding its ticks up to whole windows.
fn windows_needed(ticks: u64, window: u64) -> u64 {
    (ticks + window - 1) / window
}

/// The serving front door over a [`MoleculeFarm`]. See the module doc
/// for the flow; the short version: `submit` → queues, `run_window` =
/// EDF admission + one `run_epoch(window_ticks)` + settlement,
/// `take_result` → [`RequestResult`].
pub struct Gateway {
    farm: MoleculeFarm,
    cfg: GatewayConfig,
    now: u64,
    next_id: u64,
    builders: Vec<MoleculeBuilder>,
    queues: Vec<Vec<Pending>>,
    /// Requests resident in the farm, keyed by `RequestId.0` (BTreeMap:
    /// deterministic settlement order).
    resident: BTreeMap<u64, Resident>,
    resident_count: Vec<usize>,
    /// Settled results awaiting pickup, keyed by `RequestId.0`.
    results: BTreeMap<u64, RequestResult>,
    slo: SloLedger,
    /// Quarantine backoff per species (capacity subtracted per window).
    penalty: Vec<usize>,
    /// Farm loss / quarantine records already settled.
    loss_cursor: usize,
    quar_cursor: usize,
}

impl Gateway {
    pub fn new(species: Vec<GatewaySpecies>, cfg: GatewayConfig) -> Result<Gateway> {
        anyhow::ensure!(cfg.window_ticks >= 1, "deadline window must be >= 1 tick");
        anyhow::ensure!(cfg.queue_limit >= 1, "queue limit must be >= 1");
        anyhow::ensure!(cfg.shard_capacity >= 1, "shard capacity must be >= 1");
        let n_species = species.len();
        let mut groups = Vec::with_capacity(n_species);
        let mut builders = Vec::with_capacity(n_species);
        let mut slo_species = Vec::with_capacity(n_species);
        for s in species {
            slo_species.push(SpeciesSlo::new(s.group.name().to_string(), cfg.window_ticks));
            groups.push(s.group);
            builders.push(s.build);
        }
        let sup = FarmSupervision {
            health: cfg.health,
            #[cfg(any(test, feature = "faults"))]
            faults: cfg.faults,
        };
        let farm = MoleculeFarm::supervised(groups, cfg.lanes, cfg.mode, sup)?;
        Ok(Gateway {
            farm,
            cfg,
            now: cfg.start_tick,
            next_id: 0,
            builders,
            queues: (0..n_species).map(|_| Vec::new()).collect(),
            resident: BTreeMap::new(),
            resident_count: vec![0; n_species],
            results: BTreeMap::new(),
            slo: SloLedger {
                windows: 0,
                window_ticks: cfg.window_ticks,
                start_tick: cfg.start_tick,
                species: slo_species,
            },
            penalty: vec![0; n_species],
            loss_cursor: 0,
            quar_cursor: 0,
        })
    }

    /// The virtual clock (farm ticks since `start_tick`, plus the
    /// origin).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Submit a request: `ticks` MD ticks for a fresh molecule of
    /// `species` built from `sys`, wanted by absolute virtual-clock
    /// tick `deadline`. Admission control answers immediately —
    /// [`Submission::Rejected`] is a *decision*, not an error; `Err` is
    /// reserved for infrastructure failures (molecule construction).
    pub fn submit(
        &mut self,
        species: usize,
        sys: &System,
        ticks: u64,
        deadline: u64,
    ) -> Result<Submission> {
        anyhow::ensure!(ticks >= 1, "request must ask for at least one tick");
        if species >= self.builders.len() {
            return Ok(Submission::Rejected(Rejection::UnknownSpecies));
        }
        self.slo.species[species].submitted += 1;
        if self.farm.live_shards(species) == 0 {
            self.slo.species[species].rejected_species_down += 1;
            return Ok(Submission::Rejected(Rejection::SpeciesDown));
        }
        if self.queues[species].len() >= self.cfg.queue_limit {
            self.slo.species[species].rejected_queue_full += 1;
            return Ok(Submission::Rejected(Rejection::QueueFull));
        }
        let w = self.cfg.window_ticks;
        if self.now + windows_needed(ticks, w) * w > deadline {
            self.slo.species[species].rejected_deadline += 1;
            return Ok(Submission::Rejected(Rejection::DeadlineImpossible));
        }
        let mol = (self.builders[species])(sys)?;
        let id = RequestId(self.next_id);
        self.next_id += 1;
        self.queues[species].push(Pending { id, mol, submitted_at: self.now, ticks, deadline });
        let slo = &mut self.slo.species[species];
        slo.accepted += 1;
        slo.queue_depth_high_water =
            slo.queue_depth_high_water.max(self.queues[species].len() as u64);
        Ok(Submission::Accepted(id))
    }

    /// Settle one request into the results map and the SLO book.
    #[allow(clippy::too_many_arguments)] // internal settlement plumbing
    fn settle(
        &mut self,
        id: RequestId,
        species: usize,
        submitted_at: u64,
        deadline: u64,
        ticks_requested: u64,
        ticks_run: u64,
        outcome: Outcome,
    ) {
        let completed_at = self.now;
        let latency = completed_at - submitted_at;
        let met = matches!(outcome, Outcome::Done { .. }) && completed_at <= deadline;
        let slo = &mut self.slo.species[species];
        match &outcome {
            Outcome::Done { .. } => {
                slo.completed += 1;
                if met {
                    slo.deadline_met += 1;
                } else {
                    slo.deadline_missed += 1;
                }
                slo.latency.record(latency);
            }
            Outcome::Quarantined { .. } => slo.failed_quarantined += 1,
            Outcome::ShardLost { .. } => slo.failed_shard_lost += 1,
            Outcome::Shed => slo.shed_queued += 1,
        }
        self.results.insert(
            id.0,
            RequestResult {
                id,
                species,
                submitted_at,
                completed_at,
                deadline,
                ticks_requested,
                ticks_run,
                latency_ticks: latency,
                deadline_met: met,
                outcome,
            },
        );
    }

    /// One deadline window: EDF batch forming + admission control, one
    /// `run_epoch(window_ticks)` (the only execution call in the
    /// gateway), then settlement — shard losses first, then quarantine
    /// verdicts, then completed residents. Every decision is a pure
    /// function of gateway + supervisor state, so replays and backends
    /// agree exactly.
    pub fn run_window(&mut self) -> Result<()> {
        let w = self.cfg.window_ticks;
        // --- Batch forming: earliest deadline first, id tie-break. ---
        for sp in 0..self.queues.len() {
            self.queues[sp].sort_by_key(|p| (p.deadline, p.id.0));
            let live = self.farm.live_shards(sp);
            let cap = (live * self.cfg.shard_capacity).saturating_sub(self.penalty[sp]);
            let mut i = 0;
            while i < self.queues[sp].len() {
                let (ticks, deadline) = (self.queues[sp][i].ticks, self.queues[sp][i].deadline);
                let windows = windows_needed(ticks, w);
                if self.now + windows * w > deadline {
                    // Unmeetable — shed before the capacity check, so a
                    // saturated queue still drains its dead weight.
                    let p = self.queues[sp].remove(i);
                    self.settle(p.id, sp, p.submitted_at, p.deadline, p.ticks, 0, Outcome::Shed);
                    continue;
                }
                if live == 0 || self.resident_count[sp] >= cap {
                    i += 1; // defer to a later window
                    continue;
                }
                let p = self.queues[sp].remove(i);
                let ticket = self.farm.admit(sp, p.mol)?;
                self.resident.insert(
                    p.id.0,
                    Resident {
                        species: sp,
                        mol_id: ticket.mol_id,
                        shard: ticket.shard,
                        submitted_at: p.submitted_at,
                        admitted_farm_tick: self.now - self.cfg.start_tick,
                        due: self.now + windows * w,
                        deadline: p.deadline,
                        ticks: p.ticks,
                    },
                );
                self.resident_count[sp] += 1;
                let slo = &mut self.slo.species[sp];
                slo.resident_high_water =
                    slo.resident_high_water.max(self.resident_count[sp] as u64);
            }
        }

        // --- One epoch per window: the execution quantum. ---
        self.farm.run_epoch(w as usize)?;
        self.now += w;
        self.slo.windows += 1;

        // --- Settlement. Losses first: a lost shard's residents fail
        // (their state is frozen on the dead shard — never retired),
        // and any quarantine record recovered from that shard then
        // finds no resident to double-settle. ---
        let mut dirty = vec![false; self.queues.len()];
        let losses: Vec<(usize, usize, u64)> = self.farm.losses()[self.loss_cursor..]
            .iter()
            .map(|l| (l.shard, l.species, l.tick))
            .collect();
        self.loss_cursor += losses.len();
        for (shard, species, tick) in losses {
            dirty[species] = true;
            let failed: Vec<u64> = self
                .resident
                .iter()
                .filter(|(_, r)| r.shard == shard)
                .map(|(&k, _)| k)
                .collect();
            for k in failed {
                let r = self.resident.remove(&k).expect("resident id just listed");
                self.resident_count[r.species] -= 1;
                let run = tick.saturating_sub(r.admitted_farm_tick);
                self.settle(
                    RequestId(k),
                    r.species,
                    r.submitted_at,
                    r.deadline,
                    r.ticks,
                    run,
                    Outcome::ShardLost { tick },
                );
            }
        }
        // Quarantine verdicts: retire the pulled molecule (its shard is
        // live — dead shards' residents were settled above) and return
        // its frozen state.
        let quars: Vec<_> = self.farm.quarantine_records()[self.quar_cursor..].to_vec();
        self.quar_cursor += quars.len();
        for q in quars {
            dirty[q.species] = true;
            let hit = self
                .resident
                .iter()
                .find(|(_, r)| r.mol_id == q.molecule)
                .map(|(&k, _)| k);
            let Some(k) = hit else { continue };
            let r = self.resident.remove(&k).expect("resident id just found");
            self.resident_count[r.species] -= 1;
            let retired = self.farm.retire(r.mol_id)?;
            self.settle(
                RequestId(k),
                r.species,
                r.submitted_at,
                r.deadline,
                r.ticks,
                retired.steps,
                Outcome::Quarantined { reason: q.reason, tick: q.tick, positions: retired.positions },
            );
        }
        // Harvest completed residents (id order — BTreeMap).
        let due: Vec<u64> = self
            .resident
            .iter()
            .filter(|(_, r)| r.due <= self.now)
            .map(|(&k, _)| k)
            .collect();
        for k in due {
            let r = self.resident.remove(&k).expect("resident id just listed");
            self.resident_count[r.species] -= 1;
            let retired = self.farm.retire(r.mol_id)?;
            self.settle(
                RequestId(k),
                r.species,
                r.submitted_at,
                r.deadline,
                r.ticks,
                retired.steps,
                Outcome::Done { positions: retired.positions, steps: retired.steps },
            );
        }
        // Quarantine/loss backoff: shrink a dirty species' next-window
        // capacity by one, recover by one per clean window.
        for sp in 0..self.penalty.len() {
            if dirty[sp] {
                self.penalty[sp] += 1;
            } else {
                self.penalty[sp] = self.penalty[sp].saturating_sub(1);
            }
        }
        Ok(())
    }

    /// Run `n` deadline windows.
    pub fn run_windows(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.run_window()?;
        }
        Ok(())
    }

    /// Replay a deterministic arrival plan (see
    /// [`crate::testkit::arrivals`]): arrivals are submitted at the
    /// first window boundary at or after their `at_tick` (absolute
    /// virtual-clock ticks — offset them by `start_tick` if nonzero),
    /// `system_for(i, arrival)` supplies the i-th request's initial
    /// state, and windows run until the plan is exhausted and every
    /// accepted request has settled. Returns the per-arrival
    /// submission decisions, in plan order.
    pub fn play(
        &mut self,
        plan: &[Arrival],
        mut system_for: impl FnMut(usize, &Arrival) -> System,
    ) -> Result<Vec<Submission>> {
        let mut subs = Vec::with_capacity(plan.len());
        let mut next = 0usize;
        let mut guard = 0u32;
        loop {
            while next < plan.len() && plan[next].at_tick <= self.now {
                let a = plan[next];
                let sys = system_for(next, &a);
                subs.push(self.submit(a.species, &sys, a.ticks, a.deadline)?);
                next += 1;
            }
            if next >= plan.len() && self.queued() == 0 && self.in_flight() == 0 {
                break;
            }
            self.run_window()?;
            guard += 1;
            anyhow::ensure!(guard <= 100_000, "gateway replay did not drain");
        }
        Ok(subs)
    }

    /// Where a request currently is.
    pub fn status(&self, id: RequestId) -> RequestStatus {
        if self.results.contains_key(&id.0) {
            RequestStatus::Finished
        } else if self.resident.contains_key(&id.0) {
            RequestStatus::Running
        } else if self.queues.iter().any(|q| q.iter().any(|p| p.id == id)) {
            RequestStatus::Queued
        } else {
            RequestStatus::Unknown
        }
    }

    /// Take one settled result (None until it settles; a result can be
    /// taken once).
    pub fn take_result(&mut self, id: RequestId) -> Option<RequestResult> {
        self.results.remove(&id.0)
    }

    /// Drain every settled result, in request-id order.
    pub fn take_results(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.results).into_values().collect()
    }

    /// Requests waiting in queues.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Requests resident in the farm.
    pub fn in_flight(&self) -> usize {
        self.resident.len()
    }

    /// The SLO ledger so far.
    pub fn slo(&self) -> &SloLedger {
        &self.slo
    }

    /// The farm's live running telemetry. **Undercounts on lost
    /// replies** (a dropped epoch executed but was never reported —
    /// see [`FarmTelemetry`]); the books from [`Gateway::finish`] read
    /// shard state directly and are the source of truth.
    pub fn telemetry(&mut self) -> FarmTelemetry {
        self.farm.telemetry()
    }

    /// Tear down: the SLO ledger plus the farm's final [`FarmLedger`]
    /// (which reads shard state directly — complete even when epoch
    /// replies were lost).
    pub fn finish(self) -> Result<(SloLedger, FarmLedger)> {
        let Gateway { farm, slo, .. } = self;
        Ok((slo, farm.finish()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::farm::{random_water_systems, FarmConfig, WaterFarm};
    use crate::nn::Activation;
    use crate::testkit::arrivals::{self, ArrivalSpec};
    use crate::util::rng::Pcg;

    fn toy_model() -> Mlp {
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.3;
            }
        }
        m
    }

    fn water_gateway(shards: usize, cfg: GatewayConfig) -> Gateway {
        let m = toy_model();
        Gateway::new(vec![GatewaySpecies::water(&m, 3, shards, 0.25).unwrap()], cfg).unwrap()
    }

    #[test]
    fn single_request_is_bit_identical_to_a_direct_farm_run() {
        // A request for 10 ticks under a 4-tick window quantizes up to
        // 12 steps, and the trajectory must match a plain farm driving
        // the same system 12 ticks — admission via empty groups plus
        // admit() cannot move a bit.
        let m = toy_model();
        let sys = random_water_systems(1, 120.0, 5).pop().unwrap();
        let cfg = GatewayConfig { window_ticks: 4, ..GatewayConfig::default() };
        let mut gw = Gateway::new(vec![GatewaySpecies::water(&m, 3, 1, 0.25).unwrap()], cfg).unwrap();
        let Submission::Accepted(id) = gw.submit(0, &sys, 10, 1_000).unwrap() else {
            panic!("accept")
        };
        assert_eq!(gw.status(id), RequestStatus::Queued);
        gw.run_window().unwrap();
        assert_eq!(gw.status(id), RequestStatus::Running);
        gw.run_windows(2).unwrap();
        assert_eq!(gw.status(id), RequestStatus::Finished);
        let res = gw.take_result(id).expect("settled");
        assert_eq!(gw.status(id), RequestStatus::Unknown);
        assert!(res.deadline_met);
        assert_eq!(res.ticks_requested, 10);
        assert_eq!(res.ticks_run, 12);
        assert_eq!(res.latency_ticks, 12);
        let Outcome::Done { positions, steps } = &res.outcome else {
            panic!("done, got {:?}", res.outcome)
        };
        assert_eq!(*steps, 12);

        let mut farm =
            WaterFarm::new(&m, std::slice::from_ref(&sys), &FarmConfig::default()).unwrap();
        farm.run(12).unwrap();
        assert_eq!(positions, &farm.positions().unwrap()[0]);

        // The farm ledger keeps the retired molecule's books.
        let (slo, ledger) = gw.finish().unwrap();
        assert_eq!(ledger.molecule_steps, 12);
        assert_eq!(slo.species[0].completed, 1);
        assert_eq!(slo.species[0].deadline_met, 1);
        // Latency 12 lands in bucket [12, 16); the quantile reports the
        // conservative bucket upper bound.
        assert_eq!(slo.species[0].latency.p50(), 16);
        assert_eq!(slo.species[0].latency.max(), 12);
    }

    #[test]
    fn door_rejections_are_counted_and_typed() {
        let sys = random_water_systems(1, 120.0, 6).pop().unwrap();
        let cfg = GatewayConfig { window_ticks: 4, queue_limit: 2, ..GatewayConfig::default() };
        let mut gw = water_gateway(1, cfg);
        assert_eq!(
            gw.submit(3, &sys, 4, 100).unwrap(),
            Submission::Rejected(Rejection::UnknownSpecies)
        );
        // 9 ticks → 3 windows of 4 = 12 > deadline 11.
        assert_eq!(
            gw.submit(0, &sys, 9, 11).unwrap(),
            Submission::Rejected(Rejection::DeadlineImpossible)
        );
        assert!(matches!(gw.submit(0, &sys, 4, 100).unwrap(), Submission::Accepted(_)));
        assert!(matches!(gw.submit(0, &sys, 4, 100).unwrap(), Submission::Accepted(_)));
        assert_eq!(
            gw.submit(0, &sys, 4, 100).unwrap(),
            Submission::Rejected(Rejection::QueueFull)
        );
        let slo = &gw.slo().species[0];
        assert_eq!(slo.submitted, 4); // unknown-species lands on no species
        assert_eq!(slo.accepted, 2);
        assert_eq!(slo.rejected_deadline, 1);
        assert_eq!(slo.rejected_queue_full, 1);
        assert_eq!(slo.queue_depth_high_water, 2);
    }

    #[test]
    fn same_plan_replays_to_identical_decisions_and_ledgers() {
        let spec = ArrivalSpec { mean_gap: 2, ..ArrivalSpec::new(21, 24, 1) };
        let plan = arrivals::plan(&spec);
        let systems = random_water_systems(plan.len(), 140.0, 8);
        let cfg = GatewayConfig {
            window_ticks: 4,
            shard_capacity: 3,
            queue_limit: 6,
            ..GatewayConfig::default()
        };
        let run = || {
            let mut gw = water_gateway(2, cfg);
            let subs = gw.play(&plan, |i, _| systems[i].clone()).unwrap();
            let results = gw.take_results();
            let (slo, _) = gw.finish().unwrap();
            (subs, results, slo)
        };
        let (sa, ra, la) = run();
        let (sb, rb, lb) = run();
        assert_eq!(sa, sb, "accept/reject decisions must replay exactly");
        assert_eq!(ra, rb, "results must replay exactly");
        assert_eq!(la, lb, "SLO ledgers must replay exactly");
        assert!(ra.iter().any(|r| matches!(r.outcome, Outcome::Done { .. })));
    }

    #[test]
    fn inline_and_threaded_gateways_are_bit_identical() {
        let spec = ArrivalSpec { mean_gap: 3, ..ArrivalSpec::new(33, 20, 1) };
        let plan = arrivals::plan(&spec);
        let systems = random_water_systems(plan.len(), 150.0, 13);
        let run = |mode: ParallelMode| {
            let cfg = GatewayConfig {
                window_ticks: 4,
                shard_capacity: 2,
                queue_limit: 8,
                mode,
                ..GatewayConfig::default()
            };
            let mut gw = water_gateway(3, cfg);
            let subs = gw.play(&plan, |i, _| systems[i].clone()).unwrap();
            let results = gw.take_results();
            let (slo, ledger) = gw.finish().unwrap();
            (subs, results, slo, ledger.molecule_steps)
        };
        let (si, ri, li, mi) = run(ParallelMode::Inline);
        let (st, rt, lt, mt) = run(ParallelMode::Threaded);
        assert_eq!(si, st, "decisions diverged across backends");
        assert_eq!(ri, rt, "per-request results (incl. positions) diverged across backends");
        assert_eq!(li, lt, "SLO ledgers diverged across backends");
        assert_eq!(mi, mt);
    }

    #[test]
    fn saturation_sheds_load_but_accepted_requests_meet_deadlines() {
        // The acceptance-criteria test: a burst far beyond capacity on
        // one single shard. The gateway must bound the queue (nonzero
        // QueueFull rejects), shed/defer the rest, and every request it
        // *completes* must still meet its deadline.
        let cfg = GatewayConfig {
            window_ticks: 4,
            shard_capacity: 2,
            queue_limit: 4,
            ..GatewayConfig::default()
        };
        let mut gw = water_gateway(1, cfg);
        let systems = random_water_systems(16, 120.0, 17);
        let mut accepted = Vec::new();
        for sys in &systems {
            // Everyone wants 4 ticks by tick 4 — one window of runway,
            // but capacity is 2 molecules per window: 2 complete on
            // time, the rest of the queue sheds, the burst tail rejects
            // at the door.
            if let Submission::Accepted(id) = gw.submit(0, sys, 4, 4).unwrap() {
                accepted.push(id);
            }
        }
        gw.run_windows(12).unwrap();
        let slo = &gw.slo().species[0];
        assert_eq!(slo.submitted, 16);
        assert!(slo.rejected_queue_full > 0, "saturation must reject at the door");
        assert!(slo.queue_depth_high_water <= 4, "queue must stay bounded");
        assert!(slo.completed > 0, "capacity-worth of requests must finish");
        assert_eq!(slo.deadline_missed, 0, "completed requests must meet deadlines");
        assert!(slo.shed_queued > 0, "unmeetable queued requests must shed");
        // Accounting identities.
        assert_eq!(slo.submitted, slo.accepted + slo.rejected());
        assert_eq!(slo.accepted, slo.completed + slo.shed_queued);
        assert_eq!(gw.queued(), 0);
        assert_eq!(gw.in_flight(), 0);
        // Every accepted request settled one way or the other.
        for id in accepted {
            assert_eq!(gw.status(id), RequestStatus::Finished);
        }
    }

    #[test]
    fn telemetry_tracks_windows() {
        let cfg = GatewayConfig { window_ticks: 4, ..GatewayConfig::default() };
        let mut gw = water_gateway(2, cfg);
        let sys = random_water_systems(1, 120.0, 3).pop().unwrap();
        assert!(matches!(gw.submit(0, &sys, 8, 100).unwrap(), Submission::Accepted(_)));
        gw.run_windows(3).unwrap();
        let t = gw.telemetry();
        assert_eq!(t.ticks, 12, "three 4-tick windows");
        assert_eq!(t.epochs, 3, "one epoch per window — never per-tick");
        // The resident molecule ran 8 quantized ticks.
        assert_eq!(t.molecule_steps, 8);
        assert_eq!(gw.now(), 12);
        assert_eq!(gw.slo().windows, 3);
    }

    #[test]
    fn results_drain_in_id_order() {
        let cfg = GatewayConfig { window_ticks: 4, ..GatewayConfig::default() };
        let mut gw = water_gateway(2, cfg);
        let systems = random_water_systems(3, 130.0, 23);
        for sys in &systems {
            assert!(matches!(gw.submit(0, sys, 4, 100).unwrap(), Submission::Accepted(_)));
        }
        gw.run_windows(2).unwrap();
        let results = gw.take_results();
        assert_eq!(results.len(), 3);
        let ids: Vec<u64> = results.iter().map(|r| r.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(gw.take_results().is_empty(), "drained once");
    }

    #[test]
    fn histogram_quantiles_are_exact() {
        let mut h = LatencyHistogram::new(4);
        // 10 latencies: 8×[0,4), 1×[4,8), 1 overflow at 1000.
        for _ in 0..8 {
            h.record(2);
        }
        h.record(5);
        h.record(1_000);
        assert_eq!(h.total(), 10);
        assert_eq!(h.p50(), 4, "5th of 10 lands in the first bucket");
        assert_eq!(h.quantile(0.9), 8);
        assert_eq!(h.p99(), 1_000, "overflow bucket reports the recorded max");
        assert_eq!(h.max(), 1_000);
        assert_eq!(LatencyHistogram::new(4).p99(), 0, "empty histogram");
    }

    #[test]
    fn empty_gateway_windows_are_legal() {
        // Idle windows advance the clock and nothing else — the farm's
        // empty shards run zero-lane batches.
        let cfg = GatewayConfig { window_ticks: 8, ..GatewayConfig::default() };
        let mut gw = water_gateway(2, cfg);
        gw.run_windows(3).unwrap();
        assert_eq!(gw.now(), 24);
        let (slo, ledger) = gw.finish().unwrap();
        assert_eq!(slo.windows, 3);
        assert_eq!(ledger.molecule_steps, 0);
        assert_eq!(ledger.ticks, 24);
    }
}
