//! The molecule farm — the batched, sharded, **multi-species** serving
//! path of the coordinator.
//!
//! Where [`super::WaterSystem`] reproduces the paper's single-molecule
//! latency pipeline, [`MoleculeFarm`] turns the same devices into a
//! throughput engine for the whole Table-I mix: N independent molecules
//! advance one MD step per *tick*, sharded over worker threads. The
//! farm is parameterized over the [`ServedMolecule`] trait (extract →
//! batched infer → integrate), and molecules are grouped into
//! [`SpeciesGroup`]s: every shard programs its **own** `nn::Sqnn` from
//! its species' model, so per-species models coexist in one farm and
//! request batches route to the shard holding their model — the
//! serving-tier shape of heterogeneous ML-force-field traffic.
//!
//! Each shard owns its molecules' FPGA state, one batched MLP chip
//! programmed with the species model, and all the scratch the hot loop
//! needs, and drives the paper's §IV-C workflow in batch form:
//!
//! 1. extract — every molecule scatters its conditioned Q13 features
//!    into the shard's SoA block (water: `fpga::WaterFpga` hydrogen
//!    triples; generic molecules: the `fpga::MoleculeFpga` 4·n_nb
//!    descriptor path);
//! 2. `MlpChip::infer_batch_into` — one weight-stationary batched
//!    inference over all shard lanes via the SWAR shift-program kernel
//!    (`nn::sqnn`: precompiled per-layer instruction streams executed
//!    over 8-lane accumulator tiles, bit-identical to the scalar
//!    datapath), with the `ChipConfig::lanes` intra-ASIC parallelism
//!    model (§VI A₂) accounting ⌈B/lanes⌉ pipeline waves;
//! 3. integrate — force reconstruction (+ Newton's third law where the
//!    species needs it) and integration per molecule.
//!
//! Shards are fully independent, so the inline and threaded backends
//! are bit-identical by construction — the same guarantee the
//! single-molecule coordinator makes, extended to the farm. The
//! aggregated [`FarmLedger`] reports modelled hardware cycles (lane
//! model included), op counts, and **host throughput in
//! molecule-steps/second**, farm-wide and per species.
//!
//! [`WaterFarm`] is the water instantiation of the generic farm and
//! keeps the pre-refactor behavior bit for bit.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::asic::{ChipConfig, MlpChip};
use crate::features;
use crate::fixedpoint::Q13;
use crate::fpga::{FeatureConditioner, HFeatures, MoleculeFpga, WaterFpga, ZERO_FRAME};
use crate::hw::power::OpCounts;
use crate::hw::timing::StepCycles;
use crate::md::{initialize_velocities, System};
use crate::nn::Mlp;
use crate::potentials::WaterPes;
use crate::util::rng::Pcg;
use crate::util::Vec3;

use super::pool::WorkerPool;
use super::ParallelMode;

/// Farm construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct FarmConfig {
    /// Worker shards (clamped to the molecule count).
    pub shards: usize,
    /// Parallel MLP lanes per shard chip (see [`ChipConfig::lanes`]).
    pub lanes: usize,
    /// Shift terms per weight for quantization.
    pub k: usize,
    /// Integrator timestep (fs).
    pub dt_fs: f64,
    /// Shard execution backend.
    pub mode: ParallelMode,
}

impl Default for FarmConfig {
    fn default() -> Self {
        FarmConfig { shards: 1, lanes: 1, k: 3, dt_fs: 0.25, mode: ParallelMode::Inline }
    }
}

/// One served molecule: how a species plugs its FPGA datapath into the
/// farm's extract → batched-infer → integrate tick. Implementations own
/// all per-molecule state (including whatever the integrate stage needs
/// from extraction, e.g. the water bond frames), so a tick allocates
/// nothing.
pub trait ServedMolecule: Send {
    /// Chip lanes (inferences) this molecule occupies per tick.
    fn lanes(&self) -> usize;
    /// Atom count (serving-metric denominator).
    fn n_atoms(&self) -> usize;
    /// Modelled FPGA cycles (feature + integration stages) of one step
    /// of this molecule; the shared per-tick transfer/control windows
    /// and the chip lane model are accounted by the shard.
    fn fpga_cycles_per_tick(&self) -> u64;
    /// Scatter the conditioned Q13 features into the shard's SoA block:
    /// feature `i` of the molecule's local lane `l` belongs at
    /// `feats[i * batch + lane0 + l]`.
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize);
    /// Consume the chip's SoA outputs for this molecule's lanes (output
    /// `o` of local lane `l` at `outs[o * batch + lane0 + l]`) and
    /// advance one MD step.
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize);
    /// Decoded positions (analysis tap).
    fn positions(&self) -> Vec<Vec3>;
    /// FPGA op counters (energy model).
    fn ops(&self) -> OpCounts;
    /// Steps integrated so far.
    fn steps(&self) -> u64;
}

/// The water species: one [`WaterFpga`] per molecule, two hydrogen
/// lanes, local-frame force reconstruction + Newton's third law — the
/// paper's §IV-C datapath, bit-identical to the pre-refactor farm.
struct WaterServed {
    fpga: WaterFpga,
    /// Bond frames of the last extraction (consumed by integrate).
    frames: [HFeatures; 2],
}

impl ServedMolecule for WaterServed {
    fn lanes(&self) -> usize {
        2
    }
    fn n_atoms(&self) -> usize {
        3
    }
    fn fpga_cycles_per_tick(&self) -> u64 {
        let b = StepCycles::water();
        b.feature + b.integrate
    }
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize) {
        let fr = self.fpga.extract_features();
        for (hi, f) in fr.iter().enumerate() {
            for (i, &d) in f.d.iter().enumerate() {
                feats[i * batch + lane0 + hi] = d;
            }
        }
        self.frames = fr;
    }
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize) {
        let c = [
            [outs[lane0], outs[batch + lane0]],
            [outs[lane0 + 1], outs[batch + lane0 + 1]],
        ];
        self.fpga.integrate(&self.frames, c);
    }
    fn positions(&self) -> Vec<Vec3> {
        self.fpga.positions()
    }
    fn ops(&self) -> OpCounts {
        self.fpga.ops
    }
    fn steps(&self) -> u64 {
        self.fpga.steps
    }
}

/// A generic Table-I molecule: one [`MoleculeFpga`] per molecule, one
/// chip lane per atom over the 4·n_nb `local_descriptor` path, the chip
/// predicting Cartesian forces directly.
struct GenericServed {
    fpga: MoleculeFpga,
}

impl ServedMolecule for GenericServed {
    fn lanes(&self) -> usize {
        self.fpga.n_atoms()
    }
    fn n_atoms(&self) -> usize {
        self.fpga.n_atoms()
    }
    fn fpga_cycles_per_tick(&self) -> u64 {
        self.fpga.cycles_per_step()
    }
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize) {
        self.fpga.extract_features_soa(feats, batch, lane0);
    }
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize) {
        self.fpga.integrate_soa(outs, batch, lane0);
    }
    fn positions(&self) -> Vec<Vec3> {
        self.fpga.positions()
    }
    fn ops(&self) -> OpCounts {
        self.fpga.ops
    }
    fn steps(&self) -> u64 {
        self.fpga.steps
    }
}

/// One species' slice of the farm: its model (each shard programs its
/// own `Sqnn` from it), quantization K, requested shard count, and the
/// served molecules.
pub struct SpeciesGroup {
    name: String,
    model: Mlp,
    k: usize,
    shards: usize,
    mols: Vec<Box<dyn ServedMolecule>>,
}

impl SpeciesGroup {
    /// Assemble a species group from pre-built served molecules. The
    /// `model`/`k` pair is what every shard of this species programs
    /// into its chip; `mols` must already be programmed consistently
    /// with it (use [`water_group`] / [`generic_group`] unless you are
    /// plugging in a custom [`ServedMolecule`]).
    pub fn new(
        name: &str,
        model: Mlp,
        k: usize,
        shards: usize,
        mols: Vec<Box<dyn ServedMolecule>>,
    ) -> Result<SpeciesGroup> {
        anyhow::ensure!(!mols.is_empty(), "species {name:?} needs at least one molecule");
        anyhow::ensure!(shards >= 1, "species {name:?} needs at least one shard");
        Ok(SpeciesGroup { name: name.to_string(), model, k, shards, mols })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
    pub fn n_molecules(&self) -> usize {
        self.mols.len()
    }
}

/// Build the water species group (the Table-I water instantiation).
pub fn water_group(
    model: &Mlp,
    systems: &[System],
    k: usize,
    shards: usize,
    dt_fs: f64,
) -> Result<SpeciesGroup> {
    let force_shift = super::validate_water_model(model)?;
    let mols = systems
        .iter()
        .map(|sys| {
            let mut f = WaterFpga::new(sys, dt_fs);
            super::program_water_fpga(&mut f, model, force_shift)?;
            Ok(Box::new(WaterServed { fpga: f, frames: [ZERO_FRAME; 2] })
                as Box<dyn ServedMolecule>)
        })
        .collect::<Result<Vec<_>>>()?;
    SpeciesGroup::new("water", model.clone(), k, shards, mols)
}

/// Build a generic-molecule species group over the 4·n_nb descriptor
/// path: neighbor ordering fixed by `ref_coords` (reference topology),
/// feature conditioning and force rescale programmed from the model —
/// the host-CPU initialization path generalized beyond water.
#[allow(clippy::too_many_arguments)] // flat one-call init API, mirrors water_group + topology
pub fn generic_group(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
) -> Result<SpeciesGroup> {
    generic_group_impl(name, model, ref_coords, systems, n_nb, k, shards, dt_fs, None)
}

/// Build a bulk (periodic) species group: same descriptor path as
/// [`generic_group`] but the neighbor ordering is minimum-imaged over the
/// cubic `box_l` cell and every device runs with wrapped positions
/// ([`MoleculeFpga::new_pbc`]) — silicon-class crystals on the same
/// batched serving path as molecules.
#[allow(clippy::too_many_arguments)]
pub fn generic_group_pbc(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
    box_l: f64,
) -> Result<SpeciesGroup> {
    generic_group_impl(name, model, ref_coords, systems, n_nb, k, shards, dt_fs, Some(box_l))
}

#[allow(clippy::too_many_arguments)]
fn generic_group_impl(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
    box_l: Option<f64>,
) -> Result<SpeciesGroup> {
    let n = ref_coords.len();
    anyhow::ensure!(
        n_nb >= 1 && n_nb < n,
        "species {name:?}: n_nb = {n_nb} needs 1 ≤ n_nb < {n} atoms"
    );
    anyhow::ensure!(
        model.in_dim() == 4 * n_nb && model.out_dim() == 3,
        "species {name:?}: model must be {}→…→3 for n_nb = {n_nb} (got {}→…→{})",
        4 * n_nb,
        model.in_dim(),
        model.out_dim()
    );
    let force_shift = model.force_shift()?;
    let nb: Vec<Vec<usize>> = (0..n)
        .map(|i| match box_l {
            Some(l) => features::reference_neighbors_pbc(ref_coords, i, n_nb, l),
            None => features::reference_neighbors(ref_coords, i, n_nb),
        })
        .collect();
    let cond = FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)?;
    let mols = systems
        .iter()
        .map(|sys| {
            anyhow::ensure!(
                sys.len() == n,
                "species {name:?}: system has {} atoms, reference {n}",
                sys.len()
            );
            let mut f = match box_l {
                Some(l) => MoleculeFpga::new_pbc(sys, nb.clone(), cond.clone(), dt_fs, l)?,
                None => MoleculeFpga::new(sys, nb.clone(), cond.clone(), dt_fs)?,
            };
            f.force_shift = force_shift;
            Ok(Box::new(GenericServed { fpga: f }) as Box<dyn ServedMolecule>)
        })
        .collect::<Result<Vec<_>>>()?;
    SpeciesGroup::new(name, model.clone(), k, shards, mols)
}

/// One shard: a slice of one species' molecules, its batched chip
/// (programmed with that species' own `Sqnn`), and the scratch buffers
/// of the hot loop (owned here so a tick allocates nothing).
struct FarmShard {
    /// Index into the farm's species table.
    species: usize,
    mols: Vec<Box<dyn ServedMolecule>>,
    /// First lane of each molecule in the shard's SoA batch.
    lane0: Vec<usize>,
    /// Total chip lanes (Σ molecule lanes).
    batch: usize,
    chip: MlpChip,
    feats: Vec<Q13>,
    outs: Vec<Q13>,
    /// Modelled hardware cycles of one tick of this shard.
    tick_cycles: u64,
    ticks: u64,
    wall: Duration,
}

impl FarmShard {
    fn new(
        id: usize,
        species: usize,
        mols: Vec<Box<dyn ServedMolecule>>,
        model: &Mlp,
        k: usize,
        lanes: usize,
    ) -> Result<FarmShard> {
        let mut chip = MlpChip::new(id, ChipConfig { lanes, ..ChipConfig::default() });
        chip.program(model, k);
        let mut lane0 = Vec::with_capacity(mols.len());
        let mut batch = 0usize;
        for m in &mols {
            lane0.push(batch);
            batch += m.lanes();
        }
        let tick_cycles = Self::tick_cycle_budget(&mols, &chip, batch);
        Ok(FarmShard {
            species,
            lane0,
            batch,
            feats: vec![Q13::ZERO; model.in_dim() * batch],
            outs: vec![Q13::ZERO; model.out_dim() * batch],
            mols,
            chip,
            tick_cycles,
            ticks: 0,
            wall: Duration::ZERO,
        })
    }

    /// Modelled cycles of one shard tick: the FPGA streams its molecules
    /// through feature extraction and integration sequentially, shares
    /// one transfer/control window per tick, and the chip's lane model
    /// covers the batched MLP stage (⌈batch/lanes⌉ pipeline waves).
    fn tick_cycle_budget(mols: &[Box<dyn ServedMolecule>], chip: &MlpChip, batch: usize) -> u64 {
        let b = StepCycles::water();
        mols.iter().map(|m| m.fpga_cycles_per_tick()).sum::<u64>()
            + b.to_chip
            + b.from_chip
            + b.control
            + chip.batch_latency_cycles(batch)
    }

    /// One MD step for every molecule in the shard.
    fn tick(&mut self) -> Result<()> {
        let t0 = Instant::now();
        for (m, mol) in self.mols.iter_mut().enumerate() {
            mol.extract(&mut self.feats, self.batch, self.lane0[m]);
        }
        self.chip.infer_batch_into(&self.feats, self.batch, &mut self.outs)?;
        for (m, mol) in self.mols.iter_mut().enumerate() {
            mol.integrate(&self.outs, self.batch, self.lane0[m]);
        }
        self.ticks += 1;
        self.wall += t0.elapsed();
        Ok(())
    }

    fn positions(&self) -> Vec<Vec<Vec3>> {
        self.mols.iter().map(|m| m.positions()).collect()
    }
}

enum FarmBackend {
    Inline(Vec<FarmShard>),
    Threaded(WorkerPool<FarmShard>),
}

/// Per-species slice of the aggregated ledger.
#[derive(Debug, Clone, Default)]
pub struct SpeciesLedger {
    pub name: String,
    pub n_molecules: usize,
    /// Total atoms across the species' molecules.
    pub n_atoms: usize,
    /// Molecule-steps of this species: `ticks × n_molecules`.
    pub molecule_steps: u64,
    pub chip_inferences: u64,
    /// Host wall-clock each of the species' shards spent in its tick
    /// body.
    pub shard_walls: Vec<Duration>,
}

impl SpeciesLedger {
    /// Host molecule-steps per **shard-second** of this species: steps
    /// divided by the summed wall-clock its shards spent inside their
    /// tick bodies. Unlike an elapsed-time rate this is backend-
    /// independent — inline shards run sequentially and threaded shards
    /// concurrently, but the CPU-time a species consumes per molecule-
    /// step is the same either way — so inline and threaded rows are
    /// directly comparable: it is the per-worker serving cost. (For an
    /// elapsed-time rate, divide the species' steps by the whole farm's
    /// [`FarmLedger::host_wall`].)
    pub fn steps_per_shard_second(&self) -> f64 {
        let t: Duration = self.shard_walls.iter().sum();
        let t = t.as_secs_f64();
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }
}

/// Aggregated accounting of a farm run.
#[derive(Debug, Clone, Default)]
pub struct FarmLedger {
    /// Farm ticks completed (each advances every molecule one step).
    pub ticks: u64,
    pub n_molecules: usize,
    /// Total molecule-steps: `ticks × n_molecules`.
    pub molecule_steps: u64,
    /// Modelled hardware cycles: Σ_shards ticks × shard tick budget
    /// (shards run on parallel hardware, but the conservative ledger
    /// sums them; see [`FarmLedger::hw_seconds_parallel`]).
    pub modelled_cycles: u64,
    /// Modelled cycles of the **slowest** shard (parallel-hardware view).
    pub critical_path_cycles: u64,
    pub chip_inferences: u64,
    pub chip_ops: OpCounts,
    pub fpga_ops: OpCounts,
    /// Host wall-clock of the whole farm (tick loop, incl. transport).
    pub host_wall: Duration,
    /// Host wall-clock each shard spent inside its own tick body.
    pub shard_walls: Vec<Duration>,
    /// Per-species breakdown, in species order (the serving-mix view).
    pub species: Vec<SpeciesLedger>,
}

impl FarmLedger {
    /// Modelled hardware seconds if the shards ran on one serial device.
    pub fn hw_seconds(&self, clock_hz: f64) -> f64 {
        self.modelled_cycles as f64 / clock_hz
    }

    /// Modelled hardware seconds with one device per shard (the farm's
    /// deployment model): the critical-path shard bounds the tick.
    pub fn hw_seconds_parallel(&self, clock_hz: f64) -> f64 {
        self.critical_path_cycles as f64 / clock_hz
    }

    /// Modelled hardware throughput, molecule-steps per second, with
    /// one device per shard.
    pub fn modelled_steps_per_second(&self, clock_hz: f64) -> f64 {
        let t = self.hw_seconds_parallel(clock_hz);
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// Host (simulator) throughput, molecule-steps per second.
    pub fn host_steps_per_second(&self) -> f64 {
        let t = self.host_wall.as_secs_f64();
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// The paper's S metric over the farm (s/step/atom,
    /// parallel-hardware view), using the real atom count of the
    /// species mix (3 per molecule for a water-only farm, as before).
    pub fn s_per_step_atom(&self, clock_hz: f64) -> f64 {
        let atoms_per_tick: u64 = self.species.iter().map(|s| s.n_atoms as u64).sum();
        let atom_steps = self.ticks * atoms_per_tick;
        if atom_steps == 0 {
            return 0.0;
        }
        self.hw_seconds_parallel(clock_hz) / atom_steps as f64
    }
}

/// Species bookkeeping of a farm.
struct SpeciesMeta {
    name: String,
    n_molecules: usize,
    n_atoms: usize,
}

/// The batched multi-molecule, multi-species serving system.
pub struct MoleculeFarm {
    backend: FarmBackend,
    species: Vec<SpeciesMeta>,
    n_molecules: usize,
    n_shards: usize,
    ticks: u64,
    host_wall: Duration,
}

impl MoleculeFarm {
    /// Build the farm: each species group is partitioned into contiguous
    /// shards (clamped to its molecule count; the partition depends only
    /// on counts, so inline and threaded backends see identical shard
    /// contents), and every shard programs its own `Sqnn` from the
    /// group's model — request batches route by model.
    pub fn new(groups: Vec<SpeciesGroup>, lanes: usize, mode: ParallelMode) -> Result<MoleculeFarm> {
        anyhow::ensure!(!groups.is_empty(), "farm needs at least one species");
        anyhow::ensure!(lanes >= 1, "chip needs at least one MLP lane");
        let mut shards = Vec::new();
        let mut species = Vec::new();
        let mut n_molecules = 0usize;
        for (si, g) in groups.into_iter().enumerate() {
            let n = g.mols.len();
            let n_shards = g.shards.min(n);
            let base = n / n_shards;
            let rem = n % n_shards;
            let n_atoms = g.mols.iter().map(|m| m.n_atoms()).sum();
            n_molecules += n;
            let mut mols = g.mols.into_iter();
            for s in 0..n_shards {
                let take = base + usize::from(s < rem);
                let slice: Vec<Box<dyn ServedMolecule>> = mols.by_ref().take(take).collect();
                let id = shards.len();
                shards.push(FarmShard::new(id, si, slice, &g.model, g.k, lanes)?);
            }
            debug_assert!(mols.next().is_none());
            species.push(SpeciesMeta { name: g.name, n_molecules: n, n_atoms });
        }
        let n_shards = shards.len();
        let backend = match mode {
            ParallelMode::Inline => FarmBackend::Inline(shards),
            ParallelMode::Threaded => {
                FarmBackend::Threaded(WorkerPool::spawn("farm-shard", shards))
            }
        };
        Ok(MoleculeFarm {
            backend,
            species,
            n_molecules,
            n_shards,
            ticks: 0,
            host_wall: Duration::ZERO,
        })
    }

    /// One farm tick: every molecule of every species advances one step.
    pub fn tick(&mut self) -> Result<()> {
        let t0 = Instant::now();
        match &mut self.backend {
            FarmBackend::Inline(shards) => {
                for s in shards.iter_mut() {
                    s.tick()?;
                }
            }
            FarmBackend::Threaded(pool) => {
                for r in pool.run_all(|_, s: &mut FarmShard| s.tick())? {
                    r?;
                }
            }
        }
        self.ticks += 1;
        self.host_wall += t0.elapsed();
        Ok(())
    }

    /// Run `n` ticks.
    pub fn run(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.tick()?;
        }
        Ok(())
    }

    /// Decoded positions of every molecule ([molecule][atom]), species
    /// groups in construction order, molecules in their original order
    /// within each group.
    pub fn positions(&self) -> Result<Vec<Vec<Vec3>>> {
        let per_shard: Vec<Vec<Vec<Vec3>>> = match &self.backend {
            FarmBackend::Inline(shards) => shards.iter().map(|s| s.positions()).collect(),
            FarmBackend::Threaded(pool) => pool.run_all(|_, s: &mut FarmShard| s.positions())?,
        };
        Ok(per_shard.into_iter().flatten().collect())
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    pub fn n_molecules(&self) -> usize {
        self.n_molecules
    }

    /// Shards actually built (post-clamp, summed over species).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn n_species(&self) -> usize {
        self.species.len()
    }

    /// Tear the farm down (joining shard threads) and aggregate the
    /// ledger, farm-wide and per species.
    pub fn finish(self) -> Result<FarmLedger> {
        let shards = match self.backend {
            FarmBackend::Inline(shards) => shards,
            FarmBackend::Threaded(pool) => pool.into_items(),
        };
        let mut ledger = FarmLedger {
            ticks: self.ticks,
            n_molecules: self.n_molecules,
            molecule_steps: self.ticks * self.n_molecules as u64,
            host_wall: self.host_wall,
            species: self
                .species
                .iter()
                .map(|sp| SpeciesLedger {
                    name: sp.name.clone(),
                    n_molecules: sp.n_molecules,
                    n_atoms: sp.n_atoms,
                    molecule_steps: self.ticks * sp.n_molecules as u64,
                    ..SpeciesLedger::default()
                })
                .collect(),
            ..FarmLedger::default()
        };
        for s in &shards {
            debug_assert_eq!(s.ticks, self.ticks);
            let shard_cycles = s.ticks * s.tick_cycles;
            ledger.modelled_cycles += shard_cycles;
            ledger.critical_path_cycles = ledger.critical_path_cycles.max(shard_cycles);
            ledger.chip_inferences += s.chip.inferences;
            ledger.chip_ops.merge(&s.chip.ops);
            for m in &s.mols {
                ledger.fpga_ops.merge(&m.ops());
            }
            ledger.shard_walls.push(s.wall);
            let sp = &mut ledger.species[s.species];
            sp.chip_inferences += s.chip.inferences;
            sp.shard_walls.push(s.wall);
        }
        Ok(ledger)
    }
}

/// The batched water-only serving system — the water instantiation of
/// [`MoleculeFarm`], preserving the original farm API and behavior.
pub struct WaterFarm {
    inner: MoleculeFarm,
    pub n_molecules: usize,
    cfg: FarmConfig,
}

impl WaterFarm {
    /// Build the farm: one initial [`System`] per molecule, partitioned
    /// into contiguous shards (the partition depends only on counts, so
    /// inline and threaded backends see identical shard contents).
    pub fn new(model: &Mlp, systems: &[System], cfg: &FarmConfig) -> Result<WaterFarm> {
        anyhow::ensure!(!systems.is_empty(), "farm needs at least one molecule");
        anyhow::ensure!(cfg.shards >= 1, "farm needs at least one shard");
        anyhow::ensure!(cfg.lanes >= 1, "chip needs at least one MLP lane");
        let group = water_group(model, systems, cfg.k, cfg.shards, cfg.dt_fs)?;
        let inner = MoleculeFarm::new(vec![group], cfg.lanes, cfg.mode)?;
        // Store the *effective* configuration (shards post-clamp), so
        // `config()` agrees with what was actually built.
        let cfg_eff = FarmConfig { shards: inner.n_shards(), ..*cfg };
        Ok(WaterFarm { inner, n_molecules: systems.len(), cfg: cfg_eff })
    }

    /// One farm tick: every molecule advances one MD step.
    pub fn tick(&mut self) -> Result<()> {
        self.inner.tick()
    }

    /// Run `n` ticks.
    pub fn run(&mut self, n: usize) -> Result<()> {
        self.inner.run(n)
    }

    /// Decoded positions of every molecule ([molecule][atom], atoms
    /// ordered [O, H1, H2]), in the original `systems` order.
    pub fn positions(&self) -> Result<Vec<Vec<Vec3>>> {
        self.inner.positions()
    }

    pub fn ticks(&self) -> u64 {
        self.inner.ticks()
    }

    /// The farm's effective configuration: `shards` is the post-clamp
    /// count actually built (≤ the requested count).
    pub fn config(&self) -> FarmConfig {
        self.cfg
    }

    /// Tear the farm down (joining shard threads) and aggregate the
    /// ledger.
    pub fn finish(self) -> Result<FarmLedger> {
        self.inner.finish()
    }
}

/// Deterministic per-molecule RNG stream: molecule `i` of workload seed
/// `seed` always sees the same velocities, independent of the farm's
/// shard layout.
fn molecule_rng(seed: u64, i: usize) -> Pcg {
    let stream = (i as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(0x2545_f491_4f6c_dd1d);
    Pcg::new(seed ^ stream)
}

/// Convenience: `n` water molecules at the DFT-surrogate equilibrium
/// with Maxwell–Boltzmann velocities, each from its own deterministic
/// per-molecule stream of `seed` — the farm workload generator used by
/// tests, benches, and the scaling experiment.
pub fn random_water_systems(n: usize, t_k: f64, seed: u64) -> Vec<System> {
    let pes = WaterPes::dft_surrogate();
    (0..n)
        .map(|i| {
            let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
            let mut rng = molecule_rng(seed, i);
            initialize_velocities(&mut sys, t_k, 6, &mut rng);
            sys
        })
        .collect()
}

/// Convenience: `n` copies of a generic molecule at its reference
/// geometry with Maxwell–Boltzmann velocities (per-molecule streams as
/// in [`random_water_systems`]) — the mixed-species workload generator.
pub fn random_molecule_systems(
    coords: &[Vec3],
    masses: &[f64],
    n: usize,
    t_k: f64,
    seed: u64,
) -> Vec<System> {
    let dof = (3 * coords.len()).saturating_sub(3).max(1);
    (0..n)
        .map(|i| {
            let mut sys = System::new(coords.to_vec(), masses.to_vec());
            let mut rng = molecule_rng(seed, i);
            initialize_velocities(&mut sys, t_k, dof, &mut rng);
            sys
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WaterSystem;
    use crate::hw::timing::CLOCK_HZ;
    use crate::nn::{Activation, Sqnn};
    use crate::potentials::ff;

    fn toy_model() -> Mlp {
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.3;
            }
        }
        m
    }

    /// A toy ethanol-class model: 4·n_nb → … → 3 Cartesian forces.
    fn toy_generic_model(n_nb: usize) -> Mlp {
        let mut rng = Pcg::new(55);
        let mut m = Mlp::init_random(
            "toy-generic",
            &[4 * n_nb, 8, 8, 3],
            Activation::Phi,
            &mut rng,
        );
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.2;
            }
        }
        m
    }

    fn ethanol_group(n_mols: usize, shards: usize, seed: u64) -> SpeciesGroup {
        let mol = ff::ethanol();
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&mol.coords, &mol.masses(), n_mols, 100.0, seed);
        generic_group("ethanol", &model, &mol.coords, &systems, n_nb, 3, shards, 0.25).unwrap()
    }

    #[test]
    fn inline_and_threaded_farms_are_bit_identical() {
        // The acceptance invariant: N = 64 molecules, 1000 ticks, inline
        // vs threaded — and different shard counts — must produce
        // bit-identical trajectories (molecules are independent and the
        // partition only affects which thread owns them).
        let m = toy_model();
        let systems = random_water_systems(64, 150.0, 42);
        let mut inline = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 3, mode: ParallelMode::Inline, ..FarmConfig::default() },
        )
        .unwrap();
        let mut threaded = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 5, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        inline.run(1000).unwrap();
        threaded.run(1000).unwrap();
        let pa = inline.positions().unwrap();
        let pb = threaded.positions().unwrap();
        assert_eq!(pa.len(), 64);
        for (mol, (a, b)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(a, b, "molecule {mol} diverged between backends");
        }
        let la = inline.finish().unwrap();
        let lb = threaded.finish().unwrap();
        assert_eq!(la.molecule_steps, 64_000);
        assert_eq!(la.molecule_steps, lb.molecule_steps);
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.fpga_ops, lb.fpga_ops);
        assert_eq!(la.chip_inferences, 2 * 64_000);
    }

    #[test]
    fn single_molecule_farm_matches_water_system() {
        // The farm's datapath is the coordinator's datapath: one
        // molecule served by the batch kernel must track the
        // two-chip-in-parallel WaterSystem bit for bit.
        let m = toy_model();
        let systems = random_water_systems(1, 50.0, 7);
        let mut ws = WaterSystem::new(&m, 3, &systems[0], 0.25, ParallelMode::Inline).unwrap();
        let mut farm = WaterFarm::new(&m, &systems, &FarmConfig::default()).unwrap();
        for _ in 0..500 {
            ws.step().unwrap();
            farm.tick().unwrap();
        }
        assert_eq!(farm.positions().unwrap()[0], ws.positions());
    }

    #[test]
    fn ledger_accounts_lane_model() {
        let m = toy_model();
        let systems = random_water_systems(8, 100.0, 9);
        let run_with_lanes = |lanes: usize| -> FarmLedger {
            let mut farm = WaterFarm::new(
                &m,
                &systems,
                &FarmConfig { shards: 2, lanes, ..FarmConfig::default() },
            )
            .unwrap();
            farm.run(10).unwrap();
            farm.finish().unwrap()
        };
        let serial = run_with_lanes(1);
        let wide = run_with_lanes(8);
        assert_eq!(serial.molecule_steps, 80);
        assert_eq!(serial.chip_inferences, 160);
        // More lanes ⇒ strictly fewer modelled cycles (the MLP stage
        // compresses from 8 waves to 1 per shard tick).
        assert!(
            wide.modelled_cycles < serial.modelled_cycles,
            "lanes=8 cycles {} !< lanes=1 cycles {}",
            wide.modelled_cycles,
            serial.modelled_cycles
        );
        // Identical physics regardless of the lane model.
        assert_eq!(serial.chip_ops, wide.chip_ops);
        assert_eq!(serial.fpga_ops, wide.fpga_ops);
        // Cycle ledger is exactly ticks × Σ shard budgets (deterministic).
        assert_eq!(serial.modelled_cycles % serial.ticks, 0);
        assert!(serial.critical_path_cycles <= serial.modelled_cycles);
        assert!(serial.host_steps_per_second() > 0.0);
        let (fast, slow) = (
            wide.modelled_steps_per_second(CLOCK_HZ),
            serial.modelled_steps_per_second(CLOCK_HZ),
        );
        assert!(fast > slow, "lane model throughput {fast} !> {slow}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let m = toy_model();
        assert!(WaterFarm::new(&m, &[], &FarmConfig::default()).is_err());
        let systems = random_water_systems(2, 50.0, 1);
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 0, ..FarmConfig::default() }
        )
        .is_err());
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { lanes: 0, ..FarmConfig::default() }
        )
        .is_err());
        let mut bad = toy_model();
        bad.output_scale = 3.0; // not a power of two
        assert!(WaterFarm::new(&bad, &systems, &FarmConfig::default()).is_err());
        // multi-species validation
        assert!(MoleculeFarm::new(Vec::new(), 1, ParallelMode::Inline).is_err());
        let g = water_group(&m, &systems, 3, 1, 0.25).unwrap();
        assert!(MoleculeFarm::new(vec![g], 0, ParallelMode::Inline).is_err());
        // generic-group validation: wrong model shape for n_nb
        let mol = ff::ethanol();
        let sys = random_molecule_systems(&mol.coords, &mol.masses(), 1, 50.0, 3);
        let wrong = toy_generic_model(3); // 12 inputs, but n_nb = 4 wants 16
        assert!(
            generic_group("ethanol", &wrong, &mol.coords, &sys, 4, 3, 1, 0.25).is_err()
        );
    }

    #[test]
    fn shards_clamped_to_molecule_count() {
        let m = toy_model();
        let systems = random_water_systems(3, 50.0, 2);
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 16, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        assert_eq!(farm.config().shards, 3, "config() must report the effective shard count");
        farm.run(5).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.shard_walls.len(), 3);
        assert_eq!(l.molecule_steps, 15);
    }

    #[test]
    fn generic_single_molecule_matches_unbatched_reference() {
        // The generic serving path must be bit-identical to the
        // unbatched reference: the same MoleculeFpga stepped with
        // per-lane scalar Sqnn inference instead of the farm's batched
        // chip kernel.
        let mol = ff::ethanol();
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&mol.coords, &mol.masses(), 1, 120.0, 11);
        let group =
            generic_group("ethanol", &model, &mol.coords, &systems, n_nb, 3, 1, 0.25).unwrap();
        let mut farm = MoleculeFarm::new(vec![group], 1, ParallelMode::Inline).unwrap();
        farm.run(300).unwrap();

        // Reference path: scalar inference lane by lane.
        let net = Sqnn::from_mlp(&model, 3);
        let n = mol.coords.len();
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors(&mol.coords, i, n_nb))
            .collect();
        let cond =
            FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)
                .unwrap();
        let mut fpga = MoleculeFpga::new(&systems[0], nb, cond, 0.25).unwrap();
        fpga.force_shift = model.force_shift().unwrap();
        let in_dim = 4 * n_nb;
        let batch = n;
        let mut feats = vec![Q13::ZERO; in_dim * batch];
        let mut outs = vec![Q13::ZERO; 3 * batch];
        let mut lane = vec![Q13::ZERO; in_dim];
        for _ in 0..300 {
            fpga.extract_features_soa(&mut feats, batch, 0);
            for b in 0..batch {
                for (i, slot) in lane.iter_mut().enumerate() {
                    *slot = feats[i * batch + b];
                }
                let y = net.forward_q13(&lane);
                for (o, &v) in y.iter().enumerate() {
                    outs[o * batch + b] = v;
                }
            }
            fpga.integrate_soa(&outs, batch, 0);
        }
        let got = farm.positions().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], fpga.positions(), "batched farm diverged from scalar reference");
        let ledger = farm.finish().unwrap();
        assert_eq!(ledger.fpga_ops, fpga.ops);
        assert_eq!(ledger.chip_inferences, 300 * n as u64);
    }

    #[test]
    fn silicon_pbc_group_matches_unbatched_reference() {
        // The PBC satellite's acceptance: a bulk silicon cell served on
        // the generic batched path (minimum-image descriptors, wrapped
        // state) must be bit-identical to the same MoleculeFpga stepped
        // with scalar per-lane Sqnn inference.
        let (sw, coords) = crate::potentials::StillingerWeber::diamond_supercell(1);
        let box_l = sw.box_l;
        let n = coords.len();
        let masses = vec![28.0855; n];
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&coords, &masses, 3, 300.0, 17);
        let group = generic_group_pbc(
            "silicon", &model, &coords, &systems, n_nb, 3, 2, 0.5, box_l,
        )
        .unwrap();
        let mut farm = MoleculeFarm::new(vec![group], 1, ParallelMode::Inline).unwrap();
        farm.run(200).unwrap();

        // Reference path: scalar inference lane by lane on system 0.
        let net = Sqnn::from_mlp(&model, 3);
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors_pbc(&coords, i, n_nb, box_l))
            .collect();
        let cond =
            FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)
                .unwrap();
        let mut fpga = MoleculeFpga::new_pbc(&systems[0], nb, cond, 0.5, box_l).unwrap();
        fpga.force_shift = model.force_shift().unwrap();
        let in_dim = 4 * n_nb;
        let batch = n;
        let mut feats = vec![Q13::ZERO; in_dim * batch];
        let mut outs = vec![Q13::ZERO; 3 * batch];
        let mut lane = vec![Q13::ZERO; in_dim];
        for _ in 0..200 {
            fpga.extract_features_soa(&mut feats, batch, 0);
            for b in 0..batch {
                for (i, slot) in lane.iter_mut().enumerate() {
                    *slot = feats[i * batch + b];
                }
                let y = net.forward_q13(&lane);
                for (o, &v) in y.iter().enumerate() {
                    outs[o * batch + b] = v;
                }
            }
            fpga.integrate_soa(&outs, batch, 0);
        }
        let got = farm.positions().unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], fpga.positions(), "batched PBC farm diverged from scalar reference");
        // Every served cell stays wrapped inside the box.
        for cell in &got {
            for p in cell {
                for x in p.to_array() {
                    assert!((0.0..box_l).contains(&x), "position {x} escaped [0, {box_l})");
                }
            }
        }
        let ledger = farm.finish().unwrap();
        assert_eq!(ledger.molecule_steps, 3 * 200);
        assert_eq!(ledger.chip_inferences, 3 * 200 * n as u64);
    }

    #[test]
    fn mixed_species_farm_is_bit_identical_across_backends() {
        // The multi-model acceptance invariant: a farm serving two
        // distinct per-shard models (water 3→…→2 and an ethanol-class
        // 16→…→3) must be bit-identical between inline and threaded
        // backends, across different shard counts.
        let wm = toy_model();
        let water_systems = random_water_systems(10, 120.0, 21);
        let build = |water_shards: usize, eth_shards: usize, mode: ParallelMode| {
            let groups = vec![
                water_group(&wm, &water_systems, 3, water_shards, 0.25).unwrap(),
                ethanol_group(6, eth_shards, 33),
            ];
            MoleculeFarm::new(groups, 1, mode).unwrap()
        };
        let mut inline = build(3, 2, ParallelMode::Inline);
        let mut threaded = build(4, 3, ParallelMode::Threaded);
        inline.run(200).unwrap();
        threaded.run(200).unwrap();
        let pa = inline.positions().unwrap();
        let pb = threaded.positions().unwrap();
        assert_eq!(pa.len(), 16);
        assert_eq!(pa[0].len(), 3, "water molecules first, [O,H1,H2]");
        assert_eq!(pa[10].len(), 9, "ethanol molecules follow, 9 atoms");
        for (mol, (a, b)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(a, b, "molecule {mol} diverged between backends");
        }
        let la = inline.finish().unwrap();
        let lb = threaded.finish().unwrap();
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.fpga_ops, lb.fpga_ops);
        assert_eq!(la.molecule_steps, lb.molecule_steps);
    }

    #[test]
    fn per_species_ledger_accounts_the_mix() {
        let wm = toy_model();
        let water_systems = random_water_systems(4, 100.0, 5);
        let groups = vec![
            water_group(&wm, &water_systems, 3, 2, 0.25).unwrap(),
            ethanol_group(2, 1, 9),
        ];
        let mut farm = MoleculeFarm::new(groups, 1, ParallelMode::Inline).unwrap();
        assert_eq!(farm.n_molecules(), 6);
        assert_eq!(farm.n_species(), 2);
        assert_eq!(farm.n_shards(), 3);
        farm.run(10).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.molecule_steps, 60);
        assert_eq!(l.species.len(), 2);
        let (w, e) = (&l.species[0], &l.species[1]);
        assert_eq!(w.name, "water");
        assert_eq!(e.name, "ethanol");
        assert_eq!(w.n_molecules, 4);
        assert_eq!(e.n_molecules, 2);
        assert_eq!(w.n_atoms, 12);
        assert_eq!(e.n_atoms, 18);
        assert_eq!(w.molecule_steps, 40);
        assert_eq!(e.molecule_steps, 20);
        // Lane routing by model: water = 2 lanes/molecule, ethanol =
        // 9 lanes (one per atom).
        assert_eq!(w.chip_inferences, 10 * 4 * 2);
        assert_eq!(e.chip_inferences, 10 * 2 * 9);
        assert_eq!(w.chip_inferences + e.chip_inferences, l.chip_inferences);
        assert_eq!(w.shard_walls.len(), 2);
        assert_eq!(e.shard_walls.len(), 1);
        assert!(w.steps_per_shard_second() > 0.0);
        assert!(e.steps_per_shard_second() > 0.0);
        // Mixed-atom S metric uses the real atom mix (30 atoms/tick).
        let s = l.s_per_step_atom(CLOCK_HZ);
        assert!(s > 0.0 && s.is_finite());
        assert!((s - l.hw_seconds_parallel(CLOCK_HZ) / 300.0).abs() < 1e-18);
    }
}
