//! The molecule farm — the batched, sharded, **multi-species** serving
//! path of the coordinator.
//!
//! Where [`super::WaterSystem`] reproduces the paper's single-molecule
//! latency pipeline, [`MoleculeFarm`] turns the same devices into a
//! throughput engine for the whole Table-I mix: N independent molecules
//! advance one MD step per *tick*, sharded over worker threads. The
//! farm is parameterized over the [`ServedMolecule`] trait (extract →
//! batched infer → integrate), and molecules are grouped into
//! [`SpeciesGroup`]s: every shard programs its **own** `nn::Sqnn` from
//! its species' model, so per-species models coexist in one farm and
//! request batches route to the shard holding their model — the
//! serving-tier shape of heterogeneous ML-force-field traffic.
//!
//! Each shard owns its molecules' FPGA state, one batched MLP chip
//! programmed with the species model, and all the scratch the hot loop
//! needs, and drives the paper's §IV-C workflow in batch form:
//!
//! 1. extract — every molecule scatters its conditioned Q13 features
//!    into the shard's SoA block (water: `fpga::WaterFpga` hydrogen
//!    triples; generic molecules: the `fpga::MoleculeFpga` 4·n_nb
//!    descriptor path);
//! 2. `MlpChip::infer_batch_into` — one weight-stationary batched
//!    inference over all shard lanes via the SWAR shift-program kernel
//!    (`nn::sqnn`: precompiled per-layer instruction streams executed
//!    over 8-lane accumulator tiles, bit-identical to the scalar
//!    datapath), with the `ChipConfig::lanes` intra-ASIC parallelism
//!    model (§VI A₂) accounting ⌈B/lanes⌉ pipeline waves;
//! 3. integrate — force reconstruction (+ Newton's third law where the
//!    species needs it) and integration per molecule.
//!
//! Shards are fully independent, so the inline and threaded backends
//! are bit-identical by construction — the same guarantee the
//! single-molecule coordinator makes, extended to the farm. The
//! aggregated [`FarmLedger`] reports modelled hardware cycles (lane
//! model included), op counts, and **host throughput in
//! molecule-steps/second**, farm-wide and per species.
//!
//! [`WaterFarm`] is the water instantiation of the generic farm and
//! keeps the pre-refactor behavior bit for bit.
//!
//! **Supervision.** The farm is fault-tolerant at two granularities.
//! Per *shard*: a panicking shard job (inline or threaded — the threaded
//! transport's `catch_unwind` and an inline `catch_unwind` behave
//! identically) marks that shard **dead**; its species degrades while
//! every other shard keeps serving. Per *molecule*: a divergence monitor
//! reads the datapath's own health signals — 26-bit state-saturation
//! events from the integrator MAC (`qint::mac_step_counted`), Q13 rail
//! hits on the chip's output lanes, and a position-jump watchdog
//! (minimum-imaged under PBC) — and **quarantines** a diverging molecule:
//! its lanes are removed from the shard batch and its state frozen,
//! while the survivors' trajectories stay bit-identical (the SWAR batch
//! kernel is bit-exact per lane at any batch size). Every decision is a
//! deterministic function of per-molecule state, so quarantine verdicts
//! are identical across backends and shard layouts; faults are recorded
//! in [`FarmLedger`]. Deterministic fault *injection* (compiled in under
//! `cfg(any(test, feature = "faults"))`) drives every recovery path from
//! tests via [`crate::testkit::faults::FaultPlan`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::asic::{ChipConfig, MlpChip};
use crate::features;
use crate::fixedpoint::{q13, Q13};
use crate::fpga::{FeatureConditioner, HFeatures, MoleculeFpga, WaterFpga, ZERO_FRAME};
use crate::hw::power::OpCounts;
use crate::hw::timing::StepCycles;
use crate::md::{initialize_velocities, System};
use crate::nn::Mlp;
use crate::potentials::WaterPes;
#[cfg(any(test, feature = "faults"))]
use crate::testkit::faults::FaultPlan;
use crate::util::rng::Pcg;
use crate::util::Vec3;

use super::pool::{panic_message, PoolError, WorkerPool};
use super::ParallelMode;

/// Divergence-monitor thresholds of the farm's per-molecule health
/// monitor. The defaults are conservative: they never fire on a healthy
/// trajectory (every signal below is *identically zero* there), only on
/// hard numeric divergence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthPolicy {
    /// Master switch; `false` turns all monitoring off (the rail/
    /// saturation counters still aggregate into the ledger).
    pub enabled: bool,
    /// Quarantine once a molecule's cumulative 26-bit state-clamp count
    /// reaches this (0 disables). A healthy trajectory never clamps —
    /// the state range is ±32 Å — so the default of 1 is exact.
    pub sat_event_limit: u64,
    /// Quarantine when any atom moves farther than this (Å, minimum-
    /// imaged under PBC) within one watchdog window (0.0 disables).
    pub max_jump_ang: f64,
    /// Position-watchdog window in ticks (the jump check runs every
    /// `jump_stride` ticks, off the hot path).
    pub jump_stride: u32,
    /// Quarantine after this many *consecutive* ticks in which **every**
    /// chip output lane of the molecule sat on a Q13 rail (0 disables).
    pub rail_tick_limit: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            enabled: true,
            sat_event_limit: 1,
            max_jump_ang: 1.0,
            jump_stride: 4,
            rail_tick_limit: 32,
        }
    }
}

/// Supervision wiring of a farm: the health policy plus (in test/fault
/// builds) the deterministic fault plan.
#[derive(Debug, Clone, Copy, Default)]
pub struct FarmSupervision {
    pub health: HealthPolicy,
    #[cfg(any(test, feature = "faults"))]
    pub faults: Option<FaultPlan>,
}

/// Farm construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct FarmConfig {
    /// Worker shards (clamped to the molecule count).
    pub shards: usize,
    /// Parallel MLP lanes per shard chip (see [`ChipConfig::lanes`]).
    pub lanes: usize,
    /// Shift terms per weight for quantization.
    pub k: usize,
    /// Integrator timestep (fs).
    pub dt_fs: f64,
    /// Shard execution backend.
    pub mode: ParallelMode,
    /// Divergence-monitor thresholds.
    pub health: HealthPolicy,
    /// Deterministic fault plan (test/fault builds only).
    #[cfg(any(test, feature = "faults"))]
    pub faults: Option<FaultPlan>,
}

impl Default for FarmConfig {
    fn default() -> Self {
        FarmConfig {
            shards: 1,
            lanes: 1,
            k: 3,
            dt_fs: 0.25,
            mode: ParallelMode::Inline,
            health: HealthPolicy::default(),
            #[cfg(any(test, feature = "faults"))]
            faults: None,
        }
    }
}

/// One served molecule: how a species plugs its FPGA datapath into the
/// farm's extract → batched-infer → integrate tick. Implementations own
/// all per-molecule state (including whatever the integrate stage needs
/// from extraction, e.g. the water bond frames), so a tick allocates
/// nothing.
pub trait ServedMolecule: Send {
    /// Chip lanes (inferences) this molecule occupies per tick.
    fn lanes(&self) -> usize;
    /// Atom count (serving-metric denominator).
    fn n_atoms(&self) -> usize;
    /// Modelled FPGA cycles (feature + integration stages) of one step
    /// of this molecule; the shared per-tick transfer/control windows
    /// and the chip lane model are accounted by the shard.
    fn fpga_cycles_per_tick(&self) -> u64;
    /// Scatter the conditioned Q13 features into the shard's SoA block:
    /// feature `i` of the molecule's local lane `l` belongs at
    /// `feats[i * batch + lane0 + l]`.
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize);
    /// Consume the chip's SoA outputs for this molecule's lanes (output
    /// `o` of local lane `l` at `outs[o * batch + lane0 + l]`) and
    /// advance one MD step.
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize);
    /// Decoded positions (analysis tap).
    fn positions(&self) -> Vec<Vec3>;
    /// FPGA op counters (energy model).
    fn ops(&self) -> OpCounts;
    /// Steps integrated so far.
    fn steps(&self) -> u64;
    /// Cumulative 26-bit state-clamp events of the integrator datapath
    /// (the divergence monitor's primary signal; 0 = healthy or not
    /// instrumented).
    fn sat_events(&self) -> u64 {
        0
    }
    /// Periodic box side, if the species is bulk — the position-jump
    /// watchdog minimum-images its displacements with it.
    fn box_l(&self) -> Option<f64> {
        None
    }
    /// Fault injection: force the device into rail saturation (no-op by
    /// default, so external `ServedMolecule` impls are unaffected).
    #[cfg(any(test, feature = "faults"))]
    fn inject_saturation(&mut self) {}
}

/// The water species: one [`WaterFpga`] per molecule, two hydrogen
/// lanes, local-frame force reconstruction + Newton's third law — the
/// paper's §IV-C datapath, bit-identical to the pre-refactor farm.
struct WaterServed {
    fpga: WaterFpga,
    /// Bond frames of the last extraction (consumed by integrate).
    frames: [HFeatures; 2],
}

impl ServedMolecule for WaterServed {
    fn lanes(&self) -> usize {
        2
    }
    fn n_atoms(&self) -> usize {
        3
    }
    fn fpga_cycles_per_tick(&self) -> u64 {
        let b = StepCycles::water();
        b.feature + b.integrate
    }
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize) {
        let fr = self.fpga.extract_features();
        for (hi, f) in fr.iter().enumerate() {
            for (i, &d) in f.d.iter().enumerate() {
                feats[i * batch + lane0 + hi] = d;
            }
        }
        self.frames = fr;
    }
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize) {
        let c = [
            [outs[lane0], outs[batch + lane0]],
            [outs[lane0 + 1], outs[batch + lane0 + 1]],
        ];
        self.fpga.integrate(&self.frames, c);
    }
    fn positions(&self) -> Vec<Vec3> {
        self.fpga.positions()
    }
    fn ops(&self) -> OpCounts {
        self.fpga.ops
    }
    fn steps(&self) -> u64 {
        self.fpga.steps
    }
    fn sat_events(&self) -> u64 {
        self.fpga.sat_events
    }
    #[cfg(any(test, feature = "faults"))]
    fn inject_saturation(&mut self) {
        self.fpga.inject_rail_saturation();
    }
}

/// A generic Table-I molecule: one [`MoleculeFpga`] per molecule, one
/// chip lane per atom over the 4·n_nb `local_descriptor` path, the chip
/// predicting Cartesian forces directly.
struct GenericServed {
    fpga: MoleculeFpga,
}

impl ServedMolecule for GenericServed {
    fn lanes(&self) -> usize {
        self.fpga.n_atoms()
    }
    fn n_atoms(&self) -> usize {
        self.fpga.n_atoms()
    }
    fn fpga_cycles_per_tick(&self) -> u64 {
        self.fpga.cycles_per_step()
    }
    fn extract(&mut self, feats: &mut [Q13], batch: usize, lane0: usize) {
        self.fpga.extract_features_soa(feats, batch, lane0);
    }
    fn integrate(&mut self, outs: &[Q13], batch: usize, lane0: usize) {
        self.fpga.integrate_soa(outs, batch, lane0);
    }
    fn positions(&self) -> Vec<Vec3> {
        self.fpga.positions()
    }
    fn ops(&self) -> OpCounts {
        self.fpga.ops
    }
    fn steps(&self) -> u64 {
        self.fpga.steps
    }
    fn sat_events(&self) -> u64 {
        self.fpga.sat_events
    }
    fn box_l(&self) -> Option<f64> {
        self.fpga.box_l()
    }
    #[cfg(any(test, feature = "faults"))]
    fn inject_saturation(&mut self) {
        self.fpga.inject_rail_saturation();
    }
}

/// One species' slice of the farm: its model (each shard programs its
/// own `Sqnn` from it), quantization K, requested shard count, and the
/// served molecules.
pub struct SpeciesGroup {
    name: String,
    model: Mlp,
    k: usize,
    shards: usize,
    mols: Vec<Box<dyn ServedMolecule>>,
}

impl SpeciesGroup {
    /// Assemble a species group from pre-built served molecules. The
    /// `model`/`k` pair is what every shard of this species programs
    /// into its chip; `mols` must already be programmed consistently
    /// with it (use [`water_group`] / [`generic_group`] unless you are
    /// plugging in a custom [`ServedMolecule`]).
    ///
    /// `mols` may be empty: the group then builds `shards` empty shards
    /// (each with its chip programmed and zero batch lanes) and serves
    /// molecules admitted later via [`MoleculeFarm::admit`] — the
    /// gateway's construction shape.
    pub fn new(
        name: &str,
        model: Mlp,
        k: usize,
        shards: usize,
        mols: Vec<Box<dyn ServedMolecule>>,
    ) -> Result<SpeciesGroup> {
        anyhow::ensure!(shards >= 1, "species {name:?} needs at least one shard");
        Ok(SpeciesGroup { name: name.to_string(), model, k, shards, mols })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
    pub fn n_molecules(&self) -> usize {
        self.mols.len()
    }
    /// Disassemble the group into its served molecules (e.g. to feed
    /// them one at a time through [`MoleculeFarm::admit`]).
    pub fn into_molecules(self) -> Vec<Box<dyn ServedMolecule>> {
        self.mols
    }
}

/// Build the water species group (the Table-I water instantiation).
pub fn water_group(
    model: &Mlp,
    systems: &[System],
    k: usize,
    shards: usize,
    dt_fs: f64,
) -> Result<SpeciesGroup> {
    let force_shift = super::validate_water_model(model)?;
    let mols = systems
        .iter()
        .map(|sys| {
            let mut f = WaterFpga::new(sys, dt_fs);
            super::program_water_fpga(&mut f, model, force_shift)?;
            Ok(Box::new(WaterServed { fpga: f, frames: [ZERO_FRAME; 2] })
                as Box<dyn ServedMolecule>)
        })
        .collect::<Result<Vec<_>>>()?;
    SpeciesGroup::new("water", model.clone(), k, shards, mols)
}

/// Build a generic-molecule species group over the 4·n_nb descriptor
/// path: neighbor ordering fixed by `ref_coords` (reference topology),
/// feature conditioning and force rescale programmed from the model —
/// the host-CPU initialization path generalized beyond water.
#[allow(clippy::too_many_arguments)] // flat one-call init API, mirrors water_group + topology
pub fn generic_group(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
) -> Result<SpeciesGroup> {
    generic_group_impl(name, model, ref_coords, systems, n_nb, k, shards, dt_fs, None)
}

/// Build a bulk (periodic) species group: same descriptor path as
/// [`generic_group`] but the neighbor ordering is minimum-imaged over the
/// cubic `box_l` cell and every device runs with wrapped positions
/// ([`MoleculeFpga::new_pbc`]) — silicon-class crystals on the same
/// batched serving path as molecules.
#[allow(clippy::too_many_arguments)]
pub fn generic_group_pbc(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
    box_l: f64,
) -> Result<SpeciesGroup> {
    generic_group_impl(name, model, ref_coords, systems, n_nb, k, shards, dt_fs, Some(box_l))
}

#[allow(clippy::too_many_arguments)]
fn generic_group_impl(
    name: &str,
    model: &Mlp,
    ref_coords: &[Vec3],
    systems: &[System],
    n_nb: usize,
    k: usize,
    shards: usize,
    dt_fs: f64,
    box_l: Option<f64>,
) -> Result<SpeciesGroup> {
    let n = ref_coords.len();
    anyhow::ensure!(
        n_nb >= 1 && n_nb < n,
        "species {name:?}: n_nb = {n_nb} needs 1 ≤ n_nb < {n} atoms"
    );
    anyhow::ensure!(
        model.in_dim() == 4 * n_nb && model.out_dim() == 3,
        "species {name:?}: model must be {}→…→3 for n_nb = {n_nb} (got {}→…→{})",
        4 * n_nb,
        model.in_dim(),
        model.out_dim()
    );
    let force_shift = model.force_shift()?;
    let nb: Vec<Vec<usize>> = (0..n)
        .map(|i| match box_l {
            Some(l) => features::reference_neighbors_pbc(ref_coords, i, n_nb, l),
            None => features::reference_neighbors(ref_coords, i, n_nb),
        })
        .collect();
    let cond = FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)?;
    let mols = systems
        .iter()
        .map(|sys| {
            anyhow::ensure!(
                sys.len() == n,
                "species {name:?}: system has {} atoms, reference {n}",
                sys.len()
            );
            let mut f = match box_l {
                Some(l) => MoleculeFpga::new_pbc(sys, nb.clone(), cond.clone(), dt_fs, l)?,
                None => MoleculeFpga::new(sys, nb.clone(), cond.clone(), dt_fs)?,
            };
            f.force_shift = force_shift;
            Ok(Box::new(GenericServed { fpga: f }) as Box<dyn ServedMolecule>)
        })
        .collect::<Result<Vec<_>>>()?;
    SpeciesGroup::new(name, model.clone(), k, shards, mols)
}

/// Why the divergence monitor quarantined a molecule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuarantineReason {
    /// The 26-bit integrator state clamped ([`HealthPolicy::sat_event_limit`]).
    SaturationEvents,
    /// An atom jumped farther than [`HealthPolicy::max_jump_ang`] within
    /// one watchdog window.
    PositionJump,
    /// Every chip output lane sat on a Q13 rail for
    /// [`HealthPolicy::rail_tick_limit`] consecutive ticks.
    RailPinned,
}

impl core::fmt::Display for QuarantineReason {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            QuarantineReason::SaturationEvents => write!(f, "26-bit state saturation"),
            QuarantineReason::PositionJump => write!(f, "position jump"),
            QuarantineReason::RailPinned => write!(f, "Q13 output rails pinned"),
        }
    }
}

/// One quarantine decision, recorded in the ledger. `molecule` is the
/// farm-wide construction-order index (the same index
/// [`MoleculeFarm::positions`] uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuarantineRecord {
    pub molecule: usize,
    pub species: usize,
    /// Farm tick at which the molecule was pulled from its batch.
    pub tick: u64,
    pub reason: QuarantineReason,
}

/// A shard the farm lost (recovered panic or lost reply): the shard's
/// remaining molecules freeze at their last completed tick while every
/// other shard keeps serving.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardLoss {
    pub shard: usize,
    pub species: usize,
    /// Farm tick at which the shard died.
    pub tick: u64,
    /// Panic message / transport fault description.
    pub detail: String,
}

/// Where [`MoleculeFarm::admit`] placed a molecule: its farm-wide id
/// (the same index space as [`QuarantineRecord::molecule`] and
/// `FaultPlan` molecule schedules) and the shard now holding it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitTicket {
    pub mol_id: usize,
    pub shard: usize,
}

/// What [`MoleculeFarm::retire`] hands back: the molecule's final state
/// and its per-molecule accounting. The shard keeps the retired
/// molecule's steps/saturation/op/rail tallies in retained accumulators,
/// so [`MoleculeFarm::finish`] books stay complete across churn.
#[derive(Debug, Clone, PartialEq)]
pub struct RetiredMolecule {
    pub mol_id: usize,
    /// Index into the farm's species table.
    pub species: usize,
    /// Steps the molecule integrated while resident.
    pub steps: u64,
    /// Its cumulative 26-bit integrator saturation events.
    pub sat_events: u64,
    /// Final decoded positions (frozen at quarantine time if the
    /// divergence monitor pulled it).
    pub positions: Vec<Vec3>,
    /// The quarantine verdict, if the monitor pulled this molecule
    /// before it was retired.
    pub quarantined: Option<QuarantineRecord>,
}

/// Per-epoch report a shard hands back to the farm supervisor: one
/// reply per [`FarmShard::run_ticks`] job instead of one per tick, with
/// everything the supervisor's books need carried as tick-exact tallies
/// and event records (the `n = 1` case is the classic per-tick report).
#[derive(Debug, Clone)]
struct ShardEpoch {
    /// Ticks actually completed this epoch (= the requested epoch
    /// length unless the shard died mid-epoch).
    ticks_run: u64,
    /// Molecule-steps integrated this epoch.
    steps: u64,
    /// New 26-bit integrator saturation events observed this epoch.
    sat_events: u64,
    /// New Q13 rail hits observed on chip output lanes this epoch.
    rail_hits: u64,
    /// Molecules quarantined on this shard so far (cumulative — the
    /// supervisor's health key, as the per-tick report carried).
    quarantined: u32,
    /// Quarantine decisions made *during* this epoch, each with the
    /// exact tick it happened on.
    quarantines: Vec<QuarantineRecord>,
    /// The shard died mid-epoch: (absolute tick of the panicking tick,
    /// panic message). Ticks before it completed normally and their
    /// effects are in the tallies above.
    loss: Option<(u64, String)>,
}

/// Per-molecule divergence-monitor state.
struct MoleculeMonitor {
    /// Chip output lanes of this molecule seen on a Q13 rail, cumulative.
    rail_hits: u64,
    /// Consecutive ticks with *all* lanes railed.
    rail_consec: u32,
    /// Positions at the last watchdog check.
    prev_pos: Vec<Vec3>,
}

/// Largest per-atom displacement between two snapshots, minimum-imaged
/// when a periodic box is given (a wrap across a face is not a jump).
fn max_jump(prev: &[Vec3], cur: &[Vec3], box_l: Option<f64>) -> f64 {
    let mi = |d: f64| match box_l {
        Some(l) => d - l * (d / l).round(),
        None => d,
    };
    prev.iter()
        .zip(cur)
        .map(|(p, c)| {
            let d = *c - *p;
            let (dx, dy, dz) = (mi(d.x), mi(d.y), mi(d.z));
            (dx * dx + dy * dy + dz * dz).sqrt()
        })
        .fold(0.0, f64::max)
}

/// One shard: a slice of one species' molecules, its batched chip
/// (programmed with that species' own `Sqnn`), and the scratch buffers
/// of the hot loop (owned here so a tick allocates nothing). The shard
/// also runs the per-molecule divergence monitor: every health decision
/// is a deterministic function of molecule-local state, so verdicts are
/// identical across backends and shard layouts.
struct FarmShard {
    id: usize,
    /// Index into the farm's species table.
    species: usize,
    mols: Vec<Box<dyn ServedMolecule>>,
    /// Farm-wide construction-order index of each molecule.
    mol_ids: Vec<usize>,
    /// Molecules still in the batch (quarantine clears the flag).
    active: Vec<bool>,
    /// Divergence-monitor state per molecule.
    mon: Vec<MoleculeMonitor>,
    /// First lane of each *active* molecule in the shard's SoA batch.
    lane0: Vec<usize>,
    /// Total chip lanes (Σ active molecule lanes).
    batch: usize,
    chip: MlpChip,
    in_dim: usize,
    out_dim: usize,
    feats: Vec<Q13>,
    outs: Vec<Q13>,
    /// Modelled hardware cycles of one tick at the *current* batch.
    tick_cycles: u64,
    /// Accumulated modelled cycles (the per-tick budget shrinks when a
    /// molecule is quarantined, so this is no longer ticks × budget).
    cycles: u64,
    ticks: u64,
    wall: Duration,
    health: HealthPolicy,
    quarantined: Vec<QuarantineRecord>,
    /// Accounting retained from retired molecules, so the final books
    /// stay complete across membership churn: steps, saturation events,
    /// rail hits, and FPGA op counts of everything this shard served
    /// and has since handed back via [`FarmShard::retire`].
    retired_steps: u64,
    retired_sat: u64,
    retired_rail_hits: u64,
    retired_ops: OpCounts,
    #[cfg(any(test, feature = "faults"))]
    faults: Option<FaultPlan>,
}

impl FarmShard {
    fn new(
        id: usize,
        species: usize,
        mols: Vec<Box<dyn ServedMolecule>>,
        mol_ids: Vec<usize>,
        model: &Mlp,
        k: usize,
        lanes: usize,
        sup: &FarmSupervision,
    ) -> Result<FarmShard> {
        debug_assert_eq!(mols.len(), mol_ids.len());
        let mut chip = MlpChip::new(id, ChipConfig { lanes, ..ChipConfig::default() });
        chip.program(model, k);
        let mut lane0 = Vec::with_capacity(mols.len());
        let mut batch = 0usize;
        for m in &mols {
            lane0.push(batch);
            batch += m.lanes();
        }
        let active = vec![true; mols.len()];
        let tick_cycles = Self::tick_cycle_budget(&mols, &active, &chip, batch);
        let mon = mols
            .iter()
            .map(|m| MoleculeMonitor {
                rail_hits: 0,
                rail_consec: 0,
                prev_pos: m.positions(),
            })
            .collect();
        Ok(FarmShard {
            id,
            species,
            mol_ids,
            active,
            mon,
            lane0,
            batch,
            in_dim: model.in_dim(),
            out_dim: model.out_dim(),
            feats: vec![Q13::ZERO; model.in_dim() * batch],
            outs: vec![Q13::ZERO; model.out_dim() * batch],
            mols,
            chip,
            tick_cycles,
            cycles: 0,
            ticks: 0,
            wall: Duration::ZERO,
            health: sup.health,
            quarantined: Vec::new(),
            retired_steps: 0,
            retired_sat: 0,
            retired_rail_hits: 0,
            retired_ops: OpCounts::default(),
            #[cfg(any(test, feature = "faults"))]
            faults: sup.faults,
        })
    }

    /// Admit one molecule into the shard's batch (membership churn runs
    /// between epochs, never inside one). The repack is the quarantine
    /// seam in reverse: the SWAR batch kernel is bit-exact per lane at
    /// any batch size, so adding lanes cannot move a resident molecule's
    /// trajectory by a single bit.
    fn admit(&mut self, mol: Box<dyn ServedMolecule>, mol_id: usize) {
        self.mon.push(MoleculeMonitor {
            rail_hits: 0,
            rail_consec: 0,
            prev_pos: mol.positions(),
        });
        self.mol_ids.push(mol_id);
        self.active.push(true);
        self.lane0.push(0);
        self.mols.push(mol);
        self.rebuild_lanes();
    }

    /// Remove a molecule from the shard, returning its final state. Its
    /// accounting moves into the retained accumulators so the shard's
    /// books (and [`MoleculeFarm::finish`]) stay complete; its
    /// quarantine records, if any, stay in the shard's ledger history.
    fn retire(&mut self, mol_id: usize) -> Result<RetiredMolecule> {
        let Some(m) = self.mol_ids.iter().position(|&id| id == mol_id) else {
            anyhow::bail!("molecule {mol_id} is not resident on shard {}", self.id)
        };
        let mol = self.mols.remove(m);
        self.mol_ids.remove(m);
        self.active.remove(m);
        self.lane0.remove(m);
        let mon = self.mon.remove(m);
        self.retired_steps += mol.steps();
        self.retired_sat += mol.sat_events();
        self.retired_rail_hits += mon.rail_hits;
        self.retired_ops.merge(&mol.ops());
        self.rebuild_lanes();
        Ok(RetiredMolecule {
            mol_id,
            species: self.species,
            steps: mol.steps(),
            sat_events: mol.sat_events(),
            positions: mol.positions(),
            quarantined: self.quarantined.iter().find(|q| q.molecule == mol_id).copied(),
        })
    }

    /// Modelled cycles of one shard tick: the FPGA streams its active
    /// molecules through feature extraction and integration
    /// sequentially, shares one transfer/control window per tick, and
    /// the chip's lane model covers the batched MLP stage
    /// (⌈batch/lanes⌉ pipeline waves).
    fn tick_cycle_budget(
        mols: &[Box<dyn ServedMolecule>],
        active: &[bool],
        chip: &MlpChip,
        batch: usize,
    ) -> u64 {
        let b = StepCycles::water();
        mols.iter()
            .zip(active)
            .filter(|(_, &a)| a)
            .map(|(m, _)| m.fpga_cycles_per_tick())
            .sum::<u64>()
            + b.to_chip
            + b.from_chip
            + b.control
            + chip.batch_latency_cycles(batch)
    }

    /// One MD step for every active molecule in the shard, followed by
    /// the divergence monitor. The wall-clock sample pair lives in
    /// [`FarmShard::run_ticks`], which samples once per epoch.
    fn tick_once(&mut self) -> Result<()> {
        let tick_idx = self.ticks;
        let budget = self.tick_cycles;
        #[cfg(any(test, feature = "faults"))]
        if let Some(plan) = self.faults {
            if plan.panics_at(self.id, tick_idx) {
                panic!("injected fault: shard {} panics at tick {tick_idx}", self.id);
            }
            for m in 0..self.mols.len() {
                if self.active[m] && plan.saturates_at(self.mol_ids[m], tick_idx) {
                    self.mols[m].inject_saturation();
                }
            }
        }
        if self.batch > 0 {
            for m in 0..self.mols.len() {
                if self.active[m] {
                    self.mols[m].extract(&mut self.feats, self.batch, self.lane0[m]);
                }
            }
            self.chip.infer_batch_into(&self.feats, self.batch, &mut self.outs)?;
            if self.health.enabled {
                self.scan_rails();
            }
            for m in 0..self.mols.len() {
                if self.active[m] {
                    self.mols[m].integrate(&self.outs, self.batch, self.lane0[m]);
                }
            }
        }
        self.ticks += 1;
        if self.health.enabled {
            self.check_health(tick_idx);
        }
        self.cycles += budget;
        Ok(())
    }

    /// Run `n` ticks as one epoch: one wall-clock sample pair, one
    /// reply to the supervisor. Fault semantics stay tick-exact — each
    /// tick runs under its own `catch_unwind`, so a panic at absolute
    /// tick `t` freezes the shard with ticks `..t` completed, exactly
    /// as under per-tick driving; the shard advances its own tick
    /// counter, so health checks and `FaultPlan` injection points fire
    /// at the same absolute tick indices regardless of epoch length.
    ///
    /// `transport_faults` (threaded backend) makes a scheduled reply
    /// drop end the epoch right after its tick executes, so the shard's
    /// frozen state matches what the per-tick driver would have left
    /// when the transport lost that tick's reply.
    fn run_ticks(&mut self, n: u64, transport_faults: bool) -> Result<ShardEpoch> {
        #[cfg(not(any(test, feature = "faults")))]
        let _ = transport_faults;
        let t0 = Instant::now();
        let first_tick = self.ticks;
        let steps0: u64 = self.mols.iter().map(|m| m.steps()).sum();
        let sat0: u64 = self.mols.iter().map(|m| m.sat_events()).sum();
        let rail0: u64 = self.mon.iter().map(|mo| mo.rail_hits).sum();
        let quar0 = self.quarantined.len();
        let mut loss = None;
        let mut err = None;
        for _ in 0..n {
            let tick_idx = self.ticks;
            match catch_unwind(AssertUnwindSafe(|| self.tick_once())) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    err = Some(e);
                    break;
                }
                Err(payload) => {
                    loss = Some((tick_idx, panic_message(payload.as_ref())));
                    break;
                }
            }
            #[cfg(any(test, feature = "faults"))]
            if transport_faults {
                if let Some(plan) = self.faults {
                    if plan.drops_reply_at(self.id, tick_idx) {
                        break;
                    }
                }
            }
        }
        self.wall += t0.elapsed();
        if let Some(e) = err {
            return Err(e);
        }
        let steps1: u64 = self.mols.iter().map(|m| m.steps()).sum();
        let sat1: u64 = self.mols.iter().map(|m| m.sat_events()).sum();
        let rail1: u64 = self.mon.iter().map(|mo| mo.rail_hits).sum();
        Ok(ShardEpoch {
            ticks_run: self.ticks - first_tick,
            steps: steps1 - steps0,
            sat_events: sat1 - sat0,
            rail_hits: rail1 - rail0,
            quarantined: self.quarantined.len() as u32,
            quarantines: self.quarantined[quar0..].to_vec(),
            loss,
        })
    }

    /// Count each active molecule's output lanes sitting on a Q13 rail
    /// this tick (runs on the chip's SoA output block, before
    /// integration consumes it).
    fn scan_rails(&mut self) {
        for m in 0..self.mols.len() {
            if !self.active[m] {
                continue;
            }
            let lanes = self.mols[m].lanes();
            let mut railed = 0u32;
            for l in 0..lanes {
                let lane = self.lane0[m] + l;
                let hit = (0..self.out_dim).any(|o| {
                    let q = self.outs[o * self.batch + lane].0;
                    q == q13::MAX_RAW || q == q13::MIN_RAW
                });
                railed += u32::from(hit);
            }
            self.mon[m].rail_hits += railed as u64;
            if railed as usize == lanes {
                self.mon[m].rail_consec += 1;
            } else {
                self.mon[m].rail_consec = 0;
            }
        }
    }

    /// The divergence monitor: quarantine any active molecule whose
    /// health signals crossed the policy thresholds during `tick_idx`.
    fn check_health(&mut self, tick_idx: u64) {
        let p = self.health;
        let watchdog_due = p.jump_stride > 0 && (tick_idx + 1) % p.jump_stride as u64 == 0;
        let mut changed = false;
        for m in 0..self.mols.len() {
            if !self.active[m] {
                continue;
            }
            let mut reason = None;
            if p.sat_event_limit > 0 && self.mols[m].sat_events() >= p.sat_event_limit {
                reason = Some(QuarantineReason::SaturationEvents);
            }
            if reason.is_none() && p.rail_tick_limit > 0 && self.mon[m].rail_consec >= p.rail_tick_limit
            {
                reason = Some(QuarantineReason::RailPinned);
            }
            if reason.is_none() && watchdog_due && p.max_jump_ang > 0.0 {
                let cur = self.mols[m].positions();
                let jump = max_jump(&self.mon[m].prev_pos, &cur, self.mols[m].box_l());
                self.mon[m].prev_pos = cur;
                if jump > p.max_jump_ang {
                    reason = Some(QuarantineReason::PositionJump);
                }
            }
            if let Some(reason) = reason {
                self.active[m] = false;
                self.quarantined.push(QuarantineRecord {
                    molecule: self.mol_ids[m],
                    species: self.species,
                    tick: tick_idx,
                    reason,
                });
                changed = true;
            }
        }
        if changed {
            self.rebuild_lanes();
        }
    }

    /// Re-pack the SoA batch over the surviving molecules. The SWAR
    /// batch kernel is bit-exact per lane at any batch size, so removing
    /// lanes cannot change a survivor's trajectory by a single bit.
    fn rebuild_lanes(&mut self) {
        let mut batch = 0usize;
        for m in 0..self.mols.len() {
            if self.active[m] {
                self.lane0[m] = batch;
                batch += self.mols[m].lanes();
            }
        }
        self.batch = batch;
        self.feats.clear();
        self.feats.resize(self.in_dim * batch, Q13::ZERO);
        self.outs.clear();
        self.outs.resize(self.out_dim * batch, Q13::ZERO);
        self.tick_cycles = Self::tick_cycle_budget(&self.mols, &self.active, &self.chip, batch);
    }

    fn positions(&self) -> Vec<Vec<Vec3>> {
        self.mols.iter().map(|m| m.positions()).collect()
    }
}

enum FarmBackend {
    Inline(Vec<FarmShard>),
    Threaded(WorkerPool<FarmShard>),
}

/// Per-species slice of the aggregated ledger.
#[derive(Debug, Clone, Default)]
pub struct SpeciesLedger {
    pub name: String,
    pub n_molecules: usize,
    /// Total atoms across the species' molecules.
    pub n_atoms: usize,
    /// Molecule-steps of this species: Σ steps actually integrated
    /// (`ticks × n_molecules` on a fault-free run; less when molecules
    /// were quarantined or a shard died).
    pub molecule_steps: u64,
    pub chip_inferences: u64,
    /// Molecules the divergence monitor pulled from this species' batches.
    pub molecules_quarantined: u64,
    /// 26-bit integrator clamps summed over the species' molecules.
    pub saturation_events: u64,
    /// Host wall-clock each of the species' shards spent in its tick
    /// body.
    pub shard_walls: Vec<Duration>,
}

impl SpeciesLedger {
    /// Host molecule-steps per **shard-second** of this species: steps
    /// divided by the summed wall-clock its shards spent inside their
    /// tick bodies. Unlike an elapsed-time rate this is backend-
    /// independent — inline shards run sequentially and threaded shards
    /// concurrently, but the CPU-time a species consumes per molecule-
    /// step is the same either way — so inline and threaded rows are
    /// directly comparable: it is the per-worker serving cost. (For an
    /// elapsed-time rate, divide the species' steps by the whole farm's
    /// [`FarmLedger::host_wall`].)
    pub fn steps_per_shard_second(&self) -> f64 {
        let t: Duration = self.shard_walls.iter().sum();
        let t = t.as_secs_f64();
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }
}

/// Aggregated accounting of a farm run.
#[derive(Debug, Clone, Default)]
pub struct FarmLedger {
    /// Farm ticks completed (each advances every healthy molecule one
    /// step).
    pub ticks: u64,
    pub n_molecules: usize,
    /// Total molecule-steps actually integrated (`ticks × n_molecules`
    /// on a fault-free run; less when molecules were quarantined or a
    /// shard died mid-run).
    pub molecule_steps: u64,
    /// Modelled hardware cycles: Σ_shards ticks × shard tick budget
    /// (shards run on parallel hardware, but the conservative ledger
    /// sums them; see [`FarmLedger::hw_seconds_parallel`]).
    pub modelled_cycles: u64,
    /// Modelled cycles of the **slowest** shard (parallel-hardware view).
    pub critical_path_cycles: u64,
    pub chip_inferences: u64,
    pub chip_ops: OpCounts,
    pub fpga_ops: OpCounts,
    /// Host wall-clock of the whole farm (tick loop, incl. transport).
    pub host_wall: Duration,
    /// Host wall-clock each shard spent inside its own tick body.
    pub shard_walls: Vec<Duration>,
    /// Per-species breakdown, in species order (the serving-mix view).
    pub species: Vec<SpeciesLedger>,
    /// Shard panics the supervisor caught and recovered from (the shard
    /// froze; the farm kept serving).
    pub panics_recovered: u64,
    /// Reply channels lost in transit (threaded backend only).
    pub replies_lost: u64,
    /// Molecules the divergence monitor pulled from their batches.
    pub molecules_quarantined: u64,
    /// 26-bit integrator clamps summed over every molecule.
    pub saturation_events: u64,
    /// Q13 rail hits observed on chip output lanes.
    pub rail_hits: u64,
    /// Ticks during which at least one shard was dead or at least one
    /// molecule quarantined.
    pub degraded_ticks: u64,
    /// Every quarantine decision, in the order the supervisor saw them
    /// (shard order, then tick order within a shard).
    pub quarantined: Vec<QuarantineRecord>,
    /// Every shard loss (recovered panic / lost reply).
    pub shards_lost: Vec<ShardLoss>,
}

impl FarmLedger {
    /// Modelled hardware seconds if the shards ran on one serial device.
    pub fn hw_seconds(&self, clock_hz: f64) -> f64 {
        self.modelled_cycles as f64 / clock_hz
    }

    /// Modelled hardware seconds with one device per shard (the farm's
    /// deployment model): the critical-path shard bounds the tick.
    pub fn hw_seconds_parallel(&self, clock_hz: f64) -> f64 {
        self.critical_path_cycles as f64 / clock_hz
    }

    /// Modelled hardware throughput, molecule-steps per second, with
    /// one device per shard.
    pub fn modelled_steps_per_second(&self, clock_hz: f64) -> f64 {
        let t = self.hw_seconds_parallel(clock_hz);
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// Host (simulator) throughput, molecule-steps per second.
    pub fn host_steps_per_second(&self) -> f64 {
        let t = self.host_wall.as_secs_f64();
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// The paper's S metric over the farm (s/step/atom,
    /// parallel-hardware view), using the real atom count of the
    /// species mix (3 per molecule for a water-only farm, as before).
    pub fn s_per_step_atom(&self, clock_hz: f64) -> f64 {
        let atoms_per_tick: u64 = self.species.iter().map(|s| s.n_atoms as u64).sum();
        let atom_steps = self.ticks * atoms_per_tick;
        if atom_steps == 0 {
            return 0.0;
        }
        self.hw_seconds_parallel(clock_hz) / atom_steps as f64
    }
}

/// Live telemetry the epoch driver folds host-side while shards are
/// executing (see [`MoleculeFarm::telemetry`]). The final
/// [`FarmLedger`] from [`MoleculeFarm::finish`] is the source of truth:
/// an epoch whose reply was lost in transit executed on its shard but
/// never reported, so its steps are missing here while `finish` reads
/// them from the shard state itself.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FarmTelemetry {
    /// Epochs folded so far (a `tick()` is a 1-tick epoch).
    pub epochs: u64,
    /// Farm ticks covered by those epochs.
    pub ticks: u64,
    /// Molecule-steps reported by shard epoch replies.
    pub molecule_steps: u64,
    /// 26-bit integrator saturation events reported.
    pub saturation_events: u64,
    /// Q13 rail hits reported on chip output lanes.
    pub rail_hits: u64,
    /// Quarantine decisions reported.
    pub molecules_quarantined: u64,
}

/// The deferred host-side fold of one epoch: everything the supervisor
/// needs to settle the books for ticks `[t0, t0 + ticks)`, retained
/// across `run_epoch` calls so the folding of epoch *t* overlaps with
/// the shards' execution of epoch *t + 1*.
struct EpochFold {
    t0: u64,
    ticks: u64,
    /// Earliest tick at which a degradation event (quarantine or shard
    /// loss) landed this epoch.
    first_event: Option<u64>,
    steps: u64,
    sat_events: u64,
    rail_hits: u64,
    quarantines: u64,
}

/// Settle a retained epoch fold into the supervisor's books. Degradation
/// is monotone — a dead shard stays dead, a quarantined molecule stays
/// quarantined — so the farm has been degraded continuously since the
/// earliest event tick, and the epoch's degraded-tick count is exactly
/// the tail of its window past that tick: the same number a per-tick
/// driver accumulates one tick at a time.
fn fold_epoch(
    pending: &mut Option<EpochFold>,
    telemetry: &mut FarmTelemetry,
    degraded_since: &mut Option<u64>,
    degraded_ticks: &mut u64,
) {
    let Some(f) = pending.take() else { return };
    if let Some(t) = f.first_event {
        *degraded_since = Some(degraded_since.map_or(t, |d| d.min(t)));
    }
    if let Some(d) = *degraded_since {
        let end = f.t0 + f.ticks;
        let from = d.max(f.t0);
        if end > from {
            *degraded_ticks += end - from;
        }
    }
    telemetry.epochs += 1;
    telemetry.ticks += f.ticks;
    telemetry.molecule_steps += f.steps;
    telemetry.saturation_events += f.sat_events;
    telemetry.rail_hits += f.rail_hits;
    telemetry.molecules_quarantined += f.quarantines;
}

/// Absorb one shard's epoch reply into the current fold: tallies sum,
/// event ticks push `first_event` down, quarantine records append to
/// the supervisor's live record list (the admission-control view; the
/// per-shard lists read by `finish` stay the source of truth), and a
/// mid-epoch shard death becomes a loss for the supervisor to process.
fn absorb_epoch(
    i: usize,
    ep: ShardEpoch,
    quar_counts: &mut [u32],
    quar_records: &mut Vec<QuarantineRecord>,
    fold: &mut EpochFold,
    losses: &mut Vec<(usize, u64, String, bool)>,
) {
    debug_assert!(ep.loss.is_some() || ep.ticks_run == fold.ticks);
    quar_counts[i] = ep.quarantined;
    fold.steps += ep.steps;
    fold.sat_events += ep.sat_events;
    fold.rail_hits += ep.rail_hits;
    fold.quarantines += ep.quarantines.len() as u64;
    for q in &ep.quarantines {
        fold.first_event = Some(fold.first_event.map_or(q.tick, |t| t.min(q.tick)));
    }
    quar_records.extend(ep.quarantines);
    if let Some((tick, detail)) = ep.loss {
        losses.push((i, tick, detail, true));
    }
}

/// Species bookkeeping of a farm.
struct SpeciesMeta {
    name: String,
    n_molecules: usize,
    n_atoms: usize,
}

/// The batched multi-molecule, multi-species serving system, with a
/// supervisor: a panicking shard is caught (inline) or surfaced as a
/// typed transport error (threaded), recorded, and frozen — its species
/// group degrades while every other shard keeps serving bit-identically.
pub struct MoleculeFarm {
    backend: FarmBackend,
    species: Vec<SpeciesMeta>,
    n_molecules: usize,
    n_shards: usize,
    /// Species of each shard (supervisor-side copy; shards may be dead).
    shard_species: Vec<usize>,
    /// Shards the supervisor has written off.
    dead: Vec<bool>,
    /// Cumulative quarantine count per shard, from its last epoch report.
    quar_counts: Vec<u32>,
    /// Quarantine records reported so far (live supervisor view, in
    /// shard-then-tick order per epoch; may miss records whose epoch
    /// reply was lost — `finish` reads the shards directly).
    quar_records: Vec<QuarantineRecord>,
    /// Molecules currently resident per shard (admit/retire churn; the
    /// admission placement key).
    resident: Vec<usize>,
    /// Shard currently holding each resident molecule, by farm-wide id.
    home: std::collections::BTreeMap<usize, usize>,
    /// Next farm-wide molecule id to assign on admit.
    next_mol_id: usize,
    panics_recovered: u64,
    replies_lost: u64,
    degraded_ticks: u64,
    /// First tick since which the farm has been continuously degraded
    /// (degradation is monotone; `None` = never degraded).
    degraded_since: Option<u64>,
    /// The last submitted epoch's books, folded lazily — while shards
    /// execute epoch *t + 1*, the host settles epoch *t*.
    pending: Option<EpochFold>,
    telemetry: FarmTelemetry,
    /// Last observed positions per shard, refreshed when a shard is
    /// written off: the threaded backend's degraded-mode `positions()`
    /// source (inline reads dead shards directly; this stays empty).
    frozen: Vec<Vec<Vec<Vec3>>>,
    lost: Vec<ShardLoss>,
    ticks: u64,
    host_wall: Duration,
    #[cfg(any(test, feature = "faults"))]
    faults: Option<FaultPlan>,
}

impl MoleculeFarm {
    /// Build the farm: each species group is partitioned into contiguous
    /// shards (clamped to its molecule count; the partition depends only
    /// on counts, so inline and threaded backends see identical shard
    /// contents), and every shard programs its own `Sqnn` from the
    /// group's model — request batches route by model.
    pub fn new(groups: Vec<SpeciesGroup>, lanes: usize, mode: ParallelMode) -> Result<MoleculeFarm> {
        Self::supervised(groups, lanes, mode, FarmSupervision::default())
    }

    /// [`MoleculeFarm::new`] with an explicit supervision policy
    /// (health thresholds and, under `cfg(any(test, feature =
    /// "faults"))`, a deterministic fault plan).
    pub fn supervised(
        groups: Vec<SpeciesGroup>,
        lanes: usize,
        mode: ParallelMode,
        sup: FarmSupervision,
    ) -> Result<MoleculeFarm> {
        anyhow::ensure!(!groups.is_empty(), "farm needs at least one species");
        anyhow::ensure!(lanes >= 1, "chip needs at least one MLP lane");
        let mut shards = Vec::new();
        let mut species = Vec::new();
        let mut n_molecules = 0usize;
        let mut home = std::collections::BTreeMap::new();
        for (si, g) in groups.into_iter().enumerate() {
            let n = g.mols.len();
            // An empty group still builds its requested shards (chips
            // programmed, zero batch lanes) — molecules arrive later
            // through `admit`.
            let n_shards = if n == 0 { g.shards } else { g.shards.min(n) };
            let base = n / n_shards;
            let rem = n % n_shards;
            let n_atoms = g.mols.iter().map(|m| m.n_atoms()).sum();
            let mut mols = g.mols.into_iter();
            for s in 0..n_shards {
                let take = base + usize::from(s < rem);
                let slice: Vec<Box<dyn ServedMolecule>> = mols.by_ref().take(take).collect();
                let ids: Vec<usize> = (0..slice.len()).map(|m| n_molecules + m).collect();
                n_molecules += slice.len();
                let id = shards.len();
                for &mid in &ids {
                    home.insert(mid, id);
                }
                shards.push(FarmShard::new(id, si, slice, ids, &g.model, g.k, lanes, &sup)?);
            }
            debug_assert!(mols.next().is_none());
            species.push(SpeciesMeta { name: g.name, n_molecules: n, n_atoms });
        }
        let n_shards = shards.len();
        let shard_species = shards.iter().map(|s| s.species).collect();
        // Threaded: take the construction-time position snapshot before
        // the shards move into their worker threads — the fallback the
        // degraded-mode `positions()` serves if a dead shard's snapshot
        // could not be refreshed at death time (worker truly gone).
        let frozen = match mode {
            ParallelMode::Inline => Vec::new(),
            ParallelMode::Threaded => shards.iter().map(|s| s.positions()).collect(),
        };
        let backend = match mode {
            ParallelMode::Inline => FarmBackend::Inline(shards),
            ParallelMode::Threaded => {
                FarmBackend::Threaded(WorkerPool::spawn("farm-shard", shards)?)
            }
        };
        let resident = match &backend {
            FarmBackend::Inline(shards) => shards.iter().map(|s| s.mols.len()).collect(),
            // Threaded shards moved into their workers; reconstruct the
            // per-shard resident counts from the placement map.
            FarmBackend::Threaded(_) => {
                let mut r = vec![0usize; n_shards];
                for &s in home.values() {
                    r[s] += 1;
                }
                r
            }
        };
        Ok(MoleculeFarm {
            backend,
            species,
            n_molecules,
            n_shards,
            shard_species,
            dead: vec![false; n_shards],
            quar_counts: vec![0; n_shards],
            quar_records: Vec::new(),
            resident,
            home,
            next_mol_id: n_molecules,
            panics_recovered: 0,
            replies_lost: 0,
            degraded_ticks: 0,
            degraded_since: None,
            pending: None,
            telemetry: FarmTelemetry::default(),
            frozen,
            lost: Vec::new(),
            ticks: 0,
            host_wall: Duration::ZERO,
            #[cfg(any(test, feature = "faults"))]
            faults: sup.faults,
        })
    }

    /// One farm tick: every healthy molecule of every species advances
    /// one step. A shard that panics (or whose reply is lost) is
    /// recorded and frozen — the tick still succeeds for every other
    /// shard, and the farm keeps serving in degraded mode.
    ///
    /// This is the 1-tick case of [`MoleculeFarm::run_epoch`]; use an
    /// epoch length > 1 to amortize the per-tick transport round-trip.
    pub fn tick(&mut self) -> Result<()> {
        self.run_epoch(1)
    }

    /// Run `n` ticks as **one epoch**: one job per shard, one reply
    /// round-trip and one barrier per epoch instead of per tick.
    ///
    /// Bit-identical to `n` calls of [`MoleculeFarm::tick`] on both
    /// backends: shards advance their own tick counters, so health
    /// verdicts and `FaultPlan` injection points fire at the same
    /// absolute tick indices, and every quarantine/loss is recorded
    /// with its exact tick. What coarsens is only *detection latency*:
    /// the supervisor learns of a shard loss when the epoch's reply
    /// comes back, not mid-epoch. While shards execute this epoch, the
    /// host folds the previous epoch's ledger/telemetry (the fold is
    /// retained in `pending` and settled lazily — double-buffered
    /// submit-before-recv).
    pub fn run_epoch(&mut self, n: usize) -> Result<()> {
        if n == 0 {
            return Ok(());
        }
        let t0 = Instant::now();
        let base = self.ticks;
        let n_ticks = n as u64;
        let mut fold = EpochFold {
            t0: base,
            ticks: n_ticks,
            first_event: None,
            steps: 0,
            sat_events: 0,
            rail_hits: 0,
            quarantines: 0,
        };
        // (shard, tick, detail, was_panic) losses discovered this epoch.
        let mut losses: Vec<(usize, u64, String, bool)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        match &mut self.backend {
            FarmBackend::Inline(shards) => {
                // No transport to overlap with: settle the previous
                // epoch's books, then drive the shards in place.
                fold_epoch(
                    &mut self.pending,
                    &mut self.telemetry,
                    &mut self.degraded_since,
                    &mut self.degraded_ticks,
                );
                for (i, s) in shards.iter_mut().enumerate() {
                    if self.dead[i] {
                        continue;
                    }
                    match catch_unwind(AssertUnwindSafe(|| s.run_ticks(n_ticks, false))) {
                        Ok(Ok(ep)) => absorb_epoch(
                            i,
                            ep,
                            &mut self.quar_counts,
                            &mut self.quar_records,
                            &mut fold,
                            &mut losses,
                        ),
                        Ok(Err(e)) => first_err = first_err.or(Some(e)),
                        Err(payload) => {
                            // Escaped the per-tick catch (supervisor
                            // bookkeeping itself panicked): best
                            // attribution is the epoch's first tick.
                            losses.push((i, base, panic_message(payload.as_ref()), true));
                        }
                    }
                }
            }
            FarmBackend::Threaded(pool) => {
                // Arm a scheduled reply drop only when it is the first
                // fault of the shard's window: a panic scheduled at an
                // earlier tick ends the epoch before the drop tick is
                // reached (per-tick semantics — the panicking job still
                // delivers its reply).
                #[cfg(any(test, feature = "faults"))]
                let planned_drops: Vec<Option<u64>> = (0..self.dead.len())
                    .map(|i| {
                        let plan = self.faults?;
                        if self.dead[i] {
                            return None;
                        }
                        let drop = plan.first_reply_drop_in(i, base, base + n_ticks)?;
                        match plan.first_panic_in(i, base, base + n_ticks) {
                            Some(p) if p <= drop => None,
                            _ => Some(drop),
                        }
                    })
                    .collect();
                #[cfg(any(test, feature = "faults"))]
                for (i, d) in planned_drops.iter().enumerate() {
                    if d.is_some() {
                        pool.inject_reply_drop(i);
                    }
                }
                // Double-buffered submit: put every live shard to work
                // on this epoch *before* touching the host-side books.
                let mut replies = Vec::with_capacity(self.dead.len());
                for i in 0..self.dead.len() {
                    if self.dead[i] {
                        continue;
                    }
                    replies.push((
                        i,
                        pool.submit(i, move |_, s: &mut FarmShard| s.run_ticks(n_ticks, true)),
                    ));
                }
                // Overlap window: shards are executing this epoch while
                // the host settles the previous one.
                fold_epoch(
                    &mut self.pending,
                    &mut self.telemetry,
                    &mut self.degraded_since,
                    &mut self.degraded_ticks,
                );
                for (i, reply) in replies {
                    match reply.and_then(|r| r.recv()) {
                        Ok(Ok(ep)) => absorb_epoch(
                            i,
                            ep,
                            &mut self.quar_counts,
                            &mut self.quar_records,
                            &mut fold,
                            &mut losses,
                        ),
                        // Drain every reply before propagating an error:
                        // bailing mid-loop would orphan the remaining
                        // workers' results and skew the books.
                        Ok(Err(e)) => first_err = first_err.or(Some(e)),
                        Err(PoolError::JobPanicked { message, .. }) => {
                            losses.push((i, base, message, true));
                        }
                        Err(e @ (PoolError::ReplyLost { .. } | PoolError::WorkerGone { .. })) => {
                            #[cfg(any(test, feature = "faults"))]
                            let tick = planned_drops[i].unwrap_or(base);
                            #[cfg(not(any(test, feature = "faults")))]
                            let tick = base;
                            losses.push((i, tick, e.to_string(), false));
                        }
                        Err(e) => first_err = first_err.or(Some(e.into())),
                    }
                }
            }
        }
        for (i, tick, detail, was_panic) in losses {
            self.dead[i] = true;
            if was_panic {
                self.panics_recovered += 1;
            } else {
                self.replies_lost += 1;
                self.recover_lost_report(i, tick, &mut fold);
            }
            fold.first_event = Some(fold.first_event.map_or(tick, |t| t.min(tick)));
            self.lost.push(ShardLoss {
                shard: i,
                species: self.shard_species[i],
                tick,
                detail,
            });
            self.freeze_shard(i);
        }
        self.ticks += n_ticks;
        self.pending = Some(fold);
        self.host_wall += t0.elapsed();
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// A lost reply carried the shard's whole epoch report. Recover the
    /// supervisor-visible part from the surviving worker, exactly as a
    /// per-tick driver would have seen it: the quarantine records of
    /// the ticks whose replies *did* arrive before the drop tick (the
    /// drop tick's own report is lost in both drivers). Keeps
    /// `degraded_since` — and with it `degraded_ticks` — tick-exact
    /// when a quarantine and a reply drop land in the same epoch.
    fn recover_lost_report(&mut self, i: usize, drop_tick: u64, fold: &mut EpochFold) {
        if let FarmBackend::Threaded(pool) = &mut self.backend {
            if let Ok(recs) = pool
                .submit(i, |_, s: &mut FarmShard| s.quarantined.clone())
                .and_then(|r| r.recv())
            {
                self.quar_counts[i] = recs.iter().filter(|q| q.tick < drop_tick).count() as u32;
                for q in recs.iter().filter(|q| fold.t0 <= q.tick && q.tick < drop_tick) {
                    fold.first_event = Some(fold.first_event.map_or(q.tick, |t| t.min(q.tick)));
                    self.quar_records.push(*q);
                }
            }
        }
    }

    /// Refresh the frozen-position snapshot of a shard the supervisor
    /// just wrote off (threaded backend). Worker threads survive job
    /// panics, so the worker still serves the shard's exact frozen
    /// state; if even the snapshot query fails (worker truly gone), the
    /// previous snapshot stands.
    fn freeze_shard(&mut self, i: usize) {
        if let FarmBackend::Threaded(pool) = &mut self.backend {
            if let Ok(p) = pool
                .submit(i, |_, s: &mut FarmShard| s.positions())
                .and_then(|r| r.recv())
            {
                self.frozen[i] = p;
            }
        }
    }

    /// Run `n` ticks, one epoch each (the classic per-tick driver).
    pub fn run(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.tick()?;
        }
        Ok(())
    }

    /// Run `ticks` ticks in epochs of `epoch` ticks each (the final
    /// epoch is ragged when `epoch` does not divide `ticks`).
    pub fn run_epoched(&mut self, ticks: usize, epoch: usize) -> Result<()> {
        anyhow::ensure!(epoch >= 1, "epoch length must be >= 1");
        let mut left = ticks;
        while left > 0 {
            let n = left.min(epoch);
            self.run_epoch(n)?;
            left -= n;
        }
        Ok(())
    }

    /// Live host-side telemetry folded from the shards' epoch reports
    /// (settles the retained fold first, so the view includes every
    /// completed epoch). See [`FarmTelemetry`] for how this relates to
    /// the final ledger.
    pub fn telemetry(&mut self) -> FarmTelemetry {
        fold_epoch(
            &mut self.pending,
            &mut self.telemetry,
            &mut self.degraded_since,
            &mut self.degraded_ticks,
        );
        self.telemetry
    }

    /// Live supervisor view: molecules quarantined so far, per the last
    /// epoch reports.
    pub fn molecules_quarantined(&self) -> u64 {
        self.quar_counts.iter().map(|&q| u64::from(q)).sum()
    }

    /// Admit a molecule into a species between epochs: it is placed on
    /// the least-resident live shard of that species (lowest shard
    /// index on ties — a pure function of supervisor-side state, so
    /// inline and threaded backends place identically) and joins the
    /// shard's batch from the next epoch. Because the SWAR batch kernel
    /// is bit-exact per lane at any batch size, admission cannot move a
    /// resident molecule's trajectory by one bit. The species'
    /// `n_molecules`/`n_atoms` meta counts every molecule ever served
    /// (retire does not decrement) — the ledger denominators stay
    /// cumulative.
    pub fn admit(&mut self, species: usize, mol: Box<dyn ServedMolecule>) -> Result<AdmitTicket> {
        anyhow::ensure!(
            species < self.species.len(),
            "unknown species {species} (farm has {})",
            self.species.len()
        );
        let mut shard: Option<usize> = None;
        for i in 0..self.n_shards {
            if self.dead[i] || self.shard_species[i] != species {
                continue;
            }
            if shard.map_or(true, |best| self.resident[i] < self.resident[best]) {
                shard = Some(i);
            }
        }
        let Some(shard) = shard else {
            anyhow::bail!("species {species} has no live shard to admit into")
        };
        let mol_id = self.next_mol_id;
        self.next_mol_id += 1;
        let n_atoms = mol.n_atoms();
        match &mut self.backend {
            FarmBackend::Inline(shards) => shards[shard].admit(mol, mol_id),
            FarmBackend::Threaded(pool) => pool
                .submit(shard, move |_, s: &mut FarmShard| s.admit(mol, mol_id))
                .and_then(|r| r.recv())
                .map_err(anyhow::Error::from)?,
        }
        self.resident[shard] += 1;
        self.home.insert(mol_id, shard);
        self.n_molecules += 1;
        self.species[species].n_molecules += 1;
        self.species[species].n_atoms += n_atoms;
        Ok(AdmitTicket { mol_id, shard })
    }

    /// Retire a molecule between epochs: its lanes leave the shard's
    /// batch (survivors' bits unmoved — same contract as quarantine
    /// repacking) and its final state and books come back in a
    /// [`RetiredMolecule`]. The shard retains the molecule's step/
    /// saturation/op accounting so `finish()` ledgers stay complete.
    /// Fails if the molecule is unknown or its shard is dead (a dead
    /// shard's molecules stay frozen in place — read them through
    /// `positions()`).
    pub fn retire(&mut self, mol_id: usize) -> Result<RetiredMolecule> {
        let Some(&shard) = self.home.get(&mol_id) else {
            anyhow::bail!("molecule {mol_id} is not resident in the farm")
        };
        anyhow::ensure!(
            !self.dead[shard],
            "molecule {mol_id} is frozen on dead shard {shard}"
        );
        let retired = match &mut self.backend {
            FarmBackend::Inline(shards) => shards[shard].retire(mol_id)?,
            FarmBackend::Threaded(pool) => pool
                .submit(shard, move |_, s: &mut FarmShard| s.retire(mol_id))
                .and_then(|r| r.recv())
                .map_err(anyhow::Error::from)??,
        };
        self.resident[shard] -= 1;
        self.home.remove(&mol_id);
        Ok(retired)
    }

    /// Live shards currently serving a species (admission capacity
    /// shrinks as shards are written off).
    pub fn live_shards(&self, species: usize) -> usize {
        (0..self.n_shards)
            .filter(|&i| !self.dead[i] && self.shard_species[i] == species)
            .count()
    }

    /// Live supervisor view: molecules quarantined so far on a species'
    /// shards, per the last epoch reports.
    pub fn species_quarantined(&self, species: usize) -> u64 {
        (0..self.n_shards)
            .filter(|&i| self.shard_species[i] == species)
            .map(|i| u64::from(self.quar_counts[i]))
            .sum()
    }

    /// Quarantine records reported so far (live supervisor view; may
    /// miss records whose epoch reply was lost — `finish` reads the
    /// shards directly and is the source of truth).
    pub fn quarantine_records(&self) -> &[QuarantineRecord] {
        &self.quar_records
    }

    /// Shards written off so far, with loss attribution.
    pub fn losses(&self) -> &[ShardLoss] {
        &self.lost
    }

    /// Live supervisor view: shards written off so far.
    pub fn shards_lost(&self) -> usize {
        self.lost.len()
    }

    /// Decoded positions of every molecule ([molecule][atom]), species
    /// groups in construction order, molecules in their original order
    /// within each group. Serves in degraded mode: a dead shard's
    /// molecules report their last frozen state (inline reads the dead
    /// shard directly; threaded serves the death-time snapshot) instead
    /// of failing the whole query.
    pub fn positions(&self) -> Result<Vec<Vec<Vec3>>> {
        let per_shard: Vec<Vec<Vec<Vec3>>> = match &self.backend {
            FarmBackend::Inline(shards) => shards.iter().map(|s| s.positions()).collect(),
            FarmBackend::Threaded(pool) => {
                let live: Vec<usize> = (0..self.n_shards).filter(|&i| !self.dead[i]).collect();
                let mut answers = pool
                    .run_on(&live, |_, s: &mut FarmShard| s.positions())
                    .into_iter();
                let mut out = Vec::with_capacity(self.n_shards);
                for i in 0..self.n_shards {
                    if self.dead[i] {
                        out.push(self.frozen[i].clone());
                    } else {
                        let (j, r) = answers.next().expect("one reply per live shard");
                        debug_assert_eq!(i, j);
                        out.push(r?);
                    }
                }
                out
            }
        };
        Ok(per_shard.into_iter().flatten().collect())
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    pub fn n_molecules(&self) -> usize {
        self.n_molecules
    }

    /// Shards actually built (post-clamp, summed over species).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn n_species(&self) -> usize {
        self.species.len()
    }

    /// Tear the farm down (joining shard threads) and aggregate the
    /// ledger, farm-wide and per species. Teardown never panics: a dead
    /// worker contributes a fault record instead of its shard's books.
    pub fn finish(mut self) -> Result<FarmLedger> {
        // Settle the last epoch's retained fold before reading the books.
        fold_epoch(
            &mut self.pending,
            &mut self.telemetry,
            &mut self.degraded_since,
            &mut self.degraded_ticks,
        );
        let shards: Vec<Option<FarmShard>> = match self.backend {
            FarmBackend::Inline(shards) => shards.into_iter().map(Some).collect(),
            FarmBackend::Threaded(pool) => pool.into_items().items,
        };
        let mut ledger = FarmLedger {
            ticks: self.ticks,
            n_molecules: self.n_molecules,
            host_wall: self.host_wall,
            panics_recovered: self.panics_recovered,
            replies_lost: self.replies_lost,
            degraded_ticks: self.degraded_ticks,
            shards_lost: self.lost,
            species: self
                .species
                .iter()
                .map(|sp| SpeciesLedger {
                    name: sp.name.clone(),
                    n_molecules: sp.n_molecules,
                    n_atoms: sp.n_atoms,
                    ..SpeciesLedger::default()
                })
                .collect(),
            ..FarmLedger::default()
        };
        for (i, s) in shards.iter().enumerate() {
            let Some(s) = s else { continue };
            debug_assert!(self.dead[i] || s.ticks == self.ticks);
            ledger.modelled_cycles += s.cycles;
            ledger.critical_path_cycles = ledger.critical_path_cycles.max(s.cycles);
            ledger.chip_inferences += s.chip.inferences;
            ledger.chip_ops.merge(&s.chip.ops);
            ledger.shard_walls.push(s.wall);
            ledger.quarantined.extend(s.quarantined.iter().copied());
            let sp = &mut ledger.species[s.species];
            sp.chip_inferences += s.chip.inferences;
            sp.shard_walls.push(s.wall);
            sp.molecules_quarantined += s.quarantined.len() as u64;
            for m in &s.mols {
                let steps = m.steps();
                let sat = m.sat_events();
                ledger.fpga_ops.merge(&m.ops());
                ledger.molecule_steps += steps;
                ledger.saturation_events += sat;
                sp.molecule_steps += steps;
                sp.saturation_events += sat;
            }
            // Books retained from molecules retired off this shard —
            // churn never loses accounting.
            ledger.fpga_ops.merge(&s.retired_ops);
            ledger.molecule_steps += s.retired_steps;
            ledger.saturation_events += s.retired_sat;
            ledger.rail_hits += s.retired_rail_hits;
            sp.molecule_steps += s.retired_steps;
            sp.saturation_events += s.retired_sat;
            for mon in &s.mon {
                ledger.rail_hits += mon.rail_hits;
            }
        }
        ledger.molecules_quarantined = ledger.quarantined.len() as u64;
        Ok(ledger)
    }
}

/// The batched water-only serving system — the water instantiation of
/// [`MoleculeFarm`], preserving the original farm API and behavior.
pub struct WaterFarm {
    inner: MoleculeFarm,
    pub n_molecules: usize,
    cfg: FarmConfig,
}

impl WaterFarm {
    /// Build the farm: one initial [`System`] per molecule, partitioned
    /// into contiguous shards (the partition depends only on counts, so
    /// inline and threaded backends see identical shard contents).
    pub fn new(model: &Mlp, systems: &[System], cfg: &FarmConfig) -> Result<WaterFarm> {
        anyhow::ensure!(!systems.is_empty(), "farm needs at least one molecule");
        anyhow::ensure!(cfg.shards >= 1, "farm needs at least one shard");
        anyhow::ensure!(cfg.lanes >= 1, "chip needs at least one MLP lane");
        let group = water_group(model, systems, cfg.k, cfg.shards, cfg.dt_fs)?;
        let sup = FarmSupervision {
            health: cfg.health,
            #[cfg(any(test, feature = "faults"))]
            faults: cfg.faults,
        };
        let inner = MoleculeFarm::supervised(vec![group], cfg.lanes, cfg.mode, sup)?;
        // Store the *effective* configuration (shards post-clamp), so
        // `config()` agrees with what was actually built.
        let cfg_eff = FarmConfig { shards: inner.n_shards(), ..*cfg };
        Ok(WaterFarm { inner, n_molecules: systems.len(), cfg: cfg_eff })
    }

    /// One farm tick: every molecule advances one MD step.
    pub fn tick(&mut self) -> Result<()> {
        self.inner.tick()
    }

    /// Run `n` ticks.
    pub fn run(&mut self, n: usize) -> Result<()> {
        self.inner.run(n)
    }

    /// Run `n` ticks as one epoch (see [`MoleculeFarm::run_epoch`]).
    pub fn run_epoch(&mut self, n: usize) -> Result<()> {
        self.inner.run_epoch(n)
    }

    /// Run `ticks` ticks in epochs of `epoch` ticks each (see
    /// [`MoleculeFarm::run_epoched`]).
    pub fn run_epoched(&mut self, ticks: usize, epoch: usize) -> Result<()> {
        self.inner.run_epoched(ticks, epoch)
    }

    /// Live host-side telemetry (see [`MoleculeFarm::telemetry`]).
    pub fn telemetry(&mut self) -> FarmTelemetry {
        self.inner.telemetry()
    }

    /// Decoded positions of every molecule ([molecule][atom], atoms
    /// ordered [O, H1, H2]), in the original `systems` order.
    pub fn positions(&self) -> Result<Vec<Vec<Vec3>>> {
        self.inner.positions()
    }

    pub fn ticks(&self) -> u64 {
        self.inner.ticks()
    }

    /// The farm's effective configuration: `shards` is the post-clamp
    /// count actually built (≤ the requested count).
    pub fn config(&self) -> FarmConfig {
        self.cfg
    }

    /// Tear the farm down (joining shard threads) and aggregate the
    /// ledger.
    pub fn finish(self) -> Result<FarmLedger> {
        self.inner.finish()
    }
}

/// Deterministic per-molecule RNG stream: molecule `i` of workload seed
/// `seed` always sees the same velocities, independent of the farm's
/// shard layout.
fn molecule_rng(seed: u64, i: usize) -> Pcg {
    let stream = (i as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(0x2545_f491_4f6c_dd1d);
    Pcg::new(seed ^ stream)
}

/// Convenience: `n` water molecules at the DFT-surrogate equilibrium
/// with Maxwell–Boltzmann velocities, each from its own deterministic
/// per-molecule stream of `seed` — the farm workload generator used by
/// tests, benches, and the scaling experiment.
pub fn random_water_systems(n: usize, t_k: f64, seed: u64) -> Vec<System> {
    let pes = WaterPes::dft_surrogate();
    (0..n)
        .map(|i| {
            let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
            let mut rng = molecule_rng(seed, i);
            initialize_velocities(&mut sys, t_k, 6, &mut rng);
            sys
        })
        .collect()
}

/// Convenience: `n` copies of a generic molecule at its reference
/// geometry with Maxwell–Boltzmann velocities (per-molecule streams as
/// in [`random_water_systems`]) — the mixed-species workload generator.
pub fn random_molecule_systems(
    coords: &[Vec3],
    masses: &[f64],
    n: usize,
    t_k: f64,
    seed: u64,
) -> Vec<System> {
    let dof = (3 * coords.len()).saturating_sub(3).max(1);
    (0..n)
        .map(|i| {
            let mut sys = System::new(coords.to_vec(), masses.to_vec());
            let mut rng = molecule_rng(seed, i);
            initialize_velocities(&mut sys, t_k, dof, &mut rng);
            sys
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WaterSystem;
    use crate::hw::timing::CLOCK_HZ;
    use crate::nn::{Activation, Sqnn};
    use crate::potentials::ff;

    fn toy_model() -> Mlp {
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.3;
            }
        }
        m
    }

    /// A toy ethanol-class model: 4·n_nb → … → 3 Cartesian forces.
    fn toy_generic_model(n_nb: usize) -> Mlp {
        let mut rng = Pcg::new(55);
        let mut m = Mlp::init_random(
            "toy-generic",
            &[4 * n_nb, 8, 8, 3],
            Activation::Phi,
            &mut rng,
        );
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.2;
            }
        }
        m
    }

    fn ethanol_group(n_mols: usize, shards: usize, seed: u64) -> SpeciesGroup {
        let mol = ff::ethanol();
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&mol.coords, &mol.masses(), n_mols, 100.0, seed);
        generic_group("ethanol", &model, &mol.coords, &systems, n_nb, 3, shards, 0.25).unwrap()
    }

    #[test]
    fn inline_and_threaded_farms_are_bit_identical() {
        // The acceptance invariant: N = 64 molecules, 1000 ticks, inline
        // vs threaded — and different shard counts — must produce
        // bit-identical trajectories (molecules are independent and the
        // partition only affects which thread owns them).
        let m = toy_model();
        let systems = random_water_systems(64, 150.0, 42);
        let mut inline = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 3, mode: ParallelMode::Inline, ..FarmConfig::default() },
        )
        .unwrap();
        let mut threaded = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 5, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        inline.run(1000).unwrap();
        threaded.run(1000).unwrap();
        let pa = inline.positions().unwrap();
        let pb = threaded.positions().unwrap();
        assert_eq!(pa.len(), 64);
        for (mol, (a, b)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(a, b, "molecule {mol} diverged between backends");
        }
        let la = inline.finish().unwrap();
        let lb = threaded.finish().unwrap();
        assert_eq!(la.molecule_steps, 64_000);
        assert_eq!(la.molecule_steps, lb.molecule_steps);
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.fpga_ops, lb.fpga_ops);
        assert_eq!(la.chip_inferences, 2 * 64_000);
    }

    #[test]
    fn single_molecule_farm_matches_water_system() {
        // The farm's datapath is the coordinator's datapath: one
        // molecule served by the batch kernel must track the
        // two-chip-in-parallel WaterSystem bit for bit.
        let m = toy_model();
        let systems = random_water_systems(1, 50.0, 7);
        let mut ws = WaterSystem::new(&m, 3, &systems[0], 0.25, ParallelMode::Inline).unwrap();
        let mut farm = WaterFarm::new(&m, &systems, &FarmConfig::default()).unwrap();
        for _ in 0..500 {
            ws.step().unwrap();
            farm.tick().unwrap();
        }
        assert_eq!(farm.positions().unwrap()[0], ws.positions());
    }

    #[test]
    fn ledger_accounts_lane_model() {
        let m = toy_model();
        let systems = random_water_systems(8, 100.0, 9);
        let run_with_lanes = |lanes: usize| -> FarmLedger {
            let mut farm = WaterFarm::new(
                &m,
                &systems,
                &FarmConfig { shards: 2, lanes, ..FarmConfig::default() },
            )
            .unwrap();
            farm.run(10).unwrap();
            farm.finish().unwrap()
        };
        let serial = run_with_lanes(1);
        let wide = run_with_lanes(8);
        assert_eq!(serial.molecule_steps, 80);
        assert_eq!(serial.chip_inferences, 160);
        // More lanes ⇒ strictly fewer modelled cycles (the MLP stage
        // compresses from 8 waves to 1 per shard tick).
        assert!(
            wide.modelled_cycles < serial.modelled_cycles,
            "lanes=8 cycles {} !< lanes=1 cycles {}",
            wide.modelled_cycles,
            serial.modelled_cycles
        );
        // Identical physics regardless of the lane model.
        assert_eq!(serial.chip_ops, wide.chip_ops);
        assert_eq!(serial.fpga_ops, wide.fpga_ops);
        // Cycle ledger is exactly ticks × Σ shard budgets (deterministic).
        assert_eq!(serial.modelled_cycles % serial.ticks, 0);
        assert!(serial.critical_path_cycles <= serial.modelled_cycles);
        assert!(serial.host_steps_per_second() > 0.0);
        let (fast, slow) = (
            wide.modelled_steps_per_second(CLOCK_HZ),
            serial.modelled_steps_per_second(CLOCK_HZ),
        );
        assert!(fast > slow, "lane model throughput {fast} !> {slow}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let m = toy_model();
        assert!(WaterFarm::new(&m, &[], &FarmConfig::default()).is_err());
        let systems = random_water_systems(2, 50.0, 1);
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 0, ..FarmConfig::default() }
        )
        .is_err());
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { lanes: 0, ..FarmConfig::default() }
        )
        .is_err());
        let mut bad = toy_model();
        bad.output_scale = 3.0; // not a power of two
        assert!(WaterFarm::new(&bad, &systems, &FarmConfig::default()).is_err());
        // multi-species validation
        assert!(MoleculeFarm::new(Vec::new(), 1, ParallelMode::Inline).is_err());
        let g = water_group(&m, &systems, 3, 1, 0.25).unwrap();
        assert!(MoleculeFarm::new(vec![g], 0, ParallelMode::Inline).is_err());
        // generic-group validation: wrong model shape for n_nb
        let mol = ff::ethanol();
        let sys = random_molecule_systems(&mol.coords, &mol.masses(), 1, 50.0, 3);
        let wrong = toy_generic_model(3); // 12 inputs, but n_nb = 4 wants 16
        assert!(
            generic_group("ethanol", &wrong, &mol.coords, &sys, 4, 3, 1, 0.25).is_err()
        );
    }

    #[test]
    fn shards_clamped_to_molecule_count() {
        let m = toy_model();
        let systems = random_water_systems(3, 50.0, 2);
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 16, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        assert_eq!(farm.config().shards, 3, "config() must report the effective shard count");
        farm.run(5).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.shard_walls.len(), 3);
        assert_eq!(l.molecule_steps, 15);
    }

    #[test]
    fn generic_single_molecule_matches_unbatched_reference() {
        // The generic serving path must be bit-identical to the
        // unbatched reference: the same MoleculeFpga stepped with
        // per-lane scalar Sqnn inference instead of the farm's batched
        // chip kernel.
        let mol = ff::ethanol();
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&mol.coords, &mol.masses(), 1, 120.0, 11);
        let group =
            generic_group("ethanol", &model, &mol.coords, &systems, n_nb, 3, 1, 0.25).unwrap();
        let mut farm = MoleculeFarm::new(vec![group], 1, ParallelMode::Inline).unwrap();
        farm.run(300).unwrap();

        // Reference path: scalar inference lane by lane.
        let net = Sqnn::from_mlp(&model, 3);
        let n = mol.coords.len();
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors(&mol.coords, i, n_nb))
            .collect();
        let cond =
            FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)
                .unwrap();
        let mut fpga = MoleculeFpga::new(&systems[0], nb, cond, 0.25).unwrap();
        fpga.force_shift = model.force_shift().unwrap();
        let in_dim = 4 * n_nb;
        let batch = n;
        let mut feats = vec![Q13::ZERO; in_dim * batch];
        let mut outs = vec![Q13::ZERO; 3 * batch];
        let mut lane = vec![Q13::ZERO; in_dim];
        for _ in 0..300 {
            fpga.extract_features_soa(&mut feats, batch, 0);
            for b in 0..batch {
                for (i, slot) in lane.iter_mut().enumerate() {
                    *slot = feats[i * batch + b];
                }
                let y = net.forward_q13(&lane);
                for (o, &v) in y.iter().enumerate() {
                    outs[o * batch + b] = v;
                }
            }
            fpga.integrate_soa(&outs, batch, 0);
        }
        let got = farm.positions().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], fpga.positions(), "batched farm diverged from scalar reference");
        let ledger = farm.finish().unwrap();
        assert_eq!(ledger.fpga_ops, fpga.ops);
        assert_eq!(ledger.chip_inferences, 300 * n as u64);
    }

    #[test]
    fn silicon_pbc_group_matches_unbatched_reference() {
        // The PBC satellite's acceptance: a bulk silicon cell served on
        // the generic batched path (minimum-image descriptors, wrapped
        // state) must be bit-identical to the same MoleculeFpga stepped
        // with scalar per-lane Sqnn inference.
        let (sw, coords) = crate::potentials::StillingerWeber::diamond_supercell(1);
        let box_l = sw.box_l;
        let n = coords.len();
        let masses = vec![28.0855; n];
        let n_nb = 4usize;
        let model = toy_generic_model(n_nb);
        let systems = random_molecule_systems(&coords, &masses, 3, 300.0, 17);
        let group = generic_group_pbc(
            "silicon", &model, &coords, &systems, n_nb, 3, 2, 0.5, box_l,
        )
        .unwrap();
        let mut farm = MoleculeFarm::new(vec![group], 1, ParallelMode::Inline).unwrap();
        farm.run(200).unwrap();

        // Reference path: scalar inference lane by lane on system 0.
        let net = Sqnn::from_mlp(&model, 3);
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors_pbc(&coords, i, n_nb, box_l))
            .collect();
        let cond =
            FeatureConditioner::new(4 * n_nb, &model.feature_center, &model.feature_scale)
                .unwrap();
        let mut fpga = MoleculeFpga::new_pbc(&systems[0], nb, cond, 0.5, box_l).unwrap();
        fpga.force_shift = model.force_shift().unwrap();
        let in_dim = 4 * n_nb;
        let batch = n;
        let mut feats = vec![Q13::ZERO; in_dim * batch];
        let mut outs = vec![Q13::ZERO; 3 * batch];
        let mut lane = vec![Q13::ZERO; in_dim];
        for _ in 0..200 {
            fpga.extract_features_soa(&mut feats, batch, 0);
            for b in 0..batch {
                for (i, slot) in lane.iter_mut().enumerate() {
                    *slot = feats[i * batch + b];
                }
                let y = net.forward_q13(&lane);
                for (o, &v) in y.iter().enumerate() {
                    outs[o * batch + b] = v;
                }
            }
            fpga.integrate_soa(&outs, batch, 0);
        }
        let got = farm.positions().unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], fpga.positions(), "batched PBC farm diverged from scalar reference");
        // Every served cell stays wrapped inside the box.
        for cell in &got {
            for p in cell {
                for x in p.to_array() {
                    assert!((0.0..box_l).contains(&x), "position {x} escaped [0, {box_l})");
                }
            }
        }
        let ledger = farm.finish().unwrap();
        assert_eq!(ledger.molecule_steps, 3 * 200);
        assert_eq!(ledger.chip_inferences, 3 * 200 * n as u64);
    }

    #[test]
    fn mixed_species_farm_is_bit_identical_across_backends() {
        // The multi-model acceptance invariant: a farm serving two
        // distinct per-shard models (water 3→…→2 and an ethanol-class
        // 16→…→3) must be bit-identical between inline and threaded
        // backends, across different shard counts.
        let wm = toy_model();
        let water_systems = random_water_systems(10, 120.0, 21);
        let build = |water_shards: usize, eth_shards: usize, mode: ParallelMode| {
            let groups = vec![
                water_group(&wm, &water_systems, 3, water_shards, 0.25).unwrap(),
                ethanol_group(6, eth_shards, 33),
            ];
            MoleculeFarm::new(groups, 1, mode).unwrap()
        };
        let mut inline = build(3, 2, ParallelMode::Inline);
        let mut threaded = build(4, 3, ParallelMode::Threaded);
        inline.run(200).unwrap();
        threaded.run(200).unwrap();
        let pa = inline.positions().unwrap();
        let pb = threaded.positions().unwrap();
        assert_eq!(pa.len(), 16);
        assert_eq!(pa[0].len(), 3, "water molecules first, [O,H1,H2]");
        assert_eq!(pa[10].len(), 9, "ethanol molecules follow, 9 atoms");
        for (mol, (a, b)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(a, b, "molecule {mol} diverged between backends");
        }
        let la = inline.finish().unwrap();
        let lb = threaded.finish().unwrap();
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.fpga_ops, lb.fpga_ops);
        assert_eq!(la.molecule_steps, lb.molecule_steps);
    }

    #[test]
    fn per_species_ledger_accounts_the_mix() {
        let wm = toy_model();
        let water_systems = random_water_systems(4, 100.0, 5);
        let groups = vec![
            water_group(&wm, &water_systems, 3, 2, 0.25).unwrap(),
            ethanol_group(2, 1, 9),
        ];
        let mut farm = MoleculeFarm::new(groups, 1, ParallelMode::Inline).unwrap();
        assert_eq!(farm.n_molecules(), 6);
        assert_eq!(farm.n_species(), 2);
        assert_eq!(farm.n_shards(), 3);
        farm.run(10).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.molecule_steps, 60);
        assert_eq!(l.species.len(), 2);
        let (w, e) = (&l.species[0], &l.species[1]);
        assert_eq!(w.name, "water");
        assert_eq!(e.name, "ethanol");
        assert_eq!(w.n_molecules, 4);
        assert_eq!(e.n_molecules, 2);
        assert_eq!(w.n_atoms, 12);
        assert_eq!(e.n_atoms, 18);
        assert_eq!(w.molecule_steps, 40);
        assert_eq!(e.molecule_steps, 20);
        // Lane routing by model: water = 2 lanes/molecule, ethanol =
        // 9 lanes (one per atom).
        assert_eq!(w.chip_inferences, 10 * 4 * 2);
        assert_eq!(e.chip_inferences, 10 * 2 * 9);
        assert_eq!(w.chip_inferences + e.chip_inferences, l.chip_inferences);
        assert_eq!(w.shard_walls.len(), 2);
        assert_eq!(e.shard_walls.len(), 1);
        assert!(w.steps_per_shard_second() > 0.0);
        assert!(e.steps_per_shard_second() > 0.0);
        // Mixed-atom S metric uses the real atom mix (30 atoms/tick).
        let s = l.s_per_step_atom(CLOCK_HZ);
        assert!(s > 0.0 && s.is_finite());
        assert!((s - l.hw_seconds_parallel(CLOCK_HZ) / 300.0).abs() < 1e-18);
        // Fault-free run: the supervision counters are identically zero.
        assert_eq!(l.panics_recovered, 0);
        assert_eq!(l.molecules_quarantined, 0);
        assert_eq!(l.saturation_events, 0);
        assert_eq!(l.degraded_ticks, 0);
        assert!(l.quarantined.is_empty() && l.shards_lost.is_empty());
    }

    use crate::testkit::faults::FaultPlan;

    fn water_farm_with(
        systems: &[System],
        shards: usize,
        mode: ParallelMode,
        faults: Option<FaultPlan>,
    ) -> WaterFarm {
        let m = toy_model();
        WaterFarm::new(&m, systems, &FarmConfig { shards, mode, faults, ..FarmConfig::default() })
            .unwrap()
    }

    #[test]
    fn injected_shard_panic_degrades_its_group_not_the_farm() {
        // 8 molecules over 4 shards (2 each; shard 1 = molecules 2, 3).
        // Shard 1 panics at the top of tick 3, before mutating anything:
        // its molecules freeze at their post-tick-2 state, every other
        // molecule must stay bit-identical to a fault-free run, and both
        // backends must agree on everything including the ledger.
        let systems = random_water_systems(8, 120.0, 3);
        let plan = FaultPlan::new().panic_shard(1, 3);
        let mut clean = water_farm_with(&systems, 4, ParallelMode::Inline, None);
        clean.run(10).unwrap();
        let clean_pos = clean.positions().unwrap();

        let mut ledgers = Vec::new();
        for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
            let mut farm = water_farm_with(&systems, 4, mode, Some(plan));
            farm.run(10).unwrap();
            let pos = farm.positions().unwrap();
            for mol in [0usize, 1, 4, 5, 6, 7] {
                assert_eq!(pos[mol], clean_pos[mol], "unaffected molecule {mol} diverged");
            }
            for mol in [2usize, 3] {
                assert_ne!(pos[mol], clean_pos[mol], "molecule {mol} should be frozen early");
            }
            let l = farm.finish().unwrap();
            assert_eq!(l.panics_recovered, 1);
            assert_eq!(l.replies_lost, 0);
            assert_eq!(l.degraded_ticks, 7, "dead from tick 3 through tick 9");
            assert_eq!(l.shards_lost.len(), 1);
            assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (1, 3));
            assert!(l.shards_lost[0].detail.contains("injected fault"));
            // 6 healthy molecules × 10 ticks + 2 frozen × 3 completed.
            assert_eq!(l.molecule_steps, 66);
            ledgers.push(l);
        }
        let (a, b) = (&ledgers[0], &ledgers[1]);
        assert_eq!(a.molecule_steps, b.molecule_steps);
        assert_eq!(a.panics_recovered, b.panics_recovered);
        assert_eq!(a.degraded_ticks, b.degraded_ticks);
        assert_eq!(a.chip_inferences, b.chip_inferences);
    }

    #[test]
    fn saturated_molecule_is_quarantined_and_survivors_stay_bit_identical() {
        // 6 molecules over 2 shards (3 each; molecule 1 shares shard 0
        // with molecules 0 and 2). Molecule 1 is pinned onto the 26-bit
        // rail at tick 4: the divergence monitor must quarantine exactly
        // it on that tick, its shard-mates' trajectories must not move
        // by a bit (the SWAR kernel is bit-exact per lane at any batch
        // size), and its own state must be frozen from then on.
        let systems = random_water_systems(6, 120.0, 8);
        let plan = FaultPlan::new().saturate_molecule(1, 4);
        let mut clean = water_farm_with(&systems, 2, ParallelMode::Inline, None);
        clean.run(50).unwrap();
        let clean_pos = clean.positions().unwrap();

        let mut results = Vec::new();
        for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
            let mut farm = water_farm_with(&systems, 2, mode, Some(plan));
            farm.run(50).unwrap();
            let pos = farm.positions().unwrap();
            for mol in [0usize, 2, 3, 4, 5] {
                assert_eq!(pos[mol], clean_pos[mol], "survivor {mol} diverged");
            }
            assert_ne!(pos[1], clean_pos[1]);
            // Quarantined state is frozen: ten more ticks change nothing.
            farm.run(10).unwrap();
            assert_eq!(farm.positions().unwrap()[1], pos[1], "quarantined molecule moved");
            let l = farm.finish().unwrap();
            assert_eq!(l.molecules_quarantined, 1);
            assert_eq!(l.quarantined.len(), 1);
            let q = l.quarantined[0];
            assert_eq!((q.molecule, q.species, q.tick), (1, 0, 4));
            assert_eq!(q.reason, QuarantineReason::SaturationEvents);
            assert!(l.saturation_events >= 3, "rail pin must trip the clamp counter");
            assert_eq!(l.species[0].molecules_quarantined, 1);
            assert_eq!(l.panics_recovered, 0);
            // Degraded from the quarantine tick to the end: ticks 4..59.
            assert_eq!(l.degraded_ticks, 56);
            // 5 healthy molecules × 60 ticks + molecule 1's 5 completed
            // ticks (it still integrated on its quarantine tick).
            assert_eq!(l.molecule_steps, 305);
            results.push((pos, l));
        }
        let ((pa, la), (pb, lb)) = (&results[0], &results[1]);
        assert_eq!(pa, pb, "backends disagree under quarantine");
        assert_eq!(la.saturation_events, lb.saturation_events);
        assert_eq!(la.degraded_ticks, lb.degraded_ticks);
        assert_eq!(la.quarantined, lb.quarantined);
    }

    #[test]
    fn dropped_reply_kills_the_shard_but_the_tick_succeeds() {
        // Transport fault, threaded backend only: shard 0's reply channel
        // is dropped at tick 2. The supervisor writes the shard off as a
        // lost reply (its job actually ran — the state is simply
        // unobservable) and the farm keeps serving the other shards.
        let systems = random_water_systems(4, 100.0, 13);
        let plan = FaultPlan::new().drop_reply(0, 2);
        let mut farm = water_farm_with(&systems, 2, ParallelMode::Threaded, Some(plan));
        farm.run(8).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.replies_lost, 1);
        assert_eq!(l.panics_recovered, 0);
        assert_eq!(l.shards_lost.len(), 1);
        assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (0, 2));
        assert_eq!(l.degraded_ticks, 6, "dead from tick 2 through tick 7");
        // Shard 0's two molecules completed 3 ticks (the dropped-reply
        // tick did execute), shard 1's completed all 8.
        assert_eq!(l.molecule_steps, 2 * 3 + 2 * 8);
    }

    #[test]
    fn epoch_driver_is_bit_identical_to_per_tick() {
        // The tentpole invariant without faults: run_epoched(n, e) must
        // equal n × tick() — positions AND ledger — for epoch lengths
        // that divide the run, ones that leave a ragged tail, and the
        // whole run as one epoch, on both backends, over the
        // mixed-species workload.
        let wm = toy_model();
        let water_systems = random_water_systems(6, 120.0, 51);
        let build = |mode: ParallelMode| {
            let groups = vec![
                water_group(&wm, &water_systems, 3, 2, 0.25).unwrap(),
                ethanol_group(3, 2, 19),
            ];
            MoleculeFarm::new(groups, 1, mode).unwrap()
        };
        let mut per_tick = build(ParallelMode::Inline);
        per_tick.run(60).unwrap();
        let ref_pos = per_tick.positions().unwrap();
        let rl = per_tick.finish().unwrap();
        assert_eq!(rl.molecule_steps, 9 * 60);
        for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
            for epoch in [4usize, 7, 60] {
                let mut farm = build(mode);
                farm.run_epoched(60, epoch).unwrap();
                assert_eq!(farm.ticks(), 60);
                let pos = farm.positions().unwrap();
                assert_eq!(pos, ref_pos, "mode {mode:?} epoch {epoch} diverged");
                let l = farm.finish().unwrap();
                assert_eq!(l.ticks, 60);
                assert_eq!(l.molecule_steps, rl.molecule_steps);
                assert_eq!(l.chip_inferences, rl.chip_inferences);
                assert_eq!(l.chip_ops, rl.chip_ops);
                assert_eq!(l.fpga_ops, rl.fpga_ops);
                assert_eq!(l.modelled_cycles, rl.modelled_cycles);
                assert_eq!(l.critical_path_cycles, rl.critical_path_cycles);
                assert_eq!(l.degraded_ticks, 0);
            }
        }
    }

    #[test]
    fn epoch_zero_is_a_no_op_and_telemetry_folds_the_books() {
        let m = toy_model();
        let systems = random_water_systems(4, 100.0, 77);
        let g = water_group(&m, &systems, 3, 2, 0.25).unwrap();
        let mut farm = MoleculeFarm::new(vec![g], 1, ParallelMode::Inline).unwrap();
        farm.run_epoch(0).unwrap();
        assert_eq!(farm.ticks(), 0);
        assert_eq!(farm.telemetry(), FarmTelemetry::default());
        farm.run_epoched(10, 4).unwrap(); // epochs of 4, 4, 2
        assert_eq!(farm.ticks(), 10);
        let t = farm.telemetry();
        assert_eq!(t.epochs, 3);
        assert_eq!(t.ticks, 10);
        assert_eq!(t.molecule_steps, 40);
        assert_eq!(t.saturation_events, 0);
        assert_eq!(t.molecules_quarantined, 0);
        // The live view is idempotent (folding is not double-counted)
        // and agrees with the torn-down ledger on a fault-free run.
        assert_eq!(farm.telemetry(), t);
        assert_eq!(farm.molecules_quarantined(), 0);
        assert_eq!(farm.shards_lost(), 0);
        let l = farm.finish().unwrap();
        assert_eq!(l.molecule_steps, t.molecule_steps);
        assert_eq!(l.saturation_events, t.saturation_events);
        assert_eq!(l.rail_hits, t.rail_hits);
    }

    #[test]
    fn epoch_driver_matches_per_tick_under_injected_faults() {
        // Epoch-boundary-crossing fault schedule: shard 1 panics at
        // tick 3 and molecule 1 (shard 0) saturates at tick 4 — both
        // land mid-epoch for epoch lengths 4 and 7, and inside the
        // single whole-run epoch of 20. Ledgers and positions must
        // match the per-tick driver bit for bit on both backends.
        let systems = random_water_systems(8, 120.0, 3);
        let plan = FaultPlan::new().panic_shard(1, 3).saturate_molecule(1, 4);
        let mut per_tick = water_farm_with(&systems, 4, ParallelMode::Inline, Some(plan));
        per_tick.run(20).unwrap();
        let ref_pos = per_tick.positions().unwrap();
        let rl = per_tick.finish().unwrap();
        assert_eq!(rl.panics_recovered, 1);
        assert_eq!(rl.molecules_quarantined, 1);
        for mode in [ParallelMode::Inline, ParallelMode::Threaded] {
            for epoch in [4usize, 7, 20] {
                let mut farm = water_farm_with(&systems, 4, mode, Some(plan));
                farm.run_epoched(20, epoch).unwrap();
                let pos = farm.positions().unwrap();
                assert_eq!(pos, ref_pos, "mode {mode:?} epoch {epoch} positions diverged");
                let l = farm.finish().unwrap();
                assert_eq!(l.molecule_steps, rl.molecule_steps);
                assert_eq!(l.panics_recovered, 1);
                assert_eq!(l.degraded_ticks, rl.degraded_ticks, "mode {mode:?} epoch {epoch}");
                assert_eq!(l.quarantined, rl.quarantined);
                assert_eq!(l.saturation_events, rl.saturation_events);
                assert_eq!(l.shards_lost.len(), 1);
                assert_eq!(
                    (l.shards_lost[0].shard, l.shards_lost[0].tick),
                    (rl.shards_lost[0].shard, rl.shards_lost[0].tick)
                );
                assert!(l.shards_lost[0].detail.contains("injected fault"));
            }
        }
    }

    #[test]
    fn dropped_reply_mid_epoch_matches_per_tick() {
        // Shard 0's reply drops at tick 2 — mid-epoch when the whole
        // run is one 8-tick epoch. The epoch driver must attribute the
        // loss to tick 2 (the arming decision knows the planned drop
        // tick), freeze the shard with the drop tick executed, and
        // keep the books identical to per-tick driving.
        let systems = random_water_systems(4, 100.0, 13);
        let plan = FaultPlan::new().drop_reply(0, 2);
        let mut per_tick = water_farm_with(&systems, 2, ParallelMode::Threaded, Some(plan));
        per_tick.run(8).unwrap();
        let ref_pos = per_tick.positions().unwrap();
        let rl = per_tick.finish().unwrap();
        for epoch in [3usize, 8] {
            let mut farm = water_farm_with(&systems, 2, ParallelMode::Threaded, Some(plan));
            farm.run_epoched(8, epoch).unwrap();
            assert_eq!(farm.positions().unwrap(), ref_pos, "epoch {epoch}");
            let l = farm.finish().unwrap();
            assert_eq!(l.replies_lost, 1);
            assert_eq!(l.panics_recovered, 0);
            assert_eq!((l.shards_lost[0].shard, l.shards_lost[0].tick), (0, 2));
            assert_eq!(l.degraded_ticks, rl.degraded_ticks);
            assert_eq!(l.molecule_steps, rl.molecule_steps);
        }
    }

    #[test]
    fn positions_serve_in_degraded_mode_after_shard_loss() {
        // The satellite regression: the threaded backend's positions()
        // used to query every worker, so a farm with a dead shard could
        // fail the whole query. It must skip dead shards and serve
        // their frozen state, bit-identical to the inline backend's
        // direct view of the same fault.
        let systems = random_water_systems(8, 120.0, 3);
        let plan = FaultPlan::new().panic_shard(1, 3);
        let mut inline = water_farm_with(&systems, 4, ParallelMode::Inline, Some(plan));
        let mut threaded = water_farm_with(&systems, 4, ParallelMode::Threaded, Some(plan));
        inline.run(10).unwrap();
        threaded.run(10).unwrap();
        let pi = inline.positions().unwrap();
        let pt = threaded.positions().unwrap();
        assert_eq!(pi.len(), 8);
        assert_eq!(pi, pt, "degraded-mode positions diverged across backends");
        // The farm keeps serving the query as it keeps ticking.
        threaded.run_epoch(5).unwrap();
        inline.run_epoch(5).unwrap();
        assert_eq!(inline.positions().unwrap(), threaded.positions().unwrap());
    }

    #[test]
    fn health_monitoring_can_be_disabled() {
        // With the monitor off, a rail-pinned molecule keeps its batch
        // lanes: nothing is quarantined, but the saturation ledger still
        // reports the clamp storm.
        let systems = random_water_systems(2, 100.0, 4);
        let m = toy_model();
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig {
                health: HealthPolicy { enabled: false, ..HealthPolicy::default() },
                faults: Some(FaultPlan::new().saturate_molecule(0, 1)),
                ..FarmConfig::default()
            },
        )
        .unwrap();
        farm.run(10).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.molecules_quarantined, 0);
        assert_eq!(l.degraded_ticks, 0);
        assert!(l.saturation_events > 0);
        assert_eq!(l.molecule_steps, 20);
    }
}
