//! The molecule farm — the batched, sharded serving path of the
//! coordinator.
//!
//! Where [`super::WaterSystem`] reproduces the paper's single-molecule
//! latency pipeline, [`WaterFarm`] turns the same devices into a
//! throughput engine: N independent water molecules advance one MD step
//! per *tick*, sharded over worker threads. Each shard owns its
//! molecules' FPGA state, one batched MLP chip, and all the scratch the
//! hot loop needs, and drives the paper's §IV-C workflow in batch form:
//!
//! 1. `fpga::extract_features_batch` — feature triples of every
//!    hydrogen in the shard, scattered into the chip's SoA layout;
//! 2. `MlpChip::infer_batch_into` — one weight-stationary batched
//!    inference over all 2·N_shard hydrogen lanes, with the
//!    `ChipConfig::lanes` intra-ASIC parallelism model (§VI A₂)
//!    accounting ⌈B/lanes⌉ pipeline waves;
//! 3. `fpga::integrate_batch` — force reconstruction, Newton's third
//!    law, and integration per molecule.
//!
//! Shards are fully independent, so the inline and threaded backends
//! are bit-identical by construction — the same guarantee the
//! single-molecule coordinator makes, extended to the farm. The
//! aggregated [`FarmLedger`] reports modelled hardware cycles (lane
//! model included), op counts, and **host throughput in
//! molecule-steps/second** — the first-class serving metric.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::asic::{ChipConfig, MlpChip};
use crate::fixedpoint::Q13;
use crate::fpga::{self, HFeatures, WaterFpga, ZERO_FRAME};
use crate::hw::power::OpCounts;
use crate::hw::timing::StepCycles;
use crate::md::{initialize_velocities, System};
use crate::nn::Mlp;
use crate::potentials::WaterPes;
use crate::util::rng::Pcg;
use crate::util::Vec3;

use super::pool::WorkerPool;
use super::ParallelMode;

/// Farm construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct FarmConfig {
    /// Worker shards (clamped to the molecule count).
    pub shards: usize,
    /// Parallel MLP lanes per shard chip (see [`ChipConfig::lanes`]).
    pub lanes: usize,
    /// Shift terms per weight for quantization.
    pub k: usize,
    /// Integrator timestep (fs).
    pub dt_fs: f64,
    /// Shard execution backend.
    pub mode: ParallelMode,
}

impl Default for FarmConfig {
    fn default() -> Self {
        FarmConfig { shards: 1, lanes: 1, k: 3, dt_fs: 0.25, mode: ParallelMode::Inline }
    }
}

/// One shard: a slice of the farm's molecules, its batched chip, and
/// the scratch buffers of the hot loop (owned here so a tick allocates
/// nothing).
struct FarmShard {
    mols: Vec<WaterFpga>,
    chip: MlpChip,
    frames: Vec<HFeatures>,
    feats: Vec<Q13>,
    forces: Vec<Q13>,
    /// Modelled hardware cycles of one tick of this shard.
    tick_cycles: u64,
    ticks: u64,
    wall: Duration,
}

impl FarmShard {
    fn new(
        id: usize,
        systems: &[System],
        model: &Mlp,
        force_shift: i32,
        cfg: &FarmConfig,
    ) -> Result<FarmShard> {
        let mut chip = MlpChip::new(id, ChipConfig { lanes: cfg.lanes, ..ChipConfig::default() });
        chip.program(model, cfg.k);
        let mols: Vec<WaterFpga> = systems
            .iter()
            .map(|sys| {
                let mut f = WaterFpga::new(sys, cfg.dt_fs);
                super::program_water_fpga(&mut f, model, force_shift);
                f
            })
            .collect();
        let lanes = 2 * mols.len();
        let tick_cycles = Self::tick_cycle_budget(mols.len(), &chip);
        Ok(FarmShard {
            mols,
            chip,
            frames: vec![ZERO_FRAME; lanes],
            feats: vec![Q13::ZERO; 3 * lanes],
            forces: vec![Q13::ZERO; 2 * lanes],
            tick_cycles,
            ticks: 0,
            wall: Duration::ZERO,
        })
    }

    /// Modelled cycles of one shard tick: the FPGA streams its molecules
    /// through feature extraction and integration sequentially, shares
    /// one transfer/control window per tick, and the chip's lane model
    /// covers the batched MLP stage (⌈2·N/lanes⌉ pipeline waves).
    fn tick_cycle_budget(n_mols: usize, chip: &MlpChip) -> u64 {
        let b = StepCycles::water();
        n_mols as u64 * (b.feature + b.integrate)
            + b.to_chip
            + b.from_chip
            + b.control
            + chip.batch_latency_cycles(2 * n_mols)
    }

    /// One MD step for every molecule in the shard.
    fn tick(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let lanes = 2 * self.mols.len();
        fpga::extract_features_batch(&mut self.mols, &mut self.frames, &mut self.feats);
        self.chip.infer_batch_into(&self.feats, lanes, &mut self.forces)?;
        fpga::integrate_batch(&mut self.mols, &self.frames, &self.forces);
        self.ticks += 1;
        self.wall += t0.elapsed();
        Ok(())
    }

    fn positions(&self) -> Vec<Vec<Vec3>> {
        self.mols.iter().map(|m| m.positions()).collect()
    }
}

enum FarmBackend {
    Inline(Vec<FarmShard>),
    Threaded(WorkerPool<FarmShard>),
}

/// Aggregated accounting of a farm run.
#[derive(Debug, Clone, Default)]
pub struct FarmLedger {
    /// Farm ticks completed (each advances every molecule one step).
    pub ticks: u64,
    pub n_molecules: usize,
    /// Total molecule-steps: `ticks × n_molecules`.
    pub molecule_steps: u64,
    /// Modelled hardware cycles: Σ_shards ticks × shard tick budget
    /// (shards run on parallel hardware, but the conservative ledger
    /// sums them; see [`FarmLedger::hw_seconds_parallel`]).
    pub modelled_cycles: u64,
    /// Modelled cycles of the **slowest** shard (parallel-hardware view).
    pub critical_path_cycles: u64,
    pub chip_inferences: u64,
    pub chip_ops: OpCounts,
    pub fpga_ops: OpCounts,
    /// Host wall-clock of the whole farm (tick loop, incl. transport).
    pub host_wall: Duration,
    /// Host wall-clock each shard spent inside its own tick body.
    pub shard_walls: Vec<Duration>,
}

impl FarmLedger {
    /// Modelled hardware seconds if the shards ran on one serial device.
    pub fn hw_seconds(&self, clock_hz: f64) -> f64 {
        self.modelled_cycles as f64 / clock_hz
    }

    /// Modelled hardware seconds with one device per shard (the farm's
    /// deployment model): the critical-path shard bounds the tick.
    pub fn hw_seconds_parallel(&self, clock_hz: f64) -> f64 {
        self.critical_path_cycles as f64 / clock_hz
    }

    /// Modelled hardware throughput, molecule-steps per second, with
    /// one device per shard.
    pub fn modelled_steps_per_second(&self, clock_hz: f64) -> f64 {
        let t = self.hw_seconds_parallel(clock_hz);
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// Host (simulator) throughput, molecule-steps per second.
    pub fn host_steps_per_second(&self) -> f64 {
        let t = self.host_wall.as_secs_f64();
        if t <= 0.0 {
            return 0.0;
        }
        self.molecule_steps as f64 / t
    }

    /// The paper's S metric over the farm (s/step/atom, 3 atoms per
    /// molecule, parallel-hardware view).
    pub fn s_per_step_atom(&self, clock_hz: f64) -> f64 {
        if self.molecule_steps == 0 {
            return 0.0;
        }
        self.hw_seconds_parallel(clock_hz) / self.molecule_steps as f64 / 3.0
    }
}

/// The batched multi-molecule serving system.
pub struct WaterFarm {
    backend: FarmBackend,
    pub n_molecules: usize,
    cfg: FarmConfig,
    ticks: u64,
    host_wall: Duration,
}

impl WaterFarm {
    /// Build the farm: one initial [`System`] per molecule, partitioned
    /// into contiguous shards (the partition depends only on counts, so
    /// inline and threaded backends see identical shard contents).
    pub fn new(model: &Mlp, systems: &[System], cfg: &FarmConfig) -> Result<WaterFarm> {
        anyhow::ensure!(!systems.is_empty(), "farm needs at least one molecule");
        let force_shift = super::validate_water_model(model)?;
        anyhow::ensure!(cfg.shards >= 1, "farm needs at least one shard");
        anyhow::ensure!(cfg.lanes >= 1, "chip needs at least one MLP lane");
        let n = systems.len();
        let n_shards = cfg.shards.min(n);
        let base = n / n_shards;
        let rem = n % n_shards;
        let mut shards = Vec::with_capacity(n_shards);
        let mut start = 0usize;
        for s in 0..n_shards {
            let take = base + usize::from(s < rem);
            let slice = &systems[start..start + take];
            shards.push(FarmShard::new(s, slice, model, force_shift, cfg)?);
            start += take;
        }
        debug_assert_eq!(start, n);
        let backend = match cfg.mode {
            ParallelMode::Inline => FarmBackend::Inline(shards),
            ParallelMode::Threaded => {
                FarmBackend::Threaded(WorkerPool::spawn("farm-shard", shards))
            }
        };
        // Store the *effective* configuration (shards post-clamp), so
        // `config()` agrees with what was actually built.
        let cfg_eff = FarmConfig { shards: n_shards, ..*cfg };
        Ok(WaterFarm {
            backend,
            n_molecules: n,
            cfg: cfg_eff,
            ticks: 0,
            host_wall: Duration::ZERO,
        })
    }

    /// One farm tick: every molecule advances one MD step.
    pub fn tick(&mut self) -> Result<()> {
        let t0 = Instant::now();
        match &mut self.backend {
            FarmBackend::Inline(shards) => {
                for s in shards.iter_mut() {
                    s.tick()?;
                }
            }
            FarmBackend::Threaded(pool) => {
                for r in pool.run_all(|_, s: &mut FarmShard| s.tick())? {
                    r?;
                }
            }
        }
        self.ticks += 1;
        self.host_wall += t0.elapsed();
        Ok(())
    }

    /// Run `n` ticks.
    pub fn run(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.tick()?;
        }
        Ok(())
    }

    /// Decoded positions of every molecule ([molecule][atom], atoms
    /// ordered [O, H1, H2]), in the original `systems` order.
    pub fn positions(&self) -> Result<Vec<Vec<Vec3>>> {
        let per_shard: Vec<Vec<Vec<Vec3>>> = match &self.backend {
            FarmBackend::Inline(shards) => shards.iter().map(|s| s.positions()).collect(),
            FarmBackend::Threaded(pool) => pool.run_all(|_, s: &mut FarmShard| s.positions())?,
        };
        Ok(per_shard.into_iter().flatten().collect())
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// The farm's effective configuration: `shards` is the post-clamp
    /// count actually built (≤ the requested count).
    pub fn config(&self) -> FarmConfig {
        self.cfg
    }

    /// Tear the farm down (joining shard threads) and aggregate the
    /// ledger.
    pub fn finish(self) -> Result<FarmLedger> {
        let shards = match self.backend {
            FarmBackend::Inline(shards) => shards,
            FarmBackend::Threaded(pool) => pool.into_items(),
        };
        let mut ledger = FarmLedger {
            ticks: self.ticks,
            n_molecules: self.n_molecules,
            molecule_steps: self.ticks * self.n_molecules as u64,
            host_wall: self.host_wall,
            ..FarmLedger::default()
        };
        for s in &shards {
            debug_assert_eq!(s.ticks, self.ticks);
            let shard_cycles = s.ticks * s.tick_cycles;
            ledger.modelled_cycles += shard_cycles;
            ledger.critical_path_cycles = ledger.critical_path_cycles.max(shard_cycles);
            ledger.chip_inferences += s.chip.inferences;
            ledger.chip_ops.merge(&s.chip.ops);
            for m in &s.mols {
                ledger.fpga_ops.merge(&m.ops);
            }
            ledger.shard_walls.push(s.wall);
        }
        Ok(ledger)
    }
}

/// Convenience: `n` water molecules at the DFT-surrogate equilibrium
/// with Maxwell–Boltzmann velocities, each from its own deterministic
/// per-molecule stream of `seed` — the farm workload generator used by
/// tests, benches, and the scaling experiment.
pub fn random_water_systems(n: usize, t_k: f64, seed: u64) -> Vec<System> {
    let pes = WaterPes::dft_surrogate();
    (0..n)
        .map(|i| {
            let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
            let stream = (i as u64)
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(0x2545_f491_4f6c_dd1d);
            let mut rng = Pcg::new(seed ^ stream);
            initialize_velocities(&mut sys, t_k, 6, &mut rng);
            sys
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WaterSystem;
    use crate::hw::timing::CLOCK_HZ;
    use crate::nn::Activation;

    fn toy_model() -> Mlp {
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.3;
            }
        }
        m
    }

    #[test]
    fn inline_and_threaded_farms_are_bit_identical() {
        // The acceptance invariant: N = 64 molecules, 1000 ticks, inline
        // vs threaded — and different shard counts — must produce
        // bit-identical trajectories (molecules are independent and the
        // partition only affects which thread owns them).
        let m = toy_model();
        let systems = random_water_systems(64, 150.0, 42);
        let mut inline = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 3, mode: ParallelMode::Inline, ..FarmConfig::default() },
        )
        .unwrap();
        let mut threaded = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 5, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        inline.run(1000).unwrap();
        threaded.run(1000).unwrap();
        let pa = inline.positions().unwrap();
        let pb = threaded.positions().unwrap();
        assert_eq!(pa.len(), 64);
        for (mol, (a, b)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(a, b, "molecule {mol} diverged between backends");
        }
        let la = inline.finish().unwrap();
        let lb = threaded.finish().unwrap();
        assert_eq!(la.molecule_steps, 64_000);
        assert_eq!(la.molecule_steps, lb.molecule_steps);
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.fpga_ops, lb.fpga_ops);
        assert_eq!(la.chip_inferences, 2 * 64_000);
    }

    #[test]
    fn single_molecule_farm_matches_water_system() {
        // The farm's datapath is the coordinator's datapath: one
        // molecule served by the batch kernel must track the
        // two-chip-in-parallel WaterSystem bit for bit.
        let m = toy_model();
        let systems = random_water_systems(1, 50.0, 7);
        let mut ws = WaterSystem::new(&m, 3, &systems[0], 0.25, ParallelMode::Inline).unwrap();
        let mut farm = WaterFarm::new(&m, &systems, &FarmConfig::default()).unwrap();
        for _ in 0..500 {
            ws.step().unwrap();
            farm.tick().unwrap();
        }
        assert_eq!(farm.positions().unwrap()[0], ws.positions());
    }

    #[test]
    fn ledger_accounts_lane_model() {
        let m = toy_model();
        let systems = random_water_systems(8, 100.0, 9);
        let run_with_lanes = |lanes: usize| -> FarmLedger {
            let mut farm = WaterFarm::new(
                &m,
                &systems,
                &FarmConfig { shards: 2, lanes, ..FarmConfig::default() },
            )
            .unwrap();
            farm.run(10).unwrap();
            farm.finish().unwrap()
        };
        let serial = run_with_lanes(1);
        let wide = run_with_lanes(8);
        assert_eq!(serial.molecule_steps, 80);
        assert_eq!(serial.chip_inferences, 160);
        // More lanes ⇒ strictly fewer modelled cycles (the MLP stage
        // compresses from 8 waves to 1 per shard tick).
        assert!(
            wide.modelled_cycles < serial.modelled_cycles,
            "lanes=8 cycles {} !< lanes=1 cycles {}",
            wide.modelled_cycles,
            serial.modelled_cycles
        );
        // Identical physics regardless of the lane model.
        assert_eq!(serial.chip_ops, wide.chip_ops);
        assert_eq!(serial.fpga_ops, wide.fpga_ops);
        // Cycle ledger is exactly ticks × Σ shard budgets (deterministic).
        assert_eq!(serial.modelled_cycles % serial.ticks, 0);
        assert!(serial.critical_path_cycles <= serial.modelled_cycles);
        assert!(serial.host_steps_per_second() > 0.0);
        let (fast, slow) = (
            wide.modelled_steps_per_second(CLOCK_HZ),
            serial.modelled_steps_per_second(CLOCK_HZ),
        );
        assert!(fast > slow, "lane model throughput {fast} !> {slow}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let m = toy_model();
        assert!(WaterFarm::new(&m, &[], &FarmConfig::default()).is_err());
        let systems = random_water_systems(2, 50.0, 1);
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 0, ..FarmConfig::default() }
        )
        .is_err());
        assert!(WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { lanes: 0, ..FarmConfig::default() }
        )
        .is_err());
        let mut bad = toy_model();
        bad.output_scale = 3.0; // not a power of two
        assert!(WaterFarm::new(&bad, &systems, &FarmConfig::default()).is_err());
    }

    #[test]
    fn shards_clamped_to_molecule_count() {
        let m = toy_model();
        let systems = random_water_systems(3, 50.0, 2);
        let mut farm = WaterFarm::new(
            &m,
            &systems,
            &FarmConfig { shards: 16, mode: ParallelMode::Threaded, ..FarmConfig::default() },
        )
        .unwrap();
        assert_eq!(farm.config().shards, 3, "config() must report the effective shard count");
        farm.run(5).unwrap();
        let l = farm.finish().unwrap();
        assert_eq!(l.shard_walls.len(), 3);
        assert_eq!(l.molecule_steps, 15);
    }
}
