//! The von-Neumann baseline driver ("vN-MLMD" in Tables II/III): the
//! *same* MLMD algorithm — features → MLP forces → Eq. (2)–(3)
//! integration — executed in floating point on the host, with the MLP
//! behind a pluggable evaluator so the same driver runs:
//!
//! * [`MlpForceModel`] — the float model evaluated in-process;
//! * `runtime::HloForceModel` — the AOT-lowered JAX graph executed via
//!   PJRT (the measured vN path of Table III);
//! * a DeePMD-style model (also via PJRT).

use anyhow::Result;

use crate::features;
use crate::md::{euler_step, ForceField, System};
use crate::nn::Mlp;
use crate::util::Vec3;

/// Something that maps the two hydrogens' feature triples to their
/// local-frame force coefficients.
pub trait HForceModel {
    fn eval(&mut self, feats: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]>;
    fn name(&self) -> String {
        "h-force-model".into()
    }
}

impl HForceModel for Box<dyn HForceModel> {
    fn eval(&mut self, feats: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]> {
        (**self).eval(feats)
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

/// In-process float MLP evaluator.
pub struct MlpForceModel {
    pub model: Mlp,
}

impl HForceModel for MlpForceModel {
    fn eval(&mut self, feats: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]> {
        let a = self.model.forward_physical(&feats[0]);
        let b = self.model.forward_physical(&feats[1]);
        Ok([[a[0], a[1]], [b[0], b[1]]])
    }
    fn name(&self) -> String {
        format!("mlp:{}", self.model.name)
    }
}

/// The vN-MLMD driver.
pub struct VnMlmd<M: HForceModel> {
    pub sys: System,
    pub model: M,
    pub dt: f64,
    pub steps_done: u64,
    /// Reusable integrator scratch: holds F(t) on entry to `euler_step`
    /// each step (§Perf: the step loop allocates nothing — an earlier
    /// version cloned this buffer every step).
    forces: Vec<Vec3>,
}

impl<M: HForceModel> VnMlmd<M> {
    pub fn new(sys: System, model: M, dt: f64) -> Self {
        assert_eq!(sys.len(), 3, "water driver expects [O, H1, H2]");
        VnMlmd { sys, model, dt, steps_done: 0, forces: vec![Vec3::ZERO; 3] }
    }

    /// Evaluate MLP forces for the current positions (features → model →
    /// local-frame reconstruction → Newton's third law).
    pub fn eval_forces(&mut self) -> Result<[Vec3; 3]> {
        let pos = &self.sys.pos;
        let feats = [features::water_features(pos, 1), features::water_features(pos, 2)];
        let c = self.model.eval(&feats)?;
        let f1 = features::water_force_from_local(pos, 1, c[0]);
        let f2 = features::water_force_from_local(pos, 2, c[1]);
        Ok([-(f1 + f2), f1, f2])
    }

    /// One MD step with the paper's Eq. (2)–(3) integrator.
    pub fn step(&mut self) -> Result<()> {
        let f = self.eval_forces()?;
        // semi-implicit Euler with externally supplied forces: reuse
        // euler_step against a wrapper field that replays `f`.
        struct Replay<'a>(&'a [Vec3; 3]);
        impl ForceField for Replay<'_> {
            fn compute(&self, _pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
                forces.copy_from_slice(self.0);
                0.0
            }
        }
        // euler_step consumes F(t) from the scratch buffer on entry.
        let replay = Replay(&f);
        self.forces.copy_from_slice(&f);
        euler_step(&mut self.sys, &replay, self.dt, &mut self.forces);
        self.steps_done += 1;
        Ok(())
    }

    pub fn run(&mut self, n: usize, stride: usize, mut tap: impl FnMut(&[Vec3])) -> Result<()> {
        for s in 0..n {
            self.step()?;
            if stride > 0 && s % stride == 0 {
                tap(&self.sys.pos);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::initialize_velocities;
    use crate::nn::Activation;
    use crate::potentials::WaterPes;
    use crate::util::rng::Pcg;

    /// Oracle evaluator: local-frame projection of the true PES forces —
    /// lets us test the driver's feature/frame plumbing exactly.
    struct OracleModel;
    impl HForceModel for OracleModel {
        fn eval(&mut self, _feats: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]> {
            unreachable!("OracleModel used via eval_with_pos tests only")
        }
    }

    #[test]
    fn driver_with_oracle_matches_direct_euler() {
        // Plug a model that inverts the local-frame encoding of the PES
        // forces: the driver trajectory must equal plain Euler on the PES.
        struct PesLocal {
            pos: Vec<Vec3>,
        }
        impl HForceModel for PesLocal {
            fn eval(&mut self, _f: &[[f64; 3]; 2]) -> Result<[[f64; 2]; 2]> {
                let pes = WaterPes::dft_surrogate();
                let mut fr = vec![Vec3::ZERO; 3];
                pes.compute(&self.pos, &mut fr);
                Ok([
                    features::water_force_to_local(&self.pos, 1, fr[1]),
                    features::water_force_to_local(&self.pos, 2, fr[2]),
                ])
            }
        }

        let pes = WaterPes::dft_surrogate();
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        let mut rng = Pcg::new(12);
        initialize_velocities(&mut sys, 200.0, 6, &mut rng);

        let dt = 0.25;
        let mut reference = sys.clone();
        let mut fbuf = vec![Vec3::ZERO; 3];
        pes.compute(&reference.pos, &mut fbuf);

        let mut driver = VnMlmd::new(sys, PesLocal { pos: Vec::new() }, dt);
        for _ in 0..500 {
            driver.model.pos = driver.sys.pos.clone();
            driver.step().unwrap();
            euler_step(&mut reference, pes, dt, &mut fbuf);
        }
        for i in 0..3 {
            let d = (driver.sys.pos[i] - reference.pos[i]).norm();
            assert!(d < 1e-9, "atom {i}: {d}");
        }
    }

    #[test]
    fn mlp_model_drives_without_blowup() {
        let mut rng = Pcg::new(3);
        let mut m = Mlp::init_random("t", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.2;
            }
        }
        let pes = WaterPes::dft_surrogate();
        let sys = System::new(pes.equilibrium(), WaterPes::masses());
        let mut driver = VnMlmd::new(sys, MlpForceModel { model: m }, 0.25);
        driver.run(1_000, 0, |_| {}).unwrap();
        for p in &driver.sys.pos {
            assert!(p.norm().is_finite());
        }
        assert_eq!(driver.steps_done, 1_000);
    }

    #[test]
    fn step_scratch_buffer_preserves_trajectory() {
        // Regression for the per-step `self.forces.clone()`: the
        // reusable scratch must leave the trajectory bit-identical to
        // the old clone-per-step implementation, replicated inline here
        // with a freshly allocated buffer every step.
        let mut rng = Pcg::new(8);
        let mut m = Mlp::init_random("t", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.25;
            }
        }
        let pes = WaterPes::dft_surrogate();
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        let mut vrng = Pcg::new(17);
        initialize_velocities(&mut sys, 150.0, 6, &mut vrng);

        let mut driver = VnMlmd::new(sys.clone(), MlpForceModel { model: m.clone() }, 0.25);
        let mut reference = VnMlmd::new(sys, MlpForceModel { model: m }, 0.25);
        struct Replay<'a>(&'a [Vec3; 3]);
        impl ForceField for Replay<'_> {
            fn compute(&self, _pos: &[Vec3], forces: &mut [Vec3]) -> f64 {
                forces.copy_from_slice(self.0);
                0.0
            }
        }
        for step in 0..500 {
            driver.step().unwrap();
            // the pre-fix algorithm, verbatim
            let f = reference.eval_forces().unwrap();
            let replay = Replay(&f);
            let mut buf = vec![Vec3::ZERO; 3];
            buf.copy_from_slice(&f);
            euler_step(&mut reference.sys, &replay, reference.dt, &mut buf);
            assert_eq!(driver.sys.pos, reference.sys.pos, "positions diverged at step {step}");
            assert_eq!(driver.sys.vel, reference.sys.vel, "velocities diverged at step {step}");
        }
        assert_eq!(driver.steps_done, 500);
    }

    #[test]
    fn forces_satisfy_newtons_third_law() {
        let mut rng = Pcg::new(4);
        let m = Mlp::init_random("t", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        let pes = WaterPes::dft_surrogate();
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        sys.pos[1] += Vec3::new(0.03, 0.01, -0.02);
        let mut driver = VnMlmd::new(sys, MlpForceModel { model: m }, 0.25);
        let f = driver.eval_forces().unwrap();
        let net = f[0] + f[1] + f[2];
        assert!(net.norm() < 1e-12, "net {net:?}");
    }
}
