//! The Layer-3 coordinator — the paper's heterogeneous parallel MLMD
//! computing system (Fig. 1 / §IV-C): a host (this process) orchestrating
//! one FPGA model (feature extraction + integration) and **two MLP ASIC
//! chips working in parallel**, one per hydrogen atom.
//!
//! The per-step workflow is exactly the paper's §IV-C:
//! 1. the FPGA computes the feature triples of both hydrogens;
//! 2. both feature sets go to the two MLP chips **simultaneously**, which
//!    predict the two hydrogen forces in parallel;
//! 3. forces return to the FPGA, the oxygen force follows from Newton's
//!    third law, and the integrator advances the positions.
//!
//! Two chip backends are provided: [`ParallelMode::Threaded`] runs each
//! chip simulator on its own worker thread (the architecture
//! demonstration — real concurrent devices with channel transport), and
//! [`ParallelMode::Inline`] calls them sequentially in-process (the fast
//! path for multi-million-step property runs; identical numerics). The
//! modelled hardware time is identical in both: the step's cycle cost
//! takes max(chip latencies), not their sum.

pub mod farm;
pub mod gateway;
pub mod pool;
pub mod vn;

pub use farm::{
    generic_group, generic_group_pbc, water_group, AdmitTicket, FarmConfig, FarmLedger,
    FarmSupervision, FarmTelemetry, HealthPolicy, MoleculeFarm, QuarantineReason, QuarantineRecord,
    RetiredMolecule, ServedMolecule, ShardLoss, SpeciesGroup, SpeciesLedger, WaterFarm,
};
pub use gateway::{
    Gateway, GatewayConfig, GatewaySpecies, LatencyHistogram, MoleculeBuilder, Outcome, Rejection,
    RequestId, RequestResult, RequestStatus, SloLedger, SpeciesSlo, Submission,
};
pub use pool::{PoolError, PoolShutdown, Reply, WorkerFault, WorkerPool};

use anyhow::Result;

use crate::asic::{ChipConfig, MlpChip};
use crate::fixedpoint::Q13;
use crate::fpga::WaterFpga;
use crate::hw::power::{self, OpCounts};
use crate::hw::timing::{StepCycles, CLOCK_HZ};
use crate::md::System;
use crate::nn::Mlp;
use crate::util::Vec3;
use pool::ChipPool;

/// Chip execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// Each chip on a dedicated worker thread (channel transport).
    Threaded,
    /// Chips invoked inline (same numerics, no thread hops).
    Inline,
}

/// Cycle/energy/utilization accounting of a run.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub md_steps: u64,
    /// Modelled hardware cycles (StepCycles budget; chip stage uses the
    /// *max* of the two parallel chips).
    pub modelled_cycles: u64,
    /// Host wall-clock spent in `step()` (simulation cost, not modelled
    /// hardware time).
    pub host_wall: std::time::Duration,
    pub chip_inferences: u64,
    /// Aggregated chip op counts (both chips).
    pub chip_ops: OpCounts,
    /// Aggregated FPGA op counts.
    pub fpga_ops: OpCounts,
}

impl Ledger {
    /// Modelled hardware seconds for the run.
    pub fn hw_seconds(&self, clock_hz: f64) -> f64 {
        self.modelled_cycles as f64 / clock_hz
    }
    /// The paper's S metric over this run (s/step/atom, 3 atoms).
    pub fn s_per_step_atom(&self, clock_hz: f64) -> f64 {
        if self.md_steps == 0 {
            return 0.0;
        }
        self.hw_seconds(clock_hz) / self.md_steps as f64 / 3.0
    }
    /// Modelled energy over the run (J): system power × modelled time
    /// (the paper's η = S×P uses measured power; see `hw::power`).
    pub fn energy_j(&self, clock_hz: f64) -> f64 {
        power::SYSTEM_POWER_W * self.hw_seconds(clock_hz)
    }
}

/// The heterogeneous water-MLMD system.
pub struct WaterSystem {
    pub fpga: WaterFpga,
    chips: ChipBackend,
    pub ledger: Ledger,
    step_cycles: StepCycles,
    pub clock_hz: f64,
    chip_latency: u64,
    /// Optional weak-coupling thermostat (T_target, dt/τ): a direct-force
    /// MLP is not exactly conservative, so long property runs heat from
    /// quantization/model noise; the host control plane rescales the FPGA
    /// velocity state every [`THERMOSTAT_STRIDE`] steps (the same
    /// protocol the float drivers use). See DESIGN.md §Numerics.
    pub thermostat: Option<(f64, f64)>,
    masses: Vec<f64>,
    /// Accumulated wall-clock of the sampled steps (see `step`).
    wall_sampled: std::time::Duration,
    /// How many steps were actually timed.
    wall_samples: u64,
}

/// Steps between control-plane thermostat interventions.
pub const THERMOSTAT_STRIDE: u64 = 16;

/// Steps between host wall-clock samples (§Perf: an `Instant` pair per
/// step costs ~12% of the inline path). Deliberately coprime to
/// [`THERMOSTAT_STRIDE`]: a power-of-two stride would phase-lock the
/// samples against the thermostat ticks and the extrapolation would
/// never see (or always see) their cost; 63 = 3²·7 walks every residue
/// mod 16, so thermostat steps are sampled in proportion.
const WALL_SAMPLE_STRIDE: u64 = 63;

enum ChipBackend {
    Threaded(ChipPool),
    Inline(Vec<MlpChip>),
}

/// Validate a water model for the shift datapath (3→…→2 shape,
/// power-of-two output scale) and return the force shift the FPGA must
/// undo at reconstruction. Shared by [`WaterSystem`] and the farm so
/// the two serving paths can never diverge on the protocol.
fn validate_water_model(model: &Mlp) -> Result<i32> {
    anyhow::ensure!(model.in_dim() == 3 && model.out_dim() == 2, "water model must be 3→…→2");
    // The model predicts F / output_scale; the FPGA undoes that with a
    // free power-of-two shift at reconstruction.
    model.force_shift()
}

/// Program an FPGA's force-rescale and feature-conditioning stages from
/// a validated water model (the host-CPU initialization path, Fig. 1).
fn program_water_fpga(fpga: &mut WaterFpga, model: &Mlp, force_shift: i32) -> Result<()> {
    fpga.force_shift = force_shift;
    fpga.program_feature_conditioning(&model.feature_center, &model.feature_scale)
}

impl WaterSystem {
    /// Build and program the system: the host-CPU initialization path
    /// (Fig. 1) — load the trained model into both chips' distributed
    /// memories and the initial state into the FPGA.
    pub fn new(model: &Mlp, k: usize, sys: &System, dt_fs: f64, mode: ParallelMode) -> Result<Self> {
        let force_shift = validate_water_model(model)?;
        let mut chips: Vec<MlpChip> = (0..2)
            .map(|id| {
                let mut c = MlpChip::new(id, ChipConfig::default());
                c.program(model, k);
                c
            })
            .collect();
        let chip_latency = chips[0].latency_cycles();
        let mut fpga = WaterFpga::new(sys, dt_fs);
        program_water_fpga(&mut fpga, model, force_shift)?;
        let mut cycles = StepCycles::water();
        // The MLP stage of the budget is the *actual* programmed-network
        // latency (the nominal budget assumes the water arch).
        cycles.mlp = chip_latency;
        let backend = match mode {
            ParallelMode::Threaded => {
                ChipBackend::Threaded(ChipPool::spawn(chips.drain(..).collect())?)
            }
            ParallelMode::Inline => ChipBackend::Inline(chips),
        };
        Ok(WaterSystem {
            fpga,
            chips: backend,
            ledger: Ledger::default(),
            step_cycles: cycles,
            clock_hz: CLOCK_HZ,
            chip_latency,
            thermostat: None,
            masses: sys.masses.clone(),
            wall_sampled: std::time::Duration::ZERO,
            wall_samples: 0,
        })
    }

    /// Control-plane thermostat tick (host CPU): Berendsen λ from the
    /// decoded velocity state, applied as a fixed-point rescale.
    fn thermostat_tick(&mut self) {
        let Some((t_target, dt_over_tau)) = self.thermostat else {
            return;
        };
        let vels = self.fpga.velocities();
        let ke: f64 = vels
            .iter()
            .zip(&self.masses)
            .map(|(v, m)| 0.5 * m * v.norm_sq())
            .sum::<f64>()
            / crate::util::units::ACC_CONV;
        let t_now = 2.0 * ke / (6.0 * crate::util::units::KB);
        if t_now <= 1e-9 {
            return;
        }
        let coupling = dt_over_tau * THERMOSTAT_STRIDE as f64;
        let lambda = (1.0 + coupling * (t_target / t_now - 1.0)).max(0.0).sqrt();
        self.fpga.scale_velocities(lambda);
    }

    /// One MD step through the full heterogeneous pipeline.
    ///
    /// §Perf: host wall-clock is sampled every [`WALL_SAMPLE_STRIDE`]
    /// steps (a stride coprime to the thermostat's, so control-plane
    /// cost is sampled in proportion) and `Ledger::host_wall`
    /// extrapolated by the **actual** sample coverage
    /// (`samples / md_steps`), not a fixed ×stride — the old
    /// extrapolation over-counted runs whose length is not a stride
    /// multiple. Sampling starts at the *second* step so the cold
    /// first step (cache warmup, lazy page faults) never skews the
    /// estimate; runs shorter than two steps report zero host_wall.
    pub fn step(&mut self) -> Result<()> {
        let sample_wall = self.ledger.md_steps % WALL_SAMPLE_STRIDE == 1;
        let t0 = if sample_wall { Some(std::time::Instant::now()) } else { None };
        // (1) FPGA feature extraction.
        let frames = self.fpga.extract_features();
        let f0: [Q13; 3] = frames[0].d;
        let f1: [Q13; 3] = frames[1].d;

        // (2) two chips in parallel.
        let mut c = [[Q13::ZERO; 2]; 2];
        match &mut self.chips {
            ChipBackend::Threaded(pool) => {
                let res = pool.infer_pair(f0.to_vec(), f1.to_vec())?;
                anyhow::ensure!(res.0.len() == 2 && res.1.len() == 2, "chip output width");
                c[0] = [res.0[0], res.0[1]];
                c[1] = [res.1[0], res.1[1]];
            }
            ChipBackend::Inline(chips) => {
                // §Perf: allocation-free inline path.
                chips[0].infer_into(&f0, &mut c[0])?;
                chips[1].infer_into(&f1, &mut c[1])?;
            }
        }

        // (3) forces back to FPGA: N3L + integration.
        self.fpga.integrate(&frames, c);

        // Ledger.
        self.ledger.md_steps += 1;
        self.ledger.chip_inferences += 2;
        self.ledger.modelled_cycles += self.step_cycles.total();
        if self.thermostat.is_some() && self.ledger.md_steps % THERMOSTAT_STRIDE == 0 {
            self.thermostat_tick();
        }
        if let Some(t0) = t0 {
            self.wall_sampled += t0.elapsed();
            self.wall_samples += 1;
            self.refresh_host_wall();
        }
        Ok(())
    }

    /// Extrapolate `host_wall` from the sampled steps by their actual
    /// coverage of the run so far.
    fn refresh_host_wall(&mut self) {
        if self.wall_samples > 0 {
            self.ledger.host_wall = self
                .wall_sampled
                .mul_f64(self.ledger.md_steps as f64 / self.wall_samples as f64);
        }
    }

    /// Run `n` steps, invoking `tap` with the decoded positions every
    /// `stride` steps (0 = never).
    pub fn run(&mut self, n: usize, stride: usize, mut tap: impl FnMut(&[Vec3])) -> Result<()> {
        for s in 0..n {
            self.step()?;
            if stride > 0 && s % stride == 0 {
                tap(&self.fpga.positions());
            }
        }
        Ok(())
    }

    pub fn positions(&self) -> Vec<Vec3> {
        self.fpga.positions()
    }

    /// Collect final counters (draining worker-thread stats into the
    /// ledger) and return the ledger.
    pub fn finish(mut self) -> Result<Ledger> {
        self.refresh_host_wall();
        let (infs, _cycles, ops) = match &mut self.chips {
            ChipBackend::Threaded(pool) => pool.stats()?,
            ChipBackend::Inline(chips) => {
                let mut ops = OpCounts::default();
                let mut infs = 0;
                let mut cyc = 0;
                for c in chips.iter() {
                    ops.merge(&c.ops);
                    infs += c.inferences;
                    cyc += c.total_cycles;
                }
                (infs, cyc, ops)
            }
        };
        self.ledger.chip_ops = ops;
        self.ledger.fpga_ops = self.fpga.ops;
        debug_assert_eq!(infs, self.ledger.chip_inferences);
        Ok(self.ledger)
    }

    pub fn chip_latency_cycles(&self) -> u64 {
        self.chip_latency
    }
    pub fn step_cycle_budget(&self) -> StepCycles {
        self.step_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::WaterSeries;
    use crate::md::initialize_velocities;
    use crate::nn::Activation;
    use crate::potentials::WaterPes;
    use crate::util::rng::Pcg;

    /// A hand-made water model good enough for smoke tests (real accuracy
    /// comes from the trained artifact; these tests check plumbing, not
    /// physics).
    fn toy_model() -> Mlp {
        let mut rng = Pcg::new(77);
        let mut m = Mlp::init_random("toy-water", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.3;
            }
        }
        m
    }

    fn initial_system(seed: u64) -> System {
        let pes = WaterPes::dft_surrogate();
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        let mut rng = Pcg::new(seed);
        initialize_velocities(&mut sys, 50.0, 6, &mut rng);
        sys
    }

    #[test]
    fn threaded_and_inline_are_bit_identical() {
        let m = toy_model();
        let sys = initial_system(1);
        let mut a = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Threaded).unwrap();
        let mut b = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
        for _ in 0..300 {
            a.step().unwrap();
            b.step().unwrap();
        }
        let pa = a.positions();
        let pb = b.positions();
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x, y, "threaded vs inline positions must be bit-identical");
        }
        let la = a.finish().unwrap();
        let lb = b.finish().unwrap();
        assert_eq!(la.chip_inferences, lb.chip_inferences);
        assert_eq!(la.chip_ops, lb.chip_ops);
        assert_eq!(la.modelled_cycles, lb.modelled_cycles);
    }

    #[test]
    fn ledger_matches_budget() {
        let m = toy_model();
        let sys = initial_system(2);
        let mut s = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
        let budget = s.step_cycle_budget().total();
        for _ in 0..100 {
            s.step().unwrap();
        }
        let l = s.finish().unwrap();
        assert_eq!(l.md_steps, 100);
        assert_eq!(l.modelled_cycles, 100 * budget);
        assert_eq!(l.chip_inferences, 200);
        // S close to paper (budget calibrated in hw::timing)
        let sps = l.s_per_step_atom(CLOCK_HZ);
        assert!((sps - 1.6e-6).abs() / 1.6e-6 < 0.1, "S = {sps:e}");
    }

    #[test]
    fn trajectory_stays_bounded_with_toy_model() {
        // Plumbing test: even an untrained model saturates at ±1 force
        // coefficients; the fixed-point system must stay finite/bounded.
        let m = toy_model();
        let sys = initial_system(3);
        let mut s = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
        let mut series = WaterSeries::default();
        s.run(2_000, 10, |pos| series.push(pos)).unwrap();
        assert_eq!(series.len(), 200);
        for p in s.positions() {
            // state registers saturate at ±32 Å per axis; an untrained
            // model may drift right up to the rails but must stay finite
            assert!(p.norm() <= 32.0 * 1.8, "position escaped: {p:?}");
            assert!(p.norm().is_finite());
        }
    }

    #[test]
    fn host_wall_scales_by_actual_coverage() {
        // Regression for the sampling bias: host_wall must extrapolate
        // by the real samples-to-steps ratio, not a fixed ×stride (the
        // old version reported Σsampled × 64 regardless of run length).
        // The extrapolation arithmetic is pinned deterministically
        // (wall-clock magnitudes are too jittery for CI assertions):
        // mean(sampled) × md_steps, exactly.
        let m = toy_model();
        let sys = initial_system(11);
        let mut s = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
        s.ledger.md_steps = 100;
        s.wall_sampled = std::time::Duration::from_micros(10);
        s.wall_samples = 2;
        s.refresh_host_wall();
        // 10 µs over 2 samples ⇒ 5 µs/step × 100 steps.
        assert_eq!(s.ledger.host_wall, std::time::Duration::from_micros(500));

        // End-to-end: a real 100-step run samples the warm steps
        // (indices 1 and 64) and must report a nonzero wall…
        let run = |steps: usize| -> std::time::Duration {
            let sys = initial_system(11);
            let mut s = WaterSystem::new(&m, 3, &sys, 0.25, ParallelMode::Inline).unwrap();
            for _ in 0..steps {
                s.step().unwrap();
            }
            s.finish().unwrap().host_wall
        };
        assert!(run(100) > std::time::Duration::ZERO);
        // …while a 1-step run has no warm sample and must not invent one.
        assert_eq!(run(1), std::time::Duration::ZERO);
    }

    #[test]
    fn rejects_wrong_model_shape() {
        let mut rng = Pcg::new(1);
        let bad = Mlp::init_random("bad", &[4, 3, 3], Activation::Phi, &mut rng);
        let sys = initial_system(4);
        assert!(WaterSystem::new(&bad, 3, &sys, 0.25, ParallelMode::Inline).is_err());
    }
}
