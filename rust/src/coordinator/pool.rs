//! Worker-thread pools with channel transport — the concurrent-device
//! half of the coordinator.
//!
//! [`WorkerPool`] is the one transport: each worker thread owns one item
//! and runs shipped closures against it. Every shipped job runs under
//! `catch_unwind`, so a panicking job does **not** kill its worker
//! thread: the thread stays alive for later jobs, the submitter gets a
//! typed [`PoolError::JobPanicked`], and the fault is tallied in the
//! worker's [`WorkerFault`] record (returned by [`WorkerPool::into_items`],
//! which never panics or deadlocks even when a worker died). [`ChipPool`]
//! — the ASIC-chip pool used by the paper's two-chip step, the serving
//! example, and the Fig. 9 evaluation — is a thin routing layer
//! (round-robin dispatch, pair dispatch, stats aggregation) over a
//! `WorkerPool<MlpChip>`.
//!
//! The transport carries more than tick jobs: the farm ships whole
//! epochs (`FarmShard::run_ticks`), the gateway ships membership churn
//! (admit/retire closures between epochs) and state queries (frozen
//! positions, quarantine records) over the same `submit`/`recv` pair —
//! one mechanism, one fault model.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::asic::MlpChip;
use crate::fixedpoint::Q13;
use crate::hw::power::OpCounts;

/// Typed pool faults. Implements `std::error::Error`, so `?` lifts it
/// into `anyhow` at the coordinator seam.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// The worker index is out of range for this pool.
    NoSuchWorker { worker: usize },
    /// The worker's job channel is closed (its thread exited), so the
    /// job could not be shipped.
    WorkerGone { worker: usize },
    /// The shipped job panicked on the worker; the worker survived and
    /// keeps serving later jobs.
    JobPanicked { worker: usize, message: String },
    /// The reply channel closed without a result being sent.
    ReplyLost { worker: usize },
    /// The OS refused to spawn the worker thread.
    SpawnFailed { worker: usize, message: String },
}

impl core::fmt::Display for PoolError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            PoolError::NoSuchWorker { worker } => write!(f, "no pool worker {worker}"),
            PoolError::WorkerGone { worker } => {
                write!(f, "pool worker {worker} is gone (job channel closed)")
            }
            PoolError::JobPanicked { worker, message } => {
                write!(f, "pool worker {worker}: job panicked: {message}")
            }
            PoolError::ReplyLost { worker } => {
                write!(f, "pool worker {worker}: reply channel dropped without a result")
            }
            PoolError::SpawnFailed { worker, message } => {
                write!(f, "spawning pool worker {worker} failed: {message}")
            }
        }
    }
}

impl std::error::Error for PoolError {}

/// Render a `catch_unwind`/`JoinHandle::join` panic payload as text.
pub(crate) fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// What a shipped job reports back to its worker's loop after running
/// under `catch_unwind`.
enum JobFlow {
    /// Job completed (reply sent, or deliberately dropped by injection).
    Done,
    /// Job panicked; the payload message rides along for the tally.
    Panicked(String),
    /// Injected worker death: leave the loop without replying.
    Exit,
}

/// A job shipped to a pool worker: runs against the worker's owned item.
type PoolJob<T> = Box<dyn FnOnce(&mut T) -> JobFlow + Send>;

/// Per-worker fault tally kept by the worker thread itself.
#[derive(Debug, Clone, Default)]
struct Tally {
    jobs_panicked: u64,
    first_panic: Option<String>,
}

/// Per-worker fault record returned by [`WorkerPool::into_items`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WorkerFault {
    pub worker: usize,
    /// Jobs that panicked on this worker (each was caught; the worker
    /// survived them all unless `died` is set).
    pub jobs_panicked: u64,
    /// First panic message seen on this worker (job or thread death).
    pub first_panic: Option<String>,
    /// The worker thread itself terminated by panic (outside any job;
    /// join failed). Its item is lost.
    pub died: bool,
}

/// Items plus fault records handed back by [`WorkerPool::into_items`]:
/// `items[i]` is `None` exactly when worker *i*'s thread died and its
/// item was lost with it.
#[derive(Debug)]
pub struct PoolShutdown<T> {
    pub items: Vec<Option<T>>,
    pub faults: Vec<WorkerFault>,
}

impl<T> PoolShutdown<T> {
    /// Total jobs that panicked (and were recovered) across all workers.
    pub fn jobs_panicked(&self) -> u64 {
        self.faults.iter().map(|f| f.jobs_panicked).sum()
    }

    /// Items of the workers that survived, in worker order — the healthy
    /// path, where every slot is `Some`.
    pub fn surviving_items(self) -> Vec<T> {
        self.items.into_iter().flatten().collect()
    }
}

/// In-flight reply of a submitted job. `recv` maps the transport
/// outcomes onto typed [`PoolError`]s: a panicking job surfaces as
/// [`PoolError::JobPanicked`] (the wrapper forwards the payload message
/// before returning), and a dropped channel as [`PoolError::ReplyLost`].
pub struct Reply<R> {
    rrx: mpsc::Receiver<Result<R, String>>,
    worker: usize,
}

impl<R> Reply<R> {
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Block for the job's result.
    pub fn recv(self) -> Result<R, PoolError> {
        match self.rrx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(message)) => Err(PoolError::JobPanicked { worker: self.worker, message }),
            Err(mpsc::RecvError) => Err(PoolError::ReplyLost { worker: self.worker }),
        }
    }
}

/// One-shot fault injections armed per worker, consumed by the next
/// `submit` to that worker (deterministic: no timing involved).
#[cfg(any(test, feature = "faults"))]
#[derive(Debug, Clone, Copy, Default)]
struct Injection {
    drop_next_reply: bool,
    exit_on_next_job: bool,
}

/// Generic worker pool: each thread owns one `T` (a chip simulator, a
/// molecule-farm shard) and runs shipped closures against it. This is
/// the transport layer shared by the farm's threaded shard backend and
/// [`ChipPool`]. Dropping the pool (or calling [`Self::into_items`])
/// closes the job channels and joins every worker; neither panics nor
/// deadlocks when a worker died.
pub struct WorkerPool<T: Send + 'static> {
    txs: Vec<mpsc::Sender<PoolJob<T>>>,
    handles: Vec<JoinHandle<(T, Tally)>>,
    #[cfg(any(test, feature = "faults"))]
    inject: std::sync::Mutex<Vec<Injection>>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn one worker thread per item; threads are named `{name}-{i}`.
    ///
    /// On a spawn failure the already-started workers are abandoned to
    /// their channels closing (they exit cleanly) and the error names
    /// the worker that could not start.
    pub fn spawn(name: &str, items: Vec<T>) -> Result<WorkerPool<T>, PoolError> {
        let n = items.len();
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (i, mut item) in items.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PoolJob<T>>();
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || {
                    let mut tally = Tally::default();
                    while let Ok(job) = rx.recv() {
                        match job(&mut item) {
                            JobFlow::Done => {}
                            JobFlow::Panicked(message) => {
                                tally.jobs_panicked += 1;
                                tally.first_panic.get_or_insert(message);
                            }
                            JobFlow::Exit => break,
                        }
                    }
                    (item, tally)
                })
                .map_err(|e| PoolError::SpawnFailed { worker: i, message: e.to_string() })?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(WorkerPool {
            txs,
            handles,
            #[cfg(any(test, feature = "faults"))]
            inject: std::sync::Mutex::new(vec![Injection::default(); n]),
        })
    }

    pub fn len(&self) -> usize {
        self.txs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.txs.is_empty()
    }

    /// Arm a one-shot injection: the next job submitted to `worker`
    /// runs, but its reply is dropped unsent (the submitter sees
    /// [`PoolError::ReplyLost`]).
    #[cfg(any(test, feature = "faults"))]
    pub fn inject_reply_drop(&self, worker: usize) {
        if let Some(slot) = self.inject.lock().unwrap().get_mut(worker) {
            slot.drop_next_reply = true;
        }
    }

    /// Arm a one-shot injection: the next job submitted to `worker`
    /// kills the worker loop instead of running (the submitter sees
    /// [`PoolError::ReplyLost`]; later submits see
    /// [`PoolError::WorkerGone`] once the channel closes).
    #[cfg(any(test, feature = "faults"))]
    pub fn inject_worker_exit(&self, worker: usize) {
        if let Some(slot) = self.inject.lock().unwrap().get_mut(worker) {
            slot.exit_on_next_job = true;
        }
    }

    /// Ship `f` to worker `i` and return the in-flight [`Reply`]
    /// (asynchronous: the caller decides when to block, so several
    /// workers can be kept in flight concurrently). The job runs under
    /// `catch_unwind` on the worker: a panic inside `f` is caught,
    /// tallied, forwarded to the reply as [`PoolError::JobPanicked`],
    /// and the worker thread survives to serve later jobs.
    pub fn submit<R, F>(&self, i: usize, f: F) -> Result<Reply<R>, PoolError>
    where
        R: Send + 'static,
        F: FnOnce(usize, &mut T) -> R + Send + 'static,
    {
        let tx = self.txs.get(i).ok_or(PoolError::NoSuchWorker { worker: i })?;
        #[cfg(any(test, feature = "faults"))]
        let injection = {
            let mut guard = self.inject.lock().unwrap();
            std::mem::take(&mut guard[i])
        };
        let (rtx, rrx) = mpsc::channel::<Result<R, String>>();
        let job: PoolJob<T> = Box::new(move |item: &mut T| {
            #[cfg(any(test, feature = "faults"))]
            if injection.exit_on_next_job {
                return JobFlow::Exit;
            }
            match catch_unwind(AssertUnwindSafe(|| f(i, item))) {
                Ok(r) => {
                    #[cfg(any(test, feature = "faults"))]
                    if injection.drop_next_reply {
                        return JobFlow::Done;
                    }
                    let _ = rtx.send(Ok(r));
                    JobFlow::Done
                }
                Err(payload) => {
                    let message = panic_message(payload.as_ref());
                    let _ = rtx.send(Err(message.clone()));
                    JobFlow::Panicked(message)
                }
            }
        });
        tx.send(job).map_err(|_| PoolError::WorkerGone { worker: i })?;
        Ok(Reply { rrx, worker: i })
    }

    /// Run `f` on every worker's item **concurrently** and collect the
    /// results in worker order (a full barrier: every reply is drained
    /// before returning, even on error, so no job is abandoned
    /// in-flight; the first fault is returned).
    pub fn run_all<R, F>(&self, f: F) -> Result<Vec<R>, PoolError>
    where
        R: Send + 'static,
        F: Fn(usize, &mut T) -> R + Clone + Send + 'static,
    {
        let mut replies = Vec::with_capacity(self.txs.len());
        for i in 0..self.txs.len() {
            replies.push(self.submit(i, f.clone())?);
        }
        let mut out = Vec::with_capacity(replies.len());
        let mut first_err: Option<PoolError> = None;
        for reply in replies {
            match reply.recv() {
                Ok(r) => out.push(r),
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some(e) => Err(e),
        }
    }

    /// Ship `f` to a chosen subset of workers **concurrently** and
    /// collect per-worker outcomes in the given order. Unlike
    /// [`Self::run_all`] this never aborts early and never collapses the
    /// batch to one error: every submitted reply is drained and each
    /// slot carries its own `Result`, so a caller serving a
    /// partially-dead fleet (the degraded-mode farm) can query the live
    /// workers and substitute its own fallback for each dead one.
    pub fn run_on<R, F>(
        &self,
        workers: &[usize],
        f: F,
    ) -> Vec<(usize, Result<R, PoolError>)>
    where
        R: Send + 'static,
        F: Fn(usize, &mut T) -> R + Clone + Send + 'static,
    {
        let replies: Vec<(usize, Result<Reply<R>, PoolError>)> = workers
            .iter()
            .map(|&i| (i, self.submit(i, f.clone())))
            .collect();
        replies
            .into_iter()
            .map(|(i, r)| (i, r.and_then(Reply::recv)))
            .collect()
    }

    /// Shut the pool down and hand back what survived, plus per-worker
    /// fault records. Never panics and never deadlocks: a dead worker
    /// yields `items[i] == None` with `faults[i].died` set instead of
    /// propagating its panic.
    pub fn into_items(mut self) -> PoolShutdown<T> {
        self.txs.clear(); // closes every channel; workers fall out of recv()
        let handles = std::mem::take(&mut self.handles);
        let mut items = Vec::with_capacity(handles.len());
        let mut faults = Vec::with_capacity(handles.len());
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok((item, tally)) => {
                    faults.push(WorkerFault {
                        worker: i,
                        jobs_panicked: tally.jobs_panicked,
                        first_panic: tally.first_panic,
                        died: false,
                    });
                    items.push(Some(item));
                }
                Err(payload) => {
                    faults.push(WorkerFault {
                        worker: i,
                        jobs_panicked: 0,
                        first_panic: Some(panic_message(payload.as_ref())),
                        died: true,
                    });
                    items.push(None);
                }
            }
        }
        PoolShutdown { items, faults }
    }
}

impl<T: Send + 'static> Drop for WorkerPool<T> {
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join(); // dead worker: swallow the payload, keep joining
        }
    }
}

/// A pool of chip workers, one thread per chip, on the [`WorkerPool`]
/// transport: pair dispatch for the paper's two-hydrogen step,
/// round-robin batch service, and counter aggregation.
pub struct ChipPool {
    pool: WorkerPool<MlpChip>,
    next: usize,
    /// Input width of the programmed network, captured at spawn so
    /// batch rows are validated *before* any job ships (a bad row must
    /// not abandon in-flight work or desync the cursor).
    in_dim: Option<usize>,
}

impl ChipPool {
    /// Spawn one worker thread per chip.
    pub fn spawn(chips: Vec<MlpChip>) -> Result<ChipPool, PoolError> {
        let in_dim = chips.iter().find_map(|c| c.network().map(|n| n.in_dim()));
        Ok(ChipPool { pool: WorkerPool::spawn("mlp-chip", chips)?, next: 0, in_dim })
    }

    pub fn len(&self) -> usize {
        self.pool.len()
    }
    pub fn is_empty(&self) -> bool {
        self.pool.is_empty()
    }

    /// Kill one chip worker (one-shot, consumed by the next dispatch
    /// that routes a job to it) — drives the dead-chip recovery tests.
    #[cfg(any(test, feature = "faults"))]
    pub fn inject_chip_death(&self, chip: usize) {
        self.pool.inject_worker_exit(chip);
    }

    /// Dispatch two inferences to the first two chips *concurrently* and
    /// wait for both — the paper's two-hydrogen parallel step. Width
    /// errors are raised before either job ships.
    pub fn infer_pair(&mut self, a: Vec<Q13>, b: Vec<Q13>) -> Result<(Vec<Q13>, Vec<Q13>)> {
        anyhow::ensure!(self.pool.len() >= 2, "need ≥2 chips");
        if let Some(d) = self.in_dim {
            anyhow::ensure!(a.len() == d, "input a: {} features, chip expects {d}", a.len());
            anyhow::ensure!(b.len() == d, "input b: {} features, chip expects {d}", b.len());
        }
        let ra = self.pool.submit(0, move |_, chip: &mut MlpChip| chip.infer(&a))?;
        let rb = self.pool.submit(1, move |_, chip: &mut MlpChip| chip.infer(&b))?;
        // Drain both replies before erroring so neither job is abandoned.
        let ya = ra.recv();
        let yb = rb.recv();
        Ok((ya??, yb??))
    }

    /// Batch inference service: round-robin the rows over all chips
    /// (every row in flight at once), results returned in input order.
    ///
    /// Row widths are validated **up front**: a bad row fails the whole
    /// batch before any job is submitted, leaving the round-robin
    /// cursor and every chip's counters untouched.
    pub fn infer_batch(&mut self, rows: &[Vec<Q13>]) -> Result<Vec<Vec<Q13>>> {
        let n = self.pool.len();
        anyhow::ensure!(n > 0, "empty pool");
        if let Some(d) = self.in_dim {
            for (i, row) in rows.iter().enumerate() {
                anyhow::ensure!(
                    row.len() == d,
                    "batch row {i}: {} features, chip expects {d}",
                    row.len()
                );
            }
        }
        let mut pending = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let w = (self.next + i) % n;
            let row = row.clone();
            pending.push(self.pool.submit(w, move |_, chip: &mut MlpChip| chip.infer(&row))?);
        }
        self.next = (self.next + rows.len()) % n;
        // Drain every reply before surfacing the first fault, so an
        // early error never abandons later jobs in flight.
        let mut out = vec![Vec::new(); rows.len()];
        let mut first_err: Option<anyhow::Error> = None;
        for (i, reply) in pending.into_iter().enumerate() {
            match reply.recv() {
                Ok(Ok(y)) => out[i] = y,
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(e) => {
                    first_err.get_or_insert(e.into());
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some(e) => Err(e),
        }
    }

    /// Aggregate counters across all chips.
    pub fn stats(&mut self) -> Result<(u64, u64, OpCounts)> {
        let per_chip = self
            .pool
            .run_all(|_, c: &mut MlpChip| (c.inferences, c.total_cycles, c.ops))?;
        let mut total = (0u64, 0u64, OpCounts::default());
        for (i, c, o) in per_chip {
            total.0 += i;
            total.1 += c;
            total.2.merge(&o);
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::ChipConfig;
    use crate::nn::{Activation, Mlp};
    use crate::util::rng::Pcg;

    fn pool_of(n: usize) -> (ChipPool, Mlp) {
        let mut rng = Pcg::new(8);
        let mut m = Mlp::init_random("p", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.5;
            }
        }
        let chips = (0..n)
            .map(|id| {
                let mut c = MlpChip::new(id, ChipConfig::default());
                c.program(&m, 3);
                c
            })
            .collect();
        (ChipPool::spawn(chips).unwrap(), m)
    }

    #[test]
    fn pair_matches_direct_inference() {
        let (mut pool, m) = pool_of(2);
        let net = crate::nn::Sqnn::from_mlp(&m, 3);
        let a: Vec<Q13> = [0.9, 0.6, 1.0].iter().map(|&x| Q13::from_f64(x)).collect();
        let b: Vec<Q13> = [1.1, 0.7, 0.95].iter().map(|&x| Q13::from_f64(x)).collect();
        let (ya, yb) = pool.infer_pair(a.clone(), b.clone()).unwrap();
        assert_eq!(ya, net.forward_q13(&a));
        assert_eq!(yb, net.forward_q13(&b));
    }

    #[test]
    fn batch_preserves_order_across_chips() {
        let (mut pool, m) = pool_of(3);
        let net = crate::nn::Sqnn::from_mlp(&m, 3);
        let mut rng = Pcg::new(4);
        let rows: Vec<Vec<Q13>> = (0..50)
            .map(|_| (0..3).map(|_| Q13::from_f64(rng.range(-1.0, 1.5))).collect())
            .collect();
        let out = pool.infer_batch(&rows).unwrap();
        assert_eq!(out.len(), 50);
        for (row, y) in rows.iter().zip(&out) {
            assert_eq!(*y, net.forward_q13(row));
        }
    }

    #[test]
    fn stats_aggregate_all_work() {
        let (mut pool, _m) = pool_of(2);
        let rows: Vec<Vec<Q13>> = (0..20).map(|_| vec![Q13::ZERO; 3]).collect();
        pool.infer_batch(&rows).unwrap();
        let (inferences, cycles, ops) = pool.stats().unwrap();
        assert_eq!(inferences, 20);
        assert!(cycles > 0);
        assert!(ops.adds > 0);
    }

    #[test]
    fn round_robin_spreads_work_across_calls() {
        // The `next` cursor must persist between batch calls so repeated
        // small batches don't pile onto chip 0 (the routing semantics of
        // the pre-WorkerPool protocol, preserved).
        let (mut pool, _m) = pool_of(3);
        for _ in 0..3 {
            pool.infer_batch(&[vec![Q13::ZERO; 3]]).unwrap();
        }
        // 3 single-row batches over 3 chips: every chip served exactly 1.
        let per_chip = pool
            .pool
            .run_all(|_, c: &mut MlpChip| c.inferences)
            .unwrap();
        assert_eq!(per_chip, vec![1, 1, 1]);
    }

    #[test]
    fn bad_input_width_propagates_error() {
        let (mut pool, _m) = pool_of(2);
        let err = pool.infer_pair(vec![Q13::ZERO; 2], vec![Q13::ZERO; 3]);
        assert!(err.is_err());
        // pool still alive afterwards
        let ok = pool.infer_pair(vec![Q13::ZERO; 3], vec![Q13::ZERO; 3]);
        assert!(ok.is_ok());
    }

    #[test]
    fn bad_batch_row_fails_up_front_and_leaves_cursor_and_stats_untouched() {
        let (mut pool, _m) = pool_of(3);
        // Seed the cursor off zero with one good single-row batch.
        pool.infer_batch(&[vec![Q13::ZERO; 3]]).unwrap();
        // A batch with a bad row in the *middle* must reject the whole
        // batch before submitting anything.
        let rows = vec![vec![Q13::ZERO; 3], vec![Q13::ZERO; 7], vec![Q13::ZERO; 3]];
        assert!(pool.infer_batch(&rows).is_err());
        let (inferences, _, _) = pool.stats().unwrap();
        assert_eq!(inferences, 1, "rejected batch must not run any rows");
        // Cursor still at 1: the next two single-row batches land on
        // chips 1 and 2, giving each chip exactly one inference.
        for _ in 0..2 {
            pool.infer_batch(&[vec![Q13::ZERO; 3]]).unwrap();
        }
        let per_chip = pool.pool.run_all(|_, c: &mut MlpChip| c.inferences).unwrap();
        assert_eq!(per_chip, vec![1, 1, 1], "cursor desynced by rejected batch");
    }

    #[test]
    fn chip_pool_survives_a_dead_chip_with_typed_errors() {
        let (mut pool, _m) = pool_of(2);
        pool.inject_chip_death(1);
        // Pair dispatch routes to chips 0 and 1; chip 1 dies without a
        // reply → typed error, no hang.
        let err = pool
            .infer_pair(vec![Q13::ZERO; 3], vec![Q13::ZERO; 3])
            .unwrap_err();
        let pool_err = err.downcast_ref::<PoolError>().expect("typed PoolError");
        assert!(matches!(pool_err, PoolError::ReplyLost { worker: 1 }));
        // Later batches that route a row to the dead chip fail fast with
        // WorkerGone — still typed, still no hang, chip 0 keeps serving.
        let err = pool
            .infer_batch(&[vec![Q13::ZERO; 3], vec![Q13::ZERO; 3]])
            .unwrap_err();
        assert!(err.downcast_ref::<PoolError>().is_some());
    }

    #[test]
    fn drop_joins_workers() {
        let (pool, _m) = pool_of(4);
        drop(pool); // must not hang or panic
    }

    #[test]
    fn worker_pool_runs_concurrently_and_returns_items_in_order() {
        let pool = WorkerPool::spawn("ctr", vec![0u64, 100, 200, 300]).unwrap();
        assert_eq!(pool.len(), 4);
        for _ in 0..5 {
            let sums = pool
                .run_all(|i, c| {
                    *c += 1;
                    (i, *c)
                })
                .unwrap();
            for (slot, &(i, _)) in sums.iter().enumerate() {
                assert_eq!(slot, i, "results must come back in worker order");
            }
        }
        let shutdown = pool.into_items();
        assert_eq!(shutdown.jobs_panicked(), 0);
        assert_eq!(shutdown.surviving_items(), vec![5, 105, 205, 305]);
    }

    #[test]
    fn run_on_queries_a_subset_and_isolates_per_worker_faults() {
        let pool = WorkerPool::spawn("subset", vec![10u64, 20, 30, 40]).unwrap();
        // Subset query in caller order, untouched workers stay untouched.
        let got = pool.run_on(&[3, 1], |i, c: &mut u64| {
            *c += 1;
            (i, *c)
        });
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].0, got[1].0), (3, 1));
        assert_eq!(*got[0].1.as_ref().unwrap(), (3, 41));
        assert_eq!(*got[1].1.as_ref().unwrap(), (1, 21));
        // A dead worker yields its own typed error slot; the live
        // worker in the same query still answers.
        pool.inject_worker_exit(1);
        let got = pool.run_on(&[1, 2], |_, c: &mut u64| *c);
        assert!(matches!(got[0].1, Err(PoolError::ReplyLost { worker: 1 })));
        assert_eq!(*got[1].1.as_ref().unwrap(), 30);
        // Out-of-range index is a per-slot error, not a panic.
        let got = pool.run_on(&[9], |_, c: &mut u64| *c);
        assert!(matches!(got[0].1, Err(PoolError::NoSuchWorker { worker: 9 })));
        assert_eq!(pool.into_items().surviving_items(), vec![10, 21, 30, 40]);
    }

    #[test]
    fn worker_pool_empty_is_fine() {
        let pool: WorkerPool<u8> = WorkerPool::spawn("none", Vec::new()).unwrap();
        assert!(pool.is_empty());
        assert!(pool.run_all(|_, _: &mut u8| ()).unwrap().is_empty());
        assert!(pool.into_items().items.is_empty());
    }

    #[test]
    fn submit_targets_one_worker() {
        let pool = WorkerPool::spawn("one", vec![10u64, 20]).unwrap();
        let r = pool.submit(1, |i, c: &mut u64| (i, *c)).unwrap();
        assert_eq!(r.recv().unwrap(), (1, 20));
        assert!(
            matches!(
                pool.submit(2, |_, c: &mut u64| *c),
                Err(PoolError::NoSuchWorker { worker: 2 })
            ),
            "out-of-range worker"
        );
        assert_eq!(pool.into_items().surviving_items(), vec![10, 20]);
    }

    #[test]
    fn job_panic_is_caught_and_worker_survives() {
        let pool = WorkerPool::spawn("panicky", vec![0u64, 100]).unwrap();
        let reply = pool
            .submit(0, |_, _: &mut u64| -> u64 { panic!("injected job panic") })
            .unwrap();
        match reply.recv() {
            Err(PoolError::JobPanicked { worker: 0, message }) => {
                assert!(message.contains("injected job panic"), "got: {message}")
            }
            other => panic!("expected JobPanicked, got {other:?}"),
        }
        // Worker 0 survived the panic and keeps serving.
        let r = pool.submit(0, |_, c: &mut u64| { *c += 7; *c }).unwrap();
        assert_eq!(r.recv().unwrap(), 7);
        // Shutdown reports the tally; both items survive.
        let shutdown = pool.into_items();
        assert_eq!(shutdown.jobs_panicked(), 1);
        assert_eq!(shutdown.faults[0].jobs_panicked, 1);
        assert!(!shutdown.faults[0].died);
        assert!(shutdown.faults[0].first_panic.as_deref().unwrap().contains("injected"));
        assert_eq!(shutdown.surviving_items(), vec![7, 100]);
    }

    #[test]
    fn run_all_isolates_a_panicking_job_and_still_serves_others() {
        let pool = WorkerPool::spawn("mixed", vec![1u64, 2, 3]).unwrap();
        let err = pool
            .run_all(|i, c: &mut u64| {
                if i == 0 {
                    panic!("worker 0 job blew up");
                }
                *c += 1;
                *c
            })
            .unwrap_err();
        assert!(matches!(err, PoolError::JobPanicked { worker: 0, .. }));
        // Workers 1 and 2 ran their jobs; 0 skipped its increment but is
        // alive. A second healthy round works everywhere.
        let vals = pool.run_all(|_, c: &mut u64| *c).unwrap();
        assert_eq!(vals, vec![1, 3, 4]);
        let shutdown = pool.into_items();
        assert_eq!(shutdown.jobs_panicked(), 1);
        assert_eq!(shutdown.surviving_items(), vec![1, 3, 4]);
    }

    #[test]
    fn reply_drop_injection_surfaces_as_reply_lost() {
        let pool = WorkerPool::spawn("lossy", vec![5u64]).unwrap();
        pool.inject_reply_drop(0);
        let reply = pool.submit(0, |_, c: &mut u64| { *c += 1; *c }).unwrap();
        assert!(matches!(reply.recv(), Err(PoolError::ReplyLost { worker: 0 })));
        // The job itself DID run (only the reply was dropped) and the
        // injection was one-shot.
        let r = pool.submit(0, |_, c: &mut u64| *c).unwrap();
        assert_eq!(r.recv().unwrap(), 6);
    }

    #[test]
    fn worker_exit_injection_makes_later_submits_worker_gone() {
        let pool = WorkerPool::spawn("mortal", vec![1u64, 2]).unwrap();
        pool.inject_worker_exit(0);
        let reply = pool.submit(0, |_, c: &mut u64| *c).unwrap();
        assert!(matches!(reply.recv(), Err(PoolError::ReplyLost { worker: 0 })));
        // The worker loop exited; once the channel reports closed, a
        // submit yields WorkerGone. Send can race the loop teardown, so
        // accept either typed outcome on the first retry, then require
        // WorkerGone steady-state.
        loop {
            match pool.submit(0, |_, c: &mut u64| *c) {
                Err(PoolError::WorkerGone { worker: 0 }) => break,
                Ok(reply) => {
                    assert!(matches!(reply.recv(), Err(PoolError::ReplyLost { worker: 0 })))
                }
                other => panic!("unexpected: {other:?}"),
            }
        }
        // Worker 1 is unaffected; shutdown hands back both items (the
        // exited worker returned its item through the normal path).
        let r = pool.submit(1, |_, c: &mut u64| *c).unwrap();
        assert_eq!(r.recv().unwrap(), 2);
        let shutdown = pool.into_items();
        assert_eq!(shutdown.surviving_items(), vec![1, 2]);
        assert!(!shutdown.faults[0].died, "injected exit is clean, not a thread panic");
    }
}
