//! Worker-thread pools with channel transport — the concurrent-device
//! half of the coordinator.
//!
//! [`WorkerPool`] is the one transport: each worker thread owns one item
//! and runs shipped closures against it. [`ChipPool`] — the ASIC-chip
//! pool used by the paper's two-chip step, the serving example, and the
//! Fig. 9 evaluation — is a thin routing layer (round-robin dispatch,
//! pair dispatch, stats aggregation) over a `WorkerPool<MlpChip>`; it
//! used to speak its own request/reply protocol on hand-rolled worker
//! threads.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::asic::MlpChip;
use crate::fixedpoint::Q13;
use crate::hw::power::OpCounts;

/// A job shipped to a pool worker: runs against the worker's owned item.
type PoolJob<T> = Box<dyn FnOnce(&mut T) + Send>;

/// Generic worker pool: each thread owns one `T` (a chip simulator, a
/// molecule-farm shard) and runs shipped closures against it. This is
/// the transport layer shared by the farm's threaded shard backend and
/// [`ChipPool`]. Dropping the pool (or calling [`Self::into_items`])
/// closes the job channels and joins every worker.
pub struct WorkerPool<T: Send + 'static> {
    txs: Vec<mpsc::Sender<PoolJob<T>>>,
    handles: Vec<JoinHandle<T>>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn one worker thread per item; threads are named `{name}-{i}`.
    pub fn spawn(name: &str, items: Vec<T>) -> WorkerPool<T> {
        let mut txs = Vec::with_capacity(items.len());
        let mut handles = Vec::with_capacity(items.len());
        for (i, mut item) in items.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PoolJob<T>>();
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        job(&mut item);
                    }
                    item
                })
                .expect("spawn pool worker");
            txs.push(tx);
            handles.push(handle);
        }
        WorkerPool { txs, handles }
    }

    pub fn len(&self) -> usize {
        self.txs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.txs.is_empty()
    }

    /// Ship `f` to worker `i` and return the receiver of its result
    /// (asynchronous: the caller decides when to block on the reply, so
    /// several workers can be kept in flight concurrently).
    pub fn submit<R, F>(&self, i: usize, f: F) -> Result<mpsc::Receiver<R>>
    where
        R: Send + 'static,
        F: FnOnce(usize, &mut T) -> R + Send + 'static,
    {
        let tx = self
            .txs
            .get(i)
            .with_context(|| format!("no pool worker {i}"))?;
        let (rtx, rrx) = mpsc::channel::<R>();
        tx.send(Box::new(move |item: &mut T| {
            let _ = rtx.send(f(i, item));
        }))
        .map_err(|_| anyhow::anyhow!("pool worker {i} hung up"))?;
        Ok(rrx)
    }

    /// Run `f` on every worker's item **concurrently** and collect the
    /// results in worker order (a full barrier: returns once every
    /// worker has replied).
    pub fn run_all<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut T) -> R + Clone + Send + 'static,
    {
        let mut replies = Vec::with_capacity(self.txs.len());
        for i in 0..self.txs.len() {
            replies.push(self.submit(i, f.clone())?);
        }
        replies
            .into_iter()
            .enumerate()
            .map(|(i, rx)| rx.recv().with_context(|| format!("pool worker {i} reply")))
            .collect()
    }

    /// Shut the pool down and hand the items back in worker order.
    pub fn into_items(mut self) -> Vec<T> {
        self.txs.clear(); // closes every channel; workers fall out of recv()
        let handles = std::mem::take(&mut self.handles);
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    }
}

impl<T: Send + 'static> Drop for WorkerPool<T> {
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A pool of chip workers, one thread per chip, on the [`WorkerPool`]
/// transport: pair dispatch for the paper's two-hydrogen step,
/// round-robin batch service, and counter aggregation.
pub struct ChipPool {
    pool: WorkerPool<MlpChip>,
    next: usize,
}

impl ChipPool {
    /// Spawn one worker thread per chip.
    pub fn spawn(chips: Vec<MlpChip>) -> ChipPool {
        ChipPool { pool: WorkerPool::spawn("mlp-chip", chips), next: 0 }
    }

    pub fn len(&self) -> usize {
        self.pool.len()
    }
    pub fn is_empty(&self) -> bool {
        self.pool.is_empty()
    }

    /// Dispatch two inferences to the first two chips *concurrently* and
    /// wait for both — the paper's two-hydrogen parallel step.
    pub fn infer_pair(&mut self, a: Vec<Q13>, b: Vec<Q13>) -> Result<(Vec<Q13>, Vec<Q13>)> {
        anyhow::ensure!(self.pool.len() >= 2, "need ≥2 chips");
        let ra = self.pool.submit(0, move |_, chip: &mut MlpChip| chip.infer(&a))?;
        let rb = self.pool.submit(1, move |_, chip: &mut MlpChip| chip.infer(&b))?;
        let ya = ra.recv().context("chip 0 reply")??;
        let yb = rb.recv().context("chip 1 reply")??;
        Ok((ya, yb))
    }

    /// Batch inference service: round-robin the rows over all chips
    /// (every row in flight at once), results returned in input order.
    pub fn infer_batch(&mut self, rows: &[Vec<Q13>]) -> Result<Vec<Vec<Q13>>> {
        let n = self.pool.len();
        anyhow::ensure!(n > 0, "empty pool");
        let mut pending = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let w = (self.next + i) % n;
            let row = row.clone();
            pending.push(self.pool.submit(w, move |_, chip: &mut MlpChip| chip.infer(&row))?);
        }
        self.next = (self.next + rows.len()) % n;
        let mut out = vec![Vec::new(); rows.len()];
        for (i, rx) in pending.into_iter().enumerate() {
            out[i] = rx.recv().context("chip reply")??;
        }
        Ok(out)
    }

    /// Aggregate counters across all chips.
    pub fn stats(&mut self) -> Result<(u64, u64, OpCounts)> {
        let per_chip = self
            .pool
            .run_all(|_, c: &mut MlpChip| (c.inferences, c.total_cycles, c.ops))?;
        let mut total = (0u64, 0u64, OpCounts::default());
        for (i, c, o) in per_chip {
            total.0 += i;
            total.1 += c;
            total.2.merge(&o);
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::ChipConfig;
    use crate::nn::{Activation, Mlp};
    use crate::util::rng::Pcg;

    fn pool_of(n: usize) -> (ChipPool, Mlp) {
        let mut rng = Pcg::new(8);
        let mut m = Mlp::init_random("p", &[3, 3, 3, 2], Activation::Phi, &mut rng);
        for l in &mut m.layers {
            for w in &mut l.w {
                *w *= 0.5;
            }
        }
        let chips = (0..n)
            .map(|id| {
                let mut c = MlpChip::new(id, ChipConfig::default());
                c.program(&m, 3);
                c
            })
            .collect();
        (ChipPool::spawn(chips), m)
    }

    #[test]
    fn pair_matches_direct_inference() {
        let (mut pool, m) = pool_of(2);
        let net = crate::nn::Sqnn::from_mlp(&m, 3);
        let a: Vec<Q13> = [0.9, 0.6, 1.0].iter().map(|&x| Q13::from_f64(x)).collect();
        let b: Vec<Q13> = [1.1, 0.7, 0.95].iter().map(|&x| Q13::from_f64(x)).collect();
        let (ya, yb) = pool.infer_pair(a.clone(), b.clone()).unwrap();
        assert_eq!(ya, net.forward_q13(&a));
        assert_eq!(yb, net.forward_q13(&b));
    }

    #[test]
    fn batch_preserves_order_across_chips() {
        let (mut pool, m) = pool_of(3);
        let net = crate::nn::Sqnn::from_mlp(&m, 3);
        let mut rng = Pcg::new(4);
        let rows: Vec<Vec<Q13>> = (0..50)
            .map(|_| (0..3).map(|_| Q13::from_f64(rng.range(-1.0, 1.5))).collect())
            .collect();
        let out = pool.infer_batch(&rows).unwrap();
        assert_eq!(out.len(), 50);
        for (row, y) in rows.iter().zip(&out) {
            assert_eq!(*y, net.forward_q13(row));
        }
    }

    #[test]
    fn stats_aggregate_all_work() {
        let (mut pool, _m) = pool_of(2);
        let rows: Vec<Vec<Q13>> = (0..20).map(|_| vec![Q13::ZERO; 3]).collect();
        pool.infer_batch(&rows).unwrap();
        let (inferences, cycles, ops) = pool.stats().unwrap();
        assert_eq!(inferences, 20);
        assert!(cycles > 0);
        assert!(ops.adds > 0);
    }

    #[test]
    fn round_robin_spreads_work_across_calls() {
        // The `next` cursor must persist between batch calls so repeated
        // small batches don't pile onto chip 0 (the routing semantics of
        // the pre-WorkerPool protocol, preserved).
        let (mut pool, _m) = pool_of(3);
        for _ in 0..3 {
            pool.infer_batch(&[vec![Q13::ZERO; 3]]).unwrap();
        }
        // 3 single-row batches over 3 chips: every chip served exactly 1.
        let per_chip = pool
            .pool
            .run_all(|_, c: &mut MlpChip| c.inferences)
            .unwrap();
        assert_eq!(per_chip, vec![1, 1, 1]);
    }

    #[test]
    fn bad_input_width_propagates_error() {
        let (mut pool, _m) = pool_of(2);
        let err = pool.infer_pair(vec![Q13::ZERO; 2], vec![Q13::ZERO; 3]);
        assert!(err.is_err());
        // pool still alive afterwards
        let ok = pool.infer_pair(vec![Q13::ZERO; 3], vec![Q13::ZERO; 3]);
        assert!(ok.is_ok());
    }

    #[test]
    fn drop_joins_workers() {
        let (pool, _m) = pool_of(4);
        drop(pool); // must not hang or panic
    }

    #[test]
    fn worker_pool_runs_concurrently_and_returns_items_in_order() {
        let pool = WorkerPool::spawn("ctr", vec![0u64, 100, 200, 300]);
        assert_eq!(pool.len(), 4);
        for _ in 0..5 {
            let sums = pool
                .run_all(|i, c| {
                    *c += 1;
                    (i, *c)
                })
                .unwrap();
            for (slot, &(i, _)) in sums.iter().enumerate() {
                assert_eq!(slot, i, "results must come back in worker order");
            }
        }
        let items = pool.into_items();
        assert_eq!(items, vec![5, 105, 205, 305]);
    }

    #[test]
    fn worker_pool_empty_is_fine() {
        let pool: WorkerPool<u8> = WorkerPool::spawn("none", Vec::new());
        assert!(pool.is_empty());
        assert!(pool.run_all(|_, _: &mut u8| ()).unwrap().is_empty());
        assert!(pool.into_items().is_empty());
    }

    #[test]
    fn submit_targets_one_worker() {
        let pool = WorkerPool::spawn("one", vec![10u64, 20]);
        let r = pool.submit(1, |i, c: &mut u64| (i, *c)).unwrap();
        assert_eq!(r.recv().unwrap(), (1, 20));
        assert!(pool.submit(2, |_, c: &mut u64| *c).is_err(), "out-of-range worker");
        let items = pool.into_items();
        assert_eq!(items, vec![10, 20]);
    }
}
