//! Fixed-point reciprocal square root — the FPGA feature module's core
//! (features are inverse distances 1/r computed from r² accumulations).
//!
//! Hardware algorithm: normalize r² into [1, 4) by even shifts, look up a
//! 64-entry seed table for 1/√m, refine with one Newton–Raphson step
//! (y ← y·(3 − m·y²)/2), denormalize. All integer arithmetic; matches
//! `1/sqrt` to within ~1 Q13 LSB over the feature range.
//!
//! The seed table is a baked const (like the ROM it models), so the unit
//! is available in the float-free core profile — no startup float math,
//! no `OnceLock`. Regenerate with `python/gen_tables.py`; a `std`-gated
//! test below recomputes every entry in float and asserts exact equality
//! (the generator checks each value is far from a rounding tie, so the
//! const is reproducible from any faithfully-rounded libm).

use crate::fixedpoint::{q13, shift_raw, Q13};

/// Seed-table fraction bits.
const SEED_FRAC: u32 = 12;
const LUT_SIZE: usize = 64;

/// Seed ROM: `round((1/sqrt(m_i)) · 2^12)` for the 64 interval midpoints
/// `m_i = 1 + 3(i + 0.5)/64` of [1, 4).
const RSQRT_SEED_LUT: [i64; LUT_SIZE] = [
    4049, 3959, 3875, 3796, 3722, 3652, 3586, 3523,
    3464, 3407, 3353, 3302, 3252, 3205, 3160, 3117,
    3076, 3036, 2998, 2961, 2925, 2891, 2858, 2825,
    2794, 2764, 2735, 2707, 2680, 2653, 2628, 2603,
    2578, 2555, 2532, 2510, 2488, 2466, 2446, 2426,
    2406, 2387, 2368, 2350, 2332, 2314, 2297, 2280,
    2264, 2248, 2232, 2217, 2202, 2187, 2172, 2158,
    2144, 2131, 2117, 2104, 2091, 2079, 2066, 2054,
];

/// Working precision of the Newton refinement (fraction bits).
const WORK_FRAC: u32 = 24;

/// Compute 1/sqrt(x) as a raw fixed-point value with `frac_out` fraction
/// bits, where `x_raw` has `frac_in` fraction bits. `newton_iters` ≥ 1;
/// two iterations reach ~2⁻²⁶ relative accuracy (needed ahead of the
/// feature-conditioning gain). Returns i64::MAX/2-saturated output for
/// x ≤ 0 (hardware guards divide-by-zero with saturation).
pub fn rsqrt_raw(x_raw: i64, frac_in: u32, frac_out: u32, newton_iters: u32) -> i64 {
    if x_raw <= 0 {
        return i64::MAX / 2;
    }
    // Normalize: find k with m = x · 2^(2k) ∈ [1, 4).
    let mut m_raw = x_raw;
    let mut k: i32 = 0;
    let lo = 1i64 << frac_in;
    let hi = lo << 2;
    while m_raw < lo {
        m_raw <<= 2;
        k += 1;
    }
    while m_raw >= hi {
        m_raw >>= 2;
        k -= 1;
    }
    // Seed from the LUT, widened to the working precision.
    let idx = (((m_raw - lo) as u128 * LUT_SIZE as u128) / ((hi - lo) as u128)) as usize;
    let mut y = RSQRT_SEED_LUT[idx.min(LUT_SIZE - 1)] << (WORK_FRAC - SEED_FRAC); // frac WORK

    // Newton: y ← y·(3 − m·y²)/2, all in frac WORK.
    for _ in 0..newton_iters {
        let ysq = ((y as i128 * y as i128) >> WORK_FRAC) as i64; // frac WORK
        let t = ((m_raw as i128 * ysq as i128) >> frac_in) as i64; // frac WORK
        let three = 3i64 << WORK_FRAC;
        y = ((y as i128 * (three - t) as i128) >> (WORK_FRAC + 1)) as i64;
    }

    // Denormalize: 1/sqrt(x) = y · 2^k, convert frac WORK → frac_out.
    shift_raw(y, k + frac_out as i32 - WORK_FRAC as i32)
}

/// Compute Q13(1/sqrt(x)) where `x_raw` is a non-negative fixed-point
/// value with `frac` fraction bits (one Newton step — the original
/// 13-bit-output unit). Saturates for x ≤ 0.
pub fn rsqrt_q13(x_raw: i64, frac: u32) -> Q13 {
    let raw = rsqrt_raw(x_raw, frac, q13::FRAC, 1);
    Q13(raw.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_lut_matches_float_expression_exactly() {
        // The baked const must equal the float expression it replaced
        // (the old OnceLock initializer), entry for entry.
        for (i, &slot) in RSQRT_SEED_LUT.iter().enumerate() {
            let m = 1.0 + 3.0 * (i as f64 + 0.5) / LUT_SIZE as f64;
            let want = ((1.0 / m.sqrt()) * (1i64 << SEED_FRAC) as f64).round() as i64;
            assert_eq!(slot, want, "lut[{i}]");
        }
    }

    fn check_range(lo: f64, hi: f64, tol_lsb: f64) {
        let frac = 20u32;
        let mut x = lo;
        while x < hi {
            let raw = (x * (1i64 << frac) as f64).round() as i64;
            let got = rsqrt_q13(raw, frac).to_f64();
            let want = 1.0 / (raw as f64 / (1i64 << frac) as f64).sqrt();
            assert!(
                (got - want).abs() <= tol_lsb * q13::LSB,
                "x={x}: got {got} want {want}"
            );
            x *= 1.013;
        }
    }

    #[test]
    fn accurate_over_feature_range() {
        // water distances r ∈ (0.7, 2.3) ⇒ r² ∈ (0.49, 5.3)
        check_range(0.45, 5.5, 1.5);
    }

    #[test]
    fn accurate_over_wide_range() {
        check_range(0.08, 14.9, 2.5);
    }

    #[test]
    fn saturates_on_zero_and_negative() {
        assert_eq!(rsqrt_q13(0, 20), Q13::MAX);
        assert_eq!(rsqrt_q13(-5, 20), Q13::MAX);
    }

    #[test]
    fn saturates_on_tiny_input() {
        // 1/sqrt(tiny) overflows Q13 → MAX.
        let raw = 1i64; // 2^-20
        assert_eq!(rsqrt_q13(raw, 20), Q13::MAX);
    }

    #[test]
    fn rsqrt_raw_two_newton_is_high_precision() {
        // ahead of the ×2^m feature gain the unit must be accurate to
        // well below one amplified LSB: rel err < 1e-6 with 2 iterations.
        let frac = 20u32;
        let mut x = 0.45;
        while x < 5.5 {
            let raw = (x * (1i64 << frac) as f64).round() as i64;
            let got = rsqrt_raw(raw, frac, 24, 2) as f64 / (1i64 << 24) as f64;
            let want = 1.0 / (raw as f64 / (1i64 << frac) as f64).sqrt();
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-6, "x={x}: rel err {rel}");
            x *= 1.017;
        }
    }

    #[test]
    fn monotone_decreasing() {
        let frac = 20u32;
        let mut prev = i32::MAX;
        let mut x = 0.3;
        while x < 10.0 {
            let raw = (x * (1i64 << frac) as f64) as i64;
            let q = rsqrt_q13(raw, frac).0;
            assert!(q <= prev, "not monotone at {x}");
            prev = q;
            x *= 1.07;
        }
    }
}
