//! Cycle-level model of the FPGA (Xilinx XC7Z100 in the paper): the
//! feature-extraction module (i) and the integration module (iii) of
//! Fig. 2, in fixed point.
//!
//! Signal formats (DESIGN.md §Numerics): module-to-module signals are the
//! paper's Q(1,2,10); the integrator keeps its *state* (positions,
//! velocities) in 26-bit accumulators with 20 fraction bits — standard
//! RTL practice (a 13-bit state register cannot hold a 0.002 Å/step
//! velocity increment) — while a `strict13` mode stores state in Q13 too,
//! used by the ablation bench to demonstrate the resulting drift.
//!
//! Layering (the crate's core/host seam): the per-tick integer
//! arithmetic — signal formats, saturation, the MAC step, conditioning,
//! rsqrt — lives in the float-free [`qint`] and [`rsqrt`] submodules and
//! builds under `--no-default-features`. The devices themselves
//! (`WaterFpga`, `MoleculeFpga`, `FeatureConditioner`) are the
//! `std`-only host layer: topology, float initialization/decoding and
//! op accounting around that shared core.

pub mod qint;
pub mod rsqrt;

#[cfg(feature = "std")]
mod host;

#[cfg(feature = "std")]
pub use host::*;

// Signal-format constants have always been addressable at `fpga::`;
// they are defined in the core profile's `qint` now.
pub use qint::{CONST_FRAC, DT_FRAC, STATE_FRAC};
