//! Cycle-level model of the FPGA (Xilinx XC7Z100 in the paper): the
//! feature-extraction module (i) and the integration module (iii) of
//! Fig. 2, in fixed point.
//!
//! Signal formats (DESIGN.md §Numerics): module-to-module signals are the
//! paper's Q(1,2,10); the integrator keeps its *state* (positions,
//! velocities) in 26-bit accumulators with 20 fraction bits — standard
//! RTL practice (a 13-bit state register cannot hold a 0.002 Å/step
//! velocity increment) — while a `strict13` mode stores state in Q13 too,
//! used by the ablation bench to demonstrate the resulting drift.

pub mod rsqrt;

use crate::fixedpoint::{q13, Q13};
use crate::hw::power::OpCounts;
use crate::md::System;
use crate::util::units::ACC_CONV;
use crate::util::Vec3;

/// Working fraction of the rsqrt / conditioning pipeline.
const fn rsqrt_work_frac() -> u32 {
    24
}

/// Fraction bits of the integrator state (26-bit registers).
pub const STATE_FRAC: u32 = 20;
/// Saturation bound of the 26-bit state registers.
const STATE_MAX: i64 = (1 << 25) - 1;
const STATE_MIN: i64 = -(1 << 25);
/// Fraction bits of the per-atom dt·ACC/m constants (set by the host at
/// initialization — "CPU for initialization and control", Fig. 1).
pub const CONST_FRAC: u32 = 24;
/// Fraction bits of the dt constant.
pub const DT_FRAC: u32 = 14;

fn sat_state(x: i64) -> i64 {
    x.clamp(STATE_MIN, STATE_MAX)
}

/// Round-to-nearest right shift. The integrator MUST NOT truncate
/// (arithmetic >> rounds toward −∞): a −½-LSB systematic bias on every
/// velocity increment pumps net momentum into the system — the molecule's
/// center of mass accelerates until the ±4 Å Q13 position bus saturates
/// and the geometry collapses (found the hard way; see the
/// `no_systematic_momentum_pumping` test).
#[inline(always)]
fn rshift_round(x: i64, n: u32) -> i64 {
    (x + (1i64 << (n - 1))) >> n
}

/// Per-hydrogen output of the feature module: the Q13 feature triple and
/// the Q13 unit vectors of the local bond frame (reused by the force
/// reconstruction).
#[derive(Debug, Clone, Copy)]
pub struct HFeatures {
    pub d: [Q13; 3],
    pub u_ho: [Q13; 3],
    pub u_hh: [Q13; 3],
}

/// The water-system FPGA: feature extraction + integration + state.
#[derive(Debug, Clone)]
pub struct WaterFpga {
    /// Position/velocity state, raw 26-bit (frac 20), [atom][axis],
    /// atoms ordered [O, H1, H2].
    pos: [[i64; 3]; 3],
    vel: [[i64; 3]; 3],
    /// dt·ACC_CONV/m per atom, raw frac-24.
    c_raw: [i64; 3],
    /// dt, raw frac-14.
    dt_raw: i64,
    /// Strict 13-bit state (ablation mode).
    pub strict13: bool,
    /// Power-of-two force rescale applied at reconstruction: the chip
    /// predicts F / 2^force_shift (so the Q13 output range covers the
    /// force distribution); the FPGA undoes it with a free left shift.
    pub force_shift: i32,
    /// Feature conditioning (programmed by the host at init): the raw
    /// inverse distances are centered by these frac-24 constants and
    /// amplified by 2^feat_shift before truncation to the Q13 bus — a
    /// constant subtract + wire shift in RTL. Indexed like the feature
    /// triple (r_aO, r_ab, r_bO ⇒ per-pair constants by distance kind).
    feat_center_raw: [i64; 3],
    feat_shift: [i32; 3],
    /// Operation counters (energy model).
    pub ops: OpCounts,
    pub steps: u64,
}

impl WaterFpga {
    /// Initialize from a float system ([O, H1, H2]) — the host CPU's
    /// initialization path.
    pub fn new(sys: &System, dt_fs: f64) -> Self {
        assert_eq!(sys.len(), 3, "water FPGA expects [O, H1, H2]");
        let enc_state = |v: f64| sat_state((v * (1i64 << STATE_FRAC) as f64).round() as i64);
        let mut pos = [[0i64; 3]; 3];
        let mut vel = [[0i64; 3]; 3];
        for i in 0..3 {
            let p = sys.pos[i].to_array();
            let v = sys.vel[i].to_array();
            for a in 0..3 {
                pos[i][a] = enc_state(p[a]);
                vel[i][a] = enc_state(v[a]);
            }
        }
        let mut c_raw = [0i64; 3];
        for i in 0..3 {
            let c = dt_fs * ACC_CONV / sys.masses[i];
            c_raw[i] = (c * (1i64 << CONST_FRAC) as f64).round() as i64;
        }
        WaterFpga {
            pos,
            vel,
            c_raw,
            dt_raw: (dt_fs * (1i64 << DT_FRAC) as f64).round() as i64,
            strict13: false,
            force_shift: 0,
            feat_center_raw: [0; 3],
            feat_shift: [0; 3],
            ops: OpCounts::default(),
            steps: 0,
        }
    }

    /// Program the feature-conditioning constants (host init path).
    /// `center` is the per-feature physical center, `scale` the
    /// power-of-two gain (as trained/exported by the model).
    pub fn program_feature_conditioning(&mut self, center: &[f64], scale: &[f64]) {
        if center.is_empty() {
            self.feat_center_raw = [0; 3];
            self.feat_shift = [0; 3];
            return;
        }
        assert_eq!(center.len(), 3, "water feature center must be length 3");
        for (slot, &c) in self.feat_center_raw.iter_mut().zip(center) {
            *slot = (c * (1i64 << rsqrt_work_frac()) as f64).round() as i64;
        }
        for i in 0..3 {
            let s = match scale.len() {
                0 => 1.0,
                1 => scale[0],
                _ => scale[i],
            };
            assert!(
                s > 0.0 && s.log2().fract() == 0.0,
                "feature scale {s} must be a power of two"
            );
            self.feat_shift[i] = s.log2() as i32;
        }
    }

    /// Control-plane velocity rescale (the host CPU's weak-coupling
    /// thermostat, Fig. 1's "CPU for initialization and control"):
    /// multiply the velocity state by a frac-24 constant.
    pub fn scale_velocities(&mut self, lambda: f64) {
        let lam = (lambda * (1i64 << CONST_FRAC) as f64).round() as i64;
        for i in 0..3 {
            for a in 0..3 {
                self.vel[i][a] = sat_state(rshift_round(self.vel[i][a] * lam, CONST_FRAC));
            }
        }
        self.ops.mults += 9;
    }

    /// Decode current positions to float (for analysis taps).
    pub fn positions(&self) -> Vec<Vec3> {
        (0..3)
            .map(|i| {
                Vec3::new(
                    self.pos[i][0] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.pos[i][1] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.pos[i][2] as f64 / (1i64 << STATE_FRAC) as f64,
                )
            })
            .collect()
    }

    pub fn velocities(&self) -> Vec<Vec3> {
        (0..3)
            .map(|i| {
                Vec3::new(
                    self.vel[i][0] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.vel[i][1] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.vel[i][2] as f64 / (1i64 << STATE_FRAC) as f64,
                )
            })
            .collect()
    }

    /// Position of atom `i` on the 13-bit inter-module bus (truncated).
    fn pos_q13(&self, i: usize, a: usize) -> Q13 {
        let raw = self.pos[i][a] >> (STATE_FRAC - q13::FRAC);
        Q13(raw.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32)
    }

    /// Quantize state through Q13 (strict13 ablation: the state registers
    /// themselves are 13-bit).
    fn apply_strict13(&mut self) {
        if !self.strict13 {
            return;
        }
        let round = |raw: &mut i64| {
            let q = (*raw >> (STATE_FRAC - q13::FRAC))
                .clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64);
            *raw = q << (STATE_FRAC - q13::FRAC);
        };
        for i in 0..3 {
            for a in 0..3 {
                round(&mut self.pos[i][a]);
                round(&mut self.vel[i][a]);
            }
        }
    }

    /// Feature-extraction module: Q13 features and frames for both
    /// hydrogens. Distances are computed from the 13-bit bus view of the
    /// positions (module (i) consumes 13-bit signals); the inverse
    /// distances pass through the conditioning stage (constant subtract
    /// + 2^m gain at frac-24 precision) before truncation to the Q13 bus.
    pub fn extract_features(&mut self) -> [HFeatures; 2] {
        let mut out = [HFeatures { d: [Q13::ZERO; 3], u_ho: [Q13::ZERO; 3], u_hh: [Q13::ZERO; 3] }; 2];
        for (hi, h) in [1usize, 2].iter().enumerate() {
            let other = 3 - h;
            let (inv_ho, u_ho) = self.inv_dist_and_unit(*h, 0);
            let (inv_hh, u_hh) = self.inv_dist_and_unit(*h, other);
            let (inv_oo, _) = self.inv_dist_and_unit(other, 0); // r_bO
            out[hi] = HFeatures {
                d: [
                    self.condition(inv_ho, 0),
                    self.condition(inv_hh, 1),
                    self.condition(inv_oo, 2),
                ],
                u_ho,
                u_hh,
            };
        }
        self.ops.shifts += 6 + 6; // rsqrt normalizations + gain shifts
        self.ops.adds += 6 * 3 + 6; // diffs + accumulations + centering
        self.ops.mults += 6 * 3 + 6 * 4; // squares + Newton multiplies (×2 iter)
        self.ops.sram_reads += 6; // LUT reads
        out
    }

    /// Conditioning stage on one inverse distance (frac-24 raw in,
    /// Q13 out): (inv − c) << m, truncate, saturate.
    fn condition(&self, inv_raw24: i64, idx: usize) -> Q13 {
        let centered = inv_raw24 - self.feat_center_raw[idx];
        let amplified = crate::fixedpoint::shift_raw(centered, self.feat_shift[idx]);
        let q = amplified >> (rsqrt_work_frac() - q13::FRAC);
        Q13(q.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32)
    }

    /// 1/|r_j − r_i| as high-precision raw (frac 24) plus the Q13 unit
    /// vector (r_j − r_i)/r.
    fn inv_dist_and_unit(&self, i: usize, j: usize) -> (i64, [Q13; 3]) {
        let mut d = [Q13::ZERO; 3];
        let mut r2_raw: i64 = 0; // frac 20
        for a in 0..3 {
            let diff = self.pos_q13(j, a).sub(self.pos_q13(i, a));
            d[a] = diff;
            r2_raw += (diff.0 as i64) * (diff.0 as i64); // frac 20
        }
        let inv24 = rsqrt::rsqrt_raw(r2_raw, STATE_FRAC, rsqrt_work_frac(), 2);
        let inv_q13 = Q13(
            (inv24 >> (rsqrt_work_frac() - q13::FRAC))
                .clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32,
        );
        let mut u = [Q13::ZERO; 3];
        for a in 0..3 {
            u[a] = d[a].mul(inv_q13);
        }
        (inv24, u)
    }

    /// Force reconstruction + Newton's-third-law oxygen force +
    /// integration (module (iii), Eqs. (2)–(3)). `c` are the two chips'
    /// local-frame outputs [(c1, c2); 2], frames from `extract_features`.
    pub fn integrate(&mut self, frames: &[HFeatures; 2], c: [[Q13; 2]; 2]) {
        // Reconstruct Cartesian hydrogen forces on the 13-bit datapath.
        // Note the wide (i64) accumulation before the rescale shift: the
        // rescaled force feeds the 26-bit-constant multiply below, so no
        // 13-bit saturation applies between reconstruction and use —
        // matching an RTL that fuses reconstruct→rescale→MAC.
        let mut f = [[0i64; 3]; 3]; // raw frac-10, wide
        for hi in 0..2 {
            for a in 0..3 {
                let fa = frames[hi].u_ho[a].mul(c[hi][0]).0 as i64
                    + frames[hi].u_hh[a].mul(c[hi][1]).0 as i64;
                f[1 + hi][a] = fa << self.force_shift;
            }
        }
        // Oxygen: F_O = −(F_H1 + F_H2).
        for a in 0..3 {
            f[0][a] = -(f[1][a] + f[2][a]);
        }
        self.ops.mults += 12;
        self.ops.adds += 12;

        // Integrate. v += F·c_i (13×26-bit multiply, renormalized);
        // r += v·dt.
        for i in 0..3 {
            for a in 0..3 {
                // F raw frac 10 × c raw frac 24 → frac 34 → state frac 20,
                // rounded (not truncated — see rshift_round).
                let dv = rshift_round(f[i][a] * self.c_raw[i], 10 + CONST_FRAC - STATE_FRAC);
                self.vel[i][a] = sat_state(self.vel[i][a] + dv);
                // v frac 20 × dt frac 14 → frac 34 → frac 20.
                let dr = rshift_round(self.vel[i][a] * self.dt_raw, DT_FRAC);
                self.pos[i][a] = sat_state(self.pos[i][a] + dr);
            }
        }
        self.ops.mults += 18;
        self.ops.adds += 18;
        self.ops.reg_writes_bits += 18 * 26;
        self.steps += 1;
        self.apply_strict13();
    }
}

/// A zeroed feature frame — scratch-buffer fill value for the batched
/// entry points below.
pub const ZERO_FRAME: HFeatures =
    HFeatures { d: [Q13::ZERO; 3], u_ho: [Q13::ZERO; 3], u_hh: [Q13::ZERO; 3] };

/// Batched feature extraction over a shard of molecules: runs module (i)
/// on every molecule and scatters the Q13 feature triples into the SoA
/// layout the batched chip kernel consumes — feature `i` of lane `b` at
/// `feats[i * lanes + b]`, where lane `b = 2·mol + h` (two hydrogens per
/// molecule) and `lanes = 2 · mols.len()`.
///
/// `frames` (2 per molecule) and `feats` (3 per lane) are shard-owned
/// scratch; this function allocates nothing. Per molecule it is the
/// exact single-molecule `extract_features` datapath, so the farm
/// inherits the coordinator's bit-identity guarantee.
pub fn extract_features_batch(mols: &mut [WaterFpga], frames: &mut [HFeatures], feats: &mut [Q13]) {
    let lanes = 2 * mols.len();
    assert_eq!(frames.len(), lanes, "frames scratch: 2 per molecule");
    assert_eq!(feats.len(), 3 * lanes, "feature scratch: 3 per lane");
    for (m, fpga) in mols.iter_mut().enumerate() {
        let fr = fpga.extract_features();
        for (hi, f) in fr.iter().enumerate() {
            let b = 2 * m + hi;
            frames[b] = *f;
            for (i, &d) in f.d.iter().enumerate() {
                feats[i * lanes + b] = d;
            }
        }
    }
}

/// Batched force reconstruction + N3L + integration over a shard:
/// consumes the chips' SoA outputs (output `o` of lane `b` at
/// `c[o * lanes + b]`, lanes as in [`extract_features_batch`]) and
/// advances every molecule one step via the exact single-molecule
/// `integrate` datapath. Allocation-free.
pub fn integrate_batch(mols: &mut [WaterFpga], frames: &[HFeatures], c: &[Q13]) {
    let lanes = 2 * mols.len();
    assert_eq!(frames.len(), lanes, "frames scratch: 2 per molecule");
    assert_eq!(c.len(), 2 * lanes, "force input: 2 per lane");
    for (m, fpga) in mols.iter_mut().enumerate() {
        let fr = [frames[2 * m], frames[2 * m + 1]];
        let cc = [
            [c[2 * m], c[lanes + 2 * m]],
            [c[2 * m + 1], c[lanes + 2 * m + 1]],
        ];
        fpga.integrate(&fr, cc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features;
    use crate::potentials::WaterPes;
    use crate::md::ForceField;

    fn eq_system() -> System {
        let pes = WaterPes::dft_surrogate();
        System::new(pes.equilibrium(), WaterPes::masses())
    }

    #[test]
    fn features_match_float_reference_within_lsb() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let feats = fpga.extract_features();
        for (hi, h) in [1usize, 2].iter().enumerate() {
            let want = features::water_features(&sys.pos, *h);
            for a in 0..3 {
                let got = feats[hi].d[a].to_f64();
                assert!(
                    (got - want[a]).abs() < 6.0 * q13::LSB,
                    "h{h} feature {a}: {got} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn unit_vectors_are_unit_norm() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let feats = fpga.extract_features();
        for f in &feats {
            for u in [&f.u_ho, &f.u_hh] {
                let n: f64 = u.iter().map(|q| q.to_f64() * q.to_f64()).sum();
                assert!((n.sqrt() - 1.0).abs() < 0.01, "norm {}", n.sqrt());
            }
        }
    }

    #[test]
    fn integration_matches_float_euler_closely() {
        // Drive the FPGA integrator with *exact* PES forces (projected to
        // local frames, quantized like the chip interface) and compare a
        // short trajectory against the float semi-implicit Euler.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.pos[1] += Vec3::new(0.02, -0.01, 0.015);
        sys.vel[1] = Vec3::new(0.004, 0.002, -0.003);

        let dt = 0.25;
        let mut fpga = WaterFpga::new(&sys, dt);
        let mut float_sys = sys.clone();
        let mut forces = vec![Vec3::ZERO; 3];
        pes.compute(&float_sys.pos, &mut forces);

        for _ in 0..200 {
            // fixed-point path
            let frames = fpga.extract_features();
            let pos_fx = fpga.positions();
            let mut f_fx = vec![Vec3::ZERO; 3];
            pes.compute(&pos_fx, &mut f_fx);
            let mut c = [[Q13::ZERO; 2]; 2];
            for hi in 0..2 {
                let loc = features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
            }
            fpga.integrate(&frames, c);
            // float path
            crate::md::euler_step(&mut float_sys, pes, dt, &mut forces);
        }
        for i in 0..3 {
            let d = (fpga.positions()[i] - float_sys.pos[i]).norm();
            assert!(d < 0.02, "atom {i} diverged by {d} Å after 50 fs");
        }
    }

    #[test]
    fn strict13_drifts_more_than_wide_state() {
        // Ablation: 13-bit state registers lose the sub-LSB increments
        // and the trajectory degrades measurably vs the 26-bit state.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(0.01, 0.0, 0.0);
        sys.zero_momentum();
        let dt = 0.25;

        let run = |strict: bool| -> f64 {
            let mut fpga = WaterFpga::new(&sys, dt);
            fpga.strict13 = strict;
            let mut float_sys = sys.clone();
            let mut forces = vec![Vec3::ZERO; 3];
            pes.compute(&float_sys.pos, &mut forces);
            for _ in 0..400 {
                let frames = fpga.extract_features();
                let pos_fx = fpga.positions();
                let mut f_fx = vec![Vec3::ZERO; 3];
                pes.compute(&pos_fx, &mut f_fx);
                let mut c = [[Q13::ZERO; 2]; 2];
                for hi in 0..2 {
                    let loc = features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                    c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
                }
                fpga.integrate(&frames, c);
                crate::md::euler_step(&mut float_sys, pes, dt, &mut forces);
            }
            (0..3)
                .map(|i| (fpga.positions()[i] - float_sys.pos[i]).norm())
                .fold(0.0, f64::max)
        };
        let wide = run(false);
        let strict = run(true);
        assert!(strict > 2.0 * wide, "strict13 {strict} vs wide {wide}");
    }

    #[test]
    fn no_systematic_momentum_pumping() {
        // Regression for an RTL-class bug: truncating shifts in the
        // integrator bias every dv by −½ LSB, so the center of mass
        // accelerates without bound. With round-to-nearest the COM must
        // stay put (sub-LSB) over a long zero-net-force run.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(0.01, -0.006, 0.004);
        sys.vel[2] = Vec3::new(-0.008, 0.005, -0.002);
        sys.zero_momentum();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let masses = [15.9994, 1.00794, 1.00794];
        let com0 = {
            let p = fpga.positions();
            (p[0] * masses[0] + p[1] * masses[1] + p[2] * masses[2]) / 18.015
        };
        for _ in 0..20_000 {
            let frames = fpga.extract_features();
            let pos_fx = fpga.positions();
            let mut f_fx = vec![Vec3::ZERO; 3];
            pes.compute(&pos_fx, &mut f_fx);
            let mut c = [[Q13::ZERO; 2]; 2];
            for hi in 0..2 {
                let loc = crate::features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
            }
            fpga.integrate(&frames, c);
        }
        let com1 = {
            let p = fpga.positions();
            (p[0] * masses[0] + p[1] * masses[1] + p[2] * masses[2]) / 18.015
        };
        let drift = (com1 - com0).norm();
        assert!(drift < 0.05, "COM drifted {drift} Å over 5 ps — momentum pumping");
    }

    #[test]
    fn batched_entry_points_match_single_molecule_path() {
        // Two molecules, perturbed differently, stepped 50 times through
        // the batched entry points vs the per-molecule calls: positions
        // and op counters must be bit-identical.
        let mut sys_a = eq_system();
        sys_a.pos[1] += Vec3::new(0.02, -0.01, 0.015);
        sys_a.vel[1] = Vec3::new(0.004, 0.002, -0.003);
        let mut sys_b = eq_system();
        sys_b.pos[2] += Vec3::new(-0.015, 0.01, 0.02);
        sys_b.vel[2] = Vec3::new(-0.003, 0.001, 0.002);

        let mut batch = vec![WaterFpga::new(&sys_a, 0.25), WaterFpga::new(&sys_b, 0.25)];
        let mut solo = vec![WaterFpga::new(&sys_a, 0.25), WaterFpga::new(&sys_b, 0.25)];

        let lanes = 2 * batch.len();
        let mut frames = vec![ZERO_FRAME; lanes];
        let mut feats = vec![Q13::ZERO; 3 * lanes];
        // fixed chip outputs per lane (the integration datapath is what
        // is under test, not the network)
        let mut c = vec![Q13::ZERO; 2 * lanes];
        for (b, v) in c.iter_mut().enumerate() {
            *v = Q13(((b as i32) - 3) * 7);
        }
        for _ in 0..50 {
            extract_features_batch(&mut batch, &mut frames, &mut feats);
            integrate_batch(&mut batch, &frames, &c);
            for (m, fpga) in solo.iter_mut().enumerate() {
                let fr = fpga.extract_features();
                // lane b = 2m+hi; outputs o at c[o*lanes + b]
                let cc = [
                    [c[2 * m], c[lanes + 2 * m]],
                    [c[2 * m + 1], c[lanes + 2 * m + 1]],
                ];
                fpga.integrate(&fr, cc);
            }
        }
        for (a, b) in batch.iter().zip(&solo) {
            assert_eq!(a.positions(), b.positions());
            assert_eq!(a.velocities(), b.velocities());
            assert_eq!(a.ops, b.ops);
            assert_eq!(a.steps, b.steps);
        }
    }

    #[test]
    fn batched_features_scatter_soa_layout() {
        let sys = eq_system();
        let mut batch = vec![WaterFpga::new(&sys, 0.25)];
        let mut reference = WaterFpga::new(&sys, 0.25);
        let lanes = 2;
        let mut frames = vec![ZERO_FRAME; lanes];
        let mut feats = vec![Q13::ZERO; 3 * lanes];
        extract_features_batch(&mut batch, &mut frames, &mut feats);
        let want = reference.extract_features();
        for hi in 0..2 {
            for i in 0..3 {
                assert_eq!(feats[i * lanes + hi], want[hi].d[i], "h{hi} feature {i}");
            }
            assert_eq!(frames[hi].u_ho, want[hi].u_ho);
            assert_eq!(frames[hi].u_hh, want[hi].u_hh);
        }
    }

    #[test]
    fn op_counters_grow() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let frames = fpga.extract_features();
        let before = fpga.ops;
        fpga.integrate(&frames, [[Q13::ZERO; 2]; 2]);
        assert!(fpga.ops.mults > before.mults);
        assert!(fpga.ops.adds > before.adds);
        assert_eq!(fpga.steps, 1);
    }

    #[test]
    fn state_saturates_instead_of_wrapping() {
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(1e6, 0.0, 0.0); // absurd velocity
        let fpga = WaterFpga::new(&sys, 0.25);
        // encoded state must be clamped, not wrapped negative
        let v = fpga.velocities()[1];
        assert!(v.x > 0.0 && v.x <= 32.0, "v.x = {}", v.x);
    }
}
