//! Host layer of the FPGA model: the water and generic-molecule devices
//! (topology, float initialization/decoding, op accounting, the float
//! descriptor front-end). All per-tick integer arithmetic is delegated
//! to [`super::qint`], which also serves the float-free core profile.

use anyhow::Result;

use crate::features;
use crate::fixedpoint::{q13, Q13};
use crate::hw::power::OpCounts;
use crate::md::System;
use crate::util::units::ACC_CONV;
use crate::util::Vec3;

use super::qint::{
    bus_q13, condition_raw24, mac_step_counted, rshift_round, sat_state, CONST_FRAC, DT_FRAC,
    RSQRT_WORK_FRAC, STATE_FRAC, STATE_MAX,
};
use super::rsqrt;

/// Encode a float into the 26-bit state format (frac 20, saturated) —
/// the host CPU's initialization path, shared by the water and generic
/// molecule FPGAs.
fn enc_state(x: f64) -> i64 {
    sat_state((x * (1i64 << STATE_FRAC) as f64).round() as i64)
}

/// Resolve per-feature power-of-two gains to wire shifts, validating the
/// broadcast rule up front: length 0 = unit gain, length 1 = broadcast,
/// length `dim` = per feature. Any other length is a hard error here —
/// not an index-out-of-bounds panic deep in a broadcast arm (the old
/// water path panicked on a 2-element scale).
fn feature_shifts(dim: usize, scale: &[f64]) -> Result<Vec<i32>> {
    anyhow::ensure!(
        matches!(scale.len(), 0 | 1) || scale.len() == dim,
        "feature scale length {} must be 0, 1, or {dim}",
        scale.len()
    );
    (0..dim)
        .map(|i| {
            let s = match scale.len() {
                0 => 1.0,
                1 => scale[0],
                _ => scale[i],
            };
            anyhow::ensure!(
                s > 0.0 && s.log2().fract() == 0.0,
                "feature scale {s} must be a power of two"
            );
            Ok(s.log2() as i32)
        })
        .collect()
}

/// Encode a physical feature center at the conditioning pipeline's
/// frac-24 working precision.
fn enc_center_raw24(c: f64) -> i64 {
    (c * (1i64 << RSQRT_WORK_FRAC) as f64).round() as i64
}

/// Per-hydrogen output of the feature module: the Q13 feature triple and
/// the Q13 unit vectors of the local bond frame (reused by the force
/// reconstruction).
#[derive(Debug, Clone, Copy)]
pub struct HFeatures {
    pub d: [Q13; 3],
    pub u_ho: [Q13; 3],
    pub u_hh: [Q13; 3],
}

/// The water-system FPGA: feature extraction + integration + state.
#[derive(Debug, Clone)]
pub struct WaterFpga {
    /// Position/velocity state, raw 26-bit (frac 20), [atom][axis],
    /// atoms ordered [O, H1, H2].
    pos: [[i64; 3]; 3],
    vel: [[i64; 3]; 3],
    /// dt·ACC_CONV/m per atom, raw frac-24.
    c_raw: [i64; 3],
    /// dt, raw frac-14.
    dt_raw: i64,
    /// Strict 13-bit state (ablation mode).
    pub strict13: bool,
    /// Power-of-two force rescale applied at reconstruction: the chip
    /// predicts F / 2^force_shift (so the Q13 output range covers the
    /// force distribution); the FPGA undoes it with a free left shift.
    pub force_shift: i32,
    /// Feature conditioning (programmed by the host at init): the raw
    /// inverse distances are centered by these frac-24 constants and
    /// amplified by 2^feat_shift before truncation to the Q13 bus — a
    /// constant subtract + wire shift in RTL. Indexed like the feature
    /// triple (r_aO, r_ab, r_bO ⇒ per-pair constants by distance kind).
    feat_center_raw: [i64; 3],
    feat_shift: [i32; 3],
    /// Operation counters (energy model).
    pub ops: OpCounts,
    pub steps: u64,
    /// Cumulative 26-bit state-clamp events in the integrator MAC (the
    /// hardware's overflow sticky flag) — the farm's divergence monitor
    /// reads this as a health signal. Zero on every healthy trajectory.
    pub sat_events: u64,
}

impl WaterFpga {
    /// Initialize from a float system ([O, H1, H2]) — the host CPU's
    /// initialization path.
    pub fn new(sys: &System, dt_fs: f64) -> Self {
        assert_eq!(sys.len(), 3, "water FPGA expects [O, H1, H2]");
        let mut pos = [[0i64; 3]; 3];
        let mut vel = [[0i64; 3]; 3];
        for i in 0..3 {
            let p = sys.pos[i].to_array();
            let v = sys.vel[i].to_array();
            for a in 0..3 {
                pos[i][a] = enc_state(p[a]);
                vel[i][a] = enc_state(v[a]);
            }
        }
        let mut c_raw = [0i64; 3];
        for i in 0..3 {
            let c = dt_fs * ACC_CONV / sys.masses[i];
            c_raw[i] = (c * (1i64 << CONST_FRAC) as f64).round() as i64;
        }
        WaterFpga {
            pos,
            vel,
            c_raw,
            dt_raw: (dt_fs * (1i64 << DT_FRAC) as f64).round() as i64,
            strict13: false,
            force_shift: 0,
            feat_center_raw: [0; 3],
            feat_shift: [0; 3],
            ops: OpCounts::default(),
            steps: 0,
            sat_events: 0,
        }
    }

    /// Fault injection: pin atom 0's state registers onto the +26-bit
    /// rail, so the next MAC step saturates deterministically (the
    /// divergence the quarantine monitor must catch).
    #[cfg(any(test, feature = "faults"))]
    pub fn inject_rail_saturation(&mut self) {
        for a in 0..3 {
            self.vel[0][a] = STATE_MAX;
            self.pos[0][a] = STATE_MAX;
        }
    }

    /// Program the feature-conditioning constants (host init path).
    /// `center` is the per-feature physical center, `scale` the
    /// power-of-two gain (as trained/exported by the model). Lengths are
    /// validated up front (center: 0 or 3; scale: 0, 1, or 3; gains must
    /// be powers of two) and bad inputs are a proper error — the old
    /// broadcast arm indexed past a 2-element scale and panicked.
    pub fn program_feature_conditioning(&mut self, center: &[f64], scale: &[f64]) -> Result<()> {
        if center.is_empty() {
            self.feat_center_raw = [0; 3];
            self.feat_shift = [0; 3];
            return Ok(());
        }
        anyhow::ensure!(
            center.len() == 3,
            "water feature center length {} must be 0 or 3",
            center.len()
        );
        let shifts = feature_shifts(3, scale)?;
        for (slot, &c) in self.feat_center_raw.iter_mut().zip(center) {
            *slot = enc_center_raw24(c);
        }
        self.feat_shift.copy_from_slice(&shifts);
        Ok(())
    }

    /// Control-plane velocity rescale (the host CPU's weak-coupling
    /// thermostat, Fig. 1's "CPU for initialization and control"):
    /// multiply the velocity state by a frac-24 constant.
    pub fn scale_velocities(&mut self, lambda: f64) {
        let lam = (lambda * (1i64 << CONST_FRAC) as f64).round() as i64;
        for i in 0..3 {
            for a in 0..3 {
                self.vel[i][a] = sat_state(rshift_round(self.vel[i][a] * lam, CONST_FRAC));
            }
        }
        self.ops.mults += 9;
    }

    /// Decode current positions to float (for analysis taps).
    pub fn positions(&self) -> Vec<Vec3> {
        (0..3)
            .map(|i| {
                Vec3::new(
                    self.pos[i][0] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.pos[i][1] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.pos[i][2] as f64 / (1i64 << STATE_FRAC) as f64,
                )
            })
            .collect()
    }

    pub fn velocities(&self) -> Vec<Vec3> {
        (0..3)
            .map(|i| {
                Vec3::new(
                    self.vel[i][0] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.vel[i][1] as f64 / (1i64 << STATE_FRAC) as f64,
                    self.vel[i][2] as f64 / (1i64 << STATE_FRAC) as f64,
                )
            })
            .collect()
    }

    /// Position of atom `i` on the 13-bit inter-module bus (truncated).
    fn pos_q13(&self, i: usize, a: usize) -> Q13 {
        bus_q13(self.pos[i][a])
    }

    /// Quantize state through Q13 (strict13 ablation: the state registers
    /// themselves are 13-bit).
    fn apply_strict13(&mut self) {
        if !self.strict13 {
            return;
        }
        let round = |raw: &mut i64| {
            let q = (*raw >> (STATE_FRAC - q13::FRAC))
                .clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64);
            *raw = q << (STATE_FRAC - q13::FRAC);
        };
        for i in 0..3 {
            for a in 0..3 {
                round(&mut self.pos[i][a]);
                round(&mut self.vel[i][a]);
            }
        }
    }

    /// Feature-extraction module: Q13 features and frames for both
    /// hydrogens. Distances are computed from the 13-bit bus view of the
    /// positions (module (i) consumes 13-bit signals); the inverse
    /// distances pass through the conditioning stage (constant subtract
    /// + 2^m gain at frac-24 precision) before truncation to the Q13 bus.
    pub fn extract_features(&mut self) -> [HFeatures; 2] {
        let mut out = [HFeatures { d: [Q13::ZERO; 3], u_ho: [Q13::ZERO; 3], u_hh: [Q13::ZERO; 3] }; 2];
        for (hi, h) in [1usize, 2].iter().enumerate() {
            let other = 3 - h;
            let (inv_ho, u_ho) = self.inv_dist_and_unit(*h, 0);
            let (inv_hh, u_hh) = self.inv_dist_and_unit(*h, other);
            let (inv_oo, _) = self.inv_dist_and_unit(other, 0); // r_bO
            out[hi] = HFeatures {
                d: [
                    self.condition(inv_ho, 0),
                    self.condition(inv_hh, 1),
                    self.condition(inv_oo, 2),
                ],
                u_ho,
                u_hh,
            };
        }
        self.ops.shifts += 6 + 6; // rsqrt normalizations + gain shifts
        self.ops.adds += 6 * 3 + 6; // diffs + accumulations + centering
        self.ops.mults += 6 * 3 + 6 * 4; // squares + Newton multiplies (×2 iter)
        self.ops.sram_reads += 6; // LUT reads
        out
    }

    /// Conditioning stage on one inverse distance (frac-24 raw in,
    /// Q13 out): (inv − c) << m, truncate, saturate.
    fn condition(&self, inv_raw24: i64, idx: usize) -> Q13 {
        condition_raw24(inv_raw24, self.feat_center_raw[idx], self.feat_shift[idx])
    }

    /// 1/|r_j − r_i| as high-precision raw (frac 24) plus the Q13 unit
    /// vector (r_j − r_i)/r.
    fn inv_dist_and_unit(&self, i: usize, j: usize) -> (i64, [Q13; 3]) {
        let mut d = [Q13::ZERO; 3];
        let mut r2_raw: i64 = 0; // frac 20
        for a in 0..3 {
            let diff = self.pos_q13(j, a).sub(self.pos_q13(i, a));
            d[a] = diff;
            r2_raw += (diff.0 as i64) * (diff.0 as i64); // frac 20
        }
        let inv24 = rsqrt::rsqrt_raw(r2_raw, STATE_FRAC, RSQRT_WORK_FRAC, 2);
        let inv_q13 = Q13(
            (inv24 >> (RSQRT_WORK_FRAC - q13::FRAC))
                .clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32,
        );
        let mut u = [Q13::ZERO; 3];
        for a in 0..3 {
            u[a] = d[a].mul(inv_q13);
        }
        (inv24, u)
    }

    /// Force reconstruction + Newton's-third-law oxygen force +
    /// integration (module (iii), Eqs. (2)–(3)). `c` are the two chips'
    /// local-frame outputs [(c1, c2); 2], frames from `extract_features`.
    pub fn integrate(&mut self, frames: &[HFeatures; 2], c: [[Q13; 2]; 2]) {
        // Reconstruct Cartesian hydrogen forces on the 13-bit datapath.
        // Note the wide (i64) accumulation before the rescale shift: the
        // rescaled force feeds the 26-bit-constant multiply below, so no
        // 13-bit saturation applies between reconstruction and use —
        // matching an RTL that fuses reconstruct→rescale→MAC.
        let mut f = [[0i64; 3]; 3]; // raw frac-10, wide
        for hi in 0..2 {
            for a in 0..3 {
                let fa = frames[hi].u_ho[a].mul(c[hi][0]).0 as i64
                    + frames[hi].u_hh[a].mul(c[hi][1]).0 as i64;
                // Sign-aware wire shift: a model with output_scale < 1
                // programs a *negative* force_shift (arithmetic right
                // shift), which a raw `<<` would turn into an
                // overflowing-shift panic.
                f[1 + hi][a] = crate::fixedpoint::shift_raw(fa, self.force_shift);
            }
        }
        // Oxygen: F_O = −(F_H1 + F_H2).
        for a in 0..3 {
            f[0][a] = -(f[1][a] + f[2][a]);
        }
        self.ops.mults += 12;
        self.ops.adds += 12;

        // Integrate: the shared core MAC (v += F·c; r += v·dt, rounded —
        // see `qint::mac_step`).
        for i in 0..3 {
            for a in 0..3 {
                mac_step_counted(
                    &mut self.pos[i][a],
                    &mut self.vel[i][a],
                    f[i][a],
                    self.c_raw[i],
                    self.dt_raw,
                    &mut self.sat_events,
                );
            }
        }
        self.ops.mults += 18;
        self.ops.adds += 18;
        self.ops.reg_writes_bits += 18 * 26;
        self.steps += 1;
        self.apply_strict13();
    }
}

/// A zeroed feature frame — initial value of the per-molecule frame
/// scratch the farm's water serving path keeps between its extract and
/// integrate stages (`coordinator::farm`).
pub const ZERO_FRAME: HFeatures =
    HFeatures { d: [Q13::ZERO; 3], u_ho: [Q13::ZERO; 3], u_hh: [Q13::ZERO; 3] };

/// Float→Q13 feature-conditioning stage of the generic-molecule path —
/// the exact integer stage of [`WaterFpga::program_feature_conditioning`]
/// ((raw − center) << m at frac-24, truncate to the Q13 bus), applied to
/// descriptors the FPGA computes in its float front-end. Lengths follow
/// the same broadcast rule (center: 0 or dim; scale: 0, 1, or dim) and
/// are validated at construction.
#[derive(Debug, Clone)]
pub struct FeatureConditioner {
    /// Per-feature centers at frac-24 (all zero when unprogrammed).
    center_raw: Vec<i64>,
    /// Per-feature wire shifts (2^m gains).
    shift: Vec<i32>,
}

impl FeatureConditioner {
    pub fn new(dim: usize, center: &[f64], scale: &[f64]) -> Result<FeatureConditioner> {
        anyhow::ensure!(dim > 0, "conditioner needs at least one feature");
        anyhow::ensure!(
            center.is_empty() || center.len() == dim,
            "feature center length {} must be 0 or {dim}",
            center.len()
        );
        if center.is_empty() {
            // Unprogrammed: identity centering and unit gain, matching
            // the water FPGA's reset state (scale is ignored there too).
            return Ok(FeatureConditioner { center_raw: vec![0; dim], shift: vec![0; dim] });
        }
        Ok(FeatureConditioner {
            center_raw: center.iter().map(|&c| enc_center_raw24(c)).collect(),
            shift: feature_shifts(dim, scale)?,
        })
    }

    /// Conditioned descriptor width (features per lane).
    pub fn dim(&self) -> usize {
        self.center_raw.len()
    }

    /// Condition one raw feature onto the Q13 bus: encode at the
    /// pipeline's frac-24 working precision, then the shared integer
    /// subtract-shift-truncate stage.
    pub fn q13(&self, i: usize, raw: f64) -> Q13 {
        condition_raw24(enc_center_raw24(raw), self.center_raw[i], self.shift[i])
    }
}

/// Periodic cell of a bulk [`MoleculeFpga`]: a cubic box held both as
/// the float side length (for the descriptor front-end's minimum-image
/// arithmetic) and as a frac-20 raw (the integer wrap bound of the state
/// registers).
#[derive(Debug, Clone, Copy)]
struct PbcBox {
    l: f64,
    raw: i64,
}

/// The generic-molecule FPGA: the water pipeline's integration datapath
/// generalized to N atoms, fronted by the `features::local_descriptor`
/// path (4·n_nb features per atom) and the [`FeatureConditioner`].
///
/// Signal plan (DESIGN.md §Substitutions): positions and velocities live
/// in the same 26-bit state registers as [`WaterFpga`]; the descriptor
/// front-end consumes the truncated 13-bit bus view of the positions and
/// evaluates the DeePMD-style `(1/r, x/r², y/r², z/r²)` neighbor block
/// in the float rsqrt pipeline (the conditioning stage then truncates
/// each feature to the Q13 chip bus). The chip predicts the Cartesian
/// per-atom force `F / 2^force_shift` directly (3 outputs per atom lane,
/// as the Table-I datasets are labeled), so integration needs no local
/// frame reconstruction and no N3L pass — each atom's lane carries its
/// own force.
///
/// Bulk systems ([`Self::new_pbc`]) keep positions wrapped into a cubic
/// [0, box) cell in the integer state registers; the descriptor
/// front-end then runs minimum-image displacements, so every
/// inter-atomic quantity still fits the ±4 Å Q13 signal range even
/// though absolute positions span the cell.
#[derive(Debug, Clone)]
pub struct MoleculeFpga {
    /// 26-bit (frac 20) position/velocity state, [atom][axis].
    pos: Vec<[i64; 3]>,
    vel: Vec<[i64; 3]>,
    /// dt·ACC_CONV/m per atom, raw frac-24.
    c_raw: Vec<i64>,
    /// dt, raw frac-14.
    dt_raw: i64,
    /// Power-of-two force rescale undone at integration (see
    /// [`WaterFpga::force_shift`]).
    pub force_shift: i32,
    /// Fixed reference-topology neighbor ordering, `n_nb` per atom.
    nb: Vec<Vec<usize>>,
    cond: FeatureConditioner,
    /// Periodic cell (bulk systems); `None` = isolated molecule.
    pbc: Option<PbcBox>,
    /// Scratch: decoded bus positions and one atom's raw descriptor
    /// (owned here so extraction allocates nothing).
    pos_f: Vec<Vec3>,
    feat_f: Vec<f64>,
    pub ops: OpCounts,
    pub steps: u64,
    /// Cumulative 26-bit state-clamp events in the integrator MAC — see
    /// [`WaterFpga::sat_events`].
    pub sat_events: u64,
}

impl MoleculeFpga {
    /// Initialize from a float system, a per-atom neighbor ordering
    /// (`n_nb` entries each, e.g. `features::reference_neighbors`), and
    /// a programmed conditioning stage of width `4·n_nb`.
    pub fn new(
        sys: &System,
        nb: Vec<Vec<usize>>,
        cond: FeatureConditioner,
        dt_fs: f64,
    ) -> Result<MoleculeFpga> {
        Self::build(sys, nb, cond, dt_fs, None)
    }

    /// Initialize a bulk (periodic) system in a cubic box of side
    /// `box_l` Å: positions are wrapped into [0, box) in the integer
    /// state, the descriptor front-end uses minimum-image displacements,
    /// and the neighbor ordering should come from
    /// `features::reference_neighbors_pbc`. `box_l` must fit the 26-bit
    /// state registers with one LSB-Å of wrap headroom (≤ 31 Å — up to a
    /// 5×5×5 silicon conventional supercell).
    pub fn new_pbc(
        sys: &System,
        nb: Vec<Vec<usize>>,
        cond: FeatureConditioner,
        dt_fs: f64,
        box_l: f64,
    ) -> Result<MoleculeFpga> {
        anyhow::ensure!(box_l > 0.0, "PBC box side {box_l} must be positive");
        let raw = (box_l * (1i64 << STATE_FRAC) as f64).round() as i64;
        anyhow::ensure!(
            raw + (1 << STATE_FRAC) <= STATE_MAX,
            "PBC box side {box_l} Å exceeds the 26-bit state range (≤ 31 Å)"
        );
        Self::build(sys, nb, cond, dt_fs, Some(PbcBox { l: box_l, raw }))
    }

    fn build(
        sys: &System,
        nb: Vec<Vec<usize>>,
        cond: FeatureConditioner,
        dt_fs: f64,
        pbc: Option<PbcBox>,
    ) -> Result<MoleculeFpga> {
        let n = sys.len();
        anyhow::ensure!(n >= 2, "molecule FPGA needs at least two atoms");
        anyhow::ensure!(nb.len() == n, "neighbor lists: {} for {n} atoms", nb.len());
        let n_nb = nb[0].len();
        anyhow::ensure!(n_nb >= 1, "descriptor needs at least one neighbor");
        for (i, l) in nb.iter().enumerate() {
            anyhow::ensure!(
                l.len() == n_nb,
                "atom {i}: ragged neighbor list ({} vs {n_nb}) — lanes must share one width",
                l.len()
            );
            for &j in l {
                anyhow::ensure!(j < n && j != i, "atom {i}: bad neighbor index {j}");
            }
        }
        anyhow::ensure!(
            cond.dim() == 4 * n_nb,
            "conditioner width {} != descriptor width {}",
            cond.dim(),
            4 * n_nb
        );
        let mut pos = vec![[0i64; 3]; n];
        let mut vel = vec![[0i64; 3]; n];
        for i in 0..n {
            let p = sys.pos[i].to_array();
            let v = sys.vel[i].to_array();
            for a in 0..3 {
                pos[i][a] = enc_state(p[a]);
                if let Some(b) = pbc {
                    pos[i][a] = pos[i][a].rem_euclid(b.raw);
                }
                vel[i][a] = enc_state(v[a]);
            }
        }
        let c_raw = sys
            .masses
            .iter()
            .map(|&m| ((dt_fs * ACC_CONV / m) * (1i64 << CONST_FRAC) as f64).round() as i64)
            .collect();
        Ok(MoleculeFpga {
            pos,
            vel,
            c_raw,
            dt_raw: (dt_fs * (1i64 << DT_FRAC) as f64).round() as i64,
            force_shift: 0,
            nb,
            cond,
            pbc,
            pos_f: vec![Vec3::ZERO; n],
            feat_f: vec![0.0; 4 * n_nb],
            ops: OpCounts::default(),
            steps: 0,
            sat_events: 0,
        })
    }

    /// Fault injection: pin atom 0's velocity (and, for isolated
    /// molecules, position) onto the +26-bit rail so the next MAC step
    /// saturates (isolated) or the trajectory jumps across the cell
    /// (bulk) — both divergence signatures the monitor must catch.
    #[cfg(any(test, feature = "faults"))]
    pub fn inject_rail_saturation(&mut self) {
        for a in 0..3 {
            self.vel[0][a] = STATE_MAX;
            if self.pbc.is_none() {
                self.pos[0][a] = STATE_MAX;
            }
        }
    }

    pub fn n_atoms(&self) -> usize {
        self.pos.len()
    }

    pub fn n_nb(&self) -> usize {
        self.nb[0].len()
    }

    /// Conditioned descriptor width per atom lane (the chip `in_dim`).
    pub fn in_dim(&self) -> usize {
        self.cond.dim()
    }

    /// The periodic box side, if this is a bulk system.
    pub fn box_l(&self) -> Option<f64> {
        self.pbc.map(|b| b.l)
    }

    /// Decode current positions to float (analysis taps). Bulk systems
    /// report wrapped coordinates in [0, box).
    pub fn positions(&self) -> Vec<Vec3> {
        self.pos.iter().map(|p| Self::dec_state(p)).collect()
    }

    pub fn velocities(&self) -> Vec<Vec3> {
        self.vel.iter().map(|v| Self::dec_state(v)).collect()
    }

    fn dec_state(r: &[i64; 3]) -> Vec3 {
        let s = (1i64 << STATE_FRAC) as f64;
        Vec3::new(r[0] as f64 / s, r[1] as f64 / s, r[2] as f64 / s)
    }

    /// Position of atom `i` as seen on the truncated 13-bit inter-module
    /// bus — the view the descriptor front-end consumes, matching the
    /// water feature module. In PBC mode the position field of the bus
    /// is widened past the ±4 Å Q13 rails (same frac-10 grid, more
    /// integer bits — wrapped cell coordinates span [0, box) up to
    /// 31 Å): only *differences* of positions travel the Q13 datapath,
    /// and those are minimum-imaged back into range by the front-end.
    fn bus_pos(&self, i: usize) -> Vec3 {
        let d = |a: usize| {
            let raw = self.pos[i][a] >> (STATE_FRAC - q13::FRAC);
            match self.pbc {
                Some(_) => raw as f64 * q13::LSB,
                None => raw.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as f64 * q13::LSB,
            }
        };
        Vec3::new(d(0), d(1), d(2))
    }

    /// Extract every atom's conditioned Q13 descriptor into an SoA
    /// feature block: feature `i` of this molecule's atom `a` lands at
    /// `feats[i * batch + lane0 + a]` (one chip lane per atom). The
    /// block may be shared with other molecules of a farm shard —
    /// `batch` is the shard's total lane count and `lane0` this
    /// molecule's first lane. Allocation-free.
    pub fn extract_features_soa(&mut self, feats: &mut [Q13], batch: usize, lane0: usize) {
        let n = self.pos.len();
        let in_dim = self.cond.dim();
        assert_eq!(feats.len(), in_dim * batch, "SoA feature block size");
        assert!(lane0 + n <= batch, "molecule lanes exceed the batch");
        for i in 0..n {
            let p = self.bus_pos(i);
            self.pos_f[i] = p;
        }
        for atom in 0..n {
            match self.pbc {
                Some(b) => features::local_descriptor_pbc_into(
                    &self.pos_f,
                    atom,
                    &self.nb[atom],
                    b.l,
                    &mut self.feat_f,
                ),
                None => features::local_descriptor_into(
                    &self.pos_f,
                    atom,
                    &self.nb[atom],
                    &mut self.feat_f,
                ),
            }
            for (fi, &raw) in self.feat_f.iter().enumerate() {
                feats[fi * batch + lane0 + atom] = self.cond.q13(fi, raw);
            }
        }
        // Energy model, per neighbor pair: 3 coordinate diffs + 2
        // accumulations (adds), 3 squares + 4 Newton multiplies + 4
        // feature multiplies (mults), one rsqrt LUT read; per feature:
        // one centering subtract and one gain shift.
        let pairs = (n * self.n_nb()) as u64;
        self.ops.adds += 5 * pairs + 4 * pairs;
        self.ops.mults += 11 * pairs;
        self.ops.shifts += 4 * pairs;
        self.ops.sram_reads += pairs;
    }

    /// Consume the chip's SoA outputs (output `o` of atom `a` at
    /// `c[o * batch + lane0 + a]`, 3 Cartesian force components per atom
    /// lane, each `F / 2^force_shift`) and advance every atom one
    /// semi-implicit Euler step on the shared core MAC datapath
    /// (`qint::mac_step`, round-to-nearest renormalization). Bulk
    /// systems re-wrap the position state into [0, box) after the step.
    pub fn integrate_soa(&mut self, c: &[Q13], batch: usize, lane0: usize) {
        let n = self.pos.len();
        assert_eq!(c.len(), 3 * batch, "SoA force block size");
        assert!(lane0 + n <= batch, "molecule lanes exceed the batch");
        for i in 0..n {
            for a in 0..3 {
                // Force raw frac-10, rescaled by the free (sign-aware)
                // wire shift — see the matching note in
                // [`WaterFpga::integrate`].
                let f = crate::fixedpoint::shift_raw(c[a * batch + lane0 + i].0 as i64, self.force_shift);
                mac_step_counted(
                    &mut self.pos[i][a],
                    &mut self.vel[i][a],
                    f,
                    self.c_raw[i],
                    self.dt_raw,
                    &mut self.sat_events,
                );
                if let Some(b) = self.pbc {
                    self.pos[i][a] = self.pos[i][a].rem_euclid(b.raw);
                }
            }
        }
        let n = n as u64;
        self.ops.shifts += 3 * n;
        self.ops.mults += 6 * n;
        self.ops.adds += 6 * n;
        self.ops.reg_writes_bits += 6 * n * 26;
        self.steps += 1;
    }

    /// Modelled FPGA cycles of one step of this molecule (feature +
    /// integration stages; transfer/control windows are accounted per
    /// shard tick): per neighbor pair one distance pipeline (diff,
    /// square, accumulate ≈ 4 cycles) plus one rsqrt (LUT + 2 Newton
    /// stages ≈ 6 cycles, shared across the pair's 4 features); per atom
    /// the integrator's 3-axis MAC + state update (≈ 2 cycles each) —
    /// the same per-stage model `hw::timing::StepCycles::water` uses.
    pub fn cycles_per_step(&self) -> u64 {
        let n = self.pos.len() as u64;
        let pairs = n * self.n_nb() as u64;
        10 * pairs + 6 * n + 6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features;
    use crate::potentials::WaterPes;
    use crate::md::ForceField;

    fn eq_system() -> System {
        let pes = WaterPes::dft_surrogate();
        System::new(pes.equilibrium(), WaterPes::masses())
    }

    #[test]
    fn features_match_float_reference_within_lsb() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let feats = fpga.extract_features();
        for (hi, h) in [1usize, 2].iter().enumerate() {
            let want = features::water_features(&sys.pos, *h);
            for a in 0..3 {
                let got = feats[hi].d[a].to_f64();
                assert!(
                    (got - want[a]).abs() < 6.0 * q13::LSB,
                    "h{h} feature {a}: {got} vs {want:?}"
                );
            }
        }
    }

    #[test]
    fn unit_vectors_are_unit_norm() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let feats = fpga.extract_features();
        for f in &feats {
            for u in [&f.u_ho, &f.u_hh] {
                let n: f64 = u.iter().map(|q| q.to_f64() * q.to_f64()).sum();
                assert!((n.sqrt() - 1.0).abs() < 0.01, "norm {}", n.sqrt());
            }
        }
    }

    #[test]
    fn integration_matches_float_euler_closely() {
        // Drive the FPGA integrator with *exact* PES forces (projected to
        // local frames, quantized like the chip interface) and compare a
        // short trajectory against the float semi-implicit Euler.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.pos[1] += Vec3::new(0.02, -0.01, 0.015);
        sys.vel[1] = Vec3::new(0.004, 0.002, -0.003);

        let dt = 0.25;
        let mut fpga = WaterFpga::new(&sys, dt);
        let mut float_sys = sys.clone();
        let mut forces = vec![Vec3::ZERO; 3];
        pes.compute(&float_sys.pos, &mut forces);

        for _ in 0..200 {
            // fixed-point path
            let frames = fpga.extract_features();
            let pos_fx = fpga.positions();
            let mut f_fx = vec![Vec3::ZERO; 3];
            pes.compute(&pos_fx, &mut f_fx);
            let mut c = [[Q13::ZERO; 2]; 2];
            for hi in 0..2 {
                let loc = features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
            }
            fpga.integrate(&frames, c);
            // float path
            crate::md::euler_step(&mut float_sys, pes, dt, &mut forces);
        }
        for i in 0..3 {
            let d = (fpga.positions()[i] - float_sys.pos[i]).norm();
            assert!(d < 0.02, "atom {i} diverged by {d} Å after 50 fs");
        }
        // A healthy trajectory never touches the 26-bit clamps.
        assert_eq!(fpga.sat_events, 0);
    }

    #[test]
    fn injected_rail_saturation_trips_the_clamp_counter() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let frames = fpga.extract_features();
        fpga.integrate(&frames, [[Q13::ZERO; 2]; 2]);
        assert_eq!(fpga.sat_events, 0, "zero-force step must not clamp");
        // Pin atom 0 to the +rail: the very next step's r += v·dt pushes
        // past STATE_MAX on every axis of atom 0 and the sticky counter
        // fires (vel stays exactly at the rail under zero force, so the
        // velocity clamp itself is silent — position does the counting).
        fpga.inject_rail_saturation();
        let frames = fpga.extract_features();
        fpga.integrate(&frames, [[Q13::ZERO; 2]; 2]);
        assert!(fpga.sat_events >= 3, "expected ≥3 clamp events, got {}", fpga.sat_events);
    }

    #[test]
    fn strict13_drifts_more_than_wide_state() {
        // Ablation: 13-bit state registers lose the sub-LSB increments
        // and the trajectory degrades measurably vs the 26-bit state.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(0.01, 0.0, 0.0);
        sys.zero_momentum();
        let dt = 0.25;

        let run = |strict: bool| -> f64 {
            let mut fpga = WaterFpga::new(&sys, dt);
            fpga.strict13 = strict;
            let mut float_sys = sys.clone();
            let mut forces = vec![Vec3::ZERO; 3];
            pes.compute(&float_sys.pos, &mut forces);
            for _ in 0..400 {
                let frames = fpga.extract_features();
                let pos_fx = fpga.positions();
                let mut f_fx = vec![Vec3::ZERO; 3];
                pes.compute(&pos_fx, &mut f_fx);
                let mut c = [[Q13::ZERO; 2]; 2];
                for hi in 0..2 {
                    let loc = features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                    c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
                }
                fpga.integrate(&frames, c);
                crate::md::euler_step(&mut float_sys, pes, dt, &mut forces);
            }
            (0..3)
                .map(|i| (fpga.positions()[i] - float_sys.pos[i]).norm())
                .fold(0.0, f64::max)
        };
        let wide = run(false);
        let strict = run(true);
        assert!(strict > 2.0 * wide, "strict13 {strict} vs wide {wide}");
    }

    #[test]
    fn no_systematic_momentum_pumping() {
        // Regression for an RTL-class bug: truncating shifts in the
        // integrator bias every dv by −½ LSB, so the center of mass
        // accelerates without bound. With round-to-nearest the COM must
        // stay put (sub-LSB) over a long zero-net-force run.
        let pes = WaterPes::dft_surrogate();
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(0.01, -0.006, 0.004);
        sys.vel[2] = Vec3::new(-0.008, 0.005, -0.002);
        sys.zero_momentum();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let masses = [15.9994, 1.00794, 1.00794];
        let com0 = {
            let p = fpga.positions();
            (p[0] * masses[0] + p[1] * masses[1] + p[2] * masses[2]) / 18.015
        };
        for _ in 0..20_000 {
            let frames = fpga.extract_features();
            let pos_fx = fpga.positions();
            let mut f_fx = vec![Vec3::ZERO; 3];
            pes.compute(&pos_fx, &mut f_fx);
            let mut c = [[Q13::ZERO; 2]; 2];
            for hi in 0..2 {
                let loc = crate::features::water_force_to_local(&pos_fx, 1 + hi, f_fx[1 + hi]);
                c[hi] = [Q13::from_f64(loc[0]), Q13::from_f64(loc[1])];
            }
            fpga.integrate(&frames, c);
        }
        let com1 = {
            let p = fpga.positions();
            (p[0] * masses[0] + p[1] * masses[1] + p[2] * masses[2]) / 18.015
        };
        let drift = (com1 - com0).norm();
        assert!(drift < 0.05, "COM drifted {drift} Å over 5 ps — momentum pumping");
    }

    #[test]
    fn negative_force_shift_is_a_right_shift_not_a_panic() {
        // output_scale = 0.5 programs force_shift = −1: the rescale must
        // be the paper's sign-aware P(x, n) wire shift, not a raw `<<`
        // (which panics on negative shift amounts in debug builds).
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        fpga.force_shift = -1;
        let frames = fpga.extract_features();
        fpga.integrate(&frames, [[Q13(100), Q13(-50)]; 2]);
        assert!(fpga.positions()[1].norm().is_finite());

        let mol = crate::potentials::ff::ethanol();
        let msys = System::new(mol.coords.clone(), mol.masses());
        let nb: Vec<Vec<usize>> = (0..msys.len())
            .map(|i| features::reference_neighbors(&mol.coords, i, 4))
            .collect();
        let cond = FeatureConditioner::new(16, &[], &[]).unwrap();
        let mut g = MoleculeFpga::new(&msys, nb, cond, 0.25).unwrap();
        g.force_shift = -1;
        let n = g.n_atoms();
        let c = vec![Q13(101); 3 * n];
        g.integrate_soa(&c, n, 0);
        assert_eq!(g.steps, 1);
        assert!(g.positions()[0].norm().is_finite());
    }

    #[test]
    fn op_counters_grow() {
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let frames = fpga.extract_features();
        let before = fpga.ops;
        fpga.integrate(&frames, [[Q13::ZERO; 2]; 2]);
        assert!(fpga.ops.mults > before.mults);
        assert!(fpga.ops.adds > before.adds);
        assert_eq!(fpga.steps, 1);
    }

    #[test]
    fn conditioning_validates_scale_lengths() {
        // Regression: scale.len() == 2 used to panic with an
        // index-out-of-bounds in the broadcast arm; every length is now
        // validated up front. Lengths 0 (unit), 1 (broadcast) and 3
        // (per-feature) are accepted, anything else is a proper error.
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let center = [1.0, 0.7, 1.0];
        fpga.program_feature_conditioning(&center, &[]).unwrap();
        assert_eq!(fpga.feat_shift, [0, 0, 0]);
        fpga.program_feature_conditioning(&center, &[4.0]).unwrap();
        assert_eq!(fpga.feat_shift, [2, 2, 2]);
        fpga.program_feature_conditioning(&center, &[1.0, 2.0, 4.0]).unwrap();
        assert_eq!(fpga.feat_shift, [0, 1, 2]);
        let err = fpga.program_feature_conditioning(&center, &[2.0, 2.0]);
        assert!(err.is_err(), "2-element scale must be rejected, not panic");
        assert!(err.unwrap_err().to_string().contains("length 2"));
        // non-power-of-two and non-positive gains are rejected too
        assert!(fpga.program_feature_conditioning(&center, &[3.0]).is_err());
        assert!(fpga.program_feature_conditioning(&center, &[-2.0]).is_err());
        // bad center length is an error, not an assert
        assert!(fpga.program_feature_conditioning(&[1.0, 0.7], &[]).is_err());
        // empty center resets the stage and ignores scale (unprogrammed)
        fpga.program_feature_conditioning(&[], &[2.0, 2.0]).unwrap();
        assert_eq!(fpga.feat_shift, [0, 0, 0]);
        assert_eq!(fpga.feat_center_raw, [0, 0, 0]);
    }

    #[test]
    fn feature_conditioner_matches_water_stage() {
        // The generic float→Q13 conditioner must reproduce the water
        // FPGA's integer conditioning stage exactly when fed the same
        // frac-24 raw values.
        let sys = eq_system();
        let mut fpga = WaterFpga::new(&sys, 0.25);
        let center = [0.9, 0.6, 0.95];
        let scale = [2.0, 4.0, 2.0];
        fpga.program_feature_conditioning(&center, &scale).unwrap();
        let cond = FeatureConditioner::new(3, &center, &scale).unwrap();
        for step in 0..200 {
            let raw = 0.25 + 0.007 * step as f64; // covers the feature range
            let raw24 = enc_center_raw24(raw);
            for i in 0..3 {
                assert_eq!(cond.q13(i, raw), fpga.condition(raw24, i), "feature {i} raw {raw}");
            }
        }
        // broadcast rule mirrors the water path
        assert!(FeatureConditioner::new(3, &center, &[2.0, 2.0]).is_err());
        let unit = FeatureConditioner::new(4, &[], &[]).unwrap();
        assert_eq!(unit.dim(), 4);
        assert_eq!(unit.q13(0, 1.0), Q13::from_f64(1.0));
    }

    #[test]
    fn molecule_fpga_rejects_bad_topology() {
        let mol = crate::potentials::ff::ethanol();
        let sys = System::new(mol.coords.clone(), mol.masses());
        let n = sys.len();
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors(&mol.coords, i, 4))
            .collect();
        let cond = FeatureConditioner::new(16, &[], &[]).unwrap();
        assert!(MoleculeFpga::new(&sys, nb.clone(), cond.clone(), 0.25).is_ok());
        // ragged neighbor lists
        let mut ragged = nb.clone();
        ragged[2].pop();
        assert!(MoleculeFpga::new(&sys, ragged, cond.clone(), 0.25).is_err());
        // conditioner width mismatch
        let narrow = FeatureConditioner::new(8, &[], &[]).unwrap();
        assert!(MoleculeFpga::new(&sys, nb.clone(), narrow, 0.25).is_err());
        // self-neighbor
        let mut selfish = nb.clone();
        selfish[0][0] = 0;
        assert!(MoleculeFpga::new(&sys, selfish, cond.clone(), 0.25).is_err());
        // missing lists
        assert!(MoleculeFpga::new(&sys, nb[..n - 1].to_vec(), cond, 0.25).is_err());
    }

    #[test]
    fn molecule_fpga_features_match_descriptor_reference() {
        // The SoA extraction must equal `local_descriptor` on the bus
        // view of the positions, conditioned feature by feature.
        let mol = crate::potentials::ff::ethanol();
        let sys = System::new(mol.coords.clone(), mol.masses());
        let n = sys.len();
        let n_nb = 4usize;
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors(&mol.coords, i, n_nb))
            .collect();
        let center = vec![0.4; 16];
        let scale = vec![2.0; 16];
        let cond = FeatureConditioner::new(16, &center, &scale).unwrap();
        let mut fpga = MoleculeFpga::new(&sys, nb.clone(), cond.clone(), 0.25).unwrap();
        let batch = n + 3; // molecule embedded mid-batch
        let lane0 = 2usize;
        let mut feats = vec![Q13::ZERO; 16 * batch];
        fpga.extract_features_soa(&mut feats, batch, lane0);
        // reference: descriptor on the decoded bus positions
        let bus: Vec<Vec3> = (0..n).map(|i| fpga.bus_pos(i)).collect();
        for atom in 0..n {
            let want = features::local_descriptor(&bus, atom, &nb[atom]);
            for (fi, &raw) in want.iter().enumerate() {
                assert_eq!(
                    feats[fi * batch + lane0 + atom],
                    cond.q13(fi, raw),
                    "atom {atom} feature {fi}"
                );
            }
        }
        assert!(fpga.ops.mults > 0 && fpga.ops.adds > 0);
    }

    #[test]
    fn molecule_fpga_integration_tracks_float_euler() {
        // Drive the generic integrator with exact FF forces quantized
        // like the chip interface; it must track float semi-implicit
        // Euler closely over a short run (same tolerance class as the
        // water test).
        let mol = crate::potentials::ff::ethanol();
        let ffield = crate::potentials::MoleculeFF { mol };
        let mut sys = System::new(ffield.mol.coords.clone(), ffield.mol.masses());
        sys.pos[3] += Vec3::new(0.02, -0.015, 0.01);
        let n = sys.len();
        let dt = 0.25;
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors(&ffield.mol.coords, i, 4))
            .collect();
        let cond = FeatureConditioner::new(16, &[], &[]).unwrap();
        let mut fpga = MoleculeFpga::new(&sys, nb, cond, dt).unwrap();
        let mut float_sys = sys.clone();
        let mut forces = vec![Vec3::ZERO; n];
        ffield.compute(&float_sys.pos, &mut forces);
        let batch = n;
        let mut c = vec![Q13::ZERO; 3 * batch];
        for _ in 0..200 {
            let pos_fx = fpga.positions();
            let mut f_fx = vec![Vec3::ZERO; n];
            ffield.compute(&pos_fx, &mut f_fx);
            for i in 0..n {
                let f = f_fx[i].to_array();
                for a in 0..3 {
                    c[a * batch + i] = Q13::from_f64(f[a]);
                }
            }
            fpga.integrate_soa(&c, batch, 0);
            crate::md::euler_step(&mut float_sys, &ffield, dt, &mut forces);
        }
        for i in 0..n {
            let d = (fpga.positions()[i] - float_sys.pos[i]).norm();
            assert!(d < 0.02, "atom {i} diverged by {d} Å");
        }
        assert_eq!(fpga.steps, 200);
    }

    #[test]
    fn state_saturates_instead_of_wrapping() {
        let mut sys = eq_system();
        sys.vel[1] = Vec3::new(1e6, 0.0, 0.0); // absurd velocity
        let fpga = WaterFpga::new(&sys, 0.25);
        // encoded state must be clamped, not wrapped negative
        let v = fpga.velocities()[1];
        assert!(v.x > 0.0 && v.x <= 32.0, "v.x = {}", v.x);
    }

    #[test]
    fn pbc_features_use_minimum_image() {
        // A silicon conventional cell: every atom's neighbors are across
        // at least one periodic face for corner atoms, so the descriptor
        // must minimum-image — the non-PBC path would see ~5 Å ghosts.
        let (sw, coords) = crate::potentials::StillingerWeber::diamond_supercell(1);
        let box_l = sw.box_l;
        let n = coords.len();
        let sys = System::new(coords.clone(), vec![28.0855; n]);
        let n_nb = 4usize;
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors_pbc(&coords, i, n_nb, box_l))
            .collect();
        let cond = FeatureConditioner::new(16, &[], &[]).unwrap();
        let mut fpga =
            MoleculeFpga::new_pbc(&sys, nb.clone(), cond.clone(), 0.5, box_l).unwrap();
        assert_eq!(fpga.box_l(), Some(box_l));
        let mut feats = vec![Q13::ZERO; 16 * n];
        fpga.extract_features_soa(&mut feats, n, 0);
        // reference: the PBC descriptor on the decoded bus positions
        let bus: Vec<Vec3> = (0..n).map(|i| fpga.bus_pos(i)).collect();
        for atom in 0..n {
            let want = features::local_descriptor_pbc(&bus, atom, &nb[atom], box_l);
            for (fi, &raw) in want.iter().enumerate() {
                assert_eq!(feats[fi * n + atom], cond.q13(fi, raw), "atom {atom} feature {fi}");
            }
            // all minimum-imaged bond distances are the Si-Si bond
            // (~2.35 Å), safely on the Q13 feature grid
            for k in 0..n_nb {
                let inv_r = want[4 * k];
                assert!((1.0 / inv_r - 2.3517).abs() < 0.05, "atom {atom} nb {k}: 1/r = {inv_r}");
            }
        }
    }

    #[test]
    fn pbc_integration_wraps_positions_into_the_cell() {
        // Push an atom across the +x face: its position must re-enter at
        // 0 rather than saturate or march off the state range.
        let (sw, coords) = crate::potentials::StillingerWeber::diamond_supercell(1);
        let box_l = sw.box_l;
        let n = coords.len();
        let mut sys = System::new(coords.clone(), vec![28.0855; n]);
        sys.vel[0] = Vec3::new(0.08, 0.0, 0.0); // fast but representable
        let nb: Vec<Vec<usize>> = (0..n)
            .map(|i| features::reference_neighbors_pbc(&coords, i, 4, box_l))
            .collect();
        let cond = FeatureConditioner::new(16, &[], &[]).unwrap();
        let mut fpga = MoleculeFpga::new_pbc(&sys, nb, cond, 0.5, box_l).unwrap();
        let c = vec![Q13::ZERO; 3 * n]; // free flight
        let mut crossed = false;
        let mut prev_x = fpga.positions()[0].x;
        for _ in 0..400 {
            fpga.integrate_soa(&c, n, 0);
            let x = fpga.positions()[0].x;
            assert!((0.0..box_l).contains(&x), "x = {x} escaped [0, {box_l})");
            if x < prev_x {
                crossed = true; // wrapped through the face
            }
            prev_x = x;
        }
        assert!(crossed, "atom never crossed the periodic face");

        // box too large for the state registers is a proper error
        let bad = MoleculeFpga::new_pbc(
            &sys,
            (0..n).map(|i| features::reference_neighbors_pbc(&coords, i, 4, box_l)).collect(),
            FeatureConditioner::new(16, &[], &[]).unwrap(),
            0.5,
            40.0,
        );
        assert!(bad.is_err(), "40 Å box must exceed the 26-bit state range");
    }
}
