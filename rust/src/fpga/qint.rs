//! The FPGA's integer arithmetic, core profile: signal formats, the
//! 26-bit state registers, the semi-implicit-Euler MAC step, and the
//! feature-conditioning stage — everything module (iii) computes per
//! tick, with no float anywhere.
//!
//! The host layer (`fpga::WaterFpga` / `fpga::MoleculeFpga`, `std` only)
//! owns topology, float initialization/decoding, and op accounting; it
//! drives these functions so the two serving paths can never diverge
//! from each other — or from an embedded target compiled against the
//! core profile.

use crate::fixedpoint::{q13, shift_raw, Q13};

/// Fraction bits of the integrator state (26-bit registers).
pub const STATE_FRAC: u32 = 20;
/// Saturation bounds of the 26-bit state registers.
pub const STATE_MAX: i64 = (1 << 25) - 1;
pub const STATE_MIN: i64 = -(1 << 25);
/// Fraction bits of the per-atom dt·ACC/m constants (set by the host at
/// initialization — "CPU for initialization and control", Fig. 1).
pub const CONST_FRAC: u32 = 24;
/// Fraction bits of the dt constant.
pub const DT_FRAC: u32 = 14;
/// Working fraction of the rsqrt / conditioning pipeline.
pub const RSQRT_WORK_FRAC: u32 = 24;

/// Saturate to the 26-bit state range.
#[inline(always)]
pub fn sat_state(x: i64) -> i64 {
    x.clamp(STATE_MIN, STATE_MAX)
}

/// Round-to-nearest right shift. The integrator MUST NOT truncate
/// (arithmetic >> rounds toward −∞): a −½-LSB systematic bias on every
/// velocity increment pumps net momentum into the system — the molecule's
/// center of mass accelerates until the ±4 Å Q13 position bus saturates
/// and the geometry collapses (found the hard way; see the
/// `no_systematic_momentum_pumping` test in `fpga`).
#[inline(always)]
pub fn rshift_round(x: i64, n: u32) -> i64 {
    (x + (1i64 << (n - 1))) >> n
}

/// One axis of the semi-implicit Euler MAC (module (iii), Eqs. (2)–(3)):
///
/// ```text
/// v += F·c      F raw frac 10 × c raw frac 24 → frac 34 → state frac 20
/// r += v·dt     v frac 20 × dt raw frac 14    → frac 34 → frac 20
/// ```
///
/// with round-to-nearest renormalization (see [`rshift_round`]) and
/// 26-bit saturation on both state updates. `f_raw10` is the *rescaled*
/// force (the free 2^force_shift wire shift happens before this MAC).
/// Every integrator in the repo — water, generic molecule, and the core
/// profile's golden vectors — is this exact function.
#[inline(always)]
pub fn mac_step(pos: &mut i64, vel: &mut i64, f_raw10: i64, c_raw: i64, dt_raw: i64) {
    let mut discard = 0u64;
    mac_step_counted(pos, vel, f_raw10, c_raw, dt_raw, &mut discard);
}

/// [`mac_step`] with saturation accounting: bit-identical arithmetic,
/// plus `sat_events` is incremented once per state register the 26-bit
/// clamp actually bent (0, 1, or 2 per call). In hardware this is the
/// overflow sticky flag next to each saturating adder; the farm's
/// divergence monitor treats it as a first-class health signal rather
/// than a silent clamp.
#[inline(always)]
pub fn mac_step_counted(
    pos: &mut i64,
    vel: &mut i64,
    f_raw10: i64,
    c_raw: i64,
    dt_raw: i64,
    sat_events: &mut u64,
) {
    let dv = rshift_round(f_raw10 * c_raw, 10 + CONST_FRAC - STATE_FRAC);
    let v = *vel + dv;
    *vel = sat_state(v);
    *sat_events += (*vel != v) as u64;
    let dr = rshift_round(*vel * dt_raw, DT_FRAC);
    let p = *pos + dr;
    *pos = sat_state(p);
    *sat_events += (*pos != p) as u64;
}

/// The conditioning stage on one frac-24 raw feature: (raw − center)
/// << m, truncate to the Q13 bus, saturate — a constant subtract plus a
/// wire shift in RTL. Shared by the water datapath and the generic
/// `fpga::FeatureConditioner`, so the two can never diverge.
#[inline]
pub fn condition_raw24(raw24: i64, center_raw24: i64, shift: i32) -> Q13 {
    let centered = raw24 - center_raw24;
    let amplified = shift_raw(centered, shift);
    let q = amplified >> (RSQRT_WORK_FRAC - q13::FRAC);
    Q13(q.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32)
}

/// Truncate a 26-bit state register onto the 13-bit inter-module bus
/// (frac 20 → frac 10), saturating to the Q13 rails.
#[inline(always)]
pub fn bus_q13(state_raw: i64) -> Q13 {
    let raw = state_raw >> (STATE_FRAC - q13::FRAC);
    Q13(raw.clamp(q13::MIN_RAW as i64, q13::MAX_RAW as i64) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rshift_round_rounds_to_nearest() {
        // n = 4: ties round up (the hardware adds the half-LSB then
        // floors), negatives must not bias toward −∞.
        assert_eq!(rshift_round(7, 4), 0); // 7/16 → 0
        assert_eq!(rshift_round(8, 4), 1); // 8/16 → 1 (tie up)
        assert_eq!(rshift_round(-7, 4), 0);
        assert_eq!(rshift_round(-8, 4), 0); // −8/16 → 0 (tie up)
        assert_eq!(rshift_round(-9, 4), -1);
        assert_eq!(rshift_round(24, 4), 2); // 24/16 → 2 (tie up from 1.5)
    }

    #[test]
    fn sat_state_clamps_both_rails() {
        assert_eq!(sat_state(STATE_MAX + 1), STATE_MAX);
        assert_eq!(sat_state(STATE_MIN - 1), STATE_MIN);
        assert_eq!(sat_state(12345), 12345);
    }

    #[test]
    fn mac_step_matches_hand_computation() {
        // F = 1.0 (raw 1024 at frac 10), c = 2^-4 (frac 24), dt = 1.0
        // (frac 14), from rest at the origin:
        // dv = round(1024·2^20 / 2^14) = 2^16 (frac 20) = 1/16
        // dr = round(2^16·2^14 / 2^14) = 2^16 → pos = 1/16 on frac 20.
        let (mut pos, mut vel) = (0i64, 0i64);
        mac_step(&mut pos, &mut vel, 1024, 1i64 << 20, 1i64 << 14);
        assert_eq!(vel, 1i64 << 16);
        assert_eq!(pos, 1i64 << 16);
        // saturation: a huge force pins velocity to the rail, position
        // follows at dt·v_max
        let (mut pos, mut vel) = (0i64, 0i64);
        mac_step(&mut pos, &mut vel, 1i64 << 40, 1i64 << 24, 1i64 << 14);
        assert_eq!(vel, STATE_MAX);
        assert_eq!(pos, STATE_MAX);
    }

    #[test]
    fn mac_step_counted_is_bit_identical_and_counts_clamps() {
        // Healthy step: no clamp, no events, same state as mac_step.
        let (mut pos, mut vel) = (0i64, 0i64);
        let (mut pos2, mut vel2) = (0i64, 0i64);
        let mut events = 0u64;
        mac_step(&mut pos, &mut vel, 1024, 1i64 << 20, 1i64 << 14);
        mac_step_counted(&mut pos2, &mut vel2, 1024, 1i64 << 20, 1i64 << 14, &mut events);
        assert_eq!((pos, vel), (pos2, vel2));
        assert_eq!(events, 0);
        // Saturating step: both state registers clamp → 2 events.
        let (mut pos, mut vel) = (0i64, 0i64);
        mac_step_counted(&mut pos, &mut vel, 1i64 << 40, 1i64 << 24, 1i64 << 14, &mut events);
        assert_eq!((vel, pos), (STATE_MAX, STATE_MAX));
        assert_eq!(events, 2);
        // Once pinned at the rail with zero force, v stays exactly at
        // MAX (no clamp fires) but r keeps clamping → 1 event/step.
        mac_step_counted(&mut pos, &mut vel, 0, 1i64 << 24, 1i64 << 14, &mut events);
        assert_eq!(events, 3);
    }

    #[test]
    fn condition_raw24_centers_shifts_and_saturates() {
        // (raw − center) = 2^-4 at frac 24, gain 2^2 → 2^-2 → Q13 raw 256.
        let c = condition_raw24(1i64 << 24, (1i64 << 24) - (1i64 << 20), 2);
        assert_eq!(c, Q13(1 << 8));
        // gain pushes past the rail → saturate, both signs
        assert_eq!(condition_raw24(4 << 24, 0, 4), Q13::MAX);
        assert_eq!(condition_raw24(-(4 << 24), 0, 4), Q13::MIN);
        // negative shift is the paper's P(x, −n) arithmetic right shift
        assert_eq!(condition_raw24(1 << 24, 0, -1), Q13(1 << 9));
    }

    #[test]
    fn bus_q13_truncates_and_clamps() {
        assert_eq!(bus_q13(1i64 << 20), Q13(1 << 10)); // 1.0
        assert_eq!(bus_q13(STATE_MAX), Q13::MAX); // 32 Å clamps to the bus rail
        assert_eq!(bus_q13(STATE_MIN), Q13::MIN);
        // truncation is toward −∞ (arithmetic shift), like the wire
        assert_eq!(bus_q13(-1), Q13(-1));
    }
}
