//! Dataset generation — the training/test sets for the paper's six
//! systems (Table I / Figs. 4–5): water, ethanol, toluene, naphthalene,
//! aspirin, silicon.
//!
//! The Rust oracles are the single source of truth: `nvnmd gen-data`
//! writes `artifacts/datasets/<name>.json`, which the Python trainer
//! (L2) consumes. Water is sampled from an ensemble of re-initialized
//! NVE trajectories of the DFT-surrogate PES (mirroring the paper's
//! AIMD sampling; see `water_dataset` for why not a thermostatted run);
//! the other systems use Gaussian displacement sampling around the
//! reference geometry with forces from their oracles.

use anyhow::{Context, Result};

use crate::features;
use crate::md::{initialize_velocities, Engine, ForceField, System};
use crate::potentials::{ff, MoleculeFF, StillingerWeber, WaterPes};
use crate::util::json::{self, Value};
use crate::util::rng::Pcg;
use crate::util::Vec3;

/// A supervised dataset of feature rows → force labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub feature_dim: usize,
    pub out_dim: usize,
    pub train_x: Vec<Vec<f64>>,
    pub train_y: Vec<Vec<f64>>,
    pub test_x: Vec<Vec<f64>>,
    pub test_y: Vec<Vec<f64>>,
    /// Free-form metadata recorded in the artifact.
    pub meta: Vec<(String, Value)>,
}

/// Per-system configuration: network size grows with dataset complexity,
/// matching the paper's "model size is different according to the
/// complexity of the datasets" (§III-C).
#[derive(Debug, Clone)]
pub struct SystemSpec {
    pub name: &'static str,
    /// MLP widths including input and output.
    pub arch: Vec<usize>,
    /// Neighbors per atom in the descriptor (molecules/bulk).
    pub n_nb: usize,
    /// Displacement σ (Å) for sampling.
    pub sigma: f64,
    /// Configurations sampled.
    pub n_configs: usize,
    pub seed: u64,
}

/// The six systems in the paper's complexity order.
pub fn all_specs() -> Vec<SystemSpec> {
    vec![
        SystemSpec { name: "water", arch: vec![3, 3, 3, 2], n_nb: 2, sigma: 0.0, n_configs: 3000, seed: 101 },
        SystemSpec { name: "ethanol", arch: vec![32, 16, 16, 3], n_nb: 8, sigma: 0.035, n_configs: 320, seed: 102 },
        SystemSpec { name: "toluene", arch: vec![40, 24, 24, 3], n_nb: 10, sigma: 0.035, n_configs: 220, seed: 103 },
        SystemSpec { name: "naphthalene", arch: vec![48, 32, 32, 3], n_nb: 12, sigma: 0.035, n_configs: 190, seed: 104 },
        SystemSpec { name: "aspirin", arch: vec![56, 48, 48, 3], n_nb: 14, sigma: 0.035, n_configs: 170, seed: 105 },
        SystemSpec { name: "silicon", arch: vec![64, 64, 64, 3], n_nb: 16, sigma: 0.08, n_configs: 60, seed: 106 },
    ]
}

pub fn spec(name: &str) -> Result<SystemSpec> {
    all_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown system {name:?}"))
}

/// Generate a dataset by spec name.
pub fn generate(name: &str) -> Result<Dataset> {
    let sp = spec(name)?;
    match name {
        "water" => Ok(water_dataset(&sp)),
        "ethanol" => Ok(molecule_dataset(&sp, ff::ethanol())),
        "toluene" => Ok(molecule_dataset(&sp, ff::toluene())),
        "naphthalene" => Ok(molecule_dataset(&sp, ff::naphthalene())),
        "aspirin" => Ok(molecule_dataset(&sp, ff::aspirin())),
        "silicon" => Ok(silicon_dataset(&sp)),
        other => anyhow::bail!("unknown system {other:?}"),
    }
}

/// Water: an ensemble of short **NVE** trajectories on the DFT-surrogate
/// PES, Maxwell velocities re-drawn per trajectory; one row per hydrogen
/// per sampled frame. Features (1/r_aO, 1/r_ab, 1/r_bO); labels are the
/// local-frame force coefficients (c₁, c₂) — see `features`.
///
/// Why not one thermostatted trajectory: per-step Berendsen rescaling
/// with τ comparable to the 8 fs stretch period de-equipartitions the
/// stiff O–H modes (the "flying ice cube" artifact) — the sampled
/// stretch amplitude collapses to ~⅓ of thermal and any production run
/// immediately leaves the training manifold. Re-initialized NVE bursts
/// cover the full thermal envelope with correct mode phases. Velocities
/// are drawn at 2·T_sample because an all-kinetic start equilibrates to
/// ~half its initial temperature in a near-harmonic system.
pub fn water_dataset(sp: &SystemSpec) -> Dataset {
    let pes = WaterPes::dft_surrogate();
    let mut rng = Pcg::new(sp.seed);
    let dt = 0.25; // fs (sampling step; see DESIGN.md §Numerics)
    let t_sample = 400.0; // effective ensemble temperature (headroom over the 300 K runs)
    let sample_every = 8usize; // 2 fs between samples, like the paper's dt
    let n_traj = 32usize;
    let per_traj = (2 * sp.n_configs).div_ceil(n_traj);

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n_traj {
        let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
        initialize_velocities(&mut sys, 2.0 * t_sample, 6, &mut rng);
        let mut eng = Engine::new(sys, pes, dt);
        // dephase (NVE — the PES is conservative, no drift)
        for _ in 0..400 {
            eng.step_verlet();
        }
        let mut collected = 0usize;
        while collected < per_traj {
            for _ in 0..sample_every {
                eng.step_verlet();
            }
            let pos = &eng.sys.pos;
            let forces = eng.forces();
            for h in [1usize, 2] {
                xs.push(features::water_features(pos, h).to_vec());
                ys.push(features::water_force_to_local(pos, h, forces[h]).to_vec());
            }
            collected += 2;
        }
    }
    split(
        sp,
        xs,
        ys,
        3,
        2,
        vec![
            (
                "sampling".into(),
                json::s("32 re-initialized NVE trajectories, ~400 K effective, 2 fs stride"),
            ),
            ("force_unit".into(), json::s("eV/A (local bond frame c1,c2)")),
        ],
        &mut rng,
    )
}

/// Molecules: Gaussian displacement sampling around the reference
/// geometry; one row per heavy+light atom per configuration.
pub fn molecule_dataset(sp: &SystemSpec, mol: ff::Molecule) -> Dataset {
    let n = mol.n_atoms();
    let ffield = MoleculeFF { mol };
    let mut rng = Pcg::new(sp.seed);
    let ref_coords = ffield.mol.coords.clone();
    let nb: Vec<Vec<usize>> = (0..n)
        .map(|i| features::reference_neighbors(&ref_coords, i, sp.n_nb))
        .collect();

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut forces = vec![Vec3::ZERO; n];
    for _ in 0..sp.n_configs {
        let pos: Vec<Vec3> = ref_coords
            .iter()
            .map(|p| *p + Vec3::new(rng.normal(), rng.normal(), rng.normal()) * sp.sigma)
            .collect();
        ffield.compute(&pos, &mut forces);
        for i in 0..n {
            xs.push(features::local_descriptor(&pos, i, &nb[i]));
            let f = forces[i];
            ys.push(vec![f.x, f.y, f.z]);
        }
    }
    let fd = 4 * sp.n_nb;
    split(
        sp,
        xs,
        ys,
        fd,
        3,
        vec![
            ("n_atoms".into(), json::num(n as f64)),
            ("sampling".into(), json::s("gaussian displacement, canonical frame")),
            ("sigma_A".into(), json::num(sp.sigma)),
        ],
        &mut rng,
    )
}

/// Silicon: periodic SW supercell (2×2×2 cells, 64 atoms), displacement
/// sampling, minimum-image descriptor.
pub fn silicon_dataset(sp: &SystemSpec) -> Dataset {
    let (sw, ref_coords) = StillingerWeber::diamond_supercell(2);
    let n = ref_coords.len();
    let mut rng = Pcg::new(sp.seed);
    let nb: Vec<Vec<usize>> = (0..n)
        .map(|i| features::reference_neighbors_pbc(&ref_coords, i, sp.n_nb, sw.box_l))
        .collect();

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut forces = vec![Vec3::ZERO; n];
    for _ in 0..sp.n_configs {
        let pos: Vec<Vec3> = ref_coords
            .iter()
            .map(|p| *p + Vec3::new(rng.normal(), rng.normal(), rng.normal()) * sp.sigma)
            .collect();
        sw.compute(&pos, &mut forces);
        for i in 0..n {
            xs.push(features::local_descriptor_pbc(&pos, i, &nb[i], sw.box_l));
            ys.push(vec![forces[i].x, forces[i].y, forces[i].z]);
        }
    }
    split(
        sp,
        xs,
        ys,
        4 * sp.n_nb,
        3,
        vec![
            ("n_atoms".into(), json::num(n as f64)),
            ("box_A".into(), json::num(sw.box_l)),
            ("sampling".into(), json::s("gaussian displacement, PBC")),
        ],
        &mut rng,
    )
}

/// 80/20 train/test split (paper §IV-B), shuffled.
fn split(
    sp: &SystemSpec,
    xs: Vec<Vec<f64>>,
    ys: Vec<Vec<f64>>,
    feature_dim: usize,
    out_dim: usize,
    mut meta: Vec<(String, Value)>,
    rng: &mut Pcg,
) -> Dataset {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_train = n * 4 / 5;
    let mut d = Dataset {
        name: sp.name.to_string(),
        feature_dim,
        out_dim,
        train_x: Vec::with_capacity(n_train),
        train_y: Vec::with_capacity(n_train),
        test_x: Vec::with_capacity(n - n_train),
        test_y: Vec::with_capacity(n - n_train),
        meta: Vec::new(),
    };
    for (pos, &i) in idx.iter().enumerate() {
        if pos < n_train {
            d.train_x.push(xs[i].clone());
            d.train_y.push(ys[i].clone());
        } else {
            d.test_x.push(xs[i].clone());
            d.test_y.push(ys[i].clone());
        }
    }
    meta.push(("seed".into(), json::num(sp.seed as f64)));
    meta.push((
        "arch".into(),
        json::arr_i32(&sp.arch.iter().map(|&x| x as i32).collect::<Vec<_>>()),
    ));
    d.meta = meta;
    d
}

impl Dataset {
    pub fn to_json(&self) -> Value {
        let pack = |xs: &[Vec<f64>]| Value::Arr(xs.iter().map(|r| json::arr_f64(r)).collect());
        let mut fields = vec![
            ("name", json::s(&self.name)),
            ("feature_dim", json::num(self.feature_dim as f64)),
            ("out_dim", json::num(self.out_dim as f64)),
            ("train_x", pack(&self.train_x)),
            ("train_y", pack(&self.train_y)),
            ("test_x", pack(&self.test_x)),
            ("test_y", pack(&self.test_y)),
        ];
        let meta = Value::Obj(self.meta.clone());
        fields.push(("meta", meta));
        json::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let unpack = |key: &str| -> Result<Vec<Vec<f64>>> { v.get(key)?.as_f64_mat() };
        Ok(Dataset {
            name: v.get("name")?.as_str()?.to_string(),
            feature_dim: v.get("feature_dim")?.as_usize()?,
            out_dim: v.get("out_dim")?.as_usize()?,
            train_x: unpack("train_x")?,
            train_y: unpack("train_y")?,
            test_x: unpack("test_x")?,
            test_y: unpack("test_y")?,
            meta: v.get("meta")?.as_obj()?.to_vec(),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&json::read_file(path)?)
    }

    pub fn n_train(&self) -> usize {
        self.train_x.len()
    }
    pub fn n_test(&self) -> usize {
        self.test_x.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_have_increasing_model_size() {
        let specs = all_specs();
        let params: Vec<usize> = specs
            .iter()
            .map(|s| {
                s.arch
                    .windows(2)
                    .map(|w| w[0] * w[1] + w[1])
                    .sum::<usize>()
            })
            .collect();
        assert!(params.windows(2).all(|w| w[0] < w[1]), "params {params:?}");
        // feature dims consistent with arch input
        for s in &specs {
            if s.name != "water" {
                assert_eq!(s.arch[0], 4 * s.n_nb, "{}", s.name);
            }
        }
    }

    #[test]
    fn ethanol_dataset_shapes_and_split() {
        let mut sp = spec("ethanol").unwrap();
        sp.n_configs = 20;
        let d = molecule_dataset(&sp, ff::ethanol());
        assert_eq!(d.feature_dim, 32);
        assert_eq!(d.out_dim, 3);
        let total = d.n_train() + d.n_test();
        assert_eq!(total, 20 * 9);
        assert_eq!(d.n_train(), total * 4 / 5);
        for row in d.train_x.iter().chain(&d.test_x) {
            assert_eq!(row.len(), 32);
            assert!(row.iter().all(|v| v.is_finite()));
        }
        // forces should be nonzero and bounded for Q13 (±4)
        let max_f = d
            .train_y
            .iter()
            .flatten()
            .fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(max_f > 0.1 && max_f < 16.0, "max_f={max_f}");
    }

    #[test]
    fn water_dataset_local_frame_labels() {
        let mut sp = spec("water").unwrap();
        sp.n_configs = 60;
        let d = water_dataset(&sp);
        assert_eq!(d.feature_dim, 3);
        assert_eq!(d.out_dim, 2);
        // NVE-burst sampling rounds rows up to a whole number per burst
        let total = d.n_train() + d.n_test();
        assert!(total >= 120 && total <= 160, "total {total}");
        // features are inverse distances ⇒ around 1/0.97 ≈ 1.03 and 1/1.53
        for row in &d.train_x {
            assert!(row[0] > 0.5 && row[0] < 2.0, "1/r_aO = {}", row[0]);
            assert!(row[1] > 0.3 && row[1] < 1.5, "1/r_ab = {}", row[1]);
        }
    }

    #[test]
    fn dataset_json_roundtrip() {
        let mut sp = spec("ethanol").unwrap();
        sp.n_configs = 4;
        let d = molecule_dataset(&sp, ff::ethanol());
        let v = d.to_json();
        let back = Dataset::from_json(&v).unwrap();
        assert_eq!(back.name, d.name);
        assert_eq!(back.train_x, d.train_x);
        assert_eq!(back.test_y, d.test_y);
    }

    #[test]
    fn generation_is_deterministic() {
        let mut sp = spec("toluene").unwrap();
        sp.n_configs = 3;
        let a = molecule_dataset(&sp, ff::toluene());
        let b = molecule_dataset(&sp, ff::toluene());
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
    }

    #[test]
    fn silicon_dataset_small() {
        let mut sp = spec("silicon").unwrap();
        sp.n_configs = 2;
        let d = silicon_dataset(&sp);
        assert_eq!(d.feature_dim, 64);
        assert_eq!(d.n_train() + d.n_test(), 2 * 64);
        let max_f = d
            .train_y
            .iter()
            .flatten()
            .fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(max_f > 0.1, "silicon forces look degenerate: {max_f}");
    }
}
