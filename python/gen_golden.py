#!/usr/bin/env python3
"""Generate the core-profile golden vectors (rust/tests/core_golden.rs).

Replicates the crate's exact integer datapath — the Q13 SQNN kernel, the
phi/tanh activation units, the fixed-point rsqrt, the 26-bit integrator
MAC and the feature-conditioning stage — in arbitrary-precision Python
integers, and prints the expected outputs as Rust arrays. The Rust test
hardcodes these vectors and asserts byte-identity in BOTH build profiles
(default and --no-default-features), so the core/host refactor can never
change a single output bit without CI noticing.

Python's ``>>`` on negative ints is floor division by a power of two —
exactly the arithmetic right shift the RTL (and Rust's ``>>`` on signed
ints) performs — so every emulation below is bit-exact by construction.

Usage: python3 python/gen_golden.py   (prints the Rust const bodies)
"""

import math

# ---------------------------------------------------------------- Q13

MAX_RAW, MIN_RAW = 4095, -4096
FRAC = 10


def sat(x):
    return max(MIN_RAW, min(MAX_RAW, x))


def shift_raw(x, n):
    return x << n if n >= 0 else x >> (-n)


def round_half_away(x):
    """f64::round semantics for x >= 0."""
    f = math.floor(x)
    return f + 1 if x - f >= 0.5 else f


def phi_q13(x):
    # activation.rs::phi_q13: comparators, Q13 mul (truncate), >>2, sub.
    if x >= 2 << FRAC:
        return 1 << FRAC
    if x <= -(2 << FRAC):
        return -(1 << FRAC)
    xa = sat(-x) if x < 0 else x          # Q13::abs (saturating)
    sq = sat((x * xa) >> FRAC)            # Q13::mul
    return sat(x - sat(shift_raw(sq, -2)))  # sub(shift(-2))


def tanh_q13(x):
    # activation.rs::tanh_q13 via the baked TANH_Q13 table.
    mag = min(abs(x), MAX_RAW)
    t = round_half_away(math.tanh(mag / (1 << FRAC)) * (1 << FRAC))
    return -t if x < 0 else t


ACT = {"phi": phi_q13, "tanh": tanh_q13}

# ------------------------------------------------------------- SQNN

def forward(layers, activation, output_activation, x):
    """sqnn.rs::forward_q13_into, weights row-major (sign, [exps])."""
    cur = list(x)
    for li, (out_dim, in_dim, w, b) in enumerate(layers):
        act = li + 1 < len(layers) or output_activation
        nxt = []
        for j in range(out_dim):
            acc = b[j]  # wide accumulator
            for i in range(in_dim):
                sign, exps = w[j * in_dim + i]
                if sign == 0:
                    continue
                wsum = sum(shift_raw(cur[i], e) for e in exps)
                acc += -wsum if sign < 0 else wsum
            v = sat(acc)
            nxt.append(ACT[activation](v) if act else v)
        cur = nxt
    return cur


# The phi network: [4, 3, 2], linear output layer, k = 3.
NET_PHI = [
    (3, 4,
     [(1, [0]), (-1, [-1]), (1, [-2, -4]), (0, []),
      (-1, [1]), (1, [0, -3]), (0, []), (1, [-2]),
      (1, [-1]), (1, [-5]), (-1, [0, -2, -6]), (-1, [-3])],
     [100, -250, 37]),
    (2, 3,
     [(1, [0, -2]), (-1, [-1, -3]), (1, [-4]),
      (-1, [0]), (1, [-2]), (1, [1, -5])],
     [-64, 512]),
]
X_PHI = [
    [1024, -512, 2048, 300],
    [4095, -4096, 4095, -4096],
    [0, 0, 0, 0],
    [-37, 1, 4095, -2000],
    [123, -456, 789, -1012],
]

# The tanh network: [3, 3], activated output (exercises the table path).
NET_TANH = [
    (3, 3,
     [(1, [1]), (-1, [-2]), (1, [0]),
      (0, []), (1, [0, -1, -4]), (-1, [-2]),
      (-1, [1, -6]), (1, [-3]), (1, [0])],
     [-128, 640, 5]),
]
X_TANH = [
    [512, -1024, 2000],
    [4095, 4095, -4096],
    [-100, 200, -300],
]

# The shift-program edge network: [3, 4, 2], linear output. Exercises
# the compiler's corner cases — an all-zero-weight output row (empty
# program, bias only), a nonzero but term-free weight, and a layer
# whose every exponent is negative (pure truncating right shifts).
# Fed through the SWAR batch kernel at batch 13 = one full 8-lane tile
# plus a 5-lane ragged tail.
NET_EDGE = [
    (4, 3,
     [(1, [0]), (-1, [-2, -5]), (0, []),
      (0, []), (0, []), (0, []),
      (1, [2]), (1, [-1]), (-1, [0, -3, -7]),
      (-1, [-4]), (1, [1, 0]), (1, [])],
     [33, 700, -1200, 5]),
    (2, 4,
     [(1, [-1, -3]), (-1, [-2]), (1, [-5]), (-1, [-1]),
      (-1, [-6]), (1, [-1]), (1, [-2, -4]), (1, [-8])],
     [-77, 256]),
]
X_EDGE = [
    [4095, -4096, 4095],
    [-4096, 4095, -4096],
    [0, 0, 0],
    [1, -1, 1],
    [1024, 512, -256],
    [-1023, 77, 2048],
    [333, -333, 333],
    [2048, -2048, 1024],
    [-512, 256, -128],
    [4095, 4095, 4095],
    [-4096, -4096, -4096],
    [123, -456, 789],
    [-1012, 345, -678],
]


def program_stats(layers):
    """Mirror of Sqnn::shift_program_stats (pack-time compiler shape)."""
    weights = zero = single = ops = 0
    for (_out_dim, _in_dim, w, _b) in layers:
        for sign, exps in w:
            weights += 1
            if sign == 0:
                zero += 1
            else:
                if len(exps) == 1:
                    single += 1
                ops += len(exps)
    return weights, zero, single, ops

# ------------------------------------------------------------- rsqrt

SEED_FRAC, LUT_SIZE, WORK_FRAC = 12, 64, 24
LUT = [round_half_away((1.0 / math.sqrt(1.0 + 3.0 * (i + 0.5) / LUT_SIZE))
                       * (1 << SEED_FRAC))
       for i in range(LUT_SIZE)]


def rsqrt_raw(x_raw, frac_in, frac_out, iters):
    if x_raw <= 0:
        return (2 ** 63 - 1) // 2
    m, k = x_raw, 0
    lo, hi = 1 << frac_in, 1 << (frac_in + 2)
    while m < lo:
        m <<= 2
        k += 1
    while m >= hi:
        m >>= 2
        k -= 1
    idx = min((m - lo) * LUT_SIZE // (hi - lo), LUT_SIZE - 1)
    y = LUT[idx] << (WORK_FRAC - SEED_FRAC)
    for _ in range(iters):
        ysq = (y * y) >> WORK_FRAC
        t = (m * ysq) >> frac_in
        y = (y * ((3 << WORK_FRAC) - t)) >> (WORK_FRAC + 1)
    return shift_raw(y, k + frac_out - WORK_FRAC)


RSQRT_IN = [1 << 20, 3 << 18, 5 << 21, 1234567, 7 << 20,
            (1 << 20) * 2 + 12345, 999, 14 << 20, 1 << 26]

# --------------------------------------------------------- integrator

STATE_FRAC, CONST_FRAC, DT_FRAC = 20, 24, 14
STATE_MAX, STATE_MIN = (1 << 25) - 1, -(1 << 25)


def sat_state(x):
    return max(STATE_MIN, min(STATE_MAX, x))


def rshift_round(x, n):
    return (x + (1 << (n - 1))) >> n


def mac_step(pos, vel, f, c, dt):
    dv = rshift_round(f * c, 10 + CONST_FRAC - STATE_FRAC)
    vel = sat_state(vel + dv)
    dr = rshift_round(vel * dt, DT_FRAC)
    pos = sat_state(pos + dr)
    return pos, vel


MAC_C, MAC_DT = 174763, 4096  # arbitrary mass constant; dt = 0.25 at frac 14
MAC_FORCES = [1024, -2048, 300, -1, 0, 4095, -4096, 77]


def condition_raw24(raw24, center, shift):
    q = shift_raw(raw24 - center, shift) >> (WORK_FRAC - FRAC)
    return sat(q)


COND_IN = [  # (raw24, center_raw24, shift)
    (1 << 24, (1 << 24) - (1 << 20), 2),
    (7 << 22, 1 << 23, 1),
    (123456789, 100000000, 0),
    (4 << 24, 0, 4),
    (-(4 << 24), 0, 4),
    (1 << 24, 0, -1),
    (5555555, 7777777, 3),
]

# ------------------------------------------------------------ emit


def rust_rows(vals, per_row=8, indent="    "):
    lines = []
    for i in range(0, len(vals), per_row):
        lines.append(indent + ", ".join(str(v) for v in vals[i:i + per_row]) + ",")
    return "\n".join(lines)


def main():
    print("// NET_PHI expected (per lane, 2 outputs):")
    for x in X_PHI:
        print(f"//   {x} -> {forward(NET_PHI, 'phi', False, x)}")
    print("PHI_EXPECTED:")
    print(rust_rows([v for x in X_PHI for v in forward(NET_PHI, 'phi', False, x)]))

    print("// NET_TANH expected (per lane, 3 outputs):")
    for x in X_TANH:
        print(f"//   {x} -> {forward(NET_TANH, 'tanh', True, x)}")
    print("TANH_EXPECTED:")
    print(rust_rows([v for x in X_TANH for v in forward(NET_TANH, 'tanh', True, x)]))

    print("// NET_EDGE expected (per lane, 2 outputs):")
    for x in X_EDGE:
        print(f"//   {x} -> {forward(NET_EDGE, 'phi', False, x)}")
    print("EDGE_EXPECTED:")
    print(rust_rows([v for x in X_EDGE for v in forward(NET_EDGE, 'phi', False, x)]))

    print("PROGRAM STATS (weights, zero, single_term, ops):")
    for name, net in [("phi", NET_PHI), ("tanh", NET_TANH), ("edge", NET_EDGE)]:
        print(f"    {name}: {program_stats(net)}")

    print("RSQRT (in, out24_iters2, out10_iters1):")
    for x in RSQRT_IN:
        print(f"    ({x}, {rsqrt_raw(x, 20, 24, 2)}, {rsqrt_raw(x, 20, 10, 1)}),")

    print("MAC trajectory (f, pos, vel) from rest:")
    pos = vel = 0
    for f in MAC_FORCES:
        pos, vel = mac_step(pos, vel, f, MAC_C, MAC_DT)
        print(f"    ({f}, {pos}, {vel}),")
    print("MAC saturation (3 steps f=1<<20 c=1<<24 dt=1<<14):")
    pos = vel = 0
    for _ in range(3):
        pos, vel = mac_step(pos, vel, 1 << 20, 1 << 24, 1 << 14)
        print(f"    ({pos}, {vel}),")

    print("CONDITION (raw24, center, shift, q13):")
    for raw, c, s in COND_IN:
        print(f"    ({raw}, {c}, {s}, {condition_raw24(raw, c, s)}),")

    spots = [-4096, -2048, -2047, -1024, -333, -1, 0, 1, 777, 1024, 2047, 2048, 4095]
    print("PHI spots (in, out):")
    print("    " + ", ".join(f"({x}, {phi_q13(x)})" for x in spots))
    print("TANH spots (in, out):")
    print("    " + ", ".join(f"({x}, {tanh_q13(x)})" for x in spots))


if __name__ == "__main__":
    main()
