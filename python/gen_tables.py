#!/usr/bin/env python3
"""Regenerate the core-profile const tables.

The embedded core profile (`cargo build --no-default-features`) cannot
compute float seed tables at startup (no libm, no OnceLock), so two
tables are baked into the source as consts:

* ``rust/src/fpga/rsqrt.rs``   — ``RSQRT_SEED_LUT`` (64 entries, frac 12)
* ``rust/src/nn/tanh_table.rs``— ``TANH_Q13`` (4096 entries, frac 10)

Both are reproducible bit-for-bit from any faithfully-rounded libm: the
closest any entry comes to a rounding tie is ~8e-5 ULP-of-the-target-grid
(this script asserts the margin), while double tanh/sqrt are accurate to
<1 ulp (~1e-16 relative). Host-side Rust tests recompute each table in
float and assert exact equality, so CI proves the consts match the
expressions they replaced.

Usage: python3 python/gen_tables.py   (prints the formatted table bodies)
"""

import math

TIE_MARGIN = 1e-6


def round_half_away(x: float) -> int:
    """f64::round semantics: round half away from zero (x >= 0 here)."""
    f = math.floor(x)
    return f + 1 if x - f >= 0.5 else f


def check_tie(x: float, what: str) -> None:
    frac = x - math.floor(x)
    assert abs(frac - 0.5) > TIE_MARGIN, f"{what}: value {x} too close to a tie"


def rsqrt_seed_lut() -> list[int]:
    out = []
    for i in range(64):
        # m midpoint in [1, 4) — mirrors the original Rust expression
        m = 1.0 + 3.0 * (i + 0.5) / 64.0
        v = (1.0 / math.sqrt(m)) * float(1 << 12)
        check_tie(v, f"rsqrt lut[{i}]")
        out.append(round_half_away(v))
    return out


def tanh_q13() -> list[int]:
    out = []
    for i in range(4096):
        v = math.tanh(i / 1024.0) * 1024.0
        check_tie(v, f"tanh[{i}]")
        out.append(round_half_away(v))
    return out


def fmt_rows(vals: list[int], per: int, width: int) -> str:
    rows = []
    for r in range(0, len(vals), per):
        rows.append(
            "    " + ", ".join(str(v).rjust(width) for v in vals[r : r + per]) + ","
        )
    return "\n".join(rows)


if __name__ == "__main__":
    print("// RSQRT_SEED_LUT")
    print(fmt_rows(rsqrt_seed_lut(), 8, 4))
    print("// TANH_Q13")
    print(fmt_rows(tanh_q13(), 12, 4))
