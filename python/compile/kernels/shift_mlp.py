"""L1 Pallas kernels: the shift-quantized dense layer and the water
feature extractor.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ASIC
keeps weights stationary in distributed near-compute memory and replaces
multiplies with shift-adds. On a TPU-shaped target the analogue is

* **weights pinned in VMEM across the whole grid** — the weight
  `BlockSpec` uses a constant index map, so the same block serves every
  batch tile (no HBM re-fetch: "initialize once, never shuttle");
* **power-of-two reconstruction on the VPU, dense dot on the MXU** — the
  kernel rebuilds `w = s * sum_k 2^{n_k}` with `exp2` once per block
  (cheap VPU work) and feeds one `jnp.dot`, preserving the exact
  power-of-two numerics while using the matrix unit the hardware has;
* **φ(x) on the VPU** — already transcendental-free (Eq. 4).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the AOT artifacts must run on the Rust CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import phi

# Batch tile (VMEM-friendly; also the MXU-shaped dimension).
DEFAULT_BM = 128
# Sentinel marking an inactive shift term in the exps tensor.
INACTIVE = -127.0


def _apply_act(y, activation):
    # True/False accepted as phi/None for backwards compatibility.
    if activation is True or activation == "phi":
        return phi(y)
    if activation == "tanh":
        return jnp.tanh(y)
    assert activation is None or activation is False, \
        f"unknown activation {activation!r}"
    return y


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w.T, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = _apply_act(y, activation)


def _shift_dense_kernel(x_ref, s_ref, e_ref, b_ref, o_ref, *, activation: bool):
    x = x_ref[...]
    sign = s_ref[...]
    exps = e_ref[...]
    b = b_ref[...]
    # VPU: reconstruct the power-of-two weights once per block.
    mags = jnp.where(exps > -100.0, jnp.exp2(exps), 0.0).sum(axis=-1)
    w = sign * mags
    # MXU: one dense dot against the reconstructed block.
    y = jnp.dot(x, w.T, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = _apply_act(y, activation)


def _pad_batch(x, bm):
    n = x.shape[0]
    padded = ((n + bm - 1) // bm) * bm
    if padded == n:
        return x, n
    pad = jnp.zeros((padded - n, x.shape[1]), x.dtype)
    return jnp.concatenate([x, pad], axis=0), n


def dense(x, w, b, *, activation, bm: int = DEFAULT_BM, interpret: bool = True):
    """Pallas dense layer: y = act(x @ w.T + b).

    x: (batch, in); w: (out, in); b: (out,); activation in
    {"phi", "tanh", None}. Batch is tiled by `bm`; weight/bias blocks use
    constant index maps (VMEM-resident).
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    xp, n = _pad_batch(x, bm)
    nout, nin = w.shape
    grid = (xp.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nin), lambda i: (i, 0)),
            pl.BlockSpec((nout, nin), lambda i: (0, 0)),  # stationary
            pl.BlockSpec((nout,), lambda i: (0,)),        # stationary
        ],
        out_specs=pl.BlockSpec((bm, nout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], nout), jnp.float32),
        interpret=interpret,
    )(xp, w, b)
    return out[:n]


def shift_dense(x, sign, exps, b, *, activation, bm: int = DEFAULT_BM,
                interpret: bool = True):
    """Pallas shift-quantized dense layer.

    sign: (out, in) in {-1, 0, +1}; exps: (out, in, K) with INACTIVE
    sentinels; b: (out,).
    """
    x = jnp.asarray(x, jnp.float32)
    sign = jnp.asarray(sign, jnp.float32)
    exps = jnp.asarray(exps, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    xp, n = _pad_batch(x, bm)
    nout, nin = sign.shape
    k = exps.shape[-1]
    grid = (xp.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_shift_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nin), lambda i: (i, 0)),
            pl.BlockSpec((nout, nin), lambda i: (0, 0)),     # stationary
            pl.BlockSpec((nout, nin, k), lambda i: (0, 0, 0)),  # stationary
            pl.BlockSpec((nout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, nout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], nout), jnp.float32),
        interpret=interpret,
    )(xp, sign, exps, b)
    return out[:n]


def mlp(x, layers, *, activation: str = "phi", activation_output: bool = False,
        bm: int = DEFAULT_BM, interpret: bool = True):
    """Full MLP as a chain of Pallas dense layers. layers: [(w, b), ...];
    hidden layers use `activation`, the output layer is linear unless
    `activation_output`."""
    h = x
    for i, (w, b) in enumerate(layers):
        last = i == len(layers) - 1
        act = activation if ((not last) or activation_output) else None
        h = dense(h, w, b, activation=act, bm=bm, interpret=interpret)
    return h


def shift_mlp(x, layers, *, activation: str = "phi",
              activation_output: bool = False, bm: int = DEFAULT_BM,
              interpret: bool = True):
    """Full shift-quantized MLP. layers: [(sign, exps, b), ...]."""
    h = x
    for i, (s, e, b) in enumerate(layers):
        last = i == len(layers) - 1
        act = activation if ((not last) or activation_output) else None
        h = shift_dense(h, s, e, b, activation=act, bm=bm, interpret=interpret)
    return h


# ----------------------------------------------------------------------
# Water feature extraction kernel (module (i) of Fig. 2).
# ----------------------------------------------------------------------

def _water_features_kernel(pos_ref, feats_ref, uho_ref, uhh_ref):
    pos = pos_ref[...]
    o, h1, h2 = pos[0], pos[1], pos[2]

    def inv_norm(v):
        return jax.lax.rsqrt(jnp.sum(v * v))

    d1o = o - h1
    d12 = h2 - h1
    d2o = o - h2
    i1o = inv_norm(d1o)
    i12 = inv_norm(d12)
    i2o = inv_norm(d2o)
    feats_ref[0, 0] = i1o
    feats_ref[0, 1] = i12
    feats_ref[0, 2] = i2o
    feats_ref[1, 0] = i2o
    feats_ref[1, 1] = i12
    feats_ref[1, 2] = i1o
    uho_ref[0, :] = d1o * i1o
    uho_ref[1, :] = d2o * i2o
    uhh_ref[0, :] = d12 * i12
    uhh_ref[1, :] = -d12 * i12


def water_features(pos, *, interpret: bool = True):
    """pos (3,3) [O,H1,H2] -> (feats (2,3), u_ho (2,3), u_hh (2,3))."""
    pos = jnp.asarray(pos, jnp.float32)
    return pl.pallas_call(
        _water_features_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((2, 3), jnp.float32),
            jax.ShapeDtypeStruct((2, 3), jnp.float32),
            jax.ShapeDtypeStruct((2, 3), jnp.float32),
        ),
        interpret=interpret,
    )(pos)


def pack_shift_layer(w, k):
    """Quantize a float (out,in) weight matrix into (sign, exps) tensors
    for `shift_dense` using the exact exporter quantizer."""
    import numpy as np
    from ..quantize import quantize_pow2_exact

    w = np.asarray(w, dtype=np.float64)
    nout, nin = w.shape
    sign = np.zeros((nout, nin), dtype=np.float32)
    exps = np.full((nout, nin, k), INACTIVE, dtype=np.float32)
    for i in range(nout):
        for j in range(nin):
            s, es, _v = quantize_pow2_exact(float(w[i, j]), k)
            sign[i, j] = s
            for t, n in enumerate(es):
                exps[i, j, t] = n
    return sign, exps
