"""Pure-jnp oracles for the Pallas kernels (the L1 correctness
reference). Every kernel in this package must match its `ref_*` twin to
float32 tolerance; `tests/test_kernel.py` sweeps shapes with hypothesis."""

from __future__ import annotations

import jax.numpy as jnp


def phi(x):
    """The paper's hardware activation, Eq. (4)."""
    return jnp.where(x >= 2.0, 1.0, jnp.where(x <= -2.0, -1.0, x - x * jnp.abs(x) / 4.0))


def ref_dense(x, w, b, activation):
    """y = act(x @ w.T + b); w is (out, in) row-major like the Rust side.
    activation in {"phi", "tanh", None} (True/False accepted as phi/None
    for backwards compatibility)."""
    y = x @ w.T + b[None, :]
    if activation is True or activation == "phi":
        return phi(y)
    if activation == "tanh":
        return jnp.tanh(y)
    return y


def ref_shift_dense(x, sign, exps, b, activation):
    """Dense layer with weights reconstructed from shift parameters:
    w = sign * sum_k 2^{exps_k}, inactive terms marked with exps <= -100.

    sign: (out, in); exps: (out, in, K); b: (out,).
    """
    mags = jnp.where(exps > -100.0, jnp.exp2(exps), 0.0).sum(axis=-1)
    w = sign * mags
    return ref_dense(x, w, b, activation)


def ref_mlp(x, layers, activation: str = "phi",
            activation_output: bool = False):
    """layers: list of (w, b); hidden layers use `activation`, output
    linear unless activation_output."""
    h = x
    for i, (w, b) in enumerate(layers):
        last = i == len(layers) - 1
        act = activation if ((not last) or activation_output) else None
        h = ref_dense(h, w, b, activation=act)
    return h


def ref_water_features(pos):
    """pos: (3, 3) rows [O, H1, H2] -> features (2, 3) and local frames.

    Features per hydrogen: (1/r_aO, 1/r_ab, 1/r_bO); frames are the unit
    vectors (u_HO, u_HH) used to reconstruct Cartesian forces.
    Returns (feats[2,3], u_ho[2,3], u_hh[2,3]).
    """
    o, h1, h2 = pos[0], pos[1], pos[2]

    def one(a, b):
        d_ao = o - a
        d_ab = b - a
        d_bo = o - b
        r_ao = jnp.linalg.norm(d_ao)
        r_ab = jnp.linalg.norm(d_ab)
        r_bo = jnp.linalg.norm(d_bo)
        feats = jnp.stack([1.0 / r_ao, 1.0 / r_ab, 1.0 / r_bo])
        return feats, d_ao / r_ao, d_ab / r_ab

    f1, u1o, u1h = one(h1, h2)
    f2, u2o, u2h = one(h2, h1)
    return (
        jnp.stack([f1, f2]),
        jnp.stack([u1o, u2o]),
        jnp.stack([u1h, u2h]),
    )
