"""AOT lowering: JAX (L2, with the L1 Pallas kernels inlined) -> HLO
*text* artifacts for the Rust PJRT runtime.

HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md). All functions are lowered
with `return_tuple=True` and unwrapped with `to_tuple()` on the Rust side.

Artifacts (contracts consumed by `rust/src/runtime`):

* water_mlp.hlo.txt       f32[2,3] -> (f32[2,2],)    QNN-K3 water model
* water_mlp_cnn.hlo.txt   f32[2,3] -> (f32[2,2],)    CNN-phi float model
* water_md_step.hlo.txt   (f32[3,3], f32[3,3]) -> (f32[3,3], f32[3,3])
* water_deepmd.hlo.txt    f32[2,3] -> (f32[2,2],)    DeePMD-style model
* water_mlp_shiftkernel.hlo.txt  same as water_mlp but through the
  shift-reconstruction kernel (L1 numerics demonstration)

Usage: python -m compile.aot --models ../artifacts/models --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path, text):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def lower_mlp(model, batch):
    """The artifact contract takes *raw physical* features and returns
    *physical* outputs: the feature conditioning and the power-of-two
    output_scale are baked into the lowered graph."""
    layers = model["layers"]
    scale = model["output_scale"]

    def fn(x):
        xt = M.condition_features(x, model)
        y = M.mlp_forward(xt, layers, activation=model["activation"],
                          output_activation=model["output_activation"])
        return (y * scale,)

    spec = jax.ShapeDtypeStruct((batch, model["arch"][0]), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_shift_mlp(model, batch):
    scale = model["output_scale"]

    def fn(x):
        xt = M.condition_features(x, model)
        return (M.shift_mlp_forward(xt, model) * scale,)

    spec = jax.ShapeDtypeStruct((batch, model["arch"][0]), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_md_step(model, dt):
    def fn(pos, vel):
        return M.water_md_step(pos, vel, model, dt)

    spec = jax.ShapeDtypeStruct((3, 3), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="../artifacts/models")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dt", type=float, default=0.25, help="MD step, fs")
    args = ap.parse_args()

    load = functools.partial(os.path.join, args.models)
    qnn = M.load_model_json(load("water_qnn_k3.json"))
    cnn = M.load_model_json(load("water_cnn_phi.json"))
    deepmd = M.load_model_json(load("water_deepmd_like.json"))

    write(os.path.join(args.out, "water_mlp.hlo.txt"), lower_mlp(qnn, 2))
    write(os.path.join(args.out, "water_mlp_cnn.hlo.txt"), lower_mlp(cnn, 2))
    write(os.path.join(args.out, "water_deepmd.hlo.txt"), lower_mlp(deepmd, 2))
    write(os.path.join(args.out, "water_md_step.hlo.txt"),
          lower_md_step(qnn, args.dt))
    write(os.path.join(args.out, "water_mlp_shiftkernel.hlo.txt"),
          lower_shift_mlp(qnn, 2))
    print("[aot] done")


if __name__ == "__main__":
    main()
