"""Power-of-two weight quantization (paper Eqs. (5)-(9)) and fixed-point
quantization of signals, with straight-through estimators for QAT.

Two implementations are provided:

* :func:`quantize_pow2_exact` -- float64 numpy, bit-identical to the Rust
  `quant::quantize_weight` (same ceiling fix-ups, same clamping). Used at
  export time; parity is asserted against Rust-generated test vectors
  (``artifacts/quant_vectors.json``) by ``tests/test_quantize.py``.
* :func:`quantize_pow2_jnp` -- vectorized jnp version used inside the QAT
  training loss (wrapped with an STE).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# Hardware exponent range (rust quant::EXP_MIN/MAX).
EXP_MIN = -16
EXP_MAX = 15

# Q(1,2,10): the system's 13-bit fixed-point format.
Q13_FRAC = 10
Q13_SCALE = 1 << Q13_FRAC
Q13_MAX = (1 << 12) - 1
Q13_MIN = -(1 << 12)


def basis_exponent(w: float) -> int:
    """Eq. (8): ceil(log2(w / 1.5)) with exact fix-up, w > 0."""
    y = w / 1.5
    n = int(np.ceil(np.log2(y)))
    while 2.0 ** (n - 1) >= y:
        n -= 1
    while 2.0 ** n < y:
        n += 1
    return n


def quantize_pow2_exact(w: float, k: int):
    """Greedy K-term decomposition; returns (sign, [exponents], value).

    Mirrors rust `quant::quantize_weight` exactly (clamping, residual
    flush below 2^(EXP_MIN-1), Eq. (7)'s max(.,0) early stop).
    """
    if w == 0.0 or not np.isfinite(w):
        return 0, [], 0.0
    sign = 1 if w > 0 else -1
    residual = abs(w)
    exps = []
    for _ in range(k):
        if residual <= 2.0 ** (EXP_MIN - 1):
            break
        n = int(np.clip(basis_exponent(residual), EXP_MIN, EXP_MAX))
        exps.append(n)
        residual = max(residual - 2.0 ** n, 0.0)
        if residual == 0.0:
            break
    value = sign * sum(2.0 ** n for n in exps)
    return sign, exps, value


def quantize_matrix_exact(w: np.ndarray, k: int) -> np.ndarray:
    """Elementwise exact quantization; returns the dequantized values."""
    flat = np.asarray(w, dtype=np.float64).ravel()
    out = np.array([quantize_pow2_exact(float(v), k)[2] for v in flat])
    return out.reshape(np.shape(w))


def quantize_pow2_jnp(w: jnp.ndarray, k: int) -> jnp.ndarray:
    """Vectorized greedy power-of-two quantization (dequantized values).

    Same algorithm as the exact version, in jnp (float32-friendly). A
    double fix-up of the ceiling handles log2 rounding at exact powers.
    """
    sign = jnp.sign(w)
    residual = jnp.abs(w)
    total = jnp.zeros_like(w)
    for _ in range(k):
        y = residual / 1.5
        safe_y = jnp.where(y > 0, y, 1.0)
        n = jnp.ceil(jnp.log2(safe_y))
        for _fix in range(2):
            n = jnp.where(jnp.exp2(n - 1) >= safe_y, n - 1, n)
            n = jnp.where(jnp.exp2(n) < safe_y, n + 1, n)
        n = jnp.clip(n, EXP_MIN, EXP_MAX)
        q = jnp.exp2(n)
        active = residual > 2.0 ** (EXP_MIN - 1)
        q = jnp.where(active, q, 0.0)
        total = total + q
        residual = jnp.maximum(residual - q, 0.0)
    return sign * total


def ste(fn, x):
    """Straight-through estimator: forward fn(x), identity gradient."""
    return x + jax.lax.stop_gradient(fn(x) - x)


def quantize_pow2_ste(w: jnp.ndarray, k: int) -> jnp.ndarray:
    return ste(lambda v: quantize_pow2_jnp(v, k), w)


def quantize_q13(x: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest Q(1,2,10) quantization with saturation."""
    r = jnp.clip(jnp.round(x * Q13_SCALE), Q13_MIN, Q13_MAX)
    return r / Q13_SCALE


def quantize_q13_ste(x: jnp.ndarray) -> jnp.ndarray:
    return ste(quantize_q13, x)
