"""Quantization-aware training pipeline (build-time only).

Consumes the Rust-generated datasets (`artifacts/datasets/*.json`, the
oracles are the single source of truth) and trains, per system:

* CNN-tanh and CNN-phi float baselines (Table I),
* QNN K=1..5: initialized from CNN-phi, fine-tuned with power-of-two
  weight STE + Q(1,2,10) signal STE (paper §III-C's "load the pre-trained
  CNN baseline model, quantify the weights, and train based on the
  pre-trained model") (Fig. 4),
* a DeePMD-style larger float model for water (Table II/III baseline).

Exports rust-readable model JSONs to `artifacts/models/`, with QNN
weights stored as their *exact dequantized* power-of-two sums so the Rust
`Sqnn` re-derives identical shift parameters (idempotence of the greedy
quantizer; asserted in tests).

Usage: python -m compile.train --datasets ../artifacts/datasets \
           --out ../artifacts/models [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import quantize as Q
from .kernels.ref import phi

jax.config.update("jax_enable_x64", False)

# Physical force per unit of network output (eV/Å). Labels are divided by
# this before training so the Q(1,2,10) output range [-4, 4) covers the
# force distribution without saturation; the hardware undoes it with a
# free power-of-two shift at reconstruction (fpga::force_shift).
OUTPUT_SCALE = 4.0


def feature_conditioning(tx):
    """Per-dimension centering + per-dimension power-of-two gains.

    Raw inverse-distance features vary by <1% around large constants —
    hopeless conditioning for both training and a 13-bit datapath. The
    FPGA feature module subtracts programmed constants and applies a
    per-feature left shift (both free in RTL), mapping each feature's
    excursion onto ~±2 of the Q(1,2,10) range. Returns (center, gains)."""
    center = tx.mean(axis=0)
    dev = np.maximum(np.abs(tx - center).max(axis=0), 1e-6)
    m = np.clip(np.floor(np.log2(2.0 / dev)), 0, 12)
    return center.astype(np.float64), (2.0 ** m).astype(np.float64)


# ----------------------------------------------------------------------
# Forward passes (training side, plain jnp for speed under grad).
# ----------------------------------------------------------------------

def act(name, x):
    return jnp.tanh(x) if name == "tanh" else phi(x)


def forward_float(params, x, activation):
    h = x
    for i, (w, b) in enumerate(params):
        y = h @ w.T + b[None, :]
        h = act(activation, y) if i < len(params) - 1 else y
    return h


def forward_qat(params, x, k):
    """QAT forward: Q13 signals, power-of-two weights, phi activation."""
    h = Q.quantize_q13_ste(x)
    for i, (w, b) in enumerate(params):
        wq = Q.quantize_pow2_ste(w, k)
        bq = Q.quantize_q13_ste(b)
        y = h @ wq.T + bq[None, :]
        if i < len(params) - 1:
            h = Q.quantize_q13_ste(phi(y))
        else:
            h = Q.quantize_q13_ste(y)
    return h


def forward_frozen(params, x):
    """Deployment-exact forward: weights are *already* on the pow2 grid
    (not re-quantized, no STE), biases and signals Q13-quantized. Used by
    the bias-refinement stage, whose gradients flow only into biases."""
    h = Q.quantize_q13_ste(x)
    for i, (w, b) in enumerate(params):
        bq = Q.quantize_q13_ste(b)
        y = h @ w.T + bq[None, :]
        if i < len(params) - 1:
            h = Q.quantize_q13_ste(phi(y))
        else:
            h = Q.quantize_q13_ste(y)
    return h


def refine_biases(params, k, x, y, epochs, lr):
    """Freeze weights on their exact power-of-two grid values and train
    only the biases against the deployment-exact forward. Stabilizes the
    noisy QAT endpoint (the deployed weights no longer move, so this
    directly minimizes the deployed loss). Returns deployment params."""
    ws = [jnp.asarray(Q.quantize_matrix_exact(np.asarray(w, np.float64), k),
                      jnp.float32) for (w, _b) in params]
    bs = [b for (_w, b) in params]
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)

    def loss_fn(bs):
        p = [(w, b) for (w, b) in zip(ws, bs)]
        pred = forward_frozen(p, x)
        return jnp.mean((pred - y) ** 2)

    # Adam over the bias pytree only.
    m = [jnp.zeros_like(b) for b in bs]
    v = [jnp.zeros_like(b) for b in bs]

    @jax.jit
    def step(carry, _):
        bs, m, v, t = carry
        loss, grads = jax.value_and_grad(loss_fn)(bs)
        t = t + 1.0
        new = []
        for i, (b, g) in enumerate(zip(bs, grads)):
            m[i] = 0.9 * m[i] + 0.1 * g
            v[i] = 0.999 * v[i] + 0.001 * g * g
            mh = m[i] / (1 - 0.9 ** t)
            vh = v[i] / (1 - 0.999 ** t)
            new.append(b - lr * mh / (jnp.sqrt(vh) + 1e-8))
        return (new, m, v, t), loss

    (bs, _m, _v, _t), _losses = jax.lax.scan(
        step, (bs, m, v, jnp.zeros(())), None, length=epochs)
    return [(w, b) for (w, b) in zip(ws, bs)]


def rmse_frozen(params, x, y):
    pred = forward_frozen(params, jnp.asarray(x))
    return float(jnp.sqrt(jnp.mean((pred - jnp.asarray(y)) ** 2)))


def freeze(params, k):
    """Snap weights onto the exact pow2 grid (no bias change)."""
    return [
        (jnp.asarray(Q.quantize_matrix_exact(np.asarray(w, np.float64), k),
                     jnp.float32), b)
        for (w, b) in params
    ]


def s_rmse_frozen_of(params, k, x, y):
    return rmse_frozen(freeze(params, k), x, y)


# ----------------------------------------------------------------------
# Hand-rolled Adam (optax unavailable offline).
# ----------------------------------------------------------------------

def adam_init(params):
    zeros = [(jnp.zeros_like(w), jnp.zeros_like(b)) for (w, b) in params]
    return {"m": zeros, "v": [(jnp.zeros_like(w), jnp.zeros_like(b)) for (w, b) in params], "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    new_m, new_v, new_p = [], [], []
    for (p, g, m, v) in zip(params, grads, state["m"], state["v"]):
        layer_p, layer_m, layer_v = [], [], []
        for (pi, gi, mi, vi) in zip(p, g, m, v):
            mi = b1 * mi + (1 - b1) * gi
            vi = b2 * vi + (1 - b2) * gi * gi
            mhat = mi / (1 - b1 ** t)
            vhat = vi / (1 - b2 ** t)
            layer_p.append(pi - lr * mhat / (jnp.sqrt(vhat) + eps))
            layer_m.append(mi)
            layer_v.append(vi)
        new_p.append(tuple(layer_p))
        new_m.append(tuple(layer_m))
        new_v.append(tuple(layer_v))
    return new_p, {"m": new_m, "v": new_v, "t": t}


def init_params(arch, seed):
    rng = np.random.RandomState(seed)
    params = []
    for nin, nout in zip(arch[:-1], arch[1:]):
        w = rng.randn(nout, nin).astype(np.float32) / np.sqrt(nin)
        b = np.zeros(nout, dtype=np.float32)
        params.append((jnp.asarray(w), jnp.asarray(b)))
    return params


def train_model(x, y, arch, activation, epochs, lr, seed, qat_k=0,
                init=None, log_every=0, name=""):
    """Full-batch Adam training; returns (params, final train loss)."""
    params = init if init is not None else init_params(arch, seed)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)

    def loss_fn(params):
        pred = forward_qat(params, x, qat_k) if qat_k > 0 else \
            forward_float(params, x, activation)
        return jnp.mean((pred - y) ** 2)

    state = adam_init(params)

    @jax.jit
    def step(carry, _):
        params, state = carry
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_update(params, grads, state, lr)
        return (params, state), loss

    (params, state), losses = jax.lax.scan(step, (params, state), None,
                                           length=epochs)
    final = float(losses[-1])
    if log_every:
        print(f"    {name}: loss {float(losses[0]):.3e} -> {final:.3e}")
    return params, final


def rmse(params, x, y, activation, qat_k=0):
    pred = forward_qat(params, jnp.asarray(x), qat_k) if qat_k > 0 else \
        forward_float(params, jnp.asarray(x), activation)
    return float(jnp.sqrt(jnp.mean((pred - jnp.asarray(y)) ** 2)))


# ----------------------------------------------------------------------
# Export.
# ----------------------------------------------------------------------

def export_model(path, name, params, activation, quant_k, metrics,
                 output_scale=1.0, feature_center=None, feature_scale=1.0):
    layers = []
    for (w, b) in params:
        w = np.asarray(w, dtype=np.float64)
        b = np.asarray(b, dtype=np.float64)
        if quant_k > 0:
            w = Q.quantize_matrix_exact(w, quant_k)
            b = np.clip(np.round(b * Q.Q13_SCALE), Q.Q13_MIN, Q.Q13_MAX) / Q.Q13_SCALE
        layers.append({"w": w.tolist(), "b": b.tolist()})
    arch = [np.asarray(params[0][0]).shape[1]] + [np.asarray(w).shape[0] for (w, _b) in params]
    doc = {
        "name": name,
        "arch": arch,
        "activation": activation,
        "output_activation": False,
        "quant_k": quant_k,
        "output_scale": output_scale,
        "feature_center": [] if feature_center is None else
            np.asarray(feature_center, dtype=np.float64).tolist(),
        "feature_scale": np.asarray(feature_scale, dtype=np.float64).tolist()
            if np.ndim(feature_scale) else feature_scale,
        "layers": layers,
        "metrics": metrics,
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def load_dataset(path):
    with open(path) as f:
        d = json.load(f)
    return {
        "name": d["name"],
        "arch": [int(a) for a in d["meta"]["arch"]],
        "train_x": np.asarray(d["train_x"], dtype=np.float32),
        "train_y": np.asarray(d["train_y"], dtype=np.float32),
        "test_x": np.asarray(d["test_x"], dtype=np.float32),
        "test_y": np.asarray(d["test_y"], dtype=np.float32),
    }


def train_system(ds, out_dir, quick=False, log=print):
    """Run the full model zoo for one dataset; returns metrics dict.

    All labels are trained in scaled units (F / OUTPUT_SCALE); metrics
    are reported back in physical eV/Å.
    """
    name = ds["name"]
    arch = ds["arch"]
    s = OUTPUT_SCALE
    center, gain = feature_conditioning(ds["train_x"])
    tx = (ds["train_x"] - center) * gain
    vx = (ds["test_x"] - center) * gain
    ty, vy = ds["train_y"] / s, ds["test_y"] / s
    schedule = [(1000, 4e-3), (1000, 1e-3)] if quick else \
        [(4000, 4e-3), (4000, 1e-3), (4000, 2e-4)]
    ft_schedule = [(800, 1e-3)] if quick else [(3000, 1e-3), (2000, 2e-4)]
    results = {}
    t0 = time.time()
    common = dict(output_scale=s, feature_center=center, feature_scale=gain)

    def fit(activation, qat_k=0, init=None, schedule=schedule, tag=""):
        params = init
        for (ep, lr) in schedule:
            params, _ = train_model(tx, ty, arch, activation, ep, lr, seed=7,
                                    qat_k=qat_k, init=params, name=tag)
        return params

    # CNN baselines (Table I).
    for activation in ("tanh", "phi"):
        params = fit(activation, tag=f"{name}-cnn-{activation}")
        m = {
            "train_rmse": s * rmse(params, tx, ty, activation),
            "test_rmse": s * rmse(params, vx, vy, activation),
        }
        results[f"cnn_{activation}"] = m
        export_model(os.path.join(out_dir, f"{name}_cnn_{activation}.json"),
                     f"{name}_cnn_{activation}", params, activation, 0, m,
                     **common)
        if activation == "phi":
            phi_params = params

    # QNN K=1..5 (Fig. 4): fine-tune from the CNN-phi baseline with the
    # paper's pre-training strategy (§III-C), then a deployment-exact
    # bias-refinement stage (weights frozen on the pow2 grid).
    ref_epochs = 600 if quick else 2500
    for k in range(1, 6):
        params = fit("phi", qat_k=k, init=phi_params, schedule=ft_schedule,
                     tag=f"{name}-qnn-k{k}")
        refined = refine_biases(params, k, tx, ty, ref_epochs, 1e-3)
        # keep whichever deployment config is better on the train split
        if rmse_frozen(refined, tx, ty) > s_rmse_frozen_of(params, k, tx, ty):
            refined = freeze(params, k)
        m = {
            "train_rmse": s * rmse_frozen(refined, tx, ty),
            "test_rmse": s * rmse_frozen(refined, vx, vy),
        }
        results[f"qnn_k{k}"] = m
        # weights already exact grid values ⇒ quant_k re-derivation in the
        # exporter is lossless
        export_model(os.path.join(out_dir, f"{name}_qnn_k{k}.json"),
                     f"{name}_qnn_k{k}", refined, "phi", k, m, **common)

    log(f"  {name}: done in {time.time() - t0:.1f}s "
        f"(cnn_phi test {results['cnn_phi']['test_rmse']:.4f}, "
        f"qnn_k3 test {results['qnn_k3']['test_rmse']:.4f})")
    return results


def train_deepmd_like(ds, out_dir, quick=False, log=print):
    """The DeePMD-style baseline: same features, much larger tanh net."""
    arch = [ds["arch"][0], 60, 60, 60, ds["arch"][-1]]
    s = OUTPUT_SCALE
    center, gain = feature_conditioning(ds["train_x"])
    tx = (ds["train_x"] - center) * gain
    vx = (ds["test_x"] - center) * gain
    ty, vy = ds["train_y"] / s, ds["test_y"] / s
    params = None
    for (ep, lr) in ([(1500, 2e-3)] if quick else [(4000, 2e-3), (4000, 3e-4)]):
        params, _ = train_model(tx, ty, arch, "tanh", ep, lr, seed=11,
                                init=params, name="deepmd-like")
    m = {
        "train_rmse": s * rmse(params, tx, ty, "tanh"),
        "test_rmse": s * rmse(params, vx, vy, "tanh"),
    }
    export_model(os.path.join(out_dir, "water_deepmd_like.json"),
                 "water_deepmd_like", params, "tanh", 0, m, output_scale=s,
                 feature_center=center, feature_scale=gain)
    log(f"  deepmd-like: test rmse {m['test_rmse']:.4f}")
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="../artifacts/datasets")
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--quick", action="store_true",
                    help="fewer epochs (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="train a single system by name")
    args = ap.parse_args()

    systems = ["water", "ethanol", "toluene", "naphthalene", "aspirin", "silicon"]
    if args.only:
        systems = [args.only]
    all_metrics = {}
    for name in systems:
        path = os.path.join(args.datasets, f"{name}.json")
        if not os.path.exists(path):
            print(f"  !! missing dataset {path}, skipping")
            continue
        print(f"[train] {name}")
        ds = load_dataset(path)
        all_metrics[name] = train_system(ds, args.out, quick=args.quick)
        if name == "water":
            all_metrics["water_deepmd_like"] = train_deepmd_like(
                ds, args.out, quick=args.quick)
    with open(os.path.join(args.out, "metrics.json"), "w") as f:
        json.dump(all_metrics, f, indent=1)
    print("[train] metrics written")


if __name__ == "__main__":
    main()
