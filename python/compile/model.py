"""L2: the MLMD compute graph in JAX, built on the L1 Pallas kernels.

Three jit-able entry points (all AOT-exported by `aot.py`):

* :func:`mlp_forward` -- batched MLP force evaluation (module (ii));
* :func:`water_md_step` -- one full MD step for the water molecule:
  feature extraction -> MLP -> local-frame force reconstruction ->
  Newton's-third-law oxygen force -> semi-implicit Euler (Eqs. 2-3);
* the same graph with the shift-quantized kernel for QNN models.

Python never runs on the request path: these functions are lowered once
to HLO text and executed by the Rust PJRT runtime.
"""

from __future__ import annotations

import json

import numpy as np
import jax.numpy as jnp

from .kernels import shift_mlp as kernels

# Units (mirrors rust util::units).
ACC_CONV = 9.648533212331e-3  # (eV/Å/amu) -> Å/fs²
MASS_O = 15.9994
MASS_H = 1.00794


def load_model_json(path):
    """Load a trained model artifact (the schema rust `Mlp` reads)."""
    with open(path) as f:
        doc = json.load(f)
    layers = [
        (np.asarray(l["w"], dtype=np.float32), np.asarray(l["b"], dtype=np.float32))
        for l in doc["layers"]
    ]
    return {
        "name": doc["name"],
        "arch": doc["arch"],
        "activation": doc["activation"],
        "output_activation": bool(doc.get("output_activation", False)),
        "quant_k": int(doc.get("quant_k", 0)),
        "output_scale": float(doc.get("output_scale", 1.0)),
        "feature_center": np.asarray(doc.get("feature_center", []),
                                     dtype=np.float32),
        "feature_scale": np.asarray(doc.get("feature_scale", 1.0),
                                    dtype=np.float32),
        "layers": layers,
    }


def condition_features(x, model):
    """The FPGA feature-conditioning stage: centered + per-dim pow2
    gains (broadcasts a scalar gain too)."""
    center = model["feature_center"]
    if center.size == 0:
        return x
    scale = jnp.asarray(model["feature_scale"])
    if scale.ndim == 1:
        scale = scale[None, :]
    return (x - center[None, :]) * scale


def mlp_forward(x, layers, *, activation="phi", output_activation=False,
                interpret=True):
    """Batched MLP forward through the Pallas dense kernel."""
    return kernels.mlp(x, layers, activation=activation,
                       activation_output=output_activation,
                       interpret=interpret)


def shift_mlp_forward(x, model, *, interpret=True):
    """Batched forward through the *shift* kernel: weights quantized with
    the exact exporter quantizer, reconstructed in-kernel (L1 numerics).
    """
    k = max(model["quant_k"], 1)
    packed = [
        kernels.pack_shift_layer(w, k) + (b,)
        for (w, b) in model["layers"]
    ]
    return kernels.shift_mlp(x, packed, activation=model["activation"],
                             activation_output=model["output_activation"],
                             interpret=interpret)


def water_forces(pos, model, *, interpret=True):
    """Forces on [O, H1, H2] from the MLP (module (ii) + reconstruction).

    `model` is a dict from :func:`load_model_json` (or a compatible toy):
    the feature conditioning (FPGA constant-subtract + pow2 gain) and the
    output rescale (pow2 shift) are both part of the contract.
    """
    feats, u_ho, u_hh = kernels.water_features(pos, interpret=interpret)
    x = condition_features(feats, model)
    c = mlp_forward(x, model["layers"], activation=model["activation"],
                    output_activation=model["output_activation"],
                    interpret=interpret) * model["output_scale"]  # (2, 2)
    f_h = c[:, 0:1] * u_ho + c[:, 1:2] * u_hh  # (2, 3)
    f_o = -(f_h[0] + f_h[1])
    return jnp.concatenate([f_o[None, :], f_h], axis=0)  # (3, 3)


def water_md_step(pos, vel, model, dt, *, interpret=True):
    """One semi-implicit-Euler MD step (paper Eqs. (2)-(3)).

    pos, vel: (3, 3) float32 rows [O, H1, H2]. Returns (pos', vel').
    """
    masses = jnp.array([MASS_O, MASS_H, MASS_H], dtype=jnp.float32)
    f = water_forces(pos, model, interpret=interpret)
    vel2 = vel + f * (ACC_CONV * dt) / masses[:, None]
    pos2 = pos + vel2 * dt
    return pos2, vel2


def toy_model(layers, output_scale=1.0):
    """Wrap raw layers in the model-dict contract (tests)."""
    return {
        "name": "toy",
        "arch": [np.asarray(layers[0][0]).shape[1]]
        + [np.asarray(w).shape[0] for (w, _b) in layers],
        "activation": "phi",
        "output_activation": False,
        "quant_k": 0,
        "output_scale": output_scale,
        "feature_center": np.asarray([], dtype=np.float32),
        "feature_scale": np.asarray(1.0, dtype=np.float32),
        "layers": layers,
    }
