"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes/values with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import shift_mlp as K


def rand(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 200),
    nin=st.integers(1, 40),
    nout=st.integers(1, 40),
    activation=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_dense_matches_ref(batch, nin, nout, activation, seed):
    x = rand((batch, nin), seed)
    w = rand((nout, nin), seed + 1, 0.5)
    b = rand((nout,), seed + 2, 0.2)
    got = np.asarray(K.dense(x, w, b, activation=activation, bm=64))
    want = np.asarray(ref.ref_dense(x, w, b, activation))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 64),
    nin=st.integers(1, 16),
    nout=st.integers(1, 16),
    k=st.integers(1, 5),
    seed=st.integers(0, 1000),
)
def test_shift_dense_matches_ref(batch, nin, nout, k, seed):
    x = rand((batch, nin), seed)
    w = rand((nout, nin), seed + 1, 0.8)
    b = rand((nout,), seed + 2, 0.2)
    s, e = K.pack_shift_layer(w, k)
    got = np.asarray(K.shift_dense(x, s, e, b, activation=True, bm=32))
    want = np.asarray(ref.ref_shift_dense(x, s, e, b, True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_shift_dense_weights_are_exact_pow2_sums():
    w = np.array([[0.5, -1.25], [0.3, 0.0]], dtype=np.float32)
    s, e = K.pack_shift_layer(w, 3)
    # reconstruct
    mags = np.where(e > -100, np.exp2(e), 0.0).sum(axis=-1)
    rec = s * mags
    assert rec[0, 0] == 0.5
    assert rec[0, 1] == -1.25
    assert rec[1, 1] == 0.0
    # 0.3 -> 0.25 + 0.0625 = 0.3125 (greedy overshoot clip)
    assert abs(rec[1, 0] - 0.3125) < 1e-7


def test_mlp_chain_matches_ref():
    rng = np.random.RandomState(3)
    layers = []
    dims = [3, 5, 4, 2]
    for nin, nout in zip(dims[:-1], dims[1:]):
        layers.append((rng.randn(nout, nin).astype(np.float32) * 0.5,
                       rng.randn(nout).astype(np.float32) * 0.1))
    x = rng.randn(17, 3).astype(np.float32)
    got = np.asarray(K.mlp(x, layers, bm=8))
    want = np.asarray(ref.ref_mlp(x, layers))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_phi_definition():
    x = np.linspace(-4, 4, 401).astype(np.float32)
    y = np.asarray(ref.phi(x))
    assert y.max() == 1.0 and y.min() == -1.0
    i = np.argmin(np.abs(x - 1.0))
    assert abs(y[i] - 0.75) < 1e-6
    # odd function
    np.testing.assert_allclose(y, -y[::-1], atol=1e-6)


def test_water_features_kernel_matches_ref():
    pos = np.array([[0.0, 0.1, 0.0],
                    [0.77, 0.65, 0.02],
                    [-0.75, 0.63, -0.03]], dtype=np.float32)
    f, uho, uhh = K.water_features(pos)
    rf, ruho, ruhh = ref.ref_water_features(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(rf), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(uho), np.asarray(ruho), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(uhh), np.asarray(ruhh), rtol=1e-5, atol=1e-5)


def test_water_features_invariance():
    base = np.array([[0.0, 0.0, 0.0],
                     [0.766, 0.593, 0.0],
                     [-0.766, 0.593, 0.0]], dtype=np.float32)
    f0, _, _ = ref.ref_water_features(base)
    # translation
    f1, _, _ = ref.ref_water_features(base + np.array([1.0, -2.0, 0.5], dtype=np.float32))
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1), atol=1e-5)
    # rotation about z by 30 deg
    c, s = np.cos(0.5236), np.sin(0.5236)
    rot = np.array([[c, -s, 0], [s, c, 0], [0, 0, 1]], dtype=np.float32)
    f2, _, _ = ref.ref_water_features(base @ rot.T)
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f2), atol=1e-4)


def test_batch_padding_edge_cases():
    w = rand((4, 3), 0, 0.5)
    b = rand((4,), 1, 0.1)
    for batch in [1, 63, 64, 65, 128, 129]:
        x = rand((batch, 3), batch)
        got = np.asarray(K.dense(x, w, b, activation=True, bm=64))
        want = np.asarray(ref.ref_dense(x, w, b, True))
        assert got.shape == (batch, 4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
