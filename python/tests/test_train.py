"""Training pipeline smoke: a tiny synthetic regression must converge,
QAT export must be exactly power-of-two, and model JSON must match the
schema the Rust loader expects."""

import json
import os

import numpy as np

from compile import train as T
from compile import quantize as Q


def tiny_problem(n=400, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.uniform(-1, 1, size=(n, 3)).astype(np.float32)
    y = np.stack([
        0.8 * x[:, 0] - 0.3 * x[:, 1] ** 2,
        0.5 * np.sin(2 * x[:, 2]),
    ], axis=1).astype(np.float32)
    return x, y


def test_float_training_converges():
    x, y = tiny_problem()
    params, loss = T.train_model(x, y, [3, 8, 8, 2], "phi", 1500, 4e-3, seed=1)
    assert loss < 0.01, loss
    assert T.rmse(params, x, y, "phi") < 0.1


def test_qat_training_converges_and_exports_pow2(tmp_path):
    x, y = tiny_problem()
    params, _ = T.train_model(x, y, [3, 8, 8, 2], "phi", 1200, 4e-3, seed=1)
    qat, loss = T.train_model(x, y, [3, 8, 8, 2], "phi", 600, 1e-3, seed=1,
                              qat_k=3, init=params)
    assert loss < 0.03, loss
    doc = T.export_model(str(tmp_path / "m.json"), "m", qat, "phi", 3,
                         {"test_rmse": 0.0})
    # every exported weight is an exact sum of <=3 powers of two
    for layer in doc["layers"]:
        for row in layer["w"]:
            for w in row:
                _s, exps, v = Q.quantize_pow2_exact(w, 3)
                assert v == w, (w, v)
                assert len(exps) <= 3


def test_export_schema_matches_rust_loader(tmp_path):
    x, y = tiny_problem()
    params, _ = T.train_model(x, y, [3, 4, 2], "tanh", 200, 4e-3, seed=2)
    path = str(tmp_path / "model.json")
    T.export_model(path, "schema_check", params, "tanh", 0, {"note": 1})
    with open(path) as f:
        doc = json.load(f)
    assert doc["arch"] == [3, 4, 2]
    assert doc["activation"] in ("tanh", "phi")
    assert isinstance(doc["output_activation"], bool)
    assert len(doc["layers"]) == 2
    assert len(doc["layers"][0]["w"]) == 4
    assert len(doc["layers"][0]["w"][0]) == 3
    assert len(doc["layers"][1]["b"]) == 2


def test_dataset_loader_roundtrip(tmp_path):
    ds = {
        "name": "t", "feature_dim": 2, "out_dim": 1,
        "train_x": [[1, 2], [3, 4]], "train_y": [[0.5], [1.5]],
        "test_x": [[5, 6]], "test_y": [[2.5]],
        "meta": {"arch": [2, 3, 1]},
    }
    p = tmp_path / "t.json"
    p.write_text(json.dumps(ds))
    out = T.load_dataset(str(p))
    assert out["arch"] == [2, 3, 1]
    assert out["train_x"].shape == (2, 2)
    assert out["test_y"].shape == (1, 1)
