"""L2 graph tests: water forces/md_step physics invariants and the AOT
lowering path."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


def toy_layers(seed=0, scale=0.4):
    rng = np.random.RandomState(seed)
    dims = [3, 3, 3, 2]
    return [
        (rng.randn(nout, nin).astype(np.float32) * scale,
         rng.randn(nout).astype(np.float32) * 0.05)
        for nin, nout in zip(dims[:-1], dims[1:])
    ]


def water_pos(dtype=np.float32):
    th = np.deg2rad(104.88) / 2
    r = 0.969
    return np.array(
        [[0, 0, 0],
         [r * np.sin(th), r * np.cos(th), 0],
         [-r * np.sin(th), r * np.cos(th), 0]],
        dtype=dtype,
    )


def test_water_forces_sum_to_zero():
    model = M.toy_model(toy_layers())
    f = np.asarray(M.water_forces(water_pos(), model))
    assert f.shape == (3, 3)
    np.testing.assert_allclose(f.sum(axis=0), 0.0, atol=1e-6)


def test_water_forces_equivariance():
    model = M.toy_model(toy_layers())
    pos = water_pos()
    f0 = np.asarray(M.water_forces(pos, model))
    ang = 0.7
    c, s = np.cos(ang), np.sin(ang)
    rot = np.array([[c, 0, s], [0, 1, 0], [-s, 0, c]], dtype=np.float32)
    f1 = np.asarray(M.water_forces(pos @ rot.T, model))
    np.testing.assert_allclose(f1, f0 @ rot.T, atol=2e-5)


def test_md_step_semi_implicit_euler():
    model = M.toy_model(toy_layers(), output_scale=4.0)
    pos = water_pos()
    vel = np.zeros((3, 3), dtype=np.float32)
    dt = 0.25
    p2, v2 = M.water_md_step(pos, vel, model, dt)
    f = np.asarray(M.water_forces(pos, model))
    masses = np.array([M.MASS_O, M.MASS_H, M.MASS_H], dtype=np.float32)
    v_expect = f * (M.ACC_CONV * dt) / masses[:, None]
    np.testing.assert_allclose(np.asarray(v2), v_expect, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(p2), pos + v_expect * dt,
                               rtol=1e-5, atol=1e-7)


def test_md_step_momentum_conserved():
    model = M.toy_model(toy_layers(seed=5))
    pos = water_pos()
    vel = np.zeros((3, 3), dtype=np.float32)
    masses = np.array([M.MASS_O, M.MASS_H, M.MASS_H], dtype=np.float32)
    p, v = jnp.asarray(pos), jnp.asarray(vel)
    for _ in range(50):
        p, v = M.water_md_step(p, v, model, 0.25)
    momentum = (np.asarray(v) * masses[:, None]).sum(axis=0)
    np.testing.assert_allclose(momentum, 0.0, atol=1e-4)


def test_mlp_forward_equals_ref():
    layers = toy_layers(seed=2)
    x = np.random.RandomState(1).randn(7, 3).astype(np.float32)
    got = np.asarray(M.mlp_forward(x, layers))
    want = np.asarray(ref.ref_mlp(x, layers))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_aot_lowering_roundtrip():
    """Lower the md_step to HLO text and sanity-check the module."""
    from compile.aot import to_hlo_text

    model = M.toy_model(toy_layers(seed=3))

    def fn(pos, vel):
        return M.water_md_step(pos, vel, model, 0.25)

    spec = jax.ShapeDtypeStruct((3, 3), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[3,3]" in text
    # tuple return convention for the rust loader
    assert "(f32[3,3]" in text
