"""Quantizer correctness: python exact quantizer vs paper equations and
vs the Rust implementation (cross-language parity via exported vectors)."""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def test_basis_exponent_examples():
    assert Q.basis_exponent(1.0) == 0
    assert Q.basis_exponent(1.5) == 0
    assert Q.basis_exponent(1.6) == 1
    assert Q.basis_exponent(0.75) == -1
    for m in range(-10, 10):
        assert Q.basis_exponent(2.0 ** m) == m


@settings(max_examples=300, deadline=None)
@given(w=st.floats(-4.0, 4.0, allow_nan=False), k=st.integers(1, 5))
def test_exact_quantizer_error_bound(w, k):
    sign, exps, value = Q.quantize_pow2_exact(w, k)
    if w == 0.0:
        assert sign == 0 and value == 0.0
        return
    m = len(exps)
    if m == 0:
        assert abs(w) <= 2.0 ** (Q.EXP_MIN - 1)
        return
    # relative 3^-m bound, plus one hardware-floor LSB (2^EXP_MIN) for
    # weights small enough that exponent clamping engages
    assert abs(value - w) <= abs(w) * 3.0 ** (-m) + 2.0 ** Q.EXP_MIN + 1e-12
    assert all(Q.EXP_MIN <= e <= Q.EXP_MAX for e in exps)
    assert all(a >= b for a, b in zip(exps, exps[1:]))


@settings(max_examples=100, deadline=None)
@given(w=st.floats(-3.9, 3.9, allow_nan=False), k=st.integers(1, 5))
def test_jnp_quantizer_matches_exact(w, k):
    got = float(Q.quantize_pow2_jnp(np.float32(w), k))
    _s, _e, want = Q.quantize_pow2_exact(float(np.float32(w)), k)
    assert got == pytest.approx(want, abs=2e-6), (w, k)


def test_idempotence_of_exact_quantizer():
    # A greedy-produced value must re-quantize to itself (the property the
    # QNN export relies on: rust Sqnn re-derives identical shift params).
    rng = np.random.RandomState(0)
    for _ in range(500):
        w = float(rng.uniform(-3, 3))
        for k in (1, 3, 5):
            _s, _e, v = Q.quantize_pow2_exact(w, k)
            _s2, _e2, v2 = Q.quantize_pow2_exact(v, k) if v != 0 else (0, [], 0.0)
            assert v2 == v, (w, k, v, v2)


def test_q13_quantization():
    assert float(Q.quantize_q13(np.float32(1.0))) == 1.0
    assert float(Q.quantize_q13(np.float32(100.0))) == pytest.approx(4095 / 1024)
    assert float(Q.quantize_q13(np.float32(-100.0))) == -4.0
    x = np.float32(0.123456)
    assert abs(float(Q.quantize_q13(x)) - 0.123456) <= 0.5 / 1024 + 1e-7


def test_parity_with_rust_vectors():
    """artifacts/quant_vectors.json is produced by `nvnmd gen-data`
    (rust quant::quantize_weight on a deterministic grid)."""
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "quant_vectors.json")
    if not os.path.exists(path):
        pytest.skip("quant_vectors.json not built (run `make artifacts`)")
    with open(path) as f:
        vectors = json.load(f)["vectors"]
    assert len(vectors) >= 100
    for v in vectors:
        s, exps, value = Q.quantize_pow2_exact(v["w"], int(v["k"]))
        assert s == v["sign"], v
        assert exps == v["exps"], v
        assert value == pytest.approx(v["value"], abs=1e-12), v
