//! Quickstart: program the two MLP chips with the trained water model,
//! run a short MD trajectory on the heterogeneous system, and print the
//! measured geometry plus the hardware ledger.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use nvnmd::analysis::WaterSeries;
use nvnmd::coordinator::{ParallelMode, WaterSystem};
use nvnmd::hw::timing::CLOCK_HZ;
use nvnmd::md::{initialize_velocities, System};
use nvnmd::nn::Mlp;
use nvnmd::potentials::WaterPes;
use nvnmd::util::rng::Pcg;

fn main() -> Result<()> {
    // 1. The trained, quantization-aware water model (QNN, K = 3).
    let model_path = nvnmd::artifact_path("models/water_qnn_k3.json");
    let model = Mlp::load(&model_path)
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    println!("model: {} (arch {:?}, K = {})", model.name, model.arch(), model.quant_k);

    // 2. Initial condition: equilibrium geometry + 300 K velocities.
    let pes = WaterPes::dft_surrogate();
    let mut sys = System::new(pes.equilibrium(), WaterPes::masses());
    initialize_velocities(&mut sys, 300.0, 6, &mut Pcg::new(7));

    // 3. The heterogeneous system: FPGA (features + integration) + two
    //    ASIC MLP chips on worker threads, exactly the paper's Fig. 1.
    let mut hw = WaterSystem::new(&model, model.quant_k.max(3), &sys, 0.25, ParallelMode::Threaded)?;

    // 4. Run 20 000 steps (5 ps), sampling geometry every 10 steps.
    let mut series = WaterSeries::default();
    hw.run(20_000, 10, |pos| series.push(pos))?;

    println!("\nafter {} frames:", series.len());
    println!("  mean O–H bond  = {:.3} Å   (paper NvN row: 0.968)", series.mean_bond_length());
    println!("  mean H–O–H     = {:.2}°   (paper NvN row: 104.85)", series.mean_angle());

    let ledger = hw.finish()?;
    println!("\nhardware ledger:");
    println!("  MD steps            {}", ledger.md_steps);
    println!("  chip inferences     {}", ledger.chip_inferences);
    println!("  modelled cycles     {}", ledger.modelled_cycles);
    println!("  modelled time       {:.3} s @ 25 MHz", ledger.hw_seconds(CLOCK_HZ));
    println!("  S                   {:.2e} s/step/atom (paper: 1.6e-6)", ledger.s_per_step_atom(CLOCK_HZ));
    println!("  host simulation     {:.2?}", ledger.host_wall);
    Ok(())
}
