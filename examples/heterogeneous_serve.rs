//! Batch inference service on the chip pool — the coordinator reused as
//! a force-evaluation server: N simulated MLP chips behind a round-robin
//! router, serving batched feature requests (the shape of a vLLM-style
//! serving tier, with ASIC simulators as the backend).
//!
//!     make artifacts && cargo run --release --example heterogeneous_serve

use std::time::Instant;

use anyhow::Result;

use nvnmd::asic::{ChipConfig, MlpChip};
use nvnmd::coordinator::pool::ChipPool;
use nvnmd::fixedpoint::Q13;
use nvnmd::nn::Mlp;
use nvnmd::util::rng::Pcg;

fn main() -> Result<()> {
    let model = Mlp::load(&nvnmd::artifact_path("models/water_qnn_k3.json"))
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    let k = model.quant_k.max(3);

    for n_chips in [1usize, 2, 4, 8] {
        let chips: Vec<MlpChip> = (0..n_chips)
            .map(|id| {
                let mut c = MlpChip::new(id, ChipConfig::default());
                c.program(&model, k);
                c
            })
            .collect();
        let mut pool = ChipPool::spawn(chips)?;

        // Synthesize a request stream: batches of feature rows.
        let mut rng = Pcg::new(99);
        let batches: Vec<Vec<Vec<Q13>>> = (0..50)
            .map(|_| {
                (0..64)
                    .map(|_| {
                        (0..3)
                            .map(|_| Q13::from_f64(rng.range(0.4, 1.4)))
                            .collect()
                    })
                    .collect()
            })
            .collect();

        let t0 = Instant::now();
        let mut served = 0usize;
        for batch in &batches {
            let out = pool.infer_batch(batch)?;
            served += out.len();
        }
        let wall = t0.elapsed();
        let (inferences, cycles, _ops) = pool.stats()?;
        assert_eq!(inferences as usize, served);

        // Modelled hardware throughput: each chip retires one inference
        // per `latency` cycles; N chips in parallel.
        let latency = cycles / inferences;
        let hw_rate = n_chips as f64 * ChipConfig::default().clock_hz / latency as f64;
        println!(
            "{n_chips} chip(s): served {served} inferences in {:?} host-wall \
             ({:.0}/s); modelled hw rate {:.2e}/s @ 25 MHz",
            wall,
            served as f64 / wall.as_secs_f64(),
            hw_rate
        );
    }
    println!("\nThroughput scales with the chip count — the paper's \"higher");
    println!("intra-ASIC parallelization\" argument (§VI) in service form.");
    Ok(())
}
