//! End-to-end validation driver (EXPERIMENTS.md §E7): reproduce the
//! paper's Table II on this testbed — run all four methods (DFT
//! surrogate, vN-MLMD via PJRT, NvN-MLMD fixed-point hardware, and the
//! DeePMD-style baseline) from identical initial conditions, extract
//! bond length / angle / vibration frequencies, and print the error
//! rows.
//!
//!     make artifacts && cargo run --release --example water_properties
//!     (add --quick for a fast smoke run)

use anyhow::Result;

use nvnmd::exp::table2;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = table2::Config::with_quick(quick);
    println!(
        "running 4 methods × {} steps × {} fs (seed {})…\n",
        cfg.steps, cfg.dt, cfg.seed
    );
    let report = table2::run(cfg)?;
    println!("{}", report.render());
    if let Some(p) = &report.saved_to {
        println!("[saved: {}]", p.display());
    }

    // The strict-13-bit ablation: what Table II would look like if the
    // integrator state were truly 13 bits wide (DESIGN.md §Numerics).
    if !quick {
        println!("\n--- ablation: strict 13-bit integrator state ---");
        let mut cfg13 = cfg;
        cfg13.strict13 = true;
        cfg13.steps = cfg.steps / 4;
        let r13 = table2::run(cfg13)?;
        println!("{}", r13.render());
    }
    Ok(())
}
