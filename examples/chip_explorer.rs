//! Hardware design-space explorer: sweep datapath width, shift count K,
//! and network size through the synthesis + energy models — the tooling
//! a hardware engineer would use before committing an architecture to
//! tape-out. No trained artifacts required.
//!
//!     cargo run --release --example chip_explorer

use nvnmd::hw::power::{EnergyModel, ProcessNode};
use nvnmd::hw::synth::{self, mlp_netlist, WeightDatapath};
use nvnmd::util::table;

fn main() {
    // 1. Activation circuits (paper Fig. 3b).
    println!("== activation circuits ==");
    let tanh = synth::tanh_cordic_unit(synth::CORDIC_BITS, synth::CORDIC_ITERS).transistors();
    let phi = synth::phi_unit(synth::Q13_BITS).transistors();
    println!("  CORDIC tanh : {tanh:>7} T (paper 50418)");
    println!("  phi unit    : {phi:>7} T (paper  4098)");
    println!("  ratio       : {:.1}% (paper 8%)\n", 100.0 * phi as f64 / tanh as f64);

    // 2. Width sweep of the phi unit: what would a wider datapath cost?
    println!("== phi unit vs datapath width ==");
    let rows: Vec<Vec<String>> = [8u64, 10, 13, 16, 20, 24]
        .iter()
        .map(|&bits| {
            let t = synth::phi_unit(bits).transistors();
            vec![format!("{bits}-bit"), t.to_string()]
        })
        .collect();
    print!("{}", table::render(&["width", "transistors"], &rows));

    // 3. K sweep on the water MLP (chip sizing for the tape-out).
    println!("\n== water MLP [3,3,3,2]: shift terms vs multiplier baseline ==");
    let fqnn = mlp_netlist(&[3, 3, 3, 2], synth::FQNN_BITS, WeightDatapath::Multiplier).transistors();
    let mut rows = vec![vec!["FQNN 16-bit mult".to_string(), fqnn.to_string(), "100%".to_string()]];
    for k in 1..=5 {
        let t = mlp_netlist(&[3, 3, 3, 2], synth::Q13_BITS, WeightDatapath::Shift { k }).transistors();
        rows.push(vec![
            format!("SQNN K={k}"),
            t.to_string(),
            format!("{:.0}%", 100.0 * t as f64 / fqnn as f64),
        ]);
    }
    print!("{}", table::render(&["datapath", "transistors", "vs FQNN"], &rows));

    // 4. Per-inference dynamic energy across process nodes.
    println!("\n== per-op energy across nodes (pJ) ==");
    let rows: Vec<Vec<String>> = [ProcessNode::N180, ProcessNode::N45, ProcessNode::N14]
        .iter()
        .map(|&node| {
            let e = EnergyModel::at(node);
            vec![
                format!("{:.0} nm @ {:.1} V", node.nm, node.vdd),
                format!("{:.3}", e.add13_pj),
                format!("{:.3}", e.shift13_pj),
                format!("{:.3}", e.mult13_pj),
                format!("{:.1}", e.dram_pj),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(&["node", "add13", "shift13", "mult13", "DRAM/16b (the wall)"], &rows)
    );
    println!("\nThe last column is the paper's argument in one number: a single");
    println!("off-chip access costs more than hundreds of on-chip shift-adds —");
    println!("keeping weights resident (NvN) removes exactly that term.");
}
